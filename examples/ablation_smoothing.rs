//! Smoothing ablation example (paper §6 / Figure 4 at example scale):
//! trains SageBwd with {no smoothing, K-smoothing, QK-smoothing} plus the
//! FPA reference, and prints the final-loss ranking.
//!
//! Runs on the native training engine by default (no artifacts, no XLA);
//! pass `--backend xla` for the AOT path.
//!
//! ```text
//! cargo run --release --example ablation_smoothing -- [--steps 60] [--tps 1024]
//! ```

use anyhow::Result;
use sagebwd::cli::Args;
use sagebwd::config::TrainConfig;
use sagebwd::coordinator::{RunStatus, TrainerFactory};
use sagebwd::telemetry::{run_dir, Log};

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let steps = args.u64_or("steps", 60)?;
    let tps = args.u64_or("tps", 1024)?;
    let factory = TrainerFactory::new(
        args.str_or("backend", "native"),
        sagebwd::DEFAULT_ARTIFACTS_DIR,
    )?;
    let log = Log::new(true);

    let grid = [
        ("fpa_qknorm", "(reference)"),
        ("sage_qknorm_nosm", "no smoothing"),
        ("sage_qknorm", "K-smoothing"),
        ("sage_qknorm_qksm", "QK-smoothing"),
    ];
    let mut results = Vec::new();
    for (variant, label) in grid {
        log.info(&format!("=== {label} ({variant}) ==="));
        let cfg = TrainConfig {
            variant: variant.into(),
            steps,
            tokens_per_step: tps,
            warmup_steps: (steps / 10).max(1),
            peak_lr: 3e-3,
            min_lr_frac: 0.1,
            seed: 0,
            clip_norm: 0.0,
            grad_noise_sigma: 0.0,
            checkpoint_every: 0,
            log_every: (steps / 6).max(1),
            ..TrainConfig::default()
        };
        let mut trainer = factory.trainer(cfg)?;
        let mut batches = trainer.make_batcher(512, 4)?;
        let report = trainer.run(&mut batches, &log)?;
        let dir = run_dir(
            sagebwd::DEFAULT_RESULTS_DIR,
            &format!("ablation_smoothing/{variant}"),
        )?;
        trainer.metrics.flush_csv(&dir)?;
        results.push((label, report));
    }

    println!("\n=== smoothing ablation summary (paper §6) ===");
    for (label, report) in &results {
        let status = match report.status {
            RunStatus::Completed => "ok".to_string(),
            RunStatus::Diverged { at_step } => format!("DIVERGED@{at_step}"),
        };
        println!(
            "  {label:<14} final loss {:>8}   [{status}]",
            report
                .final_loss
                .map(|l| format!("{l:.4}"))
                .unwrap_or("-".into())
        );
    }
    println!("(paper: K-smoothing required for stability; Q-smoothing no consistent gain)");
    Ok(())
}
