//! Intermediate-tensor error tracing example (paper §5.4 / Table 2 and the
//! §4.2 dS-magnitude analysis): runs the pseudo-quantized FPA trace and
//! prints per-tensor CosSim / Rel-ℓ2, highlighting the dS bottleneck.
//!
//! Runs anywhere on the native CPU kernels (`--backend xla` switches to
//! the AOT artifacts).
//!
//! ```text
//! cargo run --release --example error_trace [-- --backend native|xla]
//! ```

use anyhow::Result;
use sagebwd::cli::Args;
use sagebwd::experiments::common::{gaussian_qkvdo, run_trace};
use sagebwd::runtime::make_backend;
use sagebwd::util::stats::{cossim, rel_l2};

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let mut be = make_backend(
        args.str_or("backend", "native"),
        args.str_or("artifacts", sagebwd::DEFAULT_ARTIFACTS_DIR),
    )?;

    // Trained-regime surrogate: grown QK norms, small upstream gradient.
    let qkvdo = gaussian_qkvdo(128, 64, 4.0, 4.0, 1.0, 0.02, 42);
    let pseudo = run_trace(be.as_mut(), "trace_pseudo", &qkvdo)?;
    let fpa = run_trace(be.as_mut(), "trace_fpa", &qkvdo)?;

    println!("Per-tensor error, SageBwd INT8 quantize-dequantize vs exact FPA (§5.4):\n");
    println!("{:<8} {:>10} {:>10}", "tensor", "cossim", "rel-l2");
    let rows = [
        ("delta", &pseudo.delta, &fpa.delta),
        ("P", &pseudo.p, &fpa.p),
        ("dP", &pseudo.dp, &fpa.dp),
        ("dS", &pseudo.ds, &fpa.ds),
        ("O", &pseudo.o, &fpa.o),
        ("dQ", &pseudo.dq, &fpa.dq),
        ("dK", &pseudo.dk, &fpa.dk),
        ("dV", &pseudo.dv, &fpa.dv),
    ];
    let mut worst = ("", 0.0f64);
    for (name, s, f) in rows {
        let r = rel_l2(&s.data, &f.data);
        println!("{:<8} {:>10.4} {:>10.4}", name, cossim(&s.data, &f.data), r);
        if r > worst.1 && name != "dQ" && name != "dK" {
            worst = (name, r);
        }
    }
    println!("\nRMS magnitudes (§4.2): P {:.3e}, dP {:.3e}, dS {:.3e}",
             fpa.rms_p, fpa.rms_dp, fpa.rms_ds);
    println!("largest non-downstream error: {} — the paper's dS bottleneck", worst.0);
    Ok(())
}
