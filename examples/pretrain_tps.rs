//! **End-to-end driver** (the repository's E2E validation run): pre-train
//! the scaled Llama with SageBwd INT8 attention and with full-precision
//! attention at low tokens-per-step, on the synthetic corpus, logging both
//! loss curves — the Figure-1b experiment at example scale.
//!
//! Runs on the native training engine by default (no artifacts, no XLA —
//! a bare checkout works); pass `--backend xla` for the AOT path.
//!
//! ```text
//! cargo run --release --example pretrain_tps -- [--steps 120] [--tps 1024]
//! ```

use anyhow::Result;
use sagebwd::cli::Args;
use sagebwd::config::TrainConfig;
use sagebwd::coordinator::TrainerFactory;
use sagebwd::telemetry::{run_dir, Log};

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let steps = args.u64_or("steps", 120)?;
    let tps = args.u64_or("tps", 1024)?;
    let factory = TrainerFactory::new(
        args.str_or("backend", "native"),
        sagebwd::DEFAULT_ARTIFACTS_DIR,
    )?;
    let log = Log::new(true);

    let mut outcomes = Vec::new();
    for variant in ["sage_qknorm", "fpa_qknorm"] {
        log.info(&format!("=== pretraining {variant} ==="));
        let cfg = TrainConfig {
            variant: variant.into(),
            steps,
            tokens_per_step: tps,
            warmup_steps: (steps / 10).max(1),
            peak_lr: 3e-3,
            min_lr_frac: 0.1,
            seed: 0,
            clip_norm: 0.0,
            grad_noise_sigma: 0.0,
            checkpoint_every: 0,
            log_every: (steps / 12).max(1),
            ..TrainConfig::default()
        };
        let mut trainer = factory.trainer(cfg)?;
        let mut batches = trainer.make_batcher(512, 4)?;
        let report = trainer.run(&mut batches, &log)?;
        let dir = run_dir(sagebwd::DEFAULT_RESULTS_DIR, &format!("pretrain_tps/{variant}"))?;
        trainer.metrics.flush_csv(&dir)?;
        trainer.save_checkpoint(&dir.join("final.ckpt"))?;
        log.info(&format!(
            "{variant}: {:?} final_loss={:?} tokens={}  → {}",
            report.status,
            report.final_loss,
            report.tokens_seen,
            dir.display()
        ));
        outcomes.push((variant, report.final_loss));
    }

    println!("\n=== E2E summary (Figure 1b analogue) ===");
    for (variant, loss) in &outcomes {
        println!("  {variant:<14} final loss {:?}", loss);
    }
    if let (Some(sage), Some(fpa)) = (outcomes[0].1, outcomes[1].1) {
        println!(
            "  gap (sage − fpa) = {:+.4}   (paper at low TPS: −0.002, parity within noise)",
            sage - fpa
        );
    }
    Ok(())
}
