//! Quickstart: run one SageBwd forward+backward on random tensors and
//! compare against exact attention — the 60-second tour of the stack.
//!
//! Runs anywhere on the native CPU kernels; pass `--backend xla` (after
//! `make artifacts`) to execute the AOT XLA artifacts instead.
//!
//! ```text
//! cargo run --release --example quickstart [-- --backend native|xla]
//! ```

use anyhow::Result;
use sagebwd::cli::Args;
use sagebwd::runtime::{make_backend, Value};
use sagebwd::tensor::Tensor;
use sagebwd::util::rng::Pcg64;
use sagebwd::util::stats::{cossim, rel_l2};

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let mut be = make_backend(
        args.str_or("backend", "native"),
        args.str_or("artifacts", sagebwd::DEFAULT_ARTIFACTS_DIR),
    )?;
    println!("backend: {}", be.name());

    // Random single-head (N=128, D=64) attention problem.
    let mut rng = Pcg64::new(0, 0);
    let inputs: Vec<Value> = (0..4)
        .map(|i| Value::F32(Tensor::randn(&[128, 64], 1.0, &mut rng.split(i))))
        .collect();

    // SageBwd (INT8 kernels, Algorithms 1+2) vs exact attention.
    let sage = be.execute("trace_sage", &inputs)?;
    let fpa = be.execute("trace_fpa", &inputs)?;

    println!("\nSageBwd vs full-precision attention (σ_Q=σ_K=1):");
    for (idx, name) in [(0usize, "O "), (1, "dQ"), (2, "dK"), (3, "dV")] {
        let s = sage[idx].as_f32()?;
        let f = fpa[idx].as_f32()?;
        println!(
            "  {name}: cossim {:.6}, rel-l2 {:.4}",
            cossim(&s.data, &f.data),
            rel_l2(&s.data, &f.data)
        );
    }
    println!("\nPaper Table 1 (σ=1): O cossim 0.9999, dQ 0.9998, dK 0.9998, dV 0.9999");
    println!("quickstart OK");
    Ok(())
}
