//! Quickstart: load the SageBwd attention artifact, run one
//! forward+backward on random tensors, and compare against exact
//! attention — the 60-second tour of the three-layer stack.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use sagebwd::runtime::{Runtime, Value};
use sagebwd::tensor::Tensor;
use sagebwd::util::rng::Pcg64;
use sagebwd::util::stats::{cossim, rel_l2};

fn main() -> Result<()> {
    let mut rt = Runtime::new(sagebwd::DEFAULT_ARTIFACTS_DIR)?;
    println!("PJRT platform: {}", rt.platform());

    // Random single-head (N=128, D=64) attention problem.
    let mut rng = Pcg64::new(0, 0);
    let inputs: Vec<Value> = (0..4)
        .map(|i| Value::F32(Tensor::randn(&[128, 64], 1.0, &mut rng.split(i))))
        .collect();

    // SageBwd (INT8 Pallas kernels, Algorithms 1+2) vs exact attention.
    let sage = rt.execute("trace_sage", &inputs)?;
    let fpa = rt.execute("trace_fpa", &inputs)?;

    println!("\nSageBwd vs full-precision attention (σ_Q=σ_K=1):");
    for (idx, name) in [(0usize, "O "), (1, "dQ"), (2, "dK"), (3, "dV")] {
        let s = sage[idx].as_f32()?;
        let f = fpa[idx].as_f32()?;
        println!(
            "  {name}: cossim {:.6}, rel-l2 {:.4}",
            cossim(&s.data, &f.data),
            rel_l2(&s.data, &f.data)
        );
    }
    println!("\nPaper Table 1 (σ=1): O cossim 0.9999, dQ 0.9998, dK 0.9998, dV 0.9999");
    println!("quickstart OK");
    Ok(())
}
