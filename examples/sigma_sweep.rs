//! σ-sweep example (paper §4.4 / Table 1): how SageBwd accuracy degrades
//! as the Q/K activation scale grows — the experiment motivating QK-norm.
//!
//! ```text
//! cargo run --release --example sigma_sweep -- [--reps 2]
//! ```

use anyhow::Result;
use sagebwd::cli::Args;
use sagebwd::experiments::table1_sigma;
use sagebwd::runtime::Runtime;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let reps = args.u64_or("reps", 2)?;
    let mut rt = Runtime::new(sagebwd::DEFAULT_ARTIFACTS_DIR)?;
    let rows = table1_sigma::run(&mut rt, sagebwd::DEFAULT_RESULTS_DIR, reps)?;

    // The §4.4 takeaway, checked programmatically:
    let first = &rows[0];
    let last = rows.last().unwrap();
    println!("\nσ={} → σ={}:", first.sigma, last.sigma);
    println!("  dQ cossim {:.4} → {:.4} (collapses)", first.dq.0, last.dq.0);
    println!("  O  cossim {:.4} → {:.4} (stays accurate)", first.o.0, last.o.0);
    println!("QK-norm bounds σ_Q/σ_K during training, keeping SageBwd in the accurate regime.");
    Ok(())
}
