//! σ-sweep example (paper §4.4 / Table 1): how SageBwd accuracy degrades
//! as the Q/K activation scale grows — the experiment motivating QK-norm.
//!
//! Runs anywhere on the native CPU kernels (`--backend xla` switches to
//! the AOT artifacts).
//!
//! ```text
//! cargo run --release --example sigma_sweep -- [--reps 2] [--backend native|xla]
//! ```

use anyhow::Result;
use sagebwd::cli::Args;
use sagebwd::experiments::table1_sigma;
use sagebwd::runtime::make_backend;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let reps = args.u64_or("reps", 2)?;
    let mut be = make_backend(
        args.str_or("backend", "native"),
        args.str_or("artifacts", sagebwd::DEFAULT_ARTIFACTS_DIR),
    )?;
    let rows = table1_sigma::run(be.as_mut(), sagebwd::DEFAULT_RESULTS_DIR, reps)?;

    // The §4.4 takeaway, checked programmatically:
    let first = &rows[0];
    let last = rows.last().unwrap();
    println!("\nσ={} → σ={}:", first.sigma, last.sigma);
    println!("  dQ cossim {:.4} → {:.4} (collapses)", first.dq.0, last.dq.0);
    println!("  O  cossim {:.4} → {:.4} (stays accurate)", first.o.0, last.o.0);
    println!("QK-norm bounds σ_Q/σ_K during training, keeping SageBwd in the accurate regime.");
    Ok(())
}
