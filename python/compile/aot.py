"""AOT exporter: lower every L2/L1 computation to HLO *text* + a JSON
manifest, the only interface the Rust runtime consumes.

Interchange is HLO text, not serialized HloModuleProto: jax ≥ 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Every artifact is a *flat positional* function — inputs and outputs are
lists of arrays whose order is recorded in ``<name>.manifest.json``.  The
Rust side addresses leaves positionally; sorted parameter-name order is the
ABI (model.param_names).

Usage:  cd python && python -m compile.aot --out ../artifacts [--only PREFIX]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import (VARIANTS, TRACE_VARIANTS, ModelConfig, TraceConfig,
                      bench_variants)
from .kernels import attention, fa2_ref, ref, sagebwd_bwd, sagebwd_fwd

# Microbatch size baked into training artifacts; the Rust coordinator
# realizes any tokens-per-step by accumulating microbatches (§4.3).
MICROBATCH = 2


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _dtype_str(x) -> str:
    return {"float32": "f32", "int32": "i32", "uint32": "u32"}[str(x.dtype)]


def _spec(name, x):
    return {"name": name, "shape": [int(s) for s in x.shape],
            "dtype": _dtype_str(x)}


def export(out_dir: str, name: str, fn, in_specs, in_names, out_names,
           meta=None) -> None:
    """Lower ``fn(*arrays)`` at the given ShapeDtypeStructs and write
    ``<name>.hlo.txt`` + ``<name>.manifest.json``."""
    t0 = time.time()
    lowered = jax.jit(fn).lower(*in_specs)
    text = to_hlo_text(lowered)
    out_shapes = jax.eval_shape(fn, *in_specs)
    flat_out, _ = jax.tree_util.tree_flatten(out_shapes)
    assert len(flat_out) == len(out_names), (name, len(flat_out), len(out_names))
    manifest = {
        "artifact": name,
        "inputs": [_spec(n, s) for n, s in zip(in_names, in_specs)],
        "outputs": [_spec(n, s) for n, s in zip(out_names, flat_out)],
        "meta": meta or {},
    }
    with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
        f.write(text)
    with open(os.path.join(out_dir, f"{name}.manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  {name}: {len(text)/1e6:.2f} MB HLO, "
          f"{len(in_specs)} in / {len(flat_out)} out, {time.time()-t0:.1f}s",
          flush=True)


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


# ---------------------------------------------------------------------------
# Training artifacts (init / grad_step / apply_step per variant)
# ---------------------------------------------------------------------------


def export_variant(out_dir: str, vname: str, cfg: ModelConfig, batch: int):
    names = model.param_names(cfg)
    shapes = model.param_shapes(cfg)
    p_specs = [_f32(*shapes[n]) for n in names]
    meta = dict(cfg._asdict(), batch=batch, param_names=names,
                param_count=int(sum(
                    int(jnp.prod(jnp.array(shapes[n]))) for n in names)))

    # init: seed → params
    def init_fn(seed):
        p = model.init_params(cfg, seed)
        return tuple(p[n] for n in names)

    export(out_dir, f"init_{vname}", init_fn, [_i32()], ["seed"], names, meta)

    # grad_step: params + (tokens, targets) → loss + grads
    def grad_fn(*args):
        params = dict(zip(names, args[:len(names)]))
        tokens, targets = args[len(names)], args[len(names) + 1]
        loss, grads = model.grad_step(cfg, params, tokens, targets)
        return (loss,) + tuple(grads[n] for n in names)

    export(out_dir, f"grad_step_{vname}", grad_fn,
           p_specs + [_i32(batch, cfg.seq_len), _i32(batch, cfg.seq_len)],
           names + ["tokens", "targets"],
           ["loss"] + [f"d.{n}" for n in names], meta)


def export_apply(out_dir: str, aname: str, cfg: ModelConfig):
    """AdamW step — depends only on the parameter tree, so one artifact is
    shared by all variants with the same qk_norm setting."""
    names = model.param_names(cfg)
    shapes = model.param_shapes(cfg)
    p_specs = [_f32(*shapes[n]) for n in names]

    def apply_fn(*args):
        np_ = len(names)
        params = dict(zip(names, args[:np_]))
        m = dict(zip(names, args[np_:2 * np_]))
        v = dict(zip(names, args[2 * np_:3 * np_]))
        grads = dict(zip(names, args[3 * np_:4 * np_]))
        lr, step = args[4 * np_], args[4 * np_ + 1]
        new_p, new_m, new_v = model.apply_step(cfg, params, m, v, grads, lr, step)
        return (tuple(new_p[n] for n in names)
                + tuple(new_m[n] for n in names)
                + tuple(new_v[n] for n in names))

    in_names = (names + [f"m.{n}" for n in names] + [f"v.{n}" for n in names]
                + [f"d.{n}" for n in names] + ["lr", "step"])
    out_names = (names + [f"m.{n}" for n in names] + [f"v.{n}" for n in names])
    export(out_dir, f"apply_step_{aname}", apply_fn,
           p_specs * 4 + [_f32(), _i32()], in_names, out_names,
           dict(param_names=names))


# ---------------------------------------------------------------------------
# Attention trace artifacts (Table 1/2, Figures 5/6, §4.2 RMS probe)
# ---------------------------------------------------------------------------

TRACE_OUTPUTS = ["o", "dq", "dk", "dv", "delta", "rms_p", "rms_dp", "rms_ds",
                 "p", "dp", "ds"]


def export_trace(out_dir: str, tname: str, tc: TraceConfig):
    """(Q, K, V, dO) → outputs + gradients + intermediates.

    For ``impl='sage'`` runs the actual Pallas kernels for (o, dq, dk, dv)
    and the block-faithful reference for the materialized intermediates
    (bit-identical math, see ref.sage_ref_bwd docstring)."""

    def trace_fn(q, k, v, do):
        if tc.impl == "fpa":
            it = ref.fpa_bwd(q, k, v, do, causal=tc.causal)
        elif tc.impl == "pseudo":
            it = ref.pseudo_quant_trace(q, k, v, do, causal=tc.causal,
                                        k_smoothing=tc.k_smoothing,
                                        q_smoothing=tc.q_smoothing,
                                        quant_ds=tc.quant_ds)
        elif tc.impl == "sage":
            o, lse = sagebwd_fwd.sage_fwd(
                q, k, v, block_q=tc.block, block_kv=tc.block,
                causal=tc.causal, k_smoothing=tc.k_smoothing,
                q_smoothing=tc.q_smoothing)
            dq, dk, dv = sagebwd_bwd.sage_bwd(
                q, k, v, do, o, lse, block_q=tc.block, block_kv=tc.block,
                causal=tc.causal, k_smoothing=tc.k_smoothing,
                q_smoothing=tc.q_smoothing, quant_ds=tc.quant_ds)
            it_ref = ref.pseudo_quant_trace(q, k, v, do, causal=tc.causal,
                                            k_smoothing=tc.k_smoothing,
                                            q_smoothing=tc.q_smoothing,
                                            quant_ds=tc.quant_ds)
            it = it_ref._replace(o=o, dq=dq, dk=dk, dv=dv)
        else:
            raise ValueError(tc.impl)
        rms = lambda x: jnp.sqrt(jnp.mean(jnp.square(x)))
        return (it.o, it.dq, it.dk, it.dv, it.delta,
                rms(it.p), rms(it.dp), rms(it.ds), it.p, it.dp, it.ds)

    spec = _f32(tc.n, tc.d)
    export(out_dir, tname, trace_fn, [spec] * 4, ["q", "k", "v", "do"],
           TRACE_OUTPUTS, dict(tc._asdict()))


# ---------------------------------------------------------------------------
# Kernel speed artifacts (Figures 2 & 3)
# ---------------------------------------------------------------------------


def export_bench(out_dir: str, bname: str, bc) -> None:
    def fwd_fn(q, k, v):
        if bc.impl == "sage":
            o, _ = sagebwd_fwd.sage_fwd(q, k, v, block_q=bc.block,
                                        block_kv=bc.block, causal=bc.causal)
        elif bc.impl == "fa2":
            o, _ = fa2_ref.fa2_fwd(q, k, v, block_q=bc.block,
                                   block_kv=bc.block, causal=bc.causal)
        else:
            o = fa2_ref.naive_sdpa(q, k, v, causal=bc.causal)
        return (o,)

    def fwdbwd_fn(q, k, v, do):
        if bc.impl == "sage":
            o, lse = sagebwd_fwd.sage_fwd(q, k, v, block_q=bc.block,
                                          block_kv=bc.block, causal=bc.causal)
            dq, dk, dv = sagebwd_bwd.sage_bwd(q, k, v, do, o, lse,
                                              block_q=bc.block,
                                              block_kv=bc.block,
                                              causal=bc.causal)
            return o, dq, dk, dv
        if bc.impl == "fa2":
            o, lse = fa2_ref.fa2_fwd(q, k, v, block_q=bc.block,
                                     block_kv=bc.block, causal=bc.causal)
            dq, dk, dv = fa2_ref.fa2_bwd(q, k, v, do, o, lse,
                                         block_q=bc.block, block_kv=bc.block,
                                         causal=bc.causal)
            return o, dq, dk, dv
        # naive: plain jnp, differentiated by XLA autodiff.
        f = lambda q, k, v: jnp.sum(
            fa2_ref.naive_sdpa(q, k, v, causal=bc.causal) * do)
        o = fa2_ref.naive_sdpa(q, k, v, causal=bc.causal)
        dq, dk, dv = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
        return o, dq, dk, dv

    spec = _f32(bc.n, bc.d)
    meta = dict(bc._asdict())
    if bc.mode == "fwd":
        export(out_dir, bname, fwd_fn, [spec] * 3, ["q", "k", "v"], ["o"], meta)
    else:
        export(out_dir, bname, fwdbwd_fn, [spec] * 4, ["q", "k", "v", "do"],
               ["o", "dq", "dk", "dv"], meta)


# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="export only artifacts whose name starts with this")
    ap.add_argument("--batch", type=int, default=MICROBATCH)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    jobs = []
    for vname, cfg in VARIANTS.items():
        jobs.append((f"init_{vname}",
                     lambda v=vname, c=cfg: export_variant(args.out, v, c, args.batch)))
    # one apply_step per distinct parameter tree (qk_norm on/off)
    jobs.append(("apply_step_qknorm",
                 lambda: export_apply(args.out, "qknorm", VARIANTS["sage_qknorm"])))
    jobs.append(("apply_step_noqknorm",
                 lambda: export_apply(args.out, "noqknorm", VARIANTS["sage_noqknorm"])))
    for tname, tc in TRACE_VARIANTS.items():
        jobs.append((tname, lambda t=tname, c=tc: export_trace(args.out, t, c)))
    for bname, bc in bench_variants().items():
        jobs.append((bname, lambda b=bname, c=bc: export_bench(args.out, b, c)))

    t0 = time.time()
    for name, job in jobs:
        if args.only and not name.startswith(args.only):
            continue
        job()
    print(f"AOT export complete in {time.time()-t0:.0f}s → {args.out}")


if __name__ == "__main__":
    main()
