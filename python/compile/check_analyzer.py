#!/usr/bin/env python3
"""Numpy-free twin of `rust/src/analysis/` — the self-hosting invariant
analyzer (DESIGN.md §13).

This script mirrors the Rust lint pass line for line so the analyzer can
be validated in a container without a Rust toolchain, exactly like
`check_native_model.py` validates the native training engine.  It must
agree with `sagebwd analyze` on every violation and on the A3 baseline
counts; `--write-baseline` regenerates
`rust/src/analysis/baseline.json` in the same canonical form the Rust
side writes (sorted keys, one-line-per-file JSON).

Usage:
  python3 python/compile/check_analyzer.py [--root DIR] [--write-baseline]
  python3 python/compile/check_analyzer.py --fixtures   # lint-fixture self-test
"""

import json
import os
import sys

# --- shared constants (keep in lockstep with rust/src/analysis/lints.rs) ---

NUMERIC_MODULES = ("rust/src/tensor/", "rust/src/kernels/",
                   "rust/src/model/", "rust/src/experiments/")

A1_BANNED = [
    ("HashMap", "HashMap iteration order is nondeterministic",
     "use BTreeMap (determinism contract, DESIGN.md S11/S13)"),
    ("HashSet", "HashSet iteration order is nondeterministic",
     "use BTreeSet (determinism contract, DESIGN.md S11/S13)"),
    ("Instant", "wall-clock read inside a numeric module",
     "time at the harness layer (bench.rs) instead"),
    ("SystemTime", "wall-clock read inside a numeric module",
     "time at the harness layer (bench.rs) instead"),
    ("thread_rng", "OS randomness breaks bitwise reproducibility",
     "use util::rng (seeded, deterministic)"),
    ("RandomState", "randomized hasher state is nondeterministic",
     "use BTreeMap or a fixed-seed hasher"),
    ("getrandom", "OS randomness breaks bitwise reproducibility",
     "use util::rng (seeded, deterministic)"),
]

A2_BANNED = [".clone()", ".to_vec()", "Vec::new", "vec!["]

HOT_FUNCTIONS = [
    ("rust/src/kernels/attention.rs", ["*_ws"]),
    ("rust/src/tensor/linalg.rs",
     ["gemm_nn_rows*", "i8_gemm_nn_rows*", "par_gemm_nn", "pack_transpose",
      "int8_gemm_nn*", "int8_gemm_nt*", "int8_gemm_tn*"]),
    ("rust/src/tensor/simd.rs", ["gemm_f32_rows*", "gemm_i8_rows*"]),
    ("rust/src/model/blocks.rs",
     ["rmsnorm_fwd", "rmsnorm_bwd", "mlp_fwd", "mlp_bwd",
      "cross_entropy_fwd", "cross_entropy_bwd"]),
    ("rust/src/model/transformer.rs", ["forward_with_targets", "loss_and_grads"]),
]

A3_TOKENS = [".unwrap()", ".expect(", "panic!"]

BENCH_V1_FIELDS = ["schema", "bench", "runs", "threads_default", "rows",
                   "op", "shape", "variant", "threads", "isa",
                   "ns_per_iter", "tokens_per_s"]
RUN_V1_FIELDS = ["schema", "experiment", "label", "config", "config_hash",
                 "code_version", "status", "artifacts", "recoveries", "summary",
                 "name", "sha256", "bytes", "view",
                 "attempt", "at_step", "resume_step", "reason", "action",
                 "peak_lr", "tokens_per_step", "variant"]
TRACE_V1_FIELDS = ["schema", "kind", "threads", "spans", "counters",
                   "name", "parent", "calls", "total_ns", "self_ns",
                   "min_ns", "max_ns", "p50_ns", "p99_ns", "value"]
SCHEMA_TARGETS = [
    ("rust/src/bench.rs", "sagebwd-bench-v1", BENCH_V1_FIELDS),
    ("rust/src/registry/manifest.rs", "sagebwd-run-v1", RUN_V1_FIELDS),
    ("rust/src/telemetry/trace.rs", "sagebwd-trace-v1", TRACE_V1_FIELDS),
]

BASELINE_REL = "rust/src/analysis/baseline.json"
BASELINE_SCHEMA = "sagebwd-analysis-baseline-v1"


# --- tokenizer (mirror of rust/src/analysis/tokenizer.rs) ---

def is_ident(ch):
    # ASCII-only on purpose: the Rust side works on bytes, and source
    # identifiers in this repo are ASCII; non-ASCII (comment prose) must
    # count as a boundary on both sides.
    return (ch.isascii() and ch.isalnum()) or ch == "_"


def tokenize(text):
    """Return a list of lines: dicts with num, code (string/char/comment
    contents stripped, string literals replaced by "<idx>" placeholders),
    strings (literal contents, recorded on the closing line), comments
    (comment text touching this line)."""
    lines = []
    num = 1
    code, strings, comments = [], [], []
    mode = "N"          # N | LC | BC | S | RS
    bc_depth = 0
    rs_hashes = 0
    sbuf = []
    comment_buf = []
    i, n = 0, len(text)

    def flush_line():
        nonlocal code, strings, comments, num, comment_buf
        if comment_buf:
            comments.append("".join(comment_buf))
            comment_buf = []
        lines.append({"num": num, "code": "".join(code),
                      "strings": strings, "comments": comments})
        num += 1
        code, strings, comments = [], [], []

    while i < n:
        ch = text[i]
        if ch == "\n":
            if mode == "LC":
                mode = "N"
            flush_line()
            i += 1
            continue
        if mode == "LC":
            comment_buf.append(ch)
            i += 1
            continue
        if mode == "BC":
            if ch == "/" and i + 1 < n and text[i + 1] == "*":
                bc_depth += 1
                comment_buf.append("/*")
                i += 2
                continue
            if ch == "*" and i + 1 < n and text[i + 1] == "/":
                bc_depth -= 1
                i += 2
                if bc_depth == 0:
                    mode = "N"
                    if comment_buf:
                        comments.append("".join(comment_buf))
                        comment_buf = []
                else:
                    comment_buf.append("*/")
                continue
            comment_buf.append(ch)
            i += 1
            continue
        if mode == "S":
            if ch == "\\" and i + 1 < n:
                if text[i + 1] == "\n":  # escaped-newline continuation
                    flush_line()
                else:
                    sbuf.append(text[i:i + 2])
                i += 2
                continue
            if ch == '"':
                strings.append("".join(sbuf))
                code.append('"%d"' % (len(strings) - 1))
                sbuf = []
                mode = "N"
                i += 1
                continue
            sbuf.append(ch)
            i += 1
            continue
        if mode == "RS":
            if ch == '"' and text[i + 1:i + 1 + rs_hashes] == "#" * rs_hashes:
                strings.append("".join(sbuf))
                code.append('"%d"' % (len(strings) - 1))
                sbuf = []
                mode = "N"
                i += 1 + rs_hashes
                continue
            sbuf.append(ch)
            i += 1
            continue
        # mode == N
        prev = text[i - 1] if i > 0 else " "
        if ch == "/" and i + 1 < n and text[i + 1] == "/":
            mode = "LC"
            i += 2
            continue
        if ch == "/" and i + 1 < n and text[i + 1] == "*":
            mode = "BC"
            bc_depth = 1
            i += 2
            continue
        if ch == '"':
            mode = "S"
            sbuf = []
            i += 1
            continue
        if ch in "rb" and not is_ident(prev):
            # r"..." / r#"..."# / b"..." / br"..." raw and byte strings.
            j = i + 1
            if ch == "b" and j < n and text[j] == "r":
                j += 1
            hashes = 0
            while j < n and text[j] == "#":
                hashes += 1
                j += 1
            if j < n and text[j] == '"' and (hashes > 0 or
                                             (ch == "r" and text[i + 1] == '"') or
                                             (ch == "b" and text[i + 1] == '"') or
                                             (ch == "b" and text[i + 1] == "r")):
                if hashes > 0 or (ch == "r" or text[i + 1] == "r"):
                    mode = "RS"
                    rs_hashes = hashes
                else:
                    mode = "S"  # b"..."
                sbuf = []
                i = j + 1
                continue
            code.append(ch)
            i += 1
            continue
        if ch == "'":
            nxt = text[i + 1] if i + 1 < n else ""
            if nxt == "\\":
                j = i + 2
                while j < n and text[j] != "'":
                    j += 1
                code.append("' '")
                i = j + 1
                continue
            if i + 2 < n and text[i + 2] == "'":
                code.append("' '")
                i += 3
                continue
            code.append(ch)  # lifetime
            i += 1
            continue
        code.append(ch)
        i += 1
    if code or strings or comments or comment_buf or mode != "N":
        flush_line()
    return lines


# --- region + helper passes (mirror of analysis/lints.rs helpers) ---

def test_lines(lines, relpath):
    """Set of 1-based line numbers that are test code."""
    if relpath.startswith("rust/tests/") or relpath.startswith("rust/benches/"):
        return set(l["num"] for l in lines)
    out = set()
    pending = False
    depth = 0
    in_region = False
    for l in lines:
        if not in_region and "#[cfg(test)]" in l["code"]:
            pending = True
            out.add(l["num"])
            continue
        if pending or in_region:
            out.add(l["num"])
            for ch in l["code"]:
                if ch == "{":
                    depth += 1
                    pending = False
                    in_region = True
                elif ch == "}":
                    depth -= 1
                    if in_region and depth == 0:
                        in_region = False
            if not pending and not in_region:
                pass  # region closed on this line
    return out


def parse_allows(lines):
    """line -> list of (lint_id, has_reason). An allow on line L covers
    violations on L and L+1."""
    allows = {}
    for l in lines:
        for c in l["comments"]:
            idx = c.find("sagebwd-allow(")
            while idx >= 0:
                rest = c[idx + len("sagebwd-allow("):]
                close = rest.find(")")
                if close > 0:
                    lint = rest[:close].strip()
                    after = rest[close + 1:]
                    reason = ""
                    if after.lstrip().startswith(":"):
                        reason = after.lstrip()[1:].strip()
                    allows.setdefault(l["num"], []).append((lint, bool(reason)))
                idx = c.find("sagebwd-allow(", idx + 1)
    return allows


def find_token(code, token):
    """Start indices of identifier-boundary-checked occurrences."""
    out = []
    start = 0
    ident_token = token[0].isalpha() or token[0] == "_"
    while True:
        idx = code.find(token, start)
        if idx < 0:
            return out
        before = code[idx - 1] if idx > 0 else " "
        end = idx + len(token)
        after = code[end] if end < len(code) else " "
        ok = True
        if ident_token and is_ident(before):
            ok = False
        if token[-1].isalnum() or token[-1] == "_":
            if is_ident(after):
                ok = False
        if ok:
            out.append(idx)
        start = idx + 1


class Ctx:
    def __init__(self, relpath, lines):
        self.relpath = relpath
        self.lines = lines
        self.tests = test_lines(lines, relpath)
        self.allows = parse_allows(lines)

    def allowed(self, lint, num):
        for at in (num, num - 1):
            for (lid, has_reason) in self.allows.get(at, []):
                if lid == lint and has_reason:
                    return True
        return False

    def allow_comment_violations(self):
        out = []
        for num, lst in sorted(self.allows.items()):
            for (lid, has_reason) in lst:
                if not has_reason:
                    out.append((self.relpath, num, "A0",
                                "sagebwd-allow(%s) without a reason" % lid,
                                "write // sagebwd-allow(%s): <why this site is safe>" % lid))
        return out


# --- the five lints ---

def lint_a1(ctx):
    out = []
    if not any(ctx.relpath.startswith(p) for p in NUMERIC_MODULES):
        return out
    for l in ctx.lines:
        if l["num"] in ctx.tests:
            continue
        for (tok, msg, hint) in A1_BANNED:
            for _ in find_token(l["code"], tok):
                if not ctx.allowed("A1", l["num"]):
                    out.append((ctx.relpath, l["num"], "A1",
                                "%s (`%s`)" % (msg, tok), hint))
    return out


def fn_matches(name, pattern):
    if pattern.startswith("*"):
        return name.endswith(pattern[1:])
    if pattern.endswith("*"):
        return name.startswith(pattern[:-1])
    return name == pattern


def hot_fn_spans(ctx, patterns):
    """Yield (fn_name, [(line_num, [loop char ranges])...]) for manifest
    functions: per body line, the char index ranges inside loop scopes."""
    matched = set()
    spans = []
    nlines = len(ctx.lines)
    li = 0
    while li < nlines:
        l = ctx.lines[li]
        if l["num"] in ctx.tests:
            li += 1
            continue
        code = l["code"]
        for idx in find_token(code, "fn"):
            rest = code[idx + 2:].lstrip()
            name = ""
            for ch in rest:
                if is_ident(ch):
                    name += ch
                else:
                    break
            if not name:
                continue
            pats = [p for p in patterns if fn_matches(name, p)]
            if not pats:
                continue
            matched.update(pats)
            # scan body: from this point, find first '{', then track
            # depth and loop scopes until the matching '}'.
            body = []
            depth = 0
            started = False
            pending_loop = False
            loop_stack = []
            word = ""
            lj, cj = li, idx
            done = False
            while lj < nlines and not done:
                lcode = ctx.lines[lj]["code"]
                ranges = []
                open_at = None
                k = cj
                while k < len(lcode):
                    ch = lcode[k]
                    if is_ident(ch):
                        word += ch
                    else:
                        if word in ("for", "while", "loop"):
                            pending_loop = True
                        word = ""
                    if ch == "{":
                        if not started:
                            started = True
                            depth = 1
                            loop_stack = []
                        else:
                            depth += 1
                            loop_stack.append(pending_loop)
                            if pending_loop and open_at is None:
                                open_at = k
                            pending_loop = False
                    elif ch == ";":
                        pending_loop = False
                    elif ch == "}":
                        if started:
                            depth -= 1
                            if depth == 0:
                                done = True
                                if any(loop_stack) or open_at is not None:
                                    pass
                                k += 1
                                break
                            was_loop = loop_stack.pop() if loop_stack else False
                            if was_loop and not any(loop_stack):
                                ranges.append((open_at if open_at is not None else 0, k))
                                open_at = None
                    k += 1
                word = ""  # tokens never span lines
                if started:
                    in_loop = any(loop_stack)
                    if in_loop and open_at is None:
                        ranges.append((0, len(lcode)))
                    elif open_at is not None:
                        ranges.append((open_at, len(lcode)))
                    if ranges:
                        body.append((ctx.lines[lj]["num"], ranges))
                lj += 1
                cj = 0
            spans.append((name, body))
        li += 1
    return spans, matched


def lint_a2(ctx):
    out = []
    patterns = None
    for (path, pats) in HOT_FUNCTIONS:
        if ctx.relpath == path:
            patterns = pats
    if patterns is None:
        return out
    spans, matched = hot_fn_spans(ctx, patterns)
    for p in patterns:
        if p not in matched:
            out.append((ctx.relpath, 1, "A2",
                        "hot-function manifest entry `%s` matches no fn" % p,
                        "update HOT_FUNCTIONS in analysis/lints.rs"))
    line_code = {l["num"]: l["code"] for l in ctx.lines}
    for (name, body) in spans:
        for (num, ranges) in body:
            code = line_code[num]
            for tok in A2_BANNED:
                for idx in find_token(code, tok):
                    if any(lo <= idx <= hi for (lo, hi) in ranges):
                        if not ctx.allowed("A2", num):
                            out.append((ctx.relpath, num, "A2",
                                        "`%s` inside a hot loop of `%s`" % (tok, name),
                                        "hoist the buffer out of the loop (Workspace slab or argument)"))
    return out


def lint_a3_sites(ctx):
    sites = []
    if not ctx.relpath.startswith("rust/src/"):
        return sites
    for l in ctx.lines:
        if l["num"] in ctx.tests:
            continue
        for tok in A3_TOKENS:
            for _ in find_token(l["code"], tok):
                if not ctx.allowed("A3", l["num"]):
                    sites.append((l["num"], tok))
    return sites


def lint_a4(ctx):
    out = []
    comment_only = {}
    by_num = {l["num"]: l for l in ctx.lines}
    for l in ctx.lines:
        comment_only[l["num"]] = (not l["code"].strip()) and bool(l["comments"])
    for l in ctx.lines:
        for _ in find_token(l["code"], "unsafe"):
            ok = any("SAFETY:" in c for c in l["comments"])
            num = l["num"] - 1
            while not ok and num >= 1 and comment_only.get(num, False):
                if any("SAFETY:" in c for c in by_num[num]["comments"]):
                    ok = True
                num -= 1
            if not ok and not ctx.allowed("A4", l["num"]):
                out.append((ctx.relpath, l["num"], "A4",
                            "`unsafe` without a `// SAFETY:` comment",
                            "document the invariant that makes this sound on the preceding line"))
    return out


IDENT_KEY = lambda s: s and s[0].isalpha() and s[0].islower() and all(
    c.islower() or c.isdigit() or c == "_" for c in s)


def json_keys(ctx):
    """(key, line) pairs extracted from ("key", ...) and (..., "key")
    call positions in non-test code."""
    out = []
    for l in ctx.lines:
        if l["num"] in ctx.tests:
            continue
        code = l["code"]
        for si, s in enumerate(l["strings"]):
            ph = '"%d"' % si
            idx = code.find(ph)
            if idx < 0:
                continue
            before = code[:idx].rstrip()
            after = code[idx + len(ph):].lstrip()
            prevc = before[-1] if before else ""
            nextc = after[0] if after else ""
            if (prevc == "(" and nextc == ",") or (prevc == "," and nextc == ")"):
                if IDENT_KEY(s):
                    out.append((s, l["num"]))
    return out


def lint_a5(ctx):
    out = []
    target = None
    for (path, tag, fields) in SCHEMA_TARGETS:
        if ctx.relpath == path:
            target = (tag, fields)
    if target is None:
        return out
    tag, fields = target
    all_strings = set()
    for l in ctx.lines:
        if l["num"] not in ctx.tests:
            all_strings.update(l["strings"])
    if tag not in all_strings:
        out.append((ctx.relpath, 1, "A5",
                    "schema tag \"%s\" not found in file" % tag,
                    "keep the schema constant in lockstep with analysis/lints.rs"))
    keys = json_keys(ctx)
    seen = set(k for (k, _) in keys)
    for (k, num) in keys:
        if k not in fields and not ctx.allowed("A5", num):
            out.append((ctx.relpath, num, "A5",
                        "field \"%s\" is not in the documented %s schema" % (k, tag),
                        "add it to the schema list in analysis/lints.rs + DESIGN.md, or rename"))
    for f in fields:
        if f not in seen:
            out.append((ctx.relpath, 1, "A5",
                        "documented %s field \"%s\" is no longer emitted/checked here" % (tag, f),
                        "re-emit the field or remove it from the documented schema"))
    return out


# --- file walking + baseline (mirror of analysis/mod.rs + baseline.rs) ---

def scan_paths(root):
    out = []
    for sub in ("rust/src", "rust/tests", "rust/benches", "examples"):
        base = os.path.join(root, sub)
        if not os.path.isdir(base):
            continue
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in ("data", "vendor", "target")
                                 and not d.startswith("."))
            for f in sorted(filenames):
                if f.endswith(".rs"):
                    rel = os.path.relpath(os.path.join(dirpath, f), root)
                    out.append(rel.replace(os.sep, "/"))
    return sorted(out)


def analyze(root, update_baseline=False):
    violations = []
    a3_counts = {}
    for rel in scan_paths(root):
        with open(os.path.join(root, rel), encoding="utf-8") as fh:
            text = fh.read()
        ctx = Ctx(rel, tokenize(text))
        violations += ctx.allow_comment_violations()
        violations += lint_a1(ctx)
        violations += lint_a2(ctx)
        violations += lint_a4(ctx)
        violations += lint_a5(ctx)
        sites = lint_a3_sites(ctx)
        if sites:
            a3_counts[rel] = sites
    # A3 ratchet against the committed baseline.
    bpath = os.path.join(root, BASELINE_REL)
    baseline = {"files": {}, "total": 0}
    have_baseline = os.path.isfile(bpath)
    if have_baseline:
        with open(bpath, encoding="utf-8") as fh:
            baseline = json.load(fh)
        if baseline.get("schema") != BASELINE_SCHEMA:
            violations.append((BASELINE_REL, 1, "A3",
                               "baseline has schema %r, want %r" % (
                                   baseline.get("schema"), BASELINE_SCHEMA),
                               "regenerate with `sagebwd analyze --write-baseline`"))
            baseline = {"files": {}, "total": 0}
    else:
        violations.append((BASELINE_REL, 1, "A3", "missing A3 baseline file",
                           "generate it with `sagebwd analyze --write-baseline`"))
    bfiles = baseline.get("files", {})
    tightened = False
    for rel in sorted(a3_counts):
        count = len(a3_counts[rel])
        base = bfiles.get(rel, 0)
        if count > base:
            first = a3_counts[rel][max(0, base)][0] if a3_counts[rel] else 1
            violations.append((rel, first, "A3",
                               "%d unwrap()/expect()/panic! sites, baseline allows %d" % (count, base),
                               "propagate with ? (or // sagebwd-allow(A3): reason), never raise the baseline"))
        elif count < base:
            tightened = True
    for rel, base in bfiles.items():
        if base > 0 and rel not in a3_counts:
            tightened = True
    total = sum(len(v) for v in a3_counts.values())
    if update_baseline and have_baseline and tightened and \
            not any(v[2] == "A3" for v in violations):
        write_baseline(bpath, a3_counts)
    return violations, a3_counts, baseline, tightened


def baseline_json(a3_counts):
    files = {rel: len(sites) for rel, sites in sorted(a3_counts.items())}
    total = sum(files.values())
    # Canonical form: matches util::json (sorted keys, no spaces).
    doc = {"files": files, "schema": BASELINE_SCHEMA, "total": total}
    return json.dumps(doc, sort_keys=True, separators=(",", ":"))


def write_baseline(path, a3_counts):
    with open(path, "w", encoding="utf-8") as fh:
        fh.write(baseline_json(a3_counts))


def check_fixtures(root):
    """Mirror of rust/tests/analysis_lints.rs over the same fixtures."""
    import shutil
    import tempfile
    fx = os.path.join(root, "rust/tests/data/lint_fixtures")
    seeded, _, _, _ = analyze(os.path.join(fx, "seeded"))
    got = sorted((f, line, lint) for (f, line, lint, _, _) in seeded)
    expect = [
        ("rust/src/bench.rs", 1, "A5"),
        ("rust/src/bench.rs", 1, "A5"),
        ("rust/src/bench.rs", 29, "A5"),
        ("rust/src/bench.rs", 30, "A5"),
        ("rust/src/kernels/attention.rs", 3, "A1"),
        ("rust/src/kernels/attention.rs", 8, "A2"),
        ("rust/src/main.rs", 4, "A3"),
        ("rust/src/runtime/raw.rs", 4, "A4"),
        ("rust/src/runtime/raw.rs", 13, "A0"),
        ("rust/src/runtime/raw.rs", 14, "A4"),
        ("rust/src/telemetry/trace.rs", 1, "A5"),
        ("rust/src/telemetry/trace.rs", 29, "A5"),
        ("rust/src/tensor/linalg.rs", 1, "A2"),
        ("rust/src/tensor/timing.rs", 4, "A1"),
    ]
    assert got == expect, "seeded fixture mismatch:\n%s" % "\n".join(map(str, got))
    for name in ("suppressed", "clean"):
        v, counts, _, _ = analyze(os.path.join(fx, name))
        assert not v, "%s fixture must be quiet: %s" % (name, v)
        assert not counts, "%s fixture must have no A3 sites" % name

    # Ratchet scenario in a temp tree (same steps as the Rust test).
    tmp = tempfile.mkdtemp(prefix="sagebwd_ratchet_")
    try:
        src = os.path.join(tmp, "rust/src")
        os.makedirs(os.path.join(src, "analysis"))
        with open(os.path.join(src, "lib.rs"), "w") as fh:
            fh.write("pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n")
        v, counts, _, _ = analyze(tmp)
        assert len(v) == 2 and all(x[2] == "A3" for x in v), v
        write_baseline(os.path.join(tmp, BASELINE_REL), counts)
        v, _, _, _ = analyze(tmp)
        assert not v, v
        with open(os.path.join(tmp, BASELINE_REL), "w") as fh:
            fh.write('{"files":{"rust/src/lib.rs":3},'
                     '"schema":"sagebwd-analysis-baseline-v1","total":3}')
        v, _, _, tightened = analyze(tmp, update_baseline=True)
        assert not v and tightened
        with open(os.path.join(tmp, BASELINE_REL)) as fh:
            assert json.load(fh)["total"] == 1, "auto-tighten must rewrite"
        with open(os.path.join(src, "lib.rs"), "a") as fh:
            fh.write("pub fn g(x: Option<u32>) -> u32 { x.unwrap() }\n")
        v, _, _, _ = analyze(tmp, update_baseline=True)
        assert len(v) == 1 and v[0][2] == "A3" and v[0][1] == 2, v
        with open(os.path.join(tmp, BASELINE_REL)) as fh:
            assert json.load(fh)["total"] == 1, "failing run must not rewrite"
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    print("fixture self-test OK")


def main():
    args = sys.argv[1:]
    root = "."
    if "--root" in args:
        root = args[args.index("--root") + 1]
    if "--fixtures" in args:
        check_fixtures(root)
        return
    violations, a3_counts, baseline, tightened = analyze(
        root, update_baseline="--write-baseline" in args)
    for (f, line, lint, msg, hint) in sorted(violations):
        print("%s:%d: %s: %s (fix: %s)" % (f, line, lint, msg, hint))
    total = sum(len(v) for v in a3_counts.values())
    print("A3 sites: %d (baseline %d)%s" % (
        total, baseline.get("total", 0), ", ratchet can tighten" if tightened else ""))
    print("%d violation(s)" % len(violations))
    if "--write-baseline" in args:
        write_baseline(os.path.join(root, BASELINE_REL), a3_counts)
        print("baseline written: %d sites over %d files" % (total, len(a3_counts)))
    sys.exit(1 if violations else 0)


if __name__ == "__main__":
    main()
