"""Numerical blueprint + validation for the native Rust training engine.

The Rust side (``rust/src/model/``, ``rust/src/coordinator/engine.rs``)
implements a decoder-only transformer with *manual* forward/backward in
f32.  This script is its numpy twin, kept formula-identical, and serves
two purposes:

1. **Gradcheck margins** — finite-difference checks for every building
   block (RMSNorm, QK-norm, SwiGLU MLP, tied-embedding cross-entropy,
   causal FPA attention, full model) in float32, printing the observed
   relative errors.  ``rust/tests/model_gradcheck.rs`` mirrors the same
   procedure and uses tolerances >= 3x the maxima printed here (the
   margins are recorded in that file's comments).

2. **Fig-1 divergence tuning** — simulates the fig1 TPS x variant grid
   (AdamW, cosine schedule, token budget) to choose the default peak LR
   at which the no-QK-norm high-TPS arm crosses the `max_attn_logit`
   divergence ceiling (50.0) while the QK-norm arms complete.  The Rust
   `fig1` harness uses the LR this script validates.

Run:  python3 python/compile/check_native_model.py [--sim]
"""

from __future__ import annotations

import argparse
import math

import numpy as np

F = np.float32
EPS_NORM = F(1e-6)

# Model dims — must match rust/src/model/mod.rs NativeModelConfig::default.
VOCAB, D_MODEL, N_HEADS, D_HEAD, D_FF, N_LAYERS = 512, 32, 2, 16, 64, 2
SEQ, MICRO_B = 32, 2

# AdamW — must match python/compile/model.py and rust/src/model/adamw.rs.
B1, B2, ADAM_EPS, WD = 0.9, 0.95, 1e-8, 0.1

CEILING = 50.0  # max_attn_logit divergence ceiling (TrainConfig default)


# ---------------------------------------------------------------------------
# Building blocks (formula-identical to rust/src/model/blocks.rs)
# ---------------------------------------------------------------------------


def rmsnorm_fwd(x, gamma):
    """x (R, D), gamma (D,) -> y, cache."""
    ms = np.mean(np.square(x), axis=-1, keepdims=True)
    r = F(1.0) / np.sqrt(ms + EPS_NORM)
    return x * r * gamma, (x, gamma, r)


def rmsnorm_bwd(dy, cache):
    x, gamma, r = cache
    d = x.shape[-1]
    w = dy * gamma
    dgamma = np.sum(dy * x * r, axis=0)
    wx = np.sum(w * x, axis=-1, keepdims=True)
    dx = w * r - x * (r ** 3) * wx / F(d)
    return dx.astype(F), dgamma.astype(F)


def silu(x):
    return x / (F(1.0) + np.exp(-x))


def silu_grad(x):
    s = F(1.0) / (F(1.0) + np.exp(-x))
    return s * (F(1.0) + x * (F(1.0) - s))


def mlp_fwd(y, w_gate, w_up, w_down):
    g = y @ w_gate
    u = y @ w_up
    h = silu(g) * u
    out = h @ w_down
    return out, (y, g, u, h)


def mlp_bwd(dout, cache, w_gate, w_up, w_down):
    y, g, u, h = cache
    dw_down = h.T @ dout
    dh = dout @ w_down.T
    du = dh * silu(g)
    dg = dh * u * silu_grad(g)
    dw_gate = y.T @ dg
    dw_up = y.T @ du
    dy = dg @ w_gate.T + du @ w_up.T
    return dy.astype(F), dw_gate.astype(F), dw_up.astype(F), dw_down.astype(F)


def attention_fwd(q, k, v, causal=True):
    """Exact FPA attention on one (N, dh) head.  Returns o, cache, max|S|."""
    n, dh = q.shape
    s = (q @ k.T) / F(math.sqrt(dh))
    if causal:
        mask = np.triu(np.ones((n, n), dtype=bool), 1)
        s = np.where(mask, F(-np.inf), s)
    max_logit = float(np.max(np.abs(np.where(np.isfinite(s), s, 0.0))))
    m = np.max(s, axis=-1, keepdims=True)
    p = np.exp(s - m)
    p /= np.sum(p, axis=-1, keepdims=True)
    o = p @ v
    return o.astype(F), (q, k, v, p.astype(F)), max_logit


def attention_bwd(do, cache):
    q, k, v, p = cache
    n, dh = q.shape
    inv = F(1.0 / math.sqrt(dh))
    dv = p.T @ do
    dp = do @ v.T
    delta = np.sum(do * (p @ v), axis=-1, keepdims=True)
    ds = p * (dp - delta)
    dq = (ds @ k) * inv
    dk = (ds.T @ q) * inv
    return dq.astype(F), dk.astype(F), dv.astype(F)


def ce_fwd(f, embed, targets):
    """Tied head: logits = f @ embed.T; mean next-token CE."""
    logits = f @ embed.T
    m = np.max(logits, axis=-1, keepdims=True)
    z = np.exp(logits - m)
    zsum = np.sum(z, axis=-1, keepdims=True)
    lse = (m + np.log(zsum)).squeeze(-1)
    gold = logits[np.arange(len(targets)), targets]
    loss = float(np.mean(lse - gold))
    p = z / zsum
    return loss, (f, p.astype(F), targets)


def ce_bwd(cache, embed):
    f, p, targets = cache
    r = len(targets)
    dlogits = p.copy()
    dlogits[np.arange(r), targets] -= F(1.0)
    dlogits /= F(r)
    df = dlogits @ embed
    dembed = dlogits.T @ f
    return df.astype(F), dembed.astype(F)


# ---------------------------------------------------------------------------
# Parameters (schema mirrors python/compile/model.py & rust model/mod.rs)
# ---------------------------------------------------------------------------


def param_shapes(qk_norm):
    shapes = {"embed": (VOCAB, D_MODEL), "final_norm": (D_MODEL,)}
    for i in range(N_LAYERS):
        p = f"layers.{i:02d}."
        shapes[p + "attn_norm"] = (D_MODEL,)
        shapes[p + "wq"] = (D_MODEL, N_HEADS * D_HEAD)
        shapes[p + "wk"] = (D_MODEL, N_HEADS * D_HEAD)
        shapes[p + "wv"] = (D_MODEL, N_HEADS * D_HEAD)
        shapes[p + "wo"] = (N_HEADS * D_HEAD, D_MODEL)
        if qk_norm:
            shapes[p + "q_norm"] = (D_HEAD,)
            shapes[p + "k_norm"] = (D_HEAD,)
        shapes[p + "mlp_norm"] = (D_MODEL,)
        shapes[p + "w_gate"] = (D_MODEL, D_FF)
        shapes[p + "w_up"] = (D_MODEL, D_FF)
        shapes[p + "w_down"] = (D_FF, D_MODEL)
    return shapes


def init_params(qk_norm, rng):
    shapes = param_shapes(qk_norm)
    resid = 1.0 / math.sqrt(2 * N_LAYERS)
    params = {}
    for name in sorted(shapes):
        shape = shapes[name]
        if name.endswith("norm"):
            params[name] = np.ones(shape, F)
        elif name.endswith(("wo", "w_down")):
            params[name] = (0.02 * resid * rng.standard_normal(shape)).astype(F)
        else:
            params[name] = (0.02 * rng.standard_normal(shape)).astype(F)
    return params


# ---------------------------------------------------------------------------
# Full model forward/backward (blueprint for rust model/transformer.rs)
# ---------------------------------------------------------------------------


def model_loss_and_grads(params, tokens, targets, qk_norm, want_grads=True):
    """tokens/targets: (B, N) int.  Returns (loss, grads, max_attn_logit)."""
    b, n = tokens.shape
    flat = tokens.reshape(-1)
    x = params["embed"][flat]  # (R, D)
    caches = []
    max_logit = 0.0
    for i in range(N_LAYERS):
        p = f"layers.{i:02d}."
        y, an_cache = rmsnorm_fwd(x, params[p + "attn_norm"])
        q = y @ params[p + "wq"]
        k = y @ params[p + "wk"]
        v = y @ params[p + "wv"]
        heads = []
        o = np.zeros_like(q)
        for bi in range(b):
            for h in range(N_HEADS):
                rs = slice(bi * n, (bi + 1) * n)
                cs = slice(h * D_HEAD, (h + 1) * D_HEAD)
                qh, kh, vh = q[rs, cs], k[rs, cs], v[rs, cs]
                if qk_norm:
                    qh, qn_cache = rmsnorm_fwd(qh, params[p + "q_norm"])
                    kh, kn_cache = rmsnorm_fwd(kh, params[p + "k_norm"])
                else:
                    qn_cache = kn_cache = None
                oh, a_cache, ml = attention_fwd(qh, kh, vh)
                max_logit = max(max_logit, ml)
                o[rs, cs] = oh
                heads.append((rs, cs, qn_cache, kn_cache, a_cache))
        attn_out = o @ params[p + "wo"]
        x1 = x + attn_out
        ym, mn_cache = rmsnorm_fwd(x1, params[p + "mlp_norm"])
        mlp_out, mlp_cache = mlp_fwd(
            ym, params[p + "w_gate"], params[p + "w_up"], params[p + "w_down"])
        x2 = x1 + mlp_out
        caches.append((x, y, an_cache, o, heads, x1, mn_cache, mlp_cache))
        x = x2
    f, fn_cache = rmsnorm_fwd(x, params["final_norm"])
    loss, ce_cache = ce_fwd(f, params["embed"], targets.reshape(-1))
    if not want_grads:
        return loss, None, max_logit

    grads = {name: np.zeros_like(t) for name, t in params.items()}
    df, dembed_head = ce_bwd(ce_cache, params["embed"])
    grads["embed"] += dembed_head
    dx, dg_final = rmsnorm_bwd(df, fn_cache)
    grads["final_norm"] += dg_final
    for i in reversed(range(N_LAYERS)):
        p = f"layers.{i:02d}."
        x_in, y, an_cache, o, heads, x1, mn_cache, mlp_cache = caches[i]
        dym, dwg, dwu, dwd = mlp_bwd(
            dx, mlp_cache, params[p + "w_gate"], params[p + "w_up"],
            params[p + "w_down"])
        grads[p + "w_gate"] += dwg
        grads[p + "w_up"] += dwu
        grads[p + "w_down"] += dwd
        dx1, dg_m = rmsnorm_bwd(dym, mn_cache)
        grads[p + "mlp_norm"] += dg_m
        dx1 = dx1 + dx  # residual
        grads[p + "wo"] += o.T @ dx1
        do = dx1 @ params[p + "wo"].T
        dq = np.zeros_like(do)
        dk = np.zeros_like(do)
        dv = np.zeros_like(do)
        for rs, cs, qn_cache, kn_cache, a_cache in heads:
            dqh, dkh, dvh = attention_bwd(do[rs, cs], a_cache)
            if qk_norm:
                dqh, dgq = rmsnorm_bwd(dqh, qn_cache)
                dkh, dgk = rmsnorm_bwd(dkh, kn_cache)
                grads[p + "q_norm"] += dgq
                grads[p + "k_norm"] += dgk
            dq[rs, cs] = dqh
            dk[rs, cs] = dkh
            dv[rs, cs] = dvh
        grads[p + "wq"] += y.T @ dq
        grads[p + "wk"] += y.T @ dk
        grads[p + "wv"] += y.T @ dv
        dy = dq @ params[p + "wq"].T + dk @ params[p + "wk"].T \
            + dv @ params[p + "wv"].T
        dxa, dg_a = rmsnorm_bwd(dy, an_cache)
        grads[p + "attn_norm"] += dg_a
        dx = dx1 + dxa  # residual into the block input
    # embedding gather backward
    np.add.at(grads["embed"], flat, dx)
    return loss, grads, max_logit


# ---------------------------------------------------------------------------
# Finite-difference harness
# ---------------------------------------------------------------------------


def fd_check(fn_loss, tensors, grads, rng, n_probe=40, eps=5e-3):
    """Central-difference check.  fn_loss() recomputes the scalar from the
    (mutated) tensors; returns max |fd - analytic| / rms(analytic)."""
    worst = 0.0
    for t, g in zip(tensors, grads):
        flat_t = t.reshape(-1)
        flat_g = g.reshape(-1)
        rms = float(np.sqrt(np.mean(np.square(flat_g.astype(np.float64))))) + 1e-12
        idx = rng.choice(len(flat_t), size=min(n_probe, len(flat_t)), replace=False)
        for j in idx:
            orig = flat_t[j]
            flat_t[j] = orig + F(eps)
            lp = fn_loss()
            flat_t[j] = orig - F(eps)
            lm = fn_loss()
            flat_t[j] = orig
            fd = (lp - lm) / (2 * eps)
            err = abs(fd - float(flat_g[j])) / rms
            worst = max(worst, err)
    return worst


def run_gradchecks():
    rng = np.random.default_rng(0)
    report = []

    # RMSNorm --------------------------------------------------------------
    x = rng.standard_normal((8, 16)).astype(F)
    gamma = (1.0 + 0.1 * rng.standard_normal(16)).astype(F)
    w = rng.standard_normal((8, 16)).astype(F)

    def loss_rms():
        y, _ = rmsnorm_fwd(x, gamma)
        return float(np.sum(w * y))

    y, cache = rmsnorm_fwd(x, gamma)
    dx, dgamma = rmsnorm_bwd(w, cache)
    report.append(("rmsnorm", fd_check(loss_rms, [x, gamma], [dx, dgamma], rng)))

    # QK-norm (same op at head width, gamma near 1) ------------------------
    xq = rng.standard_normal((SEQ, D_HEAD)).astype(F)
    gq = (1.0 + 0.05 * rng.standard_normal(D_HEAD)).astype(F)
    wq = rng.standard_normal((SEQ, D_HEAD)).astype(F)

    def loss_qk():
        yq, _ = rmsnorm_fwd(xq, gq)
        return float(np.sum(wq * yq))

    yq, cq = rmsnorm_fwd(xq, gq)
    dxq, dgq = rmsnorm_bwd(wq, cq)
    report.append(("qk-norm", fd_check(loss_qk, [xq, gq], [dxq, dgq], rng)))

    # SwiGLU MLP -----------------------------------------------------------
    ym = rng.standard_normal((8, D_MODEL)).astype(F)
    wg = (0.3 * rng.standard_normal((D_MODEL, D_FF))).astype(F)
    wu = (0.3 * rng.standard_normal((D_MODEL, D_FF))).astype(F)
    wd = (0.3 * rng.standard_normal((D_FF, D_MODEL))).astype(F)
    wm = rng.standard_normal((8, D_MODEL)).astype(F)

    def loss_mlp():
        out, _ = mlp_fwd(ym, wg, wu, wd)
        return float(np.sum(wm * out))

    out, cm = mlp_fwd(ym, wg, wu, wd)
    dy, dwg, dwu, dwd = mlp_bwd(wm, cm, wg, wu, wd)
    report.append(("mlp", fd_check(loss_mlp, [ym, wg, wu, wd],
                                   [dy, dwg, dwu, dwd], rng)))

    # Causal FPA attention -------------------------------------------------
    qa = rng.standard_normal((SEQ, D_HEAD)).astype(F)
    ka = rng.standard_normal((SEQ, D_HEAD)).astype(F)
    va = rng.standard_normal((SEQ, D_HEAD)).astype(F)
    wa = rng.standard_normal((SEQ, D_HEAD)).astype(F)

    def loss_attn():
        o, _, _ = attention_fwd(qa, ka, va)
        return float(np.sum(wa * o))

    o, ca, _ = attention_fwd(qa, ka, va)
    dqa, dka, dva = attention_bwd(wa, ca)
    report.append(("attention", fd_check(loss_attn, [qa, ka, va],
                                         [dqa, dka, dva], rng)))

    # Tied-embedding cross-entropy ----------------------------------------
    fx = rng.standard_normal((16, D_MODEL)).astype(F)
    emb = (0.5 * rng.standard_normal((64, D_MODEL))).astype(F)
    tgt = rng.integers(0, 64, size=16)

    def loss_ce():
        loss, _ = ce_fwd(fx, emb, tgt)
        return loss

    loss, cc = ce_fwd(fx, emb, tgt)
    dfx, demb = ce_bwd(cc, emb)
    report.append(("cross-entropy", fd_check(loss_ce, [fx, emb],
                                             [dfx, demb], rng, eps=1e-2)))

    # Full model, a few coordinates per leaf -------------------------------
    params = init_params(True, rng)
    tokens = rng.integers(0, VOCAB, size=(MICRO_B, SEQ))
    targets = rng.integers(0, VOCAB, size=(MICRO_B, SEQ))

    def loss_model():
        l, _, _ = model_loss_and_grads(params, tokens, targets, True,
                                       want_grads=False)
        return l

    _, grads, _ = model_loss_and_grads(params, tokens, targets, True)
    leaves = ["embed", "layers.00.wq", "layers.00.q_norm", "layers.01.w_gate",
              "final_norm"]
    worst = 0.0
    for name in leaves:
        # eps 2e-2 balances f32 round-off vs truncation end-to-end (the
        # sweep minimum); rust/tests/model_gradcheck.rs uses the same.
        worst = max(worst, fd_check(loss_model, [params[name]], [grads[name]],
                                    rng, n_probe=8, eps=2e-2))
    report.append(("full-model", worst))

    print("gradcheck: observed max |fd - analytic| / rms(analytic)  (float32)")
    for name, err in report:
        print(f"  {name:<14} {err:.3e}")
    return report


# ---------------------------------------------------------------------------
# Fig-1 divergence simulation
# ---------------------------------------------------------------------------


def zipf_batch(rng, b, n):
    """Zipf(1.2)-ish token stream — the synthetic-corpus stand-in."""
    toks = np.minimum(
        (rng.pareto(1.2, size=(b, n + 1)) * 4).astype(np.int64), VOCAB - 1)
    return toks[:, :n], toks[:, 1:]


def adamw_step(params, grads, m, v, lr, step):
    """f32 moment storage, f64 per-element update math — exactly what
    rust/src/model/adamw.rs does."""
    c1 = 1.0 - B1 ** step
    c2 = 1.0 - B2 ** step
    for name in params:
        g = grads[name].astype(np.float64)
        m[name] = (B1 * m[name].astype(np.float64) + (1 - B1) * g).astype(F)
        v[name] = (B2 * v[name].astype(np.float64) + (1 - B2) * g * g).astype(F)
        upd = (m[name].astype(np.float64) / c1) \
            / (np.sqrt(v[name].astype(np.float64) / c2) + ADAM_EPS)
        decay = 0.0 if name.endswith("norm") else WD
        params[name] = (params[name].astype(np.float64)
                        - lr * (upd + decay * params[name].astype(np.float64))).astype(F)


def cosine_lr(step, peak, warmup, total, min_frac=0.1):
    if warmup > 0 and step < warmup:
        return peak * (step + 1) / warmup
    prog = min(max((step - warmup) / max(total - warmup, 1), 0.0), 1.0)
    return peak * (min_frac + (1 - min_frac) * 0.5 * (1 + math.cos(math.pi * prog)))


def train_cell(qk_norm, tps, budget, peak_lr, seed):
    rng = np.random.default_rng(seed)
    params = init_params(qk_norm, rng)
    m = {k: np.zeros(t.shape, F) for k, t in params.items()}
    v = {k: np.zeros(t.shape, F) for k, t in params.items()}
    steps = max(budget // tps, 2)
    warmup = max(steps // 20, 1)
    micro = tps // (MICRO_B * SEQ)
    first_loss, last_loss = None, None
    for step in range(steps):
        gsum = None
        lsum = 0.0
        ml_step = 0.0
        for _ in range(micro):
            tokens, targets = zipf_batch(rng, MICRO_B, SEQ)
            loss, grads, ml = model_loss_and_grads(params, tokens, targets, qk_norm)
            ml_step = max(ml_step, ml)
            lsum += loss
            if gsum is None:
                gsum = {k: g.astype(np.float64) for k, g in grads.items()}
            else:
                for k in gsum:
                    gsum[k] += grads[k]
        loss = lsum / micro
        for k in gsum:
            gsum[k] /= micro
        if first_loss is None:
            first_loss = loss
        last_loss = loss
        if not math.isfinite(loss) or ml_step > CEILING:
            return dict(status="DIVERGED", at=step, loss=loss, max_logit=ml_step,
                        first_loss=first_loss)
        lr = cosine_lr(step, peak_lr, warmup, steps)
        adamw_step(params, gsum, m, v, lr, step + 1)
    return dict(status="ok", at=steps, loss=last_loss, max_logit=ml_step,
                first_loss=first_loss)


def run_sim(budget=131072, tps_lo=1024, tps_hi=8192, lrs=(0.02, 0.05, 0.1, 0.2)):
    print(f"\nfig1 sim: budget={budget} tps_lo={tps_lo} tps_hi={tps_hi} "
          f"(steps hi={budget // tps_hi}, lo={budget // tps_lo})")
    for lr in lrs:
        print(f"-- peak_lr {lr}")
        for qk, tps, label in [(True, tps_hi, "qknorm  @hi"),
                               (False, tps_hi, "noqknorm@hi"),
                               (True, tps_lo, "qknorm  @lo"),
                               (False, tps_lo, "noqknorm@lo")]:
            r = train_cell(qk, tps, budget, lr, seed=0)
            print(f"   {label}: {r['status']:<8} at step {r['at']:>4} "
                  f"loss {r['first_loss']:.3f}->{r['loss']:.3f} "
                  f"max_logit {r['max_logit']:.1f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--sim", action="store_true", help="run the fig1 LR sweep")
    ap.add_argument("--budget", type=int, default=131072)
    ap.add_argument("--lrs", type=str, default="0.02,0.05,0.1,0.2")
    args = ap.parse_args()
    run_gradchecks()
    if args.sim:
        run_sim(budget=args.budget,
                lrs=tuple(float(x) for x in args.lrs.split(",")))
