"""Model/run variant registry shared by aot.py, tests, and the manifest.

The paper pre-trains a 325M Llama (d=3072, N=4096) on 78B tokens; our CPU
interpret-mode substrate scales that to a few-M-parameter Llama on a
synthetic corpus (DESIGN.md §6 — substitution table).  The *variant grid*
mirrors the paper's experiment axes exactly:

  attention ∈ {sage, fpa}  ×  qk_norm ∈ {on, off}  ×  smoothing ∈ {none, k, qk}
"""

from __future__ import annotations

from typing import NamedTuple


class ModelConfig(NamedTuple):
    vocab_size: int = 512
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    d_head: int = 32
    d_ff: int = 768          # SwiGLU hidden (Llama's 8/3·d rounded to 3·d here)
    seq_len: int = 128
    norm_eps: float = 1e-6   # paper §5.1
    rope_theta: float = 10000.0
    qk_norm: bool = True
    attention: str = "sage"  # "sage" | "fpa"
    k_smoothing: bool = True  # paper default: K-smoothing on, Q-smoothing off
    q_smoothing: bool = False
    block_q: int = 32
    block_kv: int = 32

    @property
    def param_count_estimate(self) -> int:
        d, ff, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab_size
        per_layer = 4 * d * d + 3 * d * ff + 2 * d + 2 * self.d_head
        return V * d + L * per_layer + d


# The pre-training variant grid (Figures 1 & 4).  Names are artifact keys.
def _v(attention, qk_norm, k_sm, q_sm) -> ModelConfig:
    return ModelConfig(attention=attention, qk_norm=qk_norm,
                       k_smoothing=k_sm, q_smoothing=q_sm)


VARIANTS: dict[str, ModelConfig] = {
    # Figure 1: SageBwd vs FPA, ±QK-norm (K-smoothing on — the §5 default).
    "sage_qknorm": _v("sage", True, True, False),
    "sage_noqknorm": _v("sage", False, True, False),
    "fpa_qknorm": _v("fpa", True, True, False),
    "fpa_noqknorm": _v("fpa", False, True, False),
    # Figure 4 ablation (all QK-normed): no smoothing / K / QK.
    "sage_qknorm_nosm": _v("sage", True, False, False),
    "sage_qknorm_qksm": _v("sage", True, True, True),
}

# Attention-trace variants (Table 1/2, Figures 5/6): single-head (N, D).
class TraceConfig(NamedTuple):
    n: int = 128
    d: int = 64
    causal: bool = False
    impl: str = "sage"        # "sage" (kernel) | "pseudo" (§5.4) | "fpa"
    k_smoothing: bool = True
    q_smoothing: bool = False
    block: int = 32
    quant_ds: bool = True     # False = §7 future-work FP-dS variant


TRACE_VARIANTS: dict[str, TraceConfig] = {
    "trace_fpa": TraceConfig(impl="fpa"),
    "trace_sage": TraceConfig(impl="sage"),
    "trace_pseudo": TraceConfig(impl="pseudo"),
    "trace_pseudo_nosm": TraceConfig(impl="pseudo", k_smoothing=False),
    "trace_pseudo_qksm": TraceConfig(impl="pseudo", q_smoothing=True),
    "trace_sage_nosm": TraceConfig(impl="sage", k_smoothing=False),
    "trace_sage_qksm": TraceConfig(impl="sage", q_smoothing=True),
    # Longer sequence for the §4.2 dS-magnitude probe.
    "trace_fpa_n512": TraceConfig(impl="fpa", n=512),
    "trace_sage_n512": TraceConfig(impl="sage", n=512),
    # §7 future-work extension: FP dS path (4-of-7 INT8 MMs).
    "trace_sage_dsfp": TraceConfig(impl="sage", quant_ds=False),
    "trace_pseudo_dsfp": TraceConfig(impl="pseudo", quant_ds=False),
}

# Kernel speed benchmark grid (Figures 2 & 3).
class BenchConfig(NamedTuple):
    impl: str          # "sage" | "fa2" | "naive"
    n: int
    d: int
    mode: str          # "fwd" | "fwdbwd"
    causal: bool = False
    block: int = 32


BENCH_SEQ_LENS = (128, 256, 512)
BENCH_HEAD_DIMS = (64, 128)
BENCH_IMPLS = ("sage", "fa2", "naive")


def bench_variants() -> dict[str, BenchConfig]:
    out = {}
    for d in BENCH_HEAD_DIMS:
        for n in BENCH_SEQ_LENS:
            for impl in BENCH_IMPLS:
                for mode in ("fwd", "fwdbwd"):
                    if impl != "sage" and mode == "fwdbwd":
                        # Baselines differentiate via jnp autodiff.
                        pass
                    name = f"bench_{impl}_{mode}_d{d}_n{n}"
                    out[name] = BenchConfig(impl=impl, n=n, d=d, mode=mode)
    return out
