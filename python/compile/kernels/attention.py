"""Differentiable attention front-ends used by the L2 model.

``sage_attention``  — SageBwd (Algorithms 1+2) wired through ``custom_vjp``
so that ``jax.grad`` of the model loss routes through the INT8 Pallas
backward kernel instead of autodiff'ing the forward.

``fpa_attention``   — full-precision attention; plain jnp, differentiated by
JAX itself.  The paper's FPA baseline.

Both take ``(B, H, N, D)`` tensors (the single-head kernels are vmapped
over batch and head) and a static config.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import sagebwd_fwd
from . import sagebwd_bwd


class SageConfig(NamedTuple):
    """Static kernel configuration (hashable so it can be a vjp nondiff arg)."""

    block_q: int = 64
    block_kv: int = 64
    causal: bool = True
    k_smoothing: bool = True
    q_smoothing: bool = False


def _vmap2(fn):
    """vmap a single-head (N,D) function over (B, H, N, D)."""
    return jax.vmap(jax.vmap(fn))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def sage_attention(q, k, v, cfg: SageConfig = SageConfig()):
    o, _ = _sage_fwd_res(q, k, v, cfg)
    return o


def _sage_fwd_res(q, k, v, cfg: SageConfig):
    fwd = lambda qq, kk, vv: sagebwd_fwd.sage_fwd(
        qq, kk, vv, block_q=cfg.block_q, block_kv=cfg.block_kv,
        causal=cfg.causal, k_smoothing=cfg.k_smoothing,
        q_smoothing=cfg.q_smoothing)
    o, lse = _vmap2(fwd)(q, k, v)
    return o, (q, k, v, o, lse)


def _sage_fwd_vjp(cfg: SageConfig, q, k, v):
    o, res = _sage_fwd_res(q, k, v, cfg)
    return o, res


def _sage_bwd_vjp(cfg: SageConfig, res, do):
    q, k, v, o, lse = res
    bwd = lambda qq, kk, vv, dd, oo, ll: sagebwd_bwd.sage_bwd(
        qq, kk, vv, dd, oo, ll, block_q=cfg.block_q, block_kv=cfg.block_kv,
        causal=cfg.causal, k_smoothing=cfg.k_smoothing,
        q_smoothing=cfg.q_smoothing)
    dq, dk, dv = _vmap2(bwd)(q, k, v, do, o, lse)
    return dq, dk, dv


sage_attention.defvjp(
    lambda q, k, v, cfg: _sage_fwd_vjp(cfg, q, k, v),
    lambda cfg, res, do: _sage_bwd_vjp(cfg, res, do),
)


def fpa_attention(q, k, v, causal: bool = True):
    """Exact scaled-dot-product attention on (B, H, N, D); jnp autodiff."""
    d = q.shape[-1]
    s = jnp.einsum("bhnd,bhmd->bhnm", q, k) / jnp.sqrt(jnp.float32(d))
    if causal:
        n = q.shape[-2]
        mask = jnp.tril(jnp.ones((n, n), dtype=bool))
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhnm,bhmd->bhnd", p, v)
