"""FlashAttention2-style tiled baseline (paper's Figs 2–3 comparator).

Pure-jnp online-softmax attention, tiled exactly like the SageBwd kernel
but with every matmul in full precision — i.e. "Triton-FA2" from the paper
transplanted into this execution regime.  Used (a) as the speed baseline in
`rust/benches/bench_attention.rs` via an AOT artifact and (b) as another
correctness witness (FA2 must equal naive SDPA to fp32 round-off).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .sagebwd_fwd import NEG_INF


def _fa2_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *,
                block_q: int, block_kv: int, n: int, causal: bool,
                sm_scale: float):
    i = pl.program_id(0)
    d = q_ref.shape[-1]
    q_tile = q_ref[...].astype(jnp.float32)
    num_kv = n // block_kv
    row_ids = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)

    def body(j, carry):
        acc, m_i, l_i = carry
        k_tile = pl.load(k_ref, (pl.dslice(j * block_kv, block_kv), slice(None))).astype(jnp.float32)
        v_tile = pl.load(v_ref, (pl.dslice(j * block_kv, block_kv), slice(None))).astype(jnp.float32)
        s_ij = jnp.dot(q_tile, k_tile.T) * sm_scale
        if causal:
            col_ids = j * block_kv + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 1)
            s_ij = jnp.where(row_ids >= col_ids, s_ij, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s_ij, axis=-1))
        p_ij = jnp.exp(s_ij - m_new[:, None])
        corr = jnp.exp(m_i - m_new)
        l_new = l_i * corr + jnp.sum(p_ij, axis=-1)
        acc = acc * corr[:, None] + jnp.dot(p_ij, v_tile)
        return acc, m_new, l_new

    init = (jnp.zeros((block_q, d), jnp.float32),
            jnp.full((block_q,), -jnp.inf, jnp.float32),
            jnp.zeros((block_q,), jnp.float32))
    hi = jnp.minimum(((i + 1) * block_q + block_kv - 1) // block_kv, num_kv) if causal else num_kv
    acc, m_i, l_i = jax.lax.fori_loop(0, hi, body, init)
    o_ref[...] = (acc / l_i[:, None]).astype(o_ref.dtype)
    lse_ref[...] = (m_i + jnp.log(l_i)).astype(lse_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_kv", "causal"))
def fa2_fwd(q, k, v, block_q: int = 64, block_kv: int = 64,
            causal: bool = False):
    """FA2-style forward on (N, D). Returns (o, lse)."""
    n, d = q.shape
    sm_scale = 1.0 / math.sqrt(d)
    kernel = functools.partial(_fa2_kernel, block_q=block_q,
                               block_kv=block_kv, n=n, causal=causal,
                               sm_scale=sm_scale)
    return pl.pallas_call(
        kernel,
        grid=(n // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((block_q,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(q, k, v)


def naive_sdpa(q, k, v, causal: bool = False):
    """Unfused reference SDPA on (N, D) — the 'torch' baseline analogue."""
    d = q.shape[-1]
    s = (q @ k.T) / jnp.sqrt(jnp.float32(d))
    if causal:
        n = q.shape[0]
        s = jnp.where(jnp.tril(jnp.ones((n, n), dtype=bool)), s, -jnp.inf)
    return jax.nn.softmax(s, axis=-1) @ v


# ---------------------------------------------------------------------------
# FA2-style backward (full-precision twin of sagebwd_bwd's two kernels) —
# pallas_call has no autodiff rule, so the baseline backward is explicit.
# ---------------------------------------------------------------------------


def _fa2_dkdv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                     dk_ref, dv_ref, *, block_q, block_kv, n, causal, sm_scale):
    j = pl.program_id(0)
    d = q_ref.shape[-1]
    k_tile = k_ref[...].astype(jnp.float32)
    v_tile = v_ref[...].astype(jnp.float32)
    num_q = n // block_q

    def body(i, carry):
        dk_acc, dv_acc = carry
        q_tile = pl.load(q_ref, (pl.dslice(i * block_q, block_q), slice(None))).astype(jnp.float32)
        do_tile = pl.load(do_ref, (pl.dslice(i * block_q, block_q), slice(None))).astype(jnp.float32)
        lse_tile = pl.load(lse_ref, (pl.dslice(i * block_q, block_q),))
        delta_tile = pl.load(delta_ref, (pl.dslice(i * block_q, block_q),))
        s_ij = jnp.dot(q_tile, k_tile.T) * sm_scale
        if causal:
            row_ids = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
            col_ids = j * block_kv + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
            s_ij = jnp.where(row_ids >= col_ids, s_ij, NEG_INF)
        p_ij = jnp.exp(s_ij - lse_tile[:, None])
        dv_acc = dv_acc + jnp.dot(p_ij.T, do_tile)
        dp_ij = jnp.dot(do_tile, v_tile.T)
        ds_ij = p_ij * (dp_ij - delta_tile[:, None])
        dk_acc = dk_acc + jnp.dot(ds_ij.T, q_tile) * sm_scale
        return dk_acc, dv_acc

    lo = (j * block_kv) // block_q if causal else 0
    init = (jnp.zeros((block_kv, d), jnp.float32),
            jnp.zeros((block_kv, d), jnp.float32))
    dk_acc, dv_acc = jax.lax.fori_loop(lo, num_q, body, init)
    dk_ref[...] = dk_acc
    dv_ref[...] = dv_acc


def _fa2_dq_kernel(q_ref, k_ref, do_ref, v_ref, lse_ref, delta_ref, dq_ref, *,
                   block_q, block_kv, n, causal, sm_scale):
    i = pl.program_id(0)
    d = q_ref.shape[-1]
    q_tile = q_ref[...].astype(jnp.float32)
    do_tile = do_ref[...].astype(jnp.float32)
    lse_tile = lse_ref[...]
    delta_tile = delta_ref[...]
    num_kv = n // block_kv

    def body(j, dq_acc):
        k_tile = pl.load(k_ref, (pl.dslice(j * block_kv, block_kv), slice(None))).astype(jnp.float32)
        v_tile = pl.load(v_ref, (pl.dslice(j * block_kv, block_kv), slice(None))).astype(jnp.float32)
        s_ij = jnp.dot(q_tile, k_tile.T) * sm_scale
        if causal:
            row_ids = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
            col_ids = j * block_kv + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
            s_ij = jnp.where(row_ids >= col_ids, s_ij, NEG_INF)
        p_ij = jnp.exp(s_ij - lse_tile[:, None])
        dp_ij = jnp.dot(do_tile, v_tile.T)
        ds_ij = p_ij * (dp_ij - delta_tile[:, None])
        return dq_acc + jnp.dot(ds_ij, k_tile) * sm_scale

    hi = jnp.minimum(((i + 1) * block_q + block_kv - 1) // block_kv, num_kv) if causal else num_kv
    dq = jax.lax.fori_loop(0, hi, body, jnp.zeros((block_q, d), jnp.float32))
    dq_ref[...] = dq


@functools.partial(jax.jit, static_argnames=("block_q", "block_kv", "causal"))
def fa2_bwd(q, k, v, do, o, lse, block_q: int = 64, block_kv: int = 64,
            causal: bool = False):
    """FA2-style backward on (N, D) → (dQ, dK, dV); all MMs full precision."""
    n, d = q.shape
    sm_scale = 1.0 / math.sqrt(d)
    delta = jnp.sum(do * o, axis=-1)

    dkdv = functools.partial(_fa2_dkdv_kernel, block_q=block_q,
                             block_kv=block_kv, n=n, causal=causal,
                             sm_scale=sm_scale)
    dk, dv = pl.pallas_call(
        dkdv,
        grid=(n // block_kv,),
        in_specs=[
            pl.BlockSpec((n, d), lambda j: (0, 0)),
            pl.BlockSpec((block_kv, d), lambda j: (j, 0)),
            pl.BlockSpec((block_kv, d), lambda j: (j, 0)),
            pl.BlockSpec((n, d), lambda j: (0, 0)),
            pl.BlockSpec((n,), lambda j: (0,)),
            pl.BlockSpec((n,), lambda j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_kv, d), lambda j: (j, 0)),
            pl.BlockSpec((block_kv, d), lambda j: (j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((n, d), jnp.float32),
        ],
        interpret=True,
    )(q, k, v, do, lse, delta)

    dqk = functools.partial(_fa2_dq_kernel, block_q=block_q,
                            block_kv=block_kv, n=n, causal=causal,
                            sm_scale=sm_scale)
    dq = pl.pallas_call(
        dqk,
        grid=(n // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((block_q,), lambda i: (i,)),
            pl.BlockSpec((block_q,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_q, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=True,
    )(q, k, do, v, lse, delta)
    return dq, dk, dv
