"""INT8 quantization primitives for SageBwd (paper §3 "Quantization").

Implements the quantizer ψ used throughout Algorithms 1 and 2:

    x̂ = round(x / δ),   δ = max(|x|) / 127

with three granularities (paper §3 "granularity"):

  * per-tensor  — one δ for the whole matrix,
  * per-block   — one δ per FlashAttention tile (the SageBwd default),
  * per-token   — one δ per row (used for P̃ in Alg 1 line 9).

All quantized values live in int8 in [-127, 127]; scales are fp32.  The
integer matmul is done with ``preferred_element_type=int32`` so it is exact
— identical numerics to the GPU's IMMA / TPU's 8-bit MXU path.
"""

from __future__ import annotations

import jax.numpy as jnp

# Smallest scale we allow.  A true all-zeros block would otherwise produce
# δ = 0 and NaNs on the dequant path; the paper's kernels share the same
# guard implicitly through Triton's fp32 max being clamped.
EPS_SCALE = 1e-12

INT8_MAX = 127.0


def quantize_per_tensor(x: jnp.ndarray):
    """ψ with one scale for the whole tensor.

    Returns ``(x_int8, scale)`` with ``scale`` of shape ``()``.
    """
    scale = jnp.maximum(jnp.max(jnp.abs(x)), EPS_SCALE) / INT8_MAX
    q = jnp.clip(jnp.round(x / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def quantize_per_block(x: jnp.ndarray):
    """ψ for one FlashAttention tile: the tile *is* the block.

    SageBwd's per-block quantization assigns a single scale per tile
    (paper Alg 1 line 3, Alg 2 lines 6 & 9).  Inside a kernel the operand
    already is the tile, so this is per-tensor over the tile.
    """
    return quantize_per_tensor(x)


def quantize_per_token(x: jnp.ndarray):
    """ψ with one scale per row (last-axis groups).

    Used for P̃ in Alg 1 line 9 — each query token's probability row gets
    its own scale, which is essential because rowmax(P̃) varies by orders
    of magnitude across rows after the online-softmax subtraction.

    Returns ``(x_int8, scale)`` with ``scale`` of shape ``x.shape[:-1] + (1,)``.
    """
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), EPS_SCALE) / INT8_MAX
    q = jnp.clip(jnp.round(x / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Inverse of ψ: x ≈ x̂ · δ (broadcasting scale)."""
    return q.astype(jnp.float32) * scale


def int8_matmul(a_q: jnp.ndarray, a_s: jnp.ndarray, b_q: jnp.ndarray, b_s: jnp.ndarray) -> jnp.ndarray:
    """A·B ≈ δ_A δ_B · (Â B̂) with the integer product exact in int32.

    ``a_s`` may be per-tensor () or per-token (m,1); ``b_s`` per-tensor ()
    or per-token-of-B-columns (1,n) after the caller transposes.
    """
    acc = jnp.dot(a_q.astype(jnp.int32), b_q.astype(jnp.int32), preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * a_s * b_s


def fake_quant(x: jnp.ndarray, granularity: str = "block") -> jnp.ndarray:
    """Quantize-dequantize round trip (the §5.4 pseudo-quantization)."""
    if granularity == "tensor" or granularity == "block":
        q, s = quantize_per_tensor(x)
    elif granularity == "token":
        q, s = quantize_per_token(x)
    else:
        raise ValueError(f"unknown granularity {granularity!r}")
    return dequantize(q, s)


def quant_error_bound(x: jnp.ndarray) -> jnp.ndarray:
    """Worst-case absolute quantization error: δ/2 (paper §4.4's "step size")."""
    return jnp.maximum(jnp.max(jnp.abs(x)), EPS_SCALE) / INT8_MAX / 2.0
