"""Pure-jnp correctness oracles.

Three references, each serving a different experiment:

1. ``fpa_fwd`` / ``fpa_bwd`` — exact full-precision attention (FPA) with all
   intermediates materialized.  This is the ground truth every error metric
   in the paper is computed against.

2. ``sage_ref_fwd`` / ``sage_ref_bwd`` — a *block-faithful* reimplementation
   of Algorithms 1 and 2 in plain jnp: identical per-block/per-token INT8
   quantization, identical online-softmax recurrence, but without the Pallas
   plumbing.  The Pallas kernels must match this to ~fp32 round-off; it is
   the tight oracle for `pytest python/tests/test_kernel_*.py`.

3. ``pseudo_quant_trace`` — the §5.4 methodology: take a plain attention
   implementation, insert INT8 quantize-dequantize before each matmul that
   SageBwd quantizes, and return every intermediate (δ, P, dP, dS, O, dQ,
   dK, dV) for comparison against FPA.  Regenerates Table 2 and Figures 5/6.

All functions operate on single-head ``(N, D)`` tensors; the model layer
vmaps over batch and heads.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import quant
from . import smoothing


class AttnIntermediates(NamedTuple):
    """Everything the paper's error analysis inspects (§5.4, Table 2)."""

    o: jnp.ndarray      # (N, D) attention output
    s: jnp.ndarray      # (N, N) logits  Q K^T / sqrt(d)
    p: jnp.ndarray      # (N, N) softmax(S)
    lse: jnp.ndarray    # (N,)   row logsumexp of S (FlashAttention "L")
    delta: jnp.ndarray  # (N,)   rowsum(dO ∘ O)      (zeros in fwd-only)
    dp: jnp.ndarray     # (N, N) dO V^T              (zeros in fwd-only)
    ds: jnp.ndarray     # (N, N) P ∘ (dP − δ 1^T)    (zeros in fwd-only)
    dq: jnp.ndarray     # (N, D)
    dk: jnp.ndarray     # (N, D)
    dv: jnp.ndarray     # (N, D)


def _causal_mask(n: int) -> jnp.ndarray:
    return jnp.tril(jnp.ones((n, n), dtype=bool))


def fpa_fwd(q, k, v, causal: bool = False):
    """Exact attention forward.  Returns (O, (S, P, lse))."""
    d = q.shape[-1]
    s = (q @ k.T) / jnp.sqrt(jnp.float32(d))
    if causal:
        s = jnp.where(_causal_mask(q.shape[0]), s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    z = jnp.sum(e, axis=-1, keepdims=True)
    p = e / z
    lse = (m + jnp.log(z)).squeeze(-1)
    o = p @ v
    return o, (s, p, lse)


def fpa_bwd(q, k, v, do, causal: bool = False) -> AttnIntermediates:
    """Exact attention forward+backward with every intermediate (paper §3).

        dV = P^T dO,  dP = dO V^T,  δ = rowsum(dO ∘ O),
        dS = P ∘ (dP − δ 1^T),  dQ = dS K / √d,  dK = dS^T Q / √d.
    """
    d = q.shape[-1]
    o, (s, p, lse) = fpa_fwd(q, k, v, causal)
    dv = p.T @ do
    dp = do @ v.T
    delta = jnp.sum(do * o, axis=-1)
    ds = p * (dp - delta[:, None])
    inv_sqrt_d = 1.0 / jnp.sqrt(jnp.float32(d))
    dq = (ds @ k) * inv_sqrt_d
    dk = (ds.T @ q) * inv_sqrt_d
    return AttnIntermediates(o, s, p, lse, delta, dp, ds, dq, dk, dv)


# ---------------------------------------------------------------------------
# Block-faithful SageBwd reference (Algorithms 1 & 2 in plain jnp)
# ---------------------------------------------------------------------------


def _split_blocks(x, block):
    n = x.shape[0]
    assert n % block == 0, f"N={n} not divisible by block={block}"
    return x.reshape(n // block, block, *x.shape[1:])


def sage_ref_fwd(
    q,
    k,
    v,
    block_q: int = 64,
    block_kv: int = 64,
    causal: bool = False,
    k_smoothing: bool = True,
    q_smoothing: bool = False,
):
    """Algorithm 1 in plain jnp, bit-matching the Pallas kernel's math.

    Returns (O, lse, residuals) where residuals carry the quantized tiles
    and scales the backward pass reuses (Alg 2 line 1).
    """
    n, d = q.shape
    inv_sqrt_d = 1.0 / jnp.sqrt(jnp.float32(d))

    if k_smoothing:
        k_in, _ = smoothing.k_smooth(k)
    else:
        k_in = k
    mu_q = None
    if q_smoothing:
        q_in, mu_q = smoothing.q_smooth(q)
        # Rank-1 bias added back to every logit row (softmax-invariant per
        # row only for K-smoothing; for Q-smoothing the bias varies across
        # *columns* so it must be restored before the softmax).
        bias_row = (mu_q @ k_in.T).reshape(1, -1)  # (1, N)
    else:
        q_in = q
        bias_row = jnp.zeros((1, n), dtype=q.dtype)

    qb = _split_blocks(q_in, block_q)
    kb = _split_blocks(k_in, block_kv)
    vb = _split_blocks(v, block_kv)
    tm, tn = qb.shape[0], kb.shape[0]

    # Per-block quantization of Q, K, V (Alg 1 line 3).
    q_q, q_s = jax.vmap(quant.quantize_per_block)(qb)
    k_q, k_s = jax.vmap(quant.quantize_per_block)(kb)
    v_q, v_s = jax.vmap(quant.quantize_per_block)(vb)

    o = jnp.zeros((tm, block_q, d), jnp.float32)
    lse = jnp.zeros((tm, block_q), jnp.float32)

    rows = []
    lses = []
    for i in range(tm):
        acc = jnp.zeros((block_q, d), jnp.float32)
        m_i = jnp.full((block_q,), -jnp.inf, jnp.float32)
        l_i = jnp.zeros((block_q,), jnp.float32)
        for j in range(tn):
            if causal and (j * block_kv > (i + 1) * block_q - 1):
                continue
            s_ij = quant.int8_matmul(q_q[i], q_s[i], k_q[j].T, k_s[j]) * inv_sqrt_d
            s_ij = s_ij + bias_row[:, j * block_kv : (j + 1) * block_kv] * inv_sqrt_d
            if causal:
                qi = jnp.arange(i * block_q, (i + 1) * block_q)[:, None]
                kj = jnp.arange(j * block_kv, (j + 1) * block_kv)[None, :]
                s_ij = jnp.where(qi >= kj, s_ij, -jnp.inf)
            m_new = jnp.maximum(m_i, jnp.max(s_ij, axis=-1))
            p_ij = jnp.exp(s_ij - m_new[:, None])
            corr = jnp.exp(m_i - m_new)
            l_i = l_i * corr + jnp.sum(p_ij, axis=-1)
            # Per-token quantization of P̃ (Alg 1 line 9): rowmax(P̃) = 1 by
            # construction for the row that attains m_new, otherwise < 1.
            p_q, p_s = quant.quantize_per_token(p_ij)
            pv = jnp.dot(p_q.astype(jnp.int32), v_q[j].astype(jnp.int32),
                         preferred_element_type=jnp.int32).astype(jnp.float32)
            pv = pv * p_s * v_s[j]
            acc = acc * corr[:, None] + pv
            m_i = m_new
        acc = acc / l_i[:, None]
        rows.append(acc)
        lses.append(m_i + jnp.log(l_i))
    o = jnp.concatenate(rows, axis=0)
    lse = jnp.concatenate(lses, axis=0)
    residuals = dict(q_q=q_q, q_s=q_s, k_q=k_q, k_s=k_s, v_q=v_q, v_s=v_s,
                     mu_q=mu_q, bias_row=bias_row)
    return o, lse, residuals


def sage_ref_bwd(
    q,
    k,
    v,
    do,
    block_q: int = 64,
    block_kv: int = 64,
    causal: bool = False,
    k_smoothing: bool = True,
    q_smoothing: bool = False,
    quant_ds: bool = True,
):
    """Algorithms 1+2 in plain jnp.  Returns AttnIntermediates.

    Matches the kernel exactly: INT8 per-block for S, dV, dQ, dK MMs; dP in
    full precision (Alg 2 line 8 "Keep in FP16"); per-block re-quantization
    of P and dO (line 6) and of dS (line 9).
    """
    n, d = q.shape
    inv_sqrt_d = 1.0 / jnp.sqrt(jnp.float32(d))
    o, lse, res = sage_ref_fwd(q, k, v, block_q, block_kv, causal,
                               k_smoothing, q_smoothing)
    q_q, q_s, k_q, k_s = res["q_q"], res["q_s"], res["k_q"], res["k_s"]
    bias_row = res["bias_row"]
    mu_q = res["mu_q"]

    delta = jnp.sum(do * o, axis=-1)
    dob = _split_blocks(do, block_q)
    vb = _split_blocks(v, block_kv)
    tm, tn = n // block_q, n // block_kv

    dq = jnp.zeros((tm, block_q, d), jnp.float32)
    dk = jnp.zeros((tn, block_kv, d), jnp.float32)
    dv = jnp.zeros((tn, block_kv, d), jnp.float32)

    # Also materialize the big intermediates for the error analysis.
    p_full = jnp.zeros((n, n), jnp.float32)
    dp_full = jnp.zeros((n, n), jnp.float32)
    ds_full = jnp.zeros((n, n), jnp.float32)
    s_full = jnp.zeros((n, n), jnp.float32)

    for j in range(tn):
        for i in range(tm):
            if causal and (j * block_kv > (i + 1) * block_q - 1):
                continue
            s_ij = quant.int8_matmul(q_q[i], q_s[i], k_q[j].T, k_s[j]) * inv_sqrt_d
            s_ij = s_ij + bias_row[:, j * block_kv : (j + 1) * block_kv] * inv_sqrt_d
            if causal:
                qi = jnp.arange(i * block_q, (i + 1) * block_q)[:, None]
                kj = jnp.arange(j * block_kv, (j + 1) * block_kv)[None, :]
                s_ij = jnp.where(qi >= kj, s_ij, -jnp.inf)
            p_ij = jnp.exp(s_ij - lse[i * block_q : (i + 1) * block_q, None])
            # Alg 2 line 6: per-block INT8 re-quantization of P and dO.
            p_q, p_s = quant.quantize_per_block(p_ij)
            do_q, do_s = quant.quantize_per_block(dob[i])
            dv_ij = quant.int8_matmul(p_q.T, p_s, do_q, do_s)
            dv = dv.at[j].add(dv_ij)
            # Alg 2 line 8: dP = dO V^T in full precision.
            dp_ij = dob[i] @ vb[j].T
            ds_ij = p_ij * (dp_ij - delta[i * block_q : (i + 1) * block_q, None])
            # Alg 2 line 9: per-block INT8 quantization of dS (or the
            # §7 future-work FP dS path when quant_ds=False).
            if quant_ds:
                ds_q, ds_s = quant.quantize_per_block(ds_ij)
                dq_ij = quant.int8_matmul(ds_q, ds_s, k_q[j].astype(jnp.int8), k_s[j]) * inv_sqrt_d
                dk_ij = quant.int8_matmul(ds_q.T, ds_s, q_q[i], q_s[i]) * inv_sqrt_d
            else:
                dq_ij = (ds_ij @ quant.dequantize(k_q[j], k_s[j])) * inv_sqrt_d
                dk_ij = (ds_ij.T @ quant.dequantize(q_q[i], q_s[i])) * inv_sqrt_d
            dq = dq.at[i].add(dq_ij)
            dk = dk.at[j].add(dk_ij)

            sl_i = slice(i * block_q, (i + 1) * block_q)
            sl_j = slice(j * block_kv, (j + 1) * block_kv)
            s_full = s_full.at[sl_i, sl_j].set(s_ij)
            p_full = p_full.at[sl_i, sl_j].set(p_ij)
            dp_full = dp_full.at[sl_i, sl_j].set(dp_ij)
            ds_full = ds_full.at[sl_i, sl_j].set(ds_ij)

    dq = dq.reshape(n, d)
    dk = dk.reshape(n, d)
    dv = dv.reshape(n, d)
    if q_smoothing and mu_q is not None:
        # §6: dK = dS^T Q = dS^T Q_sm + (dS^T 1) μ_Q^T — the centered branch
        # was computed against quantized Q_sm, add the bias branch back.
        dk = dk + smoothing.dk_bias_branch(ds_full, mu_q) * inv_sqrt_d
    return AttnIntermediates(o, s_full, p_full, lse, delta, dp_full, ds_full,
                             dq, dk, dv)


# ---------------------------------------------------------------------------
# §5.4 pseudo-quantized FPA trace (Table 2, Figures 5/6)
# ---------------------------------------------------------------------------


def pseudo_quant_trace(q, k, v, do, causal: bool = False,
                       k_smoothing: bool = True,
                       q_smoothing: bool = False,
                       quant_ds: bool = True) -> AttnIntermediates:
    """Apply SageBwd's INT8 quantize-dequantize before each quantized MM in
    a plain attention implementation (paper §5.4).

    dP is exact because the upstream dO is treated as error-free and the
    dO·V^T product stays in high precision — reproducing Table 2's
    ``Rel-L2(dP) = 0.0000`` row.
    """
    d = q.shape[-1]
    inv_sqrt_d = 1.0 / jnp.sqrt(jnp.float32(d))

    if k_smoothing:
        k_in, _ = smoothing.k_smooth(k)
    else:
        k_in = k
    if q_smoothing:
        q_in, mu_q = smoothing.q_smooth(q)
        bias = smoothing.qk_logits_bias(mu_q, k_in)
    else:
        q_in, mu_q, bias = q, None, 0.0

    q_fq = quant.fake_quant(q_in, "block")
    k_fq = quant.fake_quant(k_in, "block")
    v_fq = quant.fake_quant(v, "block")

    s = (q_fq @ k_fq.T + bias) * inv_sqrt_d
    if causal:
        s = jnp.where(_causal_mask(q.shape[0]), s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    z = jnp.sum(e, axis=-1, keepdims=True)
    p = e / z
    lse = (m + jnp.log(z)).squeeze(-1)

    p_fq = quant.fake_quant(p, "token")
    o = p_fq @ v_fq

    # Backward (§5.4: quant-dequant before each SageBwd-quantized MM).
    p_fq_blk = quant.fake_quant(p, "block")
    do_fq = quant.fake_quant(do, "block")
    dv = p_fq_blk.T @ do_fq
    dp = do @ v.T                       # FP16 path — exact here
    delta = jnp.sum(do * o, axis=-1)
    ds = p * (dp - delta[:, None])
    ds_fq = quant.fake_quant(ds, "block") if quant_ds else ds
    dq = (ds_fq @ k_fq) * inv_sqrt_d
    dk_center = (ds_fq.T @ q_fq) * inv_sqrt_d
    if q_smoothing and mu_q is not None:
        dk = dk_center + smoothing.dk_bias_branch(ds, mu_q) * inv_sqrt_d
    else:
        dk = dk_center
    return AttnIntermediates(o, s, p, lse, delta, dp, ds, dq, dk, dv)
