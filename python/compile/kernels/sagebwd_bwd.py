"""SageBwd backward pass (paper Algorithm 2) as Pallas kernels.

Two kernels instead of Triton's single atomics-based sweep (TPU Pallas has
no cheap global atomics — DESIGN.md §7):

  * ``_dkdv_kernel`` — grid over KV blocks j, inner loop over Q blocks i
    (exactly Alg 2's loop nest).  Computes dK_j, dV_j, and the per-column
    sums of dS needed for the Q-smoothing dK bias branch (§6).
  * ``_dq_kernel`` — grid over Q blocks i, inner loop over KV blocks j.
    Computes dQ_i.

Both recompute S_ij from the *quantized* Q/K tiles (Alg 2 line 5 — the
same deterministic per-block ψ as the forward, so P matches the forward
bit-for-bit) and P_ij = exp(S_ij − L_i).

Quantization layout per Alg 2:
  line 7   dV += MM(P̂^T, d̂O) · s_P · s_dO       INT8 per-block
  line 8   dP  = MM(dO, V^T)                     kept in full precision
  line 9   dS  = P ∘ (dP − D_i);  ψ(dS)          INT8 per-block
  line 10  dQ += MM(d̂S, K̂) · s_dS · s_K         INT8
  line 11  dK += MM(d̂S^T, Q̂) · s_dS · s_Q       INT8
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import smoothing
from .sagebwd_fwd import _quant_tile, NEG_INF


def _recompute_p(q_q, q_s, k_q, k_s, bias, lse_tile, row0, col0,
                 block_q, block_kv, causal, sm_scale):
    """Alg 2 line 5: S from quantized tiles, P = exp(S − L)."""
    s_ij = jnp.dot(q_q.astype(jnp.int32), k_q.astype(jnp.int32).T,
                   preferred_element_type=jnp.int32).astype(jnp.float32)
    s_ij = s_ij * (q_s * k_s) * sm_scale + bias * sm_scale
    if causal:
        row_ids = row0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        col_ids = col0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        s_ij = jnp.where(row_ids >= col_ids, s_ij, NEG_INF)
    return jnp.exp(s_ij - lse_tile[:, None])


def _dkdv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, bias_ref,
                 dk_ref, dv_ref, dscol_ref, *,
                 block_q: int, block_kv: int, n: int, causal: bool,
                 sm_scale: float, quant_ds: bool = True):
    j = pl.program_id(0)
    d = q_ref.shape[-1]
    k_tile = k_ref[...].astype(jnp.float32)          # (block_kv, d)
    v_tile = v_ref[...].astype(jnp.float32)
    k_q, k_s = _quant_tile(k_tile)
    num_q = n // block_q

    _refs = dict(q=q_ref, do=do_ref, lse=lse_ref, delta=delta_ref, bias=bias_ref)

    def body(i, carry):
        dk_acc, dv_acc, dscol_acc = carry
        q_tile = pl.load(_refs["q"], (pl.dslice(i * block_q, block_q), slice(None))).astype(jnp.float32)
        do_tile = pl.load(_refs["do"], (pl.dslice(i * block_q, block_q), slice(None))).astype(jnp.float32)
        lse_tile = pl.load(_refs["lse"], (pl.dslice(i * block_q, block_q),))
        delta_tile = pl.load(_refs["delta"], (pl.dslice(i * block_q, block_q),))
        bias = pl.load(_refs["bias"], (slice(0, 1), pl.dslice(j * block_kv, block_kv)))

        q_q, q_s = _quant_tile(q_tile)
        p_ij = _recompute_p(q_q, q_s, k_q, k_s, bias, lse_tile,
                            i * block_q, j * block_kv,
                            block_q, block_kv, causal, sm_scale)

        # line 6+7: per-block INT8 of P and dO, dV accumulation.
        p_q, p_s = _quant_tile(p_ij)
        do_q, do_s = _quant_tile(do_tile)
        dv_ij = jnp.dot(p_q.astype(jnp.int32).T, do_q.astype(jnp.int32),
                        preferred_element_type=jnp.int32).astype(jnp.float32)
        dv_acc = dv_acc + dv_ij * (p_s * do_s)

        # line 8: dP in full precision.
        dp_ij = jnp.dot(do_tile, v_tile.T)
        ds_ij = p_ij * (dp_ij - delta_tile[:, None])

        # line 9+11: ψ(dS), dK accumulation.  When quant_ds=False (the
        # paper's §7 "mitigate dS-path quantization error" future-work
        # direction) dS stays FP and only Q̂ is dequantized — trading one
        # INT8 MM for accuracy exactly where Table 2 shows the bottleneck.
        if quant_ds:
            ds_q, ds_s = _quant_tile(ds_ij)
            dk_ij = jnp.dot(ds_q.astype(jnp.int32).T, q_q.astype(jnp.int32),
                            preferred_element_type=jnp.int32).astype(jnp.float32)
            dk_acc = dk_acc + dk_ij * (ds_s * q_s) * sm_scale
        else:
            dk_ij = jnp.dot(ds_ij.T, q_q.astype(jnp.float32) * q_s)
            dk_acc = dk_acc + dk_ij * sm_scale
        # §6 Q-smoothing bias branch needs colsum(dS) — cheap to carry.
        dscol_acc = dscol_acc + jnp.sum(ds_ij, axis=0)
        return dk_acc, dv_acc, dscol_acc

    init = (jnp.zeros((block_kv, d), jnp.float32),
            jnp.zeros((block_kv, d), jnp.float32),
            jnp.zeros((block_kv,), jnp.float32))
    if causal:
        lo = (j * block_kv) // block_q  # Q blocks strictly above the tile are masked out
    else:
        lo = 0
    dk_acc, dv_acc, dscol_acc = jax.lax.fori_loop(lo, num_q, body, init)
    dk_ref[...] = dk_acc
    dv_ref[...] = dv_acc
    dscol_ref[...] = dscol_acc


def _dq_kernel(q_ref, k_ref, do_ref, v_ref, lse_ref, delta_ref, bias_ref,
               dq_ref, *,
               block_q: int, block_kv: int, n: int, causal: bool,
               sm_scale: float, quant_ds: bool = True):
    i = pl.program_id(0)
    d = q_ref.shape[-1]
    q_tile = q_ref[...].astype(jnp.float32)
    do_tile = do_ref[...].astype(jnp.float32)
    lse_tile = lse_ref[...]
    delta_tile = delta_ref[...]
    q_q, q_s = _quant_tile(q_tile)
    num_kv = n // block_kv

    def body(j, dq_acc):
        k_tile = pl.load(k_ref, (pl.dslice(j * block_kv, block_kv), slice(None))).astype(jnp.float32)
        v_tile = pl.load(v_ref, (pl.dslice(j * block_kv, block_kv), slice(None))).astype(jnp.float32)
        bias = pl.load(bias_ref, (slice(0, 1), pl.dslice(j * block_kv, block_kv)))
        k_q, k_s = _quant_tile(k_tile)
        p_ij = _recompute_p(q_q, q_s, k_q, k_s, bias, lse_tile,
                            i * block_q, j * block_kv,
                            block_q, block_kv, causal, sm_scale)
        dp_ij = jnp.dot(do_tile, v_tile.T)
        ds_ij = p_ij * (dp_ij - delta_tile[:, None])
        if quant_ds:
            ds_q, ds_s = _quant_tile(ds_ij)
            dq_ij = jnp.dot(ds_q.astype(jnp.int32), k_q.astype(jnp.int32),
                            preferred_element_type=jnp.int32).astype(jnp.float32)
            return dq_acc + dq_ij * (ds_s * k_s) * sm_scale
        dq_ij = jnp.dot(ds_ij, k_q.astype(jnp.float32) * k_s)
        return dq_acc + dq_ij * sm_scale

    if causal:
        hi = jnp.minimum(((i + 1) * block_q + block_kv - 1) // block_kv, num_kv)
    else:
        hi = num_kv
    dq = jax.lax.fori_loop(0, hi, body, jnp.zeros((block_q, d), jnp.float32))
    dq_ref[...] = dq


@functools.partial(jax.jit, static_argnames=(
    "block_q", "block_kv", "causal", "k_smoothing", "q_smoothing",
    "quant_ds"))
def sage_bwd(q, k, v, do, o, lse, block_q: int = 64, block_kv: int = 64,
             causal: bool = False, k_smoothing: bool = True,
             q_smoothing: bool = False, quant_ds: bool = True):
    """SageBwd backward on (N, D) single-head tensors → (dQ, dK, dV).

    ``o``/``lse`` are the forward outputs (Alg 2 takes them as inputs; the
    quantized tiles are recomputed deterministically rather than stored).

    ``quant_ds=False`` implements the paper's §7 future-work direction:
    keep the dS-path matmuls (dQ = dS·K̂, dK = dSᵀ·Q̂) in floating point,
    quantizing only 4 of 7 MMs — removing the Table-2 bottleneck at the
    cost of 2 of the 6 INT8 accelerated products.
    """
    n, d = q.shape
    sm_scale = 1.0 / math.sqrt(d)

    if k_smoothing:
        k_in, _ = smoothing.k_smooth(k)
    else:
        k_in = k
    if q_smoothing:
        q_in, mu_q = smoothing.q_smooth(q)
        bias_row = (mu_q @ k_in.T).reshape(1, n).astype(jnp.float32)
    else:
        q_in, mu_q = q, None
        bias_row = jnp.zeros((1, n), jnp.float32)

    delta = jnp.sum(do * o, axis=-1)  # Alg 2 line 2

    grid_kv = (n // block_kv,)
    dkdv = functools.partial(_dkdv_kernel, block_q=block_q,
                             block_kv=block_kv, n=n, causal=causal,
                             sm_scale=sm_scale, quant_ds=quant_ds)
    dk, dv, dscol = pl.pallas_call(
        dkdv,
        grid=grid_kv,
        in_specs=[
            pl.BlockSpec((n, d), lambda j: (0, 0)),        # q (full)
            pl.BlockSpec((block_kv, d), lambda j: (j, 0)),  # k tile
            pl.BlockSpec((block_kv, d), lambda j: (j, 0)),  # v tile
            pl.BlockSpec((n, d), lambda j: (0, 0)),        # do (full)
            pl.BlockSpec((n,), lambda j: (0,)),            # lse
            pl.BlockSpec((n,), lambda j: (0,)),            # delta
            pl.BlockSpec((1, n), lambda j: (0, 0)),        # bias row
        ],
        out_specs=[
            pl.BlockSpec((block_kv, d), lambda j: (j, 0)),
            pl.BlockSpec((block_kv, d), lambda j: (j, 0)),
            pl.BlockSpec((block_kv,), lambda j: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(q_in, k_in, v, do, lse, delta, bias_row)

    grid_q = (n // block_q,)
    dqk = functools.partial(_dq_kernel, block_q=block_q, block_kv=block_kv,
                            n=n, causal=causal, sm_scale=sm_scale,
                            quant_ds=quant_ds)
    dq = pl.pallas_call(
        dqk,
        grid=grid_q,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),   # q tile
            pl.BlockSpec((n, d), lambda i: (0, 0)),         # k (full)
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),   # do tile
            pl.BlockSpec((n, d), lambda i: (0, 0)),         # v (full)
            pl.BlockSpec((block_q,), lambda i: (i,)),       # lse tile
            pl.BlockSpec((block_q,), lambda i: (i,)),       # delta tile
            pl.BlockSpec((1, n), lambda i: (0, 0)),         # bias row
        ],
        out_specs=pl.BlockSpec((block_q, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=True,
    )(q_in, k_in, do, v, lse, delta, bias_row)

    if q_smoothing and mu_q is not None:
        # §6: dK = dK_center + (dS^T 1) μ_Q^T — centered branch came from
        # quantized Q_sm inside the kernel, bias branch restored here.
        dk = dk + dscol[:, None] * mu_q.reshape(1, d) * sm_scale
    return dq, dk, dv
