"""SageBwd forward pass (paper Algorithm 1) as a Pallas kernel.

Grid: one program per query block Q_i; the KV loop (Alg 1 line 6) is a
``fori_loop`` inside the kernel.  Per iteration:

  line 7   S_ij = MM(Q̂_i, K̂_j) · s_Q · s_K          (INT8×INT8→INT32 dot)
  line 8   online softmax update (m, l)
  line 9   per-token quantization of P̃_ij
  line 10  O accumulation via MM(P̂_ij, V̂_j) · s_P · s_V (INT8 dot)

K-smoothing happens at kernel *entry* (the §6 observation that no backward
correction is needed); Q-smoothing adds the rank-1 logit bias row.

TPU mapping (DESIGN.md §7): the Triton threadblock tile becomes the Pallas
grid + BlockSpec; INT8 IMMA becomes an int8×int8→int32 ``jnp.dot`` (MXU
8-bit path on real TPUs, exact integer math under interpret=True).  VMEM
footprint per program: (B_q·D)·4 + 2·(N·D)·4 + (B_q·B_kv)·~8 bytes — K/V are
staged whole because N here is ≤ a few K tokens; a production TPU kernel
would stream K_j/V_j tiles with a 2-D grid.  interpret=True is mandatory on
CPU (Mosaic custom-calls cannot run on the CPU PJRT plugin).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import smoothing

INT8_MAX = 127.0
EPS_SCALE = 1e-12
NEG_INF = -1e30  # finite -inf stand-in: keeps exp() exact zero without nan paths


def _quant_tile(x):
    """Per-block ψ on a tile already resident in VMEM (Alg 1 line 3)."""
    s = jnp.maximum(jnp.max(jnp.abs(x)), EPS_SCALE) / INT8_MAX
    q = jnp.clip(jnp.round(x / s), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, s


def _quant_rows(x):
    """Per-token ψ for P̃ (Alg 1 line 9)."""
    s = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), EPS_SCALE) / INT8_MAX
    q = jnp.clip(jnp.round(x / s), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, s


def _fwd_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, lse_ref, *,
                block_q: int, block_kv: int, n: int, causal: bool,
                sm_scale: float):
    i = pl.program_id(0)
    d = q_ref.shape[-1]
    q_tile = q_ref[...].astype(jnp.float32)          # (block_q, d)
    q_q, q_s = _quant_tile(q_tile)

    num_kv = n // block_kv
    row_ids = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)

    def body(j, carry):
        acc, m_i, l_i = carry
        k_tile = pl.load(k_ref, (pl.dslice(j * block_kv, block_kv), slice(None)))
        v_tile = pl.load(v_ref, (pl.dslice(j * block_kv, block_kv), slice(None)))
        k_q, k_s = _quant_tile(k_tile.astype(jnp.float32))
        v_q, v_s = _quant_tile(v_tile.astype(jnp.float32))

        s_ij = jnp.dot(q_q.astype(jnp.int32), k_q.astype(jnp.int32).T,
                       preferred_element_type=jnp.int32).astype(jnp.float32)
        s_ij = s_ij * (q_s * k_s) * sm_scale
        bias = pl.load(bias_ref, (slice(0, 1), pl.dslice(j * block_kv, block_kv)))
        s_ij = s_ij + bias * sm_scale
        if causal:
            col_ids = j * block_kv + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 1)
            s_ij = jnp.where(row_ids >= col_ids, s_ij, NEG_INF)

        m_new = jnp.maximum(m_i, jnp.max(s_ij, axis=-1))
        p_ij = jnp.exp(s_ij - m_new[:, None])
        corr = jnp.exp(m_i - m_new)
        l_new = l_i * corr + jnp.sum(p_ij, axis=-1)

        p_q, p_s = _quant_rows(p_ij)
        pv = jnp.dot(p_q.astype(jnp.int32), v_q.astype(jnp.int32),
                     preferred_element_type=jnp.int32).astype(jnp.float32)
        pv = pv * p_s * v_s
        acc = acc * corr[:, None] + pv
        return acc, m_new, l_new

    init = (jnp.zeros((block_q, d), jnp.float32),
            jnp.full((block_q,), -jnp.inf, jnp.float32),
            jnp.zeros((block_q,), jnp.float32))
    if causal:
        # Only KV blocks that intersect the causal triangle contribute.
        hi = jnp.minimum(((i + 1) * block_q + block_kv - 1) // block_kv, num_kv)
    else:
        hi = num_kv
    acc, m_i, l_i = jax.lax.fori_loop(0, hi, body, init)

    o_ref[...] = (acc / l_i[:, None]).astype(o_ref.dtype)
    lse_ref[...] = (m_i + jnp.log(l_i)).astype(lse_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "block_q", "block_kv", "causal", "k_smoothing", "q_smoothing"))
def sage_fwd(q, k, v, block_q: int = 64, block_kv: int = 64,
             causal: bool = False, k_smoothing: bool = True,
             q_smoothing: bool = False):
    """SageBwd forward on (N, D) single-head tensors.

    Returns ``(o, lse)``; lse is the FlashAttention log-sum-exp residual the
    backward pass uses to recompute P (Alg 2 line 5).
    """
    n, d = q.shape
    assert n % block_q == 0 and n % block_kv == 0
    sm_scale = 1.0 / math.sqrt(d)

    if k_smoothing:
        k_in, _ = smoothing.k_smooth(k)
    else:
        k_in = k
    if q_smoothing:
        q_in, mu_q = smoothing.q_smooth(q)
        bias_row = (mu_q @ k_in.T).reshape(1, n).astype(jnp.float32)
    else:
        q_in = q
        bias_row = jnp.zeros((1, n), jnp.float32)

    grid = (n // block_q,)
    kernel = functools.partial(_fwd_kernel, block_q=block_q,
                               block_kv=block_kv, n=n, causal=causal,
                               sm_scale=sm_scale)
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((block_q,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(q_in, k_in, v, bias_row)
    return o, lse
