"""Q/K-smoothing (paper §3 "Q and K Smoothing", §6 ablation).

K-smoothing subtracts the token-wise (row) mean of K before quantization:

    K_sm = K − 1·mean_row(K)

Softmax row-invariance makes the forward exactly equivalent (every logit in
a row shifts by the same Q_i·μ_K^T), and §6 shows the backward needs *no*
correction because every row of dS sums to zero:

    dQ = dS·K = dS·(K − 1 μ_K^T) = dS·K_sm.

Q-smoothing subtracts a mean from Q; forward equivalence needs the rank-1
bias term μ_Q·K^T added back to the logits, and the dK gradient needs the
bias branch  dK_bias = (dS^T 1)·μ_Q^T  (paper §6).
"""

from __future__ import annotations

import jax.numpy as jnp


def k_smooth(k: jnp.ndarray):
    """Return ``(K_sm, μ_K)`` with μ_K the mean over the token axis (−2)."""
    mu = jnp.mean(k, axis=-2, keepdims=True)
    return k - mu, mu


def q_smooth(q: jnp.ndarray):
    """Return ``(Q_sm, μ_Q)`` with μ_Q the mean over the token axis (−2).

    The paper's per-block Q-smoothing uses a block-wise mean; SageBwd's
    pre-training ablation (§6) operates at kernel entry on the full tensor,
    which is what we implement (block means are recovered inside the kernel
    tiles because the quantizer is per-block anyway).
    """
    mu = jnp.mean(q, axis=-2, keepdims=True)
    return q - mu, mu


def qk_logits_bias(mu_q: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """Rank-1 logits correction  1·(μ_Q K^T)  restoring S after Q-smoothing.

    Shapes: mu_q (…,1,d), k (…,n,d) → (…,1,n), broadcast over the query
    axis by the caller.
    """
    return jnp.einsum("...od,...nd->...on", mu_q, k)


def dk_bias_branch(ds: jnp.ndarray, mu_q: jnp.ndarray) -> jnp.ndarray:
    """dK_bias = (dS^T 1)·μ_Q^T  — the §6 gradient correction for Q-smoothing.

    Shapes: ds (…,m,n), mu_q (…,1,d) → (…,n,d).
    """
    colsum = jnp.sum(ds, axis=-2, keepdims=True)  # (…,1,n)
    return jnp.einsum("...on,...od->...nd", colsum, mu_q)
