"""Generate golden vectors for the native Rust kernels.

Runs the pure-jnp references in ``kernels/ref.py`` (the same oracles the
Pallas kernels are tested against) on a small fixed input set and writes
``rust/tests/data/golden_attention.json``, which
``rust/tests/kernel_golden.rs`` checks the native backend against.

Also generates ``rust/tests/data/golden_gemm.json`` for the cache-blocked
compute engine (``rust/src/tensor/linalg.rs``): float32 GEMM results in
the engine's documented accumulation order (per output element, products
added in ascending reduction index from 0.0) plus an exact i8×i8→i32
case.  Before emitting, a numpy twin of the blocked ``ikj``/MR kernel is
checked **bitwise** against the naive per-element order across odd shapes
— the same determinism contract the Rust property tests assert.  This
half needs only numpy; run it standalone with ``--gemm-only`` when the
jax toolchain is absent.

Float round-tripping: every value is first cast to float32, then emitted
via Python ``repr`` of the exact float64 promotion — Rust parses the f64
and casts back to f32, recovering the bit pattern exactly.

Usage:  cd python && python -m compile.make_golden [--gemm-only]
"""

from __future__ import annotations

import json
import os
import sys

import numpy as np

N, D, BLOCK = 32, 8, 8
SIGMA_QK, SIGMA_V, SIGMA_DO = 3.0, 1.0, 0.5

DATA_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "rust", "tests", "data"
)

# Register-block height of the Rust engine's gemm_nn micro-kernel
# (rust/src/tensor/linalg.rs MR) — mirrored here so the numpy twin blocks
# identically.
GEMM_MR = 4


def _f32_list(x) -> list:
    return [float(v) for v in np.asarray(x, dtype=np.float32).reshape(-1)]


def _outputs(it: ref.AttnIntermediates, with_intermediates: bool) -> dict:
    out = {
        "o": _f32_list(it.o),
        "dq": _f32_list(it.dq),
        "dk": _f32_list(it.dk),
        "dv": _f32_list(it.dv),
        "delta": _f32_list(it.delta),
    }
    if with_intermediates:
        out["p"] = _f32_list(it.p)
        out["dp"] = _f32_list(it.dp)
        out["ds"] = _f32_list(it.ds)
    return out


def _gemm_naive_f32(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """`A·B` accumulated exactly like the Rust naive reference: per output
    element, products added in ascending `t` order starting from 0.0, every
    intermediate rounded to float32."""
    m, k = a.shape
    _, n = b.shape
    out = np.zeros((m, n), dtype=np.float32)
    for i in range(m):
        for t in range(k):
            out[i] += a[i, t] * b[t]  # f32 mul then f32 add, per lane
    return out


def _gemm_blocked_f32(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Numpy twin of linalg.rs `gemm_nn`: MR-row register block, `ikj`
    order.  Must be bitwise-equal to `_gemm_naive_f32` — blocking reorders
    *across* output elements only, never within one element's sum."""
    m, k = a.shape
    _, n = b.shape
    out = np.zeros((m, n), dtype=np.float32)
    i = 0
    while i < m:
        mr = min(GEMM_MR, m - i)
        for t in range(k):
            brow = b[t]
            for r in range(mr):
                out[i + r] += a[i + r, t] * brow
        i += mr
    return out


def check_blocked_gemm() -> None:
    """Assert the blocked twin is bitwise-identical to the naive order
    across odd/edge shapes (the linalg.rs determinism contract)."""
    rng = np.random.RandomState(7)
    for m, k, n in [(1, 1, 1), (5, 3, 7), (17, 13, 9), (33, 7, 5), (64, 32, 48)]:
        a = (rng.standard_normal((m, k)) * 3).astype(np.float32)
        b = (rng.standard_normal((k, n)) * 3).astype(np.float32)
        naive = _gemm_naive_f32(a, b)
        blocked = _gemm_blocked_f32(a, b)
        assert np.array_equal(
            naive.view(np.uint32), blocked.view(np.uint32)
        ), f"blocked GEMM not bitwise-equal to naive at ({m},{k},{n})"
    print("blocked-GEMM check: bitwise-equal to naive across all shapes")


def write_gemm_golden() -> None:
    check_blocked_gemm()
    rng = np.random.RandomState(20260730)
    cases = []
    for m, k, n in [(5, 3, 7), (16, 8, 16), (17, 13, 9)]:
        a = (rng.standard_normal((m, k)) * 2).astype(np.float32)
        b = (rng.standard_normal((k, n)) * 2).astype(np.float32)
        c = _gemm_naive_f32(a, b)
        cases.append({
            "m": m, "k": k, "n": n,
            "a": _f32_list(a), "b": _f32_list(b), "c": _f32_list(c),
        })
    # Exact integer case: i8 operands, i32 accumulation (order-free).
    m, k, n = 6, 5, 9
    ai = ((np.arange(m * k) * 37) % 255 - 127).astype(np.int64).reshape(m, k)
    bi = ((np.arange(k * n) * 91) % 255 - 127).astype(np.int64).reshape(k, n)
    ci = ai @ bi
    int8_case = {
        "m": m, "k": k, "n": n,
        "a": [int(v) for v in ai.reshape(-1)],
        "b": [int(v) for v in bi.reshape(-1)],
        "c": [int(v) for v in ci.reshape(-1)],
    }
    doc = {"mr": GEMM_MR, "f32_cases": cases, "int8_case": int8_case}
    out_path = os.path.join(DATA_DIR, "golden_gemm.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(doc, f)
    print(f"wrote {os.path.normpath(out_path)} "
          f"({os.path.getsize(out_path) / 1024:.0f} KiB, "
          f"{len(cases)} f32 cases + 1 int8 case)")


def main() -> None:
    write_gemm_golden()
    if "--gemm-only" in sys.argv[1:]:
        return
    from .kernels import ref

    rng = np.random.RandomState(20260729)
    q = (rng.standard_normal((N, D)) * SIGMA_QK).astype(np.float32)
    k = (rng.standard_normal((N, D)) * SIGMA_QK).astype(np.float32)
    v = (rng.standard_normal((N, D)) * SIGMA_V).astype(np.float32)
    do = (rng.standard_normal((N, D)) * SIGMA_DO).astype(np.float32)

    cases = []

    it = ref.fpa_bwd(q, k, v, do)
    cases.append({"name": "fpa", "outputs": _outputs(it, True)})

    for name, kwargs in [
        ("sage", dict()),
        ("sage_nosm", dict(k_smoothing=False)),
        ("sage_qksm", dict(q_smoothing=True)),
        ("sage_dsfp", dict(quant_ds=False)),
    ]:
        it = ref.sage_ref_bwd(q, k, v, do, block_q=BLOCK, block_kv=BLOCK, **kwargs)
        cases.append({"name": name, "outputs": _outputs(it, False)})

    for name, kwargs in [
        ("pseudo", dict()),
        ("pseudo_nosm", dict(k_smoothing=False)),
        ("pseudo_qksm", dict(q_smoothing=True)),
        ("pseudo_dsfp", dict(quant_ds=False)),
    ]:
        it = ref.pseudo_quant_trace(q, k, v, do, **kwargs)
        cases.append({"name": name, "outputs": _outputs(it, name == "pseudo")})

    doc = {
        "n": N,
        "d": D,
        "block": BLOCK,
        "sigma": {"qk": SIGMA_QK, "v": SIGMA_V, "do": SIGMA_DO},
        "inputs": {
            "q": _f32_list(q),
            "k": _f32_list(k),
            "v": _f32_list(v),
            "do": _f32_list(do),
        },
        "cases": cases,
    }
    out_path = os.path.join(
        os.path.dirname(__file__), "..", "..", "rust", "tests", "data",
        "golden_attention.json",
    )
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(doc, f)
    print(f"wrote {os.path.normpath(out_path)} "
          f"({os.path.getsize(out_path) / 1024:.0f} KiB, {len(cases)} cases)")


if __name__ == "__main__":
    main()
