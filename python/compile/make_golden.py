"""Generate golden vectors for the native Rust kernels.

Runs the pure-jnp references in ``kernels/ref.py`` (the same oracles the
Pallas kernels are tested against) on a small fixed input set and writes
``rust/tests/data/golden_attention.json``, which
``rust/tests/kernel_golden.rs`` checks the native backend against.

Float round-tripping: every value is first cast to float32, then emitted
via Python ``repr`` of the exact float64 promotion — Rust parses the f64
and casts back to f32, recovering the bit pattern exactly.

Usage:  cd python && python -m compile.make_golden
"""

from __future__ import annotations

import json
import os

import numpy as np

from .kernels import ref

N, D, BLOCK = 32, 8, 8
SIGMA_QK, SIGMA_V, SIGMA_DO = 3.0, 1.0, 0.5


def _f32_list(x) -> list:
    return [float(v) for v in np.asarray(x, dtype=np.float32).reshape(-1)]


def _outputs(it: ref.AttnIntermediates, with_intermediates: bool) -> dict:
    out = {
        "o": _f32_list(it.o),
        "dq": _f32_list(it.dq),
        "dk": _f32_list(it.dk),
        "dv": _f32_list(it.dv),
        "delta": _f32_list(it.delta),
    }
    if with_intermediates:
        out["p"] = _f32_list(it.p)
        out["dp"] = _f32_list(it.dp)
        out["ds"] = _f32_list(it.ds)
    return out


def main() -> None:
    rng = np.random.RandomState(20260729)
    q = (rng.standard_normal((N, D)) * SIGMA_QK).astype(np.float32)
    k = (rng.standard_normal((N, D)) * SIGMA_QK).astype(np.float32)
    v = (rng.standard_normal((N, D)) * SIGMA_V).astype(np.float32)
    do = (rng.standard_normal((N, D)) * SIGMA_DO).astype(np.float32)

    cases = []

    it = ref.fpa_bwd(q, k, v, do)
    cases.append({"name": "fpa", "outputs": _outputs(it, True)})

    for name, kwargs in [
        ("sage", dict()),
        ("sage_nosm", dict(k_smoothing=False)),
        ("sage_qksm", dict(q_smoothing=True)),
        ("sage_dsfp", dict(quant_ds=False)),
    ]:
        it = ref.sage_ref_bwd(q, k, v, do, block_q=BLOCK, block_kv=BLOCK, **kwargs)
        cases.append({"name": name, "outputs": _outputs(it, False)})

    for name, kwargs in [
        ("pseudo", dict()),
        ("pseudo_nosm", dict(k_smoothing=False)),
        ("pseudo_qksm", dict(q_smoothing=True)),
        ("pseudo_dsfp", dict(quant_ds=False)),
    ]:
        it = ref.pseudo_quant_trace(q, k, v, do, **kwargs)
        cases.append({"name": name, "outputs": _outputs(it, name == "pseudo")})

    doc = {
        "n": N,
        "d": D,
        "block": BLOCK,
        "sigma": {"qk": SIGMA_QK, "v": SIGMA_V, "do": SIGMA_DO},
        "inputs": {
            "q": _f32_list(q),
            "k": _f32_list(k),
            "v": _f32_list(v),
            "do": _f32_list(do),
        },
        "cases": cases,
    }
    out_path = os.path.join(
        os.path.dirname(__file__), "..", "..", "rust", "tests", "data",
        "golden_attention.json",
    )
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(doc, f)
    print(f"wrote {os.path.normpath(out_path)} "
          f"({os.path.getsize(out_path) / 1024:.0f} KiB, {len(cases)} cases)")


if __name__ == "__main__":
    main()
