"""Error metrics used throughout the paper (Tables 1–2, Figures 5–6).

CosSim  = <x, y> / (‖x‖‖y‖)            over flattened tensors
Rel-ℓ2  = ‖x − y‖₂ / ‖y‖₂              (y = full-precision reference)
RMS     = sqrt(mean(x²))               (§4.2's magnitude probe)
"""

from __future__ import annotations

import jax.numpy as jnp

_EPS = 1e-20


def cossim(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    xf, yf = x.reshape(-1), y.reshape(-1)
    return jnp.dot(xf, yf) / jnp.maximum(
        jnp.linalg.norm(xf) * jnp.linalg.norm(yf), _EPS)


def rel_l2(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return jnp.linalg.norm((x - y).reshape(-1)) / jnp.maximum(
        jnp.linalg.norm(y.reshape(-1)), _EPS)


def rms(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.sqrt(jnp.mean(jnp.square(x)))
