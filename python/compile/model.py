"""L2 — Llama-style decoder-only transformer with pluggable attention.

Architecture follows the paper's §5.1 setup (Llama 3 family, GPT2-style BPE
vocabulary, RMSNorm ε=1e-6, cosine LR) scaled to this substrate:

  embed → [RMSNorm → MHA(RoPE, optional QK-norm, sage|fpa) → residual
           → RMSNorm → SwiGLU → residual] × L → RMSNorm → tied LM head

Attention routes through either

  * ``kernels.attention.sage_attention`` — the SageBwd custom_vjp whose
    backward is the INT8 Pallas kernel (Algorithm 2), or
  * ``kernels.attention.fpa_attention``  — exact attention, jnp autodiff
    (the paper's FPA baseline).

Parameters live in a *flat dict* keyed by dotted names; the AOT manifest
serializes ``param_names(cfg)`` order so the Rust coordinator can address
leaves positionally.  Everything here is build-time only — the functions
are lowered to HLO text by ``aot.py`` and never imported at run time.
"""

from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import attention as attn_mod

Params = Dict[str, jnp.ndarray]

# AdamW hyperparameters (paper §5.1 uses lr=3e-5 with cosine schedule; the
# schedule itself lives in the Rust coordinator and arrives as an input).
ADAM_B1 = 0.9
ADAM_B2 = 0.95
ADAM_EPS = 1e-8
WEIGHT_DECAY = 0.1


# ---------------------------------------------------------------------------
# Parameter schema
# ---------------------------------------------------------------------------


def param_shapes(cfg: ModelConfig) -> Dict[str, tuple]:
    """Flat name → shape map.  Iteration order (sorted) IS the ABI the Rust
    side addresses leaves by; never reorder without regenerating artifacts."""
    d, h, dh, ff, v = cfg.d_model, cfg.n_heads, cfg.d_head, cfg.d_ff, cfg.vocab_size
    shapes: Dict[str, tuple] = {"embed": (v, d), "final_norm": (d,)}
    for i in range(cfg.n_layers):
        p = f"layers.{i:02d}."
        shapes[p + "attn_norm"] = (d,)
        shapes[p + "wq"] = (d, h * dh)
        shapes[p + "wk"] = (d, h * dh)
        shapes[p + "wv"] = (d, h * dh)
        shapes[p + "wo"] = (h * dh, d)
        if cfg.qk_norm:
            shapes[p + "q_norm"] = (dh,)
            shapes[p + "k_norm"] = (dh,)
        shapes[p + "mlp_norm"] = (d,)
        shapes[p + "w_gate"] = (d, ff)
        shapes[p + "w_up"] = (d, ff)
        shapes[p + "w_down"] = (ff, d)
    return shapes


def param_names(cfg: ModelConfig) -> list:
    return sorted(param_shapes(cfg).keys())


def init_params(cfg: ModelConfig, seed) -> Params:
    """Scaled-normal init (std 0.02, Llama-style residual scaling on wo/w_down)."""
    shapes = param_shapes(cfg)
    names = param_names(cfg)
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, len(names))
    params: Params = {}
    resid_scale = 1.0 / math.sqrt(2 * cfg.n_layers)
    for name, k in zip(names, keys):
        shape = shapes[name]
        if name.endswith(("attn_norm", "mlp_norm", "final_norm", "q_norm", "k_norm")):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith(("wo", "w_down")):
            params[name] = 0.02 * resid_scale * jax.random.normal(k, shape, jnp.float32)
        else:
            params[name] = 0.02 * jax.random.normal(k, shape, jnp.float32)
    return params


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def rms_norm(x, gamma, eps):
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gamma


def rope_tables(cfg: ModelConfig):
    """Rotary position-embedding cos/sin tables (seq_len, d_head/2)."""
    half = cfg.d_head // 2
    freqs = cfg.rope_theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    pos = jnp.arange(cfg.seq_len, dtype=jnp.float32)
    angles = pos[:, None] * freqs[None, :]
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x, cos, sin):
    """x: (B, H, N, Dh) with Dh even; rotate pairs (x1, x2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attention(cfg: ModelConfig, q, k, v):
    if cfg.attention == "sage":
        sage_cfg = attn_mod.SageConfig(
            block_q=cfg.block_q, block_kv=cfg.block_kv, causal=True,
            k_smoothing=cfg.k_smoothing, q_smoothing=cfg.q_smoothing)
        return attn_mod.sage_attention(q, k, v, sage_cfg)
    if cfg.attention == "fpa":
        return attn_mod.fpa_attention(q, k, v, causal=True)
    raise ValueError(f"unknown attention {cfg.attention!r}")


def _block(cfg: ModelConfig, params: Params, prefix: str, x, cos, sin):
    b, n, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head

    y = rms_norm(x, params[prefix + "attn_norm"], cfg.norm_eps)
    q = (y @ params[prefix + "wq"]).reshape(b, n, h, dh).transpose(0, 2, 1, 3)
    k = (y @ params[prefix + "wk"]).reshape(b, n, h, dh).transpose(0, 2, 1, 3)
    v = (y @ params[prefix + "wv"]).reshape(b, n, h, dh).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        # §4.1: per-token RMS normalization of Q and K with learned γ —
        # bounds σ_Q, σ_K and hence the INT8 quantization step (§4.4).
        q = rms_norm(q, params[prefix + "q_norm"], cfg.norm_eps)
        k = rms_norm(k, params[prefix + "k_norm"], cfg.norm_eps)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    o = _attention(cfg, q, k, v)
    o = o.transpose(0, 2, 1, 3).reshape(b, n, h * dh)
    x = x + o @ params[prefix + "wo"]

    y = rms_norm(x, params[prefix + "mlp_norm"], cfg.norm_eps)
    gated = jax.nn.silu(y @ params[prefix + "w_gate"]) * (y @ params[prefix + "w_up"])
    return x + gated @ params[prefix + "w_down"]


def forward(cfg: ModelConfig, params: Params, tokens):
    """tokens: (B, N) int32 → logits (B, N, V)."""
    cos, sin = rope_tables(cfg)
    x = params["embed"][tokens]
    for i in range(cfg.n_layers):
        x = _block(cfg, params, f"layers.{i:02d}.", x, cos, sin)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x @ params["embed"].T  # tied head


def loss_fn(cfg: ModelConfig, params: Params, tokens, targets):
    """Mean next-token cross-entropy."""
    logits = forward(cfg, params, tokens)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1).squeeze(-1)
    return jnp.mean(logz - gold)


def grad_step(cfg: ModelConfig, params: Params, tokens, targets):
    """One microbatch: (loss, grads).  The Rust coordinator accumulates
    grads across microbatches to realize a given tokens-per-step (§4.3)."""
    return jax.value_and_grad(lambda p: loss_fn(cfg, p, tokens, targets))(params)


# ---------------------------------------------------------------------------
# AdamW optimizer step (applied once per *optimizer* step, after the Rust
# coordinator has averaged microbatch gradients)
# ---------------------------------------------------------------------------


def apply_step(cfg: ModelConfig, params: Params, m: Params, v: Params,
               grads: Params, lr, step):
    """AdamW with bias correction and decoupled weight decay.

    ``lr`` is a scalar input computed by the Rust LR scheduler; ``step`` is
    the 1-based optimizer step for bias correction."""
    step_f = step.astype(jnp.float32)
    c1 = 1.0 - ADAM_B1 ** step_f
    c2 = 1.0 - ADAM_B2 ** step_f
    new_p, new_m, new_v = {}, {}, {}
    for name in params:
        g = grads[name]
        m_n = ADAM_B1 * m[name] + (1 - ADAM_B1) * g
        v_n = ADAM_B2 * v[name] + (1 - ADAM_B2) * jnp.square(g)
        update = (m_n / c1) / (jnp.sqrt(v_n / c2) + ADAM_EPS)
        decay = 0.0 if name.endswith(("_norm", "q_norm", "k_norm")) else WEIGHT_DECAY
        new_p[name] = params[name] - lr * (update + decay * params[name])
        new_m[name] = m_n
        new_v[name] = v_n
    return new_p, new_m, new_v
