"""Structural performance report for L1/L2 (the profile step of the §Perf
pass — DESIGN.md §8).

L1 (Pallas): interpret=True wallclock is NOT a TPU proxy, so the kernel is
profiled structurally:
  * VMEM footprint per program for a given BlockSpec (must fit ~16 MiB/core,
    budgeted at ≤8 MiB to leave room for double-buffering),
  * MAC counts per precision class → INT8 fraction (the paper's "6 of 7
    matmuls" claim, and the input to the Figs 2–3 tensor-core model),
  * MXU-tile utilization estimate: fraction of each (128×128) systolic pass
    that carries real data for the chosen block sizes.

L2 (lowered HLO): op histogram per artifact — fusion count, convolution/dot
count, while-loop count — to catch redundant recomputation or missed
fusions across exports.

Usage: cd python && python -m compile.perf_report [--out ../results/perf]
"""

from __future__ import annotations

import argparse
import collections
import json
import os
import re


def l1_report(n: int, d: int, block_q: int, block_kv: int) -> dict:
    """Static analysis of Algorithm 1+2 under a (block_q, block_kv) tiling."""
    tm, tn = n // block_q, n // block_kv
    f32 = 4

    # Forward kernel VMEM per program (Q-block resident; K/V streamed as
    # tiles in the TPU schedule; acc + softmax stats).
    fwd_vmem = (
        block_q * d * f32          # Q tile (fp32 staging)
        + block_q * d * 1          # Q̂ int8
        + 2 * (block_kv * d * (f32 + 1))  # K,V tile staging + int8
        + block_q * block_kv * f32  # S/P tile
        + block_q * block_kv * 1    # P̂ int8
        + block_q * d * f32         # O accumulator
        + 3 * block_q * f32         # m, l, s_P vectors
    )
    # Backward dKdV program: K/V tiles resident, Q/dO streamed.
    bwd_vmem = (
        2 * block_kv * d * (f32 + 1)
        + block_q * d * (f32 + 1) * 2   # Q, dO staged + int8
        + 2 * block_q * block_kv * f32  # P, dS tiles
        + 2 * block_q * block_kv * 1    # P̂, d̂S
        + 2 * block_kv * d * f32        # dK, dV accumulators
        + 2 * block_q * f32             # lse, delta
    )

    # MAC counts per full attention (fwd+bwd), by precision.
    nn_d = n * n * d
    int8_macs = 2 * nn_d        # fwd: QK^T, P̂V̂
    int8_macs += 4 * nn_d       # bwd: S-recompute, dV, dQ, dK
    fp_macs = 1 * nn_d          # bwd: dP = dO V^T stays FP16 (§3)

    # MXU utilization estimate: systolic array is 128×128; a dot of
    # (block_q × d) @ (d × block_kv) uses min(dim,128)/128 per axis.
    def mxu_util(m, k, nn):
        import math
        eff = lambda x: x / (128 * math.ceil(x / 128))
        return eff(m) * eff(k) * eff(nn)

    return {
        "config": {"n": n, "d": d, "block_q": block_q, "block_kv": block_kv},
        "fwd_vmem_bytes": fwd_vmem,
        "bwd_vmem_bytes": bwd_vmem,
        "vmem_budget_ok": max(fwd_vmem, bwd_vmem) <= 8 * 1024 * 1024,
        "int8_mac_fraction": int8_macs / (int8_macs + fp_macs),
        "mxu_util_qk": mxu_util(block_q, d, block_kv),
        "mxu_util_pv": mxu_util(block_q, block_kv, d),
        "grid_programs_fwd": tm,
        "grid_programs_bwd": tm + tn,
    }


HLO_OP = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*\S+\s+(\w+)\(")


def l2_report(artifacts_dir: str, names: list[str]) -> dict:
    out = {}
    for name in names:
        path = os.path.join(artifacts_dir, f"{name}.hlo.txt")
        if not os.path.exists(path):
            continue
        counts: collections.Counter = collections.Counter()
        with open(path) as f:
            for line in f:
                m = HLO_OP.match(line)
                if m:
                    counts[m.group(1)] += 1
        total = sum(counts.values())
        out[name] = {
            "total_ops": total,
            "dot": counts.get("dot", 0),
            "while": counts.get("while", 0),
            "fusion": counts.get("fusion", 0),
            "convert": counts.get("convert", 0),
            "top5": counts.most_common(5),
            "bytes": os.path.getsize(path),
        }
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../results/perf")
    ap.add_argument("--artifacts", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    # L1: block-shape sweep at the paper's head dims.
    l1 = {}
    for d in (64, 128):
        for blk in (16, 32, 64, 128):
            key = f"d{d}_b{blk}"
            l1[key] = l1_report(4096, d, blk, blk)
    with open(os.path.join(args.out, "l1_structural.json"), "w") as f:
        json.dump(l1, f, indent=1)

    print("L1 structural report (N=4096):")
    print(f"{'config':>12} {'fwdVMEM':>10} {'bwdVMEM':>10} {'fits8MiB':>9} "
          f"{'int8frac':>9} {'MXUqk':>7} {'MXUpv':>7}")
    for key, r in l1.items():
        print(f"{key:>12} {r['fwd_vmem_bytes']/2**20:>9.2f}M {r['bwd_vmem_bytes']/2**20:>9.2f}M "
              f"{str(r['vmem_budget_ok']):>9} {r['int8_mac_fraction']:>9.3f} "
              f"{r['mxu_util_qk']:>7.3f} {r['mxu_util_pv']:>7.3f}")

    # L2: HLO op histograms of the training + bench artifacts.
    names = ["grad_step_sage_qknorm", "grad_step_fpa_qknorm",
             "apply_step_qknorm", "bench_sage_fwdbwd_d64_n512",
             "bench_fa2_fwdbwd_d64_n512"]
    l2 = l2_report(args.artifacts, names)
    with open(os.path.join(args.out, "l2_hlo_stats.json"), "w") as f:
        json.dump(l2, f, indent=1)
    print("\nL2 HLO op histogram:")
    for name, r in l2.items():
        print(f"  {name}: {r['total_ops']} ops, dot={r['dot']}, while={r['while']}, "
              f"fusion={r['fusion']}, {r['bytes']/1e6:.2f} MB")
    print(f"\nwrote {args.out}/l1_structural.json and l2_hlo_stats.json")


if __name__ == "__main__":
    main()
