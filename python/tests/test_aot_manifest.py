"""AOT exporter tests: manifest schema, ABI ordering, HLO-text validity.

These exercise `compile.aot.export` on tiny functions (fast) and validate
the real variant registry's parameter-ABI invariants that the Rust side
relies on (sorted names == positional order)."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.configs import VARIANTS, TRACE_VARIANTS, bench_variants

jax.config.update("jax_platform_name", "cpu")


class TestExport:
    def _export_tiny(self, tmp):
        def fn(x, y):
            return (x @ y, jnp.sum(x))

        spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
        aot.export(tmp, "tiny", fn, [spec, spec], ["x", "y"], ["prod", "sum"])
        return tmp

    def test_writes_hlo_and_manifest(self):
        with tempfile.TemporaryDirectory() as tmp:
            self._export_tiny(tmp)
            hlo = open(os.path.join(tmp, "tiny.hlo.txt")).read()
            assert "HloModule" in hlo
            m = json.load(open(os.path.join(tmp, "tiny.manifest.json")))
            assert m["artifact"] == "tiny"
            assert [i["name"] for i in m["inputs"]] == ["x", "y"]
            assert [o["name"] for o in m["outputs"]] == ["prod", "sum"]
            assert m["inputs"][0]["shape"] == [4, 4]
            assert m["inputs"][0]["dtype"] == "f32"
            assert m["outputs"][1]["shape"] == []

    def test_output_count_mismatch_caught(self):
        def fn(x):
            return (x, x)

        spec = jax.ShapeDtypeStruct((2,), jnp.float32)
        with tempfile.TemporaryDirectory() as tmp:
            with pytest.raises(AssertionError):
                aot.export(tmp, "bad", fn, [spec], ["x"], ["only_one"])

    def test_i32_dtype_mapping(self):
        def fn(t):
            return (t + 1,)

        spec = jax.ShapeDtypeStruct((3,), jnp.int32)
        with tempfile.TemporaryDirectory() as tmp:
            aot.export(tmp, "ints", fn, [spec], ["t"], ["t1"])
            m = json.load(open(os.path.join(tmp, "ints.manifest.json")))
            assert m["inputs"][0]["dtype"] == "i32"


class TestParamAbi:
    """The Rust coordinator addresses parameters positionally by sorted
    name — these invariants are the de-facto ABI."""

    @pytest.mark.parametrize("vname", sorted(VARIANTS))
    def test_param_names_sorted_and_unique(self, vname):
        names = model.param_names(VARIANTS[vname])
        assert names == sorted(names)
        assert len(names) == len(set(names))

    def test_qknorm_trees_differ_only_in_norm_gammas(self):
        with_n = set(model.param_names(VARIANTS["sage_qknorm"]))
        without = set(model.param_names(VARIANTS["sage_noqknorm"]))
        extra = {n.rsplit(".", 1)[-1] for n in with_n - without}
        assert extra == {"q_norm", "k_norm"}

    def test_sage_and_fpa_share_tree(self):
        assert model.param_names(VARIANTS["sage_qknorm"]) == model.param_names(
            VARIANTS["fpa_qknorm"]
        )


class TestRegistry:
    def test_trace_variants_cover_experiments(self):
        assert {"trace_fpa", "trace_sage", "trace_pseudo", "trace_pseudo_nosm",
                "trace_pseudo_qksm", "trace_fpa_n512"} <= set(TRACE_VARIANTS)

    def test_bench_grid_is_complete(self):
        names = set(bench_variants())
        for d in (64, 128):
            for n in (128, 256, 512):
                for impl in ("sage", "fa2", "naive"):
                    for mode in ("fwd", "fwdbwd"):
                        assert f"bench_{impl}_{mode}_d{d}_n{n}" in names

    def test_trace_output_order_is_stable(self):
        # The Rust Trace struct unpacks positionally — order is ABI.
        assert aot.TRACE_OUTPUTS == ["o", "dq", "dk", "dv", "delta", "rms_p",
                                     "rms_dp", "rms_ds", "p", "dp", "ds"]
