"""Appendix B: RMS(dS) ≤ (1/√N)·max_i ‖dP_i − δ_i·1‖∞, and §4.2's
magnitude-hierarchy RMS(P) ≫ RMS(dP) ≫ RMS(dS)."""

import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import metrics
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _tensors(n, d, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    return [jax.random.normal(k, (n, d), jnp.float32) for k in keys]


@given(st.integers(0, 5000), st.sampled_from([32, 64, 128]))
@settings(max_examples=15, deadline=None)
def test_appendix_b_bound(seed, n):
    q, k, v, do = _tensors(n, 32, seed % 997)
    it = ref.fpa_bwd(q, k, v, do)
    bound = (1.0 / jnp.sqrt(jnp.float32(n))) * jnp.max(
        jnp.max(jnp.abs(it.dp - it.delta[:, None]), axis=-1))
    assert float(metrics.rms(it.ds)) <= float(bound) + 1e-7


def test_rms_p_bound():
    """Eq. (4): RMS(P_i) ≤ 1/√N for every softmax row."""
    q, k, v, do = _tensors(128, 64, seed=3)
    it = ref.fpa_bwd(q, k, v, do)
    row_rms = jnp.sqrt(jnp.mean(jnp.square(it.p), axis=-1))
    assert float(jnp.max(row_rms)) <= 1.0 / jnp.sqrt(128.0) * (1 + 1e-5) + 1e-7


def test_ds_shrinks_with_sequence_length():
    """§4.2: the 1/√N scaling makes dS smaller for longer sequences."""
    rms_by_n = {}
    for n in (32, 128, 512):
        q, k, v, do = _tensors(n, 32, seed=7)
        it = ref.fpa_bwd(q, k, v, do)
        rms_by_n[n] = float(metrics.rms(it.ds))
    assert rms_by_n[512] < rms_by_n[128] < rms_by_n[32]


def test_magnitude_hierarchy():
    """§4.2's empirical scale RMS(P) ≫ RMS(dP) ≫ RMS(dS).

    The paper measures a trained checkpoint where upstream gradients are
    tiny (RMS(dP) ≈ 5e-5); we emulate that regime by scaling dO down.  The
    dS ≪ dP part holds at *any* dO scale (it is the 1/√N softmax effect)."""
    q, k, v, do = _tensors(256, 64, seed=11)
    it = ref.fpa_bwd(q, k, v, do)
    assert float(metrics.rms(it.ds)) < 0.2 * float(metrics.rms(it.dp))

    it_small = ref.fpa_bwd(q, k, v, do * 1e-4)
    assert (float(metrics.rms(it_small.p))
            > float(metrics.rms(it_small.dp))
            > float(metrics.rms(it_small.ds)))
