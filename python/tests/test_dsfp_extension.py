"""§7 future-work extension: the FP-dS variant (quant_ds=False).

Implements and evaluates the paper's proposed direction — "mitigate
backward-pass quantization error, particularly along the dS path".
Finding (recorded in EXPERIMENTS.md): keeping the dS matmuls in floating
point barely helps, because dS's error is *inherited* from the quantized
forward (S → P → dS), exactly the multiplicative-structure argument of
§4.2.  The effective lever is bounding forward error (QK-norm), not
de-quantizing the backward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import metrics
from compile.kernels import ref, sagebwd_bwd, sagebwd_fwd

jax.config.update("jax_platform_name", "cpu")


def _tensors(sigma_qk=4.0, sigma_do=0.02, n=128, d=64, seed=7):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return (sigma_qk * jax.random.normal(ks[0], (n, d)),
            sigma_qk * jax.random.normal(ks[1], (n, d)),
            jax.random.normal(ks[2], (n, d)),
            sigma_do * jax.random.normal(ks[3], (n, d)))


class TestKernelDsFp:
    @pytest.mark.parametrize("causal", [False, True])
    def test_kernel_matches_oracle(self, causal):
        q, k, v, do = _tensors(sigma_qk=1.0, sigma_do=1.0)
        o, lse = sagebwd_fwd.sage_fwd(q, k, v, block_q=32, block_kv=32,
                                      causal=causal)
        dq, dk, dv = sagebwd_bwd.sage_bwd(q, k, v, do, o, lse, block_q=32,
                                          block_kv=32, causal=causal,
                                          quant_ds=False)
        it = ref.sage_ref_bwd(q, k, v, do, 32, 32, causal=causal,
                              quant_ds=False)
        np.testing.assert_allclose(np.asarray(dq), np.asarray(it.dq),
                                   atol=5e-4, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(dk), np.asarray(it.dk),
                                   atol=5e-4, rtol=1e-3)

    def test_fp_ds_never_worse(self):
        """At any σ, the FP-dS variant is ≥ as accurate as full INT8."""
        for sigma in (1.0, 4.0, 8.0):
            q, k, v, do = _tensors(sigma_qk=sigma, sigma_do=1.0, seed=3)
            fi = ref.fpa_bwd(q, k, v, do)
            o, lse = sagebwd_fwd.sage_fwd(q, k, v, block_q=32, block_kv=32)
            dq_q, _, _ = sagebwd_bwd.sage_bwd(q, k, v, do, o, lse, 32, 32,
                                              quant_ds=True)
            dq_f, _, _ = sagebwd_bwd.sage_bwd(q, k, v, do, o, lse, 32, 32,
                                              quant_ds=False)
            err_q = float(metrics.rel_l2(dq_q, fi.dq))
            err_f = float(metrics.rel_l2(dq_f, fi.dq))
            assert err_f <= err_q * 1.05, f"sigma={sigma}: {err_f} vs {err_q}"


class TestInheritedErrorFinding:
    def test_ds_error_is_mostly_inherited(self):
        """The negative result: de-quantizing dS removes <20% of dQ error —
        the dS tensor itself is already wrong via the quantized forward."""
        q, k, v, do = _tensors()
        fi = ref.fpa_bwd(q, k, v, do)
        tr_q = ref.pseudo_quant_trace(q, k, v, do, quant_ds=True)
        tr_f = ref.pseudo_quant_trace(q, k, v, do, quant_ds=False)
        err_q = float(metrics.rel_l2(tr_q.dq, fi.dq))
        err_f = float(metrics.rel_l2(tr_f.dq, fi.dq))
        assert err_f < err_q                      # helps a little...
        assert err_f > 0.8 * err_q                # ...but most error remains
        # dS tensor error identical in both (it is upstream of ψ(dS)).
        np.testing.assert_allclose(np.asarray(tr_q.ds), np.asarray(tr_f.ds))

    def test_forward_dequant_is_the_real_lever(self):
        """Bounding σ (what QK-norm does) beats de-quantizing dS."""
        q, k, v, do = _tensors(sigma_qk=4.0)
        fi = ref.fpa_bwd(q, k, v, do)
        tr_dsfp = ref.pseudo_quant_trace(q, k, v, do, quant_ds=False)
        err_dsfp = float(metrics.rel_l2(tr_dsfp.dq, fi.dq))

        qn = q / (4.0)  # σ back to 1 — a stand-in for QK-norm's effect
        kn = k / (4.0)
        fin = ref.fpa_bwd(qn, kn, v, do)
        tr_norm = ref.pseudo_quant_trace(qn, kn, v, do, quant_ds=True)
        err_norm = float(metrics.rel_l2(tr_norm.dq, fin.dq))
        # σ-normalization nearly halves dQ error (1.9× here); FP-dS gave <2%.
        assert err_norm < err_dsfp * 0.6
