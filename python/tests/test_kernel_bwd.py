"""Pallas backward kernels (Alg 2) vs the block-faithful jnp oracle and FPA."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, sagebwd_bwd, sagebwd_fwd

jax.config.update("jax_platform_name", "cpu")


def _tensors(n, d, seed=0, scale=1.0):
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    return [scale * jax.random.normal(k, (n, d), jnp.float32) for k in keys]


def _run_kernel(q, k, v, do, bq, bkv, causal, ksm, qsm):
    o, lse = sagebwd_fwd.sage_fwd(q, k, v, block_q=bq, block_kv=bkv,
                                  causal=causal, k_smoothing=ksm,
                                  q_smoothing=qsm)
    return sagebwd_bwd.sage_bwd(q, k, v, do, o, lse, block_q=bq,
                                block_kv=bkv, causal=causal,
                                k_smoothing=ksm, q_smoothing=qsm)


def _assert_matches_ref(q, k, v, do, bq, bkv, causal, ksm, qsm, tol=2e-5):
    dq, dk, dv = _run_kernel(q, k, v, do, bq, bkv, causal, ksm, qsm)
    it = ref.sage_ref_bwd(q, k, v, do, bq, bkv, causal, ksm, qsm)
    np.testing.assert_allclose(np.asarray(dq), np.asarray(it.dq), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(it.dk), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(dv), np.asarray(it.dv), atol=tol, rtol=tol)


class TestBwdVsOracle:
    @pytest.mark.parametrize("causal", [False, True])
    @pytest.mark.parametrize("block", [16, 32])
    def test_square_blocks(self, causal, block):
        q, k, v, do = _tensors(64, 32, seed=1)
        _assert_matches_ref(q, k, v, do, block, block, causal, True, False)

    def test_rectangular_blocks(self):
        q, k, v, do = _tensors(64, 16, seed=2)
        _assert_matches_ref(q, k, v, do, 32, 16, True, True, False)
        _assert_matches_ref(q, k, v, do, 16, 32, False, True, False)

    @pytest.mark.parametrize("ksm,qsm", [(False, False), (True, False), (True, True)])
    def test_smoothing_modes(self, ksm, qsm):
        q, k, v, do = _tensors(64, 32, seed=3)
        k = k + 2.0
        _assert_matches_ref(q, k, v, do, 32, 32, False, ksm, qsm, tol=5e-5)

    @given(st.integers(0, 10_000),
           st.sampled_from([(64, 16), (64, 32)]),
           st.sampled_from([16, 32]),
           st.booleans(), st.booleans(), st.booleans())
    @settings(max_examples=8, deadline=None)
    def test_hypothesis_sweep(self, seed, nd, block, causal, ksm, qsm):
        # Tolerance is one-quant-step sized: quantization is a step
        # function, so fp-equivalent computations can disagree by one int8
        # step on inputs landing exactly on a rounding tie (same reasoning
        # as the forward sweep).
        n, d = nd
        q, k, v, do = _tensors(n, d, seed=seed % 997)
        _assert_matches_ref(q, k, v, do, block, block, causal, ksm, qsm, tol=2e-2)


class TestBwdVsFPA:
    def test_grads_close_at_unit_sigma(self):
        """Table 1 σ=1 row: CosSim ≥ 0.999 for dQ/dK/dV."""
        q, k, v, do = _tensors(128, 64, seed=4)
        dq, dk, dv = _run_kernel(q, k, v, do, 32, 32, False, True, False)
        it = ref.fpa_bwd(q, k, v, do)

        def cossim(a, b):
            a, b = a.reshape(-1), b.reshape(-1)
            return float(jnp.dot(a, b) / (jnp.linalg.norm(a) * jnp.linalg.norm(b)))

        assert cossim(dq, it.dq) > 0.995
        assert cossim(dk, it.dk) > 0.995
        assert cossim(dv, it.dv) > 0.999

    def test_grads_degrade_at_large_sigma(self):
        """Table 1 σ=10 row: dQ/dK collapse while O/dV stay accurate (§4.4)."""
        q, k, v, do = _tensors(128, 64, seed=5)
        q10, k10 = q * 10.0, k * 10.0
        dq, dk, dv = _run_kernel(q10, k10, v, do, 32, 32, False, True, False)
        it = ref.fpa_bwd(q10, k10, v, do)

        def cossim(a, b):
            a, b = a.reshape(-1), b.reshape(-1)
            return float(jnp.dot(a, b) / (jnp.linalg.norm(a) * jnp.linalg.norm(b)))

        assert cossim(dv, it.dv) > 0.98      # dV robust
        assert cossim(dq, it.dq) < 0.98      # dQ degrades (paper: 0.78)
        assert cossim(dk, it.dk) < 0.98

    def test_dv_row_sums(self):
        # dV = P^T dO: column-stochasticity check — sum_i dV_i equals
        # sum_i dO_i because sum_j P_ij = 1 row-wise.
        q, k, v, do = _tensors(64, 32, seed=6)
        _, _, dv = _run_kernel(q, k, v, do, 32, 32, False, True, False)
        # atol is quantization-sized relative to the O(√N) column sums.
        np.testing.assert_allclose(np.asarray(jnp.sum(dv, axis=0)),
                                   np.asarray(jnp.sum(do, axis=0)),
                                   rtol=0.05, atol=0.2)
