"""Pallas forward kernel (Alg 1) vs the block-faithful jnp oracle.

The kernel must match ``ref.sage_ref_fwd`` to fp32 round-off — same
quantization decisions, same online-softmax recurrence — across shapes,
block sizes, causal flags, and smoothing modes (hypothesis-swept)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fa2_ref, ref, sagebwd_fwd

jax.config.update("jax_platform_name", "cpu")


def _qkv(n, d, seed=0, scale=1.0):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    return [scale * jax.random.normal(k, (n, d), jnp.float32) for k in keys]


def _assert_matches_ref(q, k, v, block_q, block_kv, causal, ksm, qsm,
                        atol=1e-5):
    # atol floor: quantization is a step function, so two fp-equivalent
    # computations can disagree by one int8 step (≈ max|x|/127) on inputs
    # that land exactly on a rounding tie.  Strict 1e-5 holds on the fixed
    # seeds below; the randomized sweep uses a one-quant-step allowance.
    o_k, lse_k = sagebwd_fwd.sage_fwd(q, k, v, block_q=block_q,
                                      block_kv=block_kv, causal=causal,
                                      k_smoothing=ksm, q_smoothing=qsm)
    o_r, lse_r, _ = ref.sage_ref_fwd(q, k, v, block_q, block_kv, causal,
                                     ksm, qsm)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               atol=atol, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(lse_k), np.asarray(lse_r),
                               atol=max(atol, 1e-4), rtol=1e-4)


class TestKernelVsOracle:
    @pytest.mark.parametrize("causal", [False, True])
    @pytest.mark.parametrize("block", [16, 32])
    def test_square_blocks(self, causal, block):
        q, k, v = _qkv(64, 32, seed=1)
        _assert_matches_ref(q, k, v, block, block, causal, True, False)

    def test_rectangular_blocks(self):
        q, k, v = _qkv(64, 16, seed=2)
        _assert_matches_ref(q, k, v, 32, 16, False, True, False)
        _assert_matches_ref(q, k, v, 16, 32, True, True, False)

    @pytest.mark.parametrize("ksm,qsm", [(False, False), (True, False), (True, True)])
    def test_smoothing_modes(self, ksm, qsm):
        q, k, v = _qkv(64, 32, seed=3)
        k = k + 2.0  # K mean offset so smoothing actually changes numbers
        _assert_matches_ref(q, k, v, 32, 32, True, ksm, qsm)

    @given(st.integers(0, 10_000),
           st.sampled_from([(64, 16), (64, 32), (128, 64)]),
           st.sampled_from([16, 32]),
           st.booleans(), st.booleans(), st.booleans(),
           st.floats(0.25, 4.0))
    @settings(max_examples=10, deadline=None)
    def test_hypothesis_sweep(self, seed, nd, block, causal, ksm, qsm, scale):
        n, d = nd
        q, k, v = _qkv(n, d, seed=seed % 997, scale=scale)
        _assert_matches_ref(q, k, v, block, block, causal, ksm, qsm,
                            atol=2e-2 * scale)


class TestKernelVsFPA:
    """Loose checks against exact attention — quantization-sized error."""

    def test_close_at_unit_sigma(self):
        q, k, v = _qkv(128, 64, seed=4)
        o_k, _ = sagebwd_fwd.sage_fwd(q, k, v, block_q=32, block_kv=32)
        o_f, _ = ref.fpa_fwd(q, k, v)
        rel = float(jnp.linalg.norm(o_k - o_f) / jnp.linalg.norm(o_f))
        assert rel < 0.05  # Table 1 row σ=1: Rel-ℓ2(O) ≈ 0.016

    def test_causal_rows_are_proper(self):
        # Every output row must be a convex combination of the visible V
        # prefix: row 0 == v[0] exactly under causal masking.
        q, k, v = _qkv(64, 32, seed=5)
        # Tolerance is quantization-sized: row 0's P is the 1-hot vector
        # but V itself went through per-block INT8 (≈1% relative error).
        o_k, _ = sagebwd_fwd.sage_fwd(q, k, v, block_q=32, block_kv=32,
                                      causal=True)
        np.testing.assert_allclose(np.asarray(o_k[0]), np.asarray(v[0]),
                                   atol=0.03, rtol=0.05)


class TestFa2Baseline:
    @pytest.mark.parametrize("causal", [False, True])
    def test_fa2_matches_naive(self, causal):
        q, k, v = _qkv(128, 64, seed=6)
        o, _ = fa2_ref.fa2_fwd(q, k, v, block_q=32, block_kv=32, causal=causal)
        o_n = fa2_ref.naive_sdpa(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_n),
                                   atol=2e-5, rtol=2e-5)

    def test_lse_matches_fpa(self):
        q, k, v = _qkv(64, 32, seed=7)
        _, lse = fa2_ref.fa2_fwd(q, k, v, block_q=32, block_kv=32)
        _, (_, _, lse_f) = ref.fpa_fwd(q, k, v)
        np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_f),
                                   atol=1e-5)
