"""Metric definitions must agree between Python (build-time checks) and
Rust (run-time harnesses); these pin the Python side with known values."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import metrics


def test_cossim_bounds_and_identity():
    x = jnp.array([1.0, -2.0, 3.0])
    assert abs(float(metrics.cossim(x, x)) - 1.0) < 1e-6
    assert abs(float(metrics.cossim(x, -x)) + 1.0) < 1e-6


def test_rel_l2_known_value():
    y = jnp.array([1.0, 1.0])
    x = jnp.array([1.1, 0.9])
    assert abs(float(metrics.rel_l2(x, y)) - 0.1) < 1e-6


def test_rms_known_value():
    assert abs(float(metrics.rms(jnp.array([3.0, 4.0]))) - np.sqrt(12.5)) < 1e-6


@given(st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_cossim_scale_invariant(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=32).astype(np.float32))
    y = jnp.asarray(rng.normal(size=32).astype(np.float32))
    c1 = float(metrics.cossim(x, y))
    c2 = float(metrics.cossim(3.7 * x, 0.2 * y))
    assert abs(c1 - c2) < 1e-4
    assert -1.0 - 1e-6 <= c1 <= 1.0 + 1e-6


@given(st.integers(0, 10_000), st.floats(0.0, 2.0))
@settings(max_examples=30, deadline=None)
def test_rel_l2_triangle_like(seed, eps):
    rng = np.random.default_rng(seed)
    y = jnp.asarray(rng.normal(size=16).astype(np.float32))
    x = y + eps * jnp.asarray(rng.normal(size=16).astype(np.float32))
    # error grows (weakly) with perturbation size relative to zero-perturbation
    assert float(metrics.rel_l2(y, y)) == 0.0
    assert float(metrics.rel_l2(x, y)) >= 0.0
