"""L2 model tests: shapes, loss sanity, gradient flow, variant grid."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import VARIANTS, ModelConfig

jax.config.update("jax_platform_name", "cpu")

# Tiny config so interpret-mode attention stays fast in CI.
TINY = ModelConfig(vocab_size=64, d_model=32, n_layers=2, n_heads=2,
                   d_head=16, d_ff=64, seq_len=32, block_q=16, block_kv=16)
TINY_FPA = TINY._replace(attention="fpa")


def _batch(cfg, b=2, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    tok = jax.random.randint(k1, (b, cfg.seq_len), 0, cfg.vocab_size)
    tgt = jax.random.randint(k2, (b, cfg.seq_len), 0, cfg.vocab_size)
    return tok, tgt


class TestParams:
    def test_schema_sorted_is_stable(self):
        names = model.param_names(TINY)
        assert names == sorted(names)
        assert "embed" in names and "final_norm" in names

    def test_qk_norm_adds_params(self):
        with_norm = set(model.param_names(TINY))
        without = set(model.param_names(TINY._replace(qk_norm=False)))
        diff = with_norm - without
        assert diff == {f"layers.{i:02d}.{n}" for i in range(TINY.n_layers)
                        for n in ("q_norm", "k_norm")}

    def test_init_shapes_match_schema(self):
        params = model.init_params(TINY, 0)
        shapes = model.param_shapes(TINY)
        assert set(params) == set(shapes)
        for n, p in params.items():
            assert p.shape == shapes[n], n

    def test_init_deterministic_in_seed(self):
        a = model.init_params(TINY, 7)
        b = model.init_params(TINY, 7)
        c = model.init_params(TINY, 8)
        np.testing.assert_array_equal(np.asarray(a["embed"]), np.asarray(b["embed"]))
        assert float(jnp.max(jnp.abs(a["embed"] - c["embed"]))) > 0

    def test_param_count_estimate(self):
        params = model.init_params(TINY, 0)
        actual = sum(int(np.prod(p.shape)) for p in params.values())
        est = TINY.param_count_estimate
        assert abs(actual - est) / actual < 0.02


class TestForward:
    def test_logits_shape(self):
        params = model.init_params(TINY_FPA, 0)
        tok, _ = _batch(TINY_FPA)
        logits = model.forward(TINY_FPA, params, tok)
        assert logits.shape == (2, TINY.seq_len, TINY.vocab_size)

    def test_initial_loss_near_uniform(self):
        # Fresh init ⇒ loss ≈ log(V).
        params = model.init_params(TINY_FPA, 0)
        tok, tgt = _batch(TINY_FPA)
        loss = model.loss_fn(TINY_FPA, params, tok, tgt)
        assert abs(float(loss) - np.log(TINY.vocab_size)) < 0.5

    def test_causality(self):
        # Changing a future token must not change earlier logits.
        params = model.init_params(TINY_FPA, 0)
        tok, _ = _batch(TINY_FPA)
        l1 = model.forward(TINY_FPA, params, tok)
        tok2 = tok.at[:, -1].set((tok[:, -1] + 1) % TINY.vocab_size)
        l2 = model.forward(TINY_FPA, params, tok2)
        np.testing.assert_allclose(np.asarray(l1[:, :-1]),
                                   np.asarray(l2[:, :-1]), atol=1e-5)

    def test_sage_close_to_fpa_at_init(self):
        params = model.init_params(TINY, 0)
        tok, _ = _batch(TINY)
        l_sage = model.forward(TINY, params, tok)
        l_fpa = model.forward(TINY_FPA, params, tok)
        rel = float(jnp.linalg.norm(l_sage - l_fpa) / jnp.linalg.norm(l_fpa))
        assert rel < 0.02


class TestGradStep:
    @pytest.mark.parametrize("cfg", [TINY, TINY_FPA], ids=["sage", "fpa"])
    def test_grads_cover_all_params(self, cfg):
        params = model.init_params(cfg, 0)
        tok, tgt = _batch(cfg)
        loss, grads = model.grad_step(cfg, params, tok, tgt)
        assert set(grads) == set(params)
        assert np.isfinite(float(loss))
        nonzero = sum(int(jnp.any(grads[n] != 0)) for n in grads)
        assert nonzero >= len(grads) - 1  # final_norm γ can be tiny but not all-zero

    def test_sage_grads_close_to_fpa(self):
        params = model.init_params(TINY, 1)
        tok, tgt = _batch(TINY, seed=1)
        _, g_sage = model.grad_step(TINY, params, tok, tgt)
        _, g_fpa = model.grad_step(TINY_FPA, params, tok, tgt)
        for n in ("embed", "layers.00.wq", "layers.01.w_down"):
            a, b = g_sage[n].reshape(-1), g_fpa[n].reshape(-1)
            cos = float(jnp.dot(a, b) /
                        (jnp.linalg.norm(a) * jnp.linalg.norm(b) + 1e-20))
            assert cos > 0.98, n


class TestApplyStep:
    def test_adamw_moves_params_against_gradient(self):
        params = model.init_params(TINY_FPA, 0)
        zeros = {n: jnp.zeros_like(p) for n, p in params.items()}
        grads = {n: jnp.ones_like(p) for n, p in params.items()}
        new_p, new_m, new_v = model.apply_step(
            TINY_FPA, params, zeros, zeros, grads,
            jnp.float32(1e-2), jnp.int32(1))
        # positive gradient ⇒ params decrease
        assert float(jnp.mean(new_p["embed"] - params["embed"])) < 0
        assert float(jnp.mean(new_m["embed"])) > 0

    def test_no_decay_on_norm_params(self):
        params = model.init_params(TINY_FPA, 0)
        zeros = {n: jnp.zeros_like(p) for n, p in params.items()}
        new_p, _, _ = model.apply_step(TINY_FPA, params, zeros, zeros, zeros,
                                       jnp.float32(1e-2), jnp.int32(1))
        # zero grad + zero moments: decayed params shrink, norms don't move
        np.testing.assert_allclose(np.asarray(new_p["final_norm"]),
                                   np.asarray(params["final_norm"]), atol=1e-7)
        assert float(jnp.max(jnp.abs(new_p["embed"] - params["embed"]))) > 0

    def test_two_steps_reduce_loss(self):
        cfg = TINY_FPA
        params = model.init_params(cfg, 0)
        m = {n: jnp.zeros_like(p) for n, p in params.items()}
        v = {n: jnp.zeros_like(p) for n, p in params.items()}
        tok, tgt = _batch(cfg, seed=3)
        loss0, grads = model.grad_step(cfg, params, tok, tgt)
        for step in (1, 2, 3):
            params, m, v = model.apply_step(cfg, params, m, v, grads,
                                            jnp.float32(3e-3), jnp.int32(step))
            _, grads = model.grad_step(cfg, params, tok, tgt)
        loss1 = model.loss_fn(cfg, params, tok, tgt)
        assert float(loss1) < float(loss0)


class TestVariants:
    def test_registry_covers_paper_grid(self):
        assert {"sage_qknorm", "sage_noqknorm", "fpa_qknorm", "fpa_noqknorm",
                "sage_qknorm_nosm", "sage_qknorm_qksm"} <= set(VARIANTS)

    def test_all_variants_construct_params(self):
        for name, cfg in VARIANTS.items():
            tiny = cfg._replace(vocab_size=64, d_model=32, n_layers=1,
                                n_heads=2, d_head=16, d_ff=64, seq_len=32,
                                block_q=16, block_kv=16)
            p = model.init_params(tiny, 0)
            assert len(p) == len(model.param_names(tiny)), name
