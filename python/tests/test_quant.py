"""Unit + property tests for the INT8 quantizer ψ (paper §3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quant

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed=0, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


class TestPerTensor:
    def test_int8_range(self):
        q, s = quant.quantize_per_tensor(_rand((32, 16), scale=10.0))
        assert q.dtype == jnp.int8
        assert int(jnp.max(jnp.abs(q.astype(jnp.int32)))) <= 127

    def test_scale_positive(self):
        _, s = quant.quantize_per_tensor(jnp.zeros((4, 4)))
        assert float(s) > 0.0

    def test_roundtrip_error_bounded_by_half_step(self):
        x = _rand((64, 32), seed=3, scale=5.0)
        q, s = quant.quantize_per_tensor(x)
        err = jnp.max(jnp.abs(quant.dequantize(q, s) - x))
        assert float(err) <= float(s) / 2 + 1e-6

    def test_max_element_maps_to_127(self):
        x = jnp.array([[0.5, -2.0], [1.0, 2.0]])
        q, s = quant.quantize_per_tensor(x)
        assert int(jnp.max(jnp.abs(q.astype(jnp.int32)))) == 127

    @given(st.integers(0, 2**31 - 1), st.floats(0.01, 100.0))
    @settings(max_examples=25, deadline=None)
    def test_roundtrip_property(self, seed, scale):
        x = _rand((16, 8), seed=seed % 1000, scale=scale)
        q, s = quant.quantize_per_tensor(x)
        err = jnp.max(jnp.abs(quant.dequantize(q, s) - x))
        assert float(err) <= float(s) / 2 + 1e-5 * scale


class TestPerToken:
    def test_scale_shape(self):
        _, s = quant.quantize_per_token(_rand((32, 16)))
        assert s.shape == (32, 1)

    def test_rowwise_roundtrip(self):
        # Rows with wildly different magnitudes must each stay accurate —
        # the reason Alg 1 line 9 uses per-token quantization for P̃.
        x = jnp.concatenate([
            _rand((1, 64), seed=1, scale=1e-3),
            _rand((1, 64), seed=2, scale=1.0),
            _rand((1, 64), seed=3, scale=1e3),
        ])
        q, s = quant.quantize_per_token(x)
        deq = quant.dequantize(q, s)
        rel = jnp.linalg.norm(deq - x, axis=-1) / jnp.linalg.norm(x, axis=-1)
        assert float(jnp.max(rel)) < 0.02

    def test_per_tensor_fails_where_per_token_succeeds(self):
        # Demonstrates the granularity argument from §3.
        x = jnp.concatenate([_rand((1, 64), 1, 1e-4), _rand((1, 64), 2, 1.0)])
        deq_tok = quant.dequantize(*quant.quantize_per_token(x))
        deq_ten = quant.dequantize(*quant.quantize_per_tensor(x))
        err_tok = jnp.linalg.norm(deq_tok[0] - x[0]) / jnp.linalg.norm(x[0])
        err_ten = jnp.linalg.norm(deq_ten[0] - x[0]) / jnp.linalg.norm(x[0])
        assert float(err_tok) < 0.02 < float(err_ten)


class TestInt8Matmul:
    def test_exact_on_small_integers(self):
        # Integer-valued inputs within ±127 quantize losslessly (δ chosen so
        # x/δ is integral) → the INT8 matmul must be *exact*.
        a = jnp.round(_rand((8, 8), 5) * 10).astype(jnp.float32)
        b = jnp.round(_rand((8, 8), 6) * 10).astype(jnp.float32)
        a = a * (127.0 / jnp.maximum(jnp.max(jnp.abs(a)), 1))
        a = jnp.round(a)
        b = b * (127.0 / jnp.maximum(jnp.max(jnp.abs(b)), 1))
        b = jnp.round(b)
        aq, asc = quant.quantize_per_tensor(a)
        bq, bsc = quant.quantize_per_tensor(b)
        out = quant.int8_matmul(aq, asc, bq, bsc)
        np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b), rtol=1e-5)

    @given(st.integers(0, 1000))
    @settings(max_examples=20, deadline=None)
    def test_approximation_quality(self, seed):
        a, b = _rand((16, 24), seed), _rand((24, 12), seed + 1)
        aq, asc = quant.quantize_per_tensor(a)
        bq, bsc = quant.quantize_per_tensor(b)
        approx = quant.int8_matmul(aq, asc, bq, bsc)
        exact = a @ b
        rel = jnp.linalg.norm(approx - exact) / jnp.linalg.norm(exact)
        assert float(rel) < 0.05

    def test_error_grows_with_sigma(self):
        # §4.4: quantization step (and thus absolute error) scales with the
        # input dynamic range.
        errs = []
        for sigma in [1.0, 10.0]:
            a, b = _rand((32, 32), 7, sigma), _rand((32, 32), 8, sigma)
            aq, asc = quant.quantize_per_tensor(a)
            bq, bsc = quant.quantize_per_tensor(b)
            errs.append(float(jnp.max(jnp.abs(quant.int8_matmul(aq, asc, bq, bsc) - a @ b))))
        assert errs[1] > errs[0] * 10  # error ∝ δ_A·δ_B ∝ σ²


class TestFakeQuant:
    def test_idempotent(self):
        x = _rand((16, 16), 9)
        once = quant.fake_quant(x, "block")
        twice = quant.fake_quant(once, "block")
        np.testing.assert_allclose(np.asarray(once), np.asarray(twice), atol=1e-6)

    def test_unknown_granularity_raises(self):
        with pytest.raises(ValueError):
            quant.fake_quant(jnp.zeros((2, 2)), "nope")

    def test_error_within_bound(self):
        x = _rand((32, 32), 10, 3.0)
        err = jnp.max(jnp.abs(quant.fake_quant(x, "block") - x))
        assert float(err) <= float(quant.quant_error_bound(x)) + 1e-6
