"""The oracle must itself be right: fpa_bwd's explicit gradient formulas are
checked against jax.grad of a naive attention, and the pseudo-quantized
trace's structural properties (Table 2's dP ≡ exact) are verified."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import fa2_ref, ref

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed=0, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


class TestFpaBwdAgainstAutodiff:
    @given(st.integers(0, 300), st.booleans())
    @settings(max_examples=12, deadline=None)
    def test_grads_match_jax_grad(self, seed, causal):
        n, d = 32, 16
        q, k, v, do = (_rand((n, d), seed + i) for i in range(4))

        def attn_dot(q, k, v):
            o = fa2_ref.naive_sdpa(q, k, v, causal=causal)
            return jnp.sum(o * do)

        dq_a, dk_a, dv_a = jax.grad(attn_dot, argnums=(0, 1, 2))(q, k, v)
        it = ref.fpa_bwd(q, k, v, do, causal=causal)
        np.testing.assert_allclose(np.asarray(it.dq), np.asarray(dq_a), atol=2e-5)
        np.testing.assert_allclose(np.asarray(it.dk), np.asarray(dk_a), atol=2e-5)
        np.testing.assert_allclose(np.asarray(it.dv), np.asarray(dv_a), atol=2e-5)

    def test_forward_matches_naive(self):
        q, k, v = (_rand((64, 32), i) for i in range(3))
        o, _ = ref.fpa_fwd(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(o), np.asarray(fa2_ref.naive_sdpa(q, k, v, causal=True)),
            atol=1e-5)

    def test_lse_is_logsumexp(self):
        q, k, v = (_rand((32, 16), 5 + i) for i in range(3))
        _, (s, _, lse) = ref.fpa_fwd(q, k, v)
        np.testing.assert_allclose(
            np.asarray(lse), np.asarray(jax.scipy.special.logsumexp(s, axis=-1)),
            atol=1e-5)


class TestPseudoQuantTrace:
    def test_dp_exact(self):
        """Table 2: Rel-L2(dP)=0 because upstream dO is treated error-free."""
        q, k, v, do = (_rand((64, 32), 10 + i) for i in range(4))
        tr = ref.pseudo_quant_trace(q, k, v, do)
        fi = ref.fpa_bwd(q, k, v, do)
        np.testing.assert_allclose(np.asarray(tr.dp), np.asarray(fi.dp), atol=1e-6)

    def test_error_ordering_matches_table2(self):
        """dS/dQ/dK errors dominate O/dV errors (the paper's core claim)."""
        q, k, v, do = (_rand((128, 64), 20 + i, 2.0) for i in range(4))
        tr = ref.pseudo_quant_trace(q, k, v, do)
        fi = ref.fpa_bwd(q, k, v, do)

        def rel(a, b):
            return float(jnp.linalg.norm(a - b) / jnp.linalg.norm(b))

        err_o, err_dv = rel(tr.o, fi.o), rel(tr.dv, fi.dv)
        err_ds = rel(tr.ds, fi.ds)
        err_dq, err_dk = rel(tr.dq, fi.dq), rel(tr.dk, fi.dk)
        assert err_ds > err_o and err_ds > err_dv
        assert err_dq > err_o and err_dk > err_o

    def test_smoothing_flags_change_trace(self):
        q = _rand((64, 32), 30)
        k = _rand((64, 32), 31) + 3.0  # strong K mean → smoothing matters
        v, do = _rand((64, 32), 32), _rand((64, 32), 33)
        fi = ref.fpa_bwd(q, k, v, do)
        err_nosm = float(jnp.linalg.norm(
            ref.pseudo_quant_trace(q, k, v, do, k_smoothing=False).o - fi.o))
        err_ksm = float(jnp.linalg.norm(
            ref.pseudo_quant_trace(q, k, v, do, k_smoothing=True).o - fi.o))
        assert err_ksm < err_nosm


class TestSageRefInternalConsistency:
    @given(st.sampled_from([16, 32]), st.booleans())
    @settings(max_examples=8, deadline=None)
    def test_fwd_close_to_fpa_at_sigma1(self, block, causal):
        q, k, v = (_rand((64, 32), 40 + i) for i in range(3))
        o, lse, _ = ref.sage_ref_fwd(q, k, v, block, block, causal=causal)
        o_f, (_, _, lse_f) = ref.fpa_fwd(q, k, v, causal=causal)
        assert float(jnp.max(jnp.abs(o - o_f))) < 0.05
        # LSE absorbs the raw INT8 logit error (|dS| ≈ δ_Q·δ_K·d), which is
        # larger than the output error because softmax renormalizes.
        assert float(jnp.max(jnp.abs(lse - lse_f))) < 0.5

    def test_bwd_blocks_independent_of_block_size(self):
        # Different tilings quantize differently, but must agree loosely.
        q, k, v, do = (_rand((64, 16), 50 + i) for i in range(4))
        a = ref.sage_ref_bwd(q, k, v, do, 16, 16)
        b = ref.sage_ref_bwd(q, k, v, do, 32, 32)
        rel = float(jnp.linalg.norm(a.dq - b.dq) / jnp.linalg.norm(b.dq))
        assert rel < 0.1
