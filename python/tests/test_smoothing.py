"""Tests for Q/K-smoothing identities (paper §3 and §6)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, smoothing

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed=0, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


class TestKSmooth:
    def test_mean_zero(self):
        k_sm, mu = smoothing.k_smooth(_rand((64, 16), 1))
        np.testing.assert_allclose(np.asarray(jnp.mean(k_sm, axis=0)),
                                   np.zeros(16), atol=1e-6)

    def test_softmax_invariance(self):
        # softmax(Q K^T) == softmax(Q K_sm^T): the dropped rank-1 term is
        # constant along each row (paper §3 "the additive bias term vanishes").
        q, k = _rand((32, 16), 2), _rand((48, 16), 3) + 2.0
        k_sm, _ = smoothing.k_smooth(k)
        p1 = jax.nn.softmax(q @ k.T, axis=-1)
        p2 = jax.nn.softmax(q @ k_sm.T, axis=-1)
        np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), atol=1e-5)

    @given(st.integers(0, 500))
    @settings(max_examples=20, deadline=None)
    def test_attention_output_invariant(self, seed):
        q, k, v = _rand((16, 8), seed), _rand((16, 8), seed + 1) + 1.5, _rand((16, 8), seed + 2)
        k_sm, _ = smoothing.k_smooth(k)
        o1, _ = ref.fpa_fwd(q, k, v)
        o2, _ = ref.fpa_fwd(q, k_sm, v)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


class TestDsRowSumZero:
    """§6: every row of dS sums to 0 — the reason dQ = dS·K_sm is exact."""

    @given(st.integers(0, 500))
    @settings(max_examples=15, deadline=None)
    def test_rows_sum_zero(self, seed):
        q, k, v, do = (_rand((24, 8), seed + i) for i in range(4))
        it = ref.fpa_bwd(q, k, v, do)
        rowsums = jnp.sum(it.ds, axis=-1)
        np.testing.assert_allclose(np.asarray(rowsums), np.zeros(24), atol=1e-5)

    def test_dq_invariant_to_k_mean(self):
        # dQ = dS K == dS K_sm exactly (up to fp) because rowsum(dS)=0.
        q, k, v, do = (_rand((32, 16), 10 + i) for i in range(4))
        it = ref.fpa_bwd(q, k, v, do)
        k_sm, _ = smoothing.k_smooth(k)
        dq_sm = (it.ds @ k_sm) / jnp.sqrt(16.0)
        np.testing.assert_allclose(np.asarray(it.dq), np.asarray(dq_sm),
                                   atol=1e-5)


class TestQSmoothing:
    def test_logits_decomposition(self):
        # S = Q_sm K^T + 1·(μ_Q K^T) exactly (paper §6 rewrite).
        q, k = _rand((32, 16), 20), _rand((40, 16), 21)
        q_sm, mu_q = smoothing.q_smooth(q)
        s_direct = q @ k.T
        s_recon = q_sm @ k.T + smoothing.qk_logits_bias(mu_q, k)
        np.testing.assert_allclose(np.asarray(s_direct), np.asarray(s_recon),
                                   atol=1e-4)

    @given(st.integers(0, 500))
    @settings(max_examples=15, deadline=None)
    def test_dk_bias_branch_recovers_full_gradient(self, seed):
        # dK = dS^T Q_sm + (dS^T 1) μ_Q^T   must equal   dS^T Q  (§6).
        q, k, v, do = (_rand((24, 8), seed + 7 * i) for i in range(4))
        it = ref.fpa_bwd(q, k, v, do)
        q_sm, mu_q = smoothing.q_smooth(q)
        dk_center = it.ds.T @ q_sm
        dk_full = dk_center + smoothing.dk_bias_branch(it.ds, mu_q)
        np.testing.assert_allclose(np.asarray(it.ds.T @ q),
                                   np.asarray(dk_full), atol=1e-4)

    def test_center_branch_alone_is_wrong(self):
        # The paper's point: dK ≠ dS^T Q_sm when μ_Q ≠ 0.
        q = _rand((32, 16), 30) + 1.0  # nonzero mean
        k, v, do = (_rand((32, 16), 31 + i) for i in range(3))
        it = ref.fpa_bwd(q, k, v, do)
        q_sm, _ = smoothing.q_smooth(q)
        dk_center = (it.ds.T @ q_sm) / jnp.sqrt(16.0)
        err = float(jnp.linalg.norm(dk_center - it.dk) / jnp.linalg.norm(it.dk))
        assert err > 0.01
