//! **Figures 2–3 bench**: SageBwd vs FA2-style vs naive SDPA kernel
//! throughput across head dims {64, 128} and sequence lengths, forward and
//! forward+backward — plus the analytic tensor-core model (see
//! `experiments::fig23_speed` for why both readings are reported).
//!
//! Run with `cargo bench --bench bench_attention` (or `make bench`).

use sagebwd::experiments::fig23_speed;
use sagebwd::runtime::Runtime;

fn main() {
    let mut rt = match Runtime::new(sagebwd::DEFAULT_ARTIFACTS_DIR) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("SKIP bench_attention: {e:#} (run `make artifacts`)");
            return;
        }
    };
    let quick = std::env::var("BENCH_QUICK").is_ok();
    fig23_speed::run(&mut rt, sagebwd::DEFAULT_RESULTS_DIR, quick)
        .expect("fig23 bench failed");
}
