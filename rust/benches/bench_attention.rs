//! **Figures 2–3 bench + compute-engine bench**, with the machine-readable
//! perf trajectory (DESIGN.md §11).
//!
//! Two sections:
//!
//! 1. **Engine rows** — serial-naive vs blocked vs parallel for the three
//!    f32 GEMM layouts attention uses (`A·Bᵀ`, `A·B`, `Aᵀ·B`) and the
//!    i8×i8→i32 GEMM, at the attention shapes (default n=1024, d=64;
//!    `BENCH_QUICK=1` shrinks to n=256).  The acceptance bar tracked from
//!    this PR onward: blocked+parallel ≥3× naive at n=1024/d=64 with 4
//!    threads.
//! 2. **Kernel rows** — SageBwd vs FA2-style vs naive SDPA throughput
//!    across head dims and sequence lengths, forward and forward+backward
//!    (see `experiments::fig23_speed` for the modeled/measured split).
//!
//! Every run *appends* to `BENCH_attention.json` (schema-checked after
//! writing), so the perf trajectory persists across PRs.
//!
//! Runs on the native CPU kernels by default (no artifacts needed); set
//! `BENCH_BACKEND=xla` to time the AOT executables instead, and
//! `SAGEBWD_THREADS=N` to pin the engine's worker count.
//!
//! Run with `cargo bench --bench bench_attention` (or `make bench`).

use std::path::Path;

use sagebwd::bench::{
    append_bench_json, check_bench_json, run as bench_run, BenchConfig, BenchRow, Measurement,
    Table,
};
use sagebwd::experiments::fig23_speed;
use sagebwd::kernels::quant;
use sagebwd::runtime::make_backend;
use sagebwd::tensor::linalg;
use sagebwd::tensor::simd::{self, IsaTier};
use sagebwd::util::rng::Pcg64;

const BENCH_JSON: &str = "BENCH_attention.json";

fn randv(len: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg64::new(seed, 0xBE);
    let mut v = vec![0f32; len];
    rng.fill_gaussian(&mut v, 1.0);
    v
}

/// `quant::int8_gemm`'s exact loop structure, minus its per-call output
/// allocation — the comparable serial-naive baseline (checked against the
/// allocating original once at startup).
fn naive_int8_gemm(a: &[i8], b: &[i8], m: usize, k: usize, n: usize, out: &mut [i32]) {
    out.fill(0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let acc = &mut out[i * n..(i + 1) * n];
        for (t, &av) in arow.iter().enumerate() {
            let av = av as i32;
            let brow = &b[t * n..(t + 1) * n];
            for (o, &bv) in acc.iter_mut().zip(brow) {
                *o += av * bv as i32;
            }
        }
    }
}

struct Ctx {
    table: Table,
    rows: Vec<BenchRow>,
}

impl Ctx {
    /// Record one engine row.  `tokens_per_s` is always `None` here — raw
    /// GEMMs have no token count; the fig23 kernel rows (which do) are
    /// pushed directly.
    fn record(&mut self, op: &str, shape: &str, variant: &str, threads: usize, isa: &str, m: &Measurement) {
        let ns = m.mean() * 1e9;
        self.table.row(vec![
            op.to_string(),
            shape.to_string(),
            variant.to_string(),
            threads.to_string(),
            isa.to_string(),
            format!("{ns:.0}"),
            "-".into(),
        ]);
        self.rows.push(BenchRow {
            op: op.to_string(),
            shape: shape.to_string(),
            variant: variant.to_string(),
            threads,
            isa: isa.to_string(),
            ns_per_iter: ns,
            tokens_per_s: None,
        });
    }
}

/// ISA tiers this machine can bench: always scalar, plus avx2/fma when
/// detected — the rows the ROADMAP "SIMD ≥2× blocked-scalar" target
/// reads (the `isa` column keys them apart in the trajectory).
fn bench_tiers() -> Vec<IsaTier> {
    [IsaTier::Scalar, IsaTier::Avx2, IsaTier::Fma]
        .into_iter()
        .filter(|&t| t <= simd::hw_tier())
        .collect()
}

/// naive rows (once, scalar by construction), then blocked / parallel
/// rows per available ISA tier; returns (naive, best-parallel) mean
/// seconds for the speedup summary.
#[allow(clippy::too_many_arguments)]
fn engine_op(
    ctx: &mut Ctx,
    cfg: BenchConfig,
    op: &str,
    shape: &str,
    threads: usize,
    mut naive: impl FnMut(),
    mut blocked: impl FnMut(),
    mut parallel: impl FnMut(),
) -> (f64, f64) {
    let mn = bench_run(cfg, &format!("{op}_naive"), &mut naive);
    ctx.record(op, shape, "naive", 1, "scalar", &mn);
    let mut best_par = f64::INFINITY;
    for tier in bench_tiers() {
        let isa = tier.as_str();
        let mb = simd::with_isa(tier, || bench_run(cfg, &format!("{op}_blocked_{isa}"), &mut blocked));
        ctx.record(op, shape, "blocked", 1, isa, &mb);
        let mp = simd::with_isa(tier, || bench_run(cfg, &format!("{op}_parallel_{isa}"), &mut parallel));
        ctx.record(op, shape, "parallel", threads, isa, &mp);
        best_par = best_par.min(mp.mean());
    }
    (mn.mean(), best_par)
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let backend_name = std::env::var("BENCH_BACKEND").unwrap_or_else(|_| "native".to_string());
    let threads = linalg::thread_count();
    let cfg = if quick {
        BenchConfig { warmup_iters: 1, iters: 3, max_secs: 3.0 }
    } else {
        BenchConfig { warmup_iters: 2, iters: 10, max_secs: 20.0 }
    };
    let (n, d) = if quick { (256usize, 64usize) } else { (1024, 64) };

    let mut ctx = Ctx {
        table: Table::new(&["op", "shape", "variant", "threads", "isa", "ns_per_iter", "tokens_per_s"]),
        rows: Vec::new(),
    };

    // ---- Section 1: compute-engine GEMMs at attention shapes ----
    // Each variant gets its own output buffer so the three timed closures
    // can coexist as arguments.
    println!("compute engine: serial-naive vs blocked vs parallel ({threads} threads)\n");
    let a_nd = randv(n * d, 1);
    let b_nd = randv(n * d, 2);
    let a_nn = randv(n * n, 3);

    // black_box on every output keeps release-mode dead-store elimination
    // from hollowing out the timed kernels.
    use std::hint::black_box;

    // Q·Kᵀ: (n,d) × (n,d)ᵀ → (n,n).  Pack scratch is hoisted out of the
    // timed closures (the production paths pool it too — timing a fresh
    // allocation per iter would understate the engine).
    let shape_nt = format!("m{n}_k{d}_n{n}");
    let (mut o1, mut o2, mut o3) = (vec![0f32; n * n], vec![0f32; n * n], vec![0f32; n * n]);
    let (mut pk2, mut pk3) = (Vec::new(), Vec::new());
    let (base_nt, par_nt) = engine_op(
        &mut ctx, cfg, "matmul_nt", &shape_nt, threads,
        || { linalg::naive_matmul_nt(&a_nd, &b_nd, n, d, n, &mut o1); black_box(&o1); },
        || { linalg::matmul_nt_scratch(&a_nd, &b_nd, n, d, n, &mut o2, 1, &mut pk2); black_box(&o2); },
        || { linalg::matmul_nt_scratch(&a_nd, &b_nd, n, d, n, &mut o3, threads, &mut pk3); black_box(&o3); },
    );

    // P·V: (n,n) × (n,d) → (n,d)
    let shape_nn = format!("m{n}_k{n}_n{d}");
    let (mut o1, mut o2, mut o3) = (vec![0f32; n * d], vec![0f32; n * d], vec![0f32; n * d]);
    let (base_nn, par_nn) = engine_op(
        &mut ctx, cfg, "matmul_nn", &shape_nn, threads,
        || { linalg::naive_matmul(&a_nn, &b_nd, n, n, d, &mut o1); black_box(&o1); },
        || { linalg::gemm_nn(&a_nn, &b_nd, n, n, d, &mut o2); black_box(&o2); },
        || { linalg::matmul_threads(&a_nn, &b_nd, n, n, d, &mut o3, threads); black_box(&o3); },
    );

    // Pᵀ·dO: (n,n)ᵀ-layout × (n,d) → (n,d)
    let shape_tn = format!("m{n}_k{n}_n{d}");
    let (mut o1, mut o2, mut o3) = (vec![0f32; n * d], vec![0f32; n * d], vec![0f32; n * d]);
    let (mut pk2, mut pk3) = (Vec::new(), Vec::new());
    let (base_tn, par_tn) = engine_op(
        &mut ctx, cfg, "matmul_tn", &shape_tn, threads,
        || { linalg::naive_matmul_tn(&a_nn, &b_nd, n, n, d, &mut o1); black_box(&o1); },
        || { linalg::matmul_tn_scratch(&a_nn, &b_nd, n, n, d, &mut o2, 1, &mut pk2); black_box(&o2); },
        || { linalg::matmul_tn_scratch(&a_nn, &b_nd, n, n, d, &mut o3, threads, &mut pk3); black_box(&o3); },
    );

    // ψ(P)·ψ(V): i8 (n,n) × (n,d) → i32 (n,d).  The naive row uses the
    // same loop structure as `quant::int8_gemm` but writes a preallocated
    // buffer, so all three variants exclude allocator time alike.
    let qa: Vec<i8> = (0..n * n).map(|i| (i as i32 * 37 % 255 - 127) as i8).collect();
    let qb: Vec<i8> = (0..n * d).map(|i| (i as i32 * 91 % 255 - 127) as i8).collect();
    let (mut i0, mut i1, mut i2) = (vec![0i32; n * d], vec![0i32; n * d], vec![0i32; n * d]);
    {
        let want = quant::int8_gemm(&qa, &qb, n, n, d);
        naive_int8_gemm(&qa, &qb, n, n, d, &mut i0);
        assert_eq!(want, i0, "naive int8 twin drifted from quant::int8_gemm");
    }
    let (base_i8, par_i8) = engine_op(
        &mut ctx, cfg, "int8_gemm_nn", &shape_nn, threads,
        || { naive_int8_gemm(&qa, &qb, n, n, d, &mut i0); black_box(&i0); },
        || { linalg::int8_gemm_nn(&qa, &qb, n, n, d, &mut i1); black_box(&i1); },
        || { linalg::int8_gemm_nn_threads(&qa, &qb, n, n, d, &mut i2, threads); black_box(&i2); },
    );

    // ---- Section 2: attention kernel throughput (Figures 2–3) ----
    // A backend failure (e.g. BENCH_BACKEND=xla without artifacts) skips
    // only this section — the engine rows above still reach the
    // trajectory file.
    match make_backend(&backend_name, sagebwd::DEFAULT_ARTIFACTS_DIR) {
        Ok(mut be) => {
            let rows23 = fig23_speed::run(be.as_mut(), sagebwd::DEFAULT_RESULTS_DIR, quick)
                .expect("fig23 bench failed");
            for r in &rows23 {
                ctx.rows.push(BenchRow {
                    op: format!("attention_{}_{}", r.impl_name, r.mode),
                    shape: format!("n{}_d{}", r.n, r.d),
                    variant: r.impl_name.clone(),
                    threads: r.threads,
                    isa: simd::active_tier().as_str().to_string(),
                    ns_per_iter: r.measured_ms * 1e6,
                    tokens_per_s: Some(r.n as f64 / (r.measured_ms / 1e3)),
                });
            }
        }
        Err(e) => {
            eprintln!("SKIP kernel section: {e:#} (run `make artifacts` for BENCH_BACKEND=xla)");
        }
    }

    println!("{}", ctx.table.render());
    for (op, base, par) in [
        ("matmul_nt", base_nt, par_nt),
        ("matmul_nn", base_nn, par_nn),
        ("matmul_tn", base_tn, par_tn),
        ("int8_gemm_nn", base_i8, par_i8),
    ] {
        println!("{op}: best blocked+parallel speedup vs naive = {:.2}x", base / par);
    }

    let path = Path::new(BENCH_JSON);
    append_bench_json(path, "attention", threads, &ctx.rows).expect("appending BENCH_attention.json");
    let count = check_bench_json(path).expect("BENCH_attention.json schema check");
    println!("\n{BENCH_JSON}: schema OK ({count} rows across all runs)");
    record_trajectory_snapshot("attention", path);
}

/// Snapshot the appended trajectory into the run registry: the file stays
/// where CI expects it and its current bytes get a content address.
fn record_trajectory_snapshot(bench: &str, path: &Path) {
    use sagebwd::registry::{Registry, RunState};
    use sagebwd::util::json::Json;
    let snapshot = || -> anyhow::Result<String> {
        let registry = Registry::open(sagebwd::DEFAULT_RESULTS_DIR)?;
        let config = Json::from_pairs(vec![
            ("bench", Json::from(bench)),
            ("kind", Json::from("bench-trajectory")),
        ]);
        let mut run = registry.begin_run("bench", bench, config)?;
        let hash = run.record_file(&format!("BENCH_{bench}.json"), path)?;
        run.finish(RunState::Complete)?;
        Ok(hash)
    };
    match snapshot() {
        Ok(hash) => println!("registry: trajectory snapshot sha256 {}", &hash[..16]),
        Err(e) => eprintln!("registry snapshot skipped: {e:#}"),
    }
}
