//! **Figures 2–3 bench**: SageBwd vs FA2-style vs naive SDPA kernel
//! throughput across head dims {64, 128} and sequence lengths, forward and
//! forward+backward — plus the analytic tensor-core model (see
//! `experiments::fig23_speed` for why both readings are reported).
//!
//! Runs on the native CPU kernels by default (no artifacts needed); set
//! `BENCH_BACKEND=xla` to time the AOT executables instead.
//!
//! Run with `cargo bench --bench bench_attention` (or `make bench`).

use sagebwd::experiments::fig23_speed;
use sagebwd::runtime::make_backend;

fn main() {
    let backend_name = std::env::var("BENCH_BACKEND").unwrap_or_else(|_| "native".to_string());
    let mut be = match make_backend(&backend_name, sagebwd::DEFAULT_ARTIFACTS_DIR) {
        Ok(be) => be,
        Err(e) => {
            eprintln!("SKIP bench_attention: {e:#} (run `make artifacts` for BENCH_BACKEND=xla)");
            return;
        }
    };
    let quick = std::env::var("BENCH_QUICK").is_ok();
    fig23_speed::run(be.as_mut(), sagebwd::DEFAULT_RESULTS_DIR, quick)
        .expect("fig23 bench failed");
}
