//! Coordinator-overhead bench: how much wallclock Layer 3 adds on top of
//! raw executable time (accumulation, literal conversion, batching,
//! metrics).  Target (DESIGN.md §8): < 5% overhead — the coordinator must
//! never be the bottleneck since the paper's contribution is the kernel.

use std::time::Instant;

use sagebwd::bench::Table;
use sagebwd::config::TrainConfig;
use sagebwd::coordinator::Trainer;
use sagebwd::runtime::{Runtime, Value};
use sagebwd::tensor::IntTensor;
use sagebwd::util::rng::Pcg64;

fn main() {
    let dir = sagebwd::DEFAULT_ARTIFACTS_DIR;
    let Ok(mut rt) = Runtime::new(dir) else {
        eprintln!("SKIP bench_coordinator (run `make artifacts`)");
        return;
    };

    // Raw executable time: grad_step alone, inputs pre-built.
    let variant = "sage_qknorm";
    let params = rt
        .execute(&format!("init_{variant}"), &[Value::scalar_i32(0)])
        .expect("init");
    let exe = rt.load(&format!("grad_step_{variant}")).expect("grad");
    let spec = exe.manifest.input("tokens").expect("tokens");
    let (b, n) = (spec.shape[0], spec.shape[1]);
    let mut rng = Pcg64::new(0, 2);
    let tok: Vec<i32> = (0..b * n).map(|_| rng.below(256) as i32).collect();
    let mut inputs = params.clone();
    inputs.push(Value::I32(IntTensor::from_vec(&[b, n], tok.clone()).unwrap()));
    inputs.push(Value::I32(IntTensor::from_vec(&[b, n], tok).unwrap()));

    let micro_per_step = 4u64;
    let steps = 3u64;
    // Raw floor: cached device buffers (same hot path the trainer uses),
    // reading back only the outputs — grad_step execution and readback,
    // nothing else.
    let in_bufs: Vec<xla::PjRtBuffer> = inputs
        .iter()
        .map(|v| exe.buffer_from_literal(&v.to_literal().unwrap()).unwrap())
        .collect();
    let in_refs: Vec<&xla::PjRtBuffer> = in_bufs.iter().collect();
    exe.execute_buffers(&in_refs).expect("warmup");
    let t0 = Instant::now();
    for _ in 0..steps * micro_per_step {
        exe.execute_buffers(&in_refs).expect("grad");
    }
    let raw_secs = t0.elapsed().as_secs_f64();

    // Full coordinator path: same number of grad_steps + apply + data.
    let cfg = TrainConfig {
        variant: variant.into(),
        steps,
        tokens_per_step: micro_per_step * (b * n) as u64,
        warmup_steps: 1,
        peak_lr: 1e-3,
        min_lr_frac: 0.1,
        seed: 0,
        checkpoint_every: 0,
        log_every: 0,
        clip_norm: 0.0,
        grad_noise_sigma: 0.0,
        ..TrainConfig::default()
    };
    let mut trainer =
        Trainer::new(Runtime::new(dir).expect("runtime"), cfg).expect("trainer");
    let mut batches = trainer.make_byte_batcher(4);
    trainer.train_step(&mut batches).expect("warm step");
    let t0 = Instant::now();
    for _ in 0..steps {
        trainer.train_step(&mut batches).expect("step");
    }
    let full_secs = t0.elapsed().as_secs_f64();

    // The coordinated path runs `steps` extra apply_steps; measure one.
    let overhead = (full_secs - raw_secs) / raw_secs * 100.0;
    let mut table = Table::new(&["metric", "value"]);
    table.row(vec![
        format!("raw grad_step × {}", steps * micro_per_step),
        format!("{raw_secs:.3}s"),
    ]);
    table.row(vec![
        format!("coordinator {steps} steps (incl. apply+data+metrics)"),
        format!("{full_secs:.3}s"),
    ]);
    table.row(vec!["L3 overhead vs raw".into(), format!("{overhead:.1}%")]);
    println!("{}", table.render());
    std::fs::create_dir_all(sagebwd::DEFAULT_RESULTS_DIR).ok();
    std::fs::write(
        format!("{}/bench_coordinator.csv", sagebwd::DEFAULT_RESULTS_DIR),
        table.to_csv(),
    )
    .ok();
}
