//! Data-pipeline bench: corpus synthesis, BPE training, encode throughput,
//! and batcher (sync vs prefetch) — verifies the pipeline sustains far
//! more tokens/sec than the trainer consumes.

use sagebwd::bench::{run as bench_run, BenchConfig, Table};
use sagebwd::data::{Batcher, Corpus, PrefetchBatcher, Tokenizer};

fn main() {
    let cfg = BenchConfig {
        warmup_iters: 1,
        iters: 10,
        max_secs: 20.0,
    };
    let mut table = Table::new(&["stage", "mean_ms", "throughput"]);

    // Corpus synthesis.
    let m = bench_run(cfg, "corpus_64kb", || {
        let mut c = Corpus::new(0, 0);
        let mut s = String::new();
        c.fill_text(&mut s, 65_536);
    });
    table.row(vec![
        "corpus synth (64 KiB)".into(),
        format!("{:.2}", m.mean() * 1e3),
        format!("{:.1} MiB/s", 65_536.0 / m.mean() / 1e6),
    ]);

    // Tokenizer training (one-off cost at trainer startup).
    let mut sample = String::new();
    Corpus::new(0, u64::MAX).fill_text(&mut sample, 200_000);
    let m = bench_run(
        BenchConfig { warmup_iters: 0, iters: 3, max_secs: 60.0 },
        "bpe_train",
        || {
            Tokenizer::train(&sample, 512).expect("train");
        },
    );
    table.row(vec![
        "BPE train (200 KB, 256 merges)".into(),
        format!("{:.0}", m.mean() * 1e3),
        "-".into(),
    ]);

    // Encode throughput.
    let tok = Tokenizer::train(&sample, 512).expect("train");
    let probe = &sample[..65_536];
    let m = bench_run(cfg, "bpe_encode", || {
        tok.encode(probe);
    });
    table.row(vec![
        "BPE encode (64 KiB)".into(),
        format!("{:.2}", m.mean() * 1e3),
        format!("{:.1} MiB/s", 65_536.0 / m.mean() / 1e6),
    ]);

    // Batcher: sync vs prefetch.
    let mut sync = Batcher::new(tok.clone(), 0, 0, 2, 128);
    let m = bench_run(cfg, "batcher_sync", || {
        for _ in 0..16 {
            sync.next_batch().expect("batch");
        }
    });
    let tokens = (16 * 2 * 128) as f64;
    table.row(vec![
        "batcher sync (16 microbatches)".into(),
        format!("{:.2}", m.mean() * 1e3),
        format!("{:.0} tok/s", tokens / m.mean()),
    ]);

    let mut pre = PrefetchBatcher::spawn(Batcher::new(tok.clone(), 0, 1, 2, 128), 8);
    let m = bench_run(cfg, "batcher_prefetch", || {
        for _ in 0..16 {
            pre.next_batch().expect("batch");
        }
    });
    table.row(vec![
        "batcher prefetch (16 microbatches)".into(),
        format!("{:.2}", m.mean() * 1e3),
        format!("{:.0} tok/s", tokens / m.mean()),
    ]);

    println!("{}", table.render());
    std::fs::create_dir_all(sagebwd::DEFAULT_RESULTS_DIR).ok();
    std::fs::write(
        format!("{}/bench_data_pipeline.csv", sagebwd::DEFAULT_RESULTS_DIR),
        table.to_csv(),
    )
    .ok();
}
