//! Train-step bench: wallclock of one `grad_step` microbatch and one
//! `apply_step` for the sage and fpa variants — the end-to-end numbers
//! behind the Figure-1 experiment budget, and the baseline for the
//! EXPERIMENTS.md §Perf iteration log.

use sagebwd::bench::{run as bench_run, BenchConfig, Table};
use sagebwd::runtime::{Runtime, Value};
use sagebwd::tensor::{IntTensor, Tensor};
use sagebwd::util::rng::Pcg64;

fn main() {
    let mut rt = match Runtime::new(sagebwd::DEFAULT_ARTIFACTS_DIR) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("SKIP bench_train_step: {e:#} (run `make artifacts`)");
            return;
        }
    };
    let cfg = BenchConfig {
        warmup_iters: 1,
        iters: 8,
        max_secs: 30.0,
    };
    let mut table = Table::new(&["artifact", "mean_ms", "p50_ms", "p95_ms", "tokens_per_sec"]);

    for variant in ["sage_qknorm", "fpa_qknorm"] {
        let params = rt
            .execute(&format!("init_{variant}"), &[Value::scalar_i32(0)])
            .expect("init failed");
        let grad_name = format!("grad_step_{variant}");
        let exe = rt.load(&grad_name).expect("loading grad_step");
        let tok_spec = exe.manifest.input("tokens").expect("tokens input");
        let (b, n) = (tok_spec.shape[0], tok_spec.shape[1]);
        let mut rng = Pcg64::new(0, 1);
        let tokens: Vec<i32> = (0..b * n).map(|_| rng.below(256) as i32).collect();
        let mut inputs = params.clone();
        inputs.push(Value::I32(IntTensor::from_vec(&[b, n], tokens.clone()).unwrap()));
        inputs.push(Value::I32(IntTensor::from_vec(&[b, n], tokens).unwrap()));
        let m = bench_run(cfg, &grad_name, || {
            exe.execute(&inputs).expect("grad_step failed");
        });
        table.row(vec![
            format!("{grad_name} (upload-per-call)"),
            format!("{:.2}", m.mean() * 1e3),
            format!("{:.2}", m.p50() * 1e3),
            format!("{:.2}", m.p95() * 1e3),
            format!("{:.0}", (b * n) as f64 / m.mean()),
        ]);

        // Trainer hot path: params cached as device buffers (§Perf opt 2).
        let bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|v| exe.buffer_from_literal(&v.to_literal().unwrap()).unwrap())
            .collect();
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let mc = bench_run(cfg, &grad_name, || {
            exe.execute_buffers(&refs).expect("grad_step failed");
        });
        table.row(vec![
            format!("{grad_name} (cached buffers)"),
            format!("{:.2}", mc.mean() * 1e3),
            format!("{:.2}", mc.p50() * 1e3),
            format!("{:.2}", mc.p95() * 1e3),
            format!("{:.0}", (b * n) as f64 / mc.mean()),
        ]);

        // apply_step for this tree.
        let apply_name = if variant.contains("noqknorm") {
            "apply_step_noqknorm"
        } else {
            "apply_step_qknorm"
        };
        let np = params.len();
        let zeros: Vec<Value> = params
            .iter()
            .map(|p| Value::F32(Tensor::zeros(p.shape())))
            .collect();
        let mut ainputs = Vec::with_capacity(4 * np + 2);
        ainputs.extend(params.iter().cloned());
        ainputs.extend(zeros.iter().cloned());
        ainputs.extend(zeros.iter().cloned());
        ainputs.extend(zeros.iter().cloned());
        ainputs.push(Value::scalar_f32(1e-3));
        ainputs.push(Value::scalar_i32(1));
        let aexe = rt.load(apply_name).expect("loading apply_step");
        let ma = bench_run(cfg, apply_name, || {
            aexe.execute(&ainputs).expect("apply_step failed");
        });
        table.row(vec![
            format!("{apply_name} ({variant})"),
            format!("{:.2}", ma.mean() * 1e3),
            format!("{:.2}", ma.p50() * 1e3),
            format!("{:.2}", ma.p95() * 1e3),
            "-".into(),
        ]);
    }
    println!("{}", table.render());
    std::fs::create_dir_all(sagebwd::DEFAULT_RESULTS_DIR).ok();
    std::fs::write(
        format!("{}/bench_train_step.csv", sagebwd::DEFAULT_RESULTS_DIR),
        table.to_csv(),
    )
    .ok();
}
