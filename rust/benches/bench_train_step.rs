//! Train-step bench: wallclock of one `grad_step` microbatch and one
//! `apply_step` — the end-to-end numbers behind the Figure-1 experiment
//! budget, and the baseline for the perf trajectory in
//! `BENCH_train_step.json` (appended every run, schema-checked after
//! writing — DESIGN.md §11).
//!
//! Default: the **native** engine (no artifacts needed), timed at
//! `SAGEBWD_THREADS=1` (serial) and at the default thread count
//! (head-parallel attention + row-partitioned GEMMs), for the sage and
//! fpa variants.  Set `BENCH_BACKEND=xla` for the original AOT artifact
//! path (requires `make artifacts`).

use std::path::Path;

use sagebwd::bench::{
    append_bench_json, check_bench_json, run as bench_run, BenchConfig, BenchRow, Table,
};
use sagebwd::config::TrainConfig;
use sagebwd::coordinator::engine::{NativeEngine, TrainEngine};
use sagebwd::data::{Batcher, Tokenizer};
use sagebwd::model::ModelDims;
use sagebwd::tensor::linalg;
use sagebwd::tensor::simd;

const BENCH_JSON: &str = "BENCH_train_step.json";

fn main() {
    if std::env::var("BENCH_BACKEND").as_deref() == Ok("xla") {
        xla_main();
        return;
    }
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let cfg = if quick {
        BenchConfig { warmup_iters: 1, iters: 3, max_secs: 5.0 }
    } else {
        BenchConfig { warmup_iters: 1, iters: 8, max_secs: 30.0 }
    };
    // quick: the default toy dims; full: a model whose per-layer head
    // batch crosses the engine's fan-out gate, so the threads=N rows
    // really measure the parallel path.
    let dims = if quick {
        ModelDims::default()
    } else {
        ModelDims {
            d_model: 64,
            n_heads: 4,
            d_head: 16,
            d_ff: 128,
            seq_len: 256,
            ..ModelDims::default()
        }
    };
    let default_threads = linalg::thread_count();
    // Only emit multi-thread rows when the head batch actually engages
    // the fan-out — otherwise threads=N would mislabel serial timings in
    // the persisted trajectory.
    let head_volume =
        dims.microbatch * dims.n_heads * dims.seq_len * dims.seq_len * dims.d_head;
    let thread_settings: Vec<usize> =
        if default_threads > 1 && head_volume >= linalg::PAR_MIN_BATCH_VOLUME {
            vec![1, default_threads]
        } else {
            vec![1]
        };
    let mut table = Table::new(&["op", "variant", "shape", "threads", "mean_ms", "tokens_per_sec"]);
    let mut rows: Vec<BenchRow> = Vec::new();
    for variant in ["sage_qknorm", "fpa_qknorm"] {
        for &threads in &thread_settings {
            // Panic-safe RAII pin (restores the caller's setting on drop).
            let _pin = linalg::pin_threads(threads);
            let tcfg = TrainConfig {
                variant: variant.into(),
                steps: 2,
                tokens_per_step: 128,
                warmup_steps: 1,
                ..TrainConfig::default()
            };
            let mut engine =
                NativeEngine::with_dims(&tcfg, dims).expect("building native engine");
            let (b, nseq) = engine.microbatch_shape();
            let mut batcher = Batcher::new(Tokenizer::bytes_only(), 7, 0, b, nseq);
            let batch = batcher.next_batch().expect("drawing batch");
            let shape = format!("b{b}_n{nseq}");
            let tokens = (b * nseq) as f64;

            let mg = bench_run(cfg, &format!("grad_step_{variant}_t{threads}"), || {
                engine.grad_microbatch(&batch).expect("grad_microbatch failed");
            });
            table.row(vec![
                "grad_step".into(),
                variant.into(),
                shape.clone(),
                threads.to_string(),
                format!("{:.2}", mg.mean() * 1e3),
                format!("{:.0}", tokens / mg.mean()),
            ]);
            rows.push(BenchRow {
                op: "grad_step".into(),
                shape: shape.clone(),
                variant: variant.into(),
                threads,
                isa: simd::active_tier().as_str().to_string(),
                ns_per_iter: mg.mean() * 1e9,
                tokens_per_s: Some(tokens / mg.mean()),
            });

            let stats = engine.grad_microbatch(&batch).expect("grad_microbatch failed");
            let ma = bench_run(cfg, &format!("apply_step_{variant}_t{threads}"), || {
                engine.apply(&stats.grads, 1e-3, 1).expect("apply failed");
            });
            table.row(vec![
                "apply_step".into(),
                variant.into(),
                shape.clone(),
                threads.to_string(),
                format!("{:.2}", ma.mean() * 1e3),
                "-".into(),
            ]);
            rows.push(BenchRow {
                op: "apply_step".into(),
                shape,
                variant: variant.into(),
                threads,
                isa: simd::active_tier().as_str().to_string(),
                ns_per_iter: ma.mean() * 1e9,
                tokens_per_s: None,
            });
        }
    }

    println!("{}", table.render());
    std::fs::create_dir_all(sagebwd::DEFAULT_RESULTS_DIR).ok();
    std::fs::write(
        format!("{}/bench_train_step.csv", sagebwd::DEFAULT_RESULTS_DIR),
        table.to_csv(),
    )
    .ok();
    let path = Path::new(BENCH_JSON);
    append_bench_json(path, "train_step", default_threads, &rows)
        .expect("appending BENCH_train_step.json");
    let count = check_bench_json(path).expect("BENCH_train_step.json schema check");
    println!("{BENCH_JSON}: schema OK ({count} rows across all runs)");
    record_trajectory_snapshot("train_step", path);
}

/// Snapshot the appended trajectory into the run registry: the file stays
/// where CI expects it and its current bytes get a content address.
fn record_trajectory_snapshot(bench: &str, path: &Path) {
    use sagebwd::registry::{Registry, RunState};
    use sagebwd::util::json::Json;
    let snapshot = || -> anyhow::Result<String> {
        let registry = Registry::open(sagebwd::DEFAULT_RESULTS_DIR)?;
        let config = Json::from_pairs(vec![
            ("bench", Json::from(bench)),
            ("kind", Json::from("bench-trajectory")),
        ]);
        let mut run = registry.begin_run("bench", bench, config)?;
        let hash = run.record_file(&format!("BENCH_{bench}.json"), path)?;
        run.finish(RunState::Complete)?;
        Ok(hash)
    };
    match snapshot() {
        Ok(hash) => println!("registry: trajectory snapshot sha256 {}", &hash[..16]),
        Err(e) => eprintln!("registry snapshot skipped: {e:#}"),
    }
}

// ---------------------------------------------------------------------------
// Original AOT artifact path (BENCH_BACKEND=xla) — unchanged measurement.
// ---------------------------------------------------------------------------

fn xla_main() {
    use sagebwd::runtime::{Runtime, Value};
    use sagebwd::tensor::{IntTensor, Tensor};
    use sagebwd::util::rng::Pcg64;

    let mut rt = match Runtime::new(sagebwd::DEFAULT_ARTIFACTS_DIR) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("SKIP bench_train_step: {e:#} (run `make artifacts`)");
            return;
        }
    };
    let cfg = BenchConfig {
        warmup_iters: 1,
        iters: 8,
        max_secs: 30.0,
    };
    let mut table = Table::new(&["artifact", "mean_ms", "p50_ms", "p95_ms", "tokens_per_sec"]);

    for variant in ["sage_qknorm", "fpa_qknorm"] {
        let params = rt
            .execute(&format!("init_{variant}"), &[Value::scalar_i32(0)])
            .expect("init failed");
        let grad_name = format!("grad_step_{variant}");
        let exe = rt.load(&grad_name).expect("loading grad_step");
        let tok_spec = exe.manifest.input("tokens").expect("tokens input");
        let (b, n) = (tok_spec.shape[0], tok_spec.shape[1]);
        let mut rng = Pcg64::new(0, 1);
        let tokens: Vec<i32> = (0..b * n).map(|_| rng.below(256) as i32).collect();
        let mut inputs = params.clone();
        inputs.push(Value::I32(IntTensor::from_vec(&[b, n], tokens.clone()).unwrap()));
        inputs.push(Value::I32(IntTensor::from_vec(&[b, n], tokens).unwrap()));
        let m = bench_run(cfg, &grad_name, || {
            exe.execute(&inputs).expect("grad_step failed");
        });
        table.row(vec![
            format!("{grad_name} (upload-per-call)"),
            format!("{:.2}", m.mean() * 1e3),
            format!("{:.2}", m.p50() * 1e3),
            format!("{:.2}", m.p95() * 1e3),
            format!("{:.0}", (b * n) as f64 / m.mean()),
        ]);

        // Trainer hot path: params cached as device buffers (§Perf opt 2).
        let bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|v| exe.buffer_from_literal(&v.to_literal().unwrap()).unwrap())
            .collect();
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let mc = bench_run(cfg, &grad_name, || {
            exe.execute_buffers(&refs).expect("grad_step failed");
        });
        table.row(vec![
            format!("{grad_name} (cached buffers)"),
            format!("{:.2}", mc.mean() * 1e3),
            format!("{:.2}", mc.p50() * 1e3),
            format!("{:.2}", mc.p95() * 1e3),
            format!("{:.0}", (b * n) as f64 / mc.mean()),
        ]);

        // apply_step for this tree.
        let apply_name = if variant.contains("noqknorm") {
            "apply_step_noqknorm"
        } else {
            "apply_step_qknorm"
        };
        let np = params.len();
        let zeros: Vec<Value> = params
            .iter()
            .map(|p| Value::F32(Tensor::zeros(p.shape())))
            .collect();
        let mut ainputs = Vec::with_capacity(4 * np + 2);
        ainputs.extend(params.iter().cloned());
        ainputs.extend(zeros.iter().cloned());
        ainputs.extend(zeros.iter().cloned());
        ainputs.extend(zeros.iter().cloned());
        ainputs.push(Value::scalar_f32(1e-3));
        ainputs.push(Value::scalar_i32(1));
        let aexe = rt.load(apply_name).expect("loading apply_step");
        let ma = bench_run(cfg, apply_name, || {
            aexe.execute(&ainputs).expect("apply_step failed");
        });
        table.row(vec![
            format!("{apply_name} ({variant})"),
            format!("{:.2}", ma.mean() * 1e3),
            format!("{:.2}", ma.p50() * 1e3),
            format!("{:.2}", ma.p95() * 1e3),
            "-".into(),
        ]);
    }
    println!("{}", table.render());
    std::fs::create_dir_all(sagebwd::DEFAULT_RESULTS_DIR).ok();
    std::fs::write(
        format!("{}/bench_train_step.csv", sagebwd::DEFAULT_RESULTS_DIR),
        table.to_csv(),
    )
    .ok();
}
