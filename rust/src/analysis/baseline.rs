//! The A3 ratchet baseline: per-file panic-site counts committed as
//! `rust/src/analysis/baseline.json` (DESIGN.md §13).
//!
//! Canonical form — `util::json` output (sorted keys, no whitespace) so
//! regeneration is byte-stable and diffs are honest:
//!
//! ```text
//! {"files":{"rust/src/...":N,...},"schema":"sagebwd-analysis-baseline-v1","total":T}
//! ```
//!
//! The ratchet is one-directional: a file's count may only go *down*.
//! `sagebwd analyze` auto-rewrites the baseline when counts drop (so
//! improvements are locked in by the same commit that makes them) and
//! fails when any count rises; raising the baseline by hand is a code
//! review matter, not a tooling feature.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{self, schema, Json};

/// Schema tag of `baseline.json`.
pub const BASELINE_SCHEMA: &str = "sagebwd-analysis-baseline-v1";

/// Repo-relative path of the committed baseline.
pub const BASELINE_REL: &str = "rust/src/analysis/baseline.json";

/// Parsed baseline: per-file allowed A3 site counts.
#[derive(Debug, Clone, Default)]
pub struct Baseline {
    pub files: BTreeMap<String, usize>,
    pub total: usize,
}

impl Baseline {
    /// Build from measured per-file counts (what `--write-baseline` and
    /// the auto-tighten path persist).
    pub fn from_counts(counts: &BTreeMap<String, usize>) -> Baseline {
        let files: BTreeMap<String, usize> = counts
            .iter()
            .filter(|&(_, &c)| c > 0)
            .map(|(k, &c)| (k.clone(), c))
            .collect();
        let total = files.values().sum();
        Baseline { files, total }
    }

    /// Allowed count for a file (0 when unlisted).
    pub fn allowed(&self, rel: &str) -> usize {
        self.files.get(rel).copied().unwrap_or(0)
    }

    /// Load from disk; `Ok(None)` when the file does not exist.
    pub fn load(path: &Path) -> Result<Option<Baseline>> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => {
                return Err(e).with_context(|| format!("reading {}", path.display()))
            }
        };
        let doc =
            json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        schema::expect_tag(&doc, BASELINE_SCHEMA)
            .with_context(|| format!("{}", path.display()))?;
        let mut files = BTreeMap::new();
        for (k, v) in doc.get("files")?.as_obj()? {
            files.insert(k.clone(), v.as_usize()?);
        }
        let total = schema::usize_field(&doc, "total")?;
        Ok(Some(Baseline { files, total }))
    }

    /// Canonical JSON (sorted keys, no whitespace).
    pub fn to_json(&self) -> String {
        let files: BTreeMap<String, Json> = self
            .files
            .iter()
            .map(|(k, &v)| (k.clone(), Json::from(v)))
            .collect();
        Json::from_pairs(vec![
            ("files", Json::Obj(files)),
            ("schema", Json::from(BASELINE_SCHEMA)),
            ("total", Json::from(self.total)),
        ])
        .to_string()
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json())
            .with_context(|| format!("writing {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_form_roundtrips() {
        let mut counts = BTreeMap::new();
        counts.insert("rust/src/b.rs".to_string(), 2);
        counts.insert("rust/src/a.rs".to_string(), 3);
        counts.insert("rust/src/clean.rs".to_string(), 0);
        let b = Baseline::from_counts(&counts);
        assert_eq!(b.total, 5);
        assert_eq!(b.allowed("rust/src/clean.rs"), 0, "zero-count files are dropped");
        let text = b.to_json();
        assert_eq!(
            text,
            r#"{"files":{"rust/src/a.rs":3,"rust/src/b.rs":2},"schema":"sagebwd-analysis-baseline-v1","total":5}"#
        );
        let dir = std::env::temp_dir().join(format!("sagebwd_base_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("baseline.json");
        b.save(&path).unwrap();
        let back = Baseline::load(&path).unwrap().unwrap();
        assert_eq!(back.total, 5);
        assert_eq!(back.allowed("rust/src/a.rs"), 3);
        assert!(Baseline::load(&dir.join("missing.json")).unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }
}
