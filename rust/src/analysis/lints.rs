//! The five invariant lints (DESIGN.md §13) over tokenized sources.
//!
//! * **A1 determinism** — no `HashMap`/`HashSet`, wall clocks, or OS
//!   randomness inside the numeric modules (`tensor/`, `kernels/`,
//!   `model/`, `experiments/`): the bitwise-reproducibility contract of
//!   DESIGN.md §11 at any `SAGEBWD_THREADS`.
//! * **A2 hot-loop allocation** — no `clone()`/`to_vec()`/`Vec::new`/
//!   `vec![` inside loop bodies of the [`HOT_FUNCTIONS`] manifest
//!   (the PR-5 workspace discipline).  Prologue allocations are legal;
//!   a manifest entry matching no `fn` is itself a violation, so the
//!   manifest cannot silently rot.
//! * **A3 panic-policy** — `unwrap()`/`expect()`/`panic!` in non-test
//!   library code, ratcheted against `analysis/baseline.json`.
//! * **A4 unsafe-audit** — every `unsafe` needs a `// SAFETY:` comment
//!   on the same line or the run of comment-only lines above it.
//! * **A5 schema-drift** — string keys emitted/checked by `bench.rs`,
//!   `registry/manifest.rs`, and `telemetry/trace.rs` must match the
//!   documented `sagebwd-bench-v1` / `sagebwd-run-v1` /
//!   `sagebwd-trace-v1` field lists.
//!
//! Suppression is per-site only: `// sagebwd-allow(A3): reason` on the
//! violating line or the line above.  A reason is mandatory — an allow
//! without one is reported as **A0**.
//!
//! Constants here are the spec; `python/compile/check_analyzer.py`
//! mirrors them and must be updated in the same commit.

use std::collections::BTreeMap;

use crate::analysis::tokenizer::{is_ident, Line};

/// Module prefixes under the determinism contract (A1).
pub const NUMERIC_MODULES: [&str; 4] = [
    "rust/src/tensor/",
    "rust/src/kernels/",
    "rust/src/model/",
    "rust/src/experiments/",
];

/// (token, message, hint) triples banned in numeric modules (A1).
pub const A1_BANNED: [(&str, &str, &str); 7] = [
    (
        "HashMap",
        "HashMap iteration order is nondeterministic",
        "use BTreeMap (determinism contract, DESIGN.md S11/S13)",
    ),
    (
        "HashSet",
        "HashSet iteration order is nondeterministic",
        "use BTreeSet (determinism contract, DESIGN.md S11/S13)",
    ),
    (
        "Instant",
        "wall-clock read inside a numeric module",
        "time at the harness layer (bench.rs) instead",
    ),
    (
        "SystemTime",
        "wall-clock read inside a numeric module",
        "time at the harness layer (bench.rs) instead",
    ),
    (
        "thread_rng",
        "OS randomness breaks bitwise reproducibility",
        "use util::rng (seeded, deterministic)",
    ),
    (
        "RandomState",
        "randomized hasher state is nondeterministic",
        "use BTreeMap or a fixed-seed hasher",
    ),
    (
        "getrandom",
        "OS randomness breaks bitwise reproducibility",
        "use util::rng (seeded, deterministic)",
    ),
];

/// Allocation tokens banned inside hot loops (A2).
pub const A2_BANNED: [&str; 4] = [".clone()", ".to_vec()", "Vec::new", "vec!["];

/// The hot-function manifest: (file, fn-name patterns).  `*` at either
/// end of a pattern is a prefix/suffix wildcard.
pub const HOT_FUNCTIONS: [(&str, &[&str]); 5] = [
    ("rust/src/kernels/attention.rs", &["*_ws"]),
    (
        "rust/src/tensor/linalg.rs",
        &[
            "gemm_nn_rows*",
            "i8_gemm_nn_rows*",
            "par_gemm_nn",
            "pack_transpose",
            "int8_gemm_nn*",
            "int8_gemm_nt*",
            "int8_gemm_tn*",
        ],
    ),
    (
        "rust/src/tensor/simd.rs",
        &["gemm_f32_rows*", "gemm_i8_rows*"],
    ),
    (
        "rust/src/model/blocks.rs",
        &[
            "rmsnorm_fwd",
            "rmsnorm_bwd",
            "mlp_fwd",
            "mlp_bwd",
            "cross_entropy_fwd",
            "cross_entropy_bwd",
        ],
    ),
    (
        "rust/src/model/transformer.rs",
        &["forward_with_targets", "loss_and_grads"],
    ),
];

/// Panic-family tokens (A3).
pub const A3_TOKENS: [&str; 3] = [".unwrap()", ".expect(", "panic!"];

/// Documented `sagebwd-bench-v1` field names (A5).
pub const BENCH_V1_FIELDS: [&str; 12] = [
    "schema",
    "bench",
    "runs",
    "threads_default",
    "rows",
    "op",
    "shape",
    "variant",
    "threads",
    "isa",
    "ns_per_iter",
    "tokens_per_s",
];

/// Documented `sagebwd-run-v1` field names (A5).
pub const RUN_V1_FIELDS: [&str; 22] = [
    "schema",
    "experiment",
    "label",
    "config",
    "config_hash",
    "code_version",
    "status",
    "artifacts",
    "recoveries",
    "summary",
    "name",
    "sha256",
    "bytes",
    "view",
    "attempt",
    "at_step",
    "resume_step",
    "reason",
    "action",
    "peak_lr",
    "tokens_per_step",
    "variant",
];

/// Documented `sagebwd-trace-v1` field names (A5).
pub const TRACE_V1_FIELDS: [&str; 15] = [
    "schema",
    "kind",
    "threads",
    "spans",
    "counters",
    "name",
    "parent",
    "calls",
    "total_ns",
    "self_ns",
    "min_ns",
    "max_ns",
    "p50_ns",
    "p99_ns",
    "value",
];

/// (file, schema tag, documented fields) targets for A5.
pub fn schema_targets() -> [(&'static str, &'static str, &'static [&'static str]); 3] {
    [
        ("rust/src/bench.rs", "sagebwd-bench-v1", &BENCH_V1_FIELDS),
        (
            "rust/src/registry/manifest.rs",
            "sagebwd-run-v1",
            &RUN_V1_FIELDS,
        ),
        (
            "rust/src/telemetry/trace.rs",
            "sagebwd-trace-v1",
            &TRACE_V1_FIELDS,
        ),
    ]
}

/// One reported lint hit, rendered as `file:line: LINT: message (fix: hint)`.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub lint: &'static str,
    pub message: String,
    pub hint: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}:{}: {}: {} (fix: {})",
            self.file, self.line, self.lint, self.message, self.hint
        )
    }
}

/// Per-file lint context: stripped lines + test-region and allow maps.
pub struct FileCtx {
    pub relpath: String,
    pub lines: Vec<Line>,
    tests: Vec<bool>,
    allows: BTreeMap<usize, Vec<(String, bool)>>,
}

/// 1-based line numbers that are test code: whole files under
/// `rust/tests/` and `rust/benches/`, and `#[cfg(test)]`-gated blocks in
/// library sources (tracked by brace depth).
fn test_flags(lines: &[Line], relpath: &str) -> Vec<bool> {
    let max_num = lines.iter().map(|l| l.num).max().unwrap_or(0);
    let mut flags = vec![false; max_num + 2];
    if relpath.starts_with("rust/tests/") || relpath.starts_with("rust/benches/") {
        for f in flags.iter_mut() {
            *f = true;
        }
        return flags;
    }
    let mut pending = false;
    let mut depth = 0usize;
    let mut in_region = false;
    for l in lines {
        if !in_region && l.code.contains("#[cfg(test)]") {
            pending = true;
            flags[l.num] = true;
            continue;
        }
        if pending || in_region {
            flags[l.num] = true;
            for ch in l.code.chars() {
                if ch == '{' {
                    depth += 1;
                    pending = false;
                    in_region = true;
                } else if ch == '}' {
                    depth = depth.saturating_sub(1);
                    if in_region && depth == 0 {
                        in_region = false;
                    }
                }
            }
        }
    }
    flags
}

/// line -> [(lint_id, has_reason)].  An allow on line L covers L and L+1.
fn parse_allows(lines: &[Line]) -> BTreeMap<usize, Vec<(String, bool)>> {
    const MARK: &str = "sagebwd-allow(";
    let mut allows: BTreeMap<usize, Vec<(String, bool)>> = BTreeMap::new();
    for l in lines {
        for c in &l.comments {
            let mut from = 0usize;
            while let Some(off) = c[from..].find(MARK) {
                let idx = from + off;
                let rest = &c[idx + MARK.len()..];
                if let Some(close) = rest.find(')') {
                    if close > 0 {
                        let lint = rest[..close].trim().to_string();
                        let after = rest[close + 1..].trim_start();
                        let reason = after
                            .strip_prefix(':')
                            .map(|r| !r.trim().is_empty())
                            .unwrap_or(false);
                        allows.entry(l.num).or_default().push((lint, reason));
                    }
                }
                from = idx + 1;
            }
        }
    }
    allows
}

/// Start byte offsets of boundary-checked occurrences of `token` in
/// `code`.  Tokens starting with an identifier char must not be preceded
/// by one; tokens ending with one must not be followed by one.
pub fn find_token(code: &str, token: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let bytes = code.as_bytes();
    let tok_first = token.as_bytes()[0];
    let tok_last = *token.as_bytes().last().unwrap_or(&b' ');
    let ident_start = tok_first.is_ascii_alphabetic() || tok_first == b'_';
    let ident_end = tok_last.is_ascii_alphanumeric() || tok_last == b'_';
    let mut start = 0usize;
    while let Some(off) = code[start..].find(token) {
        let idx = start + off;
        let before = if idx > 0 { bytes[idx - 1] as char } else { ' ' };
        let end = idx + token.len();
        let after = if end < bytes.len() {
            bytes[end] as char
        } else {
            ' '
        };
        let mut ok = true;
        if ident_start && is_ident(before) {
            ok = false;
        }
        if ident_end && is_ident(after) {
            ok = false;
        }
        if ok {
            out.push(idx);
        }
        start = idx + 1;
    }
    out
}

impl FileCtx {
    pub fn new(relpath: &str, text: &str) -> FileCtx {
        let lines = crate::analysis::tokenizer::tokenize(text);
        let tests = test_flags(&lines, relpath);
        let allows = parse_allows(&lines);
        FileCtx {
            relpath: relpath.to_string(),
            lines,
            tests,
            allows,
        }
    }

    fn is_test(&self, num: usize) -> bool {
        self.tests.get(num).copied().unwrap_or(false)
    }

    /// Is `lint` allowed (with a reason) on line `num`?
    fn allowed(&self, lint: &str, num: usize) -> bool {
        for at in [num, num.saturating_sub(1)] {
            if let Some(list) = self.allows.get(&at) {
                if list.iter().any(|(lid, has)| lid == lint && *has) {
                    return true;
                }
            }
        }
        false
    }

    /// A0: every `sagebwd-allow` must carry a reason.
    pub fn allow_comment_violations(&self) -> Vec<Violation> {
        let mut out = Vec::new();
        for (num, list) in &self.allows {
            for (lid, has_reason) in list {
                if !has_reason {
                    out.push(Violation {
                        file: self.relpath.clone(),
                        line: *num,
                        lint: "A0",
                        message: format!("sagebwd-allow({lid}) without a reason"),
                        hint: format!(
                            "write // sagebwd-allow({lid}): <why this site is safe>"
                        ),
                    });
                }
            }
        }
        out
    }
}

/// A1: banned nondeterminism tokens in numeric modules.
pub fn lint_a1(ctx: &FileCtx) -> Vec<Violation> {
    let mut out = Vec::new();
    if !NUMERIC_MODULES.iter().any(|p| ctx.relpath.starts_with(p)) {
        return out;
    }
    for l in &ctx.lines {
        if ctx.is_test(l.num) {
            continue;
        }
        for (tok, msg, hint) in A1_BANNED {
            for _ in find_token(&l.code, tok) {
                if !ctx.allowed("A1", l.num) {
                    out.push(Violation {
                        file: ctx.relpath.clone(),
                        line: l.num,
                        lint: "A1",
                        message: format!("{msg} (`{tok}`)"),
                        hint: hint.to_string(),
                    });
                }
            }
        }
    }
    out
}

fn fn_matches(name: &str, pattern: &str) -> bool {
    if let Some(suffix) = pattern.strip_prefix('*') {
        return name.ends_with(suffix);
    }
    if let Some(prefix) = pattern.strip_suffix('*') {
        return name.starts_with(prefix);
    }
    name == pattern
}

/// Per-line loop-body byte ranges of one matched hot function.
struct FnSpan {
    name: String,
    /// (line number, [(lo, hi)] inclusive byte ranges inside loop scopes).
    body: Vec<(usize, Vec<(usize, usize)>)>,
}

/// Find manifest functions and the byte ranges of their loop bodies.
/// Returns the spans and the set of patterns that matched at least once.
fn hot_fn_spans(ctx: &FileCtx, patterns: &[&str]) -> (Vec<FnSpan>, Vec<String>) {
    let mut matched: Vec<String> = Vec::new();
    let mut spans: Vec<FnSpan> = Vec::new();
    let nlines = ctx.lines.len();
    let mut li = 0usize;
    while li < nlines {
        let l = &ctx.lines[li];
        if ctx.is_test(l.num) {
            li += 1;
            continue;
        }
        for idx in find_token(&l.code, "fn") {
            let rest = l.code[idx + 2..].trim_start();
            let name: String = rest.chars().take_while(|&c| is_ident(c)).collect();
            if name.is_empty() {
                continue;
            }
            let pats: Vec<&str> = patterns
                .iter()
                .copied()
                .filter(|p| fn_matches(&name, p))
                .collect();
            if pats.is_empty() {
                continue;
            }
            for p in &pats {
                if !matched.iter().any(|m| m == p) {
                    matched.push(p.to_string());
                }
            }
            // Scan the body: find the first '{' from here, then track
            // brace depth with a per-scope "opened by a loop keyword"
            // stack until the matching '}'.
            let mut body: Vec<(usize, Vec<(usize, usize)>)> = Vec::new();
            let mut depth = 0usize;
            let mut started = false;
            let mut pending_loop = false;
            let mut loop_stack: Vec<bool> = Vec::new();
            let mut word = String::new();
            let (mut lj, mut cj) = (li, idx);
            let mut done = false;
            while lj < nlines && !done {
                let lcode = &ctx.lines[lj].code;
                let lbytes = lcode.as_bytes();
                let mut ranges: Vec<(usize, usize)> = Vec::new();
                let mut open_at: Option<usize> = None;
                let mut k = cj;
                while k < lbytes.len() {
                    let ch = lbytes[k] as char;
                    if is_ident(ch) {
                        word.push(ch);
                    } else {
                        if word == "for" || word == "while" || word == "loop" {
                            pending_loop = true;
                        }
                        word.clear();
                    }
                    if ch == '{' {
                        if !started {
                            started = true;
                            depth = 1;
                            loop_stack.clear();
                        } else {
                            depth += 1;
                            loop_stack.push(pending_loop);
                            if pending_loop && open_at.is_none() {
                                open_at = Some(k);
                            }
                            pending_loop = false;
                        }
                    } else if ch == ';' {
                        pending_loop = false;
                    } else if ch == '}' && started {
                        depth -= 1;
                        if depth == 0 {
                            done = true;
                            break;
                        }
                        let was_loop = loop_stack.pop().unwrap_or(false);
                        if was_loop && !loop_stack.iter().any(|&b| b) {
                            ranges.push((open_at.unwrap_or(0), k));
                            open_at = None;
                        }
                    }
                    k += 1;
                }
                word.clear(); // tokens never span lines
                if started {
                    let in_loop = loop_stack.iter().any(|&b| b);
                    if in_loop && open_at.is_none() {
                        ranges.push((0, lcode.len()));
                    } else if let Some(at) = open_at {
                        ranges.push((at, lcode.len()));
                    }
                    if !ranges.is_empty() {
                        body.push((ctx.lines[lj].num, ranges));
                    }
                }
                lj += 1;
                cj = 0;
            }
            spans.push(FnSpan { name, body });
        }
        li += 1;
    }
    (spans, matched)
}

/// A2: allocation tokens inside hot-function loop bodies, plus
/// manifest-drift (a pattern matching no fn).
pub fn lint_a2(ctx: &FileCtx) -> Vec<Violation> {
    let mut out = Vec::new();
    let Some(patterns) = HOT_FUNCTIONS
        .iter()
        .find(|(path, _)| *path == ctx.relpath)
        .map(|(_, pats)| *pats)
    else {
        return out;
    };
    let (spans, matched) = hot_fn_spans(ctx, patterns);
    for p in patterns {
        if !matched.iter().any(|m| m == p) {
            out.push(Violation {
                file: ctx.relpath.clone(),
                line: 1,
                lint: "A2",
                message: format!("hot-function manifest entry `{p}` matches no fn"),
                hint: "update HOT_FUNCTIONS in analysis/lints.rs".to_string(),
            });
        }
    }
    let line_code: BTreeMap<usize, &str> =
        ctx.lines.iter().map(|l| (l.num, l.code.as_str())).collect();
    for span in &spans {
        for (num, ranges) in &span.body {
            let Some(code) = line_code.get(num) else {
                continue;
            };
            for tok in A2_BANNED {
                for idx in find_token(code, tok) {
                    if ranges.iter().any(|&(lo, hi)| lo <= idx && idx <= hi)
                        && !ctx.allowed("A2", *num)
                    {
                        out.push(Violation {
                            file: ctx.relpath.clone(),
                            line: *num,
                            lint: "A2",
                            message: format!(
                                "`{tok}` inside a hot loop of `{}`",
                                span.name
                            ),
                            hint: "hoist the buffer out of the loop (Workspace slab or argument)"
                                .to_string(),
                        });
                    }
                }
            }
        }
    }
    out
}

/// A3 candidate sites: (line, token) of panic-family calls in non-test
/// `rust/src/` code, allow-sites excluded.  The ratchet against the
/// baseline happens in `analysis::analyze`.
pub fn lint_a3_sites(ctx: &FileCtx) -> Vec<(usize, &'static str)> {
    let mut sites = Vec::new();
    if !ctx.relpath.starts_with("rust/src/") {
        return sites;
    }
    for l in &ctx.lines {
        if ctx.is_test(l.num) {
            continue;
        }
        for tok in A3_TOKENS {
            for _ in find_token(&l.code, tok) {
                if !ctx.allowed("A3", l.num) {
                    sites.push((l.num, tok));
                }
            }
        }
    }
    sites
}

/// A4: `unsafe` without a `SAFETY:` comment on the same line or on the
/// run of comment-only lines directly above.
pub fn lint_a4(ctx: &FileCtx) -> Vec<Violation> {
    let mut out = Vec::new();
    let by_num: BTreeMap<usize, &Line> = ctx.lines.iter().map(|l| (l.num, l)).collect();
    let comment_only: BTreeMap<usize, bool> = ctx
        .lines
        .iter()
        .map(|l| (l.num, l.code.trim().is_empty() && !l.comments.is_empty()))
        .collect();
    for l in &ctx.lines {
        for _ in find_token(&l.code, "unsafe") {
            let mut ok = l.comments.iter().any(|c| c.contains("SAFETY:"));
            let mut num = l.num.saturating_sub(1);
            while !ok && num >= 1 && comment_only.get(&num).copied().unwrap_or(false) {
                if by_num[&num].comments.iter().any(|c| c.contains("SAFETY:")) {
                    ok = true;
                }
                num = num.saturating_sub(1);
            }
            if !ok && !ctx.allowed("A4", l.num) {
                out.push(Violation {
                    file: ctx.relpath.clone(),
                    line: l.num,
                    lint: "A4",
                    message: "`unsafe` without a `// SAFETY:` comment".to_string(),
                    hint: "document the invariant that makes this sound on the preceding line"
                        .to_string(),
                });
            }
        }
    }
    out
}

/// Lowercase snake_case identifier — what a JSON schema key looks like.
fn is_ident_key(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_lowercase() => {}
        _ => return false,
    }
    s.chars()
        .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

/// (key, line) pairs from `("key", ...)` and `(..., "key")` call
/// positions in non-test code — the shapes `Json::from_pairs` entries
/// and `schema::*_field` calls take.
fn json_keys(ctx: &FileCtx) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    for l in &ctx.lines {
        if ctx.is_test(l.num) {
            continue;
        }
        for (si, s) in l.strings.iter().enumerate() {
            let ph = format!("\"{si}\"");
            let Some(idx) = l.code.find(&ph) else {
                continue;
            };
            let before = l.code[..idx].trim_end();
            let after = l.code[idx + ph.len()..].trim_start();
            let prevc = before.chars().last().unwrap_or(' ');
            let nextc = after.chars().next().unwrap_or(' ');
            if ((prevc == '(' && nextc == ',') || (prevc == ',' && nextc == ')'))
                && is_ident_key(s)
            {
                out.push((s.clone(), l.num));
            }
        }
    }
    out
}

/// A5: schema-field drift in the emitter files.
pub fn lint_a5(ctx: &FileCtx) -> Vec<Violation> {
    let mut out = Vec::new();
    let Some((_, tag, fields)) = schema_targets()
        .into_iter()
        .find(|(path, _, _)| *path == ctx.relpath)
    else {
        return out;
    };
    let mut all_strings: Vec<&str> = Vec::new();
    for l in &ctx.lines {
        if !ctx.is_test(l.num) {
            all_strings.extend(l.strings.iter().map(|s| s.as_str()));
        }
    }
    if !all_strings.contains(&tag) {
        out.push(Violation {
            file: ctx.relpath.clone(),
            line: 1,
            lint: "A5",
            message: format!("schema tag \"{tag}\" not found in file"),
            hint: "keep the schema constant in lockstep with analysis/lints.rs".to_string(),
        });
    }
    let keys = json_keys(ctx);
    for (k, num) in &keys {
        if !fields.contains(&k.as_str()) && !ctx.allowed("A5", *num) {
            out.push(Violation {
                file: ctx.relpath.clone(),
                line: *num,
                lint: "A5",
                message: format!("field \"{k}\" is not in the documented {tag} schema"),
                hint: "add it to the schema list in analysis/lints.rs + DESIGN.md, or rename"
                    .to_string(),
            });
        }
    }
    for f in fields {
        if !keys.iter().any(|(k, _)| k == f) {
            out.push(Violation {
                file: ctx.relpath.clone(),
                line: 1,
                lint: "A5",
                message: format!(
                    "documented {tag} field \"{f}\" is no longer emitted/checked here"
                ),
                hint: "re-emit the field or remove it from the documented schema".to_string(),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn find_token_respects_boundaries() {
        assert_eq!(find_token("HashMap::new()", "HashMap"), vec![0]);
        assert!(find_token("MyHashMap::new()", "HashMap").is_empty());
        assert!(find_token("HashMapLike", "HashMap").is_empty());
        assert_eq!(find_token("x.unwrap();", ".unwrap()"), vec![1]);
        assert!(find_token("x.unwrap_or(1);", ".unwrap()").is_empty());
    }

    #[test]
    fn fn_pattern_wildcards() {
        assert!(fn_matches("sage_fwd_ws", "*_ws"));
        assert!(!fn_matches("sage_fwd", "*_ws"));
        assert!(fn_matches("int8_gemm_nn", "int8_*"));
        assert!(fn_matches("mlp_fwd", "mlp_fwd"));
    }

    #[test]
    fn allow_requires_reason_and_covers_next_line() {
        let src = "// sagebwd-allow(A3): checked above\nlet x = y.unwrap();\n\
                   // sagebwd-allow(A3)\nlet z = w.unwrap();\n";
        let ctx = FileCtx::new("rust/src/foo.rs", src);
        let sites = lint_a3_sites(&ctx);
        assert_eq!(sites.len(), 1, "only the reason-less allow leaves a site");
        assert_eq!(sites[0].0, 4);
        assert_eq!(ctx.allow_comment_violations().len(), 1);
    }

    #[test]
    fn a1_skips_tests_and_strings() {
        let src = "use std::collections::HashMap;\n\
                   let s = \"HashMap\";\n\
                   #[cfg(test)]\nmod tests {\n    use std::collections::HashMap;\n}\n";
        let ctx = FileCtx::new("rust/src/tensor/x.rs", src);
        let v = lint_a1(&ctx);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].line, 1);
    }

    #[test]
    fn a2_flags_loop_body_not_prologue() {
        let src = "pub fn demo_ws(n: usize) -> Vec<f32> {\n\
                   \x20   let mut out = vec![0f32; n];\n\
                   \x20   for i in 0..n {\n\
                   \x20       let t = out.clone();\n\
                   \x20       out[i] = t[i];\n\
                   \x20   }\n\
                   \x20   out\n}\n";
        let ctx = FileCtx::new("rust/src/kernels/attention.rs", src);
        let v = lint_a2(&ctx);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].line, 4);
        assert!(v[0].message.contains("demo_ws"));
    }

    #[test]
    fn a4_accepts_safety_on_preceding_comment_run() {
        let src = "// SAFETY: len checked above,\n// and alignment is 1.\n\
                   let b = unsafe { f(x) };\nlet c = unsafe { f(x) };\n";
        let ctx = FileCtx::new("rust/src/util/x.rs", src);
        let v = lint_a4(&ctx);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].line, 4);
    }
}
