//! Self-hosting static analysis over the repo's own Rust sources
//! (DESIGN.md §13): the `sagebwd analyze` subcommand and the tier-1
//! `analysis_lints` test target both drive [`analyze`].
//!
//! Pure std, no external parser: [`tokenizer`] strips strings and
//! comments line-aware, [`lints`] runs the five invariant passes
//! (A1 determinism, A2 hot-loop allocation, A3 panic-policy ratchet,
//! A4 unsafe-audit, A5 schema-drift), and [`baseline`] holds the
//! committed A3 ratchet state.  `python/compile/check_analyzer.py` is
//! the toolchain-free twin; the two must agree violation for violation.

pub mod baseline;
pub mod lints;
pub mod tokenizer;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

pub use baseline::{Baseline, BASELINE_REL, BASELINE_SCHEMA};
pub use lints::Violation;

/// Knobs for one [`analyze`] run.
#[derive(Debug, Clone, Copy)]
pub struct AnalyzeOptions {
    /// Rewrite `baseline.json` when the measured A3 counts dropped below
    /// it (the auto-tighten half of the ratchet).  `--no-ratchet` turns
    /// this off; read-only callers (the test target) also disable it.
    pub update_baseline: bool,
}

impl Default for AnalyzeOptions {
    fn default() -> AnalyzeOptions {
        AnalyzeOptions {
            update_baseline: true,
        }
    }
}

/// What one analysis run found.
#[derive(Debug, Clone, Default)]
pub struct Report {
    /// All violations, sorted by (file, line, lint).
    pub violations: Vec<Violation>,
    /// Files scanned.
    pub files_scanned: usize,
    /// Measured A3 sites per file (allow-sites excluded).
    pub a3_counts: BTreeMap<String, usize>,
    /// Sum over `a3_counts`.
    pub a3_total: usize,
    /// Total the committed baseline allows (0 when missing).
    pub a3_baseline_total: usize,
    /// Some file's count dropped below its baseline entry.
    pub baseline_tightened: bool,
    /// The baseline file was rewritten by this run.
    pub baseline_updated: bool,
}

/// Repo-relative `.rs` paths to scan, sorted: `rust/src`, `rust/tests`,
/// `rust/benches`, `examples`, skipping any directory named `data`
/// (lint fixtures live under `rust/tests/data/`), `vendor`, `target`,
/// or starting with `.`.
pub fn scan_paths(root: &Path) -> Result<Vec<String>> {
    fn walk(root: &Path, dir: &Path, out: &mut Vec<String>) -> Result<()> {
        let mut entries: Vec<std::fs::DirEntry> = std::fs::read_dir(dir)
            .with_context(|| format!("listing {}", dir.display()))?
            .collect::<std::io::Result<_>>()
            .with_context(|| format!("listing {}", dir.display()))?;
        entries.sort_by_key(|e| e.file_name());
        for entry in entries {
            let path = entry.path();
            let name = entry.file_name().to_string_lossy().into_owned();
            if path.is_dir() {
                if name == "data" || name == "vendor" || name == "target" || name.starts_with('.')
                {
                    continue;
                }
                walk(root, &path, out)?;
            } else if name.ends_with(".rs") {
                let rel = path
                    .strip_prefix(root)
                    .unwrap_or(&path)
                    .to_string_lossy()
                    .replace(std::path::MAIN_SEPARATOR, "/");
                out.push(rel);
            }
        }
        Ok(())
    }
    let mut out = Vec::new();
    for sub in ["rust/src", "rust/tests", "rust/benches", "examples"] {
        let base = root.join(sub);
        if base.is_dir() {
            walk(root, &base, &mut out)?;
        }
    }
    out.sort();
    Ok(out)
}

/// Run every lint over the tree at `root` and ratchet A3 against the
/// committed baseline.  Never returns `Err` for violations — those are
/// data in the [`Report`]; `Err` is reserved for I/O failures.
pub fn analyze(root: &Path, opts: &AnalyzeOptions) -> Result<Report> {
    let mut report = Report::default();
    let mut a3_sites: BTreeMap<String, Vec<(usize, &'static str)>> = BTreeMap::new();
    for rel in scan_paths(root)? {
        let text = std::fs::read_to_string(root.join(&rel))
            .with_context(|| format!("reading {rel}"))?;
        let ctx = lints::FileCtx::new(&rel, &text);
        report.files_scanned += 1;
        report.violations.extend(ctx.allow_comment_violations());
        report.violations.extend(lints::lint_a1(&ctx));
        report.violations.extend(lints::lint_a2(&ctx));
        report.violations.extend(lints::lint_a4(&ctx));
        report.violations.extend(lints::lint_a5(&ctx));
        let sites = lints::lint_a3_sites(&ctx);
        if !sites.is_empty() {
            a3_sites.insert(rel, sites);
        }
    }
    report.a3_counts = a3_sites
        .iter()
        .map(|(k, v)| (k.clone(), v.len()))
        .collect();
    report.a3_total = report.a3_counts.values().sum();

    // A3 ratchet against the committed baseline.
    let bpath = root.join(BASELINE_REL);
    let loaded = Baseline::load(&bpath);
    let (baseline, have_baseline) = match loaded {
        Ok(Some(b)) => (b, true),
        Ok(None) => {
            report.violations.push(Violation {
                file: BASELINE_REL.to_string(),
                line: 1,
                lint: "A3",
                message: "missing A3 baseline file".to_string(),
                hint: "generate it with `sagebwd analyze --write-baseline`".to_string(),
            });
            (Baseline::default(), false)
        }
        Err(e) => {
            report.violations.push(Violation {
                file: BASELINE_REL.to_string(),
                line: 1,
                lint: "A3",
                message: format!("unreadable baseline: {e:#}"),
                hint: "regenerate with `sagebwd analyze --write-baseline`".to_string(),
            });
            (Baseline::default(), false)
        }
    };
    report.a3_baseline_total = baseline.total;
    for (rel, sites) in &a3_sites {
        let count = sites.len();
        let base = baseline.allowed(rel);
        if count > base {
            // Point at the first site past the allowance — with a stable
            // scan order that is the newest one.
            let first = sites.get(base).map(|&(n, _)| n).unwrap_or(1);
            report.violations.push(Violation {
                file: rel.clone(),
                line: first,
                lint: "A3",
                message: format!(
                    "{count} unwrap()/expect()/panic! sites, baseline allows {base}"
                ),
                hint: "propagate with ? (or // sagebwd-allow(A3): reason), never raise the baseline"
                    .to_string(),
            });
        } else if count < base {
            report.baseline_tightened = true;
        }
    }
    for (rel, &base) in &baseline.files {
        if base > 0 && !a3_sites.contains_key(rel) {
            report.baseline_tightened = true;
        }
    }
    if opts.update_baseline
        && have_baseline
        && report.baseline_tightened
        && !report.violations.iter().any(|v| v.lint == "A3")
    {
        Baseline::from_counts(&report.a3_counts).save(&bpath)?;
        report.baseline_updated = true;
    }
    report.violations.sort();
    Ok(report)
}

/// Compute and write the baseline from the current tree (CLI
/// `--write-baseline`): the bootstrap path, and the only sanctioned way
/// to create the file.
pub fn write_baseline(root: &Path) -> Result<Report> {
    let mut report = analyze(
        root,
        &AnalyzeOptions {
            update_baseline: false,
        },
    )?;
    let bpath = root.join(BASELINE_REL);
    Baseline::from_counts(&report.a3_counts).save(&bpath)?;
    report.baseline_updated = true;
    Ok(report)
}
