//! Line-aware Rust source tokenizer for the invariant analyzer
//! (DESIGN.md §13).
//!
//! Not a parser: a character state machine that strips comments and
//! string/char literals from each line so the lint passes can match
//! tokens in code text without false positives from prose, while
//! keeping what was stripped — string-literal contents (A5 schema keys)
//! and comment text (A4 `SAFETY:` markers, `sagebwd-allow` sites) —
//! attached to the line it ended on.
//!
//! Mirrored line for line by `python/compile/check_analyzer.py` so the
//! pass can be validated without a Rust toolchain; keep the two in
//! lockstep.

/// One source line after stripping.
#[derive(Debug, Clone)]
pub struct Line {
    /// 1-based source line number.
    pub num: usize,
    /// Code text with comments removed; string literals are replaced by
    /// `"<idx>"` placeholders into `strings`, char literals by `' '`.
    pub code: String,
    /// String-literal contents; a literal spanning lines is recorded on
    /// its closing line.
    pub strings: Vec<String>,
    /// Comment text (markers stripped) touching this line.
    pub comments: Vec<String>,
}

/// ASCII identifier character. ASCII-only on purpose: source
/// identifiers in this repo are ASCII, and non-ASCII comment prose must
/// count as a token boundary.
pub fn is_ident(ch: char) -> bool {
    ch.is_ascii_alphanumeric() || ch == '_'
}

enum Mode {
    Normal,
    LineComment,
    BlockComment,
    Str,
    RawStr,
}

/// Split `text` into stripped [`Line`]s.
pub fn tokenize(text: &str) -> Vec<Line> {
    let chars: Vec<char> = text.chars().collect();
    let n = chars.len();
    let mut lines: Vec<Line> = Vec::new();
    let mut num = 1usize;
    let mut code = String::new();
    let mut strings: Vec<String> = Vec::new();
    let mut comments: Vec<String> = Vec::new();
    let mut mode = Mode::Normal;
    let mut bc_depth = 0usize;
    let mut rs_hashes = 0usize;
    let mut sbuf = String::new();
    let mut comment_buf = String::new();
    let mut i = 0usize;

    macro_rules! flush_line {
        () => {{
            if !comment_buf.is_empty() {
                comments.push(std::mem::take(&mut comment_buf));
            }
            lines.push(Line {
                num,
                code: std::mem::take(&mut code),
                strings: std::mem::take(&mut strings),
                comments: std::mem::take(&mut comments),
            });
            num += 1;
        }};
    }

    while i < n {
        let ch = chars[i];
        if ch == '\n' {
            if matches!(mode, Mode::LineComment) {
                mode = Mode::Normal;
            }
            flush_line!();
            i += 1;
            continue;
        }
        match mode {
            Mode::LineComment => {
                comment_buf.push(ch);
                i += 1;
            }
            Mode::BlockComment => {
                if ch == '/' && i + 1 < n && chars[i + 1] == '*' {
                    bc_depth += 1;
                    comment_buf.push_str("/*");
                    i += 2;
                } else if ch == '*' && i + 1 < n && chars[i + 1] == '/' {
                    bc_depth -= 1;
                    i += 2;
                    if bc_depth == 0 {
                        mode = Mode::Normal;
                        if !comment_buf.is_empty() {
                            comments.push(std::mem::take(&mut comment_buf));
                        }
                    } else {
                        comment_buf.push_str("*/");
                    }
                } else {
                    comment_buf.push(ch);
                    i += 1;
                }
            }
            Mode::Str => {
                if ch == '\\' && i + 1 < n {
                    if chars[i + 1] == '\n' {
                        // Escaped-newline continuation: the literal goes
                        // on, but the source line ends here — flush so
                        // every later line number stays correct.
                        flush_line!();
                    } else {
                        sbuf.push(ch);
                        sbuf.push(chars[i + 1]);
                    }
                    i += 2;
                } else if ch == '"' {
                    strings.push(std::mem::take(&mut sbuf));
                    code.push_str(&format!("\"{}\"", strings.len() - 1));
                    mode = Mode::Normal;
                    i += 1;
                } else {
                    sbuf.push(ch);
                    i += 1;
                }
            }
            Mode::RawStr => {
                if ch == '"'
                    && i + rs_hashes < n
                    && chars[i + 1..i + 1 + rs_hashes].iter().all(|&c| c == '#')
                {
                    strings.push(std::mem::take(&mut sbuf));
                    code.push_str(&format!("\"{}\"", strings.len() - 1));
                    mode = Mode::Normal;
                    i += 1 + rs_hashes;
                } else {
                    sbuf.push(ch);
                    i += 1;
                }
            }
            Mode::Normal => {
                let prev = if i > 0 { chars[i - 1] } else { ' ' };
                if ch == '/' && i + 1 < n && chars[i + 1] == '/' {
                    mode = Mode::LineComment;
                    i += 2;
                } else if ch == '/' && i + 1 < n && chars[i + 1] == '*' {
                    mode = Mode::BlockComment;
                    bc_depth = 1;
                    i += 2;
                } else if ch == '"' {
                    mode = Mode::Str;
                    sbuf.clear();
                    i += 1;
                } else if (ch == 'r' || ch == 'b') && !is_ident(prev) {
                    // r"..." / r#"..."# / b"..." / br"..." raw and byte
                    // string prefixes.
                    let mut j = i + 1;
                    if ch == 'b' && j < n && chars[j] == 'r' {
                        j += 1;
                    }
                    let mut hashes = 0usize;
                    while j < n && chars[j] == '#' {
                        hashes += 1;
                        j += 1;
                    }
                    let next1 = if i + 1 < n { chars[i + 1] } else { ' ' };
                    let is_prefix = j < n
                        && chars[j] == '"'
                        && (hashes > 0
                            || (ch == 'r' && next1 == '"')
                            || (ch == 'b' && next1 == '"')
                            || (ch == 'b' && next1 == 'r'));
                    if is_prefix {
                        if hashes > 0 || ch == 'r' || next1 == 'r' {
                            mode = Mode::RawStr;
                            rs_hashes = hashes;
                        } else {
                            mode = Mode::Str; // b"..."
                        }
                        sbuf.clear();
                        i = j + 1;
                    } else {
                        code.push(ch);
                        i += 1;
                    }
                } else if ch == '\'' {
                    let nxt = if i + 1 < n { chars[i + 1] } else { ' ' };
                    if nxt == '\\' {
                        // Escaped char literal: skip to the closing quote.
                        let mut j = i + 2;
                        while j < n && chars[j] != '\'' {
                            j += 1;
                        }
                        code.push_str("' '");
                        i = j + 1;
                    } else if i + 2 < n && chars[i + 2] == '\'' {
                        code.push_str("' '");
                        i += 3;
                    } else {
                        code.push(ch); // lifetime
                        i += 1;
                    }
                } else {
                    code.push(ch);
                    i += 1;
                }
            }
        }
    }
    let pending = !code.is_empty()
        || !strings.is_empty()
        || !comments.is_empty()
        || !comment_buf.is_empty()
        || !matches!(mode, Mode::Normal);
    if pending {
        flush_line!();
    }
    lines
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_comments_and_strings() {
        let src = "let x = \"HashMap\"; // HashMap here\nlet y = 2; /* Instant */\n";
        let lines = tokenize(src);
        assert_eq!(lines.len(), 2);
        assert!(!lines[0].code.contains("HashMap"));
        assert_eq!(lines[0].strings, vec!["HashMap".to_string()]);
        assert_eq!(lines[0].comments, vec![" HashMap here".to_string()]);
        assert!(!lines[1].code.contains("Instant"));
        assert_eq!(lines[1].comments, vec![" Instant ".to_string()]);
    }

    #[test]
    fn raw_and_byte_strings() {
        let src = "let a = r#\"un\"safe\"#; let b = b\"panic!\"; let c = br#\"x\"#;\n";
        let lines = tokenize(src);
        assert!(!lines[0].code.contains("unsafe"));
        assert!(!lines[0].code.contains("panic"));
        assert_eq!(lines[0].strings.len(), 3);
        assert_eq!(lines[0].strings[0], "un\"safe");
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let src = "fn f<'a>(x: &'a str) { let c = '{'; let d = '\\n'; }\n";
        let lines = tokenize(src);
        // The brace inside the char literal must not leak into code.
        let braces = lines[0].code.matches('{').count();
        assert_eq!(braces, 1);
        assert!(lines[0].code.contains("'a"));
    }

    #[test]
    fn escaped_newline_keeps_line_numbers() {
        let src = "let m = \"one \\\ntwo\";\nlet after = 1;\n";
        let lines = tokenize(src);
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[2].num, 3);
        assert!(lines[2].code.contains("after"));
        // The continued literal is recorded on its closing line.
        assert_eq!(lines[1].strings, vec!["one two".to_string()]);
    }

    #[test]
    fn nested_block_comments() {
        let src = "/* outer /* inner */ still comment */ let x = 1;\n";
        let lines = tokenize(src);
        assert!(lines[0].code.contains("let x = 1;"));
        assert!(!lines[0].code.contains("outer"));
    }
}
