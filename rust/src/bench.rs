//! Micro-benchmark harness substrate (no criterion in the vendored set).
//!
//! Warmup + timed iterations with mean/stddev/p50/p95 reporting, a
//! text table formatter for paper-figure output, CSV export, and the
//! machine-readable `BENCH_*.json` perf trajectory (DESIGN.md §11):
//! every bench run **appends** one entry to the per-bench JSON file, so
//! the repo accumulates a perf history instead of overwriting it.
//!
//! ```text
//! { "schema": "sagebwd-bench-v1", "bench": "attention",
//!   "runs": [ { "threads_default": T, "rows": [
//!       { "op", "shape", "variant", "threads", "isa",
//!         "ns_per_iter", "tokens_per_s" } ... ] } ... ] }
//! ```
//!
//! `variant` distinguishes the engine reading: `naive` (retained scalar
//! reference), `blocked` (cache-blocked serial), `parallel` (blocked +
//! scoped-thread row partition) — or a kernel/engine name for composite
//! ops.  `isa` is the SIMD tier the row executed at (`scalar` | `avx2`
//! | `fma` — DESIGN.md §15), so the trajectory can compare tiers the
//! same way it compares thread counts.  `tokens_per_s` is `null` where
//! no token count is meaningful (raw GEMMs).  [`check_bench_json`]
//! validates this schema (the CI bench smoke).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::telemetry::trace;
use crate::util::json::{self, schema, Json};
use crate::util::stats;

/// One benchmark measurement series.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub samples_secs: Vec<f64>,
}

impl Measurement {
    pub fn mean(&self) -> f64 {
        stats::mean(&self.samples_secs)
    }

    pub fn stddev(&self) -> f64 {
        stats::stddev(&self.samples_secs)
    }

    pub fn p50(&self) -> f64 {
        stats::percentile(&self.samples_secs, 50.0)
    }

    pub fn p95(&self) -> f64 {
        stats::percentile(&self.samples_secs, 95.0)
    }

    /// Throughput in ops/sec given work-per-iteration.
    pub fn throughput(&self, work_per_iter: f64) -> f64 {
        work_per_iter / self.mean()
    }
}

/// Harness configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub iters: usize,
    /// Stop early once this much wall time has been spent measuring.
    pub max_secs: f64,
}

impl Default for BenchConfig {
    fn default() -> BenchConfig {
        BenchConfig {
            warmup_iters: 3,
            iters: 20,
            max_secs: 10.0,
        }
    }
}

/// Time `f` under the config; `f` should perform one full operation.
/// Samples are read off the telemetry span clock ([`trace::now_ns`]) —
/// the same monotonic base the trainer's `step_ms` series uses, so bench
/// numbers and training telemetry are directly comparable.
pub fn run(cfg: BenchConfig, name: &str, mut f: impl FnMut()) -> Measurement {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(cfg.iters);
    let budget0 = trace::now_ns();
    for _ in 0..cfg.iters {
        let t0 = trace::now_ns();
        f();
        samples.push(trace::now_ns().saturating_sub(t0) as f64 / 1e9);
        if trace::now_ns().saturating_sub(budget0) as f64 / 1e9 > cfg.max_secs
            && samples.len() >= 5
        {
            break;
        }
    }
    Measurement {
        name: name.to_string(),
        samples_secs: samples,
    }
}

/// The `BENCH_*.json` schema tag.
pub const BENCH_SCHEMA: &str = "sagebwd-bench-v1";

/// One machine-readable benchmark row.
#[derive(Debug, Clone)]
pub struct BenchRow {
    /// What was measured, e.g. `matmul_nt`, `attention_sage_fwdbwd`,
    /// `grad_step`.
    pub op: String,
    /// Problem size, e.g. `m1024_k64_n1024` or `n512_d64`.
    pub shape: String,
    /// `naive` | `blocked` | `parallel`, or a kernel/engine name.
    pub variant: String,
    /// Worker threads this row ran with.
    pub threads: usize,
    /// ISA tier this row ran at (`scalar` | `avx2` | `fma`), from
    /// `tensor::simd::IsaTier::as_str`.
    pub isa: String,
    pub ns_per_iter: f64,
    /// Tokens (sequence rows) processed per second; `None` where no token
    /// count is meaningful.
    pub tokens_per_s: Option<f64>,
}

impl BenchRow {
    fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("op", Json::from(self.op.as_str())),
            ("shape", Json::from(self.shape.as_str())),
            ("variant", Json::from(self.variant.as_str())),
            ("threads", Json::from(self.threads)),
            ("isa", Json::from(self.isa.as_str())),
            ("ns_per_iter", Json::from(self.ns_per_iter)),
            (
                "tokens_per_s",
                self.tokens_per_s.map(Json::from).unwrap_or(Json::Null),
            ),
        ])
    }
}

/// Append one run (a row set) to `path`, creating the file with the
/// `BENCH_SCHEMA` envelope when absent — the persisted perf trajectory.
pub fn append_bench_json(path: &Path, bench: &str, threads_default: usize, rows: &[BenchRow]) -> Result<()> {
    let mut doc = match std::fs::read_to_string(path) {
        Ok(text) if !text.trim().is_empty() => json::parse(&text)
            .with_context(|| format!("parsing existing {}", path.display()))?,
        _ => Json::from_pairs(vec![
            ("schema", Json::from(BENCH_SCHEMA)),
            ("bench", Json::from(bench)),
            ("runs", Json::Arr(Vec::new())),
        ]),
    };
    schema::expect_tag(&doc, BENCH_SCHEMA)
        .with_context(|| format!("{}", path.display()))?;
    let existing_bench = schema::str_field(&doc, "bench")?;
    if existing_bench != bench {
        bail!(
            "{} holds the {existing_bench:?} trajectory, refusing to append {bench:?} runs",
            path.display(),
        );
    }
    let run = Json::from_pairs(vec![
        ("threads_default", Json::from(threads_default)),
        ("rows", Json::Arr(rows.iter().map(BenchRow::to_json).collect())),
    ]);
    let mut runs = doc.get("runs")?.as_arr()?.to_vec();
    runs.push(run);
    doc.set("runs", Json::Arr(runs));
    std::fs::write(path, doc.to_string())
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

/// Validate a `BENCH_*.json` file against the schema; returns the total
/// row count across runs.  This is what `sagebwd bench-check` and the CI
/// bench smoke call.
pub fn check_bench_json(path: &Path) -> Result<usize> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let doc = json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
    schema::expect_tag(&doc, BENCH_SCHEMA)?;
    schema::str_field(&doc, "bench")?;
    let runs = schema::arr_field(&doc, "runs")?;
    let mut total = 0;
    for (ri, run) in runs.iter().enumerate() {
        let run_ctx = || format!("run {ri}");
        schema::usize_field(run, "threads_default").with_context(run_ctx)?;
        let rows = schema::arr_field(run, "rows").with_context(run_ctx)?;
        for (i, row) in rows.iter().enumerate() {
            let ctx = || format!("run {ri} row {i}");
            schema::str_field(row, "op").with_context(ctx)?;
            schema::str_field(row, "shape").with_context(ctx)?;
            schema::str_field(row, "variant").with_context(ctx)?;
            schema::usize_field(row, "threads").with_context(ctx)?;
            schema::str_field(row, "isa").with_context(ctx)?;
            let ns = schema::f64_field(row, "ns_per_iter").with_context(ctx)?;
            if !(ns > 0.0) {
                bail!("run {ri} row {i}: ns_per_iter {ns} must be positive");
            }
            schema::nullable_f64_field(row, "tokens_per_s").with_context(ctx)?;
            total += 1;
        }
    }
    Ok(total)
}

/// Fixed-width text table (the `cargo bench` human output).
pub struct Table {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.headers.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measurement_statistics() {
        let m = Measurement {
            name: "x".into(),
            samples_secs: vec![0.01, 0.02, 0.03],
        };
        assert!((m.mean() - 0.02).abs() < 1e-12);
        assert!((m.p50() - 0.02).abs() < 1e-12);
        assert!((m.throughput(1.0) - 50.0).abs() < 1e-6);
    }

    #[test]
    fn run_counts_iters() {
        let mut calls = 0;
        let cfg = BenchConfig {
            warmup_iters: 2,
            iters: 5,
            max_secs: 60.0,
        };
        let m = run(cfg, "noop", || calls += 1);
        assert_eq!(calls, 7); // 2 warmup + 5 timed
        assert_eq!(m.samples_secs.len(), 5);
    }

    #[test]
    fn bench_json_append_and_check_roundtrip() {
        let path = std::env::temp_dir().join(format!("sagebwd_bench_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let rows = vec![
            BenchRow {
                op: "matmul_nn".into(),
                shape: "m8_k8_n8".into(),
                variant: "naive".into(),
                threads: 1,
                isa: "scalar".into(),
                ns_per_iter: 10.0,
                tokens_per_s: None,
            },
            BenchRow {
                op: "attention_sage_fwd".into(),
                shape: "n128_d64".into(),
                variant: "sage".into(),
                threads: 4,
                isa: "avx2".into(),
                ns_per_iter: 99.5,
                tokens_per_s: Some(1.3e6),
            },
        ];
        append_bench_json(&path, "attention", 4, &rows).unwrap();
        assert_eq!(check_bench_json(&path).unwrap(), 2);
        // A second run appends to the trajectory instead of overwriting.
        append_bench_json(&path, "attention", 2, &rows[..1]).unwrap();
        assert_eq!(check_bench_json(&path).unwrap(), 3);
        // Appending a different bench's runs is refused (no silent
        // trajectory cross-contamination).
        assert!(append_bench_json(&path, "train_step", 1, &rows[..1]).is_err());
        // Missing row fields and wrong schema tags are rejected.
        std::fs::write(
            &path,
            r#"{"schema":"sagebwd-bench-v1","bench":"x","runs":[{"threads_default":1,"rows":[{"op":"a"}]}]}"#,
        )
        .unwrap();
        assert!(check_bench_json(&path).is_err());
        std::fs::write(&path, r#"{"schema":"other","bench":"x","runs":[]}"#).unwrap();
        assert!(check_bench_json(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "val"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["longer".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("longer"));
        assert_eq!(s.lines().count(), 4);
        assert!(t.to_csv().starts_with("name,val\n"));
    }
}
