//! Micro-benchmark harness substrate (no criterion in the vendored set).
//!
//! Warmup + timed iterations with mean/stddev/p50/p95 reporting, a
//! text table formatter for paper-figure output, and CSV export.

use std::time::Instant;

use crate::util::stats;

/// One benchmark measurement series.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub samples_secs: Vec<f64>,
}

impl Measurement {
    pub fn mean(&self) -> f64 {
        stats::mean(&self.samples_secs)
    }

    pub fn stddev(&self) -> f64 {
        stats::stddev(&self.samples_secs)
    }

    pub fn p50(&self) -> f64 {
        stats::percentile(&self.samples_secs, 50.0)
    }

    pub fn p95(&self) -> f64 {
        stats::percentile(&self.samples_secs, 95.0)
    }

    /// Throughput in ops/sec given work-per-iteration.
    pub fn throughput(&self, work_per_iter: f64) -> f64 {
        work_per_iter / self.mean()
    }
}

/// Harness configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub iters: usize,
    /// Stop early once this much wall time has been spent measuring.
    pub max_secs: f64,
}

impl Default for BenchConfig {
    fn default() -> BenchConfig {
        BenchConfig {
            warmup_iters: 3,
            iters: 20,
            max_secs: 10.0,
        }
    }
}

/// Time `f` under the config; `f` should perform one full operation.
pub fn run(cfg: BenchConfig, name: &str, mut f: impl FnMut()) -> Measurement {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(cfg.iters);
    let budget = Instant::now();
    for _ in 0..cfg.iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if budget.elapsed().as_secs_f64() > cfg.max_secs && samples.len() >= 5 {
            break;
        }
    }
    Measurement {
        name: name.to_string(),
        samples_secs: samples,
    }
}

/// Fixed-width text table (the `cargo bench` human output).
pub struct Table {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.headers.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measurement_statistics() {
        let m = Measurement {
            name: "x".into(),
            samples_secs: vec![0.01, 0.02, 0.03],
        };
        assert!((m.mean() - 0.02).abs() < 1e-12);
        assert!((m.p50() - 0.02).abs() < 1e-12);
        assert!((m.throughput(1.0) - 50.0).abs() < 1e-6);
    }

    #[test]
    fn run_counts_iters() {
        let mut calls = 0;
        let cfg = BenchConfig {
            warmup_iters: 2,
            iters: 5,
            max_secs: 60.0,
        };
        let m = run(cfg, "noop", || calls += 1);
        assert_eq!(calls, 7); // 2 warmup + 5 timed
        assert_eq!(m.samples_secs.len(), 5);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "val"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["longer".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("longer"));
        assert_eq!(s.lines().count(), 4);
        assert!(t.to_csv().starts_with("name,val\n"));
    }
}
