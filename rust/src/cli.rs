//! CLI argument parser substrate (no clap in the vendored set).
//!
//! Grammar: `sagebwd <subcommand> [--flag] [--key value]...` with
//! typed accessors, defaults, and generated usage text.
//!
//! Flags shared across subcommands (resolved in `main.rs`): `--artifacts`,
//! `--results`, and `--backend native|xla` — the kernel-executor selector
//! introduced with the native CPU backend (DESIGN.md §4; `native` needs no
//! artifacts, `xla` is the unchanged AOT path).  The native compute
//! engine's worker count is an *environment* knob, not a flag —
//! `SAGEBWD_THREADS` (DESIGN.md §11) — because it must also reach `cargo
//! test` / `cargo bench` binaries that never parse CLI options.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed arguments for one subcommand invocation.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut it = raw.into_iter().peekable();
        let subcommand = it.next().unwrap_or_default();
        let mut args = Args {
            subcommand,
            ..Default::default()
        };
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare '--' is not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if let Some(value) = it.next_if(|n| !n.starts_with("--")) {
                    args.options.insert(name.to_string(), value);
                } else {
                    args.flags.push(name.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || self.options.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects a number, got {v:?}")),
        }
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.opt(name)
            .ok_or_else(|| anyhow!("missing required option --{name}"))
    }

    /// Error out on unknown options (catches typos early).
    pub fn ensure_known(&self, known: &[&str]) -> Result<()> {
        for k in self.options.keys().chain(self.flags.iter()) {
            if !known.contains(&k.as_str()) {
                bail!(
                    "unknown option --{k}; known: {}",
                    known
                        .iter()
                        .map(|s| format!("--{s}"))
                        .collect::<Vec<_>>()
                        .join(", ")
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse("train foo bar");
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.positional, vec!["foo", "bar"]);
    }

    #[test]
    fn options_space_and_equals() {
        let a = parse("train --steps 100 --lr=3e-5");
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
        assert!((a.f64_or("lr", 0.0).unwrap() - 3e-5).abs() < 1e-12);
    }

    #[test]
    fn flags() {
        let a = parse("train --verbose --steps 5");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("x --a --b --c 3");
        assert!(a.flag("a") && a.flag("b"));
        assert_eq!(a.usize_or("c", 0).unwrap(), 3);
    }

    #[test]
    fn type_errors() {
        let a = parse("x --n abc");
        assert!(a.usize_or("n", 0).is_err());
    }

    #[test]
    fn require_and_unknown() {
        let a = parse("x --known 1 --oops 2");
        assert!(a.require("known").is_ok());
        assert!(a.require("missing").is_err());
        assert!(a.ensure_known(&["known"]).is_err());
        assert!(a.ensure_known(&["known", "oops"]).is_ok());
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.str_or("mode", "fast"), "fast");
        assert_eq!(a.usize_or("n", 7).unwrap(), 7);
    }
}
