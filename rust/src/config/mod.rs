//! Typed run configuration, JSON-(de)serializable.
//!
//! Mirrors the paper's §5.1 hyperparameters at our scaled substrate
//! (DESIGN.md §6 substitution table): the high/low tokens-per-step pair
//! preserves the paper's 2.1M/260K = 8× ratio.

use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

/// Attention variant names — must match `python/compile/configs.VARIANTS`.
pub const VARIANTS: &[&str] = &[
    "sage_qknorm",
    "sage_noqknorm",
    "fpa_qknorm",
    "fpa_noqknorm",
    "sage_qknorm_nosm",
    "sage_qknorm_qksm",
];

/// One pre-training run.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    /// Artifact variant (see [`VARIANTS`]).
    pub variant: String,
    /// Optimizer steps to run.
    pub steps: u64,
    /// Tokens per optimizer step (§4.3) — realized as
    /// `tokens_per_step / (microbatch × seq_len)` accumulated microbatches.
    pub tokens_per_step: u64,
    /// Warmup steps for the LR schedule (paper: 1k of 37.5k / 7.5k of 300k).
    pub warmup_steps: u64,
    /// Peak learning rate (paper §5.1: 3e-5; scaled runs may use larger).
    pub peak_lr: f64,
    /// Final LR as a fraction of peak (cosine floor).
    pub min_lr_frac: f64,
    /// RNG seed for init + data order.
    pub seed: u64,
    /// Checkpoint every N steps (0 = only at end).
    pub checkpoint_every: u64,
    /// Log every N steps.
    pub log_every: u64,
    /// Global-norm gradient clipping (0 = off).
    pub clip_norm: f64,
    /// Relative synthetic gradient-noise std (0 = off) — the §4.3
    /// hypothesis probe (see coordinator::noise).
    pub grad_noise_sigma: f64,
    /// Divergence ceiling on the per-step `max |QKᵀ/√d|` telemetry
    /// (§5.3): crossing it flags the run as diverged while the loss curve
    /// is still plottable.  Only engines that report the statistic (the
    /// native engine) can trip it; non-finite loss remains the backstop.
    pub max_attn_logit_ceiling: f64,
}

impl Default for TrainConfig {
    fn default() -> TrainConfig {
        TrainConfig {
            variant: "sage_qknorm".to_string(),
            steps: 200,
            tokens_per_step: 4096,
            warmup_steps: 20,
            peak_lr: 1e-3,
            min_lr_frac: 0.1,
            seed: 0,
            checkpoint_every: 0,
            log_every: 10,
            clip_norm: 0.0,
            grad_noise_sigma: 0.0,
            max_attn_logit_ceiling: 50.0,
        }
    }
}

impl TrainConfig {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("variant", self.variant.as_str().into()),
            ("steps", (self.steps as i64).into()),
            ("tokens_per_step", (self.tokens_per_step as i64).into()),
            ("warmup_steps", (self.warmup_steps as i64).into()),
            ("peak_lr", self.peak_lr.into()),
            ("min_lr_frac", self.min_lr_frac.into()),
            ("seed", (self.seed as i64).into()),
            ("checkpoint_every", (self.checkpoint_every as i64).into()),
            ("log_every", (self.log_every as i64).into()),
            ("clip_norm", self.clip_norm.into()),
            ("grad_noise_sigma", self.grad_noise_sigma.into()),
            ("max_attn_logit_ceiling", self.max_attn_logit_ceiling.into()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<TrainConfig> {
        let d = TrainConfig::default();
        let get_u = |k: &str, dflt: u64| -> Result<u64> {
            match j.get_opt(k) {
                Some(v) => Ok(v.as_i64()? as u64),
                None => Ok(dflt),
            }
        };
        let get_f = |k: &str, dflt: f64| -> Result<f64> {
            match j.get_opt(k) {
                Some(v) => v.as_f64(),
                None => Ok(dflt),
            }
        };
        let cfg = TrainConfig {
            variant: match j.get_opt("variant") {
                Some(v) => v.as_str()?.to_string(),
                None => d.variant,
            },
            steps: get_u("steps", d.steps)?,
            tokens_per_step: get_u("tokens_per_step", d.tokens_per_step)?,
            warmup_steps: get_u("warmup_steps", d.warmup_steps)?,
            peak_lr: get_f("peak_lr", d.peak_lr)?,
            min_lr_frac: get_f("min_lr_frac", d.min_lr_frac)?,
            seed: get_u("seed", d.seed)?,
            checkpoint_every: get_u("checkpoint_every", d.checkpoint_every)?,
            log_every: get_u("log_every", d.log_every)?,
            clip_norm: get_f("clip_norm", d.clip_norm)?,
            grad_noise_sigma: get_f("grad_noise_sigma", d.grad_noise_sigma)?,
            max_attn_logit_ceiling: get_f("max_attn_logit_ceiling", d.max_attn_logit_ceiling)?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &Path) -> Result<TrainConfig> {
        let text = fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        TrainConfig::from_json(&json::parse(&text)?)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing config {}", path.display()))
    }

    pub fn validate(&self) -> Result<()> {
        if !VARIANTS.contains(&self.variant.as_str()) {
            bail!("unknown variant {:?}; known: {VARIANTS:?}", self.variant);
        }
        if self.steps == 0 {
            bail!("steps must be > 0");
        }
        if self.tokens_per_step == 0 {
            bail!("tokens_per_step must be > 0");
        }
        if self.warmup_steps >= self.steps {
            bail!(
                "warmup_steps ({}) must be < steps ({})",
                self.warmup_steps,
                self.steps
            );
        }
        if !(self.peak_lr > 0.0) {
            bail!("peak_lr must be positive");
        }
        if !(0.0..=1.0).contains(&self.min_lr_frac) {
            bail!("min_lr_frac must be in [0, 1]");
        }
        if self.clip_norm < 0.0 || self.grad_noise_sigma < 0.0 {
            bail!("clip_norm and grad_noise_sigma must be non-negative");
        }
        if !(self.max_attn_logit_ceiling > 0.0) {
            bail!("max_attn_logit_ceiling must be positive");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        TrainConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let cfg = TrainConfig {
            variant: "fpa_qknorm".into(),
            steps: 1000,
            tokens_per_step: 32_768,
            ..Default::default()
        };
        let j = cfg.to_json();
        let back = TrainConfig::from_json(&j).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn partial_json_uses_defaults() {
        let j = json::parse(r#"{"steps": 50, "warmup_steps": 5}"#).unwrap();
        let cfg = TrainConfig::from_json(&j).unwrap();
        assert_eq!(cfg.steps, 50);
        assert_eq!(cfg.variant, "sage_qknorm");
    }

    #[test]
    fn validation_catches_errors() {
        let mut cfg = TrainConfig::default();
        cfg.variant = "bogus".into();
        assert!(cfg.validate().is_err());
        cfg = TrainConfig::default();
        cfg.warmup_steps = cfg.steps;
        assert!(cfg.validate().is_err());
        cfg = TrainConfig::default();
        cfg.min_lr_frac = 2.0;
        assert!(cfg.validate().is_err());
        cfg = TrainConfig::default();
        cfg.max_attn_logit_ceiling = 0.0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn file_roundtrip() {
        let cfg = TrainConfig::default();
        let path = std::env::temp_dir().join(format!("sagebwd_cfg_{}.json", std::process::id()));
        cfg.save(&path).unwrap();
        assert_eq!(TrainConfig::load(&path).unwrap(), cfg);
        std::fs::remove_file(&path).unwrap();
    }
}
