//! Gradient accumulation — the mechanism realizing the paper's
//! *tokens-per-step* axis (§4.3).
//!
//! One optimizer step = `microbatches_per_step` executions of the AOT
//! `grad_step` artifact, whose gradients are averaged here before a single
//! `apply_step`.  TPS = microbatches_per_step × microbatch × seq_len; the
//! paper's 2.1M-vs-260K comparison is this knob (batch-size route), holding
//! sequence length fixed.

use anyhow::{bail, Result};

use crate::tensor::Tensor;

/// Averages gradients (and losses) over the microbatches of one step.
#[derive(Debug)]
pub struct GradAccumulator {
    grads: Vec<Tensor>,
    loss_sum: f64,
    count: u32,
}

impl GradAccumulator {
    /// `shapes`: gradient leaf shapes in parameter (ABI) order.
    pub fn new(shapes: &[Vec<usize>]) -> GradAccumulator {
        GradAccumulator {
            grads: shapes.iter().map(|s| Tensor::zeros(s)).collect(),
            loss_sum: 0.0,
            count: 0,
        }
    }

    pub fn num_leaves(&self) -> usize {
        self.grads.len()
    }

    pub fn count(&self) -> u32 {
        self.count
    }

    /// Add one microbatch's (loss, grads).
    pub fn add(&mut self, loss: f32, grads: &[Tensor]) -> Result<()> {
        if grads.len() != self.grads.len() {
            bail!(
                "accumulator has {} leaves, got {}",
                self.grads.len(),
                grads.len()
            );
        }
        for (acc, g) in self.grads.iter_mut().zip(grads) {
            if acc.shape != g.shape {
                bail!("gradient shape mismatch: {:?} vs {:?}", acc.shape, g.shape);
            }
            acc.add_assign(g);
        }
        self.loss_sum += loss as f64;
        self.count += 1;
        Ok(())
    }

    /// Finish the step: return (mean loss, mean grads) and reset.
    pub fn take_mean(&mut self) -> Result<(f64, Vec<Tensor>)> {
        if self.count == 0 {
            bail!("take_mean on empty accumulator");
        }
        let inv = 1.0 / self.count as f32;
        let mut grads = Vec::with_capacity(self.grads.len());
        for acc in self.grads.iter_mut() {
            let mut g = acc.clone();
            g.scale(inv);
            acc.fill(0.0);
            grads.push(g);
        }
        let loss = self.loss_sum / self.count as f64;
        self.loss_sum = 0.0;
        self.count = 0;
        Ok((loss, grads))
    }

    /// Global gradient norm of the current (unaveraged) accumulation.
    pub fn grad_norm(&self) -> f64 {
        self.grads
            .iter()
            .map(|g| g.data.iter().map(|&x| x as f64 * x as f64).sum::<f64>())
            .sum::<f64>()
            .sqrt()
    }

    /// True if any accumulated gradient is non-finite (divergence guard).
    pub fn any_nonfinite(&self) -> bool {
        self.grads.iter().any(|g| !g.is_finite())
    }
}

/// Locate the first non-finite gradient element: `(leaf name, flat index,
/// value)` — so a divergence reason can say *which* gradient went bad
/// ("non-finite gradient in blk0.k_proj[37] (NaN)") instead of a bare
/// boolean.  `names` and `grads` are in the same (ABI) order; a missing
/// name falls back to the leaf index.
pub fn first_nonfinite_site<'a>(
    names: &'a [String],
    grads: &[Tensor],
) -> Option<(&'a str, usize, f32)> {
    for (i, g) in grads.iter().enumerate() {
        if let Some(idx) = g.data.iter().position(|x| !x.is_finite()) {
            let name = names.get(i).map(String::as_str).unwrap_or("?");
            return Some((name, idx, g.data[idx]));
        }
    }
    None
}

/// Derive microbatches-per-step from a tokens-per-step target.
/// Errors when TPS is not an exact multiple (silent truncation would make
/// reported TPS a lie).
pub fn microbatches_for_tps(tokens_per_step: u64, microbatch: u64, seq_len: u64) -> Result<u64> {
    let per_micro = microbatch * seq_len;
    if per_micro == 0 || tokens_per_step == 0 || tokens_per_step % per_micro != 0 {
        bail!(
            "tokens_per_step {tokens_per_step} must be a multiple of microbatch×seq_len = {per_micro}"
        );
    }
    Ok(tokens_per_step / per_micro)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{check, Gen};

    fn t(data: Vec<f32>) -> Tensor {
        Tensor::from_vec(&[data.len()], data).unwrap()
    }

    #[test]
    fn mean_of_two_microbatches() {
        let mut acc = GradAccumulator::new(&[vec![2]]);
        acc.add(1.0, &[t(vec![2.0, 4.0])]).unwrap();
        acc.add(3.0, &[t(vec![4.0, 8.0])]).unwrap();
        let (loss, grads) = acc.take_mean().unwrap();
        assert_eq!(loss, 2.0);
        assert_eq!(grads[0].data, vec![3.0, 6.0]);
        assert_eq!(acc.count(), 0); // reset
    }

    #[test]
    fn empty_take_fails() {
        let mut acc = GradAccumulator::new(&[vec![1]]);
        assert!(acc.take_mean().is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut acc = GradAccumulator::new(&[vec![2]]);
        assert!(acc.add(0.0, &[t(vec![1.0])]).is_err());
        assert!(acc.add(0.0, &[]).is_err());
    }

    #[test]
    fn nonfinite_detection() {
        let mut acc = GradAccumulator::new(&[vec![2]]);
        acc.add(0.0, &[t(vec![1.0, f32::INFINITY])]).unwrap();
        assert!(acc.any_nonfinite());
    }

    #[test]
    fn first_nonfinite_site_names_the_leaf_and_index() {
        let names: Vec<String> = vec!["embed".into(), "blk0.k_proj".into()];
        // Seed a NaN at a known slab position via the fault plane's
        // deterministic picker, then confirm the reporter finds it.
        let mut grads = vec![t(vec![1.0, 2.0, 3.0]), t(vec![0.5, 0.5, 0.5, 0.5])];
        crate::util::faults::install(
            crate::util::faults::parse_plan("seed=5; nan@0:k_proj").unwrap(),
        );
        crate::util::faults::begin_step(0);
        let lens: Vec<usize> = grads.iter().map(|g| g.data.len()).collect();
        let (leaf, idx) = crate::util::faults::take_nan_slab(&names, &lens).unwrap();
        assert_eq!(leaf, 1);
        grads[leaf].data[idx] = f32::NAN;
        crate::util::faults::clear();

        let (name, site, val) = first_nonfinite_site(&names, &grads).unwrap();
        assert_eq!(name, "blk0.k_proj");
        assert_eq!(site, idx);
        assert!(val.is_nan());

        // Clean gradients report nothing.
        assert!(first_nonfinite_site(&names, &[t(vec![1.0]), t(vec![2.0])]).is_none());
        // More grads than names: falls back to "?" instead of panicking.
        let (name, _, _) =
            first_nonfinite_site(&names[..1].to_vec(), &[t(vec![1.0]), t(vec![f32::NAN])])
                .unwrap();
        assert_eq!(name, "?");
    }

    #[test]
    fn tps_division() {
        assert_eq!(microbatches_for_tps(4096, 2, 128).unwrap(), 16);
        assert_eq!(microbatches_for_tps(32_768, 2, 128).unwrap(), 128);
        assert!(microbatches_for_tps(1000, 2, 128).is_err());
        assert!(microbatches_for_tps(0, 2, 128).is_err());
    }

    #[test]
    fn accumulation_is_linear() {
        // Property: mean of k identical microbatches equals the microbatch.
        check("accumulate k identical", |g: &mut Gen| {
            let k = g.usize_in(1, 8);
            let len = g.usize_in(1, 32);
            let grad = Tensor::from_vec(&[len], g.vec_f32(len, 1.0)).unwrap();
            let mut acc = GradAccumulator::new(&[vec![len]]);
            for _ in 0..k {
                acc.add(2.5, &[grad.clone()]).unwrap();
            }
            let (loss, grads) = acc.take_mean().unwrap();
            if (loss - 2.5).abs() > 1e-6 {
                return Err(format!("loss {loss}"));
            }
            for (a, b) in grads[0].data.iter().zip(&grad.data) {
                if (a - b).abs() > 1e-4 * b.abs().max(1.0) {
                    return Err(format!("grad mismatch {a} vs {b}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn mean_invariant_to_order() {
        // Property: accumulation commutes (floating error aside).
        check("order invariance", |g: &mut Gen| {
            let len = g.usize_in(1, 16);
            let a = Tensor::from_vec(&[len], g.vec_f32(len, 1.0)).unwrap();
            let b = Tensor::from_vec(&[len], g.vec_f32(len, 1.0)).unwrap();
            let run = |x: &Tensor, y: &Tensor| {
                let mut acc = GradAccumulator::new(&[vec![len]]);
                acc.add(1.0, std::slice::from_ref(&x.clone())).unwrap();
                acc.add(2.0, std::slice::from_ref(&y.clone())).unwrap();
                acc.take_mean().unwrap()
            };
            let (l1, g1) = run(&a, &b);
            let (l2, g2) = run(&b, &a);
            if (l1 - l2).abs() > 1e-9 {
                return Err("loss not symmetric".into());
            }
            for (x, y) in g1[0].data.iter().zip(&g2[0].data) {
                if (x - y).abs() > 1e-5 {
                    return Err("grads not symmetric".into());
                }
            }
            Ok(())
        });
    }
}
