//! Checkpoint substrate: a simple self-describing binary format for the
//! full training state — the safetensors stand-in.
//!
//! **Format v2** (`SBWD0002`) covers the trainer-side state needed to
//! resume bit-identically: parameters, AdamW moments (`m.*`/`v.*` name
//! prefixes), the optimizer step, tokens seen, and the trainer's
//! noise-RNG state.  (The data-stream position is *not* stored — the
//! batcher is a pure function of (seed, shard), so callers replay it to
//! the checkpointed step, as the resume tests do.)  v1 (`SBWD0001`,
//! pre-`TrainEngine`) had no version-bump story and no RNG; loading one
//! now fails with a clear "unsupported version" error instead of
//! decoding garbage.
//!
//! Layout (little-endian):
//! ```text
//! magic  b"SBWD0002"
//! u64    step
//! u64    tokens_seen
//! u8     rng_present
//! if rng_present: u64 state, u64 inc, u8 has_spare, f64 spare
//! u32    num_tensors
//! per tensor:
//!   u32 name_len, name bytes (UTF-8)
//!   u32 ndim, u64×ndim dims
//!   u64 data_len_bytes, f32×(data_len/4) data
//! ```

use std::fs;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

const MAGIC_V2: &[u8; 8] = b"SBWD0002";
const MAGIC_V1: &[u8; 8] = b"SBWD0001";

/// Serialized PRNG state (the trainer's noise stream).
#[derive(Debug, Clone, PartialEq)]
pub struct RngState {
    pub state: u64,
    pub inc: u64,
    pub gauss_spare: Option<f64>,
}

impl RngState {
    pub fn from_rng(rng: &Pcg64) -> RngState {
        let (state, inc, gauss_spare) = rng.raw_state();
        RngState {
            state,
            inc,
            gauss_spare,
        }
    }

    pub fn to_rng(&self) -> Pcg64 {
        Pcg64::from_raw_state(self.state, self.inc, self.gauss_spare)
    }
}

/// A named tensor collection + run counters + optional RNG state.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub tokens_seen: u64,
    pub rng: Option<RngState>,
    pub tensors: Vec<(String, Tensor)>,
}

impl Checkpoint {
    /// Encode to the SBWD0002 wire format.  The byte form (not the file)
    /// is the canonical artifact: the supervisor stores checkpoints in the
    /// content-addressed registry by the sha256 of exactly these bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC_V2);
        buf.extend_from_slice(&self.step.to_le_bytes());
        buf.extend_from_slice(&self.tokens_seen.to_le_bytes());
        match &self.rng {
            Some(r) => {
                buf.push(1);
                buf.extend_from_slice(&r.state.to_le_bytes());
                buf.extend_from_slice(&r.inc.to_le_bytes());
                buf.push(u8::from(r.gauss_spare.is_some()));
                buf.extend_from_slice(&r.gauss_spare.unwrap_or(0.0).to_le_bytes());
            }
            None => buf.push(0),
        }
        buf.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for (name, t) in &self.tensors {
            buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
            buf.extend_from_slice(name.as_bytes());
            buf.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
            for &d in &t.shape {
                buf.extend_from_slice(&(d as u64).to_le_bytes());
            }
            buf.extend_from_slice(&((t.data.len() * 4) as u64).to_le_bytes());
            for &x in &t.data {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        buf
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let buf = self.to_bytes();
        // Atomic-ish write: temp file then rename.
        let tmp = path.with_extension("tmp");
        fs::File::create(&tmp)
            .and_then(|mut f| f.write_all(&buf))
            .with_context(|| format!("writing checkpoint {}", tmp.display()))?;
        fs::rename(&tmp, path)
            .with_context(|| format!("renaming checkpoint into {}", path.display()))?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut buf = Vec::new();
        fs::File::open(path)
            .and_then(|mut f| f.read_to_end(&mut buf))
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        Checkpoint::from_bytes(&buf)
            .with_context(|| format!("decoding checkpoint {}", path.display()))
    }

    /// Decode the SBWD0002 wire format (hardened: every length field is
    /// bounds-checked against the remaining bytes before use).
    pub fn from_bytes(buf: &[u8]) -> Result<Checkpoint> {
        let mut pos = 0usize;
        // `n` is attacker-controlled for name/dim/data reads (it comes from
        // length fields in the file), so the bound check must not itself
        // overflow: `*pos + n` with n near usize::MAX would wrap and pass.
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            let end = pos
                .checked_add(n)
                .filter(|&end| end <= buf.len())
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "truncated checkpoint: need {n} bytes at offset {} but file has {}",
                        *pos,
                        buf.len()
                    )
                })?;
            let s = &buf[*pos..end];
            *pos = end;
            Ok(s)
        };
        // Fixed-width reads: `take` already guarantees the length, so the
        // array conversions only fail on an internal logic error — which
        // must surface as a corrupt-checkpoint error, not a panic.
        let take8 = |pos: &mut usize| -> Result<[u8; 8]> {
            take(pos, 8)?
                .try_into()
                .map_err(|_| anyhow::anyhow!("internal: take(8) returned a wrong-sized slice"))
        };
        let take4 = |pos: &mut usize| -> Result<[u8; 4]> {
            take(pos, 4)?
                .try_into()
                .map_err(|_| anyhow::anyhow!("internal: take(4) returned a wrong-sized slice"))
        };
        let magic = take(&mut pos, 8)?;
        if magic == MAGIC_V1 {
            bail!(
                "format-v1 checkpoint (pre-TrainEngine: no version story, no \
                 optimizer RNG); v1 is no longer readable — re-run training to produce \
                 a v2 (SBWD0002) checkpoint"
            );
        }
        if magic != MAGIC_V2 {
            bail!("bad checkpoint magic (not an SBWD checkpoint)");
        }
        let step = u64::from_le_bytes(take8(&mut pos)?);
        let tokens_seen = u64::from_le_bytes(take8(&mut pos)?);
        let rng = match take(&mut pos, 1)?[0] {
            0 => None,
            1 => {
                let state = u64::from_le_bytes(take8(&mut pos)?);
                let inc = u64::from_le_bytes(take8(&mut pos)?);
                let has_spare = take(&mut pos, 1)?[0];
                let spare = f64::from_le_bytes(take8(&mut pos)?);
                Some(RngState {
                    state,
                    inc,
                    gauss_spare: (has_spare != 0).then_some(spare),
                })
            }
            other => bail!("corrupt rng_present flag {other}"),
        };
        let count = u32::from_le_bytes(take4(&mut pos)?) as usize;
        // Never size an allocation from an untrusted count alone: every
        // tensor record occupies at least 16 bytes (name_len + ndim +
        // data_len fields), so a count the remaining bytes cannot hold is
        // corruption, not a 4-billion-entry checkpoint.
        let remaining = buf.len() - pos;
        if count > remaining / 16 {
            bail!(
                "corrupt checkpoint: claims {count} tensors but only {remaining} bytes remain"
            );
        }
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            let name_len = u32::from_le_bytes(take4(&mut pos)?) as usize;
            let name = String::from_utf8(take(&mut pos, name_len)?.to_vec())
                .context("non-UTF-8 tensor name")?;
            let ndim = u32::from_le_bytes(take4(&mut pos)?) as usize;
            if ndim > (buf.len() - pos) / 8 {
                bail!(
                    "tensor {name}: claims {ndim} dims but only {} bytes remain",
                    buf.len() - pos
                );
            }
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(u64::from_le_bytes(take8(&mut pos)?) as usize);
            }
            // Keep the declared length in u64 until it has been checked
            // against the file: `as usize` first would silently truncate a
            // huge value on 32-bit targets and read the wrong span.
            let data_bytes_u64 = u64::from_le_bytes(take8(&mut pos)?);
            if data_bytes_u64 > (buf.len() - pos) as u64 {
                bail!(
                    "tensor {name}: claims {data_bytes_u64} data bytes but only {} remain",
                    buf.len() - pos
                );
            }
            let data_bytes = data_bytes_u64 as usize;
            if data_bytes % 4 != 0 {
                bail!("tensor {name}: data length {data_bytes} not a multiple of 4");
            }
            let raw = take(&mut pos, data_bytes)?;
            let data: Vec<f32> = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.push((name, Tensor::from_vec(&shape, data)?));
        }
        if pos != buf.len() {
            bail!("trailing bytes in checkpoint");
        }
        Ok(Checkpoint {
            step,
            tokens_seen,
            rng,
            tensors,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn temp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("sagebwd_{}_{name}", std::process::id()))
    }

    #[test]
    fn roundtrip_with_rng() {
        let mut rng = Pcg64::new(0, 0);
        let mut noise = Pcg64::new(9, 1);
        noise.gaussian(); // odd draw count → spare cached
        let ckpt = Checkpoint {
            step: 1234,
            tokens_seen: 1234 * 512,
            rng: Some(RngState::from_rng(&noise)),
            tensors: vec![
                ("embed".into(), Tensor::randn(&[8, 4], 1.0, &mut rng)),
                ("m.embed".into(), Tensor::randn(&[8, 4], 1.0, &mut rng)),
                ("scalar".into(), Tensor::scalar(2.5)),
            ],
        };
        let path = temp("rt.ckpt");
        ckpt.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ckpt, back);
        // The restored RNG continues the exact stream.
        let mut restored = back.rng.unwrap().to_rng();
        for _ in 0..8 {
            assert_eq!(noise.gaussian(), restored.gaussian());
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn roundtrip_without_rng() {
        let ckpt = Checkpoint {
            step: 7,
            tokens_seen: 0,
            rng: None,
            tensors: vec![("x".into(), Tensor::zeros(&[3]))],
        };
        let path = temp("nrng.ckpt");
        ckpt.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), ckpt);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn byte_roundtrip_without_filesystem() {
        let mut noise = Pcg64::new(3, 7);
        noise.gaussian();
        let ckpt = Checkpoint {
            step: 11,
            tokens_seen: 11 * 256,
            rng: Some(RngState::from_rng(&noise)),
            tensors: vec![("w".into(), Tensor::scalar(0.5))],
        };
        let bytes = ckpt.to_bytes();
        assert_eq!(Checkpoint::from_bytes(&bytes).unwrap(), ckpt);
        // The byte form is what `save` writes, so registry-stored bytes
        // and file checkpoints are interchangeable.
        let path = temp("bytes.ckpt");
        ckpt.save(&path).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), bytes);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn v1_checkpoint_fails_with_version_error() {
        let path = temp("v1.ckpt");
        // A minimal v1 header: old magic + step + zero tensors.
        let mut buf = b"SBWD0001".to_vec();
        buf.extend_from_slice(&42u64.to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes());
        std::fs::write(&path, &buf).unwrap();
        let err = format!("{:#}", Checkpoint::load(&path).unwrap_err());
        assert!(err.contains("format-v1"), "unhelpful v1 error: {err}");
        assert!(err.contains("SBWD0002"), "error must name the current format: {err}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn corrupt_magic_rejected() {
        let path = temp("bad.ckpt");
        std::fs::write(&path, b"NOTMAGIC rest").unwrap();
        let err = format!("{:#}", Checkpoint::load(&path).unwrap_err());
        assert!(err.contains("magic"));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn truncated_rejected() {
        let ckpt = Checkpoint {
            step: 1,
            tokens_seen: 64,
            rng: None,
            tensors: vec![("x".into(), Tensor::zeros(&[16]))],
        };
        let path = temp("trunc.ckpt");
        ckpt.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 7]).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    /// Valid one-tensor checkpoint bytes for corruption tests.
    fn valid_bytes(name: &str) -> Vec<u8> {
        let ckpt = Checkpoint {
            step: 5,
            tokens_seen: 320,
            rng: None,
            tensors: vec![("w".into(), Tensor::zeros(&[2, 3]))],
        };
        let path = temp(name);
        ckpt.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::remove_file(&path).unwrap();
        bytes
    }

    fn load_err(name: &str, bytes: &[u8]) -> String {
        let path = temp(name);
        std::fs::write(&path, bytes).unwrap();
        let err = format!("{:#}", Checkpoint::load(&path).unwrap_err());
        std::fs::remove_file(&path).unwrap();
        err
    }

    #[test]
    fn trailing_garbage_rejected() {
        let mut bytes = valid_bytes("tg_src.ckpt");
        bytes.extend_from_slice(b"extra junk");
        let err = load_err("tg.ckpt", &bytes);
        assert!(err.contains("trailing"), "{err}");
    }

    #[test]
    fn name_len_overflow_rejected() {
        // Patch the first tensor's name_len field (right after the u32
        // tensor count) to u32::MAX; the name would run past EOF.
        let mut bytes = valid_bytes("nl_src.ckpt");
        let count_off = 8 + 8 + 8 + 1; // magic + step + tokens + rng_present(0)
        let name_len_off = count_off + 4;
        bytes[name_len_off..name_len_off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = load_err("nl.ckpt", &bytes);
        assert!(err.contains("truncated"), "must fail cleanly, got: {err}");
    }

    #[test]
    fn data_len_overflow_rejected() {
        // Patch data_len_bytes to u64::MAX: with a naive `pos + n` bound
        // check this wraps around and reads out of bounds (or panics);
        // it must instead return a clear error.
        let mut bytes = valid_bytes("dl_src.ckpt");
        let count_off = 8 + 8 + 8 + 1;
        // count(4) + name_len(4) + name("w",1) + ndim(4) + dims(2×8)
        let data_len_off = count_off + 4 + 4 + 1 + 4 + 16;
        bytes[data_len_off..data_len_off + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        let err = load_err("dl.ckpt", &bytes);
        assert!(
            err.contains("data bytes") || err.contains("truncated"),
            "must fail cleanly, got: {err}"
        );
    }

    #[test]
    fn huge_ndim_rejected() {
        let mut bytes = valid_bytes("nd_src.ckpt");
        let count_off = 8 + 8 + 8 + 1;
        let ndim_off = count_off + 4 + 4 + 1; // + name_len + name("w")
        bytes[ndim_off..ndim_off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = load_err("nd.ckpt", &bytes);
        assert!(err.contains("dims"), "must fail before allocating, got: {err}");
    }

    #[test]
    fn huge_tensor_count_rejected() {
        let mut bytes = valid_bytes("tc_src.ckpt");
        let count_off = 8 + 8 + 8 + 1;
        bytes[count_off..count_off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = load_err("tc.ckpt", &bytes);
        assert!(err.contains("tensors"), "must fail before allocating, got: {err}");
    }

    #[test]
    fn empty_checkpoint() {
        let ckpt = Checkpoint {
            step: 0,
            tokens_seen: 0,
            rng: None,
            tensors: vec![],
        };
        let path = temp("empty.ckpt");
        ckpt.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), ckpt);
        std::fs::remove_file(&path).unwrap();
    }
}
