//! Checkpoint substrate: a simple self-describing binary format for
//! (params, optimizer moments, step) — the safetensors stand-in.
//!
//! Layout (little-endian):
//! ```text
//! magic  b"SBWD0001"
//! u64    step
//! u32    num_tensors
//! per tensor:
//!   u32 name_len, name bytes (UTF-8)
//!   u32 ndim, u64×ndim dims
//!   u64 data_len_bytes, f32×(data_len/4) data
//! ```

use std::fs;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

const MAGIC: &[u8; 8] = b"SBWD0001";

/// A named tensor collection + step counter.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub tensors: Vec<(String, Tensor)>,
}

impl Checkpoint {
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&self.step.to_le_bytes());
        buf.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for (name, t) in &self.tensors {
            buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
            buf.extend_from_slice(name.as_bytes());
            buf.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
            for &d in &t.shape {
                buf.extend_from_slice(&(d as u64).to_le_bytes());
            }
            buf.extend_from_slice(&((t.data.len() * 4) as u64).to_le_bytes());
            for &x in &t.data {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        // Atomic-ish write: temp file then rename.
        let tmp = path.with_extension("tmp");
        fs::File::create(&tmp)
            .and_then(|mut f| f.write_all(&buf))
            .with_context(|| format!("writing checkpoint {}", tmp.display()))?;
        fs::rename(&tmp, path)
            .with_context(|| format!("renaming checkpoint into {}", path.display()))?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut buf = Vec::new();
        fs::File::open(path)
            .and_then(|mut f| f.read_to_end(&mut buf))
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if *pos + n > buf.len() {
                bail!("truncated checkpoint at byte {}", *pos);
            }
            let s = &buf[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        if take(&mut pos, 8)? != MAGIC {
            bail!("bad checkpoint magic in {}", path.display());
        }
        let step = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
        let count = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        let mut tensors = Vec::with_capacity(count as usize);
        for _ in 0..count {
            let name_len = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
            let name = String::from_utf8(take(&mut pos, name_len)?.to_vec())
                .context("non-UTF-8 tensor name")?;
            let ndim = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()) as usize);
            }
            let data_bytes = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()) as usize;
            if data_bytes % 4 != 0 {
                bail!("tensor {name}: data length {data_bytes} not a multiple of 4");
            }
            let raw = take(&mut pos, data_bytes)?;
            let data: Vec<f32> = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            tensors.push((name, Tensor::from_vec(&shape, data)?));
        }
        if pos != buf.len() {
            bail!("trailing bytes in checkpoint {}", path.display());
        }
        Ok(Checkpoint { step, tensors })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn temp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("sagebwd_{}_{name}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let mut rng = Pcg64::new(0, 0);
        let ckpt = Checkpoint {
            step: 1234,
            tensors: vec![
                ("embed".into(), Tensor::randn(&[8, 4], 1.0, &mut rng)),
                ("scalar".into(), Tensor::scalar(2.5)),
            ],
        };
        let path = temp("rt.ckpt");
        ckpt.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ckpt, back);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn corrupt_magic_rejected() {
        let path = temp("bad.ckpt");
        std::fs::write(&path, b"NOTMAGIC rest").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn truncated_rejected() {
        let ckpt = Checkpoint {
            step: 1,
            tensors: vec![("x".into(), Tensor::zeros(&[16]))],
        };
        let path = temp("trunc.ckpt");
        ckpt.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 7]).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn empty_checkpoint() {
        let ckpt = Checkpoint {
            step: 0,
            tensors: vec![],
        };
        let path = temp("empty.ckpt");
        ckpt.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), ckpt);
        std::fs::remove_file(&path).unwrap();
    }
}
