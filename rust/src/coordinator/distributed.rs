//! Data-parallel distributed runtime: leader/worker pre-training.
//!
//! The paper's TPS experiments realize a 2.1M-token step with a global
//! batch of 512 across devices; this module is that topology on our
//! substrate: N worker threads, each owning a *private* PJRT client (the
//! `xla` client is not `Send`) with its own compiled `grad_step`
//! executable and its own deterministic data shard.  One optimizer step:
//!
//! ```text
//! leader: broadcast params (Arc<Vec<Tensor>>) ──▶ workers
//! worker i: upload params once, run k microbatches on shard i,
//!           locally pre-reduce (sum) gradients            ──▶ leader
//! leader: tree-reduce worker sums, average, apply AdamW (own client)
//! ```
//!
//! Determinism: shard i's batch stream is a pure function of (seed, i),
//! so results are independent of worker scheduling; the reduction is a
//! fixed-order tree (floating-point associativity pinned).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{bail, Context, Result};

use crate::config::TrainConfig;
use crate::coordinator::schedule::CosineSchedule;
use crate::data::{Batcher, Tokenizer};
use crate::runtime::literal::f32_from_literal;
use crate::runtime::{Runtime, TensorSpec};
use crate::telemetry::{Log, Metrics};
use crate::tensor::Tensor;

enum Task {
    /// Run `microbatches` on the worker's shard with these parameters.
    Run {
        params: Arc<Vec<Tensor>>,
        microbatches: u32,
    },
    Shutdown,
}

struct TaskResult {
    worker: usize,
    loss_sum: f64,
    count: u32,
    /// Locally summed (not averaged) gradients.
    grads: Vec<Tensor>,
}

struct WorkerHandle {
    tx: Sender<Task>,
    handle: Option<JoinHandle<()>>,
}

/// Pool of grad-step workers, one PJRT client each.
pub struct WorkerPool {
    workers: Vec<WorkerHandle>,
    results_rx: Receiver<anyhow::Result<TaskResult>>,
}

impl WorkerPool {
    /// Spawn `n` workers.  Each compiles `grad_step_<variant>` in its own
    /// client (slow, once) and streams shard `i` of the corpus.
    pub fn spawn(
        artifacts_dir: std::path::PathBuf,
        variant: &str,
        n: usize,
        seed: u64,
        microbatch: usize,
        seq_len: usize,
    ) -> Result<WorkerPool> {
        assert!(n >= 1);
        let (results_tx, results_rx) = channel();
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let (tx, rx) = channel::<Task>();
            let results_tx = results_tx.clone();
            let dir = artifacts_dir.clone();
            let grad_name = format!("grad_step_{variant}");
            let handle = std::thread::Builder::new()
                .name(format!("dp-worker-{i}"))
                .spawn(move || {
                    if let Err(e) = worker_main(i, dir, grad_name, seed, microbatch,
                                                seq_len, rx, &results_tx) {
                        let _ = results_tx.send(Err(e));
                    }
                })
                .context("spawning worker")?;
            workers.push(WorkerHandle {
                tx,
                handle: Some(handle),
            });
        }
        Ok(WorkerPool {
            workers,
            results_rx,
        })
    }

    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }

    /// Run one globally-accumulated gradient step: `total_microbatches`
    /// split as evenly as possible across workers.
    /// Returns (mean loss, averaged gradients).
    pub fn grad_step(
        &self,
        params: &Arc<Vec<Tensor>>,
        total_microbatches: u32,
    ) -> Result<(f64, Vec<Tensor>)> {
        let n = self.workers.len() as u32;
        if total_microbatches < 1 {
            bail!("need at least one microbatch");
        }
        let mut assigned = 0u32;
        let mut active = 0usize;
        for (i, w) in self.workers.iter().enumerate() {
            let share = total_microbatches / n
                + if (i as u32) < total_microbatches % n { 1 } else { 0 };
            if share == 0 {
                continue;
            }
            w.tx
                .send(Task::Run {
                    params: Arc::clone(params),
                    microbatches: share,
                })
                .context("sending task to worker")?;
            assigned += share;
            active += 1;
        }
        debug_assert_eq!(assigned, total_microbatches);

        // Collect and tree-reduce in worker-id order (deterministic sums).
        let mut results: Vec<TaskResult> = Vec::with_capacity(active);
        for _ in 0..active {
            results.push(self.results_rx.recv().context("worker died")??);
        }
        results.sort_by_key(|r| r.worker);
        let mut it = results.into_iter();
        let Some(first) = it.next() else {
            bail!("no workers were assigned microbatches (total_microbatches = 0?)");
        };
        let (mut loss_sum, mut count, mut grads) = (first.loss_sum, first.count, first.grads);
        for r in it {
            loss_sum += r.loss_sum;
            count += r.count;
            for (a, b) in grads.iter_mut().zip(&r.grads) {
                a.add_assign(b);
            }
        }
        let inv = 1.0 / count as f32;
        for g in grads.iter_mut() {
            g.scale(inv);
        }
        Ok((loss_sum / count as f64, grads))
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.tx.send(Task::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

fn worker_main(
    index: usize,
    artifacts_dir: std::path::PathBuf,
    grad_name: String,
    seed: u64,
    microbatch: usize,
    seq_len: usize,
    rx: Receiver<Task>,
    results_tx: &Sender<anyhow::Result<TaskResult>>,
) -> Result<()> {
    let mut runtime = Runtime::new(artifacts_dir)?;
    let grad_exe = runtime.load_owned(&grad_name)?;
    let out_specs = grad_exe.manifest.outputs.clone();
    let n_params = grad_exe.manifest.param_names()?.len();
    // Shard `index`: disjoint deterministic stream per worker.
    let mut batcher = Batcher::new(Tokenizer::bytes_only(), seed, index as u64,
                                   microbatch, seq_len);

    while let Ok(task) = rx.recv() {
        let Task::Run {
            params,
            microbatches,
        } = task
        else {
            break;
        };
        // Upload parameters once for all microbatches of this step.
        let param_bufs: Vec<xla::PjRtBuffer> = params
            .iter()
            .map(|t| grad_exe.upload_f32(t))
            .collect::<Result<_>>()?;
        let mut loss_sum = 0f64;
        let mut grads: Option<Vec<Tensor>> = None;
        for _ in 0..microbatches {
            let batch = batcher.next_batch()?;
            let tok = grad_exe.upload_i32(&batch.tokens)?;
            let tgt = grad_exe.upload_i32(&batch.targets)?;
            let mut inputs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(n_params + 2);
            inputs.extend(param_bufs.iter());
            inputs.push(&tok);
            inputs.push(&tgt);
            let outputs = grad_exe.execute_buffers(&inputs)?;
            loss_sum += f32_from_literal(&outputs[0], &out_specs[0])?.item() as f64;
            let micro_grads: Vec<Tensor> = outputs[1..]
                .iter()
                .zip(&out_specs[1..])
                .map(|(l, s)| f32_from_literal(l, s))
                .collect::<Result<_>>()?;
            match grads {
                None => grads = Some(micro_grads),
                Some(ref mut acc) => {
                    for (a, b) in acc.iter_mut().zip(&micro_grads) {
                        a.add_assign(b);
                    }
                }
            }
        }
        let Some(grads) = grads else {
            results_tx
                .send(Err(anyhow::anyhow!(
                    "worker {index} was assigned 0 microbatches"
                )))
                .ok();
            continue;
        };
        results_tx
            .send(Ok(TaskResult {
                worker: index,
                loss_sum,
                count: microbatches,
                grads,
            }))
            .ok();
    }
    Ok(())
}

/// Data-parallel trainer: leader applies AdamW, workers compute gradients.
pub struct DistTrainer {
    pub cfg: TrainConfig,
    pub metrics: Metrics,
    leader: Runtime,
    apply_exe: crate::runtime::Executable,
    pool: WorkerPool,
    param_specs: Vec<TensorSpec>,
    params: Arc<Vec<Tensor>>,
    m: Vec<Tensor>,
    v: Vec<Tensor>,
    micro_per_step: u64,
    schedule: CosineSchedule,
    step: u64,
}

impl DistTrainer {
    pub fn new(artifacts_dir: std::path::PathBuf, cfg: TrainConfig, workers: usize) -> Result<DistTrainer> {
        cfg.validate()?;
        let mut leader = Runtime::new(artifacts_dir.clone())?;
        let init_exe = leader.load_owned(&format!("init_{}", cfg.variant))?;
        let seed_buf = init_exe.upload_i32(&crate::tensor::IntTensor::scalar(cfg.seed as i32))?;
        let param_lits = init_exe.execute_buffers(&[&seed_buf])?;

        let grad_exe = leader.load_owned(&format!("grad_step_{}", cfg.variant))?;
        let gm = &grad_exe.manifest;
        let n_params = gm.param_names()?.len();
        let param_specs: Vec<TensorSpec> = gm.inputs[..n_params].to_vec();
        let tokens_spec = gm.input("tokens")?;
        let (microbatch, seq_len) = (tokens_spec.shape[0], tokens_spec.shape[1]);
        let micro_per_step = crate::coordinator::microbatches_for_tps(
            cfg.tokens_per_step, microbatch as u64, seq_len as u64)?;

        let params: Vec<Tensor> = param_lits
            .iter()
            .zip(&param_specs)
            .map(|(l, s)| f32_from_literal(l, s))
            .collect::<Result<_>>()?;
        let m = params.iter().map(|p| Tensor::zeros(&p.shape)).collect();
        let v = params.iter().map(|p| Tensor::zeros(&p.shape)).collect();

        let apply_name = if cfg.variant.contains("noqknorm") {
            "apply_step_noqknorm"
        } else {
            "apply_step_qknorm"
        };
        let apply_exe = leader.load_owned(apply_name)?;
        let schedule =
            CosineSchedule::new(cfg.peak_lr, cfg.warmup_steps, cfg.steps, cfg.min_lr_frac)?;
        let pool = WorkerPool::spawn(artifacts_dir, &cfg.variant, workers,
                                     cfg.seed, microbatch, seq_len)?;
        Ok(DistTrainer {
            cfg,
            metrics: Metrics::new(),
            leader,
            apply_exe,
            pool,
            param_specs,
            params: Arc::new(params),
            m,
            v,
            micro_per_step,
            schedule,
            step: 0,
        })
    }

    pub fn num_workers(&self) -> usize {
        self.pool.num_workers()
    }

    /// One data-parallel optimizer step.
    pub fn train_step(&mut self) -> Result<f64> {
        let (loss, grads) = self
            .pool
            .grad_step(&self.params, self.micro_per_step as u32)?;
        let lr = self.schedule.lr(self.step);

        // AdamW on the leader's client.
        let n = self.params.len();
        let up = |t: &Tensor| self.apply_exe.upload_f32(t);
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(4 * n + 2);
        for t in self.params.iter() {
            bufs.push(up(t)?);
        }
        for t in &self.m {
            bufs.push(up(t)?);
        }
        for t in &self.v {
            bufs.push(up(t)?);
        }
        for t in &grads {
            bufs.push(up(t)?);
        }
        bufs.push(self.apply_exe.upload_f32(&Tensor::scalar(lr as f32))?);
        bufs.push(
            self.apply_exe
                .upload_i32(&crate::tensor::IntTensor::scalar(self.step as i32 + 1))?,
        );
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let outputs = self.apply_exe.execute_buffers(&refs)?;
        if outputs.len() != 3 * n {
            bail!("apply_step returned {} outputs", outputs.len());
        }
        let decode = |lits: &[xla::Literal], specs: &[TensorSpec]| -> Result<Vec<Tensor>> {
            lits.iter()
                .zip(specs)
                .map(|(l, s)| f32_from_literal(l, s))
                .collect()
        };
        self.params = Arc::new(decode(&outputs[..n], &self.param_specs)?);
        self.m = decode(&outputs[n..2 * n], &self.param_specs)?;
        self.v = decode(&outputs[2 * n..], &self.param_specs)?;

        self.metrics.record("train_loss", self.step, loss);
        self.metrics.record("lr", self.step, lr);
        self.step += 1;
        Ok(loss)
    }

    pub fn run(&mut self, log: &Log) -> Result<f64> {
        let total = self.cfg.steps;
        log.info(&format!(
            "distributed run {}: {} workers, {} steps × {} microbatches/step",
            self.cfg.variant,
            self.pool.num_workers(),
            total,
            self.micro_per_step
        ));
        let mut last = f64::NAN;
        while self.step < total {
            last = self.train_step()?;
            if self.cfg.log_every > 0 && self.step % self.cfg.log_every == 0 {
                log.info(&format!("step {:>4}/{total}  loss {last:.4}", self.step));
            }
        }
        Ok(last)
    }

    /// Leader runtime access (e.g. for eval probes).
    pub fn leader(&mut self) -> &mut Runtime {
        &mut self.leader
    }
}
