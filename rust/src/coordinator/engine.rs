//! `TrainEngine` — the execution half of training, split from the
//! orchestration half (`Trainer`).
//!
//! The trainer owns the loop (accumulation, LR schedule, divergence
//! detection, telemetry, checkpoints); an engine owns *how one microbatch
//! gradient is computed and how one optimizer step is applied*:
//!
//! * [`NativeEngine`] — the in-process model (`crate::model`) + native
//!   AdamW, attention routed through [`AttentionBackend`].  Runs from a
//!   bare checkout: no artifacts, no Python, no XLA.  This is the default
//!   for every training subcommand.
//! * [`XlaEngine`] — the original AOT artifact path: `grad_step_*` /
//!   `apply_step_*` executables under PJRT, with device-resident
//!   parameter/moment buffers between steps (§Perf in DESIGN.md).
//!
//! [`TrainerFactory`] maps the `--backend native|xla` CLI flag to a
//! ready [`Trainer`] so every experiment harness (fig1, fig4,
//! noise-probe, train) is engine-agnostic.

use anyhow::{bail, Context, Result};

use crate::config::TrainConfig;
use crate::coordinator::trainer::Trainer;
use crate::data::Batch;
use crate::model::{AdamW, AttnVariant, Model, ModelDims};
use crate::runtime::literal::f32_from_literal;
use crate::runtime::{AttentionBackend, Executable, NativeBackend, Runtime, TensorSpec};
use crate::tensor::Tensor;

/// One microbatch's results, engine-agnostic.
#[derive(Debug)]
pub struct MicroStats {
    pub loss: f64,
    /// Gradients in parameter (ABI) order.
    pub grads: Vec<Tensor>,
    /// `max |QKᵀ/√d|` this microbatch — `None` when the engine cannot
    /// observe it (the monolithic XLA executables don't expose it).
    pub max_attn_logit: Option<f64>,
}

/// Host-side training state (checkpointing).
#[derive(Debug, Clone)]
pub struct EngineState {
    pub names: Vec<String>,
    pub params: Vec<Tensor>,
    pub m: Vec<Tensor>,
    pub v: Vec<Tensor>,
}

/// The execution backend of one training run.
pub trait TrainEngine {
    /// Engine name for logs ("native" or "xla").
    fn name(&self) -> &'static str;

    /// `(microbatch, seq_len)` of the batches this engine consumes.
    fn microbatch_shape(&self) -> (usize, usize);

    /// Parameter leaf names in ABI order.
    fn param_names(&self) -> &[String];

    /// Gradient leaf shapes in ABI order (accumulator layout).
    fn grad_shapes(&self) -> &[Vec<usize>];

    /// Forward+backward of one microbatch against the current parameters.
    fn grad_microbatch(&mut self, batch: &Batch) -> Result<MicroStats>;

    /// One AdamW step with the (already averaged/post-processed) gradient.
    /// `step` is 1-based for bias correction.
    fn apply(&mut self, grads: &[Tensor], lr: f64, step: u64) -> Result<()>;

    /// Loss of one batch without updating (held-out probes).
    fn eval_loss(&mut self, batch: &Batch) -> Result<f64>;

    /// Decode the full training state to host tensors.
    fn state(&self) -> Result<EngineState>;

    /// Restore state produced by [`Self::state`].
    fn load_state(&mut self, state: &EngineState) -> Result<()>;
}

// ---------------------------------------------------------------------------
// Native engine
// ---------------------------------------------------------------------------

/// In-process training: native model + native AdamW + kernel backend.
///
/// Hot-loop wiring (DESIGN.md §11): the engine owns both the [`Model`]
/// (whose workspace pools the per-layer backward slabs and MLP scratch
/// across steps) and the [`NativeBackend`] (whose workspace pools the
/// attention tile scratch), and every per-head attention call is
/// dispatched through `AttentionBackend::execute_many`, which fans heads
/// out over the `SAGEBWD_THREADS` scoped-thread pool with results
/// bitwise-identical to the serial loop.
pub struct NativeEngine {
    model: Model,
    backend: Box<dyn AttentionBackend>,
    params: Vec<Tensor>,
    opt: AdamW,
}

impl NativeEngine {
    /// Default-dimension engine with the in-process kernel backend.
    pub fn new(cfg: &TrainConfig) -> Result<NativeEngine> {
        NativeEngine::with_dims(cfg, ModelDims::default())
    }

    pub fn with_dims(cfg: &TrainConfig, dims: ModelDims) -> Result<NativeEngine> {
        let variant = AttnVariant::parse(&cfg.variant)?;
        let model = Model::new(dims, variant)?;
        let params = model.init_params(cfg.seed);
        let opt = AdamW::new(model.param_names(), model.param_shapes());
        Ok(NativeEngine {
            model,
            backend: Box::new(NativeBackend::new()),
            params,
            opt,
        })
    }

    pub fn dims(&self) -> &ModelDims {
        self.model.dims()
    }
}

impl TrainEngine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn microbatch_shape(&self) -> (usize, usize) {
        (self.model.dims().microbatch, self.model.dims().seq_len)
    }

    fn param_names(&self) -> &[String] {
        self.model.param_names()
    }

    fn grad_shapes(&self) -> &[Vec<usize>] {
        self.model.param_shapes()
    }

    fn grad_microbatch(&mut self, batch: &Batch) -> Result<MicroStats> {
        let out = self.model.loss_and_grads(
            &self.params,
            self.backend.as_mut(),
            &batch.tokens,
            &batch.targets,
        )?;
        Ok(MicroStats {
            loss: out.loss,
            grads: out.grads,
            max_attn_logit: Some(out.max_attn_logit),
        })
    }

    fn apply(&mut self, grads: &[Tensor], lr: f64, step: u64) -> Result<()> {
        self.opt.apply(&mut self.params, grads, lr, step)
    }

    fn eval_loss(&mut self, batch: &Batch) -> Result<f64> {
        let (loss, _) = self.model.loss_only(
            &self.params,
            self.backend.as_mut(),
            &batch.tokens,
            &batch.targets,
        )?;
        Ok(loss)
    }

    fn state(&self) -> Result<EngineState> {
        let (m, v) = self.opt.state();
        Ok(EngineState {
            names: self.model.param_names().to_vec(),
            params: self.params.clone(),
            m: m.to_vec(),
            v: v.to_vec(),
        })
    }

    fn load_state(&mut self, state: &EngineState) -> Result<()> {
        if state.names != self.model.param_names() {
            bail!(
                "checkpoint parameter names do not match this model/variant \
                 ({} leaves vs {})",
                state.names.len(),
                self.model.param_names().len()
            );
        }
        for (t, shape) in state.params.iter().zip(self.model.param_shapes()) {
            if &t.shape != shape {
                bail!("checkpoint shape {:?}, model wants {shape:?}", t.shape);
            }
        }
        self.params = state.params.clone();
        self.opt.load_state(state.m.clone(), state.v.clone())
    }
}

// ---------------------------------------------------------------------------
// XLA engine (the original AOT artifact path)
// ---------------------------------------------------------------------------

/// AOT-artifact training: `grad_step_*` / `apply_step_*` executables with
/// device-resident state buffers between steps.
pub struct XlaEngine {
    #[allow(dead_code)] // owns the PJRT client + compile cache
    runtime: Runtime,
    grad_exe: Executable,
    apply_exe: Executable,
    param_names: Vec<String>,
    param_specs: Vec<TensorSpec>,
    grad_shapes: Vec<Vec<usize>>,
    /// Canonical state: device-resident buffers reused across microbatches
    /// and steps — no host round-trip per microbatch (§Perf).
    param_bufs: Vec<xla::PjRtBuffer>,
    m_bufs: Vec<xla::PjRtBuffer>,
    v_bufs: Vec<xla::PjRtBuffer>,
    microbatch: usize,
    seq_len: usize,
}

impl XlaEngine {
    /// Load + compile the variant's artifacts and run `init_<variant>`.
    pub fn new(mut runtime: Runtime, cfg: &TrainConfig) -> Result<XlaEngine> {
        let grad_name = format!("grad_step_{}", cfg.variant);
        let apply_name = if cfg.variant.contains("noqknorm") {
            "apply_step_noqknorm".to_string()
        } else {
            "apply_step_qknorm".to_string()
        };
        let init_name = format!("init_{}", cfg.variant);

        // init: seed → params (uploaded once as device buffers).
        let init_exe = runtime.load_owned(&init_name)?;
        let seed_lit = crate::runtime::literal::literal_from_i32(
            &crate::tensor::IntTensor::scalar(cfg.seed as i32),
        )?;
        let param_lits = init_exe
            .execute_literals(&[&seed_lit])
            .with_context(|| format!("running {init_name}"))?;

        let grad_exe = runtime.load_owned(&grad_name)?;
        let gm = &grad_exe.manifest;
        let param_names = gm.param_names()?;
        if param_names.len() != param_lits.len() {
            bail!(
                "init produced {} params, grad_step manifest lists {}",
                param_lits.len(),
                param_names.len()
            );
        }
        // The first N grad_step inputs are the parameters, in ABI order.
        let param_specs: Vec<TensorSpec> = gm.inputs[..param_names.len()].to_vec();
        let grad_shapes: Vec<Vec<usize>> = param_specs.iter().map(|s| s.shape.clone()).collect();
        let tokens_spec = gm.input("tokens")?;
        let (microbatch, seq_len) = (tokens_spec.shape[0], tokens_spec.shape[1]);

        let param_bufs: Vec<xla::PjRtBuffer> = param_lits
            .iter()
            .map(|l| grad_exe.buffer_from_literal(l))
            .collect::<Result<_>>()?;
        // Zero moments, as device buffers.
        let zeros = |spec: &TensorSpec| -> Result<xla::PjRtBuffer> {
            grad_exe.upload_f32(&Tensor::zeros(&spec.shape))
        };
        let m_bufs = param_specs.iter().map(zeros).collect::<Result<Vec<_>>>()?;
        let v_bufs = param_specs.iter().map(zeros).collect::<Result<Vec<_>>>()?;

        // Pre-compile apply_step too, so the first step isn't an outlier.
        let apply_exe = runtime.load_owned(&apply_name)?;

        Ok(XlaEngine {
            runtime,
            grad_exe,
            apply_exe,
            param_names,
            param_specs,
            grad_shapes,
            param_bufs,
            m_bufs,
            v_bufs,
            microbatch,
            seq_len,
        })
    }

    fn decode(&self, bufs: &[xla::PjRtBuffer]) -> Result<Vec<Tensor>> {
        bufs.iter()
            .zip(&self.param_specs)
            .map(|(b, s)| {
                let lit = b
                    .to_literal_sync()
                    .map_err(|e| anyhow::anyhow!("downloading state: {e:?}"))?;
                f32_from_literal(&lit, s)
            })
            .collect()
    }
}

impl TrainEngine for XlaEngine {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn microbatch_shape(&self) -> (usize, usize) {
        (self.microbatch, self.seq_len)
    }

    fn param_names(&self) -> &[String] {
        &self.param_names
    }

    fn grad_shapes(&self) -> &[Vec<usize>] {
        &self.grad_shapes
    }

    fn grad_microbatch(&mut self, batch: &Batch) -> Result<MicroStats> {
        let grad_out_specs = &self.grad_exe.manifest.outputs;
        let tok_buf = self.grad_exe.upload_i32(&batch.tokens)?;
        let tgt_buf = self.grad_exe.upload_i32(&batch.targets)?;
        let mut inputs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.param_bufs.len() + 2);
        inputs.extend(self.param_bufs.iter());
        inputs.push(&tok_buf);
        inputs.push(&tgt_buf);
        let outputs = self.grad_exe.execute_buffers(&inputs)?;
        let loss = f32_from_literal(&outputs[0], &grad_out_specs[0])?.item() as f64;
        let grads: Vec<Tensor> = outputs[1..]
            .iter()
            .zip(&grad_out_specs[1..])
            .map(|(l, s)| f32_from_literal(l, s))
            .collect::<Result<_>>()?;
        Ok(MicroStats {
            loss,
            grads,
            max_attn_logit: None,
        })
    }

    fn apply(&mut self, grads: &[Tensor], lr: f64, step: u64) -> Result<()> {
        // apply_step ABI: params + m + v + grads + lr + step(1-based).
        let n = self.param_bufs.len();
        let grad_bufs: Vec<xla::PjRtBuffer> = grads
            .iter()
            .map(|g| self.apply_exe.upload_f32(g))
            .collect::<Result<_>>()?;
        let lr_buf = self.apply_exe.upload_f32(&Tensor::scalar(lr as f32))?;
        let step_buf = self
            .apply_exe
            .upload_i32(&crate::tensor::IntTensor::scalar(step as i32))?;
        let mut inputs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(4 * n + 2);
        inputs.extend(self.param_bufs.iter());
        inputs.extend(self.m_bufs.iter());
        inputs.extend(self.v_bufs.iter());
        inputs.extend(grad_bufs.iter());
        inputs.push(&lr_buf);
        inputs.push(&step_buf);
        let mut outputs = self.apply_exe.execute_buffers(&inputs)?;
        if outputs.len() != 3 * n {
            bail!(
                "apply_step returned {} outputs, expected {}",
                outputs.len(),
                3 * n
            );
        }
        // Re-upload the new state as device buffers for the next step.
        let upload = |lits: Vec<xla::Literal>| -> Result<Vec<xla::PjRtBuffer>> {
            lits.iter()
                .map(|l| self.apply_exe.buffer_from_literal(l))
                .collect()
        };
        let v_new = outputs.split_off(2 * n);
        let m_new = outputs.split_off(n);
        self.v_bufs = upload(v_new)?;
        self.m_bufs = upload(m_new)?;
        self.param_bufs = upload(outputs)?;
        Ok(())
    }

    fn eval_loss(&mut self, batch: &Batch) -> Result<f64> {
        // Decode only the loss output — not the full gradient set.
        let tok_buf = self.grad_exe.upload_i32(&batch.tokens)?;
        let tgt_buf = self.grad_exe.upload_i32(&batch.targets)?;
        let mut inputs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.param_bufs.len() + 2);
        inputs.extend(self.param_bufs.iter());
        inputs.push(&tok_buf);
        inputs.push(&tgt_buf);
        let outputs = self.grad_exe.execute_buffers(&inputs)?;
        let spec = &self.grad_exe.manifest.outputs[0];
        Ok(f32_from_literal(&outputs[0], spec)?.item() as f64)
    }

    fn state(&self) -> Result<EngineState> {
        Ok(EngineState {
            names: self.param_names.clone(),
            params: self.decode(&self.param_bufs)?,
            m: self.decode(&self.m_bufs)?,
            v: self.decode(&self.v_bufs)?,
        })
    }

    fn load_state(&mut self, state: &EngineState) -> Result<()> {
        if state.names != self.param_names {
            bail!(
                "checkpoint parameter names do not match the {} manifest",
                self.grad_exe.manifest.artifact
            );
        }
        let upload = |ts: &[Tensor]| -> Result<Vec<xla::PjRtBuffer>> {
            ts.iter().map(|t| self.grad_exe.upload_f32(t)).collect()
        };
        self.param_bufs = upload(&state.params)?;
        self.m_bufs = upload(&state.m)?;
        self.v_bufs = upload(&state.v)?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Factory: `--backend` flag → Trainer
// ---------------------------------------------------------------------------

/// Which engine a factory builds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    Native,
    Xla,
}

/// Builds engine-backed [`Trainer`]s from the CLI's `--backend` flag —
/// what the training harnesses (fig1/fig4/noise-probe/train) receive
/// instead of an XLA `Runtime` factory.
pub struct TrainerFactory {
    kind: EngineKind,
    artifacts_dir: String,
}

impl TrainerFactory {
    pub fn new(backend: &str, artifacts_dir: &str) -> Result<TrainerFactory> {
        let kind = match backend {
            "native" => EngineKind::Native,
            "xla" => EngineKind::Xla,
            other => bail!("unknown backend {other:?}; known: native, xla"),
        };
        Ok(TrainerFactory {
            kind,
            artifacts_dir: artifacts_dir.to_string(),
        })
    }

    pub fn kind(&self) -> EngineKind {
        self.kind
    }

    pub fn backend_name(&self) -> &'static str {
        match self.kind {
            EngineKind::Native => "native",
            EngineKind::Xla => "xla",
        }
    }

    /// Build a trainer for one run configuration.
    pub fn trainer(&self, cfg: TrainConfig) -> Result<Trainer> {
        match self.kind {
            EngineKind::Native => Trainer::native(cfg),
            EngineKind::Xla => Trainer::new(Runtime::new(self.artifacts_dir.clone())?, cfg),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Batcher, Tokenizer};

    fn native_cfg(variant: &str) -> TrainConfig {
        TrainConfig {
            variant: variant.into(),
            steps: 2,
            tokens_per_step: 128,
            warmup_steps: 1,
            ..TrainConfig::default()
        }
    }

    fn one_batch(engine: &dyn TrainEngine) -> Batch {
        let (b, n) = engine.microbatch_shape();
        let mut batcher = Batcher::new(Tokenizer::bytes_only(), 7, 0, b, n);
        batcher.next_batch().unwrap()
    }

    #[test]
    fn native_engine_produces_schema_shaped_grads() {
        let mut e = NativeEngine::new(&native_cfg("fpa_qknorm")).unwrap();
        assert_eq!(e.name(), "native");
        assert_eq!(e.microbatch_shape(), (2, 32));
        let batch = one_batch(&e);
        let stats = e.grad_microbatch(&batch).unwrap();
        assert!(stats.loss.is_finite());
        assert!(stats.max_attn_logit.unwrap() > 0.0);
        assert_eq!(stats.grads.len(), e.grad_shapes().len());
        for (g, s) in stats.grads.iter().zip(e.grad_shapes()) {
            assert_eq!(&g.shape, s);
        }
    }

    #[test]
    fn native_apply_changes_params_and_lowers_same_batch_loss() {
        let mut e = NativeEngine::new(&native_cfg("sage_qknorm")).unwrap();
        let batch = one_batch(&e);
        let before = e.grad_microbatch(&batch).unwrap();
        e.apply(&before.grads, 0.01, 1).unwrap();
        let after = e.eval_loss(&batch).unwrap();
        // One sign-SGD-sized AdamW step on the same batch must reduce loss.
        assert!(after < before.loss, "{after} !< {}", before.loss);
    }

    #[test]
    fn native_state_roundtrips_through_load() {
        let cfg = native_cfg("sage_qknorm");
        let mut a = NativeEngine::new(&cfg).unwrap();
        let batch = one_batch(&a);
        let s = a.grad_microbatch(&batch).unwrap();
        a.apply(&s.grads, 0.01, 1).unwrap();
        let saved = a.state().unwrap();
        let mut b = NativeEngine::new(&cfg).unwrap();
        b.load_state(&saved).unwrap();
        let la = a.eval_loss(&batch).unwrap();
        let lb = b.eval_loss(&batch).unwrap();
        assert_eq!(la, lb);
        // Wrong variant (different schema) must be rejected.
        let mut c = NativeEngine::new(&native_cfg("sage_noqknorm")).unwrap();
        assert!(c.load_state(&saved).is_err());
    }

    #[test]
    fn factory_maps_backend_names() {
        assert_eq!(TrainerFactory::new("native", "artifacts").unwrap().kind(),
                   EngineKind::Native);
        assert_eq!(TrainerFactory::new("xla", "artifacts").unwrap().kind(),
                   EngineKind::Xla);
        assert!(TrainerFactory::new("bogus", "artifacts").is_err());
        let t = TrainerFactory::new("native", "artifacts").unwrap()
            .trainer(native_cfg("fpa_qknorm")).unwrap();
        assert_eq!(t.engine_name(), "native");
    }
}
