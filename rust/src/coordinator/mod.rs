//! Layer-3 coordinator: the pre-training orchestration the paper's
//! experiments run on — tokens-per-step control via gradient accumulation
//! (§4.3), warmup+cosine LR (§5.1), divergence detection (§5.3 — the
//! `max_attn_logit` ceiling plus the non-finite backstop), checkpointing.
//!
//! Execution is split behind [`engine::TrainEngine`]: the [`Trainer`]
//! owns the loop, an engine (native model or AOT XLA artifacts) owns the
//! math.  [`engine::TrainerFactory`] maps `--backend native|xla` to a
//! ready trainer for every experiment harness.

pub mod accumulator;
pub mod checkpoint;
pub mod distributed;
pub mod engine;
pub mod noise;
pub mod schedule;
pub mod supervisor;
pub mod trainer;

pub use accumulator::{microbatches_for_tps, GradAccumulator};
pub use checkpoint::{Checkpoint, RngState};
pub use engine::{EngineKind, EngineState, MicroStats, NativeEngine, TrainEngine, TrainerFactory,
                 XlaEngine};
pub use schedule::CosineSchedule;
pub use supervisor::{Intervention, SupervisedOutcome, SupervisorConfig};
pub use trainer::{RunReport, RunStatus, Trainer};
