//! Layer-3 coordinator: the pre-training orchestration the paper's
//! experiments run on — tokens-per-step control via gradient accumulation
//! (§4.3), warmup+cosine LR (§5.1), divergence detection (§5.3),
//! checkpointing.

pub mod accumulator;
pub mod checkpoint;
pub mod distributed;
pub mod noise;
pub mod schedule;
pub mod trainer;

pub use accumulator::{microbatches_for_tps, GradAccumulator};
pub use checkpoint::Checkpoint;
pub use schedule::CosineSchedule;
pub use trainer::{RunReport, RunStatus, Trainer};
