//! Gradient post-processing: clipping and synthetic noise injection.
//!
//! Noise injection is an *extension experiment* probing the paper's §4.3
//! hypothesis head-on: if small-TPS runs tolerate INT8 error because
//! stochastic gradient noise masks the (biased) quantization error, then
//! *adding* synthetic Gaussian noise to the averaged gradient at high TPS
//! should close part of the Sage–FPA gap.  `sagebwd noise-probe` runs the
//! comparison (EXPERIMENTS.md §Extensions).
//!
//! Clipping is standard global-norm clipping — the stability guard large
//! TPS runs in the paper's setting would use.

use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

/// Clip the global ℓ2 norm of a gradient set to `max_norm`.
/// Returns the pre-clip norm.
pub fn clip_global_norm(grads: &mut [Tensor], max_norm: f64) -> f64 {
    let norm = global_norm(grads);
    if max_norm > 0.0 && norm > max_norm {
        let scale = (max_norm / norm) as f32;
        for g in grads.iter_mut() {
            g.scale(scale);
        }
    }
    norm
}

/// Global ℓ2 norm over all leaves.
pub fn global_norm(grads: &[Tensor]) -> f64 {
    grads
        .iter()
        .map(|g| g.data.iter().map(|&x| x as f64 * x as f64).sum::<f64>())
        .sum::<f64>()
        .sqrt()
}

/// Add zero-mean Gaussian noise with per-leaf std = `rel_sigma × RMS(leaf)`.
///
/// Scaling noise to each leaf's RMS keeps the perturbation *relative* —
/// mimicking how minibatch sampling noise scales with the gradient itself
/// (the mechanism §4.3 credits for masking quantization bias at low TPS).
pub fn add_relative_noise(grads: &mut [Tensor], rel_sigma: f64, rng: &mut Pcg64) {
    if rel_sigma <= 0.0 {
        return;
    }
    for g in grads.iter_mut() {
        let rms = g.rms();
        if rms == 0.0 {
            continue;
        }
        let std = (rel_sigma * rms) as f32;
        for x in g.data.iter_mut() {
            *x += (rng.gaussian() as f32) * std;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{check, Gen};

    fn t(data: Vec<f32>) -> Tensor {
        Tensor::from_vec(&[data.len()], data).unwrap()
    }

    #[test]
    fn clip_reduces_norm_to_bound() {
        let mut grads = vec![t(vec![3.0, 4.0])]; // norm 5
        let pre = clip_global_norm(&mut grads, 1.0);
        assert!((pre - 5.0).abs() < 1e-9);
        assert!((global_norm(&grads) - 1.0).abs() < 1e-6);
        // direction preserved
        assert!((grads[0].data[0] / grads[0].data[1] - 0.75).abs() < 1e-6);
    }

    #[test]
    fn clip_noop_below_bound() {
        let mut grads = vec![t(vec![0.3, 0.4])];
        clip_global_norm(&mut grads, 1.0);
        assert_eq!(grads[0].data, vec![0.3, 0.4]);
    }

    #[test]
    fn clip_disabled_with_zero_max() {
        let mut grads = vec![t(vec![30.0, 40.0])];
        clip_global_norm(&mut grads, 0.0);
        assert_eq!(grads[0].data, vec![30.0, 40.0]);
    }

    #[test]
    fn noise_zero_sigma_is_identity() {
        let mut grads = vec![t(vec![1.0, 2.0])];
        let mut rng = Pcg64::new(0, 0);
        add_relative_noise(&mut grads, 0.0, &mut rng);
        assert_eq!(grads[0].data, vec![1.0, 2.0]);
    }

    #[test]
    fn noise_scales_with_rms() {
        check("noise magnitude", |g: &mut Gen| {
            let len = 256;
            let scale = g.f64_in(0.1, 10.0) as f32;
            let base: Vec<f32> = (0..len).map(|i| scale * ((i % 7) as f32 - 3.0)).collect();
            let mut grads = vec![t(base.clone())];
            let mut rng = Pcg64::new(g.usize_in(0, 1000) as u64, 1);
            add_relative_noise(&mut grads, 0.5, &mut rng);
            let rms_base = t(base.clone()).rms();
            let diff: Vec<f32> = grads[0]
                .data
                .iter()
                .zip(&base)
                .map(|(a, b)| a - b)
                .collect();
            let rms_noise = t(diff).rms();
            // std should be ≈ 0.5 × rms_base (loose statistical bound)
            if !(rms_noise > 0.3 * rms_base && rms_noise < 0.7 * rms_base) {
                return Err(format!("noise rms {rms_noise} vs base {rms_base}"));
            }
            Ok(())
        });
    }

    #[test]
    fn noise_is_deterministic_per_stream() {
        let mk = || {
            let mut grads = vec![t(vec![1.0; 32])];
            let mut rng = Pcg64::new(9, 9);
            add_relative_noise(&mut grads, 0.1, &mut rng);
            grads
        };
        assert_eq!(mk(), mk());
    }
}
