//! Learning-rate schedule: linear warmup → cosine decay (paper §5.1).
//!
//! The schedule runs in the coordinator; each engine receives the scalar
//! LR per optimizer step (the AOT `apply_step` artifact as an input, the
//! native AdamW as an argument), so one engine serves every schedule.

use anyhow::{bail, Result};

/// Warmup + cosine decay to `peak_lr * min_frac`.
#[derive(Debug, Clone, Copy)]
pub struct CosineSchedule {
    pub peak_lr: f64,
    pub warmup_steps: u64,
    pub total_steps: u64,
    pub min_frac: f64,
}

impl CosineSchedule {
    /// Validated constructor — bad configs surface as CLI errors instead
    /// of panicking mid-run.
    pub fn new(
        peak_lr: f64,
        warmup_steps: u64,
        total_steps: u64,
        min_frac: f64,
    ) -> Result<CosineSchedule> {
        if total_steps <= warmup_steps {
            bail!(
                "cosine schedule: warmup_steps ({warmup_steps}) must be < total_steps \
                 ({total_steps})"
            );
        }
        if !(peak_lr > 0.0 && peak_lr.is_finite()) {
            bail!("cosine schedule: peak_lr must be positive and finite, got {peak_lr}");
        }
        if !(0.0..=1.0).contains(&min_frac) {
            bail!("cosine schedule: min_frac must be in [0, 1], got {min_frac}");
        }
        Ok(CosineSchedule {
            peak_lr,
            warmup_steps,
            total_steps,
            min_frac,
        })
    }

    /// LR for a 0-based optimizer step.
    pub fn lr(&self, step: u64) -> f64 {
        if self.warmup_steps > 0 && step < self.warmup_steps {
            // Linear warmup reaching peak at `warmup_steps`.
            return self.peak_lr * (step + 1) as f64 / self.warmup_steps as f64;
        }
        let progress = (step - self.warmup_steps) as f64
            / (self.total_steps - self.warmup_steps).max(1) as f64;
        let progress = progress.clamp(0.0, 1.0);
        let cos = 0.5 * (1.0 + (std::f64::consts::PI * progress).cos());
        self.peak_lr * (self.min_frac + (1.0 - self.min_frac) * cos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{check, Gen};

    fn sched() -> CosineSchedule {
        CosineSchedule::new(1e-3, 10, 100, 0.1).unwrap()
    }

    #[test]
    fn boundary_step_zero() {
        // With warmup: first step is peak/warmup exactly.
        let s = sched();
        assert_eq!(s.lr(0), 1e-3 * 1.0 / 10.0);
        // Without warmup: step 0 is exactly the peak (cos(0) = 1).
        let s0 = CosineSchedule::new(1e-3, 0, 50, 0.1).unwrap();
        assert_eq!(s0.lr(0), 1e-3);
    }

    #[test]
    fn boundary_warmup_end_is_exact_peak() {
        // step == warmup_steps is the first decay step: progress 0,
        // cos(0) = 1 ⟹ lr == peak exactly (no floating slop).
        let s = sched();
        assert_eq!(s.lr(10), 1e-3);
        // and the last warmup step also reaches peak (linear ramp ends).
        assert!((s.lr(9) - 1e-3).abs() < 1e-15);
    }

    #[test]
    fn boundary_final_step_hits_min_frac_exactly() {
        // progress 1, cos(π) = −1 ⟹ lr == peak·min_frac with no error.
        let s = sched();
        assert_eq!(s.lr(100), 1e-3 * 0.1);
        // clamped beyond the end too
        assert_eq!(s.lr(101), 1e-3 * 0.1);
        let s2 = CosineSchedule::new(7e-4, 3, 17, 0.25).unwrap();
        assert_eq!(s2.lr(17), 7e-4 * 0.25);
    }

    #[test]
    fn invalid_configs_are_errors_not_panics() {
        assert!(CosineSchedule::new(1e-3, 10, 10, 0.1).is_err()); // warmup == total
        assert!(CosineSchedule::new(1e-3, 11, 10, 0.1).is_err()); // warmup > total
        assert!(CosineSchedule::new(0.0, 0, 10, 0.1).is_err()); // lr 0
        assert!(CosineSchedule::new(-1e-3, 0, 10, 0.1).is_err());
        assert!(CosineSchedule::new(f64::NAN, 0, 10, 0.1).is_err());
        assert!(CosineSchedule::new(1e-3, 0, 10, -0.1).is_err()); // bad frac
        assert!(CosineSchedule::new(1e-3, 0, 10, 1.5).is_err());
    }

    #[test]
    fn warmup_is_linear_and_reaches_peak() {
        let s = sched();
        assert!((s.lr(0) - 1e-4).abs() < 1e-12);
        assert!((s.lr(4) - 5e-4).abs() < 1e-12);
        assert!((s.lr(9) - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn decay_ends_at_min_frac() {
        let s = sched();
        assert!((s.lr(100) - 1e-4).abs() < 1e-9);
        assert!((s.lr(10_000) - 1e-4).abs() < 1e-9); // clamped past end
    }

    #[test]
    fn monotone_decreasing_after_warmup() {
        check("cosine monotone", |g: &mut Gen| {
            let warmup = g.usize_in(0, 20) as u64;
            let total = warmup + 2 + g.usize_in(0, 500) as u64;
            let s = CosineSchedule::new(g.f64_in(1e-6, 1e-2), warmup, total, g.f64_in(0.0, 0.9)).unwrap();
            let mut prev = f64::INFINITY;
            for step in warmup..total {
                let lr = s.lr(step);
                if lr > prev + 1e-15 {
                    return Err(format!("lr increased at step {step}: {lr} > {prev}"));
                }
                prev = lr;
            }
            Ok(())
        });
    }

    #[test]
    fn lr_always_positive_and_bounded() {
        check("lr in (0, peak]", |g: &mut Gen| {
            let warmup = g.usize_in(0, 20) as u64;
            let total = warmup + 1 + g.usize_in(1, 300) as u64;
            let peak = g.f64_in(1e-6, 1e-2);
            let s = CosineSchedule::new(peak, warmup, total, g.f64_in(0.01, 1.0)).unwrap();
            for step in 0..total + 10 {
                let lr = s.lr(step);
                if !(lr > 0.0 && lr <= peak * (1.0 + 1e-12)) {
                    return Err(format!("lr {lr} out of (0, {peak}] at step {step}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn no_warmup_starts_at_peak() {
        let s = CosineSchedule::new(1e-3, 0, 50, 0.0).unwrap();
        assert!((s.lr(0) - 1e-3).abs() < 1e-12);
    }
}
