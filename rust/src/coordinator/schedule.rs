//! Learning-rate schedule: linear warmup → cosine decay (paper §5.1).
//!
//! The schedule runs in the coordinator and is fed to the AOT `apply_step`
//! artifact as a scalar input each optimizer step, so one compiled
//! executable serves every schedule.

/// Warmup + cosine decay to `peak_lr * min_frac`.
#[derive(Debug, Clone, Copy)]
pub struct CosineSchedule {
    pub peak_lr: f64,
    pub warmup_steps: u64,
    pub total_steps: u64,
    pub min_frac: f64,
}

impl CosineSchedule {
    pub fn new(peak_lr: f64, warmup_steps: u64, total_steps: u64, min_frac: f64) -> CosineSchedule {
        assert!(total_steps > warmup_steps, "warmup must be < total");
        assert!((0.0..=1.0).contains(&min_frac));
        CosineSchedule {
            peak_lr,
            warmup_steps,
            total_steps,
            min_frac,
        }
    }

    /// LR for a 0-based optimizer step.
    pub fn lr(&self, step: u64) -> f64 {
        if self.warmup_steps > 0 && step < self.warmup_steps {
            // Linear warmup reaching peak at `warmup_steps`.
            return self.peak_lr * (step + 1) as f64 / self.warmup_steps as f64;
        }
        let progress = (step - self.warmup_steps) as f64
            / (self.total_steps - self.warmup_steps).max(1) as f64;
        let progress = progress.clamp(0.0, 1.0);
        let cos = 0.5 * (1.0 + (std::f64::consts::PI * progress).cos());
        self.peak_lr * (self.min_frac + (1.0 - self.min_frac) * cos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{check, Gen};

    fn sched() -> CosineSchedule {
        CosineSchedule::new(1e-3, 10, 100, 0.1)
    }

    #[test]
    fn warmup_is_linear_and_reaches_peak() {
        let s = sched();
        assert!((s.lr(0) - 1e-4).abs() < 1e-12);
        assert!((s.lr(4) - 5e-4).abs() < 1e-12);
        assert!((s.lr(9) - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn decay_ends_at_min_frac() {
        let s = sched();
        assert!((s.lr(100) - 1e-4).abs() < 1e-9);
        assert!((s.lr(10_000) - 1e-4).abs() < 1e-9); // clamped past end
    }

    #[test]
    fn monotone_decreasing_after_warmup() {
        check("cosine monotone", |g: &mut Gen| {
            let warmup = g.usize_in(0, 20) as u64;
            let total = warmup + 2 + g.usize_in(0, 500) as u64;
            let s = CosineSchedule::new(g.f64_in(1e-6, 1e-2), warmup, total, g.f64_in(0.0, 0.9));
            let mut prev = f64::INFINITY;
            for step in warmup..total {
                let lr = s.lr(step);
                if lr > prev + 1e-15 {
                    return Err(format!("lr increased at step {step}: {lr} > {prev}"));
                }
                prev = lr;
            }
            Ok(())
        });
    }

    #[test]
    fn lr_always_positive_and_bounded() {
        check("lr in (0, peak]", |g: &mut Gen| {
            let warmup = g.usize_in(0, 20) as u64;
            let total = warmup + 1 + g.usize_in(1, 300) as u64;
            let peak = g.f64_in(1e-6, 1e-2);
            let s = CosineSchedule::new(peak, warmup, total, g.f64_in(0.01, 1.0));
            for step in 0..total + 10 {
                let lr = s.lr(step);
                if !(lr > 0.0 && lr <= peak * (1.0 + 1e-12)) {
                    return Err(format!("lr {lr} out of (0, {peak}] at step {step}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn no_warmup_starts_at_peak() {
        let s = CosineSchedule::new(1e-3, 0, 50, 0.0);
        assert!((s.lr(0) - 1e-3).abs() < 1e-12);
    }
}
