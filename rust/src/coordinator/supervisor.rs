//! Fault-tolerant training supervisor (DESIGN.md §16).
//!
//! Wraps the [`Trainer`] step loop with three robustness planes:
//!
//! 1. **Crash-safe periodic checkpointing** — every `save_every` steps the
//!    full training state (params + AdamW moments + RNG + counters) is
//!    stored content-addressed through the run registry (`ckpt_NNNNNN`
//!    artifacts) together with the metric CSVs, and the `running`
//!    manifest is persisted via [`RunHandle::save_progress`].  A killed
//!    run resumes from its newest readable checkpoint and — because the
//!    engine, data stream, noise RNG, and CSV encoding are all
//!    deterministic and byte-exact — re-emits *bitwise identical* curve
//!    artifacts to an uninterrupted run.
//! 2. **Divergence recovery ladder** — when the trainer flags divergence
//!    (the §5.3 `max_attn_logit` ceiling or the non-finite backstop), the
//!    supervisor rolls back to the last good checkpoint and applies a
//!    staged intervention: LR backoff (× `lr_backoff`), halving
//!    tokens-per-step (a gradient-accumulation resplit), then escalating
//!    the attention arm (adding QK-norm / smoothing).  Every attempt is
//!    recorded as a `recovery` block in the `sagebwd-run-v1` manifest and
//!    as trace counters, bounded by `max_recoveries`.
//! 3. **Write verification** — each checkpoint is read back through the
//!    registry's verified-get; a torn write (seen in the wild as
//!    power-loss truncation, here injected via `SAGEBWD_FAULTS=torn@N`)
//!    is repaired in place by re-putting the bytes and recorded as a
//!    `rewrite_artifact` recovery.
//!
//! Run identity is the **base** config: supervisor knobs and applied
//! interventions are not part of the registry key (like the trace knobs),
//! so a supervised run and a plain run of the same config share one
//! manifest.  The effective config after interventions is recoverable
//! from the last `recovery` record, which is how a resumed process knows
//! to rebuild the escalated trainer.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::TrainConfig;
use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::engine::TrainerFactory;
use crate::coordinator::trainer::{RunReport, RunStatus, Trainer};
use crate::data::PrefetchBatcher;
use crate::registry::{CorruptObject, RecoveryRecord, Registry, RunHandle, RunManifest, RunState};
use crate::telemetry::{trace, Log, Metrics, Series};
use crate::util::json::Json;

/// One stage of the divergence-recovery ladder.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Intervention {
    /// Multiply `peak_lr` by the configured backoff factor.
    LrBackoff,
    /// Halve `tokens_per_step` (gradient-accumulation resplit; steps and
    /// microbatch shape unchanged).  Skipped when the halved TPS is no
    /// longer a multiple of microbatch×seq_len.
    HalveTps,
    /// Escalate the attention arm toward more stabilization (see
    /// [`escalate_variant`]).  Skipped when no escalation exists.
    EscalateArm,
}

impl Intervention {
    /// The manifest `action` string for this stage.
    pub fn action(self) -> &'static str {
        match self {
            Intervention::LrBackoff => "lr_backoff",
            Intervention::HalveTps => "halve_tps",
            Intervention::EscalateArm => "escalate_arm",
        }
    }
}

/// Parse a `--ladder lr,tps,arm` stage list.
pub fn parse_ladder(s: &str) -> Result<Vec<Intervention>> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| match t {
            "lr" => Ok(Intervention::LrBackoff),
            "tps" => Ok(Intervention::HalveTps),
            "arm" => Ok(Intervention::EscalateArm),
            other => bail!("unknown ladder stage {other:?} (known: lr, tps, arm)"),
        })
        .collect()
}

/// The arm-escalation map: each variant's next-more-stable neighbour
/// (§5.3: QK-norm bounds the logits; smoothing reduces quantization
/// error).  `fpa_qknorm` and `sage_qknorm_qksm` are already at the top.
pub fn escalate_variant(v: &str) -> Option<&'static str> {
    match v {
        "sage_noqknorm" => Some("sage_qknorm"),
        "fpa_noqknorm" => Some("fpa_qknorm"),
        "sage_qknorm_nosm" => Some("sage_qknorm"),
        "sage_qknorm" => Some("sage_qknorm_qksm"),
        _ => None,
    }
}

/// Supervisor policy knobs.  Deliberately **not** part of the run key:
/// they shape *how* a config gets trained, not *what* is trained.
#[derive(Debug, Clone)]
pub struct SupervisorConfig {
    /// Checkpoint + persist the manifest every N optimizer steps
    /// (0 = only at completion).
    pub save_every: u64,
    /// Rollback budget: divergence-ladder and step-error retries combined
    /// (0 = no recovery; divergence finishes the run like the plain path).
    pub max_recoveries: u64,
    /// LR multiplier applied by [`Intervention::LrBackoff`].
    pub lr_backoff: f64,
    /// Staged interventions, indexed by divergence-recovery count;
    /// exhausted or inapplicable stages fall back to an LR backoff.
    pub ladder: Vec<Intervention>,
    /// Stop after N steps executed *in this process* without finishing
    /// the manifest — the crash-simulation hook used by the resume tests
    /// and the CI fault-injection smoke (a return, not a panic, so the
    /// harness can assert on the outcome).
    pub halt_after: Option<u64>,
}

impl Default for SupervisorConfig {
    fn default() -> SupervisorConfig {
        SupervisorConfig {
            save_every: 0,
            max_recoveries: 0,
            lr_backoff: 0.5,
            ladder: vec![
                Intervention::LrBackoff,
                Intervention::HalveTps,
                Intervention::EscalateArm,
            ],
            halt_after: None,
        }
    }
}

/// What a supervised run did.
#[derive(Debug)]
pub struct SupervisedOutcome {
    pub report: RunReport,
    /// Every recovery recorded on the manifest (including ones inherited
    /// from interrupted prior invocations of the same run).
    pub recoveries: Vec<RecoveryRecord>,
    /// The config actually in effect at the end (base + interventions).
    pub effective: TrainConfig,
    /// Checkpoint step this invocation resumed from, if any.
    pub resumed_from: Option<u64>,
    /// True when `halt_after` fired: the manifest is still `running` and
    /// a later invocation is expected to resume.
    pub halted: bool,
}

/// Apply one ladder stage to the current effective config; `None` when
/// the stage is inapplicable (the caller falls back to an LR backoff).
fn apply_intervention(
    iv: Intervention,
    cur: &TrainConfig,
    gamma: f64,
    per_micro: u64,
) -> Option<TrainConfig> {
    let mut cfg = cur.clone();
    match iv {
        Intervention::LrBackoff => {
            cfg.peak_lr = cur.peak_lr * gamma;
            Some(cfg)
        }
        Intervention::HalveTps => {
            let half = cur.tokens_per_step / 2;
            if cur.tokens_per_step % 2 == 0 && half >= per_micro && half % per_micro == 0 {
                cfg.tokens_per_step = half;
                Some(cfg)
            } else {
                None
            }
        }
        Intervention::EscalateArm => escalate_variant(&cur.variant).map(|v| {
            cfg.variant = v.to_string();
            cfg
        }),
    }
}

/// Registry artifact name for the checkpoint at `step`.
fn ckpt_name(step: u64) -> String {
    format!("ckpt_{step:06}")
}

/// Rebuild the metric registry from a manifest's CSV artifacts, rewound
/// to the state as of a checkpoint at `ckpt_step` (i.e. keeping only
/// points from steps `< ckpt_step`).  `Series::from_csv` round-trips
/// `f64` bitwise, so a resumed run's re-recorded CSVs are byte-identical
/// to an uninterrupted run's.
fn restore_metrics(registry: &Registry, m: &RunManifest, ckpt_step: u64) -> Result<Metrics> {
    let mut metrics = Metrics::new();
    for a in &m.artifacts {
        let Some(name) = a.name.strip_suffix(".csv") else {
            continue;
        };
        let bytes = registry
            .read_object(&a.sha256)
            .with_context(|| format!("restoring metric series {}", a.name))?;
        let text = std::str::from_utf8(&bytes)
            .with_context(|| format!("metric series {} is not UTF-8", a.name))?;
        let mut series =
            Series::from_csv(text).with_context(|| format!("parsing series {}", a.name))?;
        if ckpt_step == 0 {
            series = Series::default();
        } else {
            series.truncate_after(ckpt_step - 1);
        }
        if !series.points.is_empty() {
            metrics.series.insert(name.to_string(), series);
        }
    }
    Ok(metrics)
}

/// Build a trainer for `cfg`, restore `ckpt` into it (leniently when the
/// variant escalated away from `base_variant`), install the rewound
/// metrics, and replay the deterministic data stream to the checkpoint's
/// position.  Used both for registry resume and in-run rollback.
fn rebuild_at_checkpoint(
    factory: &TrainerFactory,
    cfg: &TrainConfig,
    base_variant: &str,
    ckpt: &Checkpoint,
    metrics: &Metrics,
) -> Result<(Trainer, PrefetchBatcher)> {
    let mut trainer = factory.trainer(cfg.clone())?;
    trainer.restore(ckpt, cfg.variant != base_variant)?;
    trainer.metrics = metrics.clone();
    let (mb, sl) = trainer.microbatch_shape();
    let per_micro = (mb * sl) as u64;
    let mut batches = trainer.make_batcher(512, 4)?;
    // The batcher is a pure function of (seed, shard): consuming
    // tokens_seen / per_micro batches lands exactly where the
    // checkpointed run was.
    for _ in 0..ckpt.tokens_seen / per_micro {
        batches.next_batch()?;
    }
    Ok((trainer, batches))
}

/// Checkpoint the trainer into the registry with a verified read-back;
/// a torn write is repaired in place and recorded as a
/// `rewrite_artifact` recovery.
fn save_verified_checkpoint(
    run: &mut RunHandle<'_>,
    trainer: &Trainer,
    effective: &TrainConfig,
    view_dir: &Path,
    log: &Log,
) -> Result<Checkpoint> {
    let _span = trace::span("supervisor_checkpoint");
    let ckpt = trainer.checkpoint()?;
    let bytes = ckpt.to_bytes();
    let name = ckpt_name(ckpt.step);
    let hash = run.record_bytes(&name, &bytes, None)?;
    if let Err(e) = run.registry().read_object(&hash) {
        if e.downcast_ref::<CorruptObject>().is_none() {
            return Err(e);
        }
        // Self-heal: put_bytes rewrites an object whose content no longer
        // matches its address.
        run.record_bytes(&name, &bytes, None)?;
        run.registry()
            .read_object(&hash)
            .context("checkpoint object still corrupt after rewrite")?;
        let attempt = (run.manifest().recoveries.len() + 1) as u64;
        run.push_recovery(RecoveryRecord {
            attempt,
            at_step: ckpt.step,
            resume_step: ckpt.step,
            reason: format!("{e:#}"),
            action: "rewrite_artifact".to_string(),
            peak_lr: effective.peak_lr,
            tokens_per_step: effective.tokens_per_step,
            variant: effective.variant.clone(),
        });
        trace::counter_add("supervisor.rewrites", 1);
        log.info(&format!(
            "supervisor: torn checkpoint write at step {} detected and repaired",
            ckpt.step
        ));
    }
    run.record_metrics(&trainer.metrics, view_dir)?;
    run.save_progress()?;
    trace::counter_add("supervisor.checkpoints", 1);
    Ok(ckpt)
}

fn num_or_null(v: Option<f64>) -> Json {
    v.map(Json::from).unwrap_or(Json::Null)
}

fn final_summary(run: &RunHandle<'_>, report: &RunReport, diverged_at: Option<u64>) -> Json {
    Json::from_pairs(vec![
        ("diverged_at", num_or_null(diverged_at.map(|s| s as f64))),
        ("final_loss", num_or_null(report.final_loss)),
        ("max_attn_logit", num_or_null(report.max_attn_logit)),
        ("steps_done", Json::from(report.steps_done as i64)),
        ("tokens_seen", Json::from(report.tokens_seen as i64)),
        (
            "recoveries",
            Json::from(run.manifest().recoveries.len() as i64),
        ),
    ])
}

/// Run one training config under supervision, recording through the run
/// registry.  Resumes in place from the newest readable checkpoint when
/// the run's manifest already exists (any status).
#[allow(clippy::too_many_arguments)]
pub fn run_supervised(
    factory: &TrainerFactory,
    registry: &Registry,
    experiment: &str,
    label: &str,
    base: &TrainConfig,
    sup: &SupervisorConfig,
    view_dir: &Path,
    log: &Log,
) -> Result<SupervisedOutcome> {
    base.validate()?;
    if !(sup.lr_backoff > 0.0 && sup.lr_backoff < 1.0) {
        bail!("supervisor lr_backoff must be in (0, 1), got {}", sup.lr_backoff);
    }
    let mut config = base.to_json();
    config.set("backend", Json::from(factory.backend_name()));
    let key = Registry::run_key(&config, factory.backend_name());
    let (mut run, prior) = registry.resume_or_begin(experiment, label, config, key)?;

    // Effective config = base + every intervention already on record
    // (each recovery record carries the full effective triple, so the
    // last one is authoritative).
    let mut effective = base.clone();
    if let Some(rec) = run.manifest().recoveries.last() {
        effective.peak_lr = rec.peak_lr;
        effective.tokens_per_step = rec.tokens_per_step;
        effective.variant = rec.variant.clone();
        effective
            .validate()
            .context("manifest recovery record yields an invalid effective config")?;
    }

    let mut trainer = factory.trainer(effective.clone())?;
    let (mb, sl) = trainer.microbatch_shape();
    let per_micro = (mb * sl) as u64;
    let mut batches = trainer.make_batcher(512, 4)?;
    let mut resumed_from = None;
    if let Some(p) = &prior {
        // Newest readable checkpoint wins; a corrupt one (e.g. a torn
        // write the process died before verifying) falls back to the
        // next-older, never to silently wrong bytes.
        let mut ckpts: Vec<(u64, String, String)> = p
            .artifacts
            .iter()
            .filter_map(|a| {
                a.name
                    .strip_prefix("ckpt_")
                    .and_then(|s| s.parse::<u64>().ok())
                    .map(|step| (step, a.name.clone(), a.sha256.clone()))
            })
            .collect();
        ckpts.sort_by(|a, b| b.0.cmp(&a.0));
        for (step, name, hash) in ckpts {
            let bytes = match registry.read_object(&hash) {
                Ok(b) => b,
                Err(e) => {
                    log.info(&format!(
                        "supervisor: checkpoint {name} unreadable ({e:#}); trying an older one"
                    ));
                    continue;
                }
            };
            let ckpt = Checkpoint::from_bytes(&bytes)
                .with_context(|| format!("decoding registry checkpoint {name}"))?;
            let metrics = restore_metrics(registry, p, ckpt.step)?;
            let (t, b) =
                rebuild_at_checkpoint(factory, &effective, &base.variant, &ckpt, &metrics)?;
            trainer = t;
            batches = b;
            log.info(&format!(
                "supervisor: resumed {label} from checkpoint step {step} [{}]",
                &hash[..16.min(hash.len())]
            ));
            resumed_from = Some(step);
            break;
        }
    }

    let total = effective.steps;
    log.info(&format!(
        "supervised run {label} [{}]: {} steps, save_every {}, max_recoveries {}{}",
        run.key16(),
        total,
        sup.save_every,
        sup.max_recoveries,
        resumed_from
            .map(|s| format!(", resumed@{s}"))
            .unwrap_or_default(),
    ));

    // In-memory last-good state: rollback works even before (or without)
    // the first periodic save.  At resume time this is the restored
    // checkpoint; fresh runs snapshot their initialization.
    let mut last_ckpt = trainer.checkpoint()?;
    let mut last_metrics = trainer.metrics.clone();

    // Rollback budget consumed so far (ladder + retry; `rewrite_artifact`
    // self-heals are bookkeeping, not rollbacks, and don't consume it).
    let mut rollbacks = run
        .manifest()
        .recoveries
        .iter()
        .filter(|r| r.action != "rewrite_artifact")
        .count() as u64;
    let mut steps_this_process = 0u64;

    while trainer.step() < total {
        if let Some(h) = sup.halt_after {
            if steps_this_process >= h {
                log.info(&format!(
                    "supervisor: halting after {steps_this_process} steps (simulated crash; \
                     manifest left running at step {})",
                    trainer.step()
                ));
                let report = RunReport {
                    status: RunStatus::Completed,
                    steps_done: trainer.step(),
                    final_loss: trainer.metrics.get("train_loss").and_then(|s| s.last()),
                    tokens_seen: trainer.tokens_seen(),
                    max_attn_logit: trainer.run_max_logit(),
                };
                let recoveries = run.manifest().recoveries.clone();
                return Ok(SupervisedOutcome {
                    report,
                    recoveries,
                    effective,
                    resumed_from,
                    halted: true,
                });
            }
        }

        let step_result = trainer.train_step(&mut batches);
        steps_this_process += 1;

        // Classify: hard error (engine fault), divergence, or healthy.
        let (failed, diverged) = match &step_result {
            Err(_) => (true, false),
            Ok(_) => (false, trainer.diverged()),
        };

        if failed || diverged {
            let (at_step, reason) = if failed {
                // The attempted step never completed: trainer.step() is
                // still the failing step's number.
                let e = match &step_result {
                    Err(e) => format!("step error: {e:#}"),
                    Ok(_) => String::new(),
                };
                (trainer.step(), e)
            } else {
                (
                    trainer.step() - 1,
                    trainer
                        .divergence_reason()
                        .unwrap_or("divergence flagged without a reason")
                        .to_string(),
                )
            };

            if rollbacks >= sup.max_recoveries {
                if let Err(e) = step_result {
                    let _ = run.finish(RunState::Failed);
                    return Err(e.context(format!(
                        "step {at_step} failed with no recovery budget left"
                    )));
                }
                // Divergence with the budget spent (or zero): record the
                // curves and finish `diverged`, exactly like the plain
                // path — the supervisor adds bookkeeping, not silence.
                log.info(&format!(
                    "supervisor: step {at_step} DIVERGED ({reason}); recovery budget exhausted \
                     ({rollbacks}/{})",
                    sup.max_recoveries
                ));
                run.record_metrics(&trainer.metrics, view_dir)?;
                let report = RunReport {
                    status: RunStatus::Diverged { at_step },
                    steps_done: trainer.step(),
                    final_loss: trainer.metrics.get("train_loss").and_then(|s| s.last()),
                    tokens_seen: trainer.tokens_seen(),
                    max_attn_logit: trainer.run_max_logit(),
                };
                run.set_summary(final_summary(&run, &report, Some(at_step)));
                let recoveries = run.manifest().recoveries.clone();
                run.finish(RunState::Diverged)?;
                return Ok(SupervisedOutcome {
                    report,
                    recoveries,
                    effective,
                    resumed_from,
                    halted: false,
                });
            }

            rollbacks += 1;
            let attempt = (run.manifest().recoveries.len() + 1) as u64;
            let (new_cfg, action) = if failed {
                // Transient execution fault: same config, try again from
                // the last good checkpoint.
                (effective.clone(), "retry")
            } else {
                // Divergence ladder, indexed by divergence recoveries so
                // far; inapplicable/exhausted stages back off the LR.
                let ladder_idx = run
                    .manifest()
                    .recoveries
                    .iter()
                    .filter(|r| {
                        matches!(r.action.as_str(), "lr_backoff" | "halve_tps" | "escalate_arm")
                    })
                    .count();
                let chosen = sup
                    .ladder
                    .get(ladder_idx)
                    .copied()
                    .unwrap_or(Intervention::LrBackoff);
                match apply_intervention(chosen, &effective, sup.lr_backoff, per_micro) {
                    Some(cfg) => (cfg, chosen.action()),
                    None => {
                        let mut cfg = effective.clone();
                        cfg.peak_lr *= sup.lr_backoff;
                        (cfg, "lr_backoff")
                    }
                }
            };

            let _span = trace::span("supervisor_recovery");
            log.info(&format!(
                "supervisor: recovery {attempt} at step {at_step} ({reason}) → {action}, \
                 rollback to step {} (lr {:.2e}, tps {}, {})",
                last_ckpt.step, new_cfg.peak_lr, new_cfg.tokens_per_step, new_cfg.variant
            ));
            run.push_recovery(RecoveryRecord {
                attempt,
                at_step,
                resume_step: last_ckpt.step,
                reason,
                action: action.to_string(),
                peak_lr: new_cfg.peak_lr,
                tokens_per_step: new_cfg.tokens_per_step,
                variant: new_cfg.variant.clone(),
            });
            // The recovery is on disk before the retry begins: a crash
            // mid-recovery resumes with the intervention already applied.
            run.save_progress()?;
            trace::counter_add("supervisor.recoveries", 1);

            let (t, b) = rebuild_at_checkpoint(
                factory,
                &new_cfg,
                &base.variant,
                &last_ckpt,
                &last_metrics,
            )?;
            trainer = t;
            batches = b;
            trainer.metrics.record("recovery", at_step, attempt as f64);
            effective = new_cfg;
            continue;
        }

        // Healthy step.
        if let Ok(loss) = &step_result {
            if effective.log_every > 0 && trainer.step() % effective.log_every == 0 {
                log.info(&format!(
                    "step {:>5}/{total}  loss {loss:.4}  [supervised]",
                    trainer.step()
                ));
            }
        }
        if sup.save_every > 0 && trainer.step() % sup.save_every == 0 {
            last_ckpt = save_verified_checkpoint(&mut run, &trainer, &effective, view_dir, log)?;
            last_metrics = trainer.metrics.clone();
        }
    }

    // Completion: final checkpoint + curves + summary, then `complete`.
    let final_ckpt = save_verified_checkpoint(&mut run, &trainer, &effective, view_dir, log)?;
    let final_loss = trainer
        .metrics
        .get("train_loss")
        .and_then(|s| s.tail_mean(std::cmp::max(1, (total / 20) as usize)));
    let report = RunReport {
        status: RunStatus::Completed,
        steps_done: trainer.step(),
        final_loss,
        tokens_seen: trainer.tokens_seen(),
        max_attn_logit: trainer.run_max_logit(),
    };
    run.set_summary(final_summary(&run, &report, None));
    let recoveries = run.manifest().recoveries.clone();
    run.finish(RunState::Complete)?;
    log.info(&format!(
        "supervised run {label} complete: {} steps, {} recoveries, final checkpoint step {}",
        report.steps_done,
        recoveries.len(),
        final_ckpt.step
    ));
    Ok(SupervisedOutcome {
        report,
        recoveries,
        effective,
        resumed_from,
        halted: false,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_parses_and_rejects() {
        assert_eq!(
            parse_ladder("lr,tps,arm").unwrap(),
            vec![
                Intervention::LrBackoff,
                Intervention::HalveTps,
                Intervention::EscalateArm
            ]
        );
        assert_eq!(parse_ladder(" lr , lr ").unwrap().len(), 2);
        assert!(parse_ladder("lr,bogus").is_err());
        assert!(parse_ladder("").unwrap().is_empty());
    }

    #[test]
    fn escalation_map_tops_out() {
        assert_eq!(escalate_variant("sage_noqknorm"), Some("sage_qknorm"));
        assert_eq!(escalate_variant("sage_qknorm"), Some("sage_qknorm_qksm"));
        assert_eq!(escalate_variant("sage_qknorm_qksm"), None);
        assert_eq!(escalate_variant("fpa_qknorm"), None);
        // Every escalation target is a valid variant.
        for v in crate::config::VARIANTS {
            if let Some(next) = escalate_variant(v) {
                assert!(crate::config::VARIANTS.contains(&next), "{v} → {next}");
            }
        }
    }

    #[test]
    fn interventions_respect_tps_granularity() {
        let cfg = TrainConfig {
            tokens_per_step: 256,
            ..TrainConfig::default()
        };
        // 256 → 128 is fine at per_micro 64.
        let halved = apply_intervention(Intervention::HalveTps, &cfg, 0.5, 64).unwrap();
        assert_eq!(halved.tokens_per_step, 128);
        assert_eq!(halved.steps, cfg.steps, "steps stay fixed");
        // 128 → 64 fine; 64 → 32 < per_micro: inapplicable.
        let cfg64 = TrainConfig {
            tokens_per_step: 64,
            ..TrainConfig::default()
        };
        assert!(apply_intervention(Intervention::HalveTps, &cfg64, 0.5, 64).is_none());
        // LR backoff multiplies.
        let lr = apply_intervention(Intervention::LrBackoff, &cfg, 0.25, 64).unwrap();
        assert!((lr.peak_lr - cfg.peak_lr * 0.25).abs() < 1e-12);
        // Arm escalation tops out as None.
        let top = TrainConfig {
            variant: "fpa_qknorm".into(),
            ..TrainConfig::default()
        };
        assert!(apply_intervention(Intervention::EscalateArm, &top, 0.5, 64).is_none());
    }

    #[test]
    fn checkpoint_names_are_sortable() {
        assert_eq!(ckpt_name(4), "ckpt_000004");
        assert_eq!(ckpt_name(123_456), "ckpt_123456");
        assert!(ckpt_name(5) > ckpt_name(4));
    }
}
