//! The pre-training orchestrator (Layer 3's centerpiece).
//!
//! One optimizer step:
//! ```text
//! for _ in 0..microbatches_per_step:        # tokens-per-step knob (§4.3)
//!     batch  = data pipeline (prefetch thread)
//!     loss,g = execute grad_step_<variant>   # AOT HLO, INT8 attention inside
//!     accumulator += (loss, g)
//! lr         = cosine schedule (warmup, §5.1)
//! params,m,v = execute apply_step_<tree>     # AOT AdamW
//! ```
//! Divergence (non-finite loss/grads — the paper's "loss explosion" at
//! high TPS without QK-norm, §5.3) is detected and recorded rather than
//! crashing, so experiment harnesses can plot the divergence point.
//!
//! Hot-path note (§Perf): parameters and optimizer moments live as
//! *device-resident `PjRtBuffer`s* between steps — uploaded once after
//! each `apply_step` and reused by every microbatch's `grad_step` — so
//! per-microbatch host work is just (tokens, targets) upload and gradient
//! readback.  See `runtime::Executable::buffer_from_literal` for the two
//! vendored-crate bugs (input-buffer leak, async-upload UAF) this path
//! also avoids.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::TrainConfig;
use crate::coordinator::accumulator::{microbatches_for_tps, GradAccumulator};
use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::schedule::CosineSchedule;
use crate::data::{Batcher, PrefetchBatcher, Tokenizer};
use crate::runtime::literal::{f32_from_literal, literal_from_i32};
use crate::runtime::{Executable, Runtime, TensorSpec, Value};
use crate::telemetry::{Log, Metrics};
use crate::tensor::Tensor;
use crate::util::fmt_count;

/// Final state of a training run.
#[derive(Debug, Clone, PartialEq)]
pub enum RunStatus {
    Completed,
    Diverged { at_step: u64 },
}

/// Outcome summary returned by [`Trainer::run`].
#[derive(Debug)]
pub struct RunReport {
    pub status: RunStatus,
    pub steps_done: u64,
    pub final_loss: Option<f64>,
    pub tokens_seen: u64,
}

/// Pre-training coordinator bound to one artifact variant.
pub struct Trainer {
    pub cfg: TrainConfig,
    pub metrics: Metrics,
    #[allow(dead_code)] // owns the PJRT client + compile cache
    runtime: Runtime,
    grad_exe: Executable,
    apply_exe: Executable,
    param_names: Vec<String>,
    param_specs: Vec<TensorSpec>,
    /// Canonical state: *device-resident* buffers reused across
    /// microbatches and steps (§Perf) — no host round-trip per microbatch.
    param_bufs: Vec<xla::PjRtBuffer>,
    m_bufs: Vec<xla::PjRtBuffer>,
    v_bufs: Vec<xla::PjRtBuffer>,
    microbatch: usize,
    seq_len: usize,
    micro_per_step: u64,
    schedule: CosineSchedule,
    step: u64,
    tokens_seen: u64,
    diverged: bool,
    noise_rng: crate::util::rng::Pcg64,
}

impl Trainer {
    /// Build a trainer: loads + compiles the variant's artifacts and runs
    /// the `init_<variant>` executable to materialize parameters.
    pub fn new(mut runtime: Runtime, cfg: TrainConfig) -> Result<Trainer> {
        cfg.validate()?;
        let grad_name = format!("grad_step_{}", cfg.variant);  // compiled below
        let apply_name = if cfg.variant.contains("noqknorm") {
            "apply_step_noqknorm".to_string()
        } else {
            "apply_step_qknorm".to_string()
        };
        let init_name = format!("init_{}", cfg.variant);

        // init: seed → params (uploaded once as device buffers).
        let init_exe = runtime.load_owned(&init_name)?;
        let seed_lit = literal_from_i32(&crate::tensor::IntTensor::scalar(cfg.seed as i32))?;
        let param_lits = init_exe
            .execute_literals(&[&seed_lit])
            .with_context(|| format!("running {init_name}"))?;

        let grad_exe = runtime.load_owned(&grad_name)?;
        let gm = &grad_exe.manifest;
        let param_names = gm.param_names()?;
        if param_names.len() != param_lits.len() {
            bail!(
                "init produced {} params, grad_step manifest lists {}",
                param_lits.len(),
                param_names.len()
            );
        }
        // The first N grad_step inputs are the parameters, in ABI order.
        let param_specs: Vec<TensorSpec> = gm.inputs[..param_names.len()].to_vec();
        let tokens_spec = gm.input("tokens")?;
        let (microbatch, seq_len) = (tokens_spec.shape[0], tokens_spec.shape[1]);
        let micro_per_step =
            microbatches_for_tps(cfg.tokens_per_step, microbatch as u64, seq_len as u64)?;

        let param_bufs: Vec<xla::PjRtBuffer> = param_lits
            .iter()
            .map(|l| grad_exe.buffer_from_literal(l))
            .collect::<Result<_>>()?;

        // Zero moments, as device buffers.
        let zeros = |spec: &TensorSpec| -> Result<xla::PjRtBuffer> {
            grad_exe.upload_f32(&Tensor::zeros(&spec.shape))
        };
        let m_bufs = param_specs.iter().map(zeros).collect::<Result<Vec<_>>>()?;
        let v_bufs = param_specs.iter().map(zeros).collect::<Result<Vec<_>>>()?;

        let schedule =
            CosineSchedule::new(cfg.peak_lr, cfg.warmup_steps, cfg.steps, cfg.min_lr_frac);
        let cfg_seed = cfg.seed;

        // Pre-compile apply_step too, so the first step isn't an outlier.
        let apply_exe = runtime.load_owned(&apply_name)?;

        Ok(Trainer {
            cfg,
            metrics: Metrics::new(),
            runtime,
            grad_exe,
            apply_exe,
            param_names,
            param_specs,
            param_bufs,
            m_bufs,
            v_bufs,
            microbatch,
            seq_len,
            micro_per_step,
            schedule,
            step: 0,
            tokens_seen: 0,
            diverged: false,
            noise_rng: crate::util::rng::Pcg64::new(cfg_seed, 0x4E01),
        })
    }

    pub fn microbatch_shape(&self) -> (usize, usize) {
        (self.microbatch, self.seq_len)
    }

    pub fn microbatches_per_step(&self) -> u64 {
        self.micro_per_step
    }

    pub fn step(&self) -> u64 {
        self.step
    }

    pub fn param_names(&self) -> &[String] {
        &self.param_names
    }

    /// Decode the current parameters to host tensors (checkpoint path —
    /// not used in the training hot loop).
    pub fn params_host(&self) -> Result<Vec<Tensor>> {
        self.param_bufs
            .iter()
            .zip(&self.param_specs)
            .map(|(b, s)| {
                let lit = b
                    .to_literal_sync()
                    .map_err(|e| anyhow::anyhow!("downloading param: {e:?}"))?;
                f32_from_literal(&lit, s)
            })
            .collect()
    }

    /// Build the variant's deterministic data pipeline.
    pub fn make_batcher(&self, vocab_size: usize, prefetch: usize) -> Result<PrefetchBatcher> {
        let tokenizer = crate::data::trained_tokenizer(self.cfg.seed, vocab_size)?;
        let inner = Batcher::new(tokenizer, self.cfg.seed, 0, self.microbatch, self.seq_len);
        Ok(PrefetchBatcher::spawn(inner, prefetch))
    }

    /// Tokenizer-independent batcher (raw bytes) — used when vocab == 256
    /// or for tests that want to skip BPE training.
    pub fn make_byte_batcher(&self, prefetch: usize) -> PrefetchBatcher {
        let inner = Batcher::new(
            Tokenizer::bytes_only(),
            self.cfg.seed,
            0,
            self.microbatch,
            self.seq_len,
        );
        PrefetchBatcher::spawn(inner, prefetch)
    }

    /// One optimizer step. Returns the step's mean loss.
    pub fn train_step(&mut self, batches: &mut PrefetchBatcher) -> Result<f64> {
        if self.diverged {
            bail!("trainer already diverged at step {}", self.step);
        }
        let shapes: Vec<Vec<usize>> = self.param_specs.iter().map(|s| s.shape.clone()).collect();
        let mut acc = GradAccumulator::new(&shapes);

        let grad_out_specs = &self.grad_exe.manifest.outputs;
        for _ in 0..self.micro_per_step {
            let batch = batches.next_batch()?;
            let tok_buf = self.grad_exe.upload_i32(&batch.tokens)?;
            let tgt_buf = self.grad_exe.upload_i32(&batch.targets)?;
            let mut inputs: Vec<&xla::PjRtBuffer> =
                Vec::with_capacity(self.param_bufs.len() + 2);
            inputs.extend(self.param_bufs.iter());
            inputs.push(&tok_buf);
            inputs.push(&tgt_buf);
            let outputs = self.grad_exe.execute_buffers(&inputs)?;
            let loss = f32_from_literal(&outputs[0], &grad_out_specs[0])?.item();
            let grads: Vec<Tensor> = outputs[1..]
                .iter()
                .zip(&grad_out_specs[1..])
                .map(|(l, s)| f32_from_literal(l, s))
                .collect::<Result<_>>()?;
            acc.add(loss, &grads)?;
            self.tokens_seen += batch.num_tokens();
        }

        let (loss, mut grads) = acc.take_mean()?;
        // Post-processing: global-norm clip, then the §4.3 noise probe.
        let grad_norm =
            crate::coordinator::noise::clip_global_norm(&mut grads, self.cfg.clip_norm);
        if self.cfg.grad_noise_sigma > 0.0 {
            crate::coordinator::noise::add_relative_noise(
                &mut grads,
                self.cfg.grad_noise_sigma,
                &mut self.noise_rng,
            );
        }
        let lr = self.schedule.lr(self.step);

        if !loss.is_finite() || grads.iter().any(|g| !g.is_finite()) {
            // Paper §5.3: loss explosion — record and stop updating.
            self.diverged = true;
            self.metrics.record("train_loss", self.step, loss);
            self.metrics.record("diverged", self.step, 1.0);
            self.step += 1;
            return Ok(loss);
        }

        // apply_step: params + m + v + grads + lr + step(1-based)
        let n = self.param_bufs.len();
        let grad_bufs: Vec<xla::PjRtBuffer> = grads
            .iter()
            .map(|g| self.apply_exe.upload_f32(g))
            .collect::<Result<_>>()?;
        let lr_buf = self.apply_exe.upload_f32(&Tensor::scalar(lr as f32))?;
        let step_buf = self
            .apply_exe
            .upload_i32(&crate::tensor::IntTensor::scalar(self.step as i32 + 1))?;
        let mut inputs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(4 * n + 2);
        inputs.extend(self.param_bufs.iter());
        inputs.extend(self.m_bufs.iter());
        inputs.extend(self.v_bufs.iter());
        inputs.extend(grad_bufs.iter());
        inputs.push(&lr_buf);
        inputs.push(&step_buf);
        let mut outputs = self.apply_exe.execute_buffers(&inputs)?;
        if outputs.len() != 3 * n {
            bail!(
                "apply_step returned {} outputs, expected {}",
                outputs.len(),
                3 * n
            );
        }
        // Re-upload the new state as device buffers for the next step.
        let upload = |lits: Vec<xla::Literal>| -> Result<Vec<xla::PjRtBuffer>> {
            lits.iter()
                .map(|l| self.apply_exe.buffer_from_literal(l))
                .collect()
        };
        let v_new = outputs.split_off(2 * n);
        let m_new = outputs.split_off(n);
        self.v_bufs = upload(v_new)?;
        self.m_bufs = upload(m_new)?;
        self.param_bufs = upload(outputs)?;

        self.metrics.record("train_loss", self.step, loss);
        self.metrics.record("lr", self.step, lr);
        self.metrics.record("grad_norm", self.step, grad_norm);
        self.metrics
            .record("tokens", self.step, self.tokens_seen as f64);
        self.step += 1;
        Ok(loss)
    }

    /// Run the configured number of steps (or until divergence).
    pub fn run(&mut self, batches: &mut PrefetchBatcher, log: &Log) -> Result<RunReport> {
        let total = self.cfg.steps;
        log.info(&format!(
            "run {}: {} steps × {} tok/step ({} microbatches of {}×{}) — {} total tokens",
            self.cfg.variant,
            total,
            fmt_count(self.cfg.tokens_per_step),
            self.micro_per_step,
            self.microbatch,
            self.seq_len,
            fmt_count(total * self.cfg.tokens_per_step),
        ));
        while self.step < total {
            let loss = self.train_step(batches)?;
            if self.diverged {
                log.info(&format!("step {}: DIVERGED (loss={loss:.4})", self.step - 1));
                return Ok(RunReport {
                    status: RunStatus::Diverged {
                        at_step: self.step - 1,
                    },
                    steps_done: self.step,
                    final_loss: Some(loss),
                    tokens_seen: self.tokens_seen,
                });
            }
            if self.cfg.log_every > 0 && self.step % self.cfg.log_every == 0 {
                log.info(&format!(
                    "step {:>5}/{total}  loss {:.4}  lr {:.2e}",
                    self.step,
                    loss,
                    self.schedule.lr(self.step - 1),
                ));
            }
        }
        let final_loss = self
            .metrics
            .get("train_loss")
            .and_then(|s| s.tail_mean(std::cmp::max(1, (total / 20) as usize)));
        Ok(RunReport {
            status: RunStatus::Completed,
            steps_done: self.step,
            final_loss,
            tokens_seen: self.tokens_seen,
        })
    }

    /// Save params + optimizer state.
    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        let decode = |bufs: &[xla::PjRtBuffer]| -> Result<Vec<Tensor>> {
            bufs.iter()
                .zip(&self.param_specs)
                .map(|(b, s)| {
                    let lit = b
                        .to_literal_sync()
                        .map_err(|e| anyhow::anyhow!("downloading state: {e:?}"))?;
                    f32_from_literal(&lit, s)
                })
                .collect()
        };
        let (params, m, v) = (
            decode(&self.param_bufs)?,
            decode(&self.m_bufs)?,
            decode(&self.v_bufs)?,
        );
        let mut tensors = Vec::with_capacity(3 * params.len());
        for (name, t) in self.param_names.iter().zip(params) {
            tensors.push((name.clone(), t));
        }
        for (name, t) in self.param_names.iter().zip(m) {
            tensors.push((format!("m.{name}"), t));
        }
        for (name, t) in self.param_names.iter().zip(v) {
            tensors.push((format!("v.{name}"), t));
        }
        Checkpoint {
            step: self.step,
            tensors,
        }
        .save(path)
    }

    /// Restore params + optimizer state saved by [`Self::save_checkpoint`].
    pub fn load_checkpoint(&mut self, path: &Path) -> Result<()> {
        let ckpt = Checkpoint::load(path)?;
        let find = |prefix: &str, name: &str| -> Result<xla::PjRtBuffer> {
            let full = format!("{prefix}{name}");
            let t = ckpt
                .tensors
                .iter()
                .find(|(n, _)| *n == full)
                .map(|(_, t)| t)
                .with_context(|| format!("checkpoint missing tensor {full}"))?;
            self.grad_exe.upload_f32(t)
        };
        for (i, name) in self.param_names.clone().iter().enumerate() {
            self.param_bufs[i] = find("", name)?;
            self.m_bufs[i] = find("m.", name)?;
            self.v_bufs[i] = find("v.", name)?;
        }
        self.step = ckpt.step;
        Ok(())
    }

    /// Compute the training loss of one provided batch without updating —
    /// used by harnesses for held-out probes.
    pub fn eval_loss(&mut self, batch: &crate::data::Batch) -> Result<f64> {
        let tok_buf = self.grad_exe.upload_i32(&batch.tokens)?;
        let tgt_buf = self.grad_exe.upload_i32(&batch.targets)?;
        let mut inputs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.param_bufs.len() + 2);
        inputs.extend(self.param_bufs.iter());
        inputs.push(&tok_buf);
        inputs.push(&tgt_buf);
        let outputs = self.grad_exe.execute_buffers(&inputs)?;
        let spec = &self.grad_exe.manifest.outputs[0];
        Ok(f32_from_literal(&outputs[0], spec)?.item() as f64)
    }
}

// `Value` is still the convenient API for harnesses; keep the re-export
// referenced so the import stays obviously intentional.
#[allow(unused)]
fn _value_api_witness(v: &Value) -> &[usize] {
    v.shape()
}
