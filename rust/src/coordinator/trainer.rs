//! The pre-training orchestrator (Layer 3's centerpiece).
//!
//! One optimizer step:
//! ```text
//! for _ in 0..microbatches_per_step:        # tokens-per-step knob (§4.3)
//!     batch   = data pipeline (prefetch thread)
//!     loss, g = engine.grad_microbatch      # native model or AOT HLO
//!     accumulator += (loss, g); track max_attn_logit
//! lr = cosine schedule (warmup, §5.1)
//! engine.apply(mean g, lr)                  # AdamW (native or AOT)
//! ```
//!
//! The trainer is engine-agnostic: execution lives behind
//! [`TrainEngine`] (`coordinator::engine`), with [`NativeEngine`] the
//! from-bare-checkout default and [`XlaEngine`] the AOT artifact path.
//!
//! Divergence (§5.3, the paper's "loss explosion" at high TPS without
//! QK-norm) is detected two ways and *recorded* rather than crashing, so
//! experiment harnesses can plot the divergence point:
//!
//! 1. **`max_attn_logit` ceiling** (`TrainConfig::max_attn_logit_ceiling`,
//!    default 50.0): the per-step max of `|QKᵀ/√d|` reported by the
//!    native engine.  This fires *while the curve is still plottable* —
//!    by the time the loss itself goes non-finite the logits have long
//!    since exploded and the fig1 divergence point is lost.
//! 2. **Non-finite loss/grads** — the backstop, and the only signal the
//!    XLA engine can observe.
//!
//! [`NativeEngine`]: crate::coordinator::engine::NativeEngine
//! [`XlaEngine`]: crate::coordinator::engine::XlaEngine

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::TrainConfig;
use crate::coordinator::accumulator::{microbatches_for_tps, GradAccumulator};
use crate::coordinator::checkpoint::{Checkpoint, RngState};
use crate::coordinator::engine::{EngineState, NativeEngine, TrainEngine, XlaEngine};
use crate::coordinator::schedule::CosineSchedule;
use crate::data::{Batcher, PrefetchBatcher, Tokenizer};
use crate::runtime::Runtime;
use crate::telemetry::{qerr, trace, Log, Metrics};
use crate::tensor::Tensor;
use crate::util::fmt_count;

/// Final state of a training run.
#[derive(Debug, Clone, PartialEq)]
pub enum RunStatus {
    Completed,
    Diverged { at_step: u64 },
}

/// Outcome summary returned by [`Trainer::run`].
#[derive(Debug)]
pub struct RunReport {
    pub status: RunStatus,
    pub steps_done: u64,
    pub final_loss: Option<f64>,
    pub tokens_seen: u64,
    /// Largest attention logit observed over the whole run (None when the
    /// engine does not report it, i.e. the XLA path).
    pub max_attn_logit: Option<f64>,
}

/// Pre-training coordinator bound to one [`TrainEngine`].
pub struct Trainer {
    pub cfg: TrainConfig,
    pub metrics: Metrics,
    engine: Box<dyn TrainEngine>,
    micro_per_step: u64,
    schedule: CosineSchedule,
    step: u64,
    tokens_seen: u64,
    diverged: bool,
    /// Why the run diverged (set alongside `diverged`): the ceiling
    /// crossing or the first named non-finite gradient site — what the
    /// supervisor records in its `recovery` manifest blocks.
    divergence_reason: Option<String>,
    noise_rng: crate::util::rng::Pcg64,
}

impl Trainer {
    /// XLA-engine trainer (the original artifact path) — signature kept
    /// for examples/tests that construct a `Runtime` themselves.
    pub fn new(runtime: Runtime, cfg: TrainConfig) -> Result<Trainer> {
        cfg.validate()?;
        let engine = XlaEngine::new(runtime, &cfg)?;
        Trainer::with_engine(Box::new(engine), cfg)
    }

    /// Native-engine trainer: in-process model + kernels, no artifacts.
    pub fn native(cfg: TrainConfig) -> Result<Trainer> {
        cfg.validate()?;
        let engine = NativeEngine::new(&cfg)?;
        Trainer::with_engine(Box::new(engine), cfg)
    }

    /// Wire the orchestration loop to any engine.
    pub fn with_engine(engine: Box<dyn TrainEngine>, cfg: TrainConfig) -> Result<Trainer> {
        cfg.validate()?;
        let (microbatch, seq_len) = engine.microbatch_shape();
        let micro_per_step =
            microbatches_for_tps(cfg.tokens_per_step, microbatch as u64, seq_len as u64)?;
        let schedule =
            CosineSchedule::new(cfg.peak_lr, cfg.warmup_steps, cfg.steps, cfg.min_lr_frac)?;
        let cfg_seed = cfg.seed;
        Ok(Trainer {
            cfg,
            metrics: Metrics::new(),
            engine,
            micro_per_step,
            schedule,
            step: 0,
            tokens_seen: 0,
            diverged: false,
            divergence_reason: None,
            noise_rng: crate::util::rng::Pcg64::new(cfg_seed, 0x4E01),
        })
    }

    pub fn engine_name(&self) -> &'static str {
        self.engine.name()
    }

    /// Peak `max_attn_logit` recorded so far (None on engines that don't
    /// report it).
    pub fn run_max_logit(&self) -> Option<f64> {
        self.metrics.get("max_attn_logit").and_then(|s| s.max_value())
    }

    pub fn microbatch_shape(&self) -> (usize, usize) {
        self.engine.microbatch_shape()
    }

    pub fn microbatches_per_step(&self) -> u64 {
        self.micro_per_step
    }

    pub fn step(&self) -> u64 {
        self.step
    }

    pub fn tokens_seen(&self) -> u64 {
        self.tokens_seen
    }

    /// Whether the run has hit a divergence condition.
    pub fn diverged(&self) -> bool {
        self.diverged
    }

    /// Why the run diverged (None while healthy).
    pub fn divergence_reason(&self) -> Option<&str> {
        self.divergence_reason.as_deref()
    }

    pub fn param_names(&self) -> &[String] {
        self.engine.param_names()
    }

    /// Decode the current parameters to host tensors (checkpoint path —
    /// not used in the training hot loop).
    pub fn params_host(&self) -> Result<Vec<Tensor>> {
        Ok(self.engine.state()?.params)
    }

    /// Build the variant's deterministic data pipeline.
    pub fn make_batcher(&self, vocab_size: usize, prefetch: usize) -> Result<PrefetchBatcher> {
        let (microbatch, seq_len) = self.engine.microbatch_shape();
        let tokenizer = crate::data::trained_tokenizer(self.cfg.seed, vocab_size)?;
        let inner = Batcher::new(tokenizer, self.cfg.seed, 0, microbatch, seq_len);
        Ok(PrefetchBatcher::spawn(inner, prefetch))
    }

    /// Tokenizer-independent batcher (raw bytes) — used when vocab == 256
    /// or for tests that want to skip BPE training.
    pub fn make_byte_batcher(&self, prefetch: usize) -> PrefetchBatcher {
        let (microbatch, seq_len) = self.engine.microbatch_shape();
        let inner = Batcher::new(
            Tokenizer::bytes_only(),
            self.cfg.seed,
            0,
            microbatch,
            seq_len,
        );
        PrefetchBatcher::spawn(inner, prefetch)
    }

    /// One optimizer step. Returns the step's mean loss.
    pub fn train_step(&mut self, batches: &mut PrefetchBatcher) -> Result<f64> {
        if self.diverged {
            bail!("trainer already diverged at step {}", self.step);
        }
        // The span-clock read below is the single step-timing source
        // (shared with the bench harness); the span itself roots the
        // fwd/bwd → layer → attention → GEMM hierarchy under `--trace`.
        let _span = trace::span("train_step");
        let t0 = trace::now_ns();
        // Fault plane (DESIGN.md §16): arm any panic/NaN fault scheduled
        // for this step before the first microbatch dispatch.
        crate::util::faults::begin_step(self.step);
        qerr::begin_step(self.step);
        let mut acc = GradAccumulator::new(self.engine.grad_shapes());
        let mut step_max_logit: Option<f64> = None;
        for _ in 0..self.micro_per_step {
            let batch = batches.next_batch()?;
            let stats = self.engine.grad_microbatch(&batch)?;
            acc.add(stats.loss as f32, &stats.grads)?;
            if let Some(ml) = stats.max_attn_logit {
                // NaN-aware fold (same contract as the model's per-head
                // fold): a plain max would discard a NaN from an earlier
                // microbatch and hide the divergence from the ceiling.
                let cur = step_max_logit.unwrap_or(f64::NEG_INFINITY);
                step_max_logit = Some(crate::util::stats::nan_max(cur, ml));
            }
            self.tokens_seen += batch.num_tokens();
        }

        let (loss, mut grads) = acc.take_mean()?;
        // Fault plane: poison the scheduled gradient slab (if any) before
        // the non-finite guards below, so the whole divergence/recovery
        // path downstream of a real NaN is exercised.
        if crate::util::faults::active() {
            let lens: Vec<usize> = grads.iter().map(|g| g.data.len()).collect();
            if let Some((leaf, idx)) =
                crate::util::faults::take_nan_slab(self.engine.param_names(), &lens)
            {
                grads[leaf].data[idx] = f32::NAN;
            }
        }
        // Post-processing: global-norm clip, then the §4.3 noise probe.
        let grad_norm =
            crate::coordinator::noise::clip_global_norm(&mut grads, self.cfg.clip_norm);
        if self.cfg.grad_noise_sigma > 0.0 {
            crate::coordinator::noise::add_relative_noise(
                &mut grads,
                self.cfg.grad_noise_sigma,
                &mut self.noise_rng,
            );
        }
        let lr = self.schedule.lr(self.step);

        // Telemetry recorded before the divergence decision, so the
        // divergence point itself is on every curve.
        self.metrics.record("train_loss", self.step, loss);
        self.metrics.record("lr", self.step, lr);
        self.metrics.record("grad_norm", self.step, grad_norm);
        self.metrics
            .record("tokens", self.step, self.tokens_seen as f64);
        if let Some(ml) = step_max_logit {
            self.metrics.record("max_attn_logit", self.step, ml);
        }
        if qerr::probing_configured() {
            // Sampled per-matmul quantization error (empty on unsampled
            // steps and on engines that never ran an INT8 kernel).
            for (name, rel, cos) in qerr::take_step() {
                self.metrics.record(&format!("qerr_{name}"), self.step, rel);
                self.metrics
                    .record(&format!("qerr_{name}_cos"), self.step, cos);
            }
        }

        // §5.3 divergence: the logit ceiling fires first (while curves are
        // still plottable); non-finite loss/grads is the backstop.  A NaN
        // statistic counts as a ceiling hit — `NaN > ceiling` is false, so
        // a plain comparison would let a non-finite activation sail past
        // the check (the telemetry chain is NaN-propagating end to end:
        // Tensor::max_abs → kernels::max_abs_logit → the model's fold).
        let ceiling_hit = step_max_logit
            .map(|ml| !ml.is_finite() || ml > self.cfg.max_attn_logit_ceiling)
            .unwrap_or(false);
        let nonfinite_grads = grads.iter().any(|g| !g.is_finite());
        if ceiling_hit || !loss.is_finite() || nonfinite_grads {
            self.diverged = true;
            self.divergence_reason = Some(if ceiling_hit {
                match step_max_logit {
                    Some(ml) if ml.is_finite() => format!(
                        "max_attn_logit {ml:.1} > {}",
                        self.cfg.max_attn_logit_ceiling
                    ),
                    _ => "non-finite max_attn_logit statistic".to_string(),
                }
            } else if nonfinite_grads {
                // Name the first offending site so recovery logs say
                // *which* gradient went non-finite.
                match crate::coordinator::accumulator::first_nonfinite_site(
                    self.engine.param_names(),
                    &grads,
                ) {
                    Some((name, idx, v)) => {
                        format!("non-finite gradient in {name}[{idx}] ({v})")
                    }
                    None => "non-finite gradients".to_string(),
                }
            } else {
                format!("non-finite loss ({loss})")
            });
            self.metrics.record("diverged", self.step, 1.0);
            self.metrics
                .record("step_ms", self.step, trace::now_ns().saturating_sub(t0) as f64 / 1e6);
            self.step += 1;
            return Ok(loss);
        }

        self.engine
            .apply(&grads, lr, self.step + 1)
            .with_context(|| format!("applying optimizer step {}", self.step))?;

        self.metrics
            .record("step_ms", self.step, trace::now_ns().saturating_sub(t0) as f64 / 1e6);
        self.step += 1;
        Ok(loss)
    }

    /// Run the configured number of steps (or until divergence).
    pub fn run(&mut self, batches: &mut PrefetchBatcher, log: &Log) -> Result<RunReport> {
        let total = self.cfg.steps;
        let (microbatch, seq_len) = self.engine.microbatch_shape();
        log.info(&format!(
            "run {} [{} engine]: {} steps × {} tok/step ({} microbatches of {}×{}) — {} total tokens",
            self.cfg.variant,
            self.engine.name(),
            total,
            fmt_count(self.cfg.tokens_per_step),
            self.micro_per_step,
            microbatch,
            seq_len,
            fmt_count(total * self.cfg.tokens_per_step),
        ));
        while self.step < total {
            let loss = self.train_step(batches)?;
            if self.diverged {
                let why = self
                    .divergence_reason
                    .clone()
                    .unwrap_or_else(|| "non-finite loss/grads".to_string());
                log.info(&format!(
                    "step {}: DIVERGED ({why}, loss={loss:.4})",
                    self.step - 1
                ));
                return Ok(RunReport {
                    status: RunStatus::Diverged {
                        at_step: self.step - 1,
                    },
                    steps_done: self.step,
                    final_loss: Some(loss),
                    tokens_seen: self.tokens_seen,
                    max_attn_logit: self.run_max_logit(),
                });
            }
            if self.cfg.log_every > 0 && self.step % self.cfg.log_every == 0 {
                let mut line = format!(
                    "step {:>5}/{total}  loss {:.4}  lr {:.2e}",
                    self.step,
                    loss,
                    self.schedule.lr(self.step - 1),
                );
                // Heartbeat: current span aggregate, only under --trace.
                if let Some(hb) = trace::heartbeat() {
                    line.push_str(&format!("  [{hb}]"));
                }
                log.info(&line);
            }
        }
        let final_loss = self
            .metrics
            .get("train_loss")
            .and_then(|s| s.tail_mean(std::cmp::max(1, (total / 20) as usize)));
        Ok(RunReport {
            status: RunStatus::Completed,
            steps_done: self.step,
            final_loss,
            tokens_seen: self.tokens_seen,
            max_attn_logit: self.run_max_logit(),
        })
    }

    /// Snapshot the full training state (params + AdamW moments + RNG +
    /// counters) as a checkpoint *value* — no I/O.  The supervisor stores
    /// the byte form content-addressed in the run registry.
    pub fn checkpoint(&self) -> Result<Checkpoint> {
        let state = self.engine.state()?;
        let mut tensors = Vec::with_capacity(3 * state.params.len());
        for (name, t) in state.names.iter().zip(&state.params) {
            tensors.push((name.clone(), t.clone()));
        }
        for (name, t) in state.names.iter().zip(&state.m) {
            tensors.push((format!("m.{name}"), t.clone()));
        }
        for (name, t) in state.names.iter().zip(&state.v) {
            tensors.push((format!("v.{name}"), t.clone()));
        }
        Ok(Checkpoint {
            step: self.step,
            tokens_seen: self.tokens_seen,
            rng: Some(RngState::from_rng(&self.noise_rng)),
            tensors,
        })
    }

    /// Save params + optimizer state + RNG + step (checkpoint format v2).
    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        self.checkpoint()?.save(path)
    }

    /// Restore from a checkpoint value.  Strict mode (`lenient = false`)
    /// requires every leaf of the current model in the checkpoint.
    /// Lenient mode exists for the supervisor's arm escalation: the new
    /// variant's schema may add leaves the checkpoint has never seen
    /// (e.g. the QK-norm gammas) — those keep their fresh initialization
    /// with zeroed moments, everything else restores from the checkpoint.
    /// Restoring also clears any divergence flag: a rollback is a return
    /// to a healthy state.
    pub fn restore(&mut self, ckpt: &Checkpoint, lenient: bool) -> Result<()> {
        let find = |prefix: &str, name: &str| -> Option<Tensor> {
            ckpt.tensors
                .iter()
                .find(|(n, _)| *n == format!("{prefix}{name}"))
                .map(|(_, t)| t.clone())
        };
        // Current engine state is the template: lenient fill keeps its
        // fresh-init params (and gets zero moments) for missing leaves.
        let current = self.engine.state()?;
        let names = current.names.clone();
        let mut state = EngineState {
            names: names.clone(),
            params: Vec::with_capacity(names.len()),
            m: Vec::with_capacity(names.len()),
            v: Vec::with_capacity(names.len()),
        };
        for (i, name) in names.iter().enumerate() {
            match (find("", name), find("m.", name), find("v.", name)) {
                (Some(p), Some(m), Some(v)) => {
                    state.params.push(p);
                    state.m.push(m);
                    state.v.push(v);
                }
                _ if lenient => {
                    let shape = current.params[i].shape.clone();
                    state.params.push(current.params[i].clone());
                    state.m.push(Tensor::zeros(&shape));
                    state.v.push(Tensor::zeros(&shape));
                }
                _ => bail!("checkpoint missing tensor {name} (or its m./v. moments)"),
            }
        }
        self.engine.load_state(&state)?;
        self.step = ckpt.step;
        self.tokens_seen = ckpt.tokens_seen;
        if let Some(rng) = &ckpt.rng {
            self.noise_rng = rng.to_rng();
        }
        self.diverged = false;
        self.divergence_reason = None;
        Ok(())
    }

    /// Restore state saved by [`Self::save_checkpoint`] (strict).
    pub fn load_checkpoint(&mut self, path: &Path) -> Result<()> {
        self.restore(&Checkpoint::load(path)?, false)
    }

    /// Compute the training loss of one provided batch without updating —
    /// used by harnesses for held-out probes.
    pub fn eval_loss(&mut self, batch: &crate::data::Batch) -> Result<f64> {
        self.engine.eval_loss(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::Log;

    fn cfg(variant: &str, steps: u64, tps: u64) -> TrainConfig {
        TrainConfig {
            variant: variant.into(),
            steps,
            tokens_per_step: tps,
            warmup_steps: 1,
            peak_lr: 3e-3,
            log_every: 0,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn native_trainer_completes_and_reduces_loss() {
        let mut t = Trainer::native(cfg("sage_qknorm", 5, 128)).unwrap();
        assert_eq!(t.engine_name(), "native");
        let mut b = t.make_byte_batcher(2);
        let report = t.run(&mut b, &Log::new(false)).unwrap();
        assert_eq!(report.status, RunStatus::Completed);
        assert_eq!(report.steps_done, 5);
        assert_eq!(report.tokens_seen, 5 * 128);
        assert!(report.max_attn_logit.unwrap() > 0.0);
        let losses = &t.metrics.get("train_loss").unwrap().points;
        assert!(losses.last().unwrap().1 < losses[0].1, "{losses:?}");
        // New telemetry series exist with one point per step.
        assert_eq!(t.metrics.get("max_attn_logit").unwrap().points.len(), 5);
        assert_eq!(t.metrics.get("step_ms").unwrap().points.len(), 5);
    }

    #[test]
    fn native_training_is_deterministic() {
        let run = || {
            let mut t = Trainer::native(cfg("sage_qknorm", 3, 128)).unwrap();
            let mut b = t.make_byte_batcher(2);
            t.run(&mut b, &Log::new(false)).unwrap();
            t.metrics.get("train_loss").unwrap().points.clone()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn logit_ceiling_flags_divergence_before_nonfinite_loss() {
        // An absurdly low ceiling turns a healthy run into a "divergence":
        // the finite loss at the flagged step proves the ceiling fires
        // before the loss explodes (which a healthy run never does).
        let mut c = cfg("fpa_qknorm", 4, 128);
        c.max_attn_logit_ceiling = 1e-6;
        let mut t = Trainer::native(c).unwrap();
        let mut b = t.make_byte_batcher(2);
        let report = t.run(&mut b, &Log::new(false)).unwrap();
        assert_eq!(report.status, RunStatus::Diverged { at_step: 0 });
        assert!(report.final_loss.unwrap().is_finite());
        assert_eq!(t.metrics.get("diverged").unwrap().points, vec![(0, 1.0)]);
        // train_step after divergence is an error, not a silent no-op.
        assert!(t.train_step(&mut b).is_err());
    }

    #[test]
    fn nan_logit_statistic_counts_as_ceiling_hit() {
        // Regression: `NaN > ceiling` is false, so a NaN max_attn_logit
        // could evade the divergence ceiling whenever the loss happened to
        // stay finite — the finite loss here proves the ceiling (not the
        // non-finite backstop) is what fires.
        struct NanLogitEngine {
            names: Vec<String>,
            shapes: Vec<Vec<usize>>,
        }
        impl TrainEngine for NanLogitEngine {
            fn name(&self) -> &'static str {
                "stub"
            }
            fn microbatch_shape(&self) -> (usize, usize) {
                (2, 32)
            }
            fn param_names(&self) -> &[String] {
                &self.names
            }
            fn grad_shapes(&self) -> &[Vec<usize>] {
                &self.shapes
            }
            fn grad_microbatch(
                &mut self,
                _batch: &crate::data::Batch,
            ) -> Result<crate::coordinator::engine::MicroStats> {
                Ok(crate::coordinator::engine::MicroStats {
                    loss: 1.0,
                    grads: vec![Tensor::zeros(&[2])],
                    max_attn_logit: Some(f64::NAN),
                })
            }
            fn apply(&mut self, _g: &[Tensor], _lr: f64, _s: u64) -> Result<()> {
                Ok(())
            }
            fn eval_loss(&mut self, _b: &crate::data::Batch) -> Result<f64> {
                Ok(1.0)
            }
            fn state(&self) -> Result<EngineState> {
                Ok(EngineState {
                    names: self.names.clone(),
                    params: vec![],
                    m: vec![],
                    v: vec![],
                })
            }
            fn load_state(&mut self, _s: &EngineState) -> Result<()> {
                Ok(())
            }
        }
        let engine = NanLogitEngine {
            names: vec!["w".into()],
            shapes: vec![vec![2]],
        };
        let mut t = Trainer::with_engine(Box::new(engine), cfg("sage_qknorm", 3, 64)).unwrap();
        let mut b = t.make_byte_batcher(1);
        let report = t.run(&mut b, &Log::new(false)).unwrap();
        assert_eq!(report.status, RunStatus::Diverged { at_step: 0 });
        assert!(
            report.final_loss.unwrap().is_finite(),
            "the NaN ceiling, not the loss backstop, must fire"
        );
    }

    #[test]
    fn native_checkpoint_roundtrip_resumes_identically() {
        let path = std::env::temp_dir()
            .join(format!("sagebwd_native_tr_{}.ckpt", std::process::id()));
        let mut a = Trainer::native(cfg("sage_qknorm", 3, 128)).unwrap();
        let mut ba = a.make_byte_batcher(2);
        a.train_step(&mut ba).unwrap();
        a.train_step(&mut ba).unwrap();
        a.save_checkpoint(&path).unwrap();
        let loss_a = a.train_step(&mut ba).unwrap();

        let mut b = Trainer::native(cfg("sage_qknorm", 3, 128)).unwrap();
        let mut bb = b.make_byte_batcher(2);
        for _ in 0..2 {
            b.train_step(&mut bb).unwrap();
        }
        b.load_checkpoint(&path).unwrap();
        let loss_b = b.train_step(&mut bb).unwrap();
        assert!(
            (loss_a - loss_b).abs() < 1e-9,
            "resume mismatch: {loss_a} vs {loss_b}"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn invalid_tps_rejected_by_native_engine_shape() {
        // 100 is not a multiple of microbatch×seq_len (2×32).
        assert!(Trainer::native(cfg("sage_qknorm", 2, 100)).is_err());
    }

    #[test]
    fn divergence_reason_names_the_ceiling() {
        let mut c = cfg("fpa_qknorm", 4, 128);
        c.max_attn_logit_ceiling = 1e-6;
        let mut t = Trainer::native(c).unwrap();
        let mut b = t.make_byte_batcher(2);
        t.train_step(&mut b).unwrap();
        assert!(t.diverged());
        let why = t.divergence_reason().unwrap();
        assert!(why.contains("max_attn_logit"), "{why}");
        assert!(why.contains("> 0.000001") || why.contains("> 1e-6"), "{why}");
    }

    #[test]
    fn nan_fault_reason_names_the_gradient_site() {
        crate::util::faults::install(
            crate::util::faults::parse_plan("seed=2; nan@1").unwrap(),
        );
        let mut t = Trainer::native(cfg("sage_qknorm", 4, 128)).unwrap();
        let mut b = t.make_byte_batcher(2);
        t.train_step(&mut b).unwrap();
        assert!(!t.diverged(), "step 0 is healthy; the fault is armed for step 1");
        t.train_step(&mut b).unwrap();
        assert!(t.diverged());
        let why = t.divergence_reason().unwrap().to_string();
        assert!(why.contains("non-finite gradient in "), "{why}");
        assert!(why.contains('[') && why.contains(']'), "must name the flat index: {why}");
        crate::util::faults::clear();
    }

    #[test]
    fn restore_clears_divergence_and_resumes() {
        let mut t = Trainer::native(cfg("sage_qknorm", 4, 128)).unwrap();
        let mut b = t.make_byte_batcher(2);
        t.train_step(&mut b).unwrap();
        let ckpt = t.checkpoint().unwrap();
        // Force a divergence with an injected NaN at step 1.
        crate::util::faults::install(
            crate::util::faults::parse_plan("nan@1").unwrap(),
        );
        t.train_step(&mut b).unwrap();
        crate::util::faults::clear();
        assert!(t.diverged());
        // Rollback: healthy again, stepping from the checkpoint's step.
        t.restore(&ckpt, false).unwrap();
        assert!(!t.diverged());
        assert!(t.divergence_reason().is_none());
        assert_eq!(t.step(), 1);
        assert!(t.train_step(&mut b).unwrap().is_finite());
    }

    #[test]
    fn lenient_restore_escalates_variant_schema() {
        // Arm escalation: a no-QK-norm checkpoint restored into a QK-norm
        // trainer.  Strict restore must fail (the gamma leaves are
        // missing); lenient restore keeps their fresh init + zero moments
        // and the escalated run trains on.
        let mut a = Trainer::native(cfg("sage_noqknorm", 3, 128)).unwrap();
        let mut ba = a.make_byte_batcher(2);
        a.train_step(&mut ba).unwrap();
        let ckpt = a.checkpoint().unwrap();

        let mut b = Trainer::native(cfg("sage_qknorm", 3, 128)).unwrap();
        assert!(b.restore(&ckpt, false).is_err());
        b.restore(&ckpt, true).unwrap();
        assert_eq!(b.step(), 1);
        assert_eq!(b.tokens_seen(), 128);
        let mut bb = b.make_byte_batcher(2);
        // Replay the stream to the checkpointed step (pure function of
        // seed), then continue.
        for _ in 0..b.microbatches_per_step() {
            bb.next_batch().unwrap();
        }
        assert!(b.train_step(&mut bb).unwrap().is_finite());
    }
}
