//! Batch pipeline: corpus → tokenizer → fixed-length (tokens, targets)
//! microbatches, with a prefetch thread and bounded backpressure.
//!
//! Determinism contract: the sequence of batches is a pure function of
//! (seed, shard) regardless of prefetch scheduling — the worker thread
//! just runs the same deterministic generator ahead of the consumer.

use std::sync::mpsc::{sync_channel, Receiver};
use std::thread::JoinHandle;

use anyhow::{bail, Result};

use crate::data::corpus::Corpus;
use crate::data::tokenizer::Tokenizer;
use crate::tensor::IntTensor;

/// One microbatch: `tokens[b, t]` and next-token `targets[b, t]`.
#[derive(Debug, Clone, PartialEq)]
pub struct Batch {
    pub tokens: IntTensor,
    pub targets: IntTensor,
}

impl Batch {
    pub fn num_tokens(&self) -> u64 {
        self.tokens.len() as u64
    }
}

/// Synchronous batch generator.
pub struct Batcher {
    corpus: Corpus,
    tokenizer: Tokenizer,
    batch: usize,
    seq_len: usize,
    /// Token buffer carried between fills.
    buf: Vec<i32>,
    text_buf: String,
}

impl Batcher {
    pub fn new(
        tokenizer: Tokenizer,
        seed: u64,
        shard: u64,
        batch: usize,
        seq_len: usize,
    ) -> Batcher {
        Batcher {
            corpus: Corpus::new(seed, shard),
            tokenizer,
            batch,
            seq_len,
            buf: Vec::new(),
            text_buf: String::new(),
        }
    }

    /// Produce the next microbatch (never exhausts — streaming corpus).
    pub fn next_batch(&mut self) -> Result<Batch> {
        let need = self.batch * (self.seq_len + 1);
        while self.buf.len() < need {
            self.text_buf.clear();
            // ≥4 bytes per token is a safe overshoot for byte-level BPE.
            self.corpus.fill_text(&mut self.text_buf, 4 * (need - self.buf.len()) + 64);
            self.buf.extend(self.tokenizer.encode(&self.text_buf));
        }
        let mut tokens = Vec::with_capacity(self.batch * self.seq_len);
        let mut targets = Vec::with_capacity(self.batch * self.seq_len);
        for b in 0..self.batch {
            let start = b * (self.seq_len + 1);
            let window = &self.buf[start..start + self.seq_len + 1];
            tokens.extend_from_slice(&window[..self.seq_len]);
            targets.extend_from_slice(&window[1..]);
        }
        self.buf.drain(..need);
        Ok(Batch {
            tokens: IntTensor::from_vec(&[self.batch, self.seq_len], tokens)?,
            targets: IntTensor::from_vec(&[self.batch, self.seq_len], targets)?,
        })
    }
}

/// Anything that can feed the prefetch thread.  [`Batcher`] is the
/// production source; tests inject failing sources to pin down error
/// propagation.
pub trait BatchSource: Send {
    fn next_batch(&mut self) -> Result<Batch>;
}

impl BatchSource for Batcher {
    fn next_batch(&mut self) -> Result<Batch> {
        Batcher::next_batch(self)
    }
}

/// Prefetching wrapper: runs a [`BatchSource`] on a worker thread with a
/// bounded queue (backpressure = queue depth).
///
/// Error contract: the worker sends `Result<Batch>` through the channel,
/// so a source failure reaches the consumer *as the original error* on the
/// next [`Self::next_batch`] call (previously the worker silently closed
/// the channel and the consumer saw a bare `RecvError`).
pub struct PrefetchBatcher {
    rx: Receiver<Result<Batch>>,
    handle: Option<JoinHandle<()>>,
}

impl PrefetchBatcher {
    pub fn spawn(inner: Batcher, depth: usize) -> PrefetchBatcher {
        PrefetchBatcher::spawn_source(Box::new(inner), depth)
    }

    /// Spawn over any source (tests use failing sources).
    pub fn spawn_source(mut inner: Box<dyn BatchSource>, depth: usize) -> PrefetchBatcher {
        let (tx, rx) = sync_channel(depth.max(1));
        let handle = std::thread::Builder::new()
            .name("batch-prefetch".into())
            .spawn(move || loop {
                let item = inner.next_batch();
                let stop = item.is_err();
                if tx.send(item).is_err() {
                    break; // consumer dropped
                }
                if stop {
                    break; // error delivered; the stream is over
                }
            })
            .expect("spawning prefetch thread");
        PrefetchBatcher {
            rx,
            handle: Some(handle),
        }
    }

    pub fn next_batch(&mut self) -> Result<Batch> {
        match self.rx.recv() {
            Ok(item) => item,
            // The worker only disconnects after delivering its final
            // Ok/Err item, so reaching here means the caller kept reading
            // past a reported error (or the worker panicked).
            Err(_) => bail!("batch stream ended (worker already reported an error or shut down)"),
        }
    }
}

impl Drop for PrefetchBatcher {
    fn drop(&mut self) {
        // Close the channel, then join the worker.
        let (_tx, rx) = sync_channel::<Result<Batch>>(1);
        let old = std::mem::replace(&mut self.rx, rx);
        drop(old);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok() -> Tokenizer {
        Tokenizer::bytes_only()
    }

    #[test]
    fn shapes_and_shift() {
        let mut b = Batcher::new(tok(), 0, 0, 2, 16);
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.tokens.shape, vec![2, 16]);
        assert_eq!(batch.targets.shape, vec![2, 16]);
        // targets are tokens shifted by one within each row window
        assert_eq!(batch.tokens.data[1..16], batch.targets.data[0..15]);
        assert_eq!(batch.num_tokens(), 32);
    }

    #[test]
    fn deterministic_stream() {
        let collect = |seed| {
            let mut b = Batcher::new(tok(), seed, 0, 2, 8);
            (0..5).map(|_| b.next_batch().unwrap()).collect::<Vec<_>>()
        };
        assert_eq!(collect(1), collect(1));
        assert_ne!(collect(1), collect(2));
    }

    #[test]
    fn shards_disjoint() {
        let mut a = Batcher::new(tok(), 1, 0, 1, 32);
        let mut b = Batcher::new(tok(), 1, 1, 1, 32);
        assert_ne!(a.next_batch().unwrap(), b.next_batch().unwrap());
    }

    #[test]
    fn tokens_in_byte_range() {
        let mut b = Batcher::new(tok(), 3, 0, 4, 64);
        for _ in 0..3 {
            let batch = b.next_batch().unwrap();
            assert!(batch.tokens.data.iter().all(|&t| (0..256).contains(&t)));
        }
    }

    #[test]
    fn prefetch_matches_sync() {
        let mut sync = Batcher::new(tok(), 5, 0, 2, 16);
        let mut pre = PrefetchBatcher::spawn(Batcher::new(tok(), 5, 0, 2, 16), 4);
        for _ in 0..8 {
            assert_eq!(sync.next_batch().unwrap(), pre.next_batch().unwrap());
        }
    }

    #[test]
    fn prefetch_drop_is_clean() {
        let pre = PrefetchBatcher::spawn(Batcher::new(tok(), 5, 0, 2, 16), 2);
        drop(pre); // must not hang or panic
    }

    /// A source that yields `good` batches and then fails — the regression
    /// harness for worker-error propagation.
    struct FailingSource {
        inner: Batcher,
        good: usize,
    }

    impl BatchSource for FailingSource {
        fn next_batch(&mut self) -> Result<Batch> {
            if self.good == 0 {
                anyhow::bail!("corpus shard went away mid-stream");
            }
            self.good -= 1;
            self.inner.next_batch()
        }
    }

    #[test]
    fn worker_error_reaches_consumer_verbatim() {
        let source = FailingSource {
            inner: Batcher::new(tok(), 9, 0, 2, 8),
            good: 2,
        };
        let mut pre = PrefetchBatcher::spawn_source(Box::new(source), 4);
        assert!(pre.next_batch().is_ok());
        assert!(pre.next_batch().is_ok());
        let err = pre.next_batch().unwrap_err();
        assert!(
            format!("{err:#}").contains("corpus shard went away"),
            "original error lost: {err:#}"
        );
        // Reading past the failure is a distinct, explicit error — not a
        // panic and not a bare RecvError.
        let after = pre.next_batch().unwrap_err();
        assert!(format!("{after:#}").contains("batch stream ended"));
    }

    #[test]
    fn immediate_worker_error_propagates() {
        let source = FailingSource {
            inner: Batcher::new(tok(), 9, 0, 1, 8),
            good: 0,
        };
        let mut pre = PrefetchBatcher::spawn_source(Box::new(source), 1);
        let err = pre.next_batch().unwrap_err();
        assert!(format!("{err:#}").contains("corpus shard went away"));
    }
}
