//! Synthetic corpus generator — the OpenWebText stand-in (DESIGN.md §6).
//!
//! The paper pre-trains on natural language; what the *experiments* need
//! from the data is (a) Zipfian unigram statistics, (b) local sequential
//! structure a causal LM can learn (so the loss curve has the familiar
//! shape), and (c) unbounded deterministic streaming.  We synthesize text
//! from a seeded lexicon of pronounceable words with first-order Markov
//! transitions and sentence punctuation — enough structure that a ~5M-param
//! model's loss drops well below the unigram entropy, mirroring a real
//! corpus qualitatively.

use crate::util::rng::Pcg64;

const SYLLABLES: &[&str] = &[
    "ba", "be", "bi", "bo", "bu", "da", "de", "di", "do", "du", "ka", "ke",
    "ki", "ko", "ku", "la", "le", "li", "lo", "lu", "ma", "me", "mi", "mo",
    "mu", "na", "ne", "ni", "no", "nu", "ra", "re", "ri", "ro", "ru", "sa",
    "se", "si", "so", "su", "ta", "te", "ti", "to", "tu", "va", "ve", "vi",
    "vo", "vu", "cha", "sho", "zen", "gor", "fin", "wex", "plu", "tra",
];

/// Streaming synthetic-text source.
pub struct Corpus {
    lexicon: Vec<String>,
    /// Markov row per word: a few preferred successors (topical locality).
    successors: Vec<Vec<u32>>,
    rng: Pcg64,
    /// Zipf exponent for unigram draws when leaving the Markov chain.
    zipf_s: f64,
    prev: Option<u32>,
    sentence_len: u32,
}

impl Corpus {
    /// Deterministic corpus for a (seed, shard) pair.  Different shards
    /// stream disjoint text (independent RNG streams).
    pub fn new(seed: u64, shard: u64) -> Corpus {
        let mut lex_rng = Pcg64::new(seed, 0xC0);
        let lexicon_size = 2048;
        let mut lexicon = Vec::with_capacity(lexicon_size);
        for _ in 0..lexicon_size {
            let syllables = 1 + lex_rng.below(3);
            let mut w = String::new();
            for _ in 0..=syllables {
                w.push_str(SYLLABLES[lex_rng.below(SYLLABLES.len() as u64) as usize]);
            }
            lexicon.push(w);
        }
        // Each word prefers 4 successors — the learnable bigram signal.
        let successors = (0..lexicon_size)
            .map(|_| {
                (0..4)
                    .map(|_| lex_rng.below(lexicon_size as u64) as u32)
                    .collect()
            })
            .collect();
        Corpus {
            lexicon,
            successors,
            rng: Pcg64::new(seed, 0xDA7A_0000 + shard),
            zipf_s: 1.1,
            prev: None,
            sentence_len: 0,
        }
    }

    fn next_word(&mut self) -> u32 {
        // 70%: follow the Markov chain; 30%: fresh Zipf draw.
        if let Some(prev) = self.prev {
            if self.rng.uniform() < 0.7 {
                let succ = &self.successors[prev as usize];
                return succ[self.rng.below(succ.len() as u64) as usize];
            }
        }
        self.rng.zipf(self.lexicon.len() as u64, self.zipf_s) as u32
    }

    /// Append roughly `min_bytes` of text to `out`.
    pub fn fill_text(&mut self, out: &mut String, min_bytes: usize) {
        let start = out.len();
        while out.len() - start < min_bytes {
            let w = self.next_word();
            if self.sentence_len == 0 {
                // Capitalize sentence starts (more byte diversity).
                let word = &self.lexicon[w as usize];
                let mut cs = word.chars();
                if let Some(c0) = cs.next() {
                    out.extend(c0.to_uppercase());
                    out.push_str(cs.as_str());
                }
            } else {
                out.push_str(&self.lexicon[w as usize]);
            }
            self.prev = Some(w);
            self.sentence_len += 1;
            if self.sentence_len >= 6 + self.rng.below(10) as u32 {
                out.push_str(". ");
                self.sentence_len = 0;
                self.prev = None;
            } else {
                out.push(' ');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn deterministic_per_seed_and_shard() {
        let gen = |seed, shard| {
            let mut c = Corpus::new(seed, shard);
            let mut s = String::new();
            c.fill_text(&mut s, 500);
            s
        };
        assert_eq!(gen(1, 0), gen(1, 0));
        assert_ne!(gen(1, 0), gen(2, 0));
        assert_ne!(gen(1, 0), gen(1, 1));
    }

    #[test]
    fn produces_sentences() {
        let mut c = Corpus::new(3, 0);
        let mut s = String::new();
        c.fill_text(&mut s, 2000);
        assert!(s.contains(". "));
        assert!(s.len() >= 2000);
        // Capitalized sentence starts exist.
        assert!(s.chars().any(|c| c.is_uppercase()));
    }

    #[test]
    fn unigram_distribution_is_skewed() {
        // Zipfian draws ⇒ the most common word is much more frequent than
        // the median word (what makes the LM task realistic).
        let mut c = Corpus::new(5, 0);
        let mut s = String::new();
        c.fill_text(&mut s, 100_000);
        let mut counts: HashMap<&str, u32> = HashMap::new();
        for w in s.split_whitespace() {
            *counts.entry(w.trim_end_matches('.')).or_default() += 1;
        }
        let mut freqs: Vec<u32> = counts.values().copied().collect();
        freqs.sort_unstable_by(|a, b| b.cmp(a));
        assert!(freqs[0] > 20 * freqs[freqs.len() / 2]);
    }

    #[test]
    fn streaming_continues() {
        let mut c = Corpus::new(7, 0);
        let mut a = String::new();
        c.fill_text(&mut a, 100);
        let mut b = String::new();
        c.fill_text(&mut b, 100);
        assert_ne!(a, b); // stream advances, no repetition loop
    }
}
