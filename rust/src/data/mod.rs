//! Data substrate: synthetic corpus → BPE tokenizer → batched token
//! streams (the OpenWebText + GPT2-tokenizer stand-in, DESIGN.md §6).

pub mod batcher;
pub mod corpus;
pub mod tokenizer;

pub use batcher::{Batch, BatchSource, Batcher, PrefetchBatcher};
pub use corpus::Corpus;
pub use tokenizer::Tokenizer;

use anyhow::Result;

/// Train a tokenizer for the given vocab on a fresh corpus sample.
/// Deterministic in `seed` (uses a dedicated shard so training text never
/// overlaps the training stream).
pub fn trained_tokenizer(seed: u64, vocab_size: usize) -> Result<Tokenizer> {
    let mut corpus = Corpus::new(seed, u64::MAX); // reserved tokenizer shard
    let mut sample = String::new();
    corpus.fill_text(&mut sample, 200_000);
    Tokenizer::train(&sample, vocab_size)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trained_tokenizer_fits_vocab() {
        let t = trained_tokenizer(0, 512).unwrap();
        assert_eq!(t.vocab_size(), 512);
        assert!(t.num_merges() > 0);
    }
}
