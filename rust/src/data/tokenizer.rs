//! Byte-level BPE tokenizer substrate (the GPT2-tokenizer stand-in).
//!
//! Vocabulary layout: ids 0–255 are raw bytes; ids 256.. are merge
//! products learned from a training sample by the classic BPE procedure
//! (merge the most frequent adjacent pair, repeat).  The model's
//! `vocab_size` is the hard cap, so `Tokenizer::train(sample, vocab_size)`
//! learns `vocab_size − 256` merges.
//!
//! Encoding is deterministic greedy merge application in learned order —
//! exactly GPT-2's algorithm (minus the regex pre-splitting, which our
//! synthetic corpus does not need).

use std::collections::HashMap;

use anyhow::{bail, Result};

/// A trained byte-level BPE tokenizer.
#[derive(Debug, Clone)]
pub struct Tokenizer {
    /// Learned merges in order: (left, right) → new id (256 + index).
    merges: Vec<(u32, u32)>,
    /// Fast lookup: pair → merged id.
    merge_map: HashMap<(u32, u32), u32>,
    vocab_size: usize,
}

impl Tokenizer {
    /// Byte-only tokenizer (no merges) with vocab 256.
    pub fn bytes_only() -> Tokenizer {
        Tokenizer {
            merges: Vec::new(),
            merge_map: HashMap::new(),
            vocab_size: 256,
        }
    }

    /// Learn `vocab_size - 256` merges from a text sample.
    pub fn train(sample: &str, vocab_size: usize) -> Result<Tokenizer> {
        if vocab_size < 256 {
            bail!("vocab_size must be ≥ 256, got {vocab_size}");
        }
        let mut ids: Vec<u32> = sample.bytes().map(|b| b as u32).collect();
        let mut merges = Vec::new();
        let mut merge_map = HashMap::new();
        for next_id in 256..vocab_size as u32 {
            // Count adjacent pairs.
            let mut counts: HashMap<(u32, u32), u32> = HashMap::new();
            for w in ids.windows(2) {
                *counts.entry((w[0], w[1])).or_default() += 1;
            }
            // Deterministic argmax: highest count, then smallest pair.
            let Some((&pair, &count)) = counts
                .iter()
                .max_by_key(|(&pair, &count)| (count, std::cmp::Reverse(pair)))
            else {
                break;
            };
            if count < 2 {
                break; // nothing left worth merging
            }
            merges.push(pair);
            merge_map.insert(pair, next_id);
            ids = merge_pair(&ids, pair, next_id);
        }
        Ok(Tokenizer {
            merges,
            merge_map,
            vocab_size,
        })
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab_size
    }

    pub fn num_merges(&self) -> usize {
        self.merges.len()
    }

    /// Encode text to token ids: repeatedly merge the lowest-rank adjacent
    /// pair (the standard BPE encode; identical output to applying merges
    /// in learned order, but O(pairs·merges-applied) instead of O(V·len)).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut ids: Vec<u32> = text.bytes().map(|b| b as u32).collect();
        while ids.len() >= 2 {
            // Lowest merged id == earliest-learned merge == highest priority.
            let best = ids
                .windows(2)
                .filter_map(|w| self.merge_map.get(&(w[0], w[1])).copied())
                .min();
            let Some(new_id) = best else { break };
            let (l, r) = self.merges[(new_id - 256) as usize];
            ids = merge_pair(&ids, (l, r), new_id);
        }
        ids.into_iter().map(|i| i as i32).collect()
    }

    /// Decode token ids back to text (lossless for valid UTF-8 input).
    pub fn decode(&self, ids: &[i32]) -> Result<String> {
        let mut bytes = Vec::with_capacity(ids.len() * 2);
        for &id in ids {
            self.push_bytes(id as u32, &mut bytes)?;
        }
        Ok(String::from_utf8_lossy(&bytes).into_owned())
    }

    fn push_bytes(&self, id: u32, out: &mut Vec<u8>) -> Result<()> {
        if id < 256 {
            out.push(id as u8);
            return Ok(());
        }
        let idx = (id - 256) as usize;
        if idx >= self.merges.len() {
            bail!("token id {id} out of vocabulary");
        }
        let (l, r) = self.merges[idx];
        self.push_bytes(l, out)?;
        self.push_bytes(r, out)?;
        Ok(())
    }
}

fn merge_pair(ids: &[u32], pair: (u32, u32), new_id: u32) -> Vec<u32> {
    let mut out = Vec::with_capacity(ids.len());
    let mut i = 0;
    while i < ids.len() {
        if i + 1 < ids.len() && (ids[i], ids[i + 1]) == pair {
            out.push(new_id);
            i += 2;
        } else {
            out.push(ids[i]);
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::Corpus;

    fn sample(bytes: usize) -> String {
        let mut c = Corpus::new(42, 0);
        let mut s = String::new();
        c.fill_text(&mut s, bytes);
        s
    }

    #[test]
    fn bytes_only_roundtrip() {
        let t = Tokenizer::bytes_only();
        let text = "Hello, world! ∀x";
        let ids = t.encode(text);
        assert_eq!(t.decode(&ids).unwrap(), text);
        assert_eq!(ids.len(), text.len()); // raw bytes
    }

    #[test]
    fn train_learns_merges_and_compresses() {
        let text = sample(50_000);
        let t = Tokenizer::train(&text, 512).unwrap();
        assert!(t.num_merges() > 100, "learned {} merges", t.num_merges());
        let ids = t.encode(&text[..1000]);
        assert!(
            ids.len() < 700,
            "BPE should compress: {} ids for 1000 bytes",
            ids.len()
        );
    }

    #[test]
    fn trained_roundtrip_lossless() {
        let text = sample(20_000);
        let t = Tokenizer::train(&text, 512).unwrap();
        let probe = &text[..2000];
        assert_eq!(t.decode(&t.encode(probe)).unwrap(), probe);
    }

    #[test]
    fn all_ids_within_vocab() {
        let text = sample(20_000);
        let t = Tokenizer::train(&text, 384).unwrap();
        let ids = t.encode(&text[..5000]);
        assert!(ids.iter().all(|&i| (0..384).contains(&i)));
    }

    #[test]
    fn training_is_deterministic() {
        let text = sample(10_000);
        let a = Tokenizer::train(&text, 320).unwrap();
        let b = Tokenizer::train(&text, 320).unwrap();
        assert_eq!(a.merges, b.merges);
    }

    #[test]
    fn rejects_tiny_vocab() {
        assert!(Tokenizer::train("abc", 100).is_err());
    }

    #[test]
    fn unknown_id_rejected() {
        let t = Tokenizer::bytes_only();
        assert!(t.decode(&[300]).is_err());
    }
}
