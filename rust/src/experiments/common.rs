//! Shared helpers for experiment harnesses: trace-artifact execution,
//! Gaussian input synthesis, and CSV emission.

use std::path::Path;

use anyhow::{Context, Result};

use crate::bench::Table;
use crate::registry::{Registry, RunState};
use crate::runtime::{AttentionBackend, Value};
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::rng::Pcg64;

/// Attention trace outputs, index-aligned with aot.TRACE_OUTPUTS.
#[derive(Debug)]
pub struct Trace {
    pub o: Tensor,
    pub dq: Tensor,
    pub dk: Tensor,
    pub dv: Tensor,
    pub delta: Tensor,
    pub rms_p: f64,
    pub rms_dp: f64,
    pub rms_ds: f64,
    pub p: Tensor,
    pub dp: Tensor,
    pub ds: Tensor,
}

/// Random (Q, K, V, dO) with per-tensor sigmas — the §4.4 controlled
/// setting (σ_V = σ_dO = 1, σ_Q = σ_K swept).
pub fn gaussian_qkvdo(
    n: usize,
    d: usize,
    sigma_q: f32,
    sigma_k: f32,
    sigma_v: f32,
    sigma_do: f32,
    seed: u64,
) -> [Tensor; 4] {
    let mut rng = Pcg64::new(seed, 0x51);
    [
        Tensor::randn(&[n, d], sigma_q, &mut rng.split(0)),
        Tensor::randn(&[n, d], sigma_k, &mut rng.split(1)),
        Tensor::randn(&[n, d], sigma_v, &mut rng.split(2)),
        Tensor::randn(&[n, d], sigma_do, &mut rng.split(3)),
    ]
}

/// Execute a `trace_*` artifact on (Q, K, V, dO) via any backend
/// (`--backend native` needs no artifacts at all — DESIGN.md §4).
pub fn run_trace(
    be: &mut dyn AttentionBackend,
    artifact: &str,
    qkvdo: &[Tensor; 4],
) -> Result<Trace> {
    let inputs: Vec<Value> = qkvdo.iter().map(|t| Value::F32(t.clone())).collect();
    let out = be
        .execute(artifact, &inputs)
        .with_context(|| format!("running trace artifact {artifact}"))?;
    let mut it = out.into_iter();
    let mut next = || -> Result<Tensor> { it.next().context("missing trace output")?.into_f32() };
    Ok(Trace {
        o: next()?,
        dq: next()?,
        dk: next()?,
        dv: next()?,
        delta: next()?,
        rms_p: next()?.item() as f64,
        rms_dp: next()?.item() as f64,
        rms_ds: next()?.item() as f64,
        p: next()?,
        dp: next()?,
        ds: next()?,
    })
}

/// Print a table and record it through the run registry: the CSV becomes
/// a content-addressed object with its legacy `results/<name>.csv` path
/// kept as a view, and the footer reports where it went plus the content
/// hash (so a figure in a writeup can cite the exact table bytes).
pub fn emit(table: &Table, results_dir: &str, name: &str) -> Result<()> {
    println!("{}", table.render());
    let csv = table.to_csv();
    let registry = Registry::open(results_dir).context("opening run registry")?;
    let config = Json::from_pairs(vec![
        ("kind", Json::from("table")),
        ("name", Json::from(name)),
    ]);
    let mut run = registry.begin_run("table", name, config)?;
    let path = Path::new(results_dir).join(format!("{name}.csv"));
    let hash = run
        .record_bytes(&format!("{name}.csv"), csv.as_bytes(), Some(&path))
        .with_context(|| format!("recording {}", path.display()))?;
    run.finish(RunState::Complete)?;
    println!(
        "→ wrote {} ({} bytes, sha256 {})",
        path.display(),
        csv.len(),
        &hash[..16]
    );
    Ok(())
}

pub fn fmt4(x: f64) -> String {
    format!("{x:.4}")
}

pub fn fmt_sci(x: f64) -> String {
    format!("{x:.3e}")
}
