//! **§4.2 probe** — the magnitude hierarchy RMS(P) ≫ RMS(dP) ≫ RMS(dS)
//! and the Appendix-B 1/√N scaling of dS.

use anyhow::Result;

use crate::bench::Table;
use crate::experiments::common::{emit, fmt_sci, gaussian_qkvdo, run_trace};
use crate::runtime::AttentionBackend;

pub struct Row {
    pub n: usize,
    pub rms_p: f64,
    pub rms_dp: f64,
    pub rms_ds: f64,
}

pub fn run(be: &mut dyn AttentionBackend, results_dir: &str) -> Result<Vec<Row>> {
    println!("§4.2 probe: RMS magnitudes of P, dP, dS (trained-regime surrogate inputs)");
    println!("(paper at N=4096: RMS(P)≈5e-3, RMS(dP)≈5e-5, RMS(dS)≈1e-7)\n");
    let mut table = Table::new(&["N", "rms_P", "rms_dP", "rms_dS", "dP/dS ratio", "1/sqrt(N)"]);
    let mut rows = Vec::new();
    for (artifact, n) in [("trace_fpa", 128usize), ("trace_fpa_n512", 512usize)] {
        // Small upstream gradients emulate the trained regime (§4.2).
        let qkvdo = gaussian_qkvdo(n, 64, 1.0, 1.0, 1.0, 1e-3, 99);
        let tr = run_trace(be, artifact, &qkvdo)?;
        table.row(vec![
            n.to_string(),
            fmt_sci(tr.rms_p),
            fmt_sci(tr.rms_dp),
            fmt_sci(tr.rms_ds),
            format!("{:.1}", tr.rms_dp / tr.rms_ds.max(1e-300)),
            fmt_sci(1.0 / (n as f64).sqrt()),
        ]);
        rows.push(Row {
            n,
            rms_p: tr.rms_p,
            rms_dp: tr.rms_dp,
            rms_ds: tr.rms_ds,
        });
    }
    emit(&table, results_dir, "ds_rms")?;
    Ok(rows)
}
