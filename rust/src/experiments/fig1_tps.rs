//! **Figure 1** — pretraining loss of SageBwd vs FPA at high and low
//! tokens-per-step (paper §5.2), with and without QK-norm (§5.3).
//!
//! Paper setup → ours (DESIGN.md §6): 2.1M/260K TPS (ratio 8×) becomes
//! `tps_hi`/`tps_lo` with the same 8× ratio at our microbatch×seq_len
//! granularity; curves are emitted per variant for plotting, and the
//! summary prints final losses + the Sage−FPA gap.
//!
//! Expected shape: at high TPS Sage trails FPA by a visible gap and the
//! non-QK-norm run destabilizes; at low TPS Sage ≈ FPA within noise.

use anyhow::Result;

use crate::bench::Table;
use crate::config::TrainConfig;
use crate::coordinator::{RunStatus, Trainer};
use crate::experiments::common::emit;
use crate::runtime::Runtime;
use crate::telemetry::{run_dir, Log};

pub struct Outcome {
    pub variant: String,
    pub tps: u64,
    pub final_loss: Option<f64>,
    pub diverged: bool,
}

/// One (variant, TPS) training run; loss curve lands in
/// `results/fig1/<variant>_tps<k>.csv`.
///
/// `token_budget` is fixed across cells (the paper's comparison: 78B
/// tokens at both TPS settings), so high-TPS cells take fewer steps.
pub fn run_cell(
    rt_factory: &dyn Fn() -> Result<Runtime>,
    results_dir: &str,
    variant: &str,
    tps: u64,
    token_budget: u64,
    seed: u64,
    log: &Log,
) -> Result<Outcome> {
    let steps = (token_budget / tps).max(2);
    let cfg = TrainConfig {
        variant: variant.to_string(),
        steps,
        tokens_per_step: tps,
        warmup_steps: (steps / 20).max(1),
        peak_lr: 3e-3,
        min_lr_frac: 0.1,
        seed,
        checkpoint_every: 0,
        log_every: (steps / 10).max(1),
        clip_norm: 0.0,
        grad_noise_sigma: 0.0,
    };
    let mut trainer = Trainer::new(rt_factory()?, cfg)?;
    let mut batches = trainer.make_batcher(512, 4)?;
    let report = trainer.run(&mut batches, log)?;
    let dir = run_dir(results_dir, "fig1")?;
    // One CSV per curve: fig1/<variant>_tps<tps>.{train_loss,lr,...}.csv
    let curve_dir = dir.join(format!("{variant}_tps{tps}"));
    trainer.metrics.flush_csv(&curve_dir)?;
    Ok(Outcome {
        variant: variant.to_string(),
        tps,
        final_loss: report.final_loss,
        diverged: matches!(report.status, RunStatus::Diverged { .. }),
    })
}

/// The full Figure-1 grid.
pub fn run(
    rt_factory: &dyn Fn() -> Result<Runtime>,
    results_dir: &str,
    token_budget: u64,
    tps_lo: u64,
    tps_hi: u64,
    seed: u64,
) -> Result<Vec<Outcome>> {
    let log = Log::new(true);
    println!(
        "Figure 1: pretraining loss, SageBwd vs FPA at TPS_hi={tps_hi} / TPS_lo={tps_lo} \
         (fixed budget {token_budget} tokens per cell)"
    );
    println!("(paper: hi-TPS gap 2.640 vs 2.586; lo-TPS parity 2.561 vs 2.563; no-QK-norm diverges at hi TPS)\n");
    let mut outcomes = Vec::new();
    let grid: &[(&str, u64)] = &[
        // Figure 1a (high TPS): the gap + the divergence case.
        ("fpa_qknorm", tps_hi),
        ("sage_qknorm", tps_hi),
        ("sage_noqknorm", tps_hi),
        // Figure 1b (low TPS): parity, ±QK-norm.
        ("fpa_qknorm", tps_lo),
        ("sage_qknorm", tps_lo),
        ("sage_noqknorm", tps_lo),
        ("fpa_noqknorm", tps_lo),
    ];
    for &(variant, tps) in grid {
        log.info(&format!("--- fig1 cell: {variant} @ {tps} tok/step ---"));
        outcomes.push(run_cell(
            rt_factory, results_dir, variant, tps, token_budget, seed, &log,
        )?);
    }

    let mut table = Table::new(&["variant", "tokens_per_step", "final_loss", "status"]);
    for o in &outcomes {
        table.row(vec![
            o.variant.clone(),
            o.tps.to_string(),
            o.final_loss.map(|l| format!("{l:.4}")).unwrap_or("-".into()),
            if o.diverged { "DIVERGED".into() } else { "ok".into() },
        ]);
    }
    emit(&table, results_dir, "fig1_summary")?;
    Ok(outcomes)
}
