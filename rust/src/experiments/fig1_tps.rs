//! **Figure 1** — pretraining loss of SageBwd vs FPA at high and low
//! tokens-per-step (paper §5.2), with and without QK-norm (§5.3).
//!
//! Paper setup → ours (DESIGN.md §6): 2.1M/260K TPS (ratio 8×) becomes
//! `tps_hi`/`tps_lo` with the same 8× ratio at our microbatch×seq_len
//! granularity; curves are emitted per variant for plotting, and the
//! summary prints final losses, the Sage−FPA gap, and each cell's
//! `max_attn_logit` (the §5.3 divergence statistic).
//!
//! Expected shape: the QK-normed arms complete with logits bounded far
//! below the ceiling (the RMS-normalized rows cap |S| near √d·γ², the
//! paper's claim (i) mechanism) while the no-QK-norm arms grow their
//! logits until the `max_attn_logit` ceiling (default 50.0) flags
//! divergence — at the high-TPS arm within the first quarter of the
//! budget.  Runs on either engine via `--backend native|xla`; the native
//! engine needs nothing but this checkout.
//!
//! Default `peak_lr` 0.1 is validated by the LR sweep in
//! `python/compile/check_native_model.py --sim`: across seeds the
//! no-QK-norm high-TPS arm crosses the ceiling by step ~3–6 of 16 and
//! QK-norm arms stay ≥5× below it.

use anyhow::Result;

use crate::bench::Table;
use crate::config::TrainConfig;
use crate::coordinator::{RunStatus, TrainerFactory};
use crate::experiments::common::emit;
use crate::telemetry::{run_dir, Log};

pub struct Outcome {
    pub variant: String,
    pub tps: u64,
    pub final_loss: Option<f64>,
    pub diverged: bool,
    pub diverged_at: Option<u64>,
    pub max_attn_logit: Option<f64>,
}

/// One (variant, TPS) training run; loss curve lands in
/// `results/fig1/<variant>_tps<k>.csv`.
///
/// `token_budget` is fixed across cells (the paper's comparison: 78B
/// tokens at both TPS settings), so high-TPS cells take fewer steps.
#[allow(clippy::too_many_arguments)]
pub fn run_cell(
    factory: &TrainerFactory,
    results_dir: &str,
    variant: &str,
    tps: u64,
    token_budget: u64,
    peak_lr: f64,
    seed: u64,
    log: &Log,
) -> Result<Outcome> {
    let steps = (token_budget / tps).max(2);
    let cfg = TrainConfig {
        variant: variant.to_string(),
        steps,
        tokens_per_step: tps,
        warmup_steps: (steps / 20).max(1),
        peak_lr,
        min_lr_frac: 0.1,
        seed,
        checkpoint_every: 0,
        log_every: (steps / 10).max(1),
        clip_norm: 0.0,
        grad_noise_sigma: 0.0,
        ..TrainConfig::default()
    };
    let mut trainer = factory.trainer(cfg)?;
    let mut batches = trainer.make_batcher(512, 4)?;
    let report = trainer.run(&mut batches, log)?;
    let dir = run_dir(results_dir, "fig1")?;
    // One CSV per curve: fig1/<variant>_tps<tps>.{train_loss,max_attn_logit,...}.csv
    let curve_dir = dir.join(format!("{variant}_tps{tps}"));
    trainer.metrics.flush_csv(&curve_dir)?;
    let diverged_at = match report.status {
        RunStatus::Diverged { at_step } => Some(at_step),
        RunStatus::Completed => None,
    };
    Ok(Outcome {
        variant: variant.to_string(),
        tps,
        final_loss: report.final_loss,
        diverged: diverged_at.is_some(),
        diverged_at,
        max_attn_logit: report.max_attn_logit,
    })
}

/// The full Figure-1 grid.
pub fn run(
    factory: &TrainerFactory,
    results_dir: &str,
    token_budget: u64,
    tps_lo: u64,
    tps_hi: u64,
    peak_lr: f64,
    seed: u64,
) -> Result<Vec<Outcome>> {
    let log = Log::new(true);
    println!(
        "Figure 1 [{} engine]: pretraining loss, SageBwd vs FPA at TPS_hi={tps_hi} / \
         TPS_lo={tps_lo} (fixed budget {token_budget} tokens per cell, peak_lr {peak_lr})",
        factory.backend_name(),
    );
    println!("(paper: hi-TPS gap 2.640 vs 2.586; lo-TPS parity 2.561 vs 2.563; no-QK-norm diverges at hi TPS)\n");
    let mut outcomes = Vec::new();
    let grid: &[(&str, u64)] = &[
        // Figure 1a (high TPS): the gap + the divergence case.
        ("fpa_qknorm", tps_hi),
        ("sage_qknorm", tps_hi),
        ("sage_noqknorm", tps_hi),
        // Figure 1b (low TPS): parity, ±QK-norm.
        ("fpa_qknorm", tps_lo),
        ("sage_qknorm", tps_lo),
        ("sage_noqknorm", tps_lo),
        ("fpa_noqknorm", tps_lo),
    ];
    for &(variant, tps) in grid {
        log.info(&format!("--- fig1 cell: {variant} @ {tps} tok/step ---"));
        outcomes.push(run_cell(
            factory, results_dir, variant, tps, token_budget, peak_lr, seed, &log,
        )?);
    }

    let mut table = Table::new(&[
        "variant",
        "tokens_per_step",
        "final_loss",
        "max_attn_logit",
        "status",
    ]);
    for o in &outcomes {
        table.row(vec![
            o.variant.clone(),
            o.tps.to_string(),
            o.final_loss.map(|l| format!("{l:.4}")).unwrap_or("-".into()),
            o.max_attn_logit
                .map(|m| format!("{m:.1}"))
                .unwrap_or("-".into()),
            match o.diverged_at {
                Some(at) => format!("DIVERGED@{at}"),
                None => "ok".into(),
            },
        ]);
    }
    emit(&table, results_dir, "fig1_summary")?;
    Ok(outcomes)
}
