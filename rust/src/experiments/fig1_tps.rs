//! **Figure 1** — pretraining loss of SageBwd vs FPA at high and low
//! tokens-per-step (paper §5.2), with and without QK-norm (§5.3).
//!
//! Paper setup → ours (DESIGN.md §6): 2.1M/260K TPS (ratio 8×) becomes
//! `tps_hi`/`tps_lo` with the same 8× ratio at our microbatch×seq_len
//! granularity; curves are emitted per variant for plotting, and the
//! summary prints final losses, the Sage−FPA gap, and each cell's
//! `max_attn_logit` (the §5.3 divergence statistic).
//!
//! Expected shape: the QK-normed arms complete with logits bounded far
//! below the ceiling (the RMS-normalized rows cap |S| near √d·γ², the
//! paper's claim (i) mechanism) while the no-QK-norm arms grow their
//! logits until the `max_attn_logit` ceiling (default 50.0) flags
//! divergence — at the high-TPS arm within the first quarter of the
//! budget.  Runs on either engine via `--backend native|xla`; the native
//! engine needs nothing but this checkout.
//!
//! Every cell records through the run registry (DESIGN.md §12): the
//! curve CSVs are content-addressed objects with legacy views at
//! `results/fig1/<variant>_tps<tps>[_seed<s>]/`, and a cell whose config
//! already has a *finished* manifest (complete or diverged) is a registry
//! hit — its outcome is replayed from the manifest summary instead of
//! retrained.  `--fresh` forces recomputation.
//!
//! Default `peak_lr` 0.1 is validated by the LR sweep in
//! `python/compile/check_native_model.py --sim`: across seeds the
//! no-QK-norm high-TPS arm crosses the ceiling by step ~3–6 of 16 and
//! QK-norm arms stay ≥5× below it.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::bench::Table;
use crate::config::TrainConfig;
use crate::coordinator::{supervisor, RunStatus, SupervisorConfig, TrainerFactory};
use crate::experiments::common::emit;
use crate::registry::{Registry, RunManifest, RunState};
use crate::telemetry::{trace, Log};
use crate::util::json::{schema, Json};

pub struct Outcome {
    pub variant: String,
    pub tps: u64,
    pub final_loss: Option<f64>,
    pub diverged: bool,
    pub diverged_at: Option<u64>,
    pub max_attn_logit: Option<f64>,
}

/// Everything a training cell needs besides its own (variant, tps, seed)
/// coordinates — shared by the fig1/fig4 harnesses and the grid
/// orchestrator's workers (all fields are `Sync`).
pub struct CellCtx<'a> {
    pub factory: &'a TrainerFactory,
    pub registry: &'a Registry,
    pub results_dir: &'a str,
    /// Manifest grouping label (`fig1`, `fig4`, ...) — not part of the
    /// run key, so identical configs dedup across grids.
    pub experiment: &'a str,
    /// Ignore finished manifests and retrain.
    pub fresh: bool,
    /// Run cells under the fault-tolerant supervisor (DESIGN.md §16):
    /// periodic registry checkpoints + the divergence-recovery ladder.
    /// `None` keeps the plain one-shot `Trainer::run` path (identical
    /// registry keys either way — supervision is not part of identity).
    pub supervise: Option<SupervisorConfig>,
}

/// The exact `TrainConfig` of one (variant, TPS, seed) cell — factored
/// out so harnesses and the orchestrator derive identical run keys.
///
/// `token_budget` is fixed across cells (the paper's comparison: 78B
/// tokens at both TPS settings), so high-TPS cells take fewer steps.
pub fn cell_config(
    variant: &str,
    tps: u64,
    token_budget: u64,
    peak_lr: f64,
    seed: u64,
) -> TrainConfig {
    let steps = (token_budget / tps).max(2);
    TrainConfig {
        variant: variant.to_string(),
        steps,
        tokens_per_step: tps,
        warmup_steps: (steps / 20).max(1),
        peak_lr,
        min_lr_frac: 0.1,
        seed,
        checkpoint_every: 0,
        log_every: (steps / 10).max(1),
        clip_norm: 0.0,
        grad_noise_sigma: 0.0,
        ..TrainConfig::default()
    }
}

/// The cell's human label == its legacy curve-dir name.  Seed 0 keeps the
/// historical `<variant>_tps<tps>` (CI plots read those paths); other
/// seeds get a `_seed<s>` suffix.
pub fn cell_label(variant: &str, tps: u64, seed: u64) -> String {
    if seed == 0 {
        format!("{variant}_tps{tps}")
    } else {
        format!("{variant}_tps{tps}_seed{seed}")
    }
}

/// Canonical key material for a training cell: the full config plus the
/// execution backend (a native run is not an XLA run).
pub fn cell_key(factory: &TrainerFactory, cfg: &TrainConfig) -> (Json, String) {
    let mut config = cfg.to_json();
    config.set("backend", Json::from(factory.backend_name()));
    let key = Registry::run_key(&config, factory.backend_name());
    (config, key)
}

/// Rebuild a cell outcome from a finished manifest's summary — the
/// registry-hit path.
fn outcome_from_manifest(variant: &str, tps: u64, m: &RunManifest) -> Result<Outcome> {
    let s = &m.summary;
    let diverged_at = schema::nullable_f64_field(s, "diverged_at")
        .context("manifest summary")?
        .map(|v| v as u64);
    Ok(Outcome {
        variant: variant.to_string(),
        tps,
        final_loss: schema::nullable_f64_field(s, "final_loss").context("manifest summary")?,
        diverged: diverged_at.is_some(),
        diverged_at,
        max_attn_logit: schema::nullable_f64_field(s, "max_attn_logit")
            .context("manifest summary")?,
    })
}

fn num_or_null(v: Option<f64>) -> Json {
    v.map(Json::from).unwrap_or(Json::Null)
}

/// One (variant, TPS, seed) training run through the registry; curve
/// views land in `results/fig1/<label>/<series>.csv` (fig4 reuses the
/// same shared curve dirs, exactly like the legacy layout did).
pub fn run_cell(
    ctx: &CellCtx<'_>,
    variant: &str,
    tps: u64,
    token_budget: u64,
    peak_lr: f64,
    seed: u64,
    log: &Log,
) -> Result<Outcome> {
    let cfg = cell_config(variant, tps, token_budget, peak_lr, seed);
    let label = cell_label(variant, tps, seed);
    let (config, key) = cell_key(ctx.factory, &cfg);

    if !ctx.fresh {
        if let Some(m) = ctx.registry.load_run(&key)? {
            if m.status.is_finished() {
                log.info(&format!(
                    "registry hit [{}]: {label} already {} — skipping",
                    &key[..16],
                    m.status.as_str()
                ));
                // Re-materialize missing legacy views (plots keep working
                // even if results/ was partially cleaned); best-effort —
                // the manifest is the source of truth.
                for a in &m.artifacts {
                    if let Some(view) = &a.view {
                        if let Err(e) = ctx.registry.write_view(&a.sha256, Path::new(view)) {
                            log.debug(&format!("view {view} not restored: {e:#}"));
                        }
                    }
                }
                return outcome_from_manifest(variant, tps, &m);
            }
        }
    }

    if let Some(sup) = &ctx.supervise {
        // Supervised path (DESIGN.md §16): periodic registry checkpoints,
        // divergence recovery, and in-place resume live in
        // coordinator::supervisor.  Run key and summary schema match the
        // plain path exactly, so registry hits work across both.
        let view_dir = PathBuf::from(ctx.results_dir).join("fig1").join(&label);
        let out = supervisor::run_supervised(
            ctx.factory, ctx.registry, ctx.experiment, &label, &cfg, sup, &view_dir, log,
        )?;
        if out.halted {
            anyhow::bail!(
                "supervised cell {label} halted mid-run (halt_after fired); \
                 resume it to finish"
            );
        }
        let diverged_at = match out.report.status {
            RunStatus::Diverged { at_step } => Some(at_step),
            RunStatus::Completed => None,
        };
        return Ok(Outcome {
            variant: variant.to_string(),
            tps,
            final_loss: out.report.final_loss,
            diverged: diverged_at.is_some(),
            diverged_at,
            max_attn_logit: out.report.max_attn_logit,
        });
    }

    let mut run = ctx.registry.begin_run_keyed(ctx.experiment, &label, config, key)?;
    let mut trainer = ctx.factory.trainer(cfg)?;
    let mut batches = trainer.make_batcher(512, 4)?;
    // Fresh span/counter aggregate per cell so the recorded trace covers
    // exactly this run.  (Under the parallel grid orchestrator, cells that
    // overlap in time still share the process-global aggregate — see
    // DESIGN.md §14 for that documented limitation.)
    if trace::enabled() {
        trace::reset();
    }
    let report = match trainer.run(&mut batches, log) {
        Ok(r) => r,
        Err(e) => {
            // Leave a `failed` manifest so `grid status` names the cell;
            // the original error is what the caller sees.
            let _ = run.finish(RunState::Failed);
            return Err(e);
        }
    };

    let view_dir = PathBuf::from(ctx.results_dir).join("fig1").join(&label);
    run.record_metrics(&trainer.metrics, &view_dir)?;

    // Persist the span/counter trace as a content-addressed run artifact
    // (with a legacy view next to the curve CSVs) and fold its headline
    // numbers into the manifest summary.
    let trace_summary = if trace::enabled() {
        let tr = trace::take_report();
        run.record_bytes(
            "trace.jsonl",
            tr.to_jsonl().as_bytes(),
            Some(&view_dir.join("trace.jsonl")),
        )?;
        Some(tr.summary_json())
    } else {
        None
    };

    let diverged_at = match report.status {
        RunStatus::Diverged { at_step } => Some(at_step),
        RunStatus::Completed => None,
    };
    let mut summary = vec![
        ("diverged_at", num_or_null(diverged_at.map(|s| s as f64))),
        ("final_loss", num_or_null(report.final_loss)),
        ("max_attn_logit", num_or_null(report.max_attn_logit)),
        ("steps_done", Json::from(report.steps_done as i64)),
        ("tokens_seen", Json::from(report.tokens_seen as i64)),
    ];
    if let Some(tr) = trace_summary {
        summary.push(("trace", tr));
    }
    run.set_summary(Json::from_pairs(summary));
    run.finish(if diverged_at.is_some() {
        RunState::Diverged
    } else {
        RunState::Complete
    })?;

    Ok(Outcome {
        variant: variant.to_string(),
        tps,
        final_loss: report.final_loss,
        diverged: diverged_at.is_some(),
        diverged_at,
        max_attn_logit: report.max_attn_logit,
    })
}

/// The Figure-1 arm list: (variant, tps) per cell.
pub fn grid(tps_lo: u64, tps_hi: u64) -> Vec<(&'static str, u64)> {
    vec![
        // Figure 1a (high TPS): the gap + the divergence case.
        ("fpa_qknorm", tps_hi),
        ("sage_qknorm", tps_hi),
        ("sage_noqknorm", tps_hi),
        // Figure 1b (low TPS): parity, ±QK-norm.
        ("fpa_qknorm", tps_lo),
        ("sage_qknorm", tps_lo),
        ("sage_noqknorm", tps_lo),
        ("fpa_noqknorm", tps_lo),
    ]
}

/// The full Figure-1 grid.
#[allow(clippy::too_many_arguments)]
pub fn run(
    factory: &TrainerFactory,
    results_dir: &str,
    token_budget: u64,
    tps_lo: u64,
    tps_hi: u64,
    peak_lr: f64,
    seed: u64,
    fresh: bool,
) -> Result<Vec<Outcome>> {
    let log = Log::new(true);
    println!(
        "Figure 1 [{} engine]: pretraining loss, SageBwd vs FPA at TPS_hi={tps_hi} / \
         TPS_lo={tps_lo} (fixed budget {token_budget} tokens per cell, peak_lr {peak_lr})",
        factory.backend_name(),
    );
    println!("(paper: hi-TPS gap 2.640 vs 2.586; lo-TPS parity 2.561 vs 2.563; no-QK-norm diverges at hi TPS)\n");
    let registry = Registry::open(results_dir)?;
    let ctx = CellCtx {
        factory,
        registry: &registry,
        results_dir,
        experiment: "fig1",
        fresh,
        supervise: None,
    };
    let mut outcomes = Vec::new();
    for (variant, tps) in grid(tps_lo, tps_hi) {
        log.info(&format!("--- fig1 cell: {variant} @ {tps} tok/step ---"));
        outcomes.push(run_cell(
            &ctx, variant, tps, token_budget, peak_lr, seed, &log,
        )?);
    }

    let mut table = Table::new(&[
        "variant",
        "tokens_per_step",
        "final_loss",
        "max_attn_logit",
        "status",
    ]);
    for o in &outcomes {
        table.row(vec![
            o.variant.clone(),
            o.tps.to_string(),
            o.final_loss.map(|l| format!("{l:.4}")).unwrap_or("-".into()),
            o.max_attn_logit
                .map(|m| format!("{m:.1}"))
                .unwrap_or("-".into()),
            match o.diverged_at {
                Some(at) => format!("DIVERGED@{at}"),
                None => "ok".into(),
            },
        ]);
    }
    emit(&table, results_dir, "fig1_summary")?;
    Ok(outcomes)
}
