//! **Figures 2–3** — kernel speed: SageBwd vs FA2-style vs naive SDPA,
//! forward and forward+backward, head dims 128 (Fig 2) and 64 (Fig 3).
//!
//! Two complementary readings (DESIGN.md §6–7):
//!
//! 1. **Measured**: wallclock of the AOT-compiled artifacts on the CPU
//!    PJRT backend.  Interpret-mode lowering is structurally faithful but
//!    CPU timing does *not* predict tensor-core behaviour, so this reading
//!    validates relative structure only (tiled vs naive, fwd vs fwdbwd).
//! 2. **Modeled**: an analytic INT8-vs-FP16 tensor-core cost model of each
//!    kernel's matmul volume, reproducing the paper's *claimed* speedup
//!    shape (Sage > FA2 > naive; paper reports up to 1.67× over FA2).

use anyhow::Result;

use crate::bench::{run as bench_run, BenchConfig, Table};
use crate::experiments::common::{emit, gaussian_qkvdo};
use crate::runtime::{AttentionBackend, Value};
use crate::tensor::linalg;

pub const SEQ_LENS: &[usize] = &[128, 256, 512];
pub const HEAD_DIMS: &[usize] = &[64, 128];
pub const IMPLS: &[&str] = &["sage", "fa2", "naive"];

/// Analytic cost model: relative time per (impl, mode) at (n, d).
///
/// MatMul volume per forward tile pass: QK^T and P̃V → 2·N²·d MACs; the
/// backward adds S-recompute, dV, dP, dQ, dK → 5·N²·d.  INT8 tensor-core
/// throughput is 2× FP16 on the paper's hardware (4090/B200); SageBwd runs
/// 6 of 7 MMs in INT8 (dP stays FP16, §3), the baselines run all in FP16.
/// Naive additionally materializes S/P in HBM — modeled as a 1.8×
/// memory-bound penalty (paper Figs 2–3 show ~2× vs FA2).
pub fn modeled_time(impl_name: &str, mode: &str, n: usize, d: usize) -> f64 {
    let fwd_mm = 2.0;
    let bwd_mm = 5.0;
    let vol = (n * n * d) as f64;
    let (mm, int8_mm): (f64, f64) = match (impl_name, mode) {
        ("sage", "fwd") => (fwd_mm, 2.0),          // both fwd MMs INT8
        ("sage", "fwdbwd") => (fwd_mm + bwd_mm, 6.0), // all but dP
        (_, "fwd") => (fwd_mm, 0.0),
        (_, "fwdbwd") => (fwd_mm + bwd_mm, 0.0),
        _ => unreachable!(),
    };
    let fp16_mm = mm - int8_mm;
    let tensor_core_time = fp16_mm * vol + int8_mm * vol / 2.0; // INT8 = 2× rate
    let io_penalty = if impl_name == "naive" { 1.8 } else { 1.0 };
    tensor_core_time * io_penalty
}

pub struct Row {
    pub d: usize,
    pub n: usize,
    pub impl_name: String,
    pub mode: String,
    pub measured_ms: f64,
    pub modeled_rel: f64,
    /// Worker threads the measurement ran with.  Pinned to 1 for the whole
    /// comparison: the figure contrasts *kernel structure* (tiled INT8 vs
    /// tiled FP vs dense), and letting the dense baselines auto-parallelize
    /// their big matmuls while the tile kernels run serial would skew the
    /// very ratios being reproduced.  Thread scaling is measured by the
    /// engine rows of `bench_attention` instead.
    pub threads: usize,
}

/// Measure every (impl, mode, d, n) artifact and emit both readings.
/// Pins `SAGEBWD_THREADS=1` for the duration (restored afterward, even on
/// panic) — see [`Row::threads`].
pub fn run(be: &mut dyn AttentionBackend, results_dir: &str, quick: bool) -> Result<Vec<Row>> {
    let _pin = linalg::pin_threads(1);
    run_serial(be, results_dir, quick)
}

fn run_serial(be: &mut dyn AttentionBackend, results_dir: &str, quick: bool) -> Result<Vec<Row>> {
    let cfg = if quick {
        BenchConfig { warmup_iters: 1, iters: 5, max_secs: 5.0 }
    } else {
        BenchConfig::default()
    };
    println!("Figures 2-3: kernel speed, SageBwd vs baselines");
    println!("(measured = CPU PJRT wallclock; modeled = INT8 tensor-core cost model — see module docs)\n");
    let mut rows = Vec::new();
    let threads = linalg::thread_count(); // pinned to 1 by `run`
    debug_assert_eq!(threads, 1);
    let mut table = Table::new(&[
        "headdim", "seqlen", "impl", "mode", "threads", "measured_ms", "modeled_speedup_vs_fa2",
    ]);
    for &d in HEAD_DIMS {
        for &n in SEQ_LENS {
            let fa2_model_fwd = modeled_time("fa2", "fwd", n, d);
            let fa2_model_bwd = modeled_time("fa2", "fwdbwd", n, d);
            for &impl_name in IMPLS {
                for mode in ["fwd", "fwdbwd"] {
                    let artifact = format!("bench_{impl_name}_{mode}_d{d}_n{n}");
                    let qkvdo = gaussian_qkvdo(n, d, 1.0, 1.0, 1.0, 1.0, 7);
                    let inputs: Vec<Value> = qkvdo[..if mode == "fwd" { 3 } else { 4 }]
                        .iter()
                        .map(|t| Value::F32(t.clone()))
                        .collect();
                    // Warm once (XLA compiles here; native is a no-op), so
                    // the timed loop sees the steady state for both backends.
                    be.execute(&artifact, &inputs)?;
                    let meas = bench_run(cfg, &artifact, || {
                        be.execute(&artifact, &inputs).expect("bench execution failed");
                    });
                    let fa2_base = if mode == "fwd" { fa2_model_fwd } else { fa2_model_bwd };
                    let modeled_rel = fa2_base / modeled_time(impl_name, mode, n, d);
                    let ms = meas.mean() * 1e3;
                    table.row(vec![
                        d.to_string(),
                        n.to_string(),
                        impl_name.into(),
                        mode.into(),
                        threads.to_string(),
                        format!("{ms:.3}"),
                        format!("{modeled_rel:.2}x"),
                    ]);
                    rows.push(Row {
                        d,
                        n,
                        impl_name: impl_name.into(),
                        mode: mode.into(),
                        measured_ms: ms,
                        modeled_rel,
                        threads,
                    });
                }
            }
        }
    }
    emit(&table, results_dir, "fig23_kernel_speed")?;
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_orders_impls_correctly() {
        // Sage faster than FA2 (INT8), FA2 faster than naive (IO).
        for &d in HEAD_DIMS {
            for &n in SEQ_LENS {
                for mode in ["fwd", "fwdbwd"] {
                    let sage = modeled_time("sage", mode, n, d);
                    let fa2 = modeled_time("fa2", mode, n, d);
                    let naive = modeled_time("naive", mode, n, d);
                    assert!(sage < fa2 && fa2 < naive, "{mode} d={d} n={n}");
                }
            }
        }
    }

    #[test]
    fn model_speedup_in_paper_range() {
        // Paper: up to 1.67× over FA2.  6-of-7 INT8 MMs at 2× rate gives
        // ≈1.75× fwdbwd upper bound; fwd-only gives 2×... within [1.3, 2.1].
        let s = modeled_time("fa2", "fwdbwd", 512, 128) / modeled_time("sage", "fwdbwd", 512, 128);
        assert!((1.3..2.1).contains(&s), "speedup {s}");
    }
}
