//! **Figure 4** — Q/K-smoothing ablation (paper §6): FPA vs SageBwd with
//! {no smoothing, K-smoothing, QK-smoothing}, at high and low TPS.
//! All runs QK-normed, hyperparameters identical to Figure 1.
//!
//! Expected shape: no-smoothing unstable or clearly worse; K-smoothing
//! reaches FPA-level; QK-smoothing no consistent gain over K alone.
//! Engine-agnostic via [`TrainerFactory`] (`--backend native|xla`).

use anyhow::Result;

use crate::bench::Table;
use crate::coordinator::TrainerFactory;
use crate::experiments::common::emit;
use crate::experiments::fig1_tps::{run_cell, Outcome};
use crate::telemetry::Log;

#[allow(clippy::too_many_arguments)]
pub fn run(
    factory: &TrainerFactory,
    results_dir: &str,
    token_budget: u64,
    tps_lo: u64,
    tps_hi: u64,
    peak_lr: f64,
    seed: u64,
) -> Result<Vec<Outcome>> {
    let log = Log::new(true);
    println!(
        "Figure 4 [{} engine]: smoothing ablation (none / K / QK), QK-norm on",
        factory.backend_name()
    );
    println!("(paper: K-smoothing required even at 260K TPS; Q-smoothing no consistent benefit)\n");
    let variants = [
        "fpa_qknorm",        // FPA reference
        "sage_qknorm_nosm",  // no smoothing
        "sage_qknorm",       // K-smoothing (paper default)
        "sage_qknorm_qksm",  // Q+K smoothing
    ];
    let mut outcomes = Vec::new();
    for &tps in &[tps_hi, tps_lo] {
        for variant in variants {
            log.info(&format!("--- fig4 cell: {variant} @ {tps} tok/step ---"));
            let o = run_cell(
                factory, results_dir, variant, tps, token_budget, peak_lr, seed, &log,
            )?;
            // Curve CSVs live in results/fig1/<variant>_tps<tps>/ already;
            // fig4 re-homes the comparison via its summary table only.
            outcomes.push(o);
        }
    }
    let mut table = Table::new(&[
        "smoothing",
        "variant",
        "tokens_per_step",
        "final_loss",
        "max_attn_logit",
        "status",
    ]);
    for o in &outcomes {
        let smoothing = match o.variant.as_str() {
            "sage_qknorm_nosm" => "none",
            "sage_qknorm" => "K",
            "sage_qknorm_qksm" => "QK",
            _ => "(fpa)",
        };
        table.row(vec![
            smoothing.into(),
            o.variant.clone(),
            o.tps.to_string(),
            o.final_loss.map(|l| format!("{l:.4}")).unwrap_or("-".into()),
            o.max_attn_logit
                .map(|m| format!("{m:.1}"))
                .unwrap_or("-".into()),
            if o.diverged { "DIVERGED".into() } else { "ok".into() },
        ]);
    }
    emit(&table, results_dir, "fig4_summary")?;
    Ok(outcomes)
}
