//! **Figure 4** — Q/K-smoothing ablation (paper §6): FPA vs SageBwd with
//! {no smoothing, K-smoothing, QK-smoothing}, at high and low TPS.
//! All runs QK-normed, hyperparameters identical to Figure 1.
//!
//! Expected shape: no-smoothing unstable or clearly worse; K-smoothing
//! reaches FPA-level; QK-smoothing no consistent gain over K alone.
//! Engine-agnostic via [`TrainerFactory`] (`--backend native|xla`).

use anyhow::Result;

use crate::bench::Table;
use crate::coordinator::TrainerFactory;
use crate::experiments::common::emit;
use crate::experiments::fig1_tps::{run_cell, CellCtx, Outcome};
use crate::registry::Registry;
use crate::telemetry::Log;

/// The Figure-4 arm list: (variant, tps) per cell, all QK-normed.
pub fn grid(tps_lo: u64, tps_hi: u64) -> Vec<(&'static str, u64)> {
    let variants = [
        "fpa_qknorm",        // FPA reference
        "sage_qknorm_nosm",  // no smoothing
        "sage_qknorm",       // K-smoothing (paper default)
        "sage_qknorm_qksm",  // Q+K smoothing
    ];
    let mut cells = Vec::new();
    for &tps in &[tps_hi, tps_lo] {
        for variant in variants {
            cells.push((variant, tps));
        }
    }
    cells
}

#[allow(clippy::too_many_arguments)]
pub fn run(
    factory: &TrainerFactory,
    results_dir: &str,
    token_budget: u64,
    tps_lo: u64,
    tps_hi: u64,
    peak_lr: f64,
    seed: u64,
    fresh: bool,
) -> Result<Vec<Outcome>> {
    let log = Log::new(true);
    println!(
        "Figure 4 [{} engine]: smoothing ablation (none / K / QK), QK-norm on",
        factory.backend_name()
    );
    println!("(paper: K-smoothing required even at 260K TPS; Q-smoothing no consistent benefit)\n");
    let registry = Registry::open(results_dir)?;
    let ctx = CellCtx {
        factory,
        registry: &registry,
        results_dir,
        experiment: "fig4",
        fresh,
        supervise: None,
    };
    let mut outcomes = Vec::new();
    for (variant, tps) in grid(tps_lo, tps_hi) {
        log.info(&format!("--- fig4 cell: {variant} @ {tps} tok/step ---"));
        // Curve views live in results/fig1/<variant>_tps<tps>/ (shared
        // with fig1, like the legacy layout); the two overlapping arms
        // (fpa_qknorm, sage_qknorm) are registry hits when fig1 already
        // ran them — identical config ⇒ identical run key.
        outcomes.push(run_cell(
            &ctx, variant, tps, token_budget, peak_lr, seed, &log,
        )?);
    }
    let mut table = Table::new(&[
        "smoothing",
        "variant",
        "tokens_per_step",
        "final_loss",
        "max_attn_logit",
        "status",
    ]);
    for o in &outcomes {
        let smoothing = match o.variant.as_str() {
            "sage_qknorm_nosm" => "none",
            "sage_qknorm" => "K",
            "sage_qknorm_qksm" => "QK",
            _ => "(fpa)",
        };
        table.row(vec![
            smoothing.into(),
            o.variant.clone(),
            o.tps.to_string(),
            o.final_loss.map(|l| format!("{l:.4}")).unwrap_or("-".into()),
            o.max_attn_logit
                .map(|m| format!("{m:.1}"))
                .unwrap_or("-".into()),
            if o.diverged { "DIVERGED".into() } else { "ok".into() },
        ]);
    }
    emit(&table, results_dir, "fig4_summary")?;
    Ok(outcomes)
}
