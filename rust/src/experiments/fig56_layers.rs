//! **Figures 5–6** — per-layer cosine similarity / relative ℓ2 error of
//! SageBwd vs exact attention across architectural settings (paper App. C).
//!
//! The paper extracts (Q, K, V, dO) per layer from a single
//! forward-backward of the trained 325M model.  Our substrate: per-layer
//! surrogates whose σ_QK grows with depth (the norm-growth phenomenon
//! §4.4 describes — deeper layers have grown γ and larger effective
//! activations; layer 11 of the paper's run is the most error-prone).
//! Settings compared: {K-smoothing (default), no smoothing, QK-smoothing},
//! each vs exact FPA, per layer.

use anyhow::Result;

use crate::bench::Table;
use crate::experiments::common::{emit, fmt4, gaussian_qkvdo, run_trace};
use crate::runtime::AttentionBackend;
use crate::util::stats::{cossim, rel_l2};

pub const NUM_LAYERS: usize = 12;
pub const SETTINGS: &[(&str, &str)] = &[
    ("ksm", "trace_pseudo"),
    ("nosm", "trace_pseudo_nosm"),
    ("qksm", "trace_pseudo_qksm"),
];

pub struct Row {
    pub layer: usize,
    pub setting: String,
    pub dq_cossim: f64,
    pub dq_rel: f64,
    pub dk_cossim: f64,
    pub dk_rel: f64,
}

/// Per-layer effective σ_QK: grows with depth then peaks near the last
/// layers (the paper's layer-11 hotspot in a 12-layer-probe reading).
fn layer_sigma(layer: usize) -> f32 {
    1.0 + 6.0 * (layer as f32 / (NUM_LAYERS - 1) as f32).powf(1.5)
}

pub fn run(be: &mut dyn AttentionBackend, results_dir: &str) -> Result<Vec<Row>> {
    println!("Figures 5-6: per-layer CosSim / Rel-L2 (dQ, dK) vs exact attention");
    println!("(paper: error grows with depth; non-smoothed/non-normed settings worst)\n");
    let mut rows = Vec::new();
    let mut table = Table::new(&[
        "layer", "sigma_qk", "setting", "dQ.cossim", "dQ.rel_l2", "dK.cossim", "dK.rel_l2",
    ]);
    for layer in 0..NUM_LAYERS {
        let sigma = layer_sigma(layer);
        let mut qkvdo = gaussian_qkvdo(128, 64, sigma, sigma, 1.0, 0.05, 300 + layer as u64);
        // Channel-wise K outliers — the phenomenon K-smoothing targets
        // (§3): a few channels carry a large shared offset that inflates
        // the per-block quantization step unless the mean is subtracted.
        {
            let mut rng = crate::util::rng::Pcg64::new(500 + layer as u64, 0);
            let d = 64;
            let biases: Vec<f32> = (0..d)
                .map(|_| {
                    if rng.uniform() < 0.1 {
                        4.0 * sigma * if rng.next_u32() & 1 == 1 { 1.0 } else { -1.0 }
                    } else {
                        0.0
                    }
                })
                .collect();
            let k = &mut qkvdo[1];
            for row in k.data.chunks_mut(d) {
                for (x, b) in row.iter_mut().zip(&biases) {
                    *x += b;
                }
            }
        }
        let fpa = run_trace(be, "trace_fpa", &qkvdo)?;
        for &(setting, artifact) in SETTINGS {
            let tr = run_trace(be, artifact, &qkvdo)?;
            let row = Row {
                layer,
                setting: setting.to_string(),
                dq_cossim: cossim(&tr.dq.data, &fpa.dq.data),
                dq_rel: rel_l2(&tr.dq.data, &fpa.dq.data),
                dk_cossim: cossim(&tr.dk.data, &fpa.dk.data),
                dk_rel: rel_l2(&tr.dk.data, &fpa.dk.data),
            };
            table.row(vec![
                layer.to_string(),
                format!("{sigma:.2}"),
                setting.into(),
                fmt4(row.dq_cossim),
                fmt4(row.dq_rel),
                fmt4(row.dk_cossim),
                fmt4(row.dk_rel),
            ]);
            rows.push(row);
        }
    }
    emit(&table, results_dir, "fig56_layers")?;
    Ok(rows)
}
