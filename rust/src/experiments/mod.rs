//! Experiment harnesses — one module per paper table/figure.
//!
//! | module          | regenerates                                        |
//! |-----------------|----------------------------------------------------|
//! | `table1_sigma`  | Table 1 (error vs σ_Q, σ_K)                        |
//! | `table2_trace`  | Table 2 (per-tensor pseudo-quantized error)         |
//! | `fig1_tps`      | Figure 1a/1b (pretraining loss at high/low TPS)     |
//! | `fig4_ablation` | Figure 4 (Q/K-smoothing ablation)                  |
//! | `fig23_speed`   | Figures 2–3 (kernel throughput)                     |
//! | `fig56_layers`  | Figures 5–6 (per-layer CosSim / Rel-ℓ2)             |
//! | `ds_rms`        | §4.2 magnitude probe (RMS(P), RMS(dP), RMS(dS))     |

pub mod common;
pub mod ds_rms;
pub mod fig1_tps;
pub mod fig23_speed;
pub mod fig4_ablation;
pub mod noise_probe;
pub mod fig56_layers;
pub mod table1_sigma;
pub mod table2_trace;
