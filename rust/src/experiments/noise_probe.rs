//! **Extension: §4.3 hypothesis probe** — does gradient noise mask INT8
//! quantization error?
//!
//! The paper *hypothesizes* that small-TPS runs tolerate quantization
//! because per-step gradient noise dominates the systematic quantization
//! bias, and that large-TPS runs expose it.  This harness tests the
//! mechanism directly: at high TPS (low natural noise), inject synthetic
//! relative Gaussian noise into the averaged gradient of the SageBwd run
//! and compare final losses:
//!
//!   fpa (clean)  vs  sage (clean)  vs  sage (+noise σ ∈ {0.05, 0.2})
//!
//! If the hypothesis holds, moderate injected noise should *not hurt* (and
//! may close part of) the Sage–FPA gap, mirroring what lowering TPS does.
//! Engine-agnostic via [`TrainerFactory`] (`--backend native|xla`).

use std::path::PathBuf;

use anyhow::Result;

use crate::bench::Table;
use crate::config::TrainConfig;
use crate::coordinator::{RunStatus, TrainerFactory};
use crate::experiments::common::emit;
use crate::registry::{Registry, RunState};
use crate::telemetry::Log;
use crate::util::json::{schema, Json};

pub struct Outcome {
    pub label: String,
    pub final_loss: Option<f64>,
    pub diverged: bool,
}

pub fn run(
    factory: &TrainerFactory,
    results_dir: &str,
    token_budget: u64,
    tps: u64,
    seed: u64,
    fresh: bool,
) -> Result<Vec<Outcome>> {
    let log = Log::new(true);
    println!(
        "Extension probe [{} engine]: synthetic gradient noise at high TPS (§4.3 mechanism)",
        factory.backend_name()
    );
    println!("(hypothesis: noise masks quantization bias — lowering TPS in disguise)\n");
    let registry = Registry::open(results_dir)?;
    let steps = (token_budget / tps).max(2);
    let cells: &[(&str, f64)] = &[
        ("fpa_qknorm", 0.0),
        ("sage_qknorm", 0.0),
        ("sage_qknorm", 0.05),
        ("sage_qknorm", 0.2),
    ];
    let mut outcomes = Vec::new();
    for &(variant, sigma) in cells {
        let label = if sigma == 0.0 {
            variant.to_string()
        } else {
            format!("{variant}+noise{sigma}")
        };
        log.info(&format!("--- noise-probe cell: {label} @ {tps} tok/step ---"));
        let cfg = TrainConfig {
            variant: variant.to_string(),
            steps,
            tokens_per_step: tps,
            warmup_steps: (steps / 20).max(1),
            peak_lr: 3e-3,
            min_lr_frac: 0.1,
            seed,
            checkpoint_every: 0,
            log_every: (steps / 10).max(1),
            clip_norm: 0.0,
            grad_noise_sigma: sigma,
            ..TrainConfig::default()
        };
        let mut config = cfg.to_json();
        config.set("backend", Json::from(factory.backend_name()));
        let key = Registry::run_key(&config, factory.backend_name());
        if !fresh {
            if let Some(m) = registry.load_run(&key)? {
                if m.status.is_finished() {
                    log.info(&format!(
                        "registry hit [{}]: {label} already {} — skipping",
                        &key[..16],
                        m.status.as_str()
                    ));
                    outcomes.push(Outcome {
                        label,
                        final_loss: schema::nullable_f64_field(&m.summary, "final_loss")?,
                        diverged: m.status == RunState::Diverged,
                    });
                    continue;
                }
            }
        }
        let mut run = registry.begin_run_keyed("noise_probe", &label, config, key)?;
        let mut trainer = factory.trainer(cfg)?;
        let mut batches = trainer.make_batcher(512, 4)?;
        let report = match trainer.run(&mut batches, &log) {
            Ok(r) => r,
            Err(e) => {
                let _ = run.finish(RunState::Failed);
                return Err(e);
            }
        };
        let view_dir = PathBuf::from(results_dir).join("noise_probe").join(&label);
        run.record_metrics(&trainer.metrics, &view_dir)?;
        let diverged = matches!(report.status, RunStatus::Diverged { .. });
        run.set_summary(Json::from_pairs(vec![
            (
                "final_loss",
                report.final_loss.map(Json::from).unwrap_or(Json::Null),
            ),
            ("grad_noise_sigma", Json::from(sigma)),
        ]));
        run.finish(if diverged {
            RunState::Diverged
        } else {
            RunState::Complete
        })?;
        outcomes.push(Outcome {
            label,
            final_loss: report.final_loss,
            diverged,
        });
    }

    let mut table = Table::new(&["cell", "tokens_per_step", "final_loss", "status"]);
    for o in &outcomes {
        table.row(vec![
            o.label.clone(),
            tps.to_string(),
            o.final_loss.map(|l| format!("{l:.4}")).unwrap_or("-".into()),
            if o.diverged { "DIVERGED".into() } else { "ok".into() },
        ]);
    }
    emit(&table, results_dir, "noise_probe_summary")?;
    Ok(outcomes)
}
