//! **Extension: §4.3 hypothesis probe** — does gradient noise mask INT8
//! quantization error?
//!
//! The paper *hypothesizes* that small-TPS runs tolerate quantization
//! because per-step gradient noise dominates the systematic quantization
//! bias, and that large-TPS runs expose it.  This harness tests the
//! mechanism directly: at high TPS (low natural noise), inject synthetic
//! relative Gaussian noise into the averaged gradient of the SageBwd run
//! and compare final losses:
//!
//!   fpa (clean)  vs  sage (clean)  vs  sage (+noise σ ∈ {0.05, 0.2})
//!
//! If the hypothesis holds, moderate injected noise should *not hurt* (and
//! may close part of) the Sage–FPA gap, mirroring what lowering TPS does.
//! Engine-agnostic via [`TrainerFactory`] (`--backend native|xla`).

use anyhow::Result;

use crate::bench::Table;
use crate::config::TrainConfig;
use crate::coordinator::{RunStatus, TrainerFactory};
use crate::experiments::common::emit;
use crate::telemetry::{run_dir, Log};

pub struct Outcome {
    pub label: String,
    pub final_loss: Option<f64>,
    pub diverged: bool,
}

pub fn run(
    factory: &TrainerFactory,
    results_dir: &str,
    token_budget: u64,
    tps: u64,
    seed: u64,
) -> Result<Vec<Outcome>> {
    let log = Log::new(true);
    println!(
        "Extension probe [{} engine]: synthetic gradient noise at high TPS (§4.3 mechanism)",
        factory.backend_name()
    );
    println!("(hypothesis: noise masks quantization bias — lowering TPS in disguise)\n");
    let steps = (token_budget / tps).max(2);
    let cells: &[(&str, f64)] = &[
        ("fpa_qknorm", 0.0),
        ("sage_qknorm", 0.0),
        ("sage_qknorm", 0.05),
        ("sage_qknorm", 0.2),
    ];
    let mut outcomes = Vec::new();
    for &(variant, sigma) in cells {
        let label = if sigma == 0.0 {
            variant.to_string()
        } else {
            format!("{variant}+noise{sigma}")
        };
        log.info(&format!("--- noise-probe cell: {label} @ {tps} tok/step ---"));
        let cfg = TrainConfig {
            variant: variant.to_string(),
            steps,
            tokens_per_step: tps,
            warmup_steps: (steps / 20).max(1),
            peak_lr: 3e-3,
            min_lr_frac: 0.1,
            seed,
            checkpoint_every: 0,
            log_every: (steps / 10).max(1),
            clip_norm: 0.0,
            grad_noise_sigma: sigma,
            ..TrainConfig::default()
        };
        let mut trainer = factory.trainer(cfg)?;
        let mut batches = trainer.make_batcher(512, 4)?;
        let report = trainer.run(&mut batches, &log)?;
        let dir = run_dir(results_dir, "noise_probe")?;
        trainer.metrics.flush_csv(&dir.join(&label))?;
        outcomes.push(Outcome {
            label,
            final_loss: report.final_loss,
            diverged: matches!(report.status, RunStatus::Diverged { .. }),
        });
    }

    let mut table = Table::new(&["cell", "tokens_per_step", "final_loss", "status"]);
    for o in &outcomes {
        table.row(vec![
            o.label.clone(),
            tps.to_string(),
            o.final_loss.map(|l| format!("{l:.4}")).unwrap_or("-".into()),
            if o.diverged { "DIVERGED".into() } else { "ok".into() },
        ]);
    }
    emit(&table, results_dir, "noise_probe_summary")?;
    Ok(outcomes)
}
