//! **Table 1** — SageBwd vs FPA accuracy across Gaussian QKV with varying
//! σ_Q, σ_K (σ_V = σ_dO = 1), paper §4.4.
//!
//! Expected shape: CosSim degrades / Rel-ℓ2 grows sharply with σ, with
//! dQ/dK degrading far faster than O/dV (the dS bottleneck).

use anyhow::Result;

use crate::bench::Table;
use crate::experiments::common::{emit, fmt4, gaussian_qkvdo, run_trace};
use crate::runtime::AttentionBackend;
use crate::util::stats::{cossim, rel_l2};

pub const SIGMAS: &[f32] = &[1.0, 3.0, 5.0, 8.0, 10.0];

pub struct Row {
    pub sigma: f32,
    /// (cossim, rel_l2) for O, dQ, dK, dV.
    pub o: (f64, f64),
    pub dq: (f64, f64),
    pub dk: (f64, f64),
    pub dv: (f64, f64),
}

/// Compute one sweep row at a given σ (averaged over `reps` seeds).
pub fn row(be: &mut dyn AttentionBackend, sigma: f32, n: usize, reps: u64) -> Result<Row> {
    let mut acc = [[0f64; 2]; 4];
    for rep in 0..reps {
        let qkvdo = gaussian_qkvdo(n, 64, sigma, sigma, 1.0, 1.0, 1000 + rep);
        let sage = run_trace(be, "trace_sage", &qkvdo)?;
        let fpa = run_trace(be, "trace_fpa", &qkvdo)?;
        for (slot, (s, f)) in [
            (&sage.o, &fpa.o),
            (&sage.dq, &fpa.dq),
            (&sage.dk, &fpa.dk),
            (&sage.dv, &fpa.dv),
        ]
        .iter()
        .enumerate()
        .map(|(i, (s, f))| (i, (s, f)))
        {
            acc[slot][0] += cossim(&s.data, &f.data);
            acc[slot][1] += rel_l2(&s.data, &f.data);
        }
    }
    let r = reps as f64;
    let pick = |i: usize| (acc[i][0] / r, acc[i][1] / r);
    Ok(Row {
        sigma,
        o: pick(0),
        dq: pick(1),
        dk: pick(2),
        dv: pick(3),
    })
}

/// Run the full Table 1 sweep and emit it.
pub fn run(be: &mut dyn AttentionBackend, results_dir: &str, reps: u64) -> Result<Vec<Row>> {
    let mut table = Table::new(&[
        "sigma_qk", "O.cossim", "O.rel_l2", "dQ.cossim", "dQ.rel_l2",
        "dK.cossim", "dK.rel_l2", "dV.cossim", "dV.rel_l2",
    ]);
    let mut rows = Vec::new();
    println!("Table 1: Sage vs FPA across random QKV with varying sigma_Q/sigma_K");
    println!("(paper: sigma=1 → dQ cossim 0.9998; sigma=10 → dQ cossim 0.7823)\n");
    for &sigma in SIGMAS {
        // Inputs are scaled *before* the 1/√d attention normalization, as
        // in the paper's synthetic probe.
        let r = row(be, sigma, 128, reps)?;
        table.row(vec![
            format!("{sigma}"),
            fmt4(r.o.0), fmt4(r.o.1),
            fmt4(r.dq.0), fmt4(r.dq.1),
            fmt4(r.dk.0), fmt4(r.dk.1),
            fmt4(r.dv.0), fmt4(r.dv.1),
        ]);
        rows.push(r);
    }
    emit(&table, results_dir, "table1_sigma")?;
    Ok(rows)
}
