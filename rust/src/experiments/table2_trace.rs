//! **Table 2** — cosine similarity and relative ℓ2 error for every
//! intermediate tensor of the pseudo-quantized FPA trace (paper §5.4).
//!
//! Methodology (matching the paper): apply the SageBwd INT8
//! quantize-dequantize scheme before each quantized matmul inside a plain
//! attention implementation; compare δ, P, dP, dS, O, dQ, dK, dV against
//! exact FPA.  dP must come out exactly 0 error (upstream dO is treated
//! error-free and the dP matmul is kept in high precision).
//!
//! The paper extracts Q/K/V/dO from layer 11 of a trained 2.1M-TPS
//! checkpoint; we use Gaussian surrogates matched to trained-regime scales
//! (σ_QK elevated, dO small) — DESIGN.md §6 records the substitution.

use anyhow::Result;

use crate::bench::Table;
use crate::experiments::common::{emit, fmt4, gaussian_qkvdo, run_trace, Trace};
use crate::runtime::AttentionBackend;
use crate::tensor::Tensor;
use crate::util::stats::{cossim, rel_l2};

pub const TENSORS: &[&str] = &["delta", "P", "dP", "dS", "O", "dQ", "dK", "dV"];

pub struct Row {
    pub name: &'static str,
    pub cossim: f64,
    pub rel_l2: f64,
}

fn pairs<'t>(sage: &'t Trace, fpa: &'t Trace) -> Vec<(&'static str, &'t Tensor, &'t Tensor)> {
    vec![
        ("delta", &sage.delta, &fpa.delta),
        ("P", &sage.p, &fpa.p),
        ("dP", &sage.dp, &fpa.dp),
        ("dS", &sage.ds, &fpa.ds),
        ("O", &sage.o, &fpa.o),
        ("dQ", &sage.dq, &fpa.dq),
        ("dK", &sage.dk, &fpa.dk),
        ("dV", &sage.dv, &fpa.dv),
    ]
}

/// Run Table 2 with a given pseudo-quant trace artifact.
pub fn run_with(
    be: &mut dyn AttentionBackend,
    results_dir: &str,
    artifact: &str,
    csv_name: &str,
) -> Result<Vec<Row>> {
    // Trained-regime surrogate: grown Q/K norms (σ≈4 — between Table 1's
    // σ=3 and σ=5 rows, where the dS spike is clearly visible) and small
    // upstream gradients, as measured on real checkpoints (§4.2).
    let qkvdo = gaussian_qkvdo(128, 64, 4.0, 4.0, 1.0, 0.02, 77);
    let pseudo = run_trace(be, artifact, &qkvdo)?;
    let fpa = run_trace(be, "trace_fpa", &qkvdo)?;

    let mut table = Table::new(&["metric", "delta", "P", "dP", "dS", "O", "dQ", "dK", "dV"]);
    let ps = pairs(&pseudo, &fpa);
    let mut rows = Vec::new();
    let mut cos_row = vec!["CosSim".to_string()];
    let mut rel_row = vec!["Rel-L2".to_string()];
    for (name, s, f) in &ps {
        let c = cossim(&s.data, &f.data);
        let r = rel_l2(&s.data, &f.data);
        cos_row.push(fmt4(c));
        rel_row.push(fmt4(r));
        rows.push(Row {
            name,
            cossim: c,
            rel_l2: r,
        });
    }
    table.row(cos_row);
    table.row(rel_row);
    println!("Table 2 ({artifact}): per-tensor error of pseudo-quantized FPA vs exact FPA");
    println!("(paper: Rel-L2 spikes at dS≈0.20 → dQ≈0.26/dK≈0.31; dP exactly 0; O/dV small)\n");
    emit(&table, results_dir, csv_name)?;
    Ok(rows)
}

pub fn run(be: &mut dyn AttentionBackend, results_dir: &str) -> Result<Vec<Row>> {
    let rows = run_with(be, results_dir, "trace_pseudo", "table2_trace")?;
    // Extension (§7 future work): FP-dS variant.  Expected finding
    // (EXPERIMENTS.md §Extensions): barely better — dS's error is
    // inherited from the quantized forward, not from ψ(dS) itself.
    let ext = run_with(be, results_dir, "trace_pseudo_dsfp", "table2_trace_dsfp")?;
    let dq_int8 = rows.iter().find(|r| r.name == "dQ").map(|r| r.rel_l2).unwrap_or(0.0);
    let dq_dsfp = ext.iter().find(|r| r.name == "dQ").map(|r| r.rel_l2).unwrap_or(0.0);
    println!(
        "FP-dS extension: dQ Rel-L2 {dq_int8:.4} (INT8 dS) → {dq_dsfp:.4} (FP dS) — \
         {:.0}% of the error is inherited from forward quantization",
        100.0 * dq_dsfp / dq_int8.max(1e-12)
    );
    Ok(rows)
}
