//! Native CPU SageBwd attention: tiled FlashAttention-2-style forward and
//! backward passes with per-block INT8 quantization (Algorithms 1 & 2),
//! plus the exact FPA oracle and the §5.4 pseudo-quantized trace.
//!
//! This is the Rust twin of `python/compile/kernels/ref.py` — the
//! block-faithful reference the Pallas kernels are tested against — so the
//! same golden vectors validate both sides (rust/tests/kernel_golden.rs).
//!
//! Paper structure mirrored here:
//!
//! * forward (Alg 1): per-block ψ(Q), ψ(K), ψ(V); online softmax over KV
//!   tiles; per-*token* ψ(P̃) before the P̃·V matmul.
//! * backward (Alg 2): recompute S from the quantized Q/K tiles, per-block
//!   ψ(P) and ψ(dO) for dV, **dP = dO·Vᵀ kept in full precision** (the
//!   paper's insight (ii): dS = P∘(dP − δ) is the dominant error source,
//!   so its ingredients stay exact), per-block ψ(dS) for dQ/dK (or the §7
//!   FP-dS variant when `quant_ds` is off).
//! * K-smoothing (§3): channel-mean subtraction folded into the softmax —
//!   row-invariant in the forward, gradient-free in the backward because
//!   every dS row sums to zero.
//!
//! Execution substrate (DESIGN.md §11): every matmul runs on the blocked
//! compute engine in [`crate::tensor::linalg`]; quantized tiles live in
//! one flat `i8` buffer per operand ([`QuantTiles`] — no jagged
//! `Vec<Vec<i8>>`); all per-tile scratch comes from a reusable
//! [`Workspace`], so the tile loops run allocation-free after warmup.
//! The `*_ws` entry points let long-lived callers (the native backend)
//! reuse one arena across calls; results are bitwise-independent of
//! workspace state.

use std::borrow::Cow;

use anyhow::{bail, Result};

use crate::kernels::quant;
use crate::kernels::smoothing;
use crate::tensor::{linalg, Tensor, Workspace};

/// Kernel configuration (mirrors `python/compile/configs.TraceConfig`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AttnConfig {
    pub block_q: usize,
    pub block_kv: usize,
    pub causal: bool,
    pub k_smoothing: bool,
    pub q_smoothing: bool,
    /// INT8-quantize dS before the dQ/dK matmuls (paper default).  `false`
    /// is the §7 future-work FP-dS variant (4-of-7 INT8 MMs).
    pub quant_ds: bool,
}

impl Default for AttnConfig {
    fn default() -> AttnConfig {
        AttnConfig {
            block_q: 32,
            block_kv: 32,
            causal: false,
            k_smoothing: true,
            q_smoothing: false,
            quant_ds: true,
        }
    }
}

/// Everything the paper's error analysis inspects (§5.4, Table 2) —
/// index-aligned with `ref.AttnIntermediates`.
#[derive(Debug, Clone)]
pub struct AttnTrace {
    pub o: Tensor,      // (N, D) attention output
    pub s: Tensor,      // (N, N) logits Q·Kᵀ/√d
    pub p: Tensor,      // (N, N) softmax(S)
    pub lse: Vec<f32>,  // (N,)   row logsumexp of S
    pub delta: Tensor,  // (N,)   rowsum(dO ∘ O)
    pub dp: Tensor,     // (N, N) dO·Vᵀ
    pub ds: Tensor,     // (N, N) P ∘ (dP − δ·1ᵀ)
    pub dq: Tensor,     // (N, D)
    pub dk: Tensor,     // (N, D)
    pub dv: Tensor,     // (N, D)
}

fn check_inputs(q: &Tensor, k: &Tensor, v: &Tensor) -> Result<(usize, usize)> {
    let (n, d) = q.dims2()?;
    if k.shape != q.shape || v.shape != q.shape {
        bail!(
            "attention wants equal (N, D) shapes, got q={:?} k={:?} v={:?}",
            q.shape,
            k.shape,
            v.shape
        );
    }
    Ok((n, d))
}

fn rowsum_mul(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let (n, d) = a.dims2()?;
    let mut out = vec![0f32; n];
    for (o, (ra, rb)) in out
        .iter_mut()
        .zip(a.data.chunks_exact(d).zip(b.data.chunks_exact(d)))
    {
        for (&x, &y) in ra.iter().zip(rb) {
            *o += x * y;
        }
    }
    Tensor::from_vec(&[n], out)
}

/// Divergence-telemetry statistic: `max |q_i·k_j| / √d` over unmasked
/// `(i, j)` pairs, computed in full precision regardless of which kernel
/// runs the attention itself (DESIGN.md §10 divergence contract).
///
/// NaN-propagating: a single non-finite logit makes the result NaN (∞
/// simply dominates the max) so it cannot evade the trainer's
/// `max_attn_logit` ceiling — a plain `f32::max` fold would silently
/// discard NaN and report a healthy-looking maximum.
pub fn max_abs_logit(q: &Tensor, k: &Tensor, causal: bool) -> Result<f32> {
    let (n, d) = check_inputs(q, k, k)?;
    let inv_sqrt_d = 1.0 / (d as f32).sqrt();
    let mut max = 0f32;
    for i in 0..n {
        let qi = &q.data[i * d..(i + 1) * d];
        let cols = if causal { i + 1 } else { n };
        for j in 0..cols {
            let kj = &k.data[j * d..(j + 1) * d];
            let mut acc = 0f32;
            for (&a, &b) in qi.iter().zip(kj) {
                acc += a * b;
            }
            let a = (acc * inv_sqrt_d).abs();
            if a.is_nan() {
                return Ok(f32::NAN);
            }
            if a > max {
                max = a;
            }
        }
    }
    Ok(max)
}

// ---------------------------------------------------------------------------
// Exact full-precision attention (FPA) — the ground-truth oracle
// ---------------------------------------------------------------------------

fn masked_logits(q: &Tensor, k: &Tensor, causal: bool) -> Result<Tensor> {
    let (n, d) = check_inputs(q, k, k)?;
    let inv_sqrt_d = 1.0 / (d as f32).sqrt();
    let mut s = q.matmul_nt(k)?;
    s.scale(inv_sqrt_d);
    if causal {
        for i in 0..n {
            for j in i + 1..n {
                s.data[i * n + j] = f32::NEG_INFINITY;
            }
        }
    }
    Ok(s)
}

/// Exact attention forward.  Returns `(O, S, P, lse)`.
pub fn fpa_fwd(q: &Tensor, k: &Tensor, v: &Tensor, causal: bool) -> Result<(Tensor, Tensor, Tensor, Vec<f32>)> {
    check_inputs(q, k, v)?;
    let s = masked_logits(q, k, causal)?;
    let (p, lse) = s.softmax_rows()?;
    let o = p.matmul(v)?;
    Ok((o, s, p, lse))
}

/// Exact attention forward+backward with every intermediate (paper §3):
///
///     dV = Pᵀ·dO,  dP = dO·Vᵀ,  δ = rowsum(dO ∘ O),
///     dS = P ∘ (dP − δ·1ᵀ),  dQ = dS·K/√d,  dK = dSᵀ·Q/√d.
pub fn fpa_bwd(q: &Tensor, k: &Tensor, v: &Tensor, do_: &Tensor, causal: bool) -> Result<AttnTrace> {
    let (n, d) = check_inputs(q, k, v)?;
    if do_.shape != q.shape {
        bail!("dO shape {:?} != {:?}", do_.shape, q.shape);
    }
    let inv_sqrt_d = 1.0 / (d as f32).sqrt();
    let (o, s, p, lse) = fpa_fwd(q, k, v, causal)?;
    let dv = p.matmul_tn(do_)?;
    let dp = do_.matmul_nt(v)?;
    let delta = rowsum_mul(do_, &o)?;
    let mut ds = Tensor::zeros(&[n, n]);
    for i in 0..n {
        let di = delta.data[i];
        for j in 0..n {
            ds.data[i * n + j] = p.data[i * n + j] * (dp.data[i * n + j] - di);
        }
    }
    let mut dq = ds.matmul(k)?;
    dq.scale(inv_sqrt_d);
    let mut dk = ds.matmul_tn(q)?;
    dk.scale(inv_sqrt_d);
    Ok(AttnTrace { o, s, p, lse, delta, dp, ds, dq, dk, dv })
}

// ---------------------------------------------------------------------------
// Tiled FP forward (the FA2 baseline of Figures 2–3)
// ---------------------------------------------------------------------------

/// FlashAttention-2-style tiled forward in full precision — the `fa2`
/// baseline.  Bit-equal math to [`fpa_fwd`] up to summation order.
pub fn fa2_fwd(q: &Tensor, k: &Tensor, v: &Tensor, cfg: &AttnConfig) -> Result<(Tensor, Vec<f32>)> {
    fa2_fwd_ws(q, k, v, cfg, &mut Workspace::new())
}

/// [`fa2_fwd`] with a caller-owned scratch arena (allocation-free tile
/// loop once the pools are warm).
pub fn fa2_fwd_ws(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    cfg: &AttnConfig,
    ws: &mut Workspace,
) -> Result<(Tensor, Vec<f32>)> {
    let (n, d) = check_inputs(q, k, v)?;
    let (bq, bkv) = (cfg.block_q, cfg.block_kv);
    check_blocks(n, bq, bkv)?;
    let inv_sqrt_d = 1.0 / (d as f32).sqrt();
    let (tm, tn) = (n / bq, n / bkv);

    let mut o = vec![0f32; n * d];
    let mut lse = vec![0f32; n];
    let mut acc = ws.take_f32(bq * d);
    let mut m_i = ws.take_f32(bq);
    let mut l_i = ws.take_f32(bq);
    let mut s_ij = ws.take_f32(bq * bkv);
    let mut p_ij = ws.take_f32(bq * bkv);
    let mut corr = ws.take_f32(bq);
    let mut pv = ws.take_f32(bq * d);
    // Pre-pack every K tile transposed once — not per (i, j) pair.
    let mut k_t = ws.take_f32(n * d);
    for j in 0..tn {
        linalg::pack_transpose_f32(
            &k.data[j * bkv * d..(j + 1) * bkv * d],
            bkv,
            d,
            &mut k_t[j * bkv * d..(j + 1) * bkv * d],
        );
    }
    for i in 0..tm {
        let qi = &q.data[i * bq * d..(i + 1) * bq * d];
        acc.fill(0.0);
        m_i.fill(f32::NEG_INFINITY);
        l_i.fill(0.0);
        for j in 0..tn {
            if cfg.causal && j * bkv > (i + 1) * bq - 1 {
                continue;
            }
            let ktj = &k_t[j * bkv * d..(j + 1) * bkv * d];
            let vj = &v.data[j * bkv * d..(j + 1) * bkv * d];
            linalg::gemm_nn(qi, ktj, bq, d, bkv, &mut s_ij);
            for sv in s_ij.iter_mut() {
                *sv *= inv_sqrt_d;
            }
            apply_causal_tile(&mut s_ij, cfg.causal, i * bq, j * bkv, bq, bkv);
            online_softmax_tile(
                &mut acc, &mut m_i, &mut l_i, &s_ij, bq, bkv, d,
                &mut p_ij, &mut corr, &mut pv,
                |p, pv_out| {
                    // Full-precision P̃·V (same per-element accumulation
                    // order as the pre-engine scalar loop).
                    linalg::gemm_nn(p, vj, bq, bkv, d, pv_out);
                },
            );
        }
        finish_block(&mut o, &mut lse, i * bq, &acc, &m_i, &l_i, d);
    }
    ws.give_f32(k_t);
    ws.give_f32(pv);
    ws.give_f32(corr);
    ws.give_f32(p_ij);
    ws.give_f32(s_ij);
    ws.give_f32(l_i);
    ws.give_f32(m_i);
    ws.give_f32(acc);
    Ok((Tensor::from_vec(&[n, d], o)?, lse))
}

fn check_blocks(n: usize, bq: usize, bkv: usize) -> Result<()> {
    if bq == 0 || bkv == 0 || n % bq != 0 || n % bkv != 0 {
        bail!("N={n} not divisible by block_q={bq} / block_kv={bkv}");
    }
    Ok(())
}

/// Add the Q-smoothing rank-1 logit bias (`μ_Q·K_smᵀ / √d`) for the KV
/// tile starting at `col0`.  No-op when the bias row is empty — i.e.
/// Q-smoothing is off, which is the default and most registry variants.
fn add_bias_row(s_ij: &mut [f32], bias_row: &[f32], col0: usize, bkv: usize, inv_sqrt_d: f32) {
    if bias_row.is_empty() {
        return;
    }
    let brow = &bias_row[col0..col0 + bkv];
    for srow in s_ij.chunks_exact_mut(bkv) {
        for (sv, &b) in srow.iter_mut().zip(brow) {
            *sv += b * inv_sqrt_d;
        }
    }
}

fn apply_causal_tile(s: &mut [f32], causal: bool, row0: usize, col0: usize, bq: usize, bkv: usize) {
    if !causal {
        return;
    }
    for r in 0..bq {
        for c in 0..bkv {
            if row0 + r < col0 + c {
                s[r * bkv + c] = f32::NEG_INFINITY;
            }
        }
    }
}

/// One online-softmax update over a `(bq, bkv)` logit tile.  `pv_fn` maps
/// the un-normalized tile P̃ to the `(bq, d)` partial output written into
/// `pv` — full precision for FA2, INT8 for SageBwd.  `p_ij`, `corr` and
/// `pv` are caller scratch (overwritten here).
#[allow(clippy::too_many_arguments)]
fn online_softmax_tile(
    acc: &mut [f32],
    m_i: &mut [f32],
    l_i: &mut [f32],
    s_ij: &[f32],
    bq: usize,
    bkv: usize,
    d: usize,
    p_ij: &mut [f32],
    corr: &mut [f32],
    pv: &mut [f32],
    pv_fn: impl FnOnce(&[f32], &mut [f32]),
) {
    p_ij.fill(0.0);
    for r in 0..bq {
        let row = &s_ij[r * bkv..(r + 1) * bkv];
        let m_new = row.iter().fold(m_i[r], |a, &b| a.max(b));
        if m_new == f32::NEG_INFINITY {
            // Row fully masked so far: nothing to accumulate.
            corr[r] = 0.0;
            continue;
        }
        let prow = &mut p_ij[r * bkv..(r + 1) * bkv];
        let mut sum = 0f32;
        for (pv, &sv) in prow.iter_mut().zip(row) {
            let e = if sv == f32::NEG_INFINITY { 0.0 } else { (sv - m_new).exp() };
            *pv = e;
            sum += e;
        }
        corr[r] = if m_i[r] == f32::NEG_INFINITY { 0.0 } else { (m_i[r] - m_new).exp() };
        l_i[r] = l_i[r] * corr[r] + sum;
        m_i[r] = m_new;
    }
    pv_fn(&*p_ij, &mut *pv);
    for r in 0..bq {
        let arow = &mut acc[r * d..(r + 1) * d];
        let prow = &pv[r * d..(r + 1) * d];
        for (a, &x) in arow.iter_mut().zip(prow) {
            *a = *a * corr[r] + x;
        }
    }
}

fn finish_block(o: &mut [f32], lse: &mut [f32], row0: usize, acc: &[f32], m_i: &[f32], l_i: &[f32], d: usize) {
    for (r, (&m, &l)) in m_i.iter().zip(l_i).enumerate() {
        let orow = &mut o[(row0 + r) * d..(row0 + r + 1) * d];
        if l > 0.0 {
            let inv = 1.0 / l;
            for (ov, &a) in orow.iter_mut().zip(&acc[r * d..(r + 1) * d]) {
                *ov = a * inv;
            }
            lse[row0 + r] = m + l.ln();
        } else {
            lse[row0 + r] = f32::NEG_INFINITY;
        }
    }
}

// ---------------------------------------------------------------------------
// SageBwd: Algorithms 1 & 2 (block-faithful, INT8)
// ---------------------------------------------------------------------------

/// Per-row-block INT8 tiles of an `(n, d)` matrix: one **flat** `i8`
/// buffer (tile `b` covers rows `[b·block, (b+1)·block)`, so the flat
/// layout is simply the quantized matrix row-major and tile offsets are
/// `b · block · d`) plus one ψ scale per tile.  Replaces the jagged
/// `Vec<Vec<i8>>` layout so the blocked integer GEMMs consume tiles as
/// contiguous slices with no per-tile allocation or pointer chasing.
pub struct QuantTiles {
    data: Vec<i8>,
    scales: Vec<f32>,
    rows_per_tile: usize,
    width: usize,
}

impl QuantTiles {
    /// Per-block ψ of all `n / block` row tiles (requires `block | n`).
    fn quantize(x: &Tensor, block: usize) -> Result<QuantTiles> {
        let (n, d) = x.dims2()?;
        if block == 0 || n % block != 0 {
            bail!("QuantTiles: N={n} not divisible by block={block}");
        }
        let tiles = n / block;
        let mut data = vec![0i8; n * d];
        let mut scales = Vec::with_capacity(tiles);
        for b in 0..tiles {
            let lo = b * block * d;
            let hi = (b + 1) * block * d;
            scales.push(quant::quantize_per_block_into(&x.data[lo..hi], &mut data[lo..hi]));
        }
        Ok(QuantTiles { data, scales, rows_per_tile: block, width: d })
    }

    #[inline]
    fn tile(&self, b: usize) -> &[i8] {
        let len = self.rows_per_tile * self.width;
        &self.data[b * len..(b + 1) * len]
    }

    #[inline]
    fn scale(&self, b: usize) -> f32 {
        self.scales[b]
    }

    fn tiles(&self) -> usize {
        self.scales.len()
    }

    /// All tiles transposed into one flat buffer: tile `b` becomes a
    /// `(width, rows_per_tile)` row-major panel at offset
    /// `b · rows_per_tile · width` — the packed operand for the
    /// `ψ(Q)·ψ(K)ᵀ` GEMMs, built once instead of per (i, j) pair.
    fn transposed(&self) -> Vec<i8> {
        let (r, w) = (self.rows_per_tile, self.width);
        let mut out = vec![0i8; self.data.len()];
        for b in 0..self.tiles() {
            linalg::pack_transpose_i8(self.tile(b), r, w, &mut out[b * r * w..(b + 1) * r * w]);
        }
        out
    }
}

/// Quantized residuals the backward pass reuses (Alg 2 line 1).
pub struct SageResiduals {
    q_q: QuantTiles,
    k_q: QuantTiles,
    /// K tiles pre-transposed (`(d, bkv)` panels) for the S̃ GEMMs.
    k_t: Vec<i8>,
    v_q: QuantTiles,
    mu_q: Option<Vec<f32>>,
    /// Rank-1 logit bias row (μ_Q·K_smᵀ, length N) — empty without
    /// Q-smoothing (the add is skipped entirely).
    bias_row: Vec<f32>,
}

/// Algorithm 1: tiled INT8 forward.  Returns `(O, lse, residuals)`.
pub fn sage_fwd(q: &Tensor, k: &Tensor, v: &Tensor, cfg: &AttnConfig) -> Result<(Tensor, Vec<f32>, SageResiduals)> {
    sage_fwd_ws(q, k, v, cfg, &mut Workspace::new())
}

/// [`sage_fwd`] with a caller-owned scratch arena.
pub fn sage_fwd_ws(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    cfg: &AttnConfig,
    ws: &mut Workspace,
) -> Result<(Tensor, Vec<f32>, SageResiduals)> {
    let (n, d) = check_inputs(q, k, v)?;
    let (bq, bkv) = (cfg.block_q, cfg.block_kv);
    check_blocks(n, bq, bkv)?;
    let inv_sqrt_d = 1.0 / (d as f32).sqrt();

    // No-smoothing paths borrow the caller's tensors — the wholesale
    // q.clone()/k.clone() copies only happen when smoothing really
    // produces new data.
    let k_in: Cow<'_, Tensor> = if cfg.k_smoothing {
        Cow::Owned(smoothing::k_smooth(k)?.0)
    } else {
        Cow::Borrowed(k)
    };
    let (q_in, mu_q, bias_row): (Cow<'_, Tensor>, Option<Vec<f32>>, Vec<f32>) = if cfg.q_smoothing {
        let (q_sm, mu) = smoothing::q_smooth(q)?;
        let bias = smoothing::qk_logits_bias(&mu, &k_in)?;
        (Cow::Owned(q_sm), Some(mu), bias)
    } else {
        (Cow::Borrowed(q), None, Vec::new())
    };

    // Per-block quantization of Q, K, V into flat tile buffers (Alg 1
    // line 3); K additionally packed transposed for the S̃ GEMMs.
    let q_q = QuantTiles::quantize(&q_in, bq)?;
    let k_q = QuantTiles::quantize(&k_in, bkv)?;
    let k_t = k_q.transposed();
    let v_q = QuantTiles::quantize(v, bkv)?;
    let (tm, tn) = (n / bq, n / bkv);

    let mut o = vec![0f32; n * d];
    let mut lse = vec![0f32; n];
    let mut acc = ws.take_f32(bq * d);
    let mut m_i = ws.take_f32(bq);
    let mut l_i = ws.take_f32(bq);
    let mut s_i32 = ws.take_i32(bq * bkv);
    let mut s_ij = ws.take_f32(bq * bkv);
    let mut p_ij = ws.take_f32(bq * bkv);
    let mut corr = ws.take_f32(bq);
    let mut pv = ws.take_f32(bq * d);
    let mut p_q8 = ws.take_i8(bq * bkv);
    let mut p_scales = ws.take_f32(0);
    let mut pv_i32 = ws.take_i32(bq * d);
    for i in 0..tm {
        acc.fill(0.0);
        m_i.fill(f32::NEG_INFINITY);
        l_i.fill(0.0);
        for j in 0..tn {
            if cfg.causal && j * bkv > (i + 1) * bq - 1 {
                continue;
            }
            // S̃_ij = ψ(Q)_i · ψ(K)_jᵀ · δ_Q δ_K / √d  (+ Q-smoothing bias).
            let ktj = &k_t[j * bkv * d..(j + 1) * bkv * d];
            linalg::int8_gemm_nn_auto(q_q.tile(i), ktj, bq, d, bkv, &mut s_i32);
            let sc = q_q.scale(i) * k_q.scale(j) * inv_sqrt_d;
            for (sv, &x) in s_ij.iter_mut().zip(&s_i32) {
                *sv = x as f32 * sc;
            }
            add_bias_row(&mut s_ij, &bias_row, j * bkv, bkv, inv_sqrt_d);
            apply_causal_tile(&mut s_ij, cfg.causal, i * bq, j * bkv, bq, bkv);
            let (v_qj, v_sj) = (v_q.tile(j), v_q.scale(j));
            online_softmax_tile(
                &mut acc, &mut m_i, &mut l_i, &s_ij, bq, bkv, d,
                &mut p_ij, &mut corr, &mut pv,
                |p, pv_out| {
                    // Per-token ψ(P̃) (Alg 1 line 9), then exact INT8 P̃·V.
                    quant::quantize_per_token_into(p, bkv, &mut p_q8, &mut p_scales);
                    linalg::int8_gemm_nn_auto(&p_q8, v_qj, bq, bkv, d, &mut pv_i32);
                    for ((orow, irow), &rs) in pv_out
                        .chunks_exact_mut(d)
                        .zip(pv_i32.chunks_exact(d))
                        .zip(&p_scales)
                    {
                        let s = rs * v_sj;
                        for (ov, &x) in orow.iter_mut().zip(irow) {
                            *ov = x as f32 * s;
                        }
                    }
                },
            );
        }
        finish_block(&mut o, &mut lse, i * bq, &acc, &m_i, &l_i, d);
    }
    ws.give_i32(pv_i32);
    ws.give_f32(p_scales);
    ws.give_i8(p_q8);
    ws.give_f32(pv);
    ws.give_f32(corr);
    ws.give_f32(p_ij);
    ws.give_f32(s_ij);
    ws.give_i32(s_i32);
    ws.give_f32(l_i);
    ws.give_f32(m_i);
    ws.give_f32(acc);
    Ok((
        Tensor::from_vec(&[n, d], o)?,
        lse,
        SageResiduals { q_q, k_q, k_t, v_q, mu_q, bias_row },
    ))
}

/// Algorithms 1+2: INT8 forward + backward with every intermediate
/// materialized for the error analysis.
pub fn sage_bwd(q: &Tensor, k: &Tensor, v: &Tensor, do_: &Tensor, cfg: &AttnConfig) -> Result<AttnTrace> {
    sage_bwd_ws(q, k, v, do_, cfg, &mut Workspace::new())
}

/// [`sage_bwd`] with a caller-owned scratch arena.
pub fn sage_bwd_ws(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    do_: &Tensor,
    cfg: &AttnConfig,
    ws: &mut Workspace,
) -> Result<AttnTrace> {
    let (n, d) = check_inputs(q, k, v)?;
    if do_.shape != q.shape {
        bail!("dO shape {:?} != {:?}", do_.shape, q.shape);
    }
    let (bq, bkv) = (cfg.block_q, cfg.block_kv);
    let inv_sqrt_d = 1.0 / (d as f32).sqrt();
    let (o, lse, res) = sage_fwd_ws(q, k, v, cfg, ws)?;
    let delta = rowsum_mul(do_, &o)?;
    let (tm, tn) = (n / bq, n / bkv);

    let mut dq = Tensor::zeros(&[n, d]);
    let mut dk = Tensor::zeros(&[n, d]);
    let mut dv = Tensor::zeros(&[n, d]);
    let mut s_full = Tensor::zeros(&[n, n]);
    let mut p_full = Tensor::zeros(&[n, n]);
    let mut dp_full = Tensor::zeros(&[n, n]);
    let mut ds_full = Tensor::zeros(&[n, n]);

    // ψ(dO) depends only on the query tile — quantize each once, not per
    // (j, i) pair (Alg 2 line 6; bit-identical, tn× less work).
    let do_q = QuantTiles::quantize(do_, bq)?;
    // Same hoist for the §7 FP-dS variant's dequantized Q tiles (K's is
    // per-j inside the outer loop).
    let q_deq: Vec<Vec<f32>> = if cfg.quant_ds {
        Vec::new()
    } else {
        (0..tm)
            .map(|i| quant::dequantize(res.q_q.tile(i), res.q_q.scale(i)))
            .collect()
    };

    let mut s_i32 = ws.take_i32(bq * bkv);
    let mut s_ij = ws.take_f32(bq * bkv);
    let mut p_ij = ws.take_f32(bq * bkv);
    let mut dp_ij = ws.take_f32(bq * bkv);
    let mut ds_ij = ws.take_f32(bq * bkv);
    let mut ds_q8 = ws.take_i8(bq * bkv);
    let mut acc_i32 = ws.take_i32(bq.max(bkv) * d);
    let mut v_t = ws.take_f32(bkv * d);
    let mut packf = ws.take_f32(0);
    let mut packi = ws.take_i8(0);

    for j in 0..tn {
        let vj = &v.data[j * bkv * d..(j + 1) * bkv * d];
        // V tile packed transposed once per j — the dP GEMM reuses it for
        // every query tile i.
        linalg::pack_transpose_f32(vj, bkv, d, &mut v_t);
        let ktj = &res.k_t[j * bkv * d..(j + 1) * bkv * d];
        let k_deq = if cfg.quant_ds {
            // sagebwd-allow(A2): Vec::new() is a zero-capacity placeholder, no heap touch
            Vec::new()
        } else {
            quant::dequantize(res.k_q.tile(j), res.k_q.scale(j))
        };
        for i in 0..tm {
            if cfg.causal && j * bkv > (i + 1) * bq - 1 {
                continue;
            }
            let doi = &do_.data[i * bq * d..(i + 1) * bq * d];
            // Recompute S̃_ij from the stored quantized tiles (Alg 2 line 3).
            linalg::int8_gemm_nn_auto(res.q_q.tile(i), ktj, bq, d, bkv, &mut s_i32);
            let sc = res.q_q.scale(i) * res.k_q.scale(j) * inv_sqrt_d;
            for (sv, &x) in s_ij.iter_mut().zip(&s_i32) {
                *sv = x as f32 * sc;
            }
            add_bias_row(&mut s_ij, &res.bias_row, j * bkv, bkv, inv_sqrt_d);
            apply_causal_tile(&mut s_ij, cfg.causal, i * bq, j * bkv, bq, bkv);
            // P_ij = exp(S̃_ij − lse_i) — normalized this time.
            p_ij.fill(0.0);
            for r in 0..bq {
                let l = lse[i * bq + r];
                if l == f32::NEG_INFINITY {
                    continue;
                }
                for c in 0..bkv {
                    let sv = s_ij[r * bkv + c];
                    if sv != f32::NEG_INFINITY {
                        p_ij[r * bkv + c] = (sv - l).exp();
                    }
                }
            }

            // Alg 2 line 6: per-block ψ(P) (ψ(dO) precomputed) → INT8 dV.
            let p_s = quant::quantize_per_block_into(&p_ij, &mut ds_q8);
            let dv_i32 = &mut acc_i32[..bkv * d];
            linalg::int8_gemm_tn_auto(&ds_q8, do_q.tile(i), bkv, bq, d, dv_i32, &mut packi);
            let dv_sc = p_s * do_q.scale(i);
            for (dst, &x) in dv.data[j * bkv * d..(j + 1) * bkv * d].iter_mut().zip(dv_i32.iter()) {
                *dst += x as f32 * dv_sc;
            }

            // Alg 2 line 8: dP = dO·Vᵀ in full precision.
            linalg::gemm_nn(doi, &v_t, bq, d, bkv, &mut dp_ij);
            for r in 0..bq {
                let di = delta.data[i * bq + r];
                for c in 0..bkv {
                    ds_ij[r * bkv + c] = p_ij[r * bkv + c] * (dp_ij[r * bkv + c] - di);
                }
            }

            // Alg 2 line 9: ψ(dS) → INT8 dQ/dK (or the §7 FP-dS path) —
            // accumulated straight into the output slabs, no per-tile
            // result vectors.
            if cfg.quant_ds {
                let ds_s = quant::quantize_per_block_into(&ds_ij, &mut ds_q8);
                let dq_i32 = &mut acc_i32[..bq * d];
                linalg::int8_gemm_nn_auto(&ds_q8, res.k_q.tile(j), bq, bkv, d, dq_i32);
                let dq_sc = ds_s * res.k_q.scale(j) * inv_sqrt_d;
                for (dst, &x) in dq.data[i * bq * d..(i + 1) * bq * d].iter_mut().zip(dq_i32.iter()) {
                    *dst += x as f32 * dq_sc;
                }
                let dk_i32 = &mut acc_i32[..bkv * d];
                linalg::int8_gemm_tn_auto(&ds_q8, res.q_q.tile(i), bkv, bq, d, dk_i32, &mut packi);
                let dk_sc = ds_s * res.q_q.scale(i) * inv_sqrt_d;
                for (dst, &x) in dk.data[j * bkv * d..(j + 1) * bkv * d].iter_mut().zip(dk_i32.iter()) {
                    *dst += x as f32 * dk_sc;
                }
            } else {
                // §7 FP-dS: hoisted dequantized K/Q tiles, dS stays f32
                // (no redundant copy of the tile — linalg reads it in
                // place).
                packf.clear();
                packf.resize(bq * d, 0.0);
                linalg::gemm_nn(&ds_ij, &k_deq, bq, bkv, d, &mut packf);
                for (dst, &x) in dq.data[i * bq * d..(i + 1) * bq * d].iter_mut().zip(packf.iter()) {
                    *dst += x * inv_sqrt_d;
                }
                let mut dk_f = ws.take_f32(bkv * d);
                let mut pack2 = ws.take_f32(0);
                linalg::matmul_tn_scratch(&ds_ij, &q_deq[i], bkv, bq, d, &mut dk_f, 1, &mut pack2);
                for (dst, &x) in dk.data[j * bkv * d..(j + 1) * bkv * d].iter_mut().zip(dk_f.iter()) {
                    *dst += x * inv_sqrt_d;
                }
                ws.give_f32(pack2);
                ws.give_f32(dk_f);
            }

            // Materialize the big intermediates for the error analysis.
            for r in 0..bq {
                let row = i * bq + r;
                let dst = row * n + j * bkv;
                s_full.data[dst..dst + bkv].copy_from_slice(&s_ij[r * bkv..(r + 1) * bkv]);
                p_full.data[dst..dst + bkv].copy_from_slice(&p_ij[r * bkv..(r + 1) * bkv]);
                dp_full.data[dst..dst + bkv].copy_from_slice(&dp_ij[r * bkv..(r + 1) * bkv]);
                ds_full.data[dst..dst + bkv].copy_from_slice(&ds_ij[r * bkv..(r + 1) * bkv]);
            }
        }
    }
    ws.give_i8(packi);
    ws.give_f32(packf);
    ws.give_f32(v_t);
    ws.give_i32(acc_i32);
    ws.give_i8(ds_q8);
    ws.give_f32(ds_ij);
    ws.give_f32(dp_ij);
    ws.give_f32(p_ij);
    ws.give_f32(s_ij);
    ws.give_i32(s_i32);

    if cfg.q_smoothing {
        if let Some(mu_q) = &res.mu_q {
            // §6: dK = dSᵀ·Q_sm + (dSᵀ·1)·μ_Qᵀ — add the bias branch back.
            let mut bias = smoothing::dk_bias_branch(&ds_full, mu_q)?;
            bias.scale(inv_sqrt_d);
            dk.add_assign(&bias);
        }
    }

    Ok(AttnTrace {
        o,
        s: s_full,
        p: p_full,
        lse,
        delta,
        dp: dp_full,
        ds: ds_full,
        dq,
        dk,
        dv,
    })
}

// ---------------------------------------------------------------------------
// §5.4 pseudo-quantized FPA trace (Table 2, Figures 5/6)
// ---------------------------------------------------------------------------

/// Apply SageBwd's INT8 quantize-dequantize before each quantized matmul in
/// a plain attention implementation (paper §5.4).
///
/// dP is exact because the upstream dO is treated as error-free and the
/// dO·Vᵀ product stays in high precision — reproducing Table 2's
/// `Rel-L2(dP) = 0.0000` row.
pub fn pseudo_quant_trace(q: &Tensor, k: &Tensor, v: &Tensor, do_: &Tensor, cfg: &AttnConfig) -> Result<AttnTrace> {
    let (n, d) = check_inputs(q, k, v)?;
    if do_.shape != q.shape {
        bail!("dO shape {:?} != {:?}", do_.shape, q.shape);
    }
    let inv_sqrt_d = 1.0 / (d as f32).sqrt();

    let k_in: Cow<'_, Tensor> = if cfg.k_smoothing {
        Cow::Owned(smoothing::k_smooth(k)?.0)
    } else {
        Cow::Borrowed(k)
    };
    let (q_in, mu_q, bias): (Cow<'_, Tensor>, Option<Vec<f32>>, Vec<f32>) = if cfg.q_smoothing {
        let (q_sm, mu) = smoothing::q_smooth(q)?;
        let b = smoothing::qk_logits_bias(&mu, &k_in)?;
        (Cow::Owned(q_sm), Some(mu), b)
    } else {
        (Cow::Borrowed(q), None, vec![0f32; n])
    };

    let q_fq = Tensor::from_vec(&[n, d], quant::fake_quant_block(&q_in.data))?;
    let k_fq = Tensor::from_vec(&[n, d], quant::fake_quant_block(&k_in.data))?;
    let v_fq = Tensor::from_vec(&[n, d], quant::fake_quant_block(&v.data))?;

    let mut s = q_fq.matmul_nt(&k_fq)?;
    for row in s.data.chunks_exact_mut(n) {
        for (sv, &b) in row.iter_mut().zip(&bias) {
            *sv += b;
        }
    }
    s.scale(inv_sqrt_d);
    if cfg.causal {
        for i in 0..n {
            for j in i + 1..n {
                s.data[i * n + j] = f32::NEG_INFINITY;
            }
        }
    }
    let (p, lse) = s.softmax_rows()?;

    let p_fq_token = Tensor::from_vec(&[n, n], quant::fake_quant_token(&p.data, n, n))?;
    let o = p_fq_token.matmul(&v_fq)?;

    // Backward: quant-dequant before each SageBwd-quantized MM.
    let p_fq_blk = Tensor::from_vec(&[n, n], quant::fake_quant_block(&p.data))?;
    let do_fq = Tensor::from_vec(&[n, d], quant::fake_quant_block(&do_.data))?;
    let dv = p_fq_blk.matmul_tn(&do_fq)?;
    let dp = do_.matmul_nt(v)?; // FP16 path — exact here
    let delta = rowsum_mul(do_, &o)?;
    let mut ds = Tensor::zeros(&[n, n]);
    for i in 0..n {
        let di = delta.data[i];
        for j in 0..n {
            ds.data[i * n + j] = p.data[i * n + j] * (dp.data[i * n + j] - di);
        }
    }
    let ds_fq: Cow<'_, Tensor> = if cfg.quant_ds {
        Cow::Owned(Tensor::from_vec(&[n, n], quant::fake_quant_block(&ds.data))?)
    } else {
        Cow::Borrowed(&ds)
    };
    let mut dq = ds_fq.matmul(&k_fq)?;
    dq.scale(inv_sqrt_d);
    let mut dk = ds_fq.matmul_tn(&q_fq)?;
    dk.scale(inv_sqrt_d);
    if let Some(mu_q) = &mu_q {
        let mut bias_branch = smoothing::dk_bias_branch(&ds, mu_q)?;
        bias_branch.scale(inv_sqrt_d);
        dk.add_assign(&bias_branch);
    }
    Ok(AttnTrace { o, s, p, lse, delta, dp, ds, dq, dk, dv })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::common::gaussian_qkvdo;
    use crate::util::stats::{cossim, rel_l2};

    fn inputs(n: usize, d: usize, sigma: f32, seed: u64) -> [Tensor; 4] {
        gaussian_qkvdo(n, d, sigma, sigma, 1.0, 1.0, seed)
    }

    #[test]
    fn fpa_softmax_rows_sum_to_one() {
        let [q, k, v, _] = inputs(64, 16, 1.0, 1);
        let (_, _, p, _) = fpa_fwd(&q, &k, &v, false).unwrap();
        for row in p.data.chunks(64) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "row sum {s}");
        }
    }

    #[test]
    fn fpa_ds_rows_sum_to_zero() {
        // The K-smoothing gradient identity (§6): every dS row sums to 0.
        let [q, k, v, do_] = inputs(64, 16, 1.0, 2);
        let tr = fpa_bwd(&q, &k, &v, &do_, false).unwrap();
        for row in tr.ds.data.chunks(64) {
            let s: f32 = row.iter().sum();
            assert!(s.abs() < 1e-4, "dS row sum {s}");
        }
    }

    #[test]
    fn fa2_tiling_matches_fpa_exactly() {
        let [q, k, v, _] = inputs(64, 16, 1.0, 3);
        let cfg = AttnConfig { block_q: 16, block_kv: 16, ..Default::default() };
        let (o_fa2, lse_fa2) = fa2_fwd(&q, &k, &v, &cfg).unwrap();
        let (o_fpa, _, _, lse_fpa) = fpa_fwd(&q, &k, &v, false).unwrap();
        assert!(o_fa2.rel_l2(&o_fpa) < 1e-5, "rel {}", o_fa2.rel_l2(&o_fpa));
        for (a, b) in lse_fa2.iter().zip(&lse_fpa) {
            assert!((a - b).abs() < 1e-4, "lse {a} vs {b}");
        }
    }

    #[test]
    fn fa2_causal_matches_fpa_causal() {
        let [q, k, v, _] = inputs(64, 16, 1.0, 4);
        let cfg = AttnConfig { block_q: 16, block_kv: 16, causal: true, ..Default::default() };
        let (o_fa2, _) = fa2_fwd(&q, &k, &v, &cfg).unwrap();
        let (o_fpa, _, _, _) = fpa_fwd(&q, &k, &v, true).unwrap();
        assert!(o_fa2.rel_l2(&o_fpa) < 1e-5);
    }

    #[test]
    fn sage_close_to_fpa_at_unit_sigma() {
        // Table 1's σ=1 row: cossim ≥ 0.999 on O/dV, ≥ 0.99 on dQ/dK.
        let [q, k, v, do_] = inputs(64, 32, 1.0, 5);
        let cfg = AttnConfig { block_q: 16, block_kv: 16, ..Default::default() };
        let sage = sage_bwd(&q, &k, &v, &do_, &cfg).unwrap();
        let fpa = fpa_bwd(&q, &k, &v, &do_, false).unwrap();
        for (name, s, f, min_cos) in [
            ("o", &sage.o, &fpa.o, 0.999),
            ("dq", &sage.dq, &fpa.dq, 0.99),
            ("dk", &sage.dk, &fpa.dk, 0.99),
            ("dv", &sage.dv, &fpa.dv, 0.999),
        ] {
            let c = cossim(&s.data, &f.data);
            assert!(c > min_cos, "{name}: cossim {c}");
        }
    }

    #[test]
    fn sage_backward_is_finite_and_sized() {
        let [q, k, v, do_] = inputs(64, 16, 2.0, 6);
        let cfg = AttnConfig { block_q: 32, block_kv: 32, ..Default::default() };
        let tr = sage_bwd(&q, &k, &v, &do_, &cfg).unwrap();
        for (name, t) in [("o", &tr.o), ("dq", &tr.dq), ("dk", &tr.dk), ("dv", &tr.dv)] {
            assert_eq!(t.shape, vec![64, 16], "{name}");
            assert!(t.is_finite(), "{name} has non-finite values");
        }
        assert_eq!(tr.delta.shape, vec![64]);
        assert_eq!(tr.p.shape, vec![64, 64]);
    }

    #[test]
    fn workspace_reuse_is_bitwise_stable() {
        // A warm arena (dirty pooled buffers from a previous call) must
        // not change any output bit — the allocation-free hot loop
        // contract of DESIGN.md §11.
        let [q, k, v, do_] = inputs(64, 16, 2.0, 16);
        let cfg = AttnConfig { block_q: 16, block_kv: 32, causal: true, ..Default::default() };
        let cold = sage_bwd(&q, &k, &v, &do_, &cfg).unwrap();
        let mut ws = Workspace::new();
        let warm1 = sage_bwd_ws(&q, &k, &v, &do_, &cfg, &mut ws).unwrap();
        assert!(ws.pooled() > 0, "backward returned no buffers to the pool");
        let warm2 = sage_bwd_ws(&q, &k, &v, &do_, &cfg, &mut ws).unwrap();
        for (name, a, b, c) in [
            ("o", &cold.o, &warm1.o, &warm2.o),
            ("dq", &cold.dq, &warm1.dq, &warm2.dq),
            ("dk", &cold.dk, &warm1.dk, &warm2.dk),
            ("dv", &cold.dv, &warm1.dv, &warm2.dv),
            ("ds", &cold.ds, &warm1.ds, &warm2.ds),
        ] {
            assert_eq!(a.data, b.data, "{name}: cold vs warm");
            assert_eq!(b.data, c.data, "{name}: warm vs rewarm");
        }
        // Same for the FA2 tiled forward.
        let (o_cold, _) = fa2_fwd(&q, &k, &v, &cfg).unwrap();
        let (o_warm, _) = fa2_fwd_ws(&q, &k, &v, &cfg, &mut ws).unwrap();
        assert_eq!(o_cold.data, o_warm.data);
    }

    #[test]
    fn pseudo_dp_is_exact() {
        // Table 2's structural property: the dP matmul stays full precision.
        let [q, k, v, do_] = inputs(64, 16, 4.0, 7);
        let pseudo = pseudo_quant_trace(&q, &k, &v, &do_, &AttnConfig::default()).unwrap();
        let fpa = fpa_bwd(&q, &k, &v, &do_, false).unwrap();
        assert!(rel_l2(&pseudo.dp.data, &fpa.dp.data) < 1e-6);
    }

    #[test]
    fn fp_ds_variant_at_least_as_accurate() {
        let [q, k, v, do_] = inputs(64, 16, 4.0, 8);
        let int8 = pseudo_quant_trace(&q, &k, &v, &do_, &AttnConfig::default()).unwrap();
        let fpds = pseudo_quant_trace(
            &q, &k, &v, &do_,
            &AttnConfig { quant_ds: false, ..Default::default() },
        )
        .unwrap();
        let fpa = fpa_bwd(&q, &k, &v, &do_, false).unwrap();
        let r_int8 = rel_l2(&int8.dq.data, &fpa.dq.data);
        let r_fpds = rel_l2(&fpds.dq.data, &fpa.dq.data);
        assert!(r_fpds <= r_int8 * 1.05, "fp-dS {r_fpds} vs int8 {r_int8}");
    }

    #[test]
    fn fp_ds_kernel_variant_runs_with_workspace() {
        // The §7 FP-dS path of the blocked kernel (quant_ds = false) also
        // tracks the oracle and is workspace-stable.
        let [q, k, v, do_] = inputs(64, 16, 1.0, 17);
        let cfg = AttnConfig { block_q: 16, block_kv: 16, quant_ds: false, ..Default::default() };
        let mut ws = Workspace::new();
        let a = sage_bwd_ws(&q, &k, &v, &do_, &cfg, &mut ws).unwrap();
        let b = sage_bwd_ws(&q, &k, &v, &do_, &cfg, &mut ws).unwrap();
        assert_eq!(a.dq.data, b.dq.data);
        assert_eq!(a.dk.data, b.dk.data);
        let fpa = fpa_bwd(&q, &k, &v, &do_, false).unwrap();
        assert!(a.dq.cossim(&fpa.dq) > 0.99, "fp-dS dq cossim {}", a.dq.cossim(&fpa.dq));
    }

    #[test]
    fn max_abs_logit_matches_dense_logits() {
        let [q, k, _, _] = inputs(32, 16, 2.0, 9);
        let s = masked_logits(&q, &k, false).unwrap();
        let want = s.data.iter().fold(0f32, |m, &x| m.max(x.abs()));
        let got = max_abs_logit(&q, &k, false).unwrap();
        assert!((got - want).abs() < 1e-4, "{got} vs {want}");
        // Causal: masked entries must not contribute.
        let got_c = max_abs_logit(&q, &k, true).unwrap();
        let mut want_c = 0f32;
        for i in 0..32 {
            for j in 0..=i {
                want_c = want_c.max(s.data[i * 32 + j].abs());
            }
        }
        assert!((got_c - want_c).abs() < 1e-4);
        assert!(got_c <= got + 1e-6);
    }

    #[test]
    fn max_abs_logit_propagates_non_finite() {
        // The fig1 divergence contract (DESIGN.md §10): a NaN activation
        // must surface as a NaN statistic (and ∞ as ∞), never as a
        // healthy-looking finite maximum.
        let [mut q, k, _, _] = inputs(32, 16, 1.0, 10);
        q.data[5] = f32::NAN;
        assert!(max_abs_logit(&q, &k, false).unwrap().is_nan());
        assert!(max_abs_logit(&q, &k, true).unwrap().is_nan());
        let [mut q2, k2, _, _] = inputs(32, 16, 1.0, 10);
        q2.data[0] = f32::INFINITY;
        assert!(max_abs_logit(&q2, &k2, false).unwrap().is_infinite());
    }

    #[test]
    fn bad_shapes_rejected() {
        let q = Tensor::zeros(&[32, 8]);
        let bad = Tensor::zeros(&[16, 8]);
        assert!(fpa_fwd(&q, &bad, &q, false).is_err());
        assert!(sage_fwd(&q, &q, &q, &AttnConfig { block_q: 5, ..Default::default() }).is_err());
        assert!(fpa_bwd(&q, &q, &q, &bad, false).is_err());
    }
}
