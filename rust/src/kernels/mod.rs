//! Native CPU reference kernels for SageBwd attention (DESIGN.md §3).
//!
//! Pure-Rust twins of `python/compile/kernels/{quant,smoothing,ref}.py`:
//! the paper's INT8 quantizer ψ, Q/K-smoothing, the exact FPA oracle, the
//! tiled FA2 baseline, the block-faithful Algorithms 1+2 implementation,
//! and the §5.4 pseudo-quantized trace.  Together with
//! [`crate::runtime::backend::NativeBackend`] they make every trace/bench
//! experiment harness runnable with no artifacts, no Python, and no XLA
//! runtime — `sagebwd table2 --backend native` works on a fresh checkout.
//!
//! | module        | contents                                              |
//! |---------------|-------------------------------------------------------|
//! | [`quant`]     | ψ per-block / per-token INT8, exact i32 GEMMs         |
//! | [`smoothing`] | K/Q mean subtraction + the §6 gradient corrections    |
//! | [`attention`] | `fpa_*`, `fa2_fwd`, `sage_fwd`/`sage_bwd`, §5.4 trace |

pub mod attention;
pub mod quant;
pub mod smoothing;

pub use attention::{fa2_fwd, fa2_fwd_ws, fpa_bwd, fpa_fwd, max_abs_logit, pseudo_quant_trace,
                    sage_bwd, sage_bwd_ws, sage_fwd, sage_fwd_ws};
pub use attention::{AttnConfig, AttnTrace};
