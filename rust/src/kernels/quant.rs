//! INT8 quantization primitives (paper §3 "Quantization") — the native
//! twin of `python/compile/kernels/quant.py`.
//!
//! The quantizer ψ used throughout Algorithms 1 and 2:
//!
//!     x̂ = round(x / δ),   δ = max(|x|) / 127
//!
//! in two granularities: per-block (one δ per FlashAttention tile — the
//! SageBwd default) and per-token (one δ per row, used for P̃ in Alg 1
//! line 9).  Rounding is round-half-to-even to match `jnp.round` /
//! hardware convert instructions bit-for-bit; the integer matmuls
//! accumulate in i32, which is exact for every shape this repo uses
//! (|x̂| ≤ 127 ⇒ per-product ≤ 16129; N ≤ 512 rows ⇒ |Σ| < 2³³⁄₂ ≪ i32::MAX
//! holds for all tile sizes ≤ 512 actually used: 512·16129 ≈ 8.3·10⁶).
//!
//! Production kernels run on the blocked compute engine
//! (`tensor::linalg`, DESIGN.md §11) via the `*_into` quantizers and
//! flat tile buffers.  The allocating GEMM/scale helpers below
//! (`int8_gemm*`, `scale_product*`, `quantize_per_token`) are **retained
//! as reference implementations** — the exactness oracles the engine's
//! property tests compare against — not hot-path API.

/// Largest quantized magnitude.
pub const INT8_MAX: f32 = 127.0;

/// Smallest allowed pre-division scale numerator: an all-zeros block would
/// otherwise produce δ = 0 and NaNs on the dequant path.
pub const EPS_SCALE: f32 = 1e-12;

/// `round` with ties to even (the IEEE default, and what `jnp.round` does;
/// `f32::round` rounds ties away from zero and would diverge from the
/// Python reference on exact half-integers).
#[inline]
pub fn round_ties_even(x: f32) -> f32 {
    let rounded = x.round();
    if (rounded - x).abs() == 0.5 {
        // x is an exact half-integer: pick the even neighbour.  x/2 ends in
        // .25 or .75, so its round() is never itself a tie.
        (x / 2.0).round() * 2.0
    } else {
        rounded
    }
}

#[inline]
fn quantize_one(x: f32, scale: f32) -> i8 {
    round_ties_even(x / scale).clamp(-INT8_MAX, INT8_MAX) as i8
}

/// ψ with one scale for a whole tile (per-tensor over the tile — SageBwd's
/// per-block granularity, Alg 1 line 3 / Alg 2 lines 6 & 9).
pub fn quantize_per_block(x: &[f32]) -> (Vec<i8>, f32) {
    let amax = x.iter().fold(0f32, |m, &v| m.max(v.abs()));
    let scale = amax.max(EPS_SCALE) / INT8_MAX;
    (x.iter().map(|&v| quantize_one(v, scale)).collect(), scale)
}

/// [`quantize_per_block`] writing into caller storage (a tile of the flat
/// quantized buffer the compute engine uses — no per-tile `Vec`).
/// Returns the tile's scale δ.
pub fn quantize_per_block_into(x: &[f32], out: &mut [i8]) -> f32 {
    assert_eq!(x.len(), out.len());
    let amax = x.iter().fold(0f32, |m, &v| m.max(v.abs()));
    let scale = amax.max(EPS_SCALE) / INT8_MAX;
    for (o, &v) in out.iter_mut().zip(x) {
        *o = quantize_one(v, scale);
    }
    scale
}

/// [`quantize_per_token`] writing into caller storage; `scales` receives
/// one δ per row (its previous contents are cleared).
pub fn quantize_per_token_into(x: &[f32], cols: usize, out: &mut [i8], scales: &mut Vec<f32>) {
    assert_eq!(x.len(), out.len());
    scales.clear();
    for (row, orow) in x.chunks_exact(cols).zip(out.chunks_exact_mut(cols)) {
        let amax = row.iter().fold(0f32, |m, &v| m.max(v.abs()));
        let scale = amax.max(EPS_SCALE) / INT8_MAX;
        scales.push(scale);
        for (o, &v) in orow.iter_mut().zip(row) {
            *o = quantize_one(v, scale);
        }
    }
}

/// ψ with one scale per row of a `(rows, cols)` tile (Alg 1 line 9 — each
/// query token's P̃ row gets its own scale because rowmax(P̃) varies by
/// orders of magnitude after the online-softmax subtraction).
pub fn quantize_per_token(x: &[f32], rows: usize, cols: usize) -> (Vec<i8>, Vec<f32>) {
    assert_eq!(x.len(), rows * cols);
    let mut q = Vec::with_capacity(x.len());
    let mut scales = Vec::with_capacity(rows);
    for row in x.chunks_exact(cols) {
        let amax = row.iter().fold(0f32, |m, &v| m.max(v.abs()));
        let scale = amax.max(EPS_SCALE) / INT8_MAX;
        scales.push(scale);
        q.extend(row.iter().map(|&v| quantize_one(v, scale)));
    }
    (q, scales)
}

/// Inverse of ψ: x ≈ x̂ · δ.
pub fn dequantize(q: &[i8], scale: f32) -> Vec<f32> {
    q.iter().map(|&v| v as f32 * scale).collect()
}

/// Exact integer GEMM `A·B`: `(m,k) × (k,n) → (m,n)` in i32.
pub fn int8_gemm(a: &[i8], b: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut out = vec![0i32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let acc = &mut out[i * n..(i + 1) * n];
        for (t, &av) in arow.iter().enumerate() {
            let av = av as i32;
            let brow = &b[t * n..(t + 1) * n];
            for (o, &bv) in acc.iter_mut().zip(brow) {
                *o += av * bv as i32;
            }
        }
    }
    out
}

/// Exact integer GEMM `A·Bᵀ`: `(m,k) × (n,k) → (m,n)` — the Q̂·K̂ᵀ layout.
pub fn int8_gemm_nt(a: &[i8], b: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    let mut out = vec![0i32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0i32;
            for (&av, &bv) in arow.iter().zip(brow) {
                acc += av as i32 * bv as i32;
            }
            out[i * n + j] = acc;
        }
    }
    out
}

/// Exact integer GEMM `Aᵀ·B`: `(k,m) × (k,n) → (m,n)` — the P̂ᵀ·d̂O layout.
pub fn int8_gemm_tn(a: &[i8], b: &[i8], k: usize, m: usize, n: usize) -> Vec<i32> {
    assert_eq!(a.len(), k * m);
    assert_eq!(b.len(), k * n);
    let mut out = vec![0i32; m * n];
    for t in 0..k {
        let arow = &a[t * m..(t + 1) * m];
        let brow = &b[t * n..(t + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            let av = av as i32;
            let acc = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in acc.iter_mut().zip(brow) {
                *o += av * bv as i32;
            }
        }
    }
    out
}

/// Scale an exact i32 product by a single `a_scale · b_scale` pair.
pub fn scale_product(acc: &[i32], a_scale: f32, b_scale: f32) -> Vec<f32> {
    let s = a_scale * b_scale;
    acc.iter().map(|&v| v as f32 * s).collect()
}

/// Scale an exact i32 product with per-row A scales and one B scale
/// (the per-token P̃ path of Alg 1 line 9).
pub fn scale_product_rows(
    acc: &[i32],
    row_scales: &[f32],
    b_scale: f32,
    cols: usize,
) -> Vec<f32> {
    assert_eq!(acc.len(), row_scales.len() * cols);
    let mut out = Vec::with_capacity(acc.len());
    for (row, &rs) in acc.chunks_exact(cols).zip(row_scales) {
        let s = rs * b_scale;
        out.extend(row.iter().map(|&v| v as f32 * s));
    }
    out
}

/// Quantize-dequantize round trip with per-block granularity (§5.4
/// pseudo-quantization).
pub fn fake_quant_block(x: &[f32]) -> Vec<f32> {
    let (q, s) = quantize_per_block(x);
    dequantize(&q, s)
}

/// Quantize-dequantize round trip with per-token granularity.
pub fn fake_quant_token(x: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let (q, scales) = quantize_per_token(x, rows, cols);
    let mut out = Vec::with_capacity(x.len());
    for (row, &s) in q.chunks_exact(cols).zip(&scales) {
        out.extend(row.iter().map(|&v| v as f32 * s));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_ties_even_matches_ieee() {
        assert_eq!(round_ties_even(0.5), 0.0);
        assert_eq!(round_ties_even(1.5), 2.0);
        assert_eq!(round_ties_even(2.5), 2.0);
        assert_eq!(round_ties_even(-0.5), 0.0);
        assert_eq!(round_ties_even(-1.5), -2.0);
        assert_eq!(round_ties_even(1.4), 1.0);
        assert_eq!(round_ties_even(-1.6), -2.0);
    }

    #[test]
    fn per_block_maps_max_to_127() {
        let (q, s) = quantize_per_block(&[0.0, -2.0, 1.0, 0.5]);
        assert_eq!(q[1], -127);
        assert!((s - 2.0 / 127.0).abs() < 1e-9);
        // round(1.0 / (2/127)) = round(63.5) = 64 (ties-to-even → 64 since
        // 63.5 rounds to the even 64? 63.5 → 64 is even — yes).
        assert_eq!(q[2], 64);
    }

    #[test]
    fn zero_block_is_safe() {
        let (q, s) = quantize_per_block(&[0.0; 8]);
        assert!(q.iter().all(|&v| v == 0));
        assert!(s > 0.0 && s.is_finite());
        assert!(dequantize(&q, s).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn into_variants_match_allocating_twins() {
        let x: Vec<f32> = (0..24).map(|i| ((i * 31 % 47) as f32 - 23.0) / 5.0).collect();
        let (q, s) = quantize_per_block(&x);
        let mut q2 = vec![0i8; x.len()];
        let s2 = quantize_per_block_into(&x, &mut q2);
        assert_eq!(q, q2);
        assert_eq!(s, s2);
        let (qt, st) = quantize_per_token(&x, 4, 6);
        let mut qt2 = vec![0i8; x.len()];
        let mut st2 = vec![99.0; 2]; // stale contents must be cleared
        quantize_per_token_into(&x, 6, &mut qt2, &mut st2);
        assert_eq!(qt, qt2);
        assert_eq!(st, st2);
    }

    #[test]
    fn per_token_scales_each_row() {
        let x = [1.0, -1.0, 100.0, 50.0];
        let (q, s) = quantize_per_token(&x, 2, 2);
        assert_eq!(q, vec![127, -127, 127, 64]);
        assert!((s[0] - 1.0 / 127.0).abs() < 1e-9);
        assert!((s[1] - 100.0 / 127.0).abs() < 1e-6);
    }

    #[test]
    fn quant_dequant_error_bounded_by_half_step() {
        let x: Vec<f32> = (0..64).map(|i| ((i * 37 % 129) as f32 - 64.0) / 7.0).collect();
        let (q, s) = quantize_per_block(&x);
        let back = dequantize(&q, s);
        for (a, b) in x.iter().zip(&back) {
            assert!((a - b).abs() <= s * 0.5 + 1e-6, "{a} vs {b} (δ={s})");
        }
    }

    #[test]
    fn gemm_layouts_agree_with_naive() {
        let a: Vec<i8> = (0..6).map(|i| i as i8 - 3).collect(); // (2,3)
        let b: Vec<i8> = (0..12).map(|i| (i * 5 % 11) as i8 - 5).collect(); // (3,4)
        let nn = int8_gemm(&a, &b, 2, 3, 4);
        // transpose b to (4,3) and use nt
        let mut bt = vec![0i8; 12];
        for i in 0..3 {
            for j in 0..4 {
                bt[j * 3 + i] = b[i * 4 + j];
            }
        }
        assert_eq!(int8_gemm_nt(&a, &bt, 2, 3, 4), nn);
        // transpose a to (3,2) and use tn
        let mut at = vec![0i8; 6];
        for i in 0..2 {
            for j in 0..3 {
                at[j * 2 + i] = a[i * 3 + j];
            }
        }
        assert_eq!(int8_gemm_tn(&at, &b, 3, 2, 4), nn);
    }

    #[test]
    fn int8_matmul_approximates_f32() {
        // ψ(A)·ψ(B) with dequant scales ≈ A·B.
        let a: Vec<f32> = (0..32).map(|i| ((i * 13 % 17) as f32 - 8.0) / 3.0).collect();
        let b: Vec<f32> = (0..32).map(|i| ((i * 7 % 19) as f32 - 9.0) / 4.0).collect();
        let (aq, asc) = quantize_per_block(&a);
        let (bq, bsc) = quantize_per_block(&b);
        let approx = scale_product(&int8_gemm(&aq, &bq, 4, 8, 8), asc, bsc);
        let mut exact = vec![0f32; 32];
        for i in 0..4 {
            for j in 0..8 {
                for t in 0..8 {
                    exact[i * 8 + j] += a[i * 8 + t] * b[t * 8 + j];
                }
            }
        }
        let rel = crate::util::stats::rel_l2(&approx, &exact);
        assert!(rel < 0.02, "rel_l2 {rel}");
    }

    #[test]
    fn fake_quant_token_matches_manual() {
        let x = [0.5f32, -0.25, 8.0, 2.0];
        let fq = fake_quant_token(&x, 2, 2);
        let (q, s) = quantize_per_token(&x, 2, 2);
        for i in 0..4 {
            assert_eq!(fq[i], q[i] as f32 * s[i / 2]);
        }
    }
}
