//! Q/K-smoothing (paper §3 "Q and K Smoothing", §6 ablation) — the native
//! twin of `python/compile/kernels/smoothing.py`.
//!
//! K-smoothing subtracts the token-wise (per-channel) mean of K before
//! quantization:
//!
//!     K_sm = K − 1·μ_K,   μ_K[d] = meanₙ K[n,d]
//!
//! Softmax row-invariance makes the forward exactly equivalent (every
//! logit in a row shifts by the same Q_i·μ_Kᵀ), and the backward needs no
//! correction because every row of dS sums to zero: dQ = dS·K = dS·K_sm.
//!
//! Q-smoothing subtracts μ_Q from Q; forward equivalence needs the rank-1
//! bias μ_Q·Kᵀ added back to the logits, and the dK gradient needs the
//! bias branch dK_bias = (dSᵀ·1)·μ_Qᵀ (paper §6).

use anyhow::Result;

use crate::tensor::Tensor;

/// Subtract the per-channel mean over the token axis.
/// Returns `(X_sm, μ)` with `μ` of length `d`.
pub fn smooth(x: &Tensor) -> Result<(Tensor, Vec<f32>)> {
    let (n, d) = x.dims2()?;
    let mut mu = vec![0f32; d];
    for row in x.data.chunks_exact(d) {
        for (m, &v) in mu.iter_mut().zip(row) {
            *m += v;
        }
    }
    let inv_n = 1.0 / n as f32;
    for m in mu.iter_mut() {
        *m *= inv_n;
    }
    let mut sm = x.clone();
    for row in sm.data.chunks_exact_mut(d) {
        for (v, &m) in row.iter_mut().zip(&mu) {
            *v -= m;
        }
    }
    Ok((sm, mu))
}

/// `K_sm = K − 1·μ_K` (paper default — always applied to K).
pub fn k_smooth(k: &Tensor) -> Result<(Tensor, Vec<f32>)> {
    smooth(k)
}

/// `Q_sm = Q − 1·μ_Q` (§6 ablation).
pub fn q_smooth(q: &Tensor) -> Result<(Tensor, Vec<f32>)> {
    smooth(q)
}

/// Rank-1 logits correction `μ_Q·Kᵀ` restoring S after Q-smoothing:
/// `bias[t] = Σ_d μ_Q[d]·K[t,d]`, broadcast over the query axis.
pub fn qk_logits_bias(mu_q: &[f32], k: &Tensor) -> Result<Vec<f32>> {
    let (n, d) = k.dims2()?;
    assert_eq!(mu_q.len(), d);
    let mut bias = vec![0f32; n];
    for (b, row) in bias.iter_mut().zip(k.data.chunks_exact(d)) {
        for (&m, &v) in mu_q.iter().zip(row) {
            *b += m * v;
        }
    }
    Ok(bias)
}

/// `dK_bias = (dSᵀ·1)·μ_Qᵀ` — the §6 gradient correction for Q-smoothing.
/// `ds` is `(m, n)`; the result is `(n, d)`.
pub fn dk_bias_branch(ds: &Tensor, mu_q: &[f32]) -> Result<Tensor> {
    let (m, n) = ds.dims2()?;
    let d = mu_q.len();
    let mut colsum = vec![0f32; n];
    for i in 0..m {
        let row = &ds.data[i * n..(i + 1) * n];
        for (c, &v) in colsum.iter_mut().zip(row) {
            *c += v;
        }
    }
    let mut out = vec![0f32; n * d];
    for (j, &c) in colsum.iter().enumerate() {
        for (t, &mq) in mu_q.iter().enumerate() {
            out[j * d + t] = c * mq;
        }
    }
    Tensor::from_vec(&[n, d], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn smooth_zeroes_channel_means() {
        let mut rng = Pcg64::new(1, 0);
        let mut k = Tensor::randn(&[16, 4], 1.0, &mut rng);
        // Plant a large channel offset — the outlier K-smoothing targets.
        for row in k.data.chunks_exact_mut(4) {
            row[2] += 10.0;
        }
        let (sm, mu) = k_smooth(&k).unwrap();
        assert!(mu[2] > 5.0);
        for ch in 0..4 {
            let mean: f32 = sm.data.iter().skip(ch).step_by(4).sum::<f32>() / 16.0;
            assert!(mean.abs() < 1e-5, "channel {ch} mean {mean}");
        }
    }

    #[test]
    fn smoothing_is_softmax_invariant() {
        // softmax(Q·Kᵀ) == softmax(Q·K_smᵀ + Q·μ_Kᵀ): the bias is constant
        // along each row, so P is unchanged.
        let mut rng = Pcg64::new(2, 0);
        let q = Tensor::randn(&[8, 4], 1.0, &mut rng.split(0));
        let k = Tensor::randn(&[8, 4], 1.0, &mut rng.split(1));
        let (ksm, _) = k_smooth(&k).unwrap();
        let (p1, _) = q.matmul_nt(&k).unwrap().softmax_rows().unwrap();
        // Row-constant shifts cancel in softmax even without adding the
        // bias back.
        let (p2, _) = q.matmul_nt(&ksm).unwrap().softmax_rows().unwrap();
        assert!(p1.rel_l2(&p2) < 1e-4, "rel {}", p1.rel_l2(&p2));
    }

    #[test]
    fn qk_bias_restores_logits() {
        let mut rng = Pcg64::new(3, 0);
        let q = Tensor::randn(&[6, 4], 1.0, &mut rng.split(0));
        let k = Tensor::randn(&[6, 4], 1.0, &mut rng.split(1));
        let (qsm, mu_q) = q_smooth(&q).unwrap();
        let bias = qk_logits_bias(&mu_q, &k).unwrap();
        let exact = q.matmul_nt(&k).unwrap();
        let mut restored = qsm.matmul_nt(&k).unwrap();
        for row in restored.data.chunks_exact_mut(6) {
            for (v, &b) in row.iter_mut().zip(&bias) {
                *v += b;
            }
        }
        assert!(exact.rel_l2(&restored) < 1e-5);
    }

    #[test]
    fn dk_bias_branch_completes_gradient() {
        // dSᵀ·Q == dSᵀ·Q_sm + (dSᵀ·1)·μ_Qᵀ.
        let mut rng = Pcg64::new(4, 0);
        let q = Tensor::randn(&[6, 4], 1.0, &mut rng.split(0));
        let ds = Tensor::randn(&[6, 6], 1.0, &mut rng.split(1));
        let (qsm, mu_q) = q_smooth(&q).unwrap();
        let exact = ds.matmul_tn(&q).unwrap();
        let mut center = ds.matmul_tn(&qsm).unwrap();
        center.add_assign(&dk_bias_branch(&ds, &mu_q).unwrap());
        assert!(exact.rel_l2(&center) < 1e-5);
    }
}
