//! # SageBwd — trainable low-bit attention (Rust coordinator)
//!
//! Three-layer reproduction of *"SageBwd: A Trainable Low-bit Attention"*
//! (Zhang et al., 2026).  This crate is **Layer 3**: the pre-training
//! coordinator that loads AOT-compiled XLA artifacts (produced once by the
//! Python/JAX/Pallas build path under `python/compile/`) and runs the
//! paper's experiments with Python nowhere on the hot path.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! * [`kernels`]     — native CPU SageBwd kernels: tiled INT8
//!   forward/backward (Algorithms 1+2), K-smoothing, the FPA oracle, and
//!   the §5.4 pseudo-quantized trace — no artifacts or XLA needed.
//! * [`model`]       — the native training model: a decoder-only
//!   transformer with manual forward/backward (RMSNorm, QK-norm, MHA via
//!   the attention backends, SwiGLU, tied-embedding CE head) + AdamW, so
//!   every training experiment runs from a bare checkout.
//! * [`runtime`]     — backend selection (`--backend native|xla`); the XLA
//!   half loads `artifacts/*.hlo.txt` + manifests, compiles once, executes
//!   on the hot path.
//! * [`coordinator`] — trainer over a pluggable `TrainEngine`
//!   (native|xla), tokens-per-step gradient accumulator (the paper's §4.3
//!   axis), warmup+cosine LR schedule, divergence telemetry, checkpoints.
//! * [`data`]        — synthetic-corpus substrate: generator, byte
//!   tokenizer, deterministic shardable batcher with prefetch.
//! * [`experiments`] — one harness per paper table/figure.
//! * [`registry`]    — content-addressed run registry: pure-std SHA-256,
//!   the `sagebwd-run-v1` manifest schema, the object store with legacy
//!   views, and the resumable grid orchestrator (`sagebwd grid`).
//! * [`analysis`]    — self-hosting invariant lints over this repo's own
//!   sources (`sagebwd analyze`, tier-1 test): determinism, hot-loop
//!   allocation, panic-policy ratchet, unsafe audit, schema drift
//!   (DESIGN.md §13).
//! * [`tensor`], [`util`], [`telemetry`], [`cli`], [`bench`] — substrates
//!   built in-repo (offline environment: no serde/clap/criterion/rand).

pub mod analysis;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod kernels;
pub mod model;
pub mod registry;
pub mod runtime;
pub mod telemetry;
pub mod tensor;
pub mod util;

/// Repo-relative default artifact directory (override with `--artifacts`).
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";
/// Repo-relative default results directory (harness CSV output).
pub const DEFAULT_RESULTS_DIR: &str = "results";
