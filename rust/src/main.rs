//! `sagebwd` — leader entrypoint.
//!
//! ```text
//! sagebwd train   [--variant V --steps N --tps T ...]   one pretraining run
//! sagebwd table1  [--reps R]                            Table 1 σ sweep
//! sagebwd table2                                        Table 2 pseudo-quant trace
//! sagebwd ds-rms                                        §4.2 RMS magnitude probe
//! sagebwd fig1    [--steps N --tps-lo L --tps-hi H]     Figure 1 TPS grid
//! sagebwd fig4    [--steps N --tps-lo L --tps-hi H]     Figure 4 smoothing ablation
//! sagebwd fig23   [--quick]                             Figures 2–3 kernel speed
//! sagebwd fig56                                         Figures 5–6 per-layer error
//! sagebwd inspect --artifact NAME [--stats]             manifest / HLO op stats
//! sagebwd dist-train [--workers N --steps S --tps T]     data-parallel training
//! sagebwd noise-probe [--budget B --tps T]               §4.3 noise-injection probe
//! sagebwd grid run|status|resume --exp fig1|fig4 [...]   resumable registry grid
//! sagebwd plot --csv a.csv[,b.csv] | --run DIR[,DIR]     ASCII metric curves
//! sagebwd trace-report --run DIR | --file F.jsonl        span self-time table
//! sagebwd bench-check FILE.json                          BENCH_*.json schema check
//! sagebwd analyze [--deny-all --no-ratchet --root DIR]    invariant lints (§13)
//! ```
//!
//! Every harness takes `--backend native|xla` (default `native`:
//! in-process CPU kernels and the native training engine, no `artifacts/`
//! needed — DESIGN.md §4/§10).  `--backend xla` selects the AOT artifact
//! path for both trace/bench harnesses and training (`make artifacts`
//! first).  Only `dist-train` is still XLA-only (worker pools own PJRT
//! clients).

use anyhow::{bail, Context, Result};

use sagebwd::bench::Table;
use sagebwd::cli::Args;
use sagebwd::config::TrainConfig;
use sagebwd::coordinator::{supervisor, SupervisorConfig, TrainerFactory};
use sagebwd::experiments::{ds_rms, fig1_tps, fig23_speed, fig4_ablation, fig56_layers,
                           noise_probe, table1_sigma, table2_trace};
use sagebwd::registry::{orchestrator, Registry, RunState};
use sagebwd::runtime::{make_backend, Runtime};
use sagebwd::telemetry::{qerr, run_dir, trace, Log};
use sagebwd::util::json::Json;
use sagebwd::{DEFAULT_ARTIFACTS_DIR, DEFAULT_RESULTS_DIR};

const USAGE: &str = "usage: sagebwd <train|dist-train|table1|table2|ds-rms|fig1|fig4|fig23|fig56|noise-probe|grid|plot|trace-report|inspect|bench-check|analyze> [options]
static analysis (DESIGN.md §13):
  sagebwd analyze [--deny-all] [--no-ratchet] [--root DIR]
                  [--write-baseline]
  runs the five invariant lints (A1 determinism, A2 hot-loop allocation,
  A3 panic-policy ratchet, A4 unsafe audit, A5 schema drift) over the
  repo's own sources; exits nonzero on any violation (--deny-all is the
  explicit CI spelling of the same contract); a drop in A3 counts
  auto-tightens analysis/baseline.json unless --no-ratchet
common options:
  --backend native|xla   executor for every harness, training included
                         (default native: in-process CPU kernels + native
                         training engine, no artifacts needed; xla: AOT
                         artifacts under --artifacts)
  --artifacts DIR        artifact directory for the xla backend
                         (default artifacts/, built by `make artifacts`)
  --results DIR          output directory (default results/)
  --fresh                retrain cells whose registry manifests are already
                         finished (fig1 / fig4 / noise-probe / grid)
observability (DESIGN.md §14):
  --trace                hierarchical span timers + arena/backend counters
                         (or SAGEBWD_TRACE=1); emits sagebwd-trace-v1 JSONL
                         per run; never perturbs numerics, one thread-local
                         branch when off
  --qerr-every N         on every Nth step, compare the seven INT8 attention
                         matmuls against the FP path and record qerr_* /
                         qerr_*_cos metric series (0 = off, the default)
  sagebwd trace-report --run DIR | --file F.jsonl
                         render the aggregated span self-time table from a
                         recorded trace.jsonl
grid orchestrator (DESIGN.md §12):
  sagebwd grid run    --exp fig1|fig4 [--budget B --tps-lo L --tps-hi H
                      --lr LR --seeds 0,1,... --jobs J --limit N --fresh]
  sagebwd grid status --exp ... same grid options; prints each cell's
                      registry state without executing anything
  sagebwd grid resume same as run, but errors if no registry exists yet
  finished cells (complete or diverged) are skipped by key; --jobs J runs
  J cells concurrently, splitting the SAGEBWD_THREADS budget between them
  --retry-diverged    re-queue cells whose manifests finished diverged and
                      run them under the supervisor (complete cells stay
                      skipped); implies --max-recoveries 2 unless given
fault-tolerant supervisor (DESIGN.md §16; train and grid):
  --save-every N         crash-safe checkpoint every N steps into the run
                         registry; rerunning the same config resumes from
                         the newest readable checkpoint, bitwise-identical
                         to an uninterrupted run
  --max-recoveries K     on divergence (or a failed step), roll back to the
                         last good checkpoint and apply the intervention
                         ladder, up to K rollbacks per run; every attempt
                         is recorded in the run manifest (0 = off)
  --lr-backoff G         peak-LR multiplier for the ladder's `lr` stage,
                         in (0,1) (default 0.5)
  --ladder S1,S2,...     intervention order from {lr, tps, arm}
                         (default lr,tps,arm: back off LR, then halve
                         tokens/step, then escalate the model arm)
environment:
  SAGEBWD_THREADS=N      worker threads for the native compute engine
                         (default: available parallelism; 0 or 1 forces
                         the serial path; results are bitwise-identical
                         at any setting)
  SAGEBWD_ISA=T          SIMD tier for the GEMM micro-kernels: scalar,
                         avx2, or fma (DESIGN.md §15; default
                         min(hardware, avx2); requests above the
                         hardware clamp down; scalar and avx2 are
                         bitwise-identical, fma is opt-in and may round
                         differently; INT8 is bitwise at any setting)
  SAGEBWD_FAULTS=PLAN    seeded fault injection for exercising the
                         supervisor (DESIGN.md §16), e.g.
                         \"seed=1; panic@3; torn@1; nan@5[:wq]\":
                         worker panic at step 3, first artifact write
                         torn, NaN-poisoned grads at step 5 (optionally
                         only leaves matching a substring); each clause
                         fires once, then retires
training subcommands (train, fig1, fig4, noise-probe, grid) run on either
backend; only dist-train still requires --backend xla; run `make results` to
regenerate every table and figure; `bench-check FILE.json` validates a
BENCH_*.json perf-trajectory file emitted by the cargo bench harnesses";

/// Default fig1/fig4 peak LR on the **native** engine — the regime where
/// the no-QK-norm arm visibly crosses the max_attn_logit ceiling while
/// QK-norm arms train cleanly (validated in
/// python/compile/check_native_model.py --sim).  The XLA engine keeps
/// the historical 3e-3 default: it was never validated at 0.1 and cannot
/// observe the logit ceiling (max_attn_logit: None), so divergence there
/// would only surface as a late non-finite loss.
fn fig_default_lr(backend: &str) -> f64 {
    if backend == "native" { 0.1 } else { 3e-3 }
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    // Observability knobs are global process state, deliberately *not*
    // TrainConfig fields — registry run keys (config hashes) and resume
    // byte-identity are unchanged whether tracing is on or off.
    let trace_env = std::env::var("SAGEBWD_TRACE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    if args.flag("trace") || trace_env {
        trace::set_enabled(true);
    }
    qerr::set_every(args.u64_or("qerr-every", 0)?);
    // Arm the deterministic fault-injection plane (DESIGN.md §16) from
    // SAGEBWD_FAULTS, erroring on a malformed plan up front.  Like the
    // trace/qerr knobs this is process state, not config: run keys and
    // recorded numerics are unchanged by an (un)armed plan — faults only
    // decide *whether* a step fails, never what a healthy step computes.
    sagebwd::util::faults::install_from_env()?;
    let artifacts = args.str_or("artifacts", DEFAULT_ARTIFACTS_DIR).to_string();
    let results = args.str_or("results", DEFAULT_RESULTS_DIR).to_string();
    // Trace/bench harnesses run on either backend; the native CPU kernels
    // are the default so a fresh checkout needs no `make artifacts`.
    let backend = || make_backend(args.str_or("backend", "native"), &artifacts);
    // Training harnesses are engine-agnostic the same way: the factory
    // maps --backend to a native or XLA TrainEngine per run.
    let factory = || TrainerFactory::new(args.str_or("backend", "native"), &artifacts);

    match args.subcommand.as_str() {
        "train" => cmd_train(&args, factory()?, &results),
        "table1" => {
            let reps = args.u64_or("reps", 3)?;
            table1_sigma::run(backend()?.as_mut(), &results, reps)?;
            Ok(())
        }
        "table2" => {
            table2_trace::run(backend()?.as_mut(), &results)?;
            Ok(())
        }
        "ds-rms" => {
            ds_rms::run(backend()?.as_mut(), &results)?;
            Ok(())
        }
        "fig1" => {
            // Fixed token budget per cell (paper: 78B tokens at each TPS);
            // 8× TPS ratio preserved from the paper's 2.1M / 260K.
            let budget = args.u64_or("budget", 131_072)?;
            let tps_lo = args.u64_or("tps-lo", 1024)?;
            let tps_hi = args.u64_or("tps-hi", 8192)?;
            let peak_lr = args.f64_or("lr", fig_default_lr(args.str_or("backend", "native")))?;
            let seed = args.u64_or("seed", 0)?;
            fig1_tps::run(&factory()?, &results, budget, tps_lo, tps_hi, peak_lr, seed,
                          args.flag("fresh"))?;
            Ok(())
        }
        "fig4" => {
            let budget = args.u64_or("budget", 131_072)?;
            let tps_lo = args.u64_or("tps-lo", 1024)?;
            let tps_hi = args.u64_or("tps-hi", 8192)?;
            let peak_lr = args.f64_or("lr", fig_default_lr(args.str_or("backend", "native")))?;
            let seed = args.u64_or("seed", 0)?;
            fig4_ablation::run(&factory()?, &results, budget, tps_lo, tps_hi, peak_lr, seed,
                               args.flag("fresh"))?;
            Ok(())
        }
        "grid" => cmd_grid(&args, factory()?, &results),
        "fig23" => {
            fig23_speed::run(backend()?.as_mut(), &results, args.flag("quick"))?;
            Ok(())
        }
        "fig56" => {
            fig56_layers::run(backend()?.as_mut(), &results)?;
            Ok(())
        }
        "dist-train" => {
            // Data-parallel training demo: leader + N grad workers, each
            // owning a PJRT client — the one harness still XLA-only.
            if args.str_or("backend", "xla") != "xla" {
                bail!(
                    "`sagebwd dist-train` is data-parallel over PJRT worker clients and \
                     has no native-engine topology yet — run `make artifacts` and use \
                     --backend xla (single-process native training: `sagebwd train`)"
                );
            }
            let workers = args.usize_or("workers", 2)?;
            let cfg = TrainConfig {
                variant: args.str_or("variant", "sage_qknorm").to_string(),
                steps: args.u64_or("steps", 20)?,
                tokens_per_step: args.u64_or("tps", 2048)?,
                warmup_steps: args.u64_or("warmup", 2)?,
                peak_lr: args.f64_or("lr", 3e-3)?,
                min_lr_frac: 0.1,
                seed: args.u64_or("seed", 0)?,
                checkpoint_every: 0,
                log_every: args.u64_or("log-every", 5)?,
                clip_norm: 0.0,
                grad_noise_sigma: 0.0,
                ..TrainConfig::default()
            };
            let log = Log::new(true);
            let mut t = sagebwd::coordinator::distributed::DistTrainer::new(
                std::path::PathBuf::from(&artifacts), cfg, workers)?;
            let final_loss = t.run(&log)?;
            let dir = run_dir(&results, "dist_train")?;
            t.metrics.flush_csv(&dir)?;
            log.info(&format!("distributed final loss {final_loss:.4} → {}", dir.display()));
            Ok(())
        }
        "noise-probe" => {
            let budget = args.u64_or("budget", 65_536)?;
            let tps = args.u64_or("tps", 8192)?;
            let seed = args.u64_or("seed", 0)?;
            noise_probe::run(&factory()?, &results, budget, tps, seed, args.flag("fresh"))?;
            Ok(())
        }
        "plot" => cmd_plot(&args),
        "trace-report" => cmd_trace_report(&args),
        "analyze" => cmd_analyze(&args),
        "bench-check" => {
            let path = args
                .opt("file")
                .map(|s| s.to_string())
                .or_else(|| args.positional.first().cloned())
                .ok_or_else(|| {
                    anyhow::anyhow!("usage: sagebwd bench-check FILE.json (or --file FILE)")
                })?;
            let rows = sagebwd::bench::check_bench_json(std::path::Path::new(&path))?;
            println!("{path}: schema OK ({rows} rows)");
            Ok(())
        }
        "inspect" => {
            let name = args.require("artifact")?;
            let mut runtime = Runtime::new(artifacts.clone())?;
            let exe = runtime.load(name)?;
            let m = &exe.manifest;
            println!("artifact: {}", m.artifact);
            println!("inputs ({}):", m.inputs.len());
            for s in &m.inputs {
                println!("  {:<24} {:?} {:?}", s.name, s.dtype, s.shape);
            }
            println!("outputs ({}):", m.outputs.len());
            for s in &m.outputs {
                println!("  {:<24} {:?} {:?}", s.name, s.dtype, s.shape);
            }
            println!("input bytes: {}", m.input_bytes());
            if args.flag("stats") {
                let stats = sagebwd::runtime::hlo_inspect::analyze_file(
                    std::path::Path::new(&artifacts), name)?;
                println!("
HLO stats: {} ops, {} bytes, ~{} dot-output-FLOPs",
                         stats.total_ops, stats.bytes, stats.dot_flops);
                for (op, count) in stats.top(12) {
                    println!("  {op:<24} {count}");
                }
            }
            Ok(())
        }
        "" | "help" | "--help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
}

/// `plot --csv a.csv[,b.csv...]` renders explicit CSV files;
/// `plot --run DIR[,DIR...] [--series NAME]` renders one metric series
/// (default `train_loss`; e.g. `max_attn_logit` for fig1-style divergence
/// curves, `step_ms` for per-step wall time) from run directories written
/// by `Metrics::flush_csv`.
fn cmd_plot(args: &Args) -> Result<()> {
    let mut curves = Vec::new();
    if let Some(runs) = args.opt("run") {
        let series = args.str_or("series", "train_loss");
        for dir in runs.split(',') {
            let p = std::path::Path::new(dir).join(format!("{series}.csv"));
            let name = format!(
                "{}:{series}",
                std::path::Path::new(dir)
                    .file_name()
                    .map(|s| s.to_string_lossy().into_owned())
                    .unwrap_or_else(|| dir.to_string())
            );
            curves.push(sagebwd::telemetry::plot::load_csv(&p, &name)?);
        }
    } else {
        let csvs = args.require("csv").map_err(|_| {
            anyhow::anyhow!("plot needs --csv FILE[,FILE...] or --run DIR[,DIR...]")
        })?;
        for path in csvs.split(',') {
            let p = std::path::Path::new(path);
            let name = p
                .parent()
                .and_then(|d| d.file_name())
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| path.to_string());
            curves.push(sagebwd::telemetry::plot::load_csv(p, &name)?);
        }
    }
    println!("{}", sagebwd::telemetry::plot::render(&curves, 100, 24));
    Ok(())
}

/// `trace-report --run DIR | --file FILE.jsonl` — parse a recorded
/// `sagebwd-trace-v1` event log (strict schema: unknown keys/kinds and
/// count mismatches are errors) and render the aggregated self-time
/// table plus counters.
fn cmd_trace_report(args: &Args) -> Result<()> {
    let path = if let Some(run) = args.opt("run") {
        std::path::Path::new(run).join("trace.jsonl")
    } else if let Some(file) = args.opt("file") {
        std::path::PathBuf::from(file)
    } else {
        bail!("usage: sagebwd trace-report --run DIR | --file FILE.jsonl");
    };
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading trace {}", path.display()))?;
    let report = sagebwd::telemetry::trace::TraceReport::parse_jsonl(&text)?;
    print!("{}", report.render_table());
    Ok(())
}

/// `analyze` — the self-hosting invariant lints (DESIGN.md §13).  Any
/// violation exits nonzero; `--deny-all` is accepted as the explicit CI
/// spelling of that same contract.  `--write-baseline` (re)creates
/// `analysis/baseline.json` from the current tree — the bootstrap path;
/// day to day the ratchet only tightens it.
fn cmd_analyze(args: &Args) -> Result<()> {
    use sagebwd::analysis::{self, AnalyzeOptions};
    let root = std::path::PathBuf::from(args.str_or("root", "."));
    if args.flag("write-baseline") {
        let report = analysis::write_baseline(&root)?;
        println!(
            "baseline written: {} sites over {} files",
            report.a3_total,
            report.a3_counts.len()
        );
        return Ok(());
    }
    let opts = AnalyzeOptions {
        update_baseline: !args.flag("no-ratchet"),
    };
    let report = analysis::analyze(&root, &opts)?;
    for v in &report.violations {
        println!("{v}");
    }
    println!(
        "A3 sites: {} (baseline {}){}",
        report.a3_total,
        report.a3_baseline_total,
        if report.baseline_updated {
            ", baseline tightened"
        } else if report.baseline_tightened {
            ", ratchet can tighten"
        } else {
            ""
        }
    );
    println!(
        "{} violation(s) across {} files",
        report.violations.len(),
        report.files_scanned
    );
    if !report.violations.is_empty() {
        bail!("static analysis failed — see violations above");
    }
    Ok(())
}

/// `grid <run|status|resume>` — the resumable experiment orchestrator
/// over the content-addressed run registry (DESIGN.md §12).
fn cmd_grid(args: &Args, factory: TrainerFactory, results: &str) -> Result<()> {
    let action = args
        .positional
        .first()
        .map(String::as_str)
        .ok_or_else(|| anyhow::anyhow!("usage: sagebwd grid <run|status|resume> [options]"))?;
    let exp = args.str_or("exp", "fig1");
    let budget = args.u64_or("budget", 131_072)?;
    let tps_lo = args.u64_or("tps-lo", 1024)?;
    let tps_hi = args.u64_or("tps-hi", 8192)?;
    let peak_lr = args.f64_or("lr", fig_default_lr(args.str_or("backend", "native")))?;
    let seeds = orchestrator::parse_seeds(args.str_or("seeds", "0"))?;
    let jobs = args.usize_or("jobs", 1)?;
    let limit = args.usize_or("limit", 0)?;
    let retry_diverged = args.flag("retry-diverged");
    // --retry-diverged re-runs diverged cells under the supervisor so the
    // second attempt gets the recovery ladder; without an explicit
    // --max-recoveries it defaults to 2 rollbacks (otherwise the retry
    // would just diverge identically — same config, same seed).
    let save_every = args.u64_or("save-every", 0)?;
    let max_recoveries =
        args.u64_or("max-recoveries", if retry_diverged { 2 } else { 0 })?;
    let supervise = if save_every > 0 || max_recoveries > 0 {
        Some(SupervisorConfig {
            save_every,
            max_recoveries,
            lr_backoff: args.f64_or("lr-backoff", 0.5)?,
            ladder: supervisor::parse_ladder(args.str_or("ladder", "lr,tps,arm"))?,
            halt_after: None,
        })
    } else {
        None
    };
    let spec = orchestrator::grid_spec(exp, budget, tps_lo, tps_hi, peak_lr, &seeds)?;
    let registry_dir = std::path::Path::new(results).join("registry");

    match action {
        "status" => {
            if !registry_dir.is_dir() {
                println!("no registry under {results} — nothing recorded yet");
                return Ok(());
            }
            let registry = Registry::open(results)?;
            let statuses = orchestrator::status(&factory, &registry, &spec)?;
            let mut table = Table::new(&["cell", "key", "state"]);
            let mut pending = 0usize;
            for st in &statuses {
                let state = match st.state {
                    Some(s) => s.as_str().to_string(),
                    None => {
                        pending += 1;
                        "pending".to_string()
                    }
                };
                table.row(vec![st.label.clone(), st.key[..16].to_string(), state]);
            }
            println!("{}", table.render());
            let finished = statuses
                .iter()
                .filter(|s| s.state.map(RunState::is_finished).unwrap_or(false))
                .count();
            println!(
                "{exp} grid [{} backend]: {} cells, {finished} finished, {pending} pending, \
                 {} other",
                factory.backend_name(),
                statuses.len(),
                statuses.len() - finished - pending
            );
            Ok(())
        }
        "run" | "resume" => {
            if action == "resume" && !registry_dir.is_dir() {
                bail!(
                    "nothing to resume: no registry under {results} — \
                     start one with `sagebwd grid run`"
                );
            }
            let registry = Registry::open(results)?;
            let log = Log::new(true);
            let report = orchestrator::run(
                &factory,
                &registry,
                results,
                &spec,
                jobs,
                limit,
                args.flag("fresh"),
                retry_diverged,
                supervise,
                &log,
            )?;
            println!(
                "\n{exp} grid: {} cells — {} skipped (registry hits), {} ran, \
                 {} left pending, {} failed",
                report.total,
                report.skipped,
                report.ran,
                report.remaining,
                report.failed.len()
            );
            for (label, err) in &report.failed {
                eprintln!("FAILED {label}: {err}");
            }
            if !report.failed.is_empty() {
                bail!("{} grid cell(s) failed", report.failed.len());
            }
            Ok(())
        }
        other => bail!("unknown grid action {other:?}; usage: sagebwd grid <run|status|resume>"),
    }
}

fn cmd_train(args: &Args, factory: TrainerFactory, results: &str) -> Result<()> {
    let cfg = if let Some(path) = args.opt("config") {
        TrainConfig::load(std::path::Path::new(path))?
    } else {
        TrainConfig {
            variant: args.str_or("variant", "sage_qknorm").to_string(),
            steps: args.u64_or("steps", 100)?,
            tokens_per_step: args.u64_or("tps", 4096)?,
            warmup_steps: args.u64_or("warmup", 10)?,
            peak_lr: args.f64_or("lr", 3e-3)?,
            min_lr_frac: args.f64_or("min-lr-frac", 0.1)?,
            seed: args.u64_or("seed", 0)?,
            checkpoint_every: args.u64_or("checkpoint-every", 0)?,
            log_every: args.u64_or("log-every", 10)?,
            clip_norm: args.f64_or("clip-norm", 0.0)?,
            grad_noise_sigma: args.f64_or("grad-noise", 0.0)?,
            max_attn_logit_ceiling: args
                .f64_or("logit-ceiling", TrainConfig::default().max_attn_logit_ceiling)?,
        }
    };
    let run_name = args.str_or("run-name", &format!("train_{}_tps{}", cfg.variant, cfg.tokens_per_step)).to_string();
    let log = Log::new(args.flag("verbose"));

    // Fault-tolerant supervisor path (DESIGN.md §16): any supervisor knob
    // opts in.  Unlike the plain path the view dir is *stable* (not
    // versioned on collision) — a rerun of the same name is a resume, and
    // the registry keeps history content-addressed anyway.
    let save_every = args.u64_or("save-every", 0)?;
    let max_recoveries = args.u64_or("max-recoveries", 0)?;
    if save_every > 0 || max_recoveries > 0 {
        let sup = SupervisorConfig {
            save_every,
            max_recoveries,
            lr_backoff: args.f64_or("lr-backoff", 0.5)?,
            ladder: supervisor::parse_ladder(args.str_or("ladder", "lr,tps,arm"))?,
            halt_after: None,
        };
        let dir = std::path::Path::new(results).join("train").join(&run_name);
        let registry = Registry::open(results)?;
        let out = supervisor::run_supervised(
            &factory, &registry, "train", &run_name, &cfg, &sup, &dir, &log,
        )?;
        log.info(&format!(
            "done [supervised]: {:?}, final loss {:?}, {} recovery(ies){}  → {}",
            out.report.status,
            out.report.final_loss,
            out.recoveries.len(),
            out.resumed_from
                .map(|s| format!(", resumed from step {s}"))
                .unwrap_or_default(),
            dir.display()
        ));
        return Ok(());
    }

    // run_dir versions on collision (train_x, train_x_2, ...), so a rerun
    // never interleaves CSVs with an earlier run's directory.
    let dir = run_dir(results, &run_name)?;
    let registry = Registry::open(results)?;
    let mut config = cfg.to_json();
    config.set("backend", Json::from(factory.backend_name()));
    let mut run = registry.begin_run("train", &run_name, config)?;
    let mut trainer = factory.trainer(cfg.clone())?;
    let mut batches = trainer.make_batcher(512, 4)?;
    if trace::enabled() {
        trace::reset();
    }
    let report = match trainer.run(&mut batches, &log) {
        Ok(r) => r,
        Err(e) => {
            let _ = run.finish(RunState::Failed);
            return Err(e);
        }
    };
    run.record_metrics(&trainer.metrics, &dir)?;
    run.record_bytes(
        "config.json",
        cfg.to_json().to_string().as_bytes(),
        Some(&dir.join("config.json")),
    )?;
    let trace_summary = if trace::enabled() {
        let tr = trace::take_report();
        run.record_bytes(
            "trace.jsonl",
            tr.to_jsonl().as_bytes(),
            Some(&dir.join("trace.jsonl")),
        )?;
        Some(tr.summary_json())
    } else {
        None
    };
    trainer.save_checkpoint(&dir.join("final.ckpt"))?;
    run.record_file("final.ckpt", &dir.join("final.ckpt"))?;
    let mut summary = vec![
        (
            "final_loss",
            report.final_loss.map(Json::from).unwrap_or(Json::Null),
        ),
        ("steps_done", Json::from(report.steps_done as i64)),
        ("tokens_seen", Json::from(report.tokens_seen as i64)),
    ];
    if let Some(tr) = trace_summary {
        summary.push(("trace", tr));
    }
    run.set_summary(Json::from_pairs(summary));
    let key16 = run.key16().to_string();
    run.finish(match report.status {
        sagebwd::coordinator::RunStatus::Diverged { .. } => RunState::Diverged,
        sagebwd::coordinator::RunStatus::Completed => RunState::Complete,
    })?;
    log.info(&format!(
        "done [{} engine]: {:?}, final loss {:?}, curves in {} (registry run {key16})",
        trainer.engine_name(),
        report.status,
        report.final_loss,
        dir.display()
    ));
    Ok(())
}
