//! `sagebwd` — leader entrypoint.
//!
//! ```text
//! sagebwd train   [--variant V --steps N --tps T ...]   one pretraining run
//! sagebwd table1  [--reps R]                            Table 1 σ sweep
//! sagebwd table2                                        Table 2 pseudo-quant trace
//! sagebwd ds-rms                                        §4.2 RMS magnitude probe
//! sagebwd fig1    [--steps N --tps-lo L --tps-hi H]     Figure 1 TPS grid
//! sagebwd fig4    [--steps N --tps-lo L --tps-hi H]     Figure 4 smoothing ablation
//! sagebwd fig23   [--quick]                             Figures 2–3 kernel speed
//! sagebwd fig56                                         Figures 5–6 per-layer error
//! sagebwd inspect --artifact NAME [--stats]             manifest / HLO op stats
//! sagebwd dist-train [--workers N --steps S --tps T]     data-parallel training
//! sagebwd noise-probe [--budget B --tps T]               §4.3 noise-injection probe
//! sagebwd plot --csv a.csv[,b.csv] | --run DIR[,DIR]     ASCII metric curves
//! sagebwd bench-check FILE.json                          BENCH_*.json schema check
//! ```
//!
//! Every harness takes `--backend native|xla` (default `native`:
//! in-process CPU kernels and the native training engine, no `artifacts/`
//! needed — DESIGN.md §4/§10).  `--backend xla` selects the AOT artifact
//! path for both trace/bench harnesses and training (`make artifacts`
//! first).  Only `dist-train` is still XLA-only (worker pools own PJRT
//! clients).

use anyhow::{bail, Result};

use sagebwd::cli::Args;
use sagebwd::config::TrainConfig;
use sagebwd::coordinator::TrainerFactory;
use sagebwd::experiments::{ds_rms, fig1_tps, fig23_speed, fig4_ablation, fig56_layers,
                           noise_probe, table1_sigma, table2_trace};
use sagebwd::runtime::{make_backend, Runtime};
use sagebwd::telemetry::{run_dir, Log};
use sagebwd::{DEFAULT_ARTIFACTS_DIR, DEFAULT_RESULTS_DIR};

const USAGE: &str = "usage: sagebwd <train|dist-train|table1|table2|ds-rms|fig1|fig4|fig23|fig56|noise-probe|plot|inspect|bench-check> [options]
common options:
  --backend native|xla   executor for every harness, training included
                         (default native: in-process CPU kernels + native
                         training engine, no artifacts needed; xla: AOT
                         artifacts under --artifacts)
  --artifacts DIR        artifact directory for the xla backend
                         (default artifacts/, built by `make artifacts`)
  --results DIR          output directory (default results/)
environment:
  SAGEBWD_THREADS=N      worker threads for the native compute engine
                         (default: available parallelism; 0 or 1 forces
                         the serial path; results are bitwise-identical
                         at any setting)
training subcommands (train, fig1, fig4, noise-probe) run on either backend;
only dist-train still requires --backend xla; run `make results` to
regenerate every table and figure; `bench-check FILE.json` validates a
BENCH_*.json perf-trajectory file emitted by the cargo bench harnesses";

/// Default fig1/fig4 peak LR on the **native** engine — the regime where
/// the no-QK-norm arm visibly crosses the max_attn_logit ceiling while
/// QK-norm arms train cleanly (validated in
/// python/compile/check_native_model.py --sim).  The XLA engine keeps
/// the historical 3e-3 default: it was never validated at 0.1 and cannot
/// observe the logit ceiling (max_attn_logit: None), so divergence there
/// would only surface as a late non-finite loss.
fn fig_default_lr(backend: &str) -> f64 {
    if backend == "native" { 0.1 } else { 3e-3 }
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    let artifacts = args.str_or("artifacts", DEFAULT_ARTIFACTS_DIR).to_string();
    let results = args.str_or("results", DEFAULT_RESULTS_DIR).to_string();
    // Trace/bench harnesses run on either backend; the native CPU kernels
    // are the default so a fresh checkout needs no `make artifacts`.
    let backend = || make_backend(args.str_or("backend", "native"), &artifacts);
    // Training harnesses are engine-agnostic the same way: the factory
    // maps --backend to a native or XLA TrainEngine per run.
    let factory = || TrainerFactory::new(args.str_or("backend", "native"), &artifacts);

    match args.subcommand.as_str() {
        "train" => cmd_train(&args, factory()?, &results),
        "table1" => {
            let reps = args.u64_or("reps", 3)?;
            table1_sigma::run(backend()?.as_mut(), &results, reps)?;
            Ok(())
        }
        "table2" => {
            table2_trace::run(backend()?.as_mut(), &results)?;
            Ok(())
        }
        "ds-rms" => {
            ds_rms::run(backend()?.as_mut(), &results)?;
            Ok(())
        }
        "fig1" => {
            // Fixed token budget per cell (paper: 78B tokens at each TPS);
            // 8× TPS ratio preserved from the paper's 2.1M / 260K.
            let budget = args.u64_or("budget", 131_072)?;
            let tps_lo = args.u64_or("tps-lo", 1024)?;
            let tps_hi = args.u64_or("tps-hi", 8192)?;
            let peak_lr = args.f64_or("lr", fig_default_lr(args.str_or("backend", "native")))?;
            let seed = args.u64_or("seed", 0)?;
            fig1_tps::run(&factory()?, &results, budget, tps_lo, tps_hi, peak_lr, seed)?;
            Ok(())
        }
        "fig4" => {
            let budget = args.u64_or("budget", 131_072)?;
            let tps_lo = args.u64_or("tps-lo", 1024)?;
            let tps_hi = args.u64_or("tps-hi", 8192)?;
            let peak_lr = args.f64_or("lr", fig_default_lr(args.str_or("backend", "native")))?;
            let seed = args.u64_or("seed", 0)?;
            fig4_ablation::run(&factory()?, &results, budget, tps_lo, tps_hi, peak_lr, seed)?;
            Ok(())
        }
        "fig23" => {
            fig23_speed::run(backend()?.as_mut(), &results, args.flag("quick"))?;
            Ok(())
        }
        "fig56" => {
            fig56_layers::run(backend()?.as_mut(), &results)?;
            Ok(())
        }
        "dist-train" => {
            // Data-parallel training demo: leader + N grad workers, each
            // owning a PJRT client — the one harness still XLA-only.
            if args.str_or("backend", "xla") != "xla" {
                bail!(
                    "`sagebwd dist-train` is data-parallel over PJRT worker clients and \
                     has no native-engine topology yet — run `make artifacts` and use \
                     --backend xla (single-process native training: `sagebwd train`)"
                );
            }
            let workers = args.usize_or("workers", 2)?;
            let cfg = TrainConfig {
                variant: args.str_or("variant", "sage_qknorm").to_string(),
                steps: args.u64_or("steps", 20)?,
                tokens_per_step: args.u64_or("tps", 2048)?,
                warmup_steps: args.u64_or("warmup", 2)?,
                peak_lr: args.f64_or("lr", 3e-3)?,
                min_lr_frac: 0.1,
                seed: args.u64_or("seed", 0)?,
                checkpoint_every: 0,
                log_every: args.u64_or("log-every", 5)?,
                clip_norm: 0.0,
                grad_noise_sigma: 0.0,
                ..TrainConfig::default()
            };
            let log = Log::new(true);
            let mut t = sagebwd::coordinator::distributed::DistTrainer::new(
                std::path::PathBuf::from(&artifacts), cfg, workers)?;
            let final_loss = t.run(&log)?;
            let dir = run_dir(&results, "dist_train")?;
            t.metrics.flush_csv(&dir)?;
            log.info(&format!("distributed final loss {final_loss:.4} → {}", dir.display()));
            Ok(())
        }
        "noise-probe" => {
            let budget = args.u64_or("budget", 65_536)?;
            let tps = args.u64_or("tps", 8192)?;
            let seed = args.u64_or("seed", 0)?;
            noise_probe::run(&factory()?, &results, budget, tps, seed)?;
            Ok(())
        }
        "plot" => cmd_plot(&args),
        "bench-check" => {
            let path = args
                .opt("file")
                .map(|s| s.to_string())
                .or_else(|| args.positional.first().cloned())
                .ok_or_else(|| {
                    anyhow::anyhow!("usage: sagebwd bench-check FILE.json (or --file FILE)")
                })?;
            let rows = sagebwd::bench::check_bench_json(std::path::Path::new(&path))?;
            println!("{path}: schema OK ({rows} rows)");
            Ok(())
        }
        "inspect" => {
            let name = args.require("artifact")?;
            let mut runtime = Runtime::new(artifacts.clone())?;
            let exe = runtime.load(name)?;
            let m = &exe.manifest;
            println!("artifact: {}", m.artifact);
            println!("inputs ({}):", m.inputs.len());
            for s in &m.inputs {
                println!("  {:<24} {:?} {:?}", s.name, s.dtype, s.shape);
            }
            println!("outputs ({}):", m.outputs.len());
            for s in &m.outputs {
                println!("  {:<24} {:?} {:?}", s.name, s.dtype, s.shape);
            }
            println!("input bytes: {}", m.input_bytes());
            if args.flag("stats") {
                let stats = sagebwd::runtime::hlo_inspect::analyze_file(
                    std::path::Path::new(&artifacts), name)?;
                println!("
HLO stats: {} ops, {} bytes, ~{} dot-output-FLOPs",
                         stats.total_ops, stats.bytes, stats.dot_flops);
                for (op, count) in stats.top(12) {
                    println!("  {op:<24} {count}");
                }
            }
            Ok(())
        }
        "" | "help" | "--help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
}

/// `plot --csv a.csv[,b.csv...]` renders explicit CSV files;
/// `plot --run DIR[,DIR...] [--series NAME]` renders one metric series
/// (default `train_loss`; e.g. `max_attn_logit` for fig1-style divergence
/// curves, `step_ms` for per-step wall time) from run directories written
/// by `Metrics::flush_csv`.
fn cmd_plot(args: &Args) -> Result<()> {
    let mut curves = Vec::new();
    if let Some(runs) = args.opt("run") {
        let series = args.str_or("series", "train_loss");
        for dir in runs.split(',') {
            let p = std::path::Path::new(dir).join(format!("{series}.csv"));
            let name = format!(
                "{}:{series}",
                std::path::Path::new(dir)
                    .file_name()
                    .map(|s| s.to_string_lossy().into_owned())
                    .unwrap_or_else(|| dir.to_string())
            );
            curves.push(sagebwd::telemetry::plot::load_csv(&p, &name)?);
        }
    } else {
        let csvs = args.require("csv").map_err(|_| {
            anyhow::anyhow!("plot needs --csv FILE[,FILE...] or --run DIR[,DIR...]")
        })?;
        for path in csvs.split(',') {
            let p = std::path::Path::new(path);
            let name = p
                .parent()
                .and_then(|d| d.file_name())
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| path.to_string());
            curves.push(sagebwd::telemetry::plot::load_csv(p, &name)?);
        }
    }
    println!("{}", sagebwd::telemetry::plot::render(&curves, 100, 24));
    Ok(())
}

fn cmd_train(args: &Args, factory: TrainerFactory, results: &str) -> Result<()> {
    let cfg = if let Some(path) = args.opt("config") {
        TrainConfig::load(std::path::Path::new(path))?
    } else {
        TrainConfig {
            variant: args.str_or("variant", "sage_qknorm").to_string(),
            steps: args.u64_or("steps", 100)?,
            tokens_per_step: args.u64_or("tps", 4096)?,
            warmup_steps: args.u64_or("warmup", 10)?,
            peak_lr: args.f64_or("lr", 3e-3)?,
            min_lr_frac: args.f64_or("min-lr-frac", 0.1)?,
            seed: args.u64_or("seed", 0)?,
            checkpoint_every: args.u64_or("checkpoint-every", 0)?,
            log_every: args.u64_or("log-every", 10)?,
            clip_norm: args.f64_or("clip-norm", 0.0)?,
            grad_noise_sigma: args.f64_or("grad-noise", 0.0)?,
            max_attn_logit_ceiling: args
                .f64_or("logit-ceiling", TrainConfig::default().max_attn_logit_ceiling)?,
        }
    };
    let run_name = args.str_or("run-name", &format!("train_{}_tps{}", cfg.variant, cfg.tokens_per_step)).to_string();
    let log = Log::new(args.flag("verbose"));
    let mut trainer = factory.trainer(cfg.clone())?;
    let mut batches = trainer.make_batcher(512, 4)?;
    let report = trainer.run(&mut batches, &log)?;
    let dir = run_dir(results, &run_name)?;
    trainer.metrics.flush_csv(&dir)?;
    cfg.save(&dir.join("config.json"))?;
    trainer.save_checkpoint(&dir.join("final.ckpt"))?;
    log.info(&format!(
        "done [{} engine]: {:?}, final loss {:?}, curves in {}",
        trainer.engine_name(),
        report.status,
        report.final_loss,
        dir.display()
    ));
    Ok(())
}
