//! Native AdamW — the optimizer half of the native training engine.
//!
//! Hyperparameters and update rule mirror `python/compile/model.py`
//! (β₁=0.9, β₂=0.95, ε=1e-8, decoupled weight decay 0.1, bias
//! correction, no decay on any `*norm` γ).  Moments are stored as f32
//! tensors (checkpointable through the existing `Checkpoint` format);
//! the per-element update is computed in f64 — the same arithmetic the
//! numpy blueprint (`python/compile/check_native_model.py`) validates.

use anyhow::{bail, Result};

use crate::tensor::Tensor;

pub const ADAM_B1: f64 = 0.9;
pub const ADAM_B2: f64 = 0.95;
pub const ADAM_EPS: f64 = 1e-8;
pub const WEIGHT_DECAY: f64 = 0.1;

/// AdamW state for a flat parameter list.
pub struct AdamW {
    m: Vec<Tensor>,
    v: Vec<Tensor>,
    /// Per-leaf decoupled weight decay (0 for norm γ leaves).
    decay: Vec<f64>,
}

impl AdamW {
    /// Zero-initialized moments for the given schema.  Leaves whose name
    /// ends in `norm` (attn/mlp/final/q/k norms) are exempt from decay.
    pub fn new(names: &[String], shapes: &[Vec<usize>]) -> AdamW {
        AdamW {
            m: shapes.iter().map(|s| Tensor::zeros(s)).collect(),
            v: shapes.iter().map(|s| Tensor::zeros(s)).collect(),
            decay: names
                .iter()
                .map(|n| if n.ends_with("norm") { 0.0 } else { WEIGHT_DECAY })
                .collect(),
        }
    }

    /// One optimizer step.  `step` is 1-based (bias correction).
    pub fn apply(
        &mut self,
        params: &mut [Tensor],
        grads: &[Tensor],
        lr: f64,
        step: u64,
    ) -> Result<()> {
        if params.len() != self.m.len() || grads.len() != self.m.len() {
            bail!(
                "AdamW has {} leaves, got {} params / {} grads",
                self.m.len(),
                params.len(),
                grads.len()
            );
        }
        if step == 0 {
            bail!("AdamW step is 1-based");
        }
        let c1 = 1.0 - ADAM_B1.powi(step as i32);
        let c2 = 1.0 - ADAM_B2.powi(step as i32);
        for (((p, g), (m, v)), &decay) in params
            .iter_mut()
            .zip(grads)
            .zip(self.m.iter_mut().zip(self.v.iter_mut()))
            .zip(&self.decay)
        {
            if p.shape != g.shape || p.shape != m.shape {
                bail!(
                    "AdamW shape mismatch: param {:?} grad {:?} moment {:?}",
                    p.shape,
                    g.shape,
                    m.shape
                );
            }
            for (((pv, &gv), mv), vv) in p
                .data
                .iter_mut()
                .zip(&g.data)
                .zip(m.data.iter_mut())
                .zip(v.data.iter_mut())
            {
                let g64 = gv as f64;
                let m_n = ADAM_B1 * (*mv as f64) + (1.0 - ADAM_B1) * g64;
                let v_n = ADAM_B2 * (*vv as f64) + (1.0 - ADAM_B2) * g64 * g64;
                *mv = m_n as f32;
                *vv = v_n as f32;
                let update = ((*mv as f64) / c1) / (((*vv as f64) / c2).sqrt() + ADAM_EPS);
                *pv = ((*pv as f64) - lr * (update + decay * (*pv as f64))) as f32;
            }
        }
        Ok(())
    }

    /// Moment tensors (checkpointing).
    pub fn state(&self) -> (&[Tensor], &[Tensor]) {
        (&self.m, &self.v)
    }

    /// Restore moments saved by [`Self::state`].
    pub fn load_state(&mut self, m: Vec<Tensor>, v: Vec<Tensor>) -> Result<()> {
        if m.len() != self.m.len() || v.len() != self.v.len() {
            bail!(
                "AdamW restore: {} leaves, got m={} v={}",
                self.m.len(),
                m.len(),
                v.len()
            );
        }
        for ((cur, new_m), new_v) in self.m.iter().zip(&m).zip(&v) {
            if cur.shape != new_m.shape || cur.shape != new_v.shape {
                bail!(
                    "AdamW restore shape mismatch: {:?} vs m {:?} / v {:?}",
                    cur.shape,
                    new_m.shape,
                    new_v.shape
                );
            }
        }
        self.m = m;
        self.v = v;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn names(decayed: &str, norm: &str) -> Vec<String> {
        vec![decayed.to_string(), norm.to_string()]
    }

    #[test]
    fn first_step_moves_against_gradient_by_lr() {
        // With bias correction, step 1 update is g/(|g|+ε) ≈ sign(g).
        let mut opt = AdamW::new(&names("w", "x_norm"), &[vec![2], vec![2]]);
        let mut params = vec![
            Tensor::from_vec(&[2], vec![0.0, 0.0]).unwrap(),
            Tensor::from_vec(&[2], vec![1.0, 1.0]).unwrap(),
        ];
        let grads = vec![
            Tensor::from_vec(&[2], vec![0.5, -2.0]).unwrap(),
            Tensor::from_vec(&[2], vec![1.0, -1.0]).unwrap(),
        ];
        opt.apply(&mut params, &grads, 0.1, 1).unwrap();
        assert!((params[0].data[0] - (-0.1)).abs() < 1e-4);
        assert!((params[0].data[1] - 0.1).abs() < 1e-4);
        // norm leaf: same sign-step, no decay term
        assert!((params[1].data[0] - 0.9).abs() < 1e-4);
    }

    #[test]
    fn weight_decay_only_on_non_norm_leaves() {
        let mut opt = AdamW::new(&names("w", "g_norm"), &[vec![1], vec![1]]);
        let mut params = vec![
            Tensor::from_vec(&[1], vec![10.0]).unwrap(),
            Tensor::from_vec(&[1], vec![10.0]).unwrap(),
        ];
        let grads = vec![Tensor::zeros(&[1]), Tensor::zeros(&[1])];
        opt.apply(&mut params, &grads, 0.1, 1).unwrap();
        // zero grad ⟹ pure decay: w ← w(1 − lr·0.1)
        assert!((params[0].data[0] - 10.0 * (1.0 - 0.1 * 0.1) as f32).abs() < 1e-5);
        assert_eq!(params[1].data[0], 10.0);
    }

    #[test]
    fn moments_accumulate_and_roundtrip() {
        let mut opt = AdamW::new(&names("w", "b_norm"), &[vec![3], vec![1]]);
        let mut params = vec![Tensor::zeros(&[3]), Tensor::zeros(&[1])];
        let grads = vec![
            Tensor::from_vec(&[3], vec![1.0, -1.0, 0.5]).unwrap(),
            Tensor::from_vec(&[1], vec![0.2]).unwrap(),
        ];
        for step in 1..=3 {
            opt.apply(&mut params, &grads, 1e-2, step).unwrap();
        }
        let (m, v) = opt.state();
        assert!(m[0].data[0] > 0.0 && v[0].data[0] > 0.0);
        let (m_saved, v_saved) = (m.to_vec(), v.to_vec());
        let mut opt2 = AdamW::new(&names("w", "b_norm"), &[vec![3], vec![1]]);
        opt2.load_state(m_saved, v_saved).unwrap();
        let mut p2 = params.clone();
        opt.apply(&mut params, &grads, 1e-2, 4).unwrap();
        opt2.apply(&mut p2, &grads, 1e-2, 4).unwrap();
        assert_eq!(params[0].data, p2[0].data);
    }

    #[test]
    fn mismatches_rejected() {
        let mut opt = AdamW::new(&names("w", "b_norm"), &[vec![2], vec![1]]);
        let mut params = vec![Tensor::zeros(&[2]), Tensor::zeros(&[1])];
        let grads = vec![Tensor::zeros(&[2])];
        assert!(opt.apply(&mut params, &grads, 0.1, 1).is_err());
        let grads = vec![Tensor::zeros(&[3]), Tensor::zeros(&[1])];
        assert!(opt.apply(&mut params, &grads, 0.1, 1).is_err());
        let ok = vec![Tensor::zeros(&[2]), Tensor::zeros(&[1])];
        assert!(opt.apply(&mut params, &ok, 0.1, 0).is_err()); // 0-based step
        assert!(opt.load_state(vec![Tensor::zeros(&[2])], vec![]).is_err());
    }
}
