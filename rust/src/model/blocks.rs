//! Manual forward/backward building blocks for the native model.
//!
//! Every function here is formula-identical to its numpy twin in
//! `python/compile/check_native_model.py`, which documents the observed
//! finite-difference error of each backward pass; the tolerances in
//! `rust/tests/model_gradcheck.rs` are ≥3× those margins.
//!
//! Conventions: activations are 2-D `(R, ·)` tensors with `R = microbatch
//! × seq_len` flattened rows; backward functions return gradients in the
//! same order as their forward inputs.

use anyhow::{bail, Result};

use crate::tensor::{linalg, Tensor, Workspace};

// ---------------------------------------------------------------------------
// RMSNorm (also used as QK-norm at head width, §4.1)
// ---------------------------------------------------------------------------

/// Residuals saved by [`rmsnorm_fwd`] for the backward pass.
pub struct RmsNormCache {
    x: Tensor,
    /// Per-row `1/√(mean(x²)+ε)`.
    r: Vec<f32>,
}

/// `y[i,:] = x[i,:] · r_i · γ` with `r_i = 1/√(mean(x[i,:]²)+ε)`.
pub fn rmsnorm_fwd(x: &Tensor, gamma: &Tensor, eps: f32) -> Result<(Tensor, RmsNormCache)> {
    let (rows, d) = x.dims2()?;
    if gamma.shape != [d] {
        bail!("rmsnorm γ shape {:?} != [{d}]", gamma.shape);
    }
    let mut y = Tensor::zeros(&[rows, d]);
    let mut r = vec![0f32; rows];
    for i in 0..rows {
        let xr = &x.data[i * d..(i + 1) * d];
        let ms = xr.iter().map(|&v| v * v).sum::<f32>() / d as f32;
        let ri = 1.0 / (ms + eps).sqrt();
        r[i] = ri;
        for (o, (&xv, &g)) in y.data[i * d..(i + 1) * d]
            .iter_mut()
            .zip(xr.iter().zip(&gamma.data))
        {
            *o = xv * ri * g;
        }
    }
    Ok((y, RmsNormCache { x: x.clone(), r }))
}

/// Backward: returns `(dx, dγ)`.
///
/// With `w = dy∘γ`:  `dx = w·r − x·r³·(w·x)/D`,  `dγ = Σ_rows dy∘x·r`.
pub fn rmsnorm_bwd(dy: &Tensor, gamma: &Tensor, cache: &RmsNormCache) -> Result<(Tensor, Tensor)> {
    let (rows, d) = cache.x.dims2()?;
    if dy.shape != cache.x.shape {
        bail!("rmsnorm dy shape {:?} != {:?}", dy.shape, cache.x.shape);
    }
    let mut dx = Tensor::zeros(&[rows, d]);
    let mut dgamma = Tensor::zeros(&[d]);
    for i in 0..rows {
        let xr = &cache.x.data[i * d..(i + 1) * d];
        let dyr = &dy.data[i * d..(i + 1) * d];
        let ri = cache.r[i];
        let mut wx = 0f32;
        for ((&dyv, &xv), &g) in dyr.iter().zip(xr).zip(&gamma.data) {
            wx += dyv * g * xv;
        }
        let coef = ri * ri * ri * wx / d as f32;
        for (j, ((&dyv, &xv), o)) in dyr
            .iter()
            .zip(xr)
            .zip(dx.data[i * d..(i + 1) * d].iter_mut())
            .enumerate()
        {
            *o = dyv * gamma.data[j] * ri - xv * coef;
            dgamma.data[j] += dyv * xv * ri;
        }
    }
    Ok((dx, dgamma))
}

// ---------------------------------------------------------------------------
// SwiGLU MLP
// ---------------------------------------------------------------------------

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[inline]
pub fn silu(x: f32) -> f32 {
    x * sigmoid(x)
}

/// `d/dx silu(x) = σ(x)·(1 + x·(1−σ(x)))`.
#[inline]
pub fn silu_grad(x: f32) -> f32 {
    let s = sigmoid(x);
    s * (1.0 + x * (1.0 - s))
}

/// Residuals saved by [`mlp_fwd`].
pub struct MlpCache {
    y: Tensor,
    g: Tensor,
    u: Tensor,
    h: Tensor,
}

/// `out = (silu(y·W_gate) ∘ (y·W_up)) · W_down`.
pub fn mlp_fwd(
    y: &Tensor,
    w_gate: &Tensor,
    w_up: &Tensor,
    w_down: &Tensor,
) -> Result<(Tensor, MlpCache)> {
    let g = y.matmul(w_gate)?;
    let u = y.matmul(w_up)?;
    let mut h = Tensor::zeros(&g.shape);
    for ((o, &gv), &uv) in h.data.iter_mut().zip(&g.data).zip(&u.data) {
        *o = silu(gv) * uv;
    }
    let out = h.matmul(w_down)?;
    Ok((
        out,
        MlpCache {
            y: y.clone(),
            g,
            u,
            h,
        },
    ))
}

/// Backward: returns `(dy, dW_gate, dW_up, dW_down)`.
///
/// The dH/dG/dU intermediates come from (and return to) the caller's
/// [`Workspace`], so the training hot loop runs this allocation-free for
/// everything that does not escape as a gradient.
pub fn mlp_bwd(
    dout: &Tensor,
    cache: &MlpCache,
    w_gate: &Tensor,
    w_up: &Tensor,
    w_down: &Tensor,
    ws: &mut Workspace,
) -> Result<(Tensor, Tensor, Tensor, Tensor)> {
    let dw_down = cache.h.matmul_tn(dout)?;
    let (rows, d_model) = dout.dims2()?;
    let (_, d_ff) = cache.g.dims2()?;
    let (w_dff, w_dmodel) = w_down.dims2()?;
    if w_dff != d_ff || w_dmodel != d_model {
        bail!("mlp_bwd: W_down {:?} vs dout {:?} / g {:?}", w_down.shape, dout.shape, cache.g.shape);
    }
    let mut dh = ws.take_tensor(&[rows, d_ff]);
    linalg::matmul_nt_into(&dout.data, &w_down.data, rows, d_model, d_ff, &mut dh.data);
    let mut dg = ws.take_tensor(&cache.g.shape);
    let mut du = ws.take_tensor(&cache.u.shape);
    for (((odg, odu), (&dhv, &gv)), &uv) in dg
        .data
        .iter_mut()
        .zip(du.data.iter_mut())
        .zip(dh.data.iter().zip(&cache.g.data))
        .zip(&cache.u.data)
    {
        *odu = dhv * silu(gv);
        *odg = dhv * uv * silu_grad(gv);
    }
    let dw_gate = cache.y.matmul_tn(&dg)?;
    let dw_up = cache.y.matmul_tn(&du)?;
    let mut dy = dg.matmul_nt(w_gate)?;
    dy.add_assign(&du.matmul_nt(w_up)?);
    ws.give_tensor(du);
    ws.give_tensor(dg);
    ws.give_tensor(dh);
    Ok((dy, dw_gate, dw_up, dw_down))
}

// ---------------------------------------------------------------------------
// Token embedding (gather / scatter-add)
// ---------------------------------------------------------------------------

/// `x[r,:] = embed[ids[r],:]`.
pub fn gather_rows(embed: &Tensor, ids: &[i32]) -> Result<Tensor> {
    let (v, d) = embed.dims2()?;
    let mut out = Tensor::zeros(&[ids.len(), d]);
    for (r, &id) in ids.iter().enumerate() {
        if id < 0 || id as usize >= v {
            bail!("token id {id} out of vocab range [0, {v})");
        }
        let src = id as usize * d;
        out.data[r * d..(r + 1) * d].copy_from_slice(&embed.data[src..src + d]);
    }
    Ok(out)
}

/// `dembed[ids[r],:] += dx[r,:]` — the gather's backward.
pub fn scatter_add_rows(dembed: &mut Tensor, ids: &[i32], dx: &Tensor) -> Result<()> {
    let (v, d) = dembed.dims2()?;
    let (rows, d2) = dx.dims2()?;
    if rows != ids.len() || d2 != d {
        bail!(
            "scatter_add: dx {:?} vs {} ids × width {d}",
            dx.shape,
            ids.len()
        );
    }
    for (r, &id) in ids.iter().enumerate() {
        if id < 0 || id as usize >= v {
            bail!("token id {id} out of vocab range [0, {v})");
        }
        let dst = id as usize * d;
        for (o, &x) in dembed.data[dst..dst + d].iter_mut().zip(&dx.data[r * d..]) {
            *o += x;
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Tied-embedding cross-entropy head
// ---------------------------------------------------------------------------

/// Residuals saved by [`cross_entropy_fwd`].
pub struct CeCache {
    f: Tensor,
    /// Row-softmax of the logits.
    p: Tensor,
    targets: Vec<i32>,
}

/// `logits = f · embedᵀ`; mean next-token cross-entropy over all rows.
pub fn cross_entropy_fwd(f: &Tensor, embed: &Tensor, targets: &[i32]) -> Result<(f64, CeCache)> {
    let (rows, _d) = f.dims2()?;
    let (v, _) = embed.dims2()?;
    if targets.len() != rows {
        bail!("{} targets for {rows} rows", targets.len());
    }
    let logits = f.matmul_nt(embed)?;
    let (p, lse) = logits.softmax_rows()?;
    let mut loss = 0f64;
    for (r, &t) in targets.iter().enumerate() {
        if t < 0 || t as usize >= v {
            bail!("target id {t} out of vocab range [0, {v})");
        }
        loss += (lse[r] - logits.data[r * v + t as usize]) as f64;
    }
    loss /= rows as f64;
    Ok((
        loss,
        CeCache {
            f: f.clone(),
            p,
            targets: targets.to_vec(),
        },
    ))
}

/// Backward: returns `(df, dembed)` where `dembed` is the tied head's
/// contribution only (the gather contribution is added separately).
pub fn cross_entropy_bwd(cache: &CeCache, embed: &Tensor) -> Result<(Tensor, Tensor)> {
    let (rows, v) = cache.p.dims2()?;
    let mut dlogits = cache.p.clone();
    let inv = 1.0 / rows as f32;
    for (r, &t) in cache.targets.iter().enumerate() {
        dlogits.data[r * v + t as usize] -= 1.0;
    }
    dlogits.scale(inv);
    let df = dlogits.matmul(embed)?;
    let dembed = dlogits.matmul_tn(&cache.f)?;
    Ok((df, dembed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn rmsnorm_unit_gamma_normalizes_rows() {
        let mut rng = Pcg64::new(1, 0);
        let x = Tensor::randn(&[5, 8], 3.0, &mut rng);
        let mut gamma = Tensor::zeros(&[8]);
        gamma.fill(1.0);
        let (y, _) = rmsnorm_fwd(&x, &gamma, 1e-6).unwrap();
        for row in y.data.chunks(8) {
            let rms = (row.iter().map(|&v| v * v).sum::<f32>() / 8.0).sqrt();
            assert!((rms - 1.0).abs() < 1e-3, "row rms {rms}");
        }
    }

    #[test]
    fn rmsnorm_bwd_row_identity() {
        // dS rows of a normalized vector are orthogonal to x: x·dx ≈ 0
        // when dy ⊥ scaling direction is removed — check the cheap
        // invariant instead: scaling x leaves y (γ=1) unchanged, so dx of
        // a scaled input shrinks by the same factor.
        let mut rng = Pcg64::new(2, 0);
        let x = Tensor::randn(&[3, 8], 1.0, &mut rng);
        let mut x2 = x.clone();
        x2.scale(2.0);
        let mut gamma = Tensor::zeros(&[8]);
        gamma.fill(1.0);
        let dy = Tensor::randn(&[3, 8], 1.0, &mut Pcg64::new(3, 0));
        let (y1, c1) = rmsnorm_fwd(&x, &gamma, 0.0).unwrap();
        let (y2, c2) = rmsnorm_fwd(&x2, &gamma, 0.0).unwrap();
        assert!(y1.rel_l2(&y2) < 1e-5, "rmsnorm not scale-invariant");
        let (dx1, _) = rmsnorm_bwd(&dy, &gamma, &c1).unwrap();
        let (dx2, _) = rmsnorm_bwd(&dy, &gamma, &c2).unwrap();
        let mut half = dx1.clone();
        half.scale(0.5);
        assert!(half.rel_l2(&dx2) < 1e-4, "dx must scale as 1/|x|");
    }

    #[test]
    fn silu_matches_reference_points() {
        assert!((silu(0.0) - 0.0).abs() < 1e-7);
        assert!((silu(10.0) - 10.0 * (1.0 / (1.0 + (-10f32).exp()))).abs() < 1e-5);
        // numeric derivative spot check
        for &x in &[-2.0f32, -0.3, 0.0, 0.7, 3.0] {
            let eps = 1e-3;
            let num = (silu(x + eps) - silu(x - eps)) / (2.0 * eps);
            assert!((num - silu_grad(x)).abs() < 1e-3, "silu'({x})");
        }
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let mut rng = Pcg64::new(4, 0);
        let embed = Tensor::randn(&[10, 4], 1.0, &mut rng);
        let ids = [3i32, 0, 3, 9];
        let x = gather_rows(&embed, &ids).unwrap();
        assert_eq!(x.shape, vec![4, 4]);
        assert_eq!(&x.data[0..4], &embed.data[12..16]);
        let mut d = Tensor::zeros(&[10, 4]);
        let mut dx = Tensor::zeros(&[4, 4]);
        dx.fill(1.0);
        scatter_add_rows(&mut d, &ids, &dx).unwrap();
        // row 3 appears twice → accumulates 2.0
        assert_eq!(d.data[3 * 4], 2.0);
        assert_eq!(d.data[0], 1.0);
        assert_eq!(d.data[9 * 4], 1.0);
        assert_eq!(d.data[4], 0.0); // row 1 untouched
        assert!(gather_rows(&embed, &[10]).is_err());
        assert!(gather_rows(&embed, &[-1]).is_err());
    }

    #[test]
    fn cross_entropy_uniform_logits_is_log_v() {
        // f = 0 → logits all 0 → loss = ln(V) exactly.
        let f = Tensor::zeros(&[3, 4]);
        let mut rng = Pcg64::new(5, 0);
        let embed = Tensor::randn(&[7, 4], 1.0, &mut rng);
        let (loss, _) = cross_entropy_fwd(&f, &embed, &[0, 3, 6]).unwrap();
        assert!((loss - (7f64).ln()).abs() < 1e-5, "loss {loss}");
    }

    #[test]
    fn cross_entropy_dlogits_rows_sum_to_zero() {
        let mut rng = Pcg64::new(6, 0);
        let f = Tensor::randn(&[4, 5], 1.0, &mut rng.split(0));
        let embed = Tensor::randn(&[9, 5], 1.0, &mut rng.split(1));
        let (_, cache) = cross_entropy_fwd(&f, &embed, &[1, 2, 0, 8]).unwrap();
        let (df, dembed) = cross_entropy_bwd(&cache, &embed).unwrap();
        assert_eq!(df.shape, vec![4, 5]);
        assert_eq!(dembed.shape, vec![9, 5]);
        // Σ_v dlogits[r, v] = 0 ⟹ Σ_v dembed columns weighted — use the
        // direct identity on p − onehot: sum of dembed over vocab rows
        // equals Σ_r (Σ_v dlogits[r,v]) f[r,:] = 0.
        for c in 0..5 {
            let col: f32 = (0..9).map(|r| dembed.data[r * 5 + c]).sum();
            assert!(col.abs() < 1e-5, "dembed col {c} sums to {col}");
        }
        assert!(cross_entropy_fwd(&f, &embed, &[1, 2]).is_err());
        assert!(cross_entropy_fwd(&f, &embed, &[1, 2, 0, 9]).is_err());
    }

    #[test]
    fn mlp_bwd_workspace_reuse_is_bitwise_stable() {
        let mut rng = Pcg64::new(8, 0);
        let y = Tensor::randn(&[4, 6], 1.0, &mut rng.split(0));
        let w_gate = Tensor::randn(&[6, 10], 0.3, &mut rng.split(1));
        let w_up = Tensor::randn(&[6, 10], 0.3, &mut rng.split(2));
        let w_down = Tensor::randn(&[10, 6], 0.3, &mut rng.split(3));
        let dout = Tensor::randn(&[4, 6], 1.0, &mut rng.split(4));
        let (_, cache) = mlp_fwd(&y, &w_gate, &w_up, &w_down).unwrap();
        let mut ws = Workspace::new();
        let a = mlp_bwd(&dout, &cache, &w_gate, &w_up, &w_down, &mut ws).unwrap();
        assert_eq!(ws.pooled(), 3, "dh/dg/du must return to the pool");
        let b = mlp_bwd(&dout, &cache, &w_gate, &w_up, &w_down, &mut ws).unwrap();
        assert_eq!(a.0.data, b.0.data);
        assert_eq!(a.1.data, b.1.data);
        // Shape mismatch still rejected.
        assert!(mlp_bwd(&dout, &cache, &w_gate, &w_up, &y, &mut ws).is_err());
    }

    #[test]
    fn mlp_zero_gate_blocks_output() {
        let mut rng = Pcg64::new(7, 0);
        let y = Tensor::randn(&[3, 4], 1.0, &mut rng.split(0));
        let w_gate = Tensor::zeros(&[4, 6]); // silu(0) = 0 ⟹ out = 0
        let w_up = Tensor::randn(&[4, 6], 1.0, &mut rng.split(1));
        let w_down = Tensor::randn(&[6, 4], 1.0, &mut rng.split(2));
        let (out, _) = mlp_fwd(&y, &w_gate, &w_up, &w_down).unwrap();
        assert!(out.max_abs() < 1e-6);
    }
}
