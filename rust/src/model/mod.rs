//! Native training model: a small decoder-only transformer with **manual
//! forward/backward** in f32 — the subsystem that lets every training
//! experiment (`train`, `fig1`, `fig4`, `noise-probe`) run from a bare
//! checkout with no XLA artifacts (DESIGN.md §10).
//!
//! Architecture mirrors `python/compile/model.py` at the same substrate
//! scale (minus RoPE, which none of the paper's training-side claims
//! need):
//!
//! ```text
//! embed → [RMSNorm → MHA(optional QK-norm, causal, fpa|sage via
//!          runtime::AttentionBackend) → residual
//!          → RMSNorm → SwiGLU → residual] × L
//!       → RMSNorm → tied-embedding cross-entropy head
//! ```
//!
//! Attention is *routed through the existing [`AttentionBackend`] trait*
//! (artifact names `model_attn_*`, see `runtime::backend`), so the
//! FPA/SageBwd/smoothing kernel variants plug into training unchanged.
//! QK-norm (§4.1) is the per-token RMS normalization of Q and K with a
//! learned γ — the paper's claim (i) is that it is *necessary* at large
//! tokens-per-step because it bounds the attention logits and hence the
//! INT8 quantization step.
//!
//! Formula-identical numpy twin + finite-difference margins:
//! `python/compile/check_native_model.py`.
//!
//! [`AttentionBackend`]: crate::runtime::AttentionBackend

pub mod adamw;
pub mod blocks;
pub mod transformer;

pub use adamw::AdamW;
pub use transformer::{MicroOutput, Model};

use anyhow::{bail, Result};

use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

/// Dimensions of the native pre-training model.  The defaults are the
/// substrate scale every training harness uses (DESIGN.md §6): small
/// enough that a full fig1 grid runs on CPU in about a minute, large
/// enough that QK-norm / TPS dynamics are visible.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelDims {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    pub seq_len: usize,
    pub microbatch: usize,
    pub norm_eps: f32,
}

impl Default for ModelDims {
    fn default() -> ModelDims {
        ModelDims {
            vocab_size: 512, // matches the trained-BPE vocab the harnesses use
            d_model: 32,
            n_heads: 2,
            d_head: 16,
            d_ff: 64,
            n_layers: 2,
            seq_len: 32, // one SageBwd block: seq_len % block (32) == 0
            microbatch: 2,
            norm_eps: 1e-6,
        }
    }
}

impl ModelDims {
    pub fn validate(&self) -> Result<()> {
        if self.vocab_size == 0
            || self.d_model == 0
            || self.n_heads == 0
            || self.d_head == 0
            || self.d_ff == 0
            || self.n_layers == 0
            || self.seq_len == 0
            || self.microbatch == 0
        {
            bail!("all model dimensions must be non-zero: {self:?}");
        }
        if self.n_heads * self.d_head != self.d_model {
            bail!(
                "n_heads ({}) × d_head ({}) must equal d_model ({})",
                self.n_heads,
                self.d_head,
                self.d_model
            );
        }
        Ok(())
    }

    /// Tokens contributed by one microbatch.
    pub fn tokens_per_microbatch(&self) -> u64 {
        (self.microbatch * self.seq_len) as u64
    }
}

/// Which attention kernel the model routes through the backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttnImpl {
    /// Exact full-precision attention (the paper's FPA baseline).
    Fpa,
    /// SageBwd INT8 with K-smoothing (paper default).
    Sage,
    /// SageBwd without smoothing.
    SageNosm,
    /// SageBwd with Q+K smoothing.
    SageQksm,
}

impl AttnImpl {
    /// Token used in `model_attn_<impl>_...` artifact names.
    pub fn name(self) -> &'static str {
        match self {
            AttnImpl::Fpa => "fpa",
            AttnImpl::Sage => "sage",
            AttnImpl::SageNosm => "sage_nosm",
            AttnImpl::SageQksm => "sage_qksm",
        }
    }
}

/// Training variant = attention kernel + whether QK-norm is applied.
/// Parsed from the `config::VARIANTS` names the experiments use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AttnVariant {
    pub imp: AttnImpl,
    pub qk_norm: bool,
}

impl AttnVariant {
    pub fn parse(variant: &str) -> Result<AttnVariant> {
        let (imp, qk_norm) = match variant {
            "sage_qknorm" => (AttnImpl::Sage, true),
            "sage_noqknorm" => (AttnImpl::Sage, false),
            "fpa_qknorm" => (AttnImpl::Fpa, true),
            "fpa_noqknorm" => (AttnImpl::Fpa, false),
            "sage_qknorm_nosm" => (AttnImpl::SageNosm, true),
            "sage_qknorm_qksm" => (AttnImpl::SageQksm, true),
            other => bail!(
                "unknown training variant {other:?}; known: {:?}",
                crate::config::VARIANTS
            ),
        };
        Ok(AttnVariant { imp, qk_norm })
    }
}

/// Flat `(name, shape)` schema in sorted-name (ABI) order — mirrors
/// `python/compile/model.py::param_shapes`.
pub fn param_schema(dims: &ModelDims, qk_norm: bool) -> Vec<(String, Vec<usize>)> {
    let (d, hd, ff, v) = (
        dims.d_model,
        dims.n_heads * dims.d_head,
        dims.d_ff,
        dims.vocab_size,
    );
    let mut schema: Vec<(String, Vec<usize>)> =
        vec![("embed".into(), vec![v, d]), ("final_norm".into(), vec![d])];
    for i in 0..dims.n_layers {
        let p = format!("layers.{i:02}.");
        schema.push((format!("{p}attn_norm"), vec![d]));
        schema.push((format!("{p}wq"), vec![d, hd]));
        schema.push((format!("{p}wk"), vec![d, hd]));
        schema.push((format!("{p}wv"), vec![d, hd]));
        schema.push((format!("{p}wo"), vec![hd, d]));
        if qk_norm {
            schema.push((format!("{p}q_norm"), vec![dims.d_head]));
            schema.push((format!("{p}k_norm"), vec![dims.d_head]));
        }
        schema.push((format!("{p}mlp_norm"), vec![d]));
        schema.push((format!("{p}w_gate"), vec![d, ff]));
        schema.push((format!("{p}w_up"), vec![d, ff]));
        schema.push((format!("{p}w_down"), vec![ff, d]));
    }
    schema.sort_by(|a, b| a.0.cmp(&b.0));
    schema
}

/// Scaled-normal init (std 0.02, Llama-style 1/√(2L) residual scaling on
/// `wo`/`w_down`, ones for every norm γ).  Deterministic in `seed`; each
/// leaf draws from its own RNG stream so the schema order can never
/// change the values.
pub fn init_params(dims: &ModelDims, qk_norm: bool, seed: u64) -> Vec<Tensor> {
    let resid_scale = 1.0 / ((2 * dims.n_layers) as f32).sqrt();
    param_schema(dims, qk_norm)
        .iter()
        .enumerate()
        .map(|(i, (name, shape))| {
            if name.ends_with("norm") {
                let mut t = Tensor::zeros(shape);
                t.fill(1.0);
                t
            } else {
                let sigma = if name.ends_with("wo") || name.ends_with("w_down") {
                    0.02 * resid_scale
                } else {
                    0.02
                };
                let mut rng = Pcg64::new(seed, 0x4D0D_E100 ^ i as u64);
                Tensor::randn(shape, sigma, &mut rng)
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_dims_are_consistent() {
        let d = ModelDims::default();
        d.validate().unwrap();
        assert_eq!(d.tokens_per_microbatch(), 64);
    }

    #[test]
    fn bad_dims_rejected() {
        let mut d = ModelDims::default();
        d.d_head = 8; // 2×8 ≠ 32
        assert!(d.validate().is_err());
        let mut d = ModelDims::default();
        d.n_layers = 0;
        assert!(d.validate().is_err());
    }

    #[test]
    fn variant_parsing_covers_registry() {
        for v in crate::config::VARIANTS {
            AttnVariant::parse(v).unwrap();
        }
        assert!(AttnVariant::parse("bogus").is_err());
        let v = AttnVariant::parse("sage_noqknorm").unwrap();
        assert_eq!(v.imp, AttnImpl::Sage);
        assert!(!v.qk_norm);
        let v = AttnVariant::parse("sage_qknorm_qksm").unwrap();
        assert_eq!(v.imp, AttnImpl::SageQksm);
        assert!(v.qk_norm);
    }

    #[test]
    fn schema_is_sorted_and_qknorm_adds_gammas() {
        let dims = ModelDims::default();
        let with = param_schema(&dims, true);
        let without = param_schema(&dims, false);
        assert_eq!(with.len(), without.len() + 2 * dims.n_layers);
        let names: Vec<&str> = with.iter().map(|(n, _)| n.as_str()).collect();
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted);
        assert!(names.contains(&"layers.01.q_norm"));
        assert!(names.contains(&"embed"));
    }

    #[test]
    fn init_is_deterministic_and_schema_shaped() {
        let dims = ModelDims::default();
        let a = init_params(&dims, true, 7);
        let b = init_params(&dims, true, 7);
        let c = init_params(&dims, true, 8);
        assert_eq!(a.len(), param_schema(&dims, true).len());
        for ((t, u), (name, shape)) in a.iter().zip(&b).zip(param_schema(&dims, true)) {
            assert_eq!(t.shape, shape, "{name}");
            assert_eq!(t.data, u.data, "{name} not deterministic");
            if name.ends_with("norm") {
                assert!(t.data.iter().all(|&x| x == 1.0), "{name} γ must init to 1");
            }
        }
        // different seed changes at least the embedding
        assert_ne!(a[0].data, c[0].data);
    }
}
