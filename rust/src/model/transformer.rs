//! The native decoder-only transformer: manual forward/backward over the
//! flat parameter list, with multi-head attention routed through the
//! [`AttentionBackend`] trait (artifact names `model_attn_*`, resolved by
//! `runtime::backend`) so the FPA/SageBwd/smoothing kernels plug into
//! training unchanged.
//!
//! Backward convention: attention gradients come from one `fwdbwd`
//! backend call per (batch row, head) — FlashAttention-style recompute,
//! nothing quadratic is stored between passes.  Everything else keeps
//! explicit residuals (`blocks::*Cache`).
//!
//! All per-layer head calls are dispatched as one
//! [`AttentionBackend::execute_many`] batch: the native backend fans the
//! heads out over a scoped-thread pool (each head computed whole by one
//! worker, so results are bitwise-identical to the serial loop), and the
//! head q/k/v tensors are *moved* through the call list instead of
//! cloned.  A model-owned [`Workspace`] pools the per-layer backward
//! slabs and MLP intermediates across microbatches and steps.
//!
//! Divergence telemetry contract (DESIGN.md §10): every forward reports
//! `max_attn_logit = max |QKᵀ/√d|` over unmasked pairs, computed in full
//! precision on the (QK-normed, pre-smoothing) attention inputs.  The
//! trainer flags divergence when it crosses
//! `TrainConfig::max_attn_logit_ceiling` — non-finite loss alone fires
//! too late to plot the fig1 divergence point.

use std::cell::RefCell;

use anyhow::{bail, Context, Result};

use crate::model::blocks::{
    cross_entropy_bwd, cross_entropy_fwd, gather_rows, mlp_bwd, mlp_fwd, rmsnorm_bwd, rmsnorm_fwd,
    scatter_add_rows, CeCache, MlpCache, RmsNormCache,
};
use crate::model::{param_schema, AttnVariant, ModelDims};
use crate::runtime::{AttentionBackend, Value};
use crate::telemetry::trace;
use crate::tensor::{IntTensor, Tensor, Workspace};

/// One microbatch's training outputs.
#[derive(Debug)]
pub struct MicroOutput {
    pub loss: f64,
    /// Gradients in parameter (sorted-name) order.
    pub grads: Vec<Tensor>,
    /// max |S| over all layers/heads/rows this microbatch (telemetry).
    pub max_attn_logit: f64,
}

/// The model: dimensions + variant + parameter schema.  Parameters are
/// owned by the caller (the engine) and passed in flat sorted-name order.
pub struct Model {
    dims: ModelDims,
    variant: AttnVariant,
    names: Vec<String>,
    shapes: Vec<Vec<usize>>,
    fwd_artifact: String,
    fwdbwd_artifact: String,
    /// Scratch arena for the per-layer backward slabs (dq/dk/dv) and the
    /// MLP backward intermediates.  Owned by the model so the training
    /// engine's hot loop reuses the same pools every microbatch/step;
    /// interior mutability keeps the `&self` forward/backward API.
    ws: RefCell<Workspace>,
}

struct HeadCache {
    row0: usize,
    col0: usize,
    /// Attention inputs (post-QK-norm).
    qh: Tensor,
    kh: Tensor,
    vh: Tensor,
    qn: Option<RmsNormCache>,
    kn: Option<RmsNormCache>,
}

struct LayerCache {
    y: Tensor,
    an: RmsNormCache,
    heads: Vec<HeadCache>,
    o: Tensor,
    mn: RmsNormCache,
    mlp: MlpCache,
}

impl Model {
    pub fn new(dims: ModelDims, variant: AttnVariant) -> Result<Model> {
        dims.validate()?;
        if variant.imp != crate::model::AttnImpl::Fpa && dims.seq_len % 32 != 0 {
            bail!(
                "SageBwd kernels tile at block 32: seq_len {} must be a multiple of 32",
                dims.seq_len
            );
        }
        let schema = param_schema(&dims, variant.qk_norm);
        let (names, shapes) = schema.into_iter().unzip();
        let stem = format!(
            "model_attn_{}", variant.imp.name()
        );
        Ok(Model {
            fwd_artifact: format!("{stem}_fwd_n{}_d{}", dims.seq_len, dims.d_head),
            fwdbwd_artifact: format!("{stem}_fwdbwd_n{}_d{}", dims.seq_len, dims.d_head),
            dims,
            variant,
            names,
            shapes,
            ws: RefCell::new(Workspace::new()),
        })
    }

    pub fn dims(&self) -> &ModelDims {
        &self.dims
    }

    pub fn variant(&self) -> AttnVariant {
        self.variant
    }

    pub fn param_names(&self) -> &[String] {
        &self.names
    }

    pub fn param_shapes(&self) -> &[Vec<usize>] {
        &self.shapes
    }

    pub fn init_params(&self, seed: u64) -> Vec<Tensor> {
        crate::model::init_params(&self.dims, self.variant.qk_norm, seed)
    }

    /// Index of a parameter leaf (names are sorted, so binary search).
    fn idx(&self, name: &str) -> usize {
        self.names
            .binary_search_by(|n| n.as_str().cmp(name))
            .unwrap_or_else(|_| panic!("parameter {name} not in schema"))
    }

    fn check_batch(&self, tokens: &IntTensor, targets: &IntTensor) -> Result<()> {
        let want = [self.dims.microbatch, self.dims.seq_len];
        if tokens.shape != want || targets.shape != want {
            bail!(
                "batch shape tokens={:?} targets={:?}, model wants {:?}",
                tokens.shape,
                targets.shape,
                want
            );
        }
        Ok(())
    }

    /// Forward + manual backward for one microbatch.
    pub fn loss_and_grads(
        &self,
        params: &[Tensor],
        backend: &mut dyn AttentionBackend,
        tokens: &IntTensor,
        targets: &IntTensor,
    ) -> Result<MicroOutput> {
        let (loss, caches, ce, x_final_cache, max_attn_logit) =
            self.forward_with_targets(params, backend, tokens, targets, true)?;
        let _bwd = trace::span("bwd");
        let caches = caches.expect("forward(want_grads) returns caches");
        let (fn_cache, _f) = x_final_cache.expect("forward(want_grads) returns final-norm cache");
        let ce = ce.expect("forward(want_grads) returns CE cache");

        let hd = self.dims.n_heads * self.dims.d_head;
        let dh = self.dims.d_head;
        let n = self.dims.seq_len;
        let mut grads: Vec<Tensor> = self.shapes.iter().map(|s| Tensor::zeros(s)).collect();

        let embed = &params[self.idx("embed")];
        let (df, dembed_head) = cross_entropy_bwd(&ce, embed)?;
        grads[self.idx("embed")].add_assign(&dembed_head);
        let (mut dx, dg_final) =
            rmsnorm_bwd(&df, &params[self.idx("final_norm")], &fn_cache)?;
        grads[self.idx("final_norm")].add_assign(&dg_final);

        for (l, cache) in caches.into_iter().enumerate().rev() {
            let _layer = trace::span("layer");
            let p = format!("layers.{l:02}.");
            let (i_wq, i_wk, i_wv, i_wo) = (
                self.idx(&format!("{p}wq")),
                self.idx(&format!("{p}wk")),
                self.idx(&format!("{p}wv")),
                self.idx(&format!("{p}wo")),
            );
            // MLP half.
            let (dym, dwg, dwu, dwd) = mlp_bwd(
                &dx,
                &cache.mlp,
                &params[self.idx(&format!("{p}w_gate"))],
                &params[self.idx(&format!("{p}w_up"))],
                &params[self.idx(&format!("{p}w_down"))],
                &mut self.ws.borrow_mut(),
            )?;
            grads[self.idx(&format!("{p}w_gate"))].add_assign(&dwg);
            grads[self.idx(&format!("{p}w_up"))].add_assign(&dwu);
            grads[self.idx(&format!("{p}w_down"))].add_assign(&dwd);
            let (dx1m, dg_m) = rmsnorm_bwd(
                &dym,
                &params[self.idx(&format!("{p}mlp_norm"))],
                &cache.mn,
            )?;
            grads[self.idx(&format!("{p}mlp_norm"))].add_assign(&dg_m);
            let mut dx1 = dx1m;
            dx1.add_assign(&dx); // MLP residual

            // Attention half.  One fwdbwd call per (batch row, head),
            // dispatched as a batch so the native backend can fan heads
            // out across threads; the cached q/k/v head tensors are moved
            // into the calls — no per-head clones.
            grads[i_wo].add_assign(&cache.o.matmul_tn(&dx1)?);
            let do_full = dx1.matmul_nt(&params[i_wo])?;
            let rows = do_full.shape[0];
            let mut dq = self.ws.borrow_mut().take_tensor(&[rows, hd]);
            let mut dk = self.ws.borrow_mut().take_tensor(&[rows, hd]);
            let mut dv = self.ws.borrow_mut().take_tensor(&[rows, hd]);
            let mut calls = Vec::with_capacity(cache.heads.len());
            let mut meta = Vec::with_capacity(cache.heads.len());
            for head in cache.heads {
                let do_h = do_full.block(head.row0, head.col0, n, dh)?;
                // sagebwd-allow(A2): per-head XLA call marshalling, not a kernel loop
                calls.push(vec![
                    Value::F32(head.qh),
                    Value::F32(head.kh),
                    Value::F32(head.vh),
                    Value::F32(do_h),
                ]);
                meta.push((head.row0, head.col0, head.qn, head.kn));
            }
            let outs = backend
                .execute_many(&self.fwdbwd_artifact, &calls)
                .with_context(|| format!("attention backward {}", self.fwdbwd_artifact))?;
            for (out, (row0, col0, qn, kn)) in outs.into_iter().zip(meta) {
                if out.len() != 4 {
                    bail!(
                        "{} returned {} outputs, expected 4 (o, dq, dk, dv)",
                        self.fwdbwd_artifact,
                        out.len()
                    );
                }
                let mut it = out.into_iter();
                let _o = it.next();
                let mut dqh = it.next().unwrap().into_f32()?;
                let mut dkh = it.next().unwrap().into_f32()?;
                let dvh = it.next().unwrap().into_f32()?;
                if self.variant.qk_norm {
                    let qn = qn.as_ref().expect("qk_norm caches present");
                    let kn = kn.as_ref().expect("qk_norm caches present");
                    let gq = &params[self.idx(&format!("{p}q_norm"))];
                    let gk = &params[self.idx(&format!("{p}k_norm"))];
                    let (dq_pre, dgq) = rmsnorm_bwd(&dqh, gq, qn)?;
                    let (dk_pre, dgk) = rmsnorm_bwd(&dkh, gk, kn)?;
                    grads[self.idx(&format!("{p}q_norm"))].add_assign(&dgq);
                    grads[self.idx(&format!("{p}k_norm"))].add_assign(&dgk);
                    dqh = dq_pre;
                    dkh = dk_pre;
                }
                dq.set_block(row0, col0, &dqh)?;
                dk.set_block(row0, col0, &dkh)?;
                dv.set_block(row0, col0, &dvh)?;
            }
            grads[i_wq].add_assign(&cache.y.matmul_tn(&dq)?);
            grads[i_wk].add_assign(&cache.y.matmul_tn(&dk)?);
            grads[i_wv].add_assign(&cache.y.matmul_tn(&dv)?);
            let mut dy = dq.matmul_nt(&params[i_wq])?;
            dy.add_assign(&dk.matmul_nt(&params[i_wk])?);
            dy.add_assign(&dv.matmul_nt(&params[i_wv])?);
            {
                let mut ws = self.ws.borrow_mut();
                ws.give_tensor(dv);
                ws.give_tensor(dk);
                ws.give_tensor(dq);
            }
            let (dxa, dg_a) = rmsnorm_bwd(
                &dy,
                &params[self.idx(&format!("{p}attn_norm"))],
                &cache.an,
            )?;
            grads[self.idx(&format!("{p}attn_norm"))].add_assign(&dg_a);
            dx1.add_assign(&dxa); // attention residual into the block input
            dx = dx1;
        }

        // Embedding gather backward.
        let flat_ids: Vec<i32> = tokens.data.clone();
        scatter_add_rows(&mut grads[self.idx("embed")], &flat_ids, &dx)?;
        debug_assert_eq!(grads.len(), self.shapes.len());
        Ok(MicroOutput {
            loss,
            grads,
            max_attn_logit,
        })
    }

    /// Forward-only loss (held-out probes).  Returns `(loss, max_attn_logit)`.
    pub fn loss_only(
        &self,
        params: &[Tensor],
        backend: &mut dyn AttentionBackend,
        tokens: &IntTensor,
        targets: &IntTensor,
    ) -> Result<(f64, f64)> {
        let (loss, _, _, _, max_logit) =
            self.forward_with_targets(params, backend, tokens, targets, false)?;
        Ok((loss, max_logit))
    }

    /// Shared forward pass.  When `want_caches` is false, only the loss
    /// and telemetry survive (no residuals are stored).
    #[allow(clippy::type_complexity)]
    fn forward_with_targets(
        &self,
        params: &[Tensor],
        backend: &mut dyn AttentionBackend,
        tokens: &IntTensor,
        targets: &IntTensor,
        want_caches: bool,
    ) -> Result<(
        f64,
        Option<Vec<LayerCache>>,
        Option<CeCache>,
        Option<(RmsNormCache, Tensor)>,
        f64,
    )> {
        let _fwd = trace::span("fwd");
        self.check_batch(tokens, targets)?;
        if params.len() != self.shapes.len() {
            bail!(
                "model has {} parameter leaves, got {}",
                self.shapes.len(),
                params.len()
            );
        }
        for (t, (name, shape)) in params.iter().zip(self.names.iter().zip(&self.shapes)) {
            if &t.shape != shape {
                bail!("parameter {name}: shape {:?}, schema wants {shape:?}", t.shape);
            }
        }
        let (b, n, dh) = (self.dims.microbatch, self.dims.seq_len, self.dims.d_head);
        let eps = self.dims.norm_eps;
        let mut max_logit = 0f64;
        let mut x = gather_rows(&params[self.idx("embed")], &tokens.data)?;
        let mut caches = Vec::with_capacity(self.dims.n_layers);
        for l in 0..self.dims.n_layers {
            let _layer = trace::span("layer");
            let p = format!("layers.{l:02}.");
            let (y, an) = rmsnorm_fwd(&x, &params[self.idx(&format!("{p}attn_norm"))], eps)?;
            let q = y.matmul(&params[self.idx(&format!("{p}wq"))])?;
            let k = y.matmul(&params[self.idx(&format!("{p}wk"))])?;
            let v = y.matmul(&params[self.idx(&format!("{p}wv"))])?;
            let mut o = Tensor::zeros(&q.shape);
            // Build every (batch row, head) attention input first, dispatch
            // them as one batch (head-parallel on the native backend,
            // bitwise-identical to the serial loop), then reclaim the q/k/v
            // tensors from the call list for the backward caches — moved,
            // not cloned.
            let mut calls = Vec::with_capacity(b * self.dims.n_heads);
            let mut meta = Vec::with_capacity(b * self.dims.n_heads);
            for bi in 0..b {
                for h in 0..self.dims.n_heads {
                    let (row0, col0) = (bi * n, h * dh);
                    let mut qh = q.block(row0, col0, n, dh)?;
                    let mut kh = k.block(row0, col0, n, dh)?;
                    let vh = v.block(row0, col0, n, dh)?;
                    let (mut qn, mut kn) = (None, None);
                    if self.variant.qk_norm {
                        let (qn_out, qc) = rmsnorm_fwd(
                            &qh,
                            &params[self.idx(&format!("{p}q_norm"))],
                            eps,
                        )?;
                        let (kn_out, kc) = rmsnorm_fwd(
                            &kh,
                            &params[self.idx(&format!("{p}k_norm"))],
                            eps,
                        )?;
                        qh = qn_out;
                        kh = kn_out;
                        qn = Some(qc);
                        kn = Some(kc);
                    }
                    // sagebwd-allow(A2): per-head XLA call marshalling, not a kernel loop
                    calls.push(vec![Value::F32(qh), Value::F32(kh), Value::F32(vh)]);
                    meta.push((row0, col0, qn, kn));
                }
            }
            let outs = backend
                .execute_many(&self.fwd_artifact, &calls)
                .with_context(|| format!("attention forward {}", self.fwd_artifact))?;
            let mut heads = Vec::with_capacity(calls.len());
            for ((call, out), (row0, col0, qn, kn)) in
                calls.into_iter().zip(outs).zip(meta)
            {
                if out.len() != 2 {
                    bail!(
                        "{} returned {} outputs, expected 2 (o, max_logit)",
                        self.fwd_artifact,
                        out.len()
                    );
                }
                let mut it = out.into_iter();
                let oh = it.next().unwrap().into_f32()?;
                let ml = it.next().unwrap().into_f32()?.item() as f64;
                // NaN-aware fold: a non-finite head statistic must poison
                // the microbatch maximum so the trainer's divergence
                // ceiling sees it (DESIGN.md §10).
                max_logit = crate::util::stats::nan_max(max_logit, ml);
                o.set_block(row0, col0, &oh)?;
                if want_caches {
                    let mut ci = call.into_iter();
                    let qh = ci.next().unwrap().into_f32()?;
                    let kh = ci.next().unwrap().into_f32()?;
                    let vh = ci.next().unwrap().into_f32()?;
                    heads.push(HeadCache {
                        row0,
                        col0,
                        qh,
                        kh,
                        vh,
                        qn,
                        kn,
                    });
                }
            }
            let attn_out = o.matmul(&params[self.idx(&format!("{p}wo"))])?;
            // sagebwd-allow(A2): residual stream copy, once per layer not per token
            let mut x1 = x.clone();
            x1.add_assign(&attn_out);
            let (ym, mn) = rmsnorm_fwd(&x1, &params[self.idx(&format!("{p}mlp_norm"))], eps)?;
            let (mlp_out, mlp) = mlp_fwd(
                &ym,
                &params[self.idx(&format!("{p}w_gate"))],
                &params[self.idx(&format!("{p}w_up"))],
                &params[self.idx(&format!("{p}w_down"))],
            )?;
            // sagebwd-allow(A2): residual stream copy, once per layer not per token
            let mut x2 = x1.clone();
            x2.add_assign(&mlp_out);
            if want_caches {
                caches.push(LayerCache {
                    y,
                    an,
                    heads,
                    o,
                    mn,
                    mlp,
                });
            }
            x = x2;
        }
        let (f, fn_cache) = rmsnorm_fwd(&x, &params[self.idx("final_norm")], eps)?;
        let (loss, ce) = cross_entropy_fwd(&f, &params[self.idx("embed")], &targets.data)?;
        if want_caches {
            Ok((
                loss,
                Some(caches),
                Some(ce),
                Some((fn_cache, f)),
                max_logit,
            ))
        } else {
            Ok((loss, None, None, None, max_logit))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{AttnImpl, AttnVariant};
    use crate::runtime::NativeBackend;
    use crate::util::rng::Pcg64;

    fn tiny_dims() -> ModelDims {
        ModelDims {
            vocab_size: 64,
            d_model: 16,
            n_heads: 2,
            d_head: 8,
            d_ff: 32,
            n_layers: 1,
            seq_len: 16, // fpa path has no block constraint
            microbatch: 1,
            norm_eps: 1e-6,
        }
    }

    fn batch(dims: &ModelDims, seed: u64) -> (IntTensor, IntTensor) {
        let mut rng = Pcg64::new(seed, 0xBA7C);
        let count = dims.microbatch * dims.seq_len;
        let draw = |rng: &mut Pcg64| -> Vec<i32> {
            (0..count)
                .map(|_| rng.below(dims.vocab_size as u64) as i32)
                .collect()
        };
        let shape = [dims.microbatch, dims.seq_len];
        (
            IntTensor::from_vec(&shape, draw(&mut rng)).unwrap(),
            IntTensor::from_vec(&shape, draw(&mut rng)).unwrap(),
        )
    }

    #[test]
    fn init_loss_is_log_vocab() {
        let dims = tiny_dims();
        let model = Model::new(dims, AttnVariant { imp: AttnImpl::Fpa, qk_norm: true }).unwrap();
        let params = model.init_params(0);
        let mut be = NativeBackend::new();
        let (tokens, targets) = batch(&dims, 1);
        let (loss, max_logit) = model.loss_only(&params, &mut be, &tokens, &targets).unwrap();
        // 0.02-scale init ⟹ near-uniform logits ⟹ loss ≈ ln(64) = 4.158.
        assert!((loss - (64f64).ln()).abs() < 0.05, "init loss {loss}");
        // QK-norm bounds |S| ≤ √d_head at γ=1 (Cauchy–Schwarz on unit-RMS rows).
        assert!(max_logit > 0.0 && max_logit <= (dims.d_head as f64).sqrt() * 1.01,
                "max_logit {max_logit}");
    }

    #[test]
    fn grads_match_schema_and_are_deterministic() {
        let dims = tiny_dims();
        let model = Model::new(dims, AttnVariant { imp: AttnImpl::Fpa, qk_norm: true }).unwrap();
        let params = model.init_params(3);
        let mut be = NativeBackend::new();
        let (tokens, targets) = batch(&dims, 2);
        let a = model.loss_and_grads(&params, &mut be, &tokens, &targets).unwrap();
        let b = model.loss_and_grads(&params, &mut be, &tokens, &targets).unwrap();
        assert_eq!(a.grads.len(), model.param_shapes().len());
        for ((g, h), (name, shape)) in a.grads.iter().zip(&b.grads)
            .zip(model.param_names().iter().zip(model.param_shapes()))
        {
            assert_eq!(&g.shape, shape, "{name}");
            assert_eq!(g.data, h.data, "{name} grad not deterministic");
            assert!(g.is_finite(), "{name} grad not finite");
        }
        assert_eq!(a.loss, b.loss);
        // Loss must respond to parameters: at least the embedding grad is
        // non-zero (every token both gathers and feeds the tied head).
        assert!(a.grads[0].max_abs() > 0.0, "embed grad identically zero");
    }

    #[test]
    fn no_qknorm_schema_has_no_gamma_leaves() {
        let dims = tiny_dims();
        let model = Model::new(dims, AttnVariant { imp: AttnImpl::Fpa, qk_norm: false }).unwrap();
        assert!(model.param_names().iter().all(|n| !n.contains("q_norm")));
        let params = model.init_params(0);
        let mut be = NativeBackend::new();
        let (tokens, targets) = batch(&dims, 4);
        let out = model.loss_and_grads(&params, &mut be, &tokens, &targets).unwrap();
        assert!(out.loss.is_finite());
    }

    #[test]
    fn sage_variant_needs_block_aligned_seq() {
        let dims = tiny_dims(); // seq_len 16
        assert!(Model::new(dims, AttnVariant { imp: AttnImpl::Sage, qk_norm: true }).is_err());
        let mut ok = tiny_dims();
        ok.seq_len = 32;
        assert!(Model::new(ok, AttnVariant { imp: AttnImpl::Sage, qk_norm: true }).is_ok());
    }

    #[test]
    fn sage_and_fpa_grads_agree_at_small_scale() {
        // Table-1-style: at unit-ish activations the INT8 path tracks FPA.
        let mut dims = tiny_dims();
        dims.seq_len = 32;
        let mk = |imp| Model::new(dims, AttnVariant { imp, qk_norm: true }).unwrap();
        let fpa = mk(AttnImpl::Fpa);
        let sage = mk(AttnImpl::Sage);
        let params = fpa.init_params(5);
        let mut be = NativeBackend::new();
        let (tokens, targets) = batch(&dims, 5);
        let a = fpa.loss_and_grads(&params, &mut be, &tokens, &targets).unwrap();
        let b = sage.loss_and_grads(&params, &mut be, &tokens, &targets).unwrap();
        assert!((a.loss - b.loss).abs() < 0.05, "{} vs {}", a.loss, b.loss);
        // Gradient direction agreement on the largest leaf (embed).
        let c = a.grads[0].cossim(&b.grads[0]);
        assert!(c > 0.98, "embed grad cossim {c}");
    }

    #[test]
    fn batch_shape_mismatch_rejected() {
        let dims = tiny_dims();
        let model = Model::new(dims, AttnVariant { imp: AttnImpl::Fpa, qk_norm: true }).unwrap();
        let params = model.init_params(0);
        let mut be = NativeBackend::new();
        let bad = IntTensor::zeros(&[1, 8]);
        let good = IntTensor::zeros(&[1, 16]);
        assert!(model.loss_only(&params, &mut be, &bad, &good).is_err());
        assert!(model.loss_only(&params, &mut be, &good, &bad).is_err());
    }
}
