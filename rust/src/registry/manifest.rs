//! The `sagebwd-run-v1` run manifest — one versioned schema for every
//! experiment's products (DESIGN.md §12).
//!
//! A manifest lives at `registry/runs/<key16>/manifest.json` and names:
//! the experiment + human label, the full run configuration (canonical
//! JSON, the hash preimage), the content hash that keys the run, the
//! code/schema versions, a lifecycle status, the named artifact refs
//! (content hash + size + optional legacy view path), and a small
//! summary object (final loss, divergence step, peak logit, ...).
//!
//! Serialization is deterministic end to end (`util::json` objects are
//! BTreeMaps; artifact refs keep recording order), so a manifest's bytes
//! are a pure function of the run — the resume test asserts completed
//! manifests are byte-identical across `grid resume`.  Parsing is the
//! third consumer of the shared `util::json::schema` checkers (after
//! `BENCH_*.json` and the artifact manifests).

use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::{self, schema, Json};

/// Schema tag: bump when the manifest layout changes (old manifests then
/// fail parsing loudly instead of being half-read).
pub const RUN_SCHEMA: &str = "sagebwd-run-v1";

/// Run lifecycle.  `Complete` and `Diverged` are *finished* outcomes
/// (divergence is a first-class experimental result here — the fig1
/// no-QK-norm arms are supposed to cross the `max_attn_logit` ceiling),
/// so the orchestrator skips both on resume.  `Pending` (no manifest
/// yet), `Running` (stale crash leftover), and `Failed` are re-runnable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunState {
    Pending,
    Running,
    Complete,
    Failed,
    Diverged,
}

impl RunState {
    pub fn as_str(self) -> &'static str {
        match self {
            RunState::Pending => "pending",
            RunState::Running => "running",
            RunState::Complete => "complete",
            RunState::Failed => "failed",
            RunState::Diverged => "diverged",
        }
    }

    pub fn parse(s: &str) -> Result<RunState> {
        Ok(match s {
            "pending" => RunState::Pending,
            "running" => RunState::Running,
            "complete" => RunState::Complete,
            "failed" => RunState::Failed,
            "diverged" => RunState::Diverged,
            other => bail!("unknown run status {other:?}"),
        })
    }

    /// Finished outcomes are skipped by `grid run`/`resume`.
    pub fn is_finished(self) -> bool {
        matches!(self, RunState::Complete | RunState::Diverged)
    }
}

/// One named product of a run, stored content-addressed.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactRef {
    /// Logical name within the run, e.g. `train_loss.csv`, `final.ckpt`.
    pub name: String,
    /// Content hash — the object lives at `registry/objects/<sha256>`.
    pub sha256: String,
    pub bytes: u64,
    /// Legacy view path (symlink or copy) kept so existing plot/CI
    /// tooling finds the file where it always did; `None` for artifacts
    /// that only live in the store.
    pub view: Option<String>,
}

impl ArtifactRef {
    fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("name", Json::from(self.name.as_str())),
            ("sha256", Json::from(self.sha256.as_str())),
            ("bytes", Json::from(self.bytes as i64)),
            (
                "view",
                self.view.as_deref().map(Json::from).unwrap_or(Json::Null),
            ),
        ])
    }

    fn from_json(j: &Json) -> Result<ArtifactRef> {
        Ok(ArtifactRef {
            name: schema::str_field(j, "name")?.to_string(),
            sha256: schema::str_field(j, "sha256")?.to_string(),
            bytes: schema::u64_field(j, "bytes")?,
            view: schema::opt_str_field(j, "view")?.map(str::to_string),
        })
    }
}

/// One supervisor recovery attempt (DESIGN.md §16): why the run was
/// rolled back, where it resumed, and the effective training config the
/// intervention produced.  `peak_lr`/`tokens_per_step`/`variant` record
/// the *post-intervention* values so the manifest alone reconstructs the
/// entire recovery ladder walk.
#[derive(Debug, Clone, PartialEq)]
pub struct RecoveryRecord {
    /// 1-based attempt number within the run.
    pub attempt: u64,
    /// Step at which the failure was detected.
    pub at_step: u64,
    /// Step the run rolled back to (the last good checkpoint).
    pub resume_step: u64,
    /// Failure description (divergence reason, injected fault, ...).
    pub reason: String,
    /// Intervention applied: `lr_backoff`, `halve_tps`, `escalate_arm`,
    /// `retry`, or `rewrite_artifact`.
    pub action: String,
    /// Peak learning rate after the intervention.
    pub peak_lr: f64,
    /// Tokens per optimizer step after the intervention.
    pub tokens_per_step: u64,
    /// Attention variant after the intervention (arm escalation).
    pub variant: String,
}

impl RecoveryRecord {
    fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("attempt", Json::from(self.attempt as i64)),
            ("at_step", Json::from(self.at_step as i64)),
            ("resume_step", Json::from(self.resume_step as i64)),
            ("reason", Json::from(self.reason.as_str())),
            ("action", Json::from(self.action.as_str())),
            ("peak_lr", Json::from(self.peak_lr)),
            ("tokens_per_step", Json::from(self.tokens_per_step as i64)),
            ("variant", Json::from(self.variant.as_str())),
        ])
    }

    fn from_json(j: &Json) -> Result<RecoveryRecord> {
        Ok(RecoveryRecord {
            attempt: schema::u64_field(j, "attempt")?,
            at_step: schema::u64_field(j, "at_step")?,
            resume_step: schema::u64_field(j, "resume_step")?,
            reason: schema::str_field(j, "reason")?.to_string(),
            action: schema::str_field(j, "action")?.to_string(),
            peak_lr: schema::f64_field(j, "peak_lr")?,
            tokens_per_step: schema::u64_field(j, "tokens_per_step")?,
            variant: schema::str_field(j, "variant")?.to_string(),
        })
    }
}

/// Parsed (or under-construction) run manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct RunManifest {
    /// Grouping label (`fig1`, `fig4`, `noise_probe`, `train`, `bench`,
    /// `table`, ...) — *not* part of the run key: identical configs are
    /// one run no matter which grid asked for them.
    pub experiment: String,
    /// Human-readable cell label, e.g. `sage_qknorm_tps2048_seed0`.
    pub label: String,
    /// Canonical run configuration (part of the hash preimage).
    pub config: Json,
    /// Full sha256 of the key material — the run's identity.
    pub config_hash: String,
    /// Crate version that produced the run.
    pub code_version: String,
    pub status: RunState,
    pub artifacts: Vec<ArtifactRef>,
    /// Supervisor recovery attempts, in order (empty for unsupervised
    /// runs).  Parsed leniently so pre-supervisor manifests still load.
    pub recoveries: Vec<RecoveryRecord>,
    /// Small outcome record (experiment-specific; `final_loss`,
    /// `diverged_at`, `max_attn_logit`, ... for training cells).
    pub summary: Json,
}

impl RunManifest {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("schema", Json::from(RUN_SCHEMA)),
            ("experiment", Json::from(self.experiment.as_str())),
            ("label", Json::from(self.label.as_str())),
            ("config", self.config.clone()),
            ("config_hash", Json::from(self.config_hash.as_str())),
            ("code_version", Json::from(self.code_version.as_str())),
            ("status", Json::from(self.status.as_str())),
            (
                "artifacts",
                Json::Arr(self.artifacts.iter().map(ArtifactRef::to_json).collect()),
            ),
            {
                let recs = self.recoveries.iter().map(RecoveryRecord::to_json).collect();
                ("recoveries", Json::Arr(recs))
            },
            ("summary", self.summary.clone()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<RunManifest> {
        schema::expect_tag(j, RUN_SCHEMA)?;
        Ok(RunManifest {
            experiment: schema::str_field(j, "experiment")?.to_string(),
            label: schema::str_field(j, "label")?.to_string(),
            config: j.get("config")?.clone(),
            config_hash: schema::str_field(j, "config_hash")?.to_string(),
            code_version: schema::str_field(j, "code_version")?.to_string(),
            status: RunState::parse(schema::str_field(j, "status")?)?,
            artifacts: schema::arr_field(j, "artifacts")?
                .iter()
                .map(ArtifactRef::from_json)
                .collect::<Result<Vec<_>>>()?,
            // Lenient: pre-supervisor manifests have no `recoveries` key.
            recoveries: match j.get_opt("recoveries") {
                Some(Json::Arr(items)) => items
                    .iter()
                    .map(RecoveryRecord::from_json)
                    .collect::<Result<Vec<_>>>()?,
                _ => Vec::new(),
            },
            summary: j.get("summary")?.clone(),
        })
    }

    pub fn parse(text: &str) -> Result<RunManifest> {
        RunManifest::from_json(&json::parse(text)?)
    }

    pub fn load(path: &Path) -> Result<RunManifest> {
        let text = fs::read_to_string(path)
            .with_context(|| format!("reading run manifest {}", path.display()))?;
        RunManifest::parse(&text)
            .with_context(|| format!("parsing run manifest {}", path.display()))
    }

    /// Atomic write: temp file + rename, so a reader never sees a
    /// half-written manifest and a crash leaves either the old manifest
    /// or the new one, never a torn file.
    pub fn save(&self, path: &Path) -> Result<()> {
        let tmp = path.with_extension("json.tmp");
        fs::write(&tmp, self.to_json().to_string())
            .with_context(|| format!("writing run manifest {}", tmp.display()))?;
        fs::rename(&tmp, path)
            .with_context(|| format!("renaming run manifest into {}", path.display()))?;
        Ok(())
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactRef> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunManifest {
        RunManifest {
            experiment: "fig1".into(),
            label: "sage_qknorm_tps2048_seed0".into(),
            config: json::parse(r#"{"steps":4,"variant":"sage_qknorm"}"#).unwrap(),
            config_hash: "ab".repeat(32),
            code_version: "0.2.0".into(),
            status: RunState::Complete,
            artifacts: vec![
                ArtifactRef {
                    name: "train_loss.csv".into(),
                    sha256: "cd".repeat(32),
                    bytes: 120,
                    view: Some("results/fig1/sage_qknorm_tps2048/train_loss.csv".into()),
                },
                ArtifactRef {
                    name: "config.json".into(),
                    sha256: "ef".repeat(32),
                    bytes: 64,
                    view: None,
                },
            ],
            recoveries: vec![RecoveryRecord {
                attempt: 1,
                at_step: 12,
                resume_step: 8,
                reason: "max_attn_logit 61.2 > 50".into(),
                action: "lr_backoff".into(),
                peak_lr: 0.05,
                tokens_per_step: 2048,
                variant: "sage_qknorm".into(),
            }],
            summary: json::parse(r#"{"diverged_at":null,"final_loss":2.5}"#).unwrap(),
        }
    }

    #[test]
    fn roundtrip_and_determinism() {
        let m = sample();
        let text = m.to_json().to_string();
        let back = RunManifest::parse(&text).unwrap();
        assert_eq!(m, back);
        // Byte-determinism: re-serializing parses back to identical text.
        assert_eq!(back.to_json().to_string(), text);
        assert_eq!(m.artifact("config.json").unwrap().bytes, 64);
        assert!(m.artifact("nope").is_none());
    }

    #[test]
    fn status_lifecycle() {
        for s in [
            RunState::Pending,
            RunState::Running,
            RunState::Complete,
            RunState::Failed,
            RunState::Diverged,
        ] {
            assert_eq!(RunState::parse(s.as_str()).unwrap(), s);
        }
        assert!(RunState::parse("exploded").is_err());
        assert!(RunState::Complete.is_finished());
        assert!(RunState::Diverged.is_finished());
        assert!(!RunState::Failed.is_finished());
        assert!(!RunState::Running.is_finished());
    }

    #[test]
    fn wrong_schema_tag_rejected() {
        let mut j = sample().to_json();
        j.set("schema", Json::from("sagebwd-run-v0"));
        let err = format!("{:#}", RunManifest::from_json(&j).unwrap_err());
        assert!(err.contains("sagebwd-run-v1"), "{err}");
    }

    #[test]
    fn missing_required_key_rejected() {
        let j = json::parse(&sample().to_json().to_string()).unwrap();
        if let Json::Obj(mut o) = j {
            o.remove("status");
            assert!(RunManifest::from_json(&Json::Obj(o)).is_err());
        } else {
            unreachable!();
        }
    }

    #[test]
    fn pre_supervisor_manifest_parses_without_recoveries() {
        // Manifests written before the supervisor era have no
        // `recoveries` key; they must still load (as an empty list).
        let j = json::parse(&sample().to_json().to_string()).unwrap();
        if let Json::Obj(mut o) = j {
            o.remove("recoveries");
            let m = RunManifest::from_json(&Json::Obj(o)).unwrap();
            assert!(m.recoveries.is_empty());
        } else {
            unreachable!();
        }
    }

    #[test]
    fn recovery_record_roundtrips_in_order() {
        let mut m = sample();
        m.recoveries.push(RecoveryRecord {
            attempt: 2,
            at_step: 20,
            resume_step: 16,
            reason: "non-finite gradient in blk0.k_proj[3]".into(),
            action: "halve_tps".into(),
            peak_lr: 0.05,
            tokens_per_step: 1024,
            variant: "sage_qknorm".into(),
        });
        let back = RunManifest::parse(&m.to_json().to_string()).unwrap();
        assert_eq!(back.recoveries.len(), 2);
        assert_eq!(back.recoveries, m.recoveries);
        assert_eq!(back.recoveries[1].action, "halve_tps");
    }

    #[test]
    fn file_roundtrip_atomic() {
        let dir = std::env::temp_dir().join(format!("sagebwd_rm_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("manifest.json");
        let m = sample();
        m.save(&path).unwrap();
        assert_eq!(RunManifest::load(&path).unwrap(), m);
        // No temp file left behind.
        assert!(!path.with_extension("json.tmp").exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
