//! Content-addressed run registry + resumable experiment orchestration
//! (DESIGN.md §12).
//!
//! - [`sha256`]: pure-std SHA-256 (FIPS 180-4), the content addressing
//!   and run-identity hash — no new dependencies.
//! - [`manifest`]: the versioned `sagebwd-run-v1` run-manifest schema.
//! - [`store`]: the object store (`registry/objects/<sha256>`), run
//!   manifests (`registry/runs/<key16>/manifest.json`), legacy views,
//!   and the [`RunHandle`] every writer records artifacts through.
//! - [`orchestrator`]: grid expansion → key-hashed cells → skip finished
//!   → execute the rest on budget-capped worker threads (`sagebwd grid
//!   run|status|resume`).

pub mod manifest;
pub mod orchestrator;
pub mod sha256;
pub mod store;

pub use manifest::{ArtifactRef, RecoveryRecord, RunManifest, RunState, RUN_SCHEMA};
pub use store::{CorruptObject, Registry, RunHandle};
