//! Resumable grid orchestrator (DESIGN.md §12): expand an experiment
//! grid into config-hashed run keys, skip the cells whose manifests are
//! already finished, execute the remainder on scoped worker threads, and
//! report what was skipped / ran / failed.
//!
//! Interrupt-then-resume is the whole point: a killed grid leaves
//! `complete`/`diverged` manifests for the cells that finished and (at
//! most) one `running` leftover per worker; `grid resume` recomputes the
//! same keys, skips everything finished, and picks up the rest.  The
//! integration test asserts finished manifests are **byte-identical**
//! across a resume — nothing rewrites a finished run.
//!
//! Thread budget: the orchestrator shares `SAGEBWD_THREADS` with the
//! linalg pool instead of multiplying it.  With `J` workers, each cell
//! trains under `linalg::with_thread_cap(max(1, T/J))`, so total compute
//! threads stay ≈ T.  The engine's determinism contract (bitwise-equal
//! results at any thread count, DESIGN.md §11) makes the cap invisible
//! in the outputs.

use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::coordinator::{SupervisorConfig, TrainerFactory};
use crate::experiments::{fig1_tps, fig4_ablation};
use crate::registry::manifest::RunState;
use crate::registry::store::Registry;
use crate::telemetry::{trace, Log};
use crate::tensor::linalg;
use crate::util::faults;

/// One grid cell: a (variant, tps, seed) coordinate plus its display
/// label (also the legacy curve-dir name).
#[derive(Debug, Clone)]
pub struct GridCell {
    pub label: String,
    pub variant: String,
    pub tps: u64,
    pub seed: u64,
}

/// A fully-expanded experiment grid.
#[derive(Debug, Clone)]
pub struct GridSpec {
    /// Manifest grouping label: `fig1` or `fig4`.
    pub experiment: String,
    pub token_budget: u64,
    pub peak_lr: f64,
    pub cells: Vec<GridCell>,
}

/// Expand `fig1` or `fig4` arms × seeds into a [`GridSpec`] — the same
/// arm lists the sequential harnesses run, so orchestrated and manual
/// runs share registry keys.
pub fn grid_spec(
    experiment: &str,
    token_budget: u64,
    tps_lo: u64,
    tps_hi: u64,
    peak_lr: f64,
    seeds: &[u64],
) -> Result<GridSpec> {
    let arms = match experiment {
        "fig1" => fig1_tps::grid(tps_lo, tps_hi),
        "fig4" => fig4_ablation::grid(tps_lo, tps_hi),
        other => bail!("unknown grid experiment {other:?}; known: fig1, fig4"),
    };
    if seeds.is_empty() {
        bail!("grid needs at least one seed");
    }
    let mut cells = Vec::new();
    for &seed in seeds {
        for &(variant, tps) in &arms {
            cells.push(GridCell {
                label: fig1_tps::cell_label(variant, tps, seed),
                variant: variant.to_string(),
                tps,
                seed,
            });
        }
    }
    Ok(GridSpec {
        experiment: experiment.to_string(),
        token_budget,
        peak_lr,
        cells,
    })
}

/// Registry state of one cell, as `grid status` reports it.
#[derive(Debug, Clone)]
pub struct CellStatus {
    pub label: String,
    pub key: String,
    /// `None` = no manifest yet (pending).
    pub state: Option<RunState>,
}

/// What a grid execution did.
#[derive(Debug, Default)]
pub struct GridReport {
    pub total: usize,
    /// Finished manifests found up front (registry hits).
    pub skipped: usize,
    /// Cells executed this invocation (complete or diverged).
    pub ran: usize,
    /// Cells left pending by `limit`.
    pub remaining: usize,
    /// (label, error) for cells that errored; the grid keeps going.
    pub failed: Vec<(String, String)>,
}

/// Compute every cell's run key and current registry state (no
/// execution).
pub fn status(
    factory: &TrainerFactory,
    registry: &Registry,
    spec: &GridSpec,
) -> Result<Vec<CellStatus>> {
    spec.cells
        .iter()
        .map(|cell| {
            let cfg = fig1_tps::cell_config(
                &cell.variant,
                cell.tps,
                spec.token_budget,
                spec.peak_lr,
                cell.seed,
            );
            let (_, key) = fig1_tps::cell_key(factory, &cfg);
            let state = registry.load_run(&key)?.map(|m| m.status);
            Ok(CellStatus {
                label: cell.label.clone(),
                key,
                state,
            })
        })
        .collect()
}

/// Execute the grid: skip finished cells, run up to `limit` of the rest
/// on `jobs` scoped worker threads.  `limit = 0` means no limit (the CI
/// registry smoke uses a strict subset to simulate a mid-grid kill).
/// Per-cell failures are recorded as `failed` manifests and collected in
/// the report; the grid keeps executing the remaining cells.
///
/// `retry_diverged` re-queues cells whose manifests finished `diverged`
/// (instead of treating them as registry hits); `complete` cells are
/// still skipped untouched.  `supervise` runs every executed cell under
/// the fault-tolerant supervisor (DESIGN.md §16) — the natural partner
/// of `retry_diverged`, so the second attempt gets the recovery ladder.
#[allow(clippy::too_many_arguments)]
pub fn run(
    factory: &TrainerFactory,
    registry: &Registry,
    results_dir: &str,
    spec: &GridSpec,
    jobs: usize,
    limit: usize,
    fresh: bool,
    retry_diverged: bool,
    supervise: Option<SupervisorConfig>,
    log: &Log,
) -> Result<GridReport> {
    let mut report = GridReport {
        total: spec.cells.len(),
        ..GridReport::default()
    };

    // Partition up front: finished manifests are registry hits.
    let mut todo: Vec<&GridCell> = Vec::new();
    for (cell, st) in spec.cells.iter().zip(status(factory, registry, spec)?) {
        match st.state {
            // `--retry-diverged` re-queues diverged cells for another
            // attempt (under the supervisor when `supervise` is set);
            // complete cells stay registry hits either way.
            Some(state)
                if !fresh
                    && state.is_finished()
                    && !(retry_diverged && matches!(state, RunState::Diverged)) =>
            {
                log.info(&format!(
                    "registry hit [{}]: {} already {} — skipping",
                    &st.key[..16],
                    cell.label,
                    state.as_str()
                ));
                report.skipped += 1;
            }
            _ => todo.push(cell),
        }
    }
    if limit > 0 && todo.len() > limit {
        report.remaining = todo.len() - limit;
        todo.truncate(limit);
        log.info(&format!(
            "--limit {limit}: running {} of {} pending cells ({} left pending)",
            todo.len(),
            todo.len() + report.remaining,
            report.remaining
        ));
    }
    if todo.is_empty() {
        return Ok(report);
    }

    let workers = jobs.clamp(1, todo.len());
    // Split the thread budget across workers; each worker's cells train
    // under the cap so the grid uses ≈ SAGEBWD_THREADS total threads.
    let cap = (linalg::thread_count() / workers).max(1);
    let ctx = fig1_tps::CellCtx {
        factory,
        registry,
        results_dir,
        experiment: &spec.experiment,
        // The skip decision was already made above; workers must not
        // re-skip a cell whose stale `running`/`failed` manifest is being
        // replaced — and with `fresh` they must retrain finished cells.
        fresh: true,
        supervise,
    };
    let queue: Mutex<Vec<&GridCell>> = Mutex::new(todo.into_iter().rev().collect());
    let done: Mutex<(usize, Vec<(String, String)>)> = Mutex::new((0, Vec::new()));

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                // The fault plane is thread-local: each worker re-arms its
                // own plan from `SAGEBWD_FAULTS`.  The plan was already
                // validated once at process start, so a parse error here
                // is unreachable and safely ignored.
                let _ = faults::install_from_env();
                linalg::with_thread_cap(cap, || loop {
                    // A poisoned queue mutex means a sibling worker panicked;
                    // re-panicking is the right way to surface that inside
                    // thread::scope.
                    // sagebwd-allow(A3): propagate sibling-worker panic
                    let Some(cell) = queue.lock().unwrap().pop() else {
                        return;
                    };
                    // Per-run heartbeat: with tracing on, each worker notes
                    // the cell it picks up (the trainer's step lines carry
                    // the live span summary) and the done line below reports
                    // wall time off the same span clock.
                    if trace::enabled() {
                        let hb = trace::heartbeat()
                            .map(|h| format!(" [{h}]"))
                            .unwrap_or_default();
                        log.info(&format!("grid cell start: {}{hb}", cell.label));
                    }
                    let t0 = trace::now_ns();
                    let outcome = fig1_tps::run_cell(
                        &ctx,
                        &cell.variant,
                        cell.tps,
                        spec.token_budget,
                        spec.peak_lr,
                        cell.seed,
                        log,
                    );
                    // sagebwd-allow(A3): same poisoning argument as the queue lock above
                    let mut d = done.lock().unwrap();
                    match outcome {
                        Ok(o) => {
                            d.0 += 1;
                            let secs = trace::now_ns().saturating_sub(t0) as f64 / 1e9;
                            log.info(&format!(
                                "grid cell done: {} ({}, {secs:.1}s)",
                                cell.label,
                                match o.diverged_at {
                                    Some(at) => format!("diverged@{at}"),
                                    None => "complete".to_string(),
                                }
                            ));
                        }
                        Err(e) => d.1.push((cell.label.clone(), format!("{e:#}"))),
                    }
                });
            });
        }
    });

    // Scope has joined every worker, so poisoning here can only follow a
    // worker panic, which thread::scope already re-raised.
    // sagebwd-allow(A3): unreachable after thread::scope join
    let (ran, failed) = done.into_inner().unwrap();
    report.ran = ran;
    report.failed = failed;
    Ok(report)
}

/// Parse a `--seeds "0,1,2"` list.
pub fn parse_seeds(s: &str) -> Result<Vec<u64>> {
    s.split(',')
        .map(|t| {
            t.trim()
                .parse::<u64>()
                .with_context(|| format!("bad seed {t:?} in --seeds {s:?}"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_expands_arms_by_seeds() {
        let spec = grid_spec("fig1", 4096, 256, 2048, 0.1, &[0, 7]).unwrap();
        assert_eq!(spec.cells.len(), 14); // 7 arms × 2 seeds
        assert_eq!(spec.cells[0].label, "fpa_qknorm_tps2048");
        assert!(spec.cells[7].label.ends_with("_seed7"));
        let fig4 = grid_spec("fig4", 4096, 256, 2048, 0.1, &[0]).unwrap();
        assert_eq!(fig4.cells.len(), 8); // 4 variants × 2 TPS
        assert!(grid_spec("fig9", 1, 1, 2, 0.1, &[0]).is_err());
        assert!(grid_spec("fig1", 1, 1, 2, 0.1, &[]).is_err());
    }

    #[test]
    fn seed_list_parses() {
        assert_eq!(parse_seeds("0").unwrap(), vec![0]);
        assert_eq!(parse_seeds("0, 1,9").unwrap(), vec![0, 1, 9]);
        assert!(parse_seeds("0,x").is_err());
    }
}
