//! Pure-std SHA-256 (FIPS 180-4) — the content address of the run
//! registry's object store.
//!
//! No new dependencies: the vendored set has no crypto crate, and the
//! registry only needs a stable, collision-resistant content hash — not
//! constant-time guarantees.  The implementation is the straight FIPS
//! 180-4 schedule/compression; the unit tests pin the standard's own
//! vectors (empty, "abc", the one- and two-block alphabet messages, and
//! the million-`a` message) so any transcription slip is caught.

/// Incremental SHA-256 hasher.
pub struct Sha256 {
    state: [u32; 8],
    /// Total message bytes absorbed so far (for the length suffix).
    len_bytes: u64,
    buf: [u8; 64],
    buf_len: usize,
}

/// First 32 bits of the fractional parts of the cube roots of the first
/// 64 primes (FIPS 180-4 §4.2.2).
const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5,
    0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3,
    0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc,
    0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7,
    0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13,
    0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3,
    0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5,
    0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208,
    0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

/// Initial hash value: fractional parts of the square roots of the first
/// 8 primes (FIPS 180-4 §5.3.3).
const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a,
    0x510e527f, 0x9b05688c, 0x1f83d9ab, 0x5be0cd19,
];

impl Default for Sha256 {
    fn default() -> Sha256 {
        Sha256::new()
    }
}

impl Sha256 {
    pub fn new() -> Sha256 {
        Sha256 {
            state: H0,
            len_bytes: 0,
            buf: [0; 64],
            buf_len: 0,
        }
    }

    pub fn update(&mut self, mut data: &[u8]) {
        self.len_bytes = self.len_bytes.wrapping_add(data.len() as u64);
        // Top up a partial block first.
        if self.buf_len > 0 {
            let need = 64 - self.buf_len;
            let take = need.min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                self.compress(&block);
                self.buf_len = 0;
            }
        }
        // Whole blocks straight from the input.
        while data.len() >= 64 {
            let (block, rest) = data.split_at(64);
            // sagebwd-allow(A3): split_at(64) guarantees block.len() == 64
            self.compress(block.try_into().unwrap());
            data = rest;
        }
        // Stash the tail.
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    pub fn finalize(mut self) -> [u8; 32] {
        let bit_len = self.len_bytes.wrapping_mul(8);
        // Padding: 0x80, zeros to 56 mod 64, then the 64-bit BE bit length.
        self.update(&[0x80]);
        while self.buf_len != 56 {
            self.update(&[0]);
        }
        // Append the length without re-counting it.
        self.buf[56..64].copy_from_slice(&bit_len.to_be_bytes());
        let block = self.buf;
        self.compress(&block);
        let mut out = [0u8; 32];
        for (i, word) in self.state.iter().enumerate() {
            out[4 * i..4 * i + 4].copy_from_slice(&word.to_be_bytes());
        }
        out
    }

    fn compress(&mut self, block: &[u8; 64]) {
        let mut w = [0u32; 64];
        for (t, chunk) in block.chunks_exact(4).enumerate() {
            w[t] = u32::from_be_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        for t in 16..64 {
            let s0 = w[t - 15].rotate_right(7) ^ w[t - 15].rotate_right(18) ^ (w[t - 15] >> 3);
            let s1 = w[t - 2].rotate_right(17) ^ w[t - 2].rotate_right(19) ^ (w[t - 2] >> 10);
            w[t] = w[t - 16]
                .wrapping_add(s0)
                .wrapping_add(w[t - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = self.state;
        for t in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[t])
                .wrapping_add(w[t]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        self.state[0] = self.state[0].wrapping_add(a);
        self.state[1] = self.state[1].wrapping_add(b);
        self.state[2] = self.state[2].wrapping_add(c);
        self.state[3] = self.state[3].wrapping_add(d);
        self.state[4] = self.state[4].wrapping_add(e);
        self.state[5] = self.state[5].wrapping_add(f);
        self.state[6] = self.state[6].wrapping_add(g);
        self.state[7] = self.state[7].wrapping_add(h);
    }
}

/// One-shot digest.
pub fn digest(data: &[u8]) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(data);
    h.finalize()
}

/// Lowercase hex of a digest.
pub fn to_hex(digest: &[u8; 32]) -> String {
    const HEX: &[u8; 16] = b"0123456789abcdef";
    let mut s = String::with_capacity(64);
    for b in digest {
        s.push(HEX[(b >> 4) as usize] as char);
        s.push(HEX[(b & 0xf) as usize] as char);
    }
    s
}

/// One-shot lowercase-hex digest — the registry's content address.
pub fn hex_digest(data: &[u8]) -> String {
    to_hex(&digest(data))
}

#[cfg(test)]
mod tests {
    use super::*;

    // FIPS 180-4 / NIST CAVP standard vectors.
    const VECTORS: &[(&str, &str)] = &[
        ("", "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"),
        ("abc", "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"),
        (
            "abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq",
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1",
        ),
        (
            "abcdefghbcdefghicdefghijdefghijkefghijklfghijklmghijklmnhijklmno\
             ijklmnopjklmnopqklmnopqrlmnopqrsmnopqrstnopqrstu",
            "cf5b16a778af8380036ce59e7b0492370b249b11e8f07a51afac45037afee9d1",
        ),
    ];

    #[test]
    fn fips_vectors() {
        for (msg, want) in VECTORS {
            assert_eq!(hex_digest(msg.as_bytes()), *want, "message {msg:?}");
        }
    }

    #[test]
    fn fips_million_a() {
        let msg = vec![b'a'; 1_000_000];
        assert_eq!(
            hex_digest(&msg),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    #[test]
    fn incremental_matches_oneshot_at_all_split_points() {
        // Exercise every buffer-boundary case: splits straddling the
        // 64-byte block edge must agree with the one-shot digest.
        let msg: Vec<u8> = (0..200u16).map(|i| (i % 251) as u8).collect();
        let want = hex_digest(&msg);
        for split in [0, 1, 55, 56, 63, 64, 65, 127, 128, 199, 200] {
            let mut h = Sha256::new();
            h.update(&msg[..split]);
            h.update(&msg[split..]);
            assert_eq!(to_hex(&h.finalize()), want, "split {split}");
        }
        // Byte-at-a-time absorption.
        let mut h = Sha256::new();
        for b in &msg {
            h.update(std::slice::from_ref(b));
        }
        assert_eq!(to_hex(&h.finalize()), want);
    }

    #[test]
    fn distinct_inputs_distinct_digests() {
        assert_ne!(hex_digest(b"run-a"), hex_digest(b"run-b"));
        assert_eq!(hex_digest(b"stable"), hex_digest(b"stable"));
    }
}
