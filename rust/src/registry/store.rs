//! Content-addressed object store + run handles (DESIGN.md §12).
//!
//! Layout under `<results>/registry/`:
//!
//! ```text
//! registry/objects/<sha256>          # immutable artifact bytes
//! registry/runs/<key16>/manifest.json  # sagebwd-run-v1 manifests
//! ```
//!
//! Objects are written atomically (unique temp file in `objects/`, then
//! rename), so a crash never leaves a torn object and concurrent writers
//! of the same content race benignly (same hash ⇒ same bytes).  A run's
//! identity is the sha256 of its *key material* — canonical config JSON
//! + execution backend + schema version — so identical configs are one
//! run no matter which harness or grid asked for them, and re-running a
//! finished config is a registry hit, not a recompute.
//!
//! Legacy output paths (`results/fig1/<cell>/train_loss.csv`, summary
//! CSVs, ...) are kept as *views*: symlinks into the object store, plain
//! copies where symlinks are unavailable.  Existing plot/CI tooling keeps
//! working unchanged.

use std::fs;
use std::io::Read as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{Context, Result};

use crate::registry::manifest::{ArtifactRef, RecoveryRecord, RunManifest, RunState, RUN_SCHEMA};
use crate::registry::sha256;
use crate::telemetry::Metrics;
use crate::util::{faults, json::Json};

/// Characters of the run key used for the on-disk run directory name
/// (the full hash is in the manifest).
const KEY_DIR_LEN: usize = 16;

/// Monotonic discriminator for temp-file names (several orchestrator
/// workers may stage objects concurrently in one process).
static TMP_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Structured corruption error from [`Registry::read_object`]: the bytes
/// at `objects/<hash>` no longer hash to their address (torn write, bit
/// rot, truncation).  Downcastable from the `anyhow` chain so callers —
/// the supervisor's post-save verify — can distinguish corruption (repair
/// by re-putting the bytes) from a missing object (re-record the run).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CorruptObject {
    /// The address the object was stored under (expected sha256).
    pub hash: String,
    /// What the on-disk bytes actually hash to.
    pub actual: String,
    /// On-disk size found.
    pub bytes: u64,
}

impl std::fmt::Display for CorruptObject {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "corrupt registry object {}: {} bytes on disk hash to {} \
             (torn write or bit rot; re-put the content to repair)",
            self.hash, self.bytes, self.actual
        )
    }
}

impl std::error::Error for CorruptObject {}

/// Handle on one registry root.
#[derive(Debug, Clone)]
pub struct Registry {
    root: PathBuf,
}

impl Registry {
    /// Open (creating if needed) the registry under `<results>/registry`.
    pub fn open(results_dir: &str) -> Result<Registry> {
        let root = PathBuf::from(results_dir).join("registry");
        fs::create_dir_all(root.join("objects"))
            .with_context(|| format!("creating {}", root.join("objects").display()))?;
        fs::create_dir_all(root.join("runs"))
            .with_context(|| format!("creating {}", root.join("runs").display()))?;
        Ok(Registry { root })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn object_path(&self, hash: &str) -> PathBuf {
        self.root.join("objects").join(hash)
    }

    pub fn has_object(&self, hash: &str) -> bool {
        self.object_path(hash).is_file()
    }

    /// Store `bytes` content-addressed; returns the sha256 hex address.
    /// Atomic: staged under a unique temp name, renamed into place.
    /// Idempotent *and self-healing*: an existing object is left
    /// untouched only if its content still hashes to its address, so
    /// re-putting known-good bytes repairs a torn earlier write.
    pub fn put_bytes(&self, bytes: &[u8]) -> Result<String> {
        let hash = sha256::hex_digest(bytes);
        let dst = self.object_path(&hash);
        if let Ok(existing) = fs::read(&dst) {
            if sha256::hex_digest(&existing) == hash {
                return Ok(hash);
            }
            // Corrupt object at this address: fall through and rewrite.
        }
        // Fault plane (DESIGN.md §16): a `torn@N` fault replaces the N-th
        // staged payload with a truncated copy.  The address still names
        // the *intended* content, so a verified read detects the tear and
        // the self-heal path above repairs it on re-put.
        let staged = faults::corrupt_write(bytes);
        let payload: &[u8] = staged.as_deref().unwrap_or(bytes);
        let tmp = self.root.join("objects").join(format!(
            ".tmp-{}-{}",
            std::process::id(),
            TMP_COUNTER.fetch_add(1, Ordering::Relaxed)
        ));
        fs::write(&tmp, payload).with_context(|| format!("staging object {}", tmp.display()))?;
        fs::rename(&tmp, &dst)
            .with_context(|| format!("renaming object into {}", dst.display()))?;
        Ok(hash)
    }

    /// Store an existing file's contents (e.g. a checkpoint the trainer
    /// already wrote); the source stays in place.
    pub fn put_file(&self, path: &Path) -> Result<(String, u64)> {
        let mut f = fs::File::open(path)
            .with_context(|| format!("opening {} for hashing", path.display()))?;
        let mut bytes = Vec::new();
        f.read_to_end(&mut bytes)
            .with_context(|| format!("reading {}", path.display()))?;
        let len = bytes.len() as u64;
        Ok((self.put_bytes(&bytes)?, len))
    }

    /// Read an object, verifying its content against its address.  Bytes
    /// that no longer hash to `hash` yield a downcastable
    /// [`CorruptObject`] error instead of silently wrong data.
    pub fn read_object(&self, hash: &str) -> Result<Vec<u8>> {
        let bytes = fs::read(self.object_path(hash))
            .with_context(|| format!("reading object {hash} from {}", self.root.display()))?;
        let actual = sha256::hex_digest(&bytes);
        if actual != hash {
            return Err(CorruptObject {
                hash: hash.to_string(),
                actual,
                bytes: bytes.len() as u64,
            }
            .into());
        }
        Ok(bytes)
    }

    /// Materialize a legacy view of an object at `view`: a symlink into
    /// the object store where the platform supports it, a plain copy
    /// otherwise.  Replaces whatever was there (the view is derived
    /// state; the object is the source of truth).
    pub fn write_view(&self, hash: &str, view: &Path) -> Result<()> {
        if let Some(parent) = view.parent() {
            if !parent.as_os_str().is_empty() {
                fs::create_dir_all(parent)
                    .with_context(|| format!("creating view dir {}", parent.display()))?;
            }
        }
        let _ = fs::remove_file(view);
        let obj = fs::canonicalize(self.object_path(hash))
            .with_context(|| format!("resolving object {hash}"))?;
        #[cfg(unix)]
        {
            if std::os::unix::fs::symlink(&obj, view).is_ok() {
                return Ok(());
            }
        }
        fs::copy(&obj, view)
            .with_context(|| format!("copying object {hash} to view {}", view.display()))?;
        Ok(())
    }

    /// The run key: sha256 over canonical key material.  `backend` is
    /// part of the identity (a native run is not an XLA run); the
    /// experiment label is *not* (identical configs dedup across grids —
    /// fig4 reuses fig1's shared arms exactly like the legacy curve dirs
    /// did).
    pub fn run_key(config: &Json, backend: &str) -> String {
        let material = Json::from_pairs(vec![
            ("backend", Json::from(backend)),
            ("config", config.clone()),
            ("schema", Json::from(RUN_SCHEMA)),
        ]);
        sha256::hex_digest(material.to_string().as_bytes())
    }

    pub fn run_dir(&self, key: &str) -> PathBuf {
        self.root.join("runs").join(&key[..KEY_DIR_LEN.min(key.len())])
    }

    pub fn manifest_path(&self, key: &str) -> PathBuf {
        self.run_dir(key).join("manifest.json")
    }

    /// Load the manifest for a run key, if one exists.
    pub fn load_run(&self, key: &str) -> Result<Option<RunManifest>> {
        let path = self.manifest_path(key);
        if !path.is_file() {
            return Ok(None);
        }
        Ok(Some(RunManifest::load(&path)?))
    }

    /// List every recorded run (key16 dir name + manifest), sorted by
    /// directory name for deterministic output.
    pub fn list_runs(&self) -> Result<Vec<(String, RunManifest)>> {
        let mut out = Vec::new();
        let runs = self.root.join("runs");
        let mut entries: Vec<_> = fs::read_dir(&runs)
            .with_context(|| format!("listing {}", runs.display()))?
            .collect::<std::io::Result<Vec<_>>>()?;
        entries.sort_by_key(|e| e.file_name());
        for e in entries {
            let manifest = e.path().join("manifest.json");
            if manifest.is_file() {
                out.push((
                    e.file_name().to_string_lossy().into_owned(),
                    RunManifest::load(&manifest)?,
                ));
            }
        }
        Ok(out)
    }

    /// Start a run: writes a `running` manifest immediately (so a crash
    /// leaves a re-runnable `running` leftover, not silence) and returns
    /// the handle every writer records through.
    pub fn begin_run(&self, experiment: &str, label: &str, config: Json) -> Result<RunHandle<'_>> {
        let key = Registry::run_key(&config, experiment_backend(&config));
        self.begin_run_keyed(experiment, label, config, key)
    }

    /// `begin_run` with an explicit precomputed key (the orchestrator
    /// computes keys up front for skip decisions).
    pub fn begin_run_keyed(
        &self,
        experiment: &str,
        label: &str,
        config: Json,
        key: String,
    ) -> Result<RunHandle<'_>> {
        let dir = self.run_dir(&key);
        fs::create_dir_all(&dir).with_context(|| format!("creating {}", dir.display()))?;
        let manifest = RunManifest {
            experiment: experiment.to_string(),
            label: label.to_string(),
            config,
            config_hash: key.clone(),
            code_version: env!("CARGO_PKG_VERSION").to_string(),
            status: RunState::Running,
            artifacts: Vec::new(),
            recoveries: Vec::new(),
            summary: Json::obj(),
        };
        manifest.save(&self.manifest_path(&key))?;
        Ok(RunHandle {
            registry: self,
            key,
            manifest,
        })
    }

    /// Resume an interrupted run in place, or start a fresh one.  When a
    /// prior manifest exists (any status), its artifact refs, recovery
    /// records, and summary are carried onto the new `running` manifest
    /// in one atomic write — a crash mid-resume never orphans the
    /// checkpoint refs the resume needs.  Returns the prior manifest so
    /// the caller can find its last checkpoint.
    pub fn resume_or_begin(
        &self,
        experiment: &str,
        label: &str,
        config: Json,
        key: String,
    ) -> Result<(RunHandle<'_>, Option<RunManifest>)> {
        let prior = self.load_run(&key)?;
        let Some(p) = prior else {
            return Ok((self.begin_run_keyed(experiment, label, config, key)?, None));
        };
        let dir = self.run_dir(&key);
        fs::create_dir_all(&dir).with_context(|| format!("creating {}", dir.display()))?;
        let manifest = RunManifest {
            experiment: experiment.to_string(),
            label: label.to_string(),
            config,
            config_hash: key.clone(),
            code_version: env!("CARGO_PKG_VERSION").to_string(),
            status: RunState::Running,
            artifacts: p.artifacts.clone(),
            recoveries: p.recoveries.clone(),
            summary: p.summary.clone(),
        };
        manifest.save(&self.manifest_path(&key))?;
        Ok((
            RunHandle {
                registry: self,
                key,
                manifest,
            },
            Some(p),
        ))
    }
}

/// Pull the backend out of a run config if the caller embedded one;
/// harness-level runs (tables, benches) have no backend axis.
fn experiment_backend(config: &Json) -> &str {
    config
        .get_opt("backend")
        .and_then(|b| b.as_str().ok())
        .unwrap_or("-")
}

/// An in-flight run: every artifact a writer produces goes through here,
/// so the run's products are hashed and listed in its manifest.
pub struct RunHandle<'a> {
    registry: &'a Registry,
    key: String,
    manifest: RunManifest,
}

impl RunHandle<'_> {
    pub fn key(&self) -> &str {
        &self.key
    }

    /// Short key — the on-disk run dir name, handy for log lines.
    pub fn key16(&self) -> &str {
        &self.key[..KEY_DIR_LEN]
    }

    /// Record an artifact from bytes; optionally materialize a legacy
    /// view at `view`.  Returns the content hash.
    pub fn record_bytes(
        &mut self,
        name: &str,
        bytes: &[u8],
        view: Option<&Path>,
    ) -> Result<String> {
        let hash = self.registry.put_bytes(bytes)?;
        if let Some(v) = view {
            self.registry.write_view(&hash, v)?;
        }
        self.push_ref(name, hash.clone(), bytes.len() as u64, view);
        Ok(hash)
    }

    /// Record an artifact that already exists on disk (checkpoints, the
    /// appended `BENCH_*.json` trajectories).  The file stays where it is
    /// and becomes its own view.
    pub fn record_file(&mut self, name: &str, path: &Path) -> Result<String> {
        let (hash, bytes) = self.registry.put_file(path)?;
        self.push_ref(name, hash.clone(), bytes, Some(path));
        Ok(hash)
    }

    /// Record every metric series as `<name>.csv`, with legacy views
    /// under `view_dir` — the registry-era `Metrics::flush_csv`.
    pub fn record_metrics(&mut self, metrics: &Metrics, view_dir: &Path) -> Result<()> {
        for (name, series) in &metrics.series {
            let file = format!("{name}.csv");
            self.record_bytes(&file, series.to_csv().as_bytes(), Some(&view_dir.join(&file)))?;
        }
        Ok(())
    }

    /// Replace the manifest's summary object.
    pub fn set_summary(&mut self, summary: Json) {
        self.manifest.summary = summary;
    }

    /// The registry this run records into (the supervisor's verified
    /// read-back path).
    pub fn registry(&self) -> &Registry {
        self.registry
    }

    /// The manifest as recorded so far (still `running` until `finish`).
    pub fn manifest(&self) -> &RunManifest {
        &self.manifest
    }

    /// Append a supervisor recovery record.
    pub fn push_recovery(&mut self, rec: RecoveryRecord) {
        self.manifest.recoveries.push(rec);
    }

    /// Persist the manifest mid-run, status still `Running` — the
    /// supervisor's crash-safety point after each periodic checkpoint,
    /// so a kill finds the checkpoint refs in a loadable manifest.
    pub fn save_progress(&self) -> Result<()> {
        self.manifest.save(&self.registry.manifest_path(&self.key))
    }

    /// Finish the run: writes the final manifest atomically.  This is the
    /// last write — a crash before it leaves the `running` manifest, so
    /// resume re-runs the cell (objects already staged are harmless:
    /// content-addressed and idempotent).
    pub fn finish(mut self, status: RunState) -> Result<RunManifest> {
        self.manifest.status = status;
        self.manifest
            .save(&self.registry.manifest_path(&self.key))?;
        Ok(self.manifest)
    }

    fn push_ref(&mut self, name: &str, sha256: String, bytes: u64, view: Option<&Path>) {
        // Re-recording a name replaces the ref (idempotent writers).
        self.manifest.artifacts.retain(|a| a.name != name);
        self.manifest.artifacts.push(ArtifactRef {
            name: name.to_string(),
            sha256,
            bytes,
            view: view.map(|p| p.to_string_lossy().into_owned()),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn temp_results(tag: &str) -> String {
        let dir = std::env::temp_dir().join(format!("sagebwd_reg_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir.to_string_lossy().into_owned()
    }

    #[test]
    fn put_bytes_content_addressed_and_idempotent() {
        let results = temp_results("put");
        let reg = Registry::open(&results).unwrap();
        let h1 = reg.put_bytes(b"hello registry").unwrap();
        let h2 = reg.put_bytes(b"hello registry").unwrap();
        assert_eq!(h1, h2);
        assert_eq!(h1.len(), 64);
        assert!(reg.has_object(&h1));
        assert_eq!(reg.read_object(&h1).unwrap(), b"hello registry");
        // No stray temp files.
        let objs: Vec<_> = fs::read_dir(reg.root().join("objects"))
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(objs, vec![h1.clone()]);
        assert_ne!(reg.put_bytes(b"other").unwrap(), h1);
        fs::remove_dir_all(&results).unwrap();
    }

    #[test]
    fn views_point_at_objects() {
        let results = temp_results("view");
        let reg = Registry::open(&results).unwrap();
        let h = reg.put_bytes(b"step,value\n0,1\n").unwrap();
        let view = PathBuf::from(&results).join("fig1/cell/train_loss.csv");
        reg.write_view(&h, &view).unwrap();
        assert_eq!(fs::read(&view).unwrap(), b"step,value\n0,1\n");
        // Re-pointing the view at new content replaces it.
        let h2 = reg.put_bytes(b"step,value\n0,2\n").unwrap();
        reg.write_view(&h2, &view).unwrap();
        assert_eq!(fs::read(&view).unwrap(), b"step,value\n0,2\n");
        fs::remove_dir_all(&results).unwrap();
    }

    #[test]
    fn run_key_is_stable_and_sensitive() {
        let a = json::parse(r#"{"steps":4,"variant":"sage_qknorm"}"#).unwrap();
        let b = json::parse(r#"{"variant":"sage_qknorm","steps":4}"#).unwrap();
        // Canonical (sorted-key) serialization: field order is identity-
        // irrelevant.
        assert_eq!(Registry::run_key(&a, "native"), Registry::run_key(&b, "native"));
        // Config and backend are both part of the identity.
        let c = json::parse(r#"{"steps":5,"variant":"sage_qknorm"}"#).unwrap();
        assert_ne!(Registry::run_key(&a, "native"), Registry::run_key(&c, "native"));
        assert_ne!(Registry::run_key(&a, "native"), Registry::run_key(&a, "xla"));
    }

    #[test]
    fn run_lifecycle_and_listing() {
        let results = temp_results("life");
        let reg = Registry::open(&results).unwrap();
        let cfg = json::parse(r#"{"kind":"demo","n":1}"#).unwrap();
        let key = Registry::run_key(&cfg, "-");
        assert!(reg.load_run(&key).unwrap().is_none());

        let mut run = reg.begin_run("demo", "demo_cell", cfg.clone()).unwrap();
        assert_eq!(run.key(), key);
        // begin_run writes a `running` manifest immediately.
        let m = reg.load_run(&key).unwrap().unwrap();
        assert_eq!(m.status, RunState::Running);

        run.record_bytes("out.csv", b"a,b\n1,2\n", None).unwrap();
        run.set_summary(Json::from_pairs(vec![("final_loss", Json::from(2.5))]));
        let done = run.finish(RunState::Complete).unwrap();
        assert_eq!(done.artifacts.len(), 1);
        assert_eq!(done.artifacts[0].bytes, 8);

        let m = reg.load_run(&key).unwrap().unwrap();
        assert_eq!(m, done);
        assert!(m.status.is_finished());
        assert!(reg.has_object(&m.artifacts[0].sha256));

        let listed = reg.list_runs().unwrap();
        assert_eq!(listed.len(), 1);
        assert_eq!(listed[0].0, &key[..KEY_DIR_LEN]);
        assert_eq!(listed[0].1, m);
        fs::remove_dir_all(&results).unwrap();
    }

    #[test]
    fn record_metrics_writes_series_views() {
        let results = temp_results("met");
        let reg = Registry::open(&results).unwrap();
        let mut metrics = Metrics::new();
        metrics.record("train_loss", 0, 2.5);
        metrics.record("train_loss", 1, 2.0);
        metrics.record("lr", 0, 1e-3);
        let mut run = reg
            .begin_run("train", "t", json::parse(r#"{"kind":"demo","n":2}"#).unwrap())
            .unwrap();
        let view_dir = PathBuf::from(&results).join("train_demo");
        run.record_metrics(&metrics, &view_dir).unwrap();
        let m = run.finish(RunState::Complete).unwrap();
        assert_eq!(m.artifacts.len(), 2); // lr.csv + train_loss.csv
        let loss = fs::read_to_string(view_dir.join("train_loss.csv")).unwrap();
        assert!(loss.starts_with("step,value\n0,2.5\n1,2\n"), "{loss}");
        // The view's bytes hash to the recorded address.
        let a = m.artifact("train_loss.csv").unwrap();
        assert_eq!(sha256::hex_digest(loss.as_bytes()), a.sha256);
        fs::remove_dir_all(&results).unwrap();
    }

    /// Corrupt the stored object for `hash` via `mutate` and assert the
    /// verified read reports a downcastable [`CorruptObject`].
    fn corrupt_and_read(tag: &str, mutate: impl FnOnce(Vec<u8>) -> Vec<u8>) -> CorruptObject {
        let results = temp_results(tag);
        let reg = Registry::open(&results).unwrap();
        let h = reg.put_bytes(b"precious artifact bytes").unwrap();
        let on_disk = fs::read(reg.object_path(&h)).unwrap();
        fs::write(reg.object_path(&h), mutate(on_disk)).unwrap();
        let err = reg.read_object(&h).unwrap_err();
        let corrupt = err
            .downcast_ref::<CorruptObject>()
            .unwrap_or_else(|| panic!("not a CorruptObject: {err:#}"))
            .clone();
        assert_eq!(corrupt.hash, h);
        assert_ne!(corrupt.actual, corrupt.hash);
        fs::remove_dir_all(&results).unwrap();
        corrupt
    }

    #[test]
    fn read_object_detects_flipped_byte() {
        let c = corrupt_and_read("flip", |mut b| {
            b[0] ^= 0xFF;
            b
        });
        assert_eq!(c.bytes, b"precious artifact bytes".len() as u64);
    }

    #[test]
    fn read_object_detects_truncation() {
        let c = corrupt_and_read("trunc", |b| b[..b.len() / 2].to_vec());
        assert!(c.bytes < b"precious artifact bytes".len() as u64);
    }

    #[test]
    fn read_object_detects_empty_object_file() {
        let c = corrupt_and_read("empty", |_| Vec::new());
        assert_eq!(c.bytes, 0);
        let msg = format!("{c}");
        assert!(msg.contains("corrupt registry object"), "{msg}");
    }

    #[test]
    fn put_bytes_self_heals_corrupt_object() {
        let results = temp_results("heal");
        let reg = Registry::open(&results).unwrap();
        let h = reg.put_bytes(b"good content").unwrap();
        fs::write(reg.object_path(&h), b"torn").unwrap();
        assert!(reg.read_object(&h).is_err());
        // Re-putting the same content rewrites the damaged object
        // instead of taking the idempotent early-out.
        assert_eq!(reg.put_bytes(b"good content").unwrap(), h);
        assert_eq!(reg.read_object(&h).unwrap(), b"good content");
        fs::remove_dir_all(&results).unwrap();
    }

    #[test]
    fn torn_write_fault_then_repair() {
        let results = temp_results("torn");
        let reg = Registry::open(&results).unwrap();
        crate::util::faults::install(crate::util::faults::parse_plan("torn@1").unwrap());
        let h = reg.put_bytes(b"checkpoint payload bytes").unwrap();
        // The address names the intended content, but the staged object
        // is torn: the verified read must catch it.
        let err = reg.read_object(&h).unwrap_err();
        assert!(err.downcast_ref::<CorruptObject>().is_some(), "{err:#}");
        // The fault fired once; re-putting repairs the object.
        assert_eq!(reg.put_bytes(b"checkpoint payload bytes").unwrap(), h);
        assert_eq!(reg.read_object(&h).unwrap(), b"checkpoint payload bytes");
        crate::util::faults::clear();
        fs::remove_dir_all(&results).unwrap();
    }

    #[test]
    fn resume_or_begin_preserves_prior_artifacts_and_recoveries() {
        let results = temp_results("resume");
        let reg = Registry::open(&results).unwrap();
        let cfg = json::parse(r#"{"kind":"demo","n":9}"#).unwrap();
        let key = Registry::run_key(&cfg, "-");

        // Fresh start: behaves like begin_run_keyed.
        let (run, prior) = reg
            .resume_or_begin("train", "t", cfg.clone(), key.clone())
            .unwrap();
        assert!(prior.is_none());
        let mut run = run;
        run.record_bytes("ckpt_000004", b"SBWD0002-pretend", None).unwrap();
        run.push_recovery(RecoveryRecord {
            attempt: 1,
            at_step: 6,
            resume_step: 4,
            reason: "max_attn_logit 80 > 50".into(),
            action: "lr_backoff".into(),
            peak_lr: 0.05,
            tokens_per_step: 128,
            variant: "sage_noqknorm".into(),
        });
        run.save_progress().unwrap();
        drop(run); // simulated crash: manifest left `running`

        let (resumed, prior) = reg
            .resume_or_begin("train", "t", cfg.clone(), key.clone())
            .unwrap();
        let p = prior.unwrap();
        assert_eq!(p.status, RunState::Running);
        assert!(p.artifact("ckpt_000004").is_some());
        // The new running manifest carries the refs forward on disk.
        assert_eq!(resumed.manifest().recoveries.len(), 1);
        let on_disk = reg.load_run(&key).unwrap().unwrap();
        assert!(on_disk.artifact("ckpt_000004").is_some());
        assert_eq!(on_disk.recoveries.len(), 1);
        fs::remove_dir_all(&results).unwrap();
    }

    #[test]
    fn record_file_hashes_in_place() {
        let results = temp_results("file");
        let reg = Registry::open(&results).unwrap();
        let ext = PathBuf::from(&results).join("final.ckpt");
        fs::write(&ext, b"SBWD0002-pretend").unwrap();
        let mut run = reg
            .begin_run("train", "t", json::parse(r#"{"kind":"demo","n":3}"#).unwrap())
            .unwrap();
        let h = run.record_file("final.ckpt", &ext).unwrap();
        let m = run.finish(RunState::Complete).unwrap();
        // Source untouched, object stored, ref points at the source path.
        assert_eq!(fs::read(&ext).unwrap(), b"SBWD0002-pretend");
        assert_eq!(reg.read_object(&h).unwrap(), b"SBWD0002-pretend");
        assert_eq!(
            m.artifact("final.ckpt").unwrap().view.as_deref(),
            Some(ext.to_string_lossy().as_ref())
        );
        fs::remove_dir_all(&results).unwrap();
    }
}
