//! Backend selection: one executor interface over the native CPU kernels
//! and the AOT XLA artifact path (DESIGN.md §4).
//!
//! Every trace/bench experiment harness talks to [`AttentionBackend`]
//! instead of the XLA [`Runtime`] directly, so the same harness runs:
//!
//! * `--backend native` — [`NativeBackend`]: artifact *names* are resolved
//!   against the registry mirrored from `python/compile/configs.py`
//!   (`TRACE_VARIANTS`, `bench_variants`) and executed by the in-process
//!   kernels in `crate::kernels`. No `artifacts/` directory, Python
//!   toolchain, or XLA runtime required — this is what CI uses.
//! * `--backend xla` — [`XlaBackend`]: the unchanged AOT path; loads
//!   `<name>.hlo.txt` + manifest, compiles once under PJRT, executes many.
//!
//! Output ABI is identical: the native backend produces values in
//! `aot.TRACE_OUTPUTS` order — `o, dq, dk, dv, delta, rms_p, rms_dp,
//! rms_ds, p, dp, ds` — and `o[, dq, dk, dv]` for bench artifacts.

use anyhow::{bail, Context, Result};

use crate::kernels::{self, AttnConfig};
use crate::runtime::{Runtime, Value};
use crate::telemetry::{qerr, trace};
use crate::tensor::{linalg, Tensor, Workspace};
use crate::util::{faults, stats};

/// A runtime capable of executing attention trace/bench artifacts by name.
pub trait AttentionBackend {
    /// Backend name for logs/telemetry ("native" or "xla").
    fn name(&self) -> &'static str;

    /// Execute one artifact; outputs in manifest order.
    fn execute(&mut self, artifact: &str, inputs: &[Value]) -> Result<Vec<Value>>;

    /// Execute many **independent** calls of the same artifact; results in
    /// call order.  Default is the serial loop; implementations may fan
    /// out (the native backend partitions calls over a scoped-thread pool
    /// — per-head parallelism for the training engine) but must return
    /// results bitwise-identical to the serial path, since every call is
    /// computed whole by exactly one worker.
    fn execute_many(&mut self, artifact: &str, calls: &[Vec<Value>]) -> Result<Vec<Vec<Value>>> {
        calls.iter().map(|c| self.execute(artifact, c)).collect()
    }
}

/// Build a backend from the `--backend` CLI flag.
pub fn make_backend(name: &str, artifacts_dir: &str) -> Result<Box<dyn AttentionBackend>> {
    match name {
        "native" => Ok(Box::new(NativeBackend::new())),
        "xla" => Ok(Box::new(XlaBackend::new(Runtime::new(artifacts_dir)?))),
        other => bail!("unknown backend {other:?}; known: native, xla"),
    }
}

// ---------------------------------------------------------------------------
// XLA backend: thin adapter over the unchanged Runtime
// ---------------------------------------------------------------------------

/// The AOT artifact path, unchanged: compile once, execute many.
pub struct XlaBackend {
    runtime: Runtime,
}

impl XlaBackend {
    pub fn new(runtime: Runtime) -> XlaBackend {
        XlaBackend { runtime }
    }

    pub fn runtime_mut(&mut self) -> &mut Runtime {
        &mut self.runtime
    }
}

impl AttentionBackend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn execute(&mut self, artifact: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        self.runtime.execute(artifact, inputs)
    }
}

// ---------------------------------------------------------------------------
// Native backend: artifact registry + in-process kernels
// ---------------------------------------------------------------------------

/// What a trace artifact computes (mirrors `configs.TraceConfig.impl`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum TraceImpl {
    Fpa,
    Sage,
    Pseudo,
}

#[derive(Debug, Clone, Copy)]
struct TraceSpec {
    name: &'static str,
    imp: TraceImpl,
    n: usize,
    k_smoothing: bool,
    q_smoothing: bool,
    quant_ds: bool,
}

const TRACE_D: usize = 64;
const TRACE_BLOCK: usize = 32;

/// The registry mirrored from `python/compile/configs.TRACE_VARIANTS`.
const TRACE_SPECS: &[TraceSpec] = &[
    TraceSpec { name: "trace_fpa", imp: TraceImpl::Fpa, n: 128, k_smoothing: true, q_smoothing: false, quant_ds: true },
    TraceSpec { name: "trace_sage", imp: TraceImpl::Sage, n: 128, k_smoothing: true, q_smoothing: false, quant_ds: true },
    TraceSpec { name: "trace_pseudo", imp: TraceImpl::Pseudo, n: 128, k_smoothing: true, q_smoothing: false, quant_ds: true },
    TraceSpec { name: "trace_pseudo_nosm", imp: TraceImpl::Pseudo, n: 128, k_smoothing: false, q_smoothing: false, quant_ds: true },
    TraceSpec { name: "trace_pseudo_qksm", imp: TraceImpl::Pseudo, n: 128, k_smoothing: true, q_smoothing: true, quant_ds: true },
    TraceSpec { name: "trace_sage_nosm", imp: TraceImpl::Sage, n: 128, k_smoothing: false, q_smoothing: false, quant_ds: true },
    TraceSpec { name: "trace_sage_qksm", imp: TraceImpl::Sage, n: 128, k_smoothing: true, q_smoothing: true, quant_ds: true },
    TraceSpec { name: "trace_fpa_n512", imp: TraceImpl::Fpa, n: 512, k_smoothing: true, q_smoothing: false, quant_ds: true },
    TraceSpec { name: "trace_sage_n512", imp: TraceImpl::Sage, n: 512, k_smoothing: true, q_smoothing: false, quant_ds: true },
    TraceSpec { name: "trace_sage_dsfp", imp: TraceImpl::Sage, n: 128, k_smoothing: true, q_smoothing: false, quant_ds: false },
    TraceSpec { name: "trace_pseudo_dsfp", imp: TraceImpl::Pseudo, n: 128, k_smoothing: true, q_smoothing: false, quant_ds: false },
];

/// In-process CPU executor for trace/bench artifacts.  Owns a reusable
/// [`Workspace`] (serial calls) plus one per fan-out worker slot, so
/// back-to-back kernel calls (the training hot loop) run allocation-free
/// after warmup on both the serial and the parallel path.
#[derive(Debug, Default)]
pub struct NativeBackend {
    ws: Workspace,
    /// Per-worker arenas for [`Self::execute_many`], indexed by partition
    /// slot — persistent across batches so each worker's pools stay warm.
    worker_ws: Vec<Workspace>,
}

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend::default()
    }
}

/// Dispatch one artifact by name against the in-process kernels.  Free
/// function (not a method) so [`NativeBackend::execute_many`] workers can
/// run it with per-thread workspaces.
fn execute_native(artifact: &str, inputs: &[Value], ws: &mut Workspace) -> Result<Vec<Value>> {
    if let Some(spec) = TRACE_SPECS.iter().find(|s| s.name == artifact) {
        return run_trace_artifact(*spec, inputs, ws)
            .with_context(|| format!("native backend executing {artifact}"));
    }
    if let Some(bench) = parse_bench_name(artifact) {
        return run_bench_artifact(bench, inputs, ws)
            .with_context(|| format!("native backend executing {artifact}"));
    }
    if let Some(spec) = parse_model_attn_name(artifact) {
        return run_model_attn_artifact(spec, inputs, ws)
            .with_context(|| format!("native backend executing {artifact}"));
    }
    if artifact.starts_with("init_")
        || artifact.starts_with("grad_step_")
        || artifact.starts_with("apply_step_")
    {
        bail!(
            "artifact {artifact} is a monolithic AOT training executable; the native \
             engine trains through `model_attn_*` attention calls instead (any training \
             subcommand with --backend native) — to execute this artifact itself, run \
             `make artifacts` and use --backend xla"
        );
    }
    bail!("native backend knows no artifact named {artifact:?}");
}

/// Total MAC-volume estimate (`Σ n²·d` over calls) used to gate the
/// scoped-thread fan-out against [`linalg::PAR_MIN_BATCH_VOLUME`]:
/// toy-scale batches stay serial so spawn latency never lands on tiny
/// hot loops.
fn batch_mac_volume(calls: &[Vec<Value>]) -> usize {
    calls
        .iter()
        .filter_map(|c| c.first())
        .map(|v| match v.shape() {
            [n, d] => n.saturating_mul(*n).saturating_mul(*d),
            _ => 0,
        })
        .sum()
}

impl AttentionBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn execute(&mut self, artifact: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        execute_native(artifact, inputs, &mut self.ws)
    }

    /// Partition the calls over a std scoped-thread pool (`SAGEBWD_THREADS`
    /// workers, default `available_parallelism`).  Each call is computed
    /// whole by one worker with its own [`Workspace`], so outputs are
    /// bitwise-identical to the serial loop at any thread count.
    fn execute_many(&mut self, artifact: &str, calls: &[Vec<Value>]) -> Result<Vec<Vec<Value>>> {
        let _t = trace::span("execute_many");
        trace::counter_add("exec_many_batches", 1);
        trace::counter_add("exec_many_calls", calls.len() as u64);
        // Fault plane (DESIGN.md §16): an armed `panic@S` fault forces the
        // scoped-thread fan-out even for toy batches (tier-1 tests run
        // below the volume gate, where no worker would otherwise spawn)
        // and makes the first worker panic before computing anything.
        let inject_panic = faults::take_worker_panic();
        let threads = linalg::thread_count().min(calls.len()).max(1);
        if !inject_panic
            && (threads <= 1 || batch_mac_volume(calls) < linalg::PAR_MIN_BATCH_VOLUME)
        {
            trace::counter_add("exec_many_serial_batches", 1);
            return calls
                .iter()
                .map(|c| execute_native(artifact, c, &mut self.ws))
                .collect();
        }
        let parts = linalg::partition(calls.len(), threads);
        // Fan-out occupancy: workers actually spawned vs the thread cap.
        trace::counter_add("exec_many_workers", parts.len() as u64);
        trace::counter_max("exec_many_peak_workers", parts.len() as u64);
        while self.worker_ws.len() < parts.len() {
            self.worker_ws.push(Workspace::new());
        }
        let mut results: Vec<Option<Result<Vec<Value>>>> = Vec::with_capacity(calls.len());
        results.resize_with(calls.len(), || None);
        std::thread::scope(|s| {
            let mut rest = results.as_mut_slice();
            let mut pool = self.worker_ws.iter_mut();
            for (wi, (lo, hi)) in parts.into_iter().enumerate() {
                let (chunk, tail) = rest.split_at_mut(hi - lo);
                rest = tail;
                let calls_chunk = &calls[lo..hi];
                let ws = pool.next().expect("worker_ws sized to the partition count");
                let fire_fault = inject_panic && wi == 0;
                s.spawn(move || {
                    // A worker panic (injected or a kernel bug) must not
                    // abort the process: catch the unwind and turn the
                    // worker's unfilled slots into errors the trainer and
                    // supervisor can recover from.
                    let unwound = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        if fire_fault {
                            faults::injected_panic();
                        }
                        // Each call is computed whole by this worker: the
                        // inner auto-dispatching GEMMs stay serial so T
                        // workers never nest-spawn T more threads each.
                        linalg::with_serial(|| {
                            for (slot, call) in chunk.iter_mut().zip(calls_chunk) {
                                *slot = Some(execute_native(artifact, call, ws));
                            }
                        });
                    }));
                    if let Err(payload) = unwound {
                        let msg = payload
                            .downcast_ref::<&str>()
                            .map(|m| m.to_string())
                            .or_else(|| payload.downcast_ref::<String>().cloned())
                            .unwrap_or_else(|| "worker panicked".to_string());
                        for slot in chunk.iter_mut() {
                            if slot.is_none() {
                                *slot = Some(Err(anyhow::anyhow!(
                                    "execute_many worker panicked: {msg}"
                                )));
                            }
                        }
                    }
                });
            }
        });
        results
            .into_iter()
            .map(|r| match r {
                Some(res) => res,
                // Unreachable by construction (every slot is either filled
                // by its worker or error-marked after a caught unwind),
                // but a logic bug here must be an error, not a panic.
                None => Err(anyhow::anyhow!(
                    "internal: execute_many slot never filled by its worker"
                )),
            })
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Model-attention artifacts: causal per-head attention for the native
// training engine (`model/transformer.rs`)
// ---------------------------------------------------------------------------

/// `model_attn_<impl>_<fwd|fwdbwd>_n<N>_d<D>` — always causal.
///
/// ABI: `fwd` takes `(q, k, v)`, returns `[o, max_logit]`; `fwdbwd` takes
/// `(q, k, v, dO)` and returns `[o, dq, dk, dv]` (FlashAttention-style
/// recompute: backward re-runs the forward).  The scalar `max_logit` is
/// `kernels::max_abs_logit` on the *given* q/k in full precision — the
/// trainer's divergence statistic (DESIGN.md §10).  Only the `fwd` path
/// computes it: every training backward is preceded by the forward that
/// already recorded the statistic, so the O(N²·d) sweep is not repeated
/// on the backward hot path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct ModelAttnSpec {
    imp: ModelAttnImpl,
    fwdbwd: bool,
    n: usize,
    d: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ModelAttnImpl {
    Fpa,
    Sage,
    SageNosm,
    SageQksm,
}

fn parse_model_attn_name(artifact: &str) -> Option<ModelAttnSpec> {
    let rest = artifact.strip_prefix("model_attn_")?;
    let (imp, rest) = if let Some(r) = rest.strip_prefix("sage_nosm_") {
        (ModelAttnImpl::SageNosm, r)
    } else if let Some(r) = rest.strip_prefix("sage_qksm_") {
        (ModelAttnImpl::SageQksm, r)
    } else if let Some(r) = rest.strip_prefix("sage_") {
        (ModelAttnImpl::Sage, r)
    } else if let Some(r) = rest.strip_prefix("fpa_") {
        (ModelAttnImpl::Fpa, r)
    } else {
        return None;
    };
    let (fwdbwd, rest) = if let Some(r) = rest.strip_prefix("fwdbwd_") {
        (true, r)
    } else if let Some(r) = rest.strip_prefix("fwd_") {
        (false, r)
    } else {
        return None;
    };
    let rest = rest.strip_prefix('n')?;
    let (n_str, d_part) = rest.split_once("_d")?;
    let n = n_str.parse().ok()?;
    let d = d_part.parse().ok()?;
    Some(ModelAttnSpec { imp, fwdbwd, n, d })
}

fn model_attn_cfg(spec: ModelAttnSpec) -> AttnConfig {
    let (k_sm, q_sm) = match spec.imp {
        ModelAttnImpl::Fpa => (false, false), // unused by the FPA oracle
        ModelAttnImpl::Sage => (true, false),
        ModelAttnImpl::SageNosm => (false, false),
        ModelAttnImpl::SageQksm => (true, true),
    };
    AttnConfig {
        block_q: TRACE_BLOCK,
        block_kv: TRACE_BLOCK,
        causal: true,
        k_smoothing: k_sm,
        q_smoothing: q_sm,
        quant_ds: true,
    }
}

fn run_model_attn_artifact(spec: ModelAttnSpec, inputs: &[Value], ws: &mut Workspace) -> Result<Vec<Value>> {
    let _t = trace::span("attention");
    let cfg = model_attn_cfg(spec);
    if spec.imp != ModelAttnImpl::Fpa && spec.n % TRACE_BLOCK != 0 {
        bail!(
            "sage model attention tiles at block {TRACE_BLOCK}: N={} not divisible",
            spec.n
        );
    }
    if spec.fwdbwd {
        let ins = take_f32_inputs(inputs, 4, spec.n, spec.d)?;
        let (q, k, v, do_) = (ins[0], ins[1], ins[2], ins[3]);
        let tr = match spec.imp {
            ModelAttnImpl::Fpa => kernels::fpa_bwd(q, k, v, do_, true)?,
            _ => {
                let tr = kernels::sage_bwd_ws(q, k, v, do_, &cfg, ws)?;
                // Sampled quantization-error probe: re-run the exact FPA
                // oracle and fold the seven matmul errors (read-only —
                // outputs and numerics are untouched, see telemetry::qerr).
                if qerr::active() {
                    let _p = trace::span("qerr_probe");
                    let fp = kernels::fpa_bwd(q, k, v, do_, true)?;
                    qerr::probe(&tr, &fp, cfg.causal);
                }
                tr
            }
        };
        Ok(vec![
            Value::F32(tr.o),
            Value::F32(tr.dq),
            Value::F32(tr.dk),
            Value::F32(tr.dv),
        ])
    } else {
        let ins = take_f32_inputs(inputs, 3, spec.n, spec.d)?;
        let (q, k, v) = (ins[0], ins[1], ins[2]);
        let ml = kernels::max_abs_logit(q, k, true)?;
        let o = match spec.imp {
            ModelAttnImpl::Fpa => kernels::fpa_fwd(q, k, v, true)?.0,
            _ => kernels::sage_fwd_ws(q, k, v, &cfg, ws)?.0,
        };
        Ok(vec![Value::F32(o), Value::F32(Tensor::scalar(ml))])
    }
}

fn take_f32_inputs(inputs: &[Value], want: usize, n: usize, d: usize) -> Result<Vec<&Tensor>> {
    if inputs.len() != want {
        bail!("expected {want} inputs, got {}", inputs.len());
    }
    let mut out = Vec::with_capacity(want);
    for (idx, v) in inputs.iter().enumerate() {
        let t = v
            .as_f32()
            .with_context(|| format!("input {idx} must be f32"))?;
        if t.shape != [n, d] {
            bail!("input {idx}: expected shape [{n}, {d}], got {:?}", t.shape);
        }
        out.push(t);
    }
    Ok(out)
}

fn trace_cfg(spec: TraceSpec) -> AttnConfig {
    AttnConfig {
        block_q: TRACE_BLOCK,
        block_kv: TRACE_BLOCK,
        causal: false,
        k_smoothing: spec.k_smoothing,
        q_smoothing: spec.q_smoothing,
        quant_ds: spec.quant_ds,
    }
}

fn run_trace_artifact(spec: TraceSpec, inputs: &[Value], ws: &mut Workspace) -> Result<Vec<Value>> {
    let ins = take_f32_inputs(inputs, 4, spec.n, TRACE_D)?;
    let (q, k, v, do_) = (ins[0], ins[1], ins[2], ins[3]);
    let cfg = trace_cfg(spec);
    let trace = match spec.imp {
        TraceImpl::Fpa => kernels::fpa_bwd(q, k, v, do_, cfg.causal)?,
        TraceImpl::Pseudo => kernels::pseudo_quant_trace(q, k, v, do_, &cfg)?,
        TraceImpl::Sage => {
            // Mirror aot.export_trace: the blocked kernel produces
            // (o, dq, dk, dv); the materialized intermediates come from the
            // §5.4 pseudo trace (same quantization scheme, dense layout).
            let sage = kernels::sage_bwd_ws(q, k, v, do_, &cfg, ws)?;
            let mut it = kernels::pseudo_quant_trace(q, k, v, do_, &cfg)?;
            it.o = sage.o;
            it.dq = sage.dq;
            it.dk = sage.dk;
            it.dv = sage.dv;
            it
        }
    };
    // aot.TRACE_OUTPUTS order.
    Ok(vec![
        Value::F32(trace.o),
        Value::F32(trace.dq),
        Value::F32(trace.dk),
        Value::F32(trace.dv),
        Value::F32(trace.delta),
        Value::F32(Tensor::scalar(stats::rms(&trace.p.data) as f32)),
        Value::F32(Tensor::scalar(stats::rms(&trace.dp.data) as f32)),
        Value::F32(Tensor::scalar(stats::rms(&trace.ds.data) as f32)),
        Value::F32(trace.p),
        Value::F32(trace.dp),
        Value::F32(trace.ds),
    ])
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct BenchSpec {
    imp: BenchImpl,
    fwdbwd: bool,
    d: usize,
    n: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum BenchImpl {
    Sage,
    Fa2,
    Naive,
}

/// Parse `bench_{sage|fa2|naive}_{fwd|fwdbwd}_d{D}_n{N}`.
fn parse_bench_name(artifact: &str) -> Option<BenchSpec> {
    let rest = artifact.strip_prefix("bench_")?;
    let (imp, rest) = if let Some(r) = rest.strip_prefix("sage_") {
        (BenchImpl::Sage, r)
    } else if let Some(r) = rest.strip_prefix("fa2_") {
        (BenchImpl::Fa2, r)
    } else if let Some(r) = rest.strip_prefix("naive_") {
        (BenchImpl::Naive, r)
    } else {
        return None;
    };
    let (fwdbwd, rest) = if let Some(r) = rest.strip_prefix("fwdbwd_") {
        (true, r)
    } else if let Some(r) = rest.strip_prefix("fwd_") {
        (false, r)
    } else {
        return None;
    };
    let rest = rest.strip_prefix('d')?;
    let (d_str, n_part) = rest.split_once("_n")?;
    let d = d_str.parse().ok()?;
    let n = n_part.parse().ok()?;
    Some(BenchSpec { imp, fwdbwd, d, n })
}

fn run_bench_artifact(spec: BenchSpec, inputs: &[Value], ws: &mut Workspace) -> Result<Vec<Value>> {
    let cfg = AttnConfig {
        block_q: TRACE_BLOCK,
        block_kv: TRACE_BLOCK,
        ..Default::default()
    };
    if spec.fwdbwd {
        let ins = take_f32_inputs(inputs, 4, spec.n, spec.d)?;
        let (q, k, v, do_) = (ins[0], ins[1], ins[2], ins[3]);
        let tr = match spec.imp {
            BenchImpl::Sage => kernels::sage_bwd_ws(q, k, v, do_, &cfg, ws)?,
            // Baselines differentiate exactly (aot uses jnp autodiff).
            BenchImpl::Fa2 | BenchImpl::Naive => kernels::fpa_bwd(q, k, v, do_, cfg.causal)?,
        };
        Ok(vec![
            Value::F32(tr.o),
            Value::F32(tr.dq),
            Value::F32(tr.dk),
            Value::F32(tr.dv),
        ])
    } else {
        let ins = take_f32_inputs(inputs, 3, spec.n, spec.d)?;
        let (q, k, v) = (ins[0], ins[1], ins[2]);
        let o = match spec.imp {
            BenchImpl::Sage => kernels::sage_fwd_ws(q, k, v, &cfg, ws)?.0,
            BenchImpl::Fa2 => kernels::fa2_fwd_ws(q, k, v, &cfg, ws)?.0,
            BenchImpl::Naive => kernels::fpa_fwd(q, k, v, cfg.causal)?.0,
        };
        Ok(vec![Value::F32(o)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::common::gaussian_qkvdo;

    fn trace_inputs(n: usize, seed: u64) -> Vec<Value> {
        gaussian_qkvdo(n, TRACE_D, 1.0, 1.0, 1.0, 1.0, seed)
            .into_iter()
            .map(Value::F32)
            .collect()
    }

    #[test]
    fn native_trace_fpa_output_abi() {
        let mut be = NativeBackend::new();
        let out = be.execute("trace_fpa", &trace_inputs(128, 1)).unwrap();
        assert_eq!(out.len(), 11);
        assert_eq!(out[0].shape(), &[128, 64]); // o
        assert_eq!(out[4].shape(), &[128]); // delta
        assert_eq!(out[5].shape(), &[] as &[usize]); // rms_p scalar
        assert_eq!(out[8].shape(), &[128, 128]); // p
        // P rows sum to 1.
        let p = out[8].as_f32().unwrap();
        for row in p.data.chunks(128) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn native_rejects_bad_inputs() {
        let mut be = NativeBackend::new();
        let mut bad = trace_inputs(128, 2);
        bad.truncate(3);
        assert!(be.execute("trace_fpa", &bad).is_err());
        assert!(be.execute("trace_fpa", &trace_inputs(64, 3)).is_err()); // wrong N
        let err = be.execute("no_such_artifact", &[]).unwrap_err();
        assert!(format!("{err:#}").contains("no_such_artifact"));
    }

    #[test]
    fn native_training_artifacts_guided_to_xla() {
        let mut be = NativeBackend::new();
        let err = be.execute("grad_step_sage_qknorm", &[]).unwrap_err();
        assert!(format!("{err:#}").contains("--backend xla"));
    }

    #[test]
    fn bench_name_parsing() {
        let s = parse_bench_name("bench_sage_fwdbwd_d64_n256").unwrap();
        assert_eq!(s, BenchSpec { imp: BenchImpl::Sage, fwdbwd: true, d: 64, n: 256 });
        let s = parse_bench_name("bench_naive_fwd_d128_n128").unwrap();
        assert_eq!(s, BenchSpec { imp: BenchImpl::Naive, fwdbwd: false, d: 128, n: 128 });
        assert!(parse_bench_name("bench_bogus_fwd_d64_n128").is_none());
        assert!(parse_bench_name("trace_fpa").is_none());
    }

    #[test]
    fn native_bench_artifacts_run() {
        let mut be = NativeBackend::new();
        let qkvdo = gaussian_qkvdo(128, 64, 1.0, 1.0, 1.0, 1.0, 4);
        let fwd_inputs: Vec<Value> = qkvdo[..3].iter().cloned().map(Value::F32).collect();
        let out = be.execute("bench_fa2_fwd_d64_n128", &fwd_inputs).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape(), &[128, 64]);
        let all_inputs: Vec<Value> = qkvdo.iter().cloned().map(Value::F32).collect();
        let out = be.execute("bench_sage_fwdbwd_d64_n128", &all_inputs).unwrap();
        assert_eq!(out.len(), 4);
    }

    #[test]
    fn execute_many_matches_serial_execute() {
        let mut be = NativeBackend::new();
        // n²·d per call is large enough that the scoped-thread fan-out
        // engages whenever the host has >1 core; the assertion is the
        // determinism contract — parallel output == serial output, bitwise.
        let artifact = "bench_sage_fwd_d64_n256";
        let calls: Vec<Vec<Value>> = (0..3u64)
            .map(|seed| {
                let qkvdo = gaussian_qkvdo(256, 64, 1.0, 1.0, 1.0, 1.0, 40 + seed);
                qkvdo[..3].iter().cloned().map(Value::F32).collect()
            })
            .collect();
        let many = be.execute_many(artifact, &calls).unwrap();
        assert_eq!(many.len(), 3);
        for (call, out) in calls.iter().zip(&many) {
            let serial = be.execute(artifact, call).unwrap();
            assert_eq!(
                out[0].as_f32().unwrap().data,
                serial[0].as_f32().unwrap().data,
                "parallel batch result differs from serial"
            );
        }
        // Errors propagate out of the batch.
        let mut bad = calls.clone();
        bad[1].truncate(2);
        assert!(be.execute_many(artifact, &bad).is_err());
    }

    #[test]
    fn injected_worker_panic_is_caught_and_retires() {
        // An armed `panic@S` fault forces the scoped-thread fan-out even for
        // tiny batches, fires exactly once inside a worker, and surfaces as
        // an Err — never an abort.  The clause retires on arming, so the
        // very next batch (e.g. a supervisor retry) succeeds.
        let mut be = NativeBackend::new();
        let artifact = "bench_sage_fwd_d64_n128";
        let calls: Vec<Vec<Value>> = (0..2u64)
            .map(|seed| {
                let qkvdo = gaussian_qkvdo(128, 64, 1.0, 1.0, 1.0, 1.0, 90 + seed);
                qkvdo[..3].iter().cloned().map(Value::F32).collect()
            })
            .collect();
        crate::util::faults::install(crate::util::faults::parse_plan("panic@0").unwrap());
        crate::util::faults::begin_step(0);
        let err = be.execute_many(artifact, &calls).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("worker panicked"), "unexpected error: {msg}");
        assert!(msg.contains(crate::util::faults::INJECTED_PANIC_MSG), "unexpected error: {msg}");
        // Fault retired: the same plan replayed at the same step stays quiet.
        crate::util::faults::begin_step(0);
        let ok = be.execute_many(artifact, &calls).unwrap();
        assert_eq!(ok.len(), 2);
        crate::util::faults::clear();
        // Output after the fault matches a serial execute (no poisoned state).
        let serial = be.execute(artifact, &calls[0]).unwrap();
        assert_eq!(ok[0][0].as_f32().unwrap().data, serial[0].as_f32().unwrap().data);
    }

    #[test]
    fn model_attn_name_parsing() {
        let s = parse_model_attn_name("model_attn_fpa_fwd_n32_d16").unwrap();
        assert_eq!(s, ModelAttnSpec { imp: ModelAttnImpl::Fpa, fwdbwd: false, n: 32, d: 16 });
        let s = parse_model_attn_name("model_attn_sage_nosm_fwdbwd_n64_d16").unwrap();
        assert_eq!(s, ModelAttnSpec { imp: ModelAttnImpl::SageNosm, fwdbwd: true, n: 64, d: 16 });
        let s = parse_model_attn_name("model_attn_sage_fwd_n32_d8").unwrap();
        assert_eq!(s.imp, ModelAttnImpl::Sage);
        assert!(parse_model_attn_name("model_attn_bogus_fwd_n32_d8").is_none());
        assert!(parse_model_attn_name("bench_sage_fwd_d64_n128").is_none());
    }

    #[test]
    fn model_attn_fwd_abi_and_causality() {
        let mut be = NativeBackend::new();
        let qkvdo = gaussian_qkvdo(32, 16, 1.0, 1.0, 1.0, 1.0, 11);
        let fwd: Vec<Value> = qkvdo[..3].iter().cloned().map(Value::F32).collect();
        let out = be.execute("model_attn_fpa_fwd_n32_d16", &fwd).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].shape(), &[32, 16]);
        assert_eq!(out[1].shape(), &[] as &[usize]); // max_logit scalar
        // Causality: row 0 can only attend to itself ⟹ o[0,:] == v[0,:].
        let o = out[0].as_f32().unwrap();
        let v = qkvdo[2].clone();
        for c in 0..16 {
            assert!((o.data[c] - v.data[c]).abs() < 1e-5, "col {c}");
        }
        let ml = out[1].as_f32().unwrap().item();
        let want = crate::kernels::max_abs_logit(&qkvdo[0], &qkvdo[1], true).unwrap();
        assert!((ml - want).abs() < 1e-6);
    }

    #[test]
    fn model_attn_fwdbwd_matches_fpa_kernel() {
        let mut be = NativeBackend::new();
        let qkvdo = gaussian_qkvdo(32, 16, 1.0, 1.0, 1.0, 1.0, 12);
        let all: Vec<Value> = qkvdo.iter().cloned().map(Value::F32).collect();
        let out = be.execute("model_attn_fpa_fwdbwd_n32_d16", &all).unwrap();
        assert_eq!(out.len(), 4);
        let tr = crate::kernels::fpa_bwd(&qkvdo[0], &qkvdo[1], &qkvdo[2], &qkvdo[3], true)
            .unwrap();
        for (idx, want) in [(1, &tr.dq), (2, &tr.dk), (3, &tr.dv)] {
            let got = out[idx].as_f32().unwrap();
            assert!(got.rel_l2(want) < 1e-6, "output {idx}");
        }
        // The sage variant runs too and tracks the oracle directionally.
        let out_s = be.execute("model_attn_sage_fwdbwd_n32_d16", &all).unwrap();
        let dq_s = out_s[1].as_f32().unwrap();
        assert!(dq_s.cossim(&tr.dq) > 0.97, "sage dq cossim {}", dq_s.cossim(&tr.dq));
        // Sage needs block-aligned N.
        let short: Vec<Value> = gaussian_qkvdo(16, 8, 1.0, 1.0, 1.0, 1.0, 13)
            .iter().cloned().map(Value::F32).collect();
        assert!(be.execute("model_attn_sage_fwdbwd_n16_d8", &short).is_err());
        assert!(be.execute("model_attn_fpa_fwdbwd_n16_d8", &short).is_ok());
    }

    #[test]
    fn sage_trace_close_to_fpa_at_unit_sigma() {
        // The runtime_integration tolerance, artifact-free.
        let mut be = NativeBackend::new();
        let inputs = trace_inputs(128, 5);
        let sage = be.execute("trace_sage", &inputs).unwrap();
        let fpa = be.execute("trace_fpa", &inputs).unwrap();
        for (idx, name, min_cos) in
            [(0, "o", 0.999), (1, "dq", 0.99), (2, "dk", 0.99), (3, "dv", 0.999)]
        {
            let s = sage[idx].as_f32().unwrap();
            let f = fpa[idx].as_f32().unwrap();
            let c = crate::util::stats::cossim(&s.data, &f.data);
            assert!(c > min_cos, "{name}: cossim {c}");
        }
    }
}
