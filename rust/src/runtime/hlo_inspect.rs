//! HLO-text static analyzer — the Rust half of the §Perf L2 profiling
//! (python/compile/perf_report.py is the build-time half).
//!
//! Parses the artifact's HLO text into an op histogram and derived
//! quality signals (dot count, while count, estimated FLOPs from dot
//! shapes) without needing a compiler in the loop.  Powers
//! `sagebwd inspect --artifact X --stats`.

use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

use anyhow::{Context, Result};

/// Parsed statistics for one HLO module.
#[derive(Debug, Default, Clone)]
pub struct HloStats {
    pub total_ops: usize,
    pub by_op: BTreeMap<String, usize>,
    /// (m, k, n) per dot derived from shapes — rough FLOP accounting.
    pub dot_flops: u64,
    pub bytes: usize,
}

impl HloStats {
    pub fn count(&self, op: &str) -> usize {
        self.by_op.get(op).copied().unwrap_or(0)
    }

    pub fn top(&self, n: usize) -> Vec<(&str, usize)> {
        let mut v: Vec<(&str, usize)> = self
            .by_op
            .iter()
            .map(|(k, &c)| (k.as_str(), c))
            .collect();
        v.sort_by_key(|&(_, c)| std::cmp::Reverse(c));
        v.truncate(n);
        v
    }
}

/// `f32[128,64]{1,0}` → product of dims (element count); None for scalars
/// and tuples.
fn numel(shape: &str) -> Option<u64> {
    let open = shape.find('[')?;
    let close = shape[open..].find(']')? + open;
    let dims = &shape[open + 1..close];
    if dims.is_empty() {
        return Some(1);
    }
    dims.split(',')
        .map(|d| d.trim().parse::<u64>().ok())
        .product::<Option<u64>>()
}

/// Parse HLO text into stats.  This is a line-shape parser, not a full
/// grammar: each instruction line is `%name = <shape> opcode(...)`.
pub fn analyze_text(text: &str) -> HloStats {
    let mut stats = HloStats {
        bytes: text.len(),
        ..Default::default()
    };
    for line in text.lines() {
        let trimmed = line.trim_start();
        if trimmed.starts_with("//") || trimmed.starts_with('#') {
            continue;
        }
        let rest = trimmed.strip_prefix("ROOT ").unwrap_or(trimmed);
        // instruction lines: "%x = shape opcode(" or "x = shape opcode(";
        // the lhs must be a plain identifier (rejects prose containing "=").
        let Some(eq) = rest.find(" = ") else { continue };
        let lhs = &rest[..eq];
        if lhs.is_empty()
            || !lhs
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || matches!(c, '%' | '.' | '_' | '-'))
        {
            continue;
        }
        let after = &rest[eq + 3..];
        // after = "f32[2,3]{1,0} dot(...)" — split shape then opcode.
        let mut parts = after.splitn(2, ' ');
        let shape = parts.next().unwrap_or("");
        let Some(tail) = parts.next() else { continue };
        let opcode: String = tail
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '-' || *c == '_')
            .collect();
        if opcode.is_empty() || !tail[opcode.len()..].starts_with('(') {
            continue;
        }
        stats.total_ops += 1;
        *stats.by_op.entry(opcode.clone()).or_insert(0) += 1;
        if opcode == "dot" {
            // Rough FLOPs: 2 × output elements × contraction size.  The
            // contraction size is not on this line; approximate with
            // output elements (lower bound) × 2 — good enough for
            // relative artifact comparisons.
            if let Some(n) = numel(shape) {
                stats.dot_flops += 2 * n;
            }
        }
    }
    stats
}

/// Analyze an artifact's `.hlo.txt` file.
pub fn analyze_file(dir: &Path, artifact: &str) -> Result<HloStats> {
    let path = dir.join(format!("{artifact}.hlo.txt"));
    let text = fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    Ok(analyze_text(&text))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
HloModule jit_fn

ENTRY main.5 {
  %p0 = f32[4,8]{1,0} parameter(0)
  %p1 = f32[8,2]{1,0} parameter(1)
  %dot.1 = f32[4,2]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}
  %c = f32[] constant(2)
  %b = f32[4,2]{1,0} broadcast(%c), dimensions={}
  ROOT %add.2 = f32[4,2]{1,0} add(%dot.1, %b)
}
"#;

    #[test]
    fn counts_ops() {
        let s = analyze_text(SAMPLE);
        assert_eq!(s.count("dot"), 1);
        assert_eq!(s.count("add"), 1);
        assert_eq!(s.count("parameter"), 2);
        assert_eq!(s.count("broadcast"), 1);
        assert!(s.total_ops >= 5);
    }

    #[test]
    fn dot_flops_counted() {
        let s = analyze_text(SAMPLE);
        assert_eq!(s.dot_flops, 2 * 8); // 2 × numel(f32[4,2])
    }

    #[test]
    fn numel_parsing() {
        assert_eq!(numel("f32[128,64]{1,0}"), Some(128 * 64));
        assert_eq!(numel("f32[]"), Some(1));
        assert_eq!(numel("pred[3]{0}"), Some(3));
        assert_eq!(numel("no-brackets"), None);
    }

    #[test]
    fn top_sorts_descending() {
        let s = analyze_text(SAMPLE);
        let top = s.top(2);
        assert_eq!(top[0].0, "parameter");
    }

    #[test]
    fn ignores_non_instruction_lines() {
        let s = analyze_text("HloModule foo\n\n// comment = like dot(\n");
        assert_eq!(s.total_ops, 0);
    }
}
