//! Host tensor ⇄ XLA [`xla::Literal`] conversion.
//!
//! The interchange is raw little-endian bytes via
//! `Literal::create_from_shape_and_untyped_data`, avoiding per-element
//! copies on the hot path.

use anyhow::{anyhow, bail, Result};
use xla::{ElementType, Literal};

use crate::runtime::manifest::{DType, TensorSpec};
use crate::tensor::{IntTensor, Tensor};

/// f32 tensor → literal.
pub fn literal_from_f32(t: &Tensor) -> Result<Literal> {
    // SAFETY: `t.data` is a live `Vec<f32>`, so its buffer is valid for
    // `len * 4` bytes; every f32 bit pattern is a valid `[u8; 4]`, u8 has
    // alignment 1, and the borrow of `t` outlives `bytes` (the literal
    // constructor copies before we return).
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
    };
    Literal::create_from_shape_and_untyped_data(ElementType::F32, &t.shape, bytes)
        .map_err(|e| anyhow!("creating f32 literal: {e:?}"))
}

/// i32 tensor → literal.
pub fn literal_from_i32(t: &IntTensor) -> Result<Literal> {
    // SAFETY: same argument as [`literal_from_f32`] — `t.data` is a live
    // `Vec<i32>` valid for `len * 4` bytes, i32→u8 reinterpretation is
    // always defined, and the slice does not outlive the borrow.
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
    };
    Literal::create_from_shape_and_untyped_data(ElementType::S32, &t.shape, bytes)
        .map_err(|e| anyhow!("creating i32 literal: {e:?}"))
}

/// literal → f32 tensor with the spec's shape.
pub fn f32_from_literal(lit: &Literal, spec: &TensorSpec) -> Result<Tensor> {
    if spec.dtype != DType::F32 {
        bail!("output {} is {:?}, not f32", spec.name, spec.dtype);
    }
    let data = lit
        .to_vec::<f32>()
        .map_err(|e| anyhow!("reading f32 literal {}: {e:?}", spec.name))?;
    Tensor::from_vec(&spec.shape, data)
}

/// literal → i32 tensor with the spec's shape.
pub fn i32_from_literal(lit: &Literal, spec: &TensorSpec) -> Result<IntTensor> {
    if spec.dtype != DType::I32 {
        bail!("output {} is {:?}, not i32", spec.name, spec.dtype);
    }
    let data = lit
        .to_vec::<i32>()
        .map_err(|e| anyhow!("reading i32 literal {}: {e:?}", spec.name))?;
    IntTensor::from_vec(&spec.shape, data)
}

/// Either-typed host value (what the executor passes/returns).
#[derive(Debug, Clone)]
pub enum Value {
    F32(Tensor),
    I32(IntTensor),
}

impl Value {
    pub fn scalar_f32(x: f32) -> Value {
        Value::F32(Tensor::scalar(x))
    }

    pub fn scalar_i32(x: i32) -> Value {
        Value::I32(IntTensor::scalar(x))
    }

    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            Value::I32(_) => bail!("expected f32 value, got i32"),
        }
    }

    pub fn as_i32(&self) -> Result<&IntTensor> {
        match self {
            Value::I32(t) => Ok(t),
            Value::F32(_) => bail!("expected i32 value, got f32"),
        }
    }

    pub fn into_f32(self) -> Result<Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            Value::I32(_) => bail!("expected f32 value, got i32"),
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => &t.shape,
            Value::I32(t) => &t.shape,
        }
    }

    pub fn to_literal(&self) -> Result<Literal> {
        match self {
            Value::F32(t) => literal_from_f32(t),
            Value::I32(t) => literal_from_i32(t),
        }
    }

    pub fn from_literal(lit: &Literal, spec: &TensorSpec) -> Result<Value> {
        match spec.dtype {
            DType::F32 => Ok(Value::F32(f32_from_literal(lit, spec)?)),
            DType::I32 => Ok(Value::I32(i32_from_literal(lit, spec)?)),
        }
    }

    /// Validate against a manifest spec (shape + dtype) before execution.
    pub fn check_spec(&self, spec: &TensorSpec) -> Result<()> {
        let (shape, is_f32) = match self {
            Value::F32(t) => (&t.shape, true),
            Value::I32(t) => (&t.shape, false),
        };
        let want_f32 = spec.dtype == DType::F32;
        if is_f32 != want_f32 || shape != &spec.shape {
            bail!(
                "input {}: expected {:?} {:?}, got {} {:?}",
                spec.name,
                spec.dtype,
                spec.shape,
                if is_f32 { "f32" } else { "i32" },
                shape
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, shape: &[usize], dtype: DType) -> TensorSpec {
        TensorSpec {
            name: name.into(),
            shape: shape.to_vec(),
            dtype,
        }
    }

    #[test]
    fn f32_roundtrip() {
        let t = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let lit = literal_from_f32(&t).unwrap();
        let back = f32_from_literal(&lit, &spec("x", &[2, 3], DType::F32)).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn i32_roundtrip() {
        let t = IntTensor::from_vec(&[4], vec![-1, 0, 7, 42]).unwrap();
        let lit = literal_from_i32(&t).unwrap();
        let back = i32_from_literal(&lit, &spec("x", &[4], DType::I32)).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn scalar_literals() {
        let v = Value::scalar_f32(2.5);
        let lit = v.to_literal().unwrap();
        let back = f32_from_literal(&lit, &spec("s", &[], DType::F32)).unwrap();
        assert_eq!(back.item(), 2.5);
    }

    #[test]
    fn spec_checking() {
        let v = Value::F32(Tensor::zeros(&[2, 2]));
        assert!(v.check_spec(&spec("a", &[2, 2], DType::F32)).is_ok());
        assert!(v.check_spec(&spec("a", &[2, 3], DType::F32)).is_err());
        assert!(v.check_spec(&spec("a", &[2, 2], DType::I32)).is_err());
    }

    #[test]
    fn dtype_mismatch_on_read() {
        let t = Tensor::zeros(&[2]);
        let lit = literal_from_f32(&t).unwrap();
        assert!(i32_from_literal(&lit, &spec("x", &[2], DType::I32)).is_err());
    }
}
