//! Artifact manifests — the typed description of each AOT-lowered HLO
//! module (`<name>.manifest.json`, written by `python/compile/aot.py`).

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, schema, Json};

/// Element type of one artifact input/output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other:?}"),
        }
    }

    pub fn size_bytes(self) -> usize {
        4
    }
}

/// One named tensor in an artifact signature.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = schema::arr_field(j, "shape")?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Result<Vec<_>>>()
            .context("field \"shape\"")?;
        Ok(TensorSpec {
            name: schema::str_field(j, "name")?.to_string(),
            shape,
            dtype: DType::parse(schema::str_field(j, "dtype")?)?,
        })
    }
}

/// Parsed `<name>.manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifact: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: Json,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = json::parse(text)?;
        let specs = |key: &str| -> Result<Vec<TensorSpec>> {
            schema::arr_field(&j, key)?
                .iter()
                .map(TensorSpec::from_json)
                .collect()
        };
        Ok(Manifest {
            artifact: schema::str_field(&j, "artifact")?.to_string(),
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
            meta: j.get_opt("meta").cloned().unwrap_or(Json::obj()),
        })
    }

    pub fn load(dir: &Path, name: &str) -> Result<Manifest> {
        let path = dir.join(format!("{name}.manifest.json"));
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        let m = Manifest::parse(&text)?;
        if m.artifact != name {
            bail!("manifest {} names artifact {:?}", path.display(), m.artifact);
        }
        Ok(m)
    }

    pub fn hlo_path(&self, dir: &Path) -> PathBuf {
        dir.join(format!("{}.hlo.txt", self.artifact))
    }

    pub fn input(&self, name: &str) -> Result<&TensorSpec> {
        self.inputs
            .iter()
            .find(|s| s.name == name)
            .with_context(|| format!("artifact {} has no input {name:?}", self.artifact))
    }

    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|s| s.name == name)
            .with_context(|| format!("artifact {} has no output {name:?}", self.artifact))
    }

    /// Names of the model parameters from meta.param_names (training
    /// artifacts only).
    pub fn param_names(&self) -> Result<Vec<String>> {
        self.meta
            .get("param_names")?
            .as_arr()?
            .iter()
            .map(|v| Ok(v.as_str()?.to_string()))
            .collect()
    }

    /// Sum of input sizes in bytes (sanity/perf reporting).
    pub fn input_bytes(&self) -> usize {
        self.inputs
            .iter()
            .map(|s| s.numel() * s.dtype.size_bytes())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifact": "toy",
      "inputs": [{"name": "q", "shape": [128, 64], "dtype": "f32"},
                 {"name": "tok", "shape": [2, 16], "dtype": "i32"}],
      "outputs": [{"name": "o", "shape": [128, 64], "dtype": "f32"}],
      "meta": {"param_names": ["a", "b"], "batch": 2}
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifact, "toy");
        assert_eq!(m.inputs.len(), 2);
        assert_eq!(m.inputs[0].numel(), 128 * 64);
        assert_eq!(m.inputs[1].dtype, DType::I32);
        assert_eq!(m.output_index("o").unwrap(), 0);
        assert!(m.output_index("nope").is_err());
        assert_eq!(m.param_names().unwrap(), vec!["a", "b"]);
        assert_eq!(m.input_bytes(), (128 * 64 + 32) * 4);
    }

    #[test]
    fn bad_dtype_rejected() {
        let bad = SAMPLE.replace("\"i32\"", "\"f64\"");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn input_lookup() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.input("q").unwrap().shape, vec![128, 64]);
        assert!(m.input("missing").is_err());
    }
}
