//! Layer-3 runtime: the PJRT CPU client that loads AOT artifacts and
//! executes them on the request path.
//!
//! Pipeline per artifact (compile once, execute many):
//!
//! ```text
//! <name>.hlo.txt  ──HloModuleProto::from_text_file──▶ XlaComputation
//!                 ──client.compile──▶ PjRtLoadedExecutable
//! Value (host)    ──literal::to_literal──▶ Literal ──execute──▶ outputs
//! ```
//!
//! HLO *text* is the interchange (64-bit-id protos from jax ≥ 0.5 are
//! rejected by xla_extension 0.5.1 — see DESIGN.md / aot.py).

pub mod backend;
pub mod hlo_inspect;
pub mod literal;
pub mod manifest;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

pub use backend::{make_backend, AttentionBackend, NativeBackend, XlaBackend};
pub use literal::Value;
pub use manifest::{DType, Manifest, TensorSpec};

/// Shared PJRT CPU client + artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    /// Compile cache: artifact name → loaded executable.  BTreeMap so any
    /// future iteration over it is deterministic (A1 lint, DESIGN.md §13).
    cache: BTreeMap<String, Executable>,
}

/// One compiled artifact ready to execute.
#[derive(Clone)]
pub struct Executable {
    pub manifest: Manifest,
    exe: std::rc::Rc<xla::PjRtLoadedExecutable>,
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU runtime rooted at an artifact directory.
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Runtime> {
        let dir = artifacts_dir.into();
        if !dir.is_dir() {
            bail!(
                "artifact directory {} does not exist — run `make artifacts` first",
                dir.display()
            );
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            client,
            artifacts_dir: dir,
            cache: BTreeMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// Load + compile an artifact (cached).
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let exe = self.compile(name)?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Like [`Self::load`] but returns an owned handle (cheap: the
    /// compiled executable is reference-counted) so callers can hold it
    /// without borrowing the runtime.
    pub fn load_owned(&mut self, name: &str) -> Result<Executable> {
        Ok(self.load(name)?.clone())
    }

    fn compile(&self, name: &str) -> Result<Executable> {
        let manifest = Manifest::load(&self.artifacts_dir, name)?;
        let hlo_path = manifest.hlo_path(&self.artifacts_dir);
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .ok_or_else(|| anyhow!("non-UTF-8 path {}", hlo_path.display()))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        Ok(Executable {
            manifest,
            exe: std::rc::Rc::new(exe),
            client: self.client.clone(),
        })
    }

    /// Convenience: load and execute in one call.
    pub fn execute(&mut self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        self.load(name)?;
        self.cache[name].execute(inputs)
    }
}

impl Executable {
    /// Execute with manifest-validated inputs; returns outputs in manifest
    /// order.  The AOT path lowers with `return_tuple=True`, so the single
    /// result literal is a tuple we decompose.
    pub fn execute(&self, inputs: &[Value]) -> Result<Vec<Value>> {
        let m = &self.manifest;
        if inputs.len() != m.inputs.len() {
            bail!(
                "artifact {} expects {} inputs, got {}",
                m.artifact,
                m.inputs.len(),
                inputs.len()
            );
        }
        for (v, spec) in inputs.iter().zip(&m.inputs) {
            v.check_spec(spec)
                .with_context(|| format!("executing {}", m.artifact))?;
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|v| v.to_literal())
            .collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> = literals.iter().collect();
        let parts = self.execute_literals(&refs)?;
        parts
            .iter()
            .zip(&m.outputs)
            .map(|(lit, spec)| Value::from_literal(lit, spec))
            .collect()
    }

    /// Execute and pick a single named output.
    pub fn execute_pick(&self, inputs: &[Value], output: &str) -> Result<Value> {
        let idx = self.manifest.output_index(output)?;
        let mut outs = self.execute(inputs)?;
        Ok(outs.swap_remove(idx))
    }

    /// Upload a host literal to a device buffer owned by Rust.
    ///
    /// Two vendored-crate footguns are deliberately avoided here:
    ///
    /// 1. `PjRtLoadedExecutable::execute` (literal inputs) — its C shim
    ///    leaks every input device buffer it creates (`buffer.release()`
    ///    with no matching free).  All execution in this repo goes through
    ///    [`Self::execute_buffers`], whose inputs are `PjRtBuffer`s with
    ///    proper `Drop` impls.
    /// 2. `PjRtClient::buffer_from_host_literal` — `BufferFromHostLiteral`
    ///    is *asynchronous* and the shim never awaits the transfer, so a
    ///    literal dropped right after the call is a use-after-free.  We
    ///    instead stage through `buffer_from_host_buffer`, whose
    ///    `kImmutableOnlyDuringCall` semantics force a synchronous copy.
    pub fn buffer_from_literal(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        let shape = lit
            .array_shape()
            .map_err(|e| anyhow!("literal shape for {}: {e:?}", self.manifest.artifact))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let ty = lit
            .ty()
            .map_err(|e| anyhow!("literal type for {}: {e:?}", self.manifest.artifact))?;
        let buf = match ty {
            xla::ElementType::F32 => {
                let data = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("reading f32 literal: {e:?}"))?;
                self.client.buffer_from_host_buffer(&data, &dims, None)
            }
            xla::ElementType::S32 => {
                let data = lit
                    .to_vec::<i32>()
                    .map_err(|e| anyhow!("reading i32 literal: {e:?}"))?;
                self.client.buffer_from_host_buffer(&data, &dims, None)
            }
            other => bail!("unsupported upload element type {other:?}"),
        };
        buf.map_err(|e| anyhow!("uploading buffer for {}: {e:?}", self.manifest.artifact))
    }

    /// Upload an f32 host tensor directly (no literal staging).
    pub fn upload_f32(&self, t: &crate::tensor::Tensor) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(&t.data, &t.shape, None)
            .map_err(|e| anyhow!("uploading f32 buffer for {}: {e:?}", self.manifest.artifact))
    }

    /// Upload an i32 host tensor directly (no literal staging).
    pub fn upload_i32(&self, t: &crate::tensor::IntTensor) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(&t.data, &t.shape, None)
            .map_err(|e| anyhow!("uploading i32 buffer for {}: {e:?}", self.manifest.artifact))
    }

    /// Hot-path variant: execute with device-resident input buffers (no
    /// per-call host→device transfer for cached state) and return raw
    /// output literals in manifest order.
    ///
    /// This is what the trainer uses: parameter/moment buffers are built
    /// once per optimizer step and reused across all microbatches (§Perf).
    pub fn execute_buffers(&self, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let m = &self.manifest;
        if inputs.len() != m.inputs.len() {
            bail!(
                "artifact {} expects {} inputs, got {}",
                m.artifact,
                m.inputs.len(),
                inputs.len()
            );
        }
        let result = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(inputs)
            .map_err(|e| anyhow!("executing {}: {e:?}", m.artifact))?;
        let mut tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {}: {e:?}", m.artifact))?;
        let parts = tuple
            .decompose_tuple()
            .map_err(|e| anyhow!("decomposing result tuple of {}: {e:?}", m.artifact))?;
        if parts.len() != m.outputs.len() {
            bail!(
                "artifact {} returned {} outputs, manifest says {}",
                m.artifact,
                parts.len(),
                m.outputs.len()
            );
        }
        Ok(parts)
    }

    /// Execute with host literals: uploads each input to a Rust-owned
    /// buffer (freed on drop) and runs [`Self::execute_buffers`].
    pub fn execute_literals(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|l| self.buffer_from_literal(l))
            .collect::<Result<_>>()?;
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        self.execute_buffers(&refs)
    }
}

#[cfg(test)]
mod tests {
    // Runtime tests that need real artifacts live in rust/tests/ — they
    // skip gracefully when artifacts/ has not been built.
}
