//! Telemetry substrate: metric registry, CSV series writer, and run logs.
//!
//! The trainer emits `(step, name, value)` points; series are buffered in
//! memory and flushed to `results/<run>/<series>.csv` so every paper
//! figure can be regenerated from the raw curves.  Standard training
//! series: `train_loss`, `lr`, `grad_norm`, `tokens`, `max_attn_logit`
//! (the §5.3 divergence statistic), `step_ms` (per-step wall time), and
//! `diverged` (a single 1.0 at the flagged step).  With `--qerr-every N`
//! the [`qerr`] probes add the per-matmul quantization-error family on
//! sampled steps: `qerr_qk`, `qerr_pv`, `qerr_dv`, `qerr_dp`, `qerr_ds`,
//! `qerr_dq`, `qerr_dk` (max rel-L2 vs the FPA oracle) and their
//! `qerr_*_cos` twins (min cosine similarity).  Render any of them
//! offline with `sagebwd plot --run DIR[,DIR] --series NAME`.

pub mod plot;
pub mod qerr;
pub mod trace;

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::stats::Summary;

/// A single named time series (e.g. "train_loss").
#[derive(Debug, Default, Clone)]
pub struct Series {
    pub points: Vec<(u64, f64)>,
    pub summary: Summary,
}

impl Series {
    pub fn push(&mut self, step: u64, value: f64) {
        self.points.push((step, value));
        self.summary.observe(value);
    }

    pub fn last(&self) -> Option<f64> {
        self.points.last().map(|&(_, v)| v)
    }

    /// Mean of the final `k` points — the "final loss" a paper reports.
    pub fn tail_mean(&self, k: usize) -> Option<f64> {
        if self.points.is_empty() {
            return None;
        }
        let tail = &self.points[self.points.len().saturating_sub(k)..];
        Some(tail.iter().map(|&(_, v)| v).sum::<f64>() / tail.len() as f64)
    }

    /// Largest recorded value — e.g. the peak `max_attn_logit` of a run
    /// (the fig1 divergence statistic).
    pub fn max_value(&self) -> Option<f64> {
        self.points
            .iter()
            .map(|&(_, v)| v)
            .fold(None, |acc, v| Some(acc.map_or(v, |a: f64| a.max(v))))
    }

    /// The series as `step,value` CSV text — the one encoder behind both
    /// [`Metrics::flush_csv`] and the registry's `RunHandle::record_metrics`
    /// (identical bytes, so a flushed file hashes to its registry address).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("step,value\n");
        for &(step, value) in &self.points {
            out.push_str(&format!("{step},{value}\n"));
        }
        out
    }

    /// Inverse of [`Series::to_csv`].  Rust's `f64` `Display` prints the
    /// shortest round-tripping decimal, so `from_csv(to_csv()) == self`
    /// *bitwise* — the property the supervisor's checkpoint rollback
    /// leans on when it restores a metrics registry from saved CSV
    /// artifacts and expects the resumed run to re-emit identical bytes.
    pub fn from_csv(text: &str) -> Result<Series> {
        let mut lines = text.lines();
        match lines.next() {
            Some("step,value") => {}
            other => anyhow::bail!("series CSV missing step,value header (got {other:?})"),
        }
        let mut s = Series::default();
        for (i, line) in lines.enumerate() {
            let (step, value) = line
                .split_once(',')
                .with_context(|| format!("series CSV line {}: no comma in {line:?}", i + 2))?;
            let step: u64 = step
                .parse()
                .with_context(|| format!("series CSV line {}: bad step {step:?}", i + 2))?;
            let value: f64 = value
                .parse()
                .with_context(|| format!("series CSV line {}: bad value {value:?}", i + 2))?;
            s.push(step, value);
        }
        Ok(s)
    }

    /// Drop points after `step` (inclusive keep) — the rollback primitive:
    /// a recovery rewinds every series to the checkpointed step before the
    /// run continues, so diverged tail points never reach the artifacts.
    pub fn truncate_after(&mut self, step: u64) {
        self.points.retain(|&(s, _)| s <= step);
        self.summary = Summary::default();
        let pts = std::mem::take(&mut self.points);
        for (s, v) in pts {
            self.push(s, v);
        }
    }
}

/// Metric registry for one run.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    pub series: BTreeMap<String, Series>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record(&mut self, name: &str, step: u64, value: f64) {
        self.series.entry(name.to_string()).or_default().push(step, value);
    }

    pub fn get(&self, name: &str) -> Option<&Series> {
        self.series.get(name)
    }

    /// Write every series as `<dir>/<name>.csv` with a `step,value` header.
    ///
    /// Each file lands via unique-tmp + rename (the registry object
    /// store's idiom), so an interrupted run never leaves a truncated
    /// CSV behind — readers see the old file or the new one, never half.
    pub fn flush_csv(&self, dir: &Path) -> Result<()> {
        use std::sync::atomic::{AtomicU64, Ordering};
        static TMP_COUNTER: AtomicU64 = AtomicU64::new(0);
        fs::create_dir_all(dir)
            .with_context(|| format!("creating metrics dir {}", dir.display()))?;
        for (name, series) in &self.series {
            let path = dir.join(format!("{name}.csv"));
            let tmp = dir.join(format!(
                ".tmp-{}-{}",
                std::process::id(),
                TMP_COUNTER.fetch_add(1, Ordering::Relaxed)
            ));
            fs::write(&tmp, series.to_csv())
                .with_context(|| format!("writing {}", tmp.display()))?;
            fs::rename(&tmp, &path)
                .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))?;
        }
        Ok(())
    }
}

/// Resolve (and create) a **fresh** results directory for a named run.
///
/// Collision fix: re-running an experiment with the same run name used to
/// write into (and interleave CSVs with) the previous run's directory.
/// Now an existing *non-empty* `<base>/<run_name>` is left untouched and
/// the run is versioned to `<run_name>_2`, `<run_name>_3`, ... (first
/// free slot).  An existing empty directory is reused — nothing to
/// clobber.  Registry-era experiment harnesses don't call this (their
/// outputs are content-addressed views); the per-run CLI paths
/// (`sagebwd train`, `dist-train`) do.
pub fn run_dir(base: &str, run_name: &str) -> Result<PathBuf> {
    let is_free = |dir: &Path| -> Result<bool> {
        if !dir.exists() {
            return Ok(true);
        }
        if !dir.is_dir() {
            return Ok(false);
        }
        Ok(fs::read_dir(dir)
            .with_context(|| format!("listing {}", dir.display()))?
            .next()
            .is_none())
    };
    let base_dir = PathBuf::from(base);
    let mut dir = base_dir.join(run_name);
    let mut version = 1usize;
    while !is_free(&dir)? {
        version += 1;
        if version > 10_000 {
            anyhow::bail!(
                "over 10000 versioned run dirs for {run_name:?} under {base} — \
                 clean results/ or pick a new run name"
            );
        }
        dir = base_dir.join(format!("{run_name}_{version}"));
    }
    fs::create_dir_all(&dir).with_context(|| format!("creating {}", dir.display()))?;
    Ok(dir)
}

/// Leveled stderr logger with a wall-clock prefix.
pub struct Log {
    pub verbose: bool,
    t0: std::time::Instant,
}

impl Log {
    pub fn new(verbose: bool) -> Log {
        Log {
            verbose,
            t0: std::time::Instant::now(),
        }
    }

    pub fn info(&self, msg: &str) {
        eprintln!("[{:8.1}s] {msg}", self.t0.elapsed().as_secs_f64());
    }

    pub fn debug(&self, msg: &str) {
        if self.verbose {
            self.info(msg);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_tail_mean() {
        let mut s = Series::default();
        for (i, v) in [1.0, 2.0, 3.0, 4.0].iter().enumerate() {
            s.push(i as u64, *v);
        }
        assert_eq!(s.tail_mean(2), Some(3.5));
        assert_eq!(s.tail_mean(100), Some(2.5));
        assert_eq!(s.last(), Some(4.0));
    }

    #[test]
    fn metrics_record_and_flush() {
        let mut m = Metrics::new();
        m.record("loss", 0, 2.5);
        m.record("loss", 1, 2.0);
        m.record("lr", 0, 3e-5);
        let dir = std::env::temp_dir().join(format!("sagebwd_tm_{}", std::process::id()));
        m.flush_csv(&dir).unwrap();
        let loss = std::fs::read_to_string(dir.join("loss.csv")).unwrap();
        assert!(loss.starts_with("step,value\n0,2.5\n1,2\n"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_series() {
        let s = Series::default();
        assert_eq!(s.last(), None);
        assert_eq!(s.tail_mean(3), None);
        assert_eq!(s.max_value(), None);
    }

    #[test]
    fn run_dir_versions_instead_of_interleaving() {
        let base = std::env::temp_dir().join(format!("sagebwd_rd_{}", std::process::id()));
        let base_s = base.to_str().unwrap();

        // Fresh name: plain dir.
        let d1 = run_dir(base_s, "demo").unwrap();
        assert_eq!(d1, base.join("demo"));

        // Existing but empty: reused (nothing to clobber).
        let d1b = run_dir(base_s, "demo").unwrap();
        assert_eq!(d1b, d1);

        // Existing and non-empty: versioned, previous run untouched.
        std::fs::write(d1.join("train_loss.csv"), "step,value\n0,1\n").unwrap();
        let d2 = run_dir(base_s, "demo").unwrap();
        assert_eq!(d2, base.join("demo_2"));
        std::fs::write(d2.join("train_loss.csv"), "step,value\n0,2\n").unwrap();
        let d3 = run_dir(base_s, "demo").unwrap();
        assert_eq!(d3, base.join("demo_3"));

        // The original run's CSV was never interleaved into.
        let first = std::fs::read_to_string(d1.join("train_loss.csv")).unwrap();
        assert_eq!(first, "step,value\n0,1\n");

        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn series_to_csv_matches_flush() {
        let mut m = Metrics::new();
        m.record("loss", 0, 2.5);
        m.record("loss", 3, 1.25);
        let dir = std::env::temp_dir().join(format!("sagebwd_tc_{}", std::process::id()));
        m.flush_csv(&dir).unwrap();
        let flushed = std::fs::read_to_string(dir.join("loss.csv")).unwrap();
        assert_eq!(flushed, m.get("loss").unwrap().to_csv());
        assert_eq!(flushed, "step,value\n0,2.5\n3,1.25\n");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn series_csv_roundtrips_bitwise() {
        let mut s = Series::default();
        // Values chosen to stress Display round-tripping: subnormal-ish,
        // repeating binary fractions, huge, and exactly representable.
        for (i, v) in [2.5, 0.1, 1.0 / 3.0, 6.02214076e23, -0.0, 3e-5].iter().enumerate() {
            s.push(i as u64 * 7, *v);
        }
        let back = Series::from_csv(&s.to_csv()).unwrap();
        assert_eq!(back.points.len(), s.points.len());
        for (&(s1, v1), &(s2, v2)) in s.points.iter().zip(&back.points) {
            assert_eq!(s1, s2);
            assert_eq!(v1.to_bits(), v2.to_bits(), "value {v1} did not round-trip bitwise");
        }
        assert_eq!(back.to_csv(), s.to_csv());

        // Malformed inputs are errors, not silent empties.
        assert!(Series::from_csv("").is_err());
        assert!(Series::from_csv("time,value\n0,1\n").is_err());
        assert!(Series::from_csv("step,value\n0 1\n").is_err());
        assert!(Series::from_csv("step,value\nx,1\n").is_err());
        assert!(Series::from_csv("step,value\n0,x\n").is_err());
        // Header alone is a valid empty series.
        assert!(Series::from_csv("step,value\n").unwrap().points.is_empty());
    }

    #[test]
    fn series_truncate_after_rewinds_points_and_summary() {
        let mut s = Series::default();
        for (step, v) in [(0u64, 1.0), (2, 5.0), (4, 9.0), (6, 2.0)] {
            s.push(step, v);
        }
        s.truncate_after(4);
        assert_eq!(s.points, vec![(0, 1.0), (2, 5.0), (4, 9.0)]);
        assert_eq!(s.max_value(), Some(9.0));
        s.truncate_after(3);
        assert_eq!(s.points, vec![(0, 1.0), (2, 5.0)]);
        // Summary is rebuilt, not stale: max reflects the surviving points.
        assert_eq!(s.max_value(), Some(5.0));
        s.truncate_after(0);
        assert_eq!(s.points, vec![(0, 1.0)]);
    }

    #[test]
    fn series_max_value() {
        let mut s = Series::default();
        for (i, v) in [1.5, 9.25, -3.0, 4.0].iter().enumerate() {
            s.push(i as u64, *v);
        }
        assert_eq!(s.max_value(), Some(9.25));
    }
}
