//! Terminal plotting: render metric curves from `results/**/<series>.csv`
//! as ASCII charts, so the paper's figures can be eyeballed without
//! leaving the terminal.  `sagebwd plot --csv a.csv,b.csv` plots explicit
//! files; `sagebwd plot --run DIR,DIR --series max_attn_logit` compares
//! one series (loss, divergence logits, step wall-time, …) across runs.

use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// A named (x, y) series.
#[derive(Debug, Clone)]
pub struct Curve {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

/// Load a `step,value` CSV written by `telemetry::Metrics::flush_csv`.
pub fn load_csv(path: &Path, name: &str) -> Result<Curve> {
    let text = fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut points = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if i == 0 {
            continue; // header
        }
        let mut cols = line.split(',');
        let (Some(x), Some(y)) = (cols.next(), cols.next()) else {
            bail!("malformed CSV line {i} in {}", path.display());
        };
        points.push((
            x.trim().parse().with_context(|| format!("bad x at line {i}"))?,
            y.trim().parse().with_context(|| format!("bad y at line {i}"))?,
        ));
    }
    if points.is_empty() {
        bail!("{} has no data rows", path.display());
    }
    Ok(Curve {
        name: name.to_string(),
        points,
    })
}

const MARKS: &[char] = &['*', 'o', '+', 'x', '#', '@'];

/// Render curves into a `width × height` ASCII grid with axes and legend.
pub fn render(curves: &[Curve], width: usize, height: usize) -> String {
    assert!(!curves.is_empty());
    let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    for c in curves {
        for &(x, y) in &c.points {
            xmin = xmin.min(x);
            xmax = xmax.max(x);
            ymin = ymin.min(y);
            ymax = ymax.max(y);
        }
    }
    if (xmax - xmin).abs() < 1e-12 {
        xmax = xmin + 1.0;
    }
    if (ymax - ymin).abs() < 1e-12 {
        ymax = ymin + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (ci, c) in curves.iter().enumerate() {
        let mark = MARKS[ci % MARKS.len()];
        for &(x, y) in &c.points {
            let col = (((x - xmin) / (xmax - xmin)) * (width - 1) as f64).round() as usize;
            let row = (((ymax - y) / (ymax - ymin)) * (height - 1) as f64).round() as usize;
            grid[row.min(height - 1)][col.min(width - 1)] = mark;
        }
    }
    let mut out = String::new();
    for (i, row) in grid.iter().enumerate() {
        let label = if i == 0 {
            format!("{ymax:9.4} ┤")
        } else if i == height - 1 {
            format!("{ymin:9.4} ┤")
        } else {
            format!("{:9} │", "")
        };
        out.push_str(&label);
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!(
        "{:9} └{}\n{:11}{xmin:<12.0}{:>w$.0}\n",
        "",
        "─".repeat(width),
        "",
        xmax,
        w = width - 12
    ));
    for (ci, c) in curves.iter().enumerate() {
        out.push_str(&format!("  {} {}\n", MARKS[ci % MARKS.len()], c.name));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let path = std::env::temp_dir().join(format!("sagebwd_plot_{}.csv", std::process::id()));
        std::fs::write(&path, "step,value\n0,2.5\n1,2.0\n2,1.5\n").unwrap();
        let c = load_csv(&path, "loss").unwrap();
        assert_eq!(c.points, vec![(0.0, 2.5), (1.0, 2.0), (2.0, 1.5)]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn csv_errors() {
        let path = std::env::temp_dir().join(format!("sagebwd_plot_bad_{}.csv", std::process::id()));
        std::fs::write(&path, "step,value\n").unwrap();
        assert!(load_csv(&path, "x").is_err());
        std::fs::write(&path, "step,value\n0,abc\n").unwrap();
        assert!(load_csv(&path, "x").is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn render_marks_endpoints() {
        let c = Curve {
            name: "test".into(),
            points: vec![(0.0, 0.0), (10.0, 10.0)],
        };
        let s = render(&[c], 40, 10);
        assert!(s.contains('*'));
        assert!(s.contains("test"));
        // min and max labels present
        assert!(s.contains("10.0000"));
        assert!(s.contains("0.0000"));
    }

    #[test]
    fn render_multiple_markers() {
        let a = Curve { name: "a".into(), points: vec![(0.0, 1.0), (1.0, 2.0)] };
        let b = Curve { name: "b".into(), points: vec![(0.0, 2.0), (1.0, 1.0)] };
        let s = render(&[a, b], 30, 8);
        assert!(s.contains('*') && s.contains('o'));
    }

    #[test]
    fn constant_series_does_not_panic() {
        let c = Curve { name: "flat".into(), points: vec![(0.0, 5.0), (1.0, 5.0)] };
        render(&[c], 20, 5);
    }
}
