//! Online quantization-error probes (DESIGN.md §14, paper insight (ii)).
//!
//! On sampled optimizer steps (`--qerr-every N`) the native backend
//! re-runs the exact FPA oracle next to the INT8 attention kernel and
//! folds cossim / rel-L2 of each of the seven attention matmul products
//! into a per-step accumulator:
//!
//! | series    | product                         | comparison domain    |
//! |-----------|---------------------------------|----------------------|
//! | `qerr_qk` | S̃ = ψ(Q)·ψ(K)ᵀ                  | causal entries j ≤ i |
//! | `qerr_pv` | O = ψ(P̃)·ψ(V)                   | dense (N, D)         |
//! | `qerr_dv` | dV = ψ(P)ᵀ·ψ(dO)                | dense (N, D)         |
//! | `qerr_dp` | dP = dO·Vᵀ (kept FP, insight ii)| causal entries j ≤ i |
//! | `qerr_ds` | dS = P ∘ (dP − δ)               | causal entries j ≤ i |
//! | `qerr_dq` | dQ = ψ(dS)·ψ(K)/√d              | dense (N, D)         |
//! | `qerr_dk` | dK = ψ(dS)ᵀ·ψ(Q)/√d             | dense (N, D)         |
//!
//! The per-step fold is the **worst** error across heads/microbatches
//! (max rel-L2, min cossim) — an order-independent reduction, so the
//! recorded values do not depend on worker-thread interleaving.  Probes
//! only read kernel outputs: the training numerics are bitwise identical
//! with probing on or off.  The trainer drains [`take_step`] into
//! `qerr_*` / `qerr_*_cos` metric series, which flow to CSV and the run
//! registry exactly like `train_loss`, so fig1/fig4 runs chart the
//! paper's dS-dominance claim directly.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::kernels::AttnTrace;
use crate::util::stats;

/// Sampling period: probe steps where `step % every == 0`.  0 = off.
static EVERY: AtomicU64 = AtomicU64::new(0);
/// Whether the step currently in flight is a sampled one.
static ACTIVE: AtomicBool = AtomicBool::new(false);

/// Worst-case (max rel-L2, min cossim) fold for one matmul this step.
#[derive(Clone, Copy)]
struct Fold {
    rel_l2: f64,
    cossim: f64,
}

static ACC: Mutex<BTreeMap<&'static str, Fold>> = Mutex::new(BTreeMap::new());

/// Enable probing every `n` steps (0 disables).  Like
/// [`super::trace::set_enabled`], a global knob — deliberately **not**
/// part of `TrainConfig`, so registry run keys and resume byte-identity
/// are unaffected by observability settings.
pub fn set_every(n: u64) {
    EVERY.store(n, Ordering::SeqCst);
}

/// True when `--qerr-every` is set at all.
pub fn probing_configured() -> bool {
    EVERY.load(Ordering::Relaxed) != 0
}

/// Called by the trainer at the top of each step: decides whether this
/// step is sampled and clears any stale partial accumulator.
pub fn begin_step(step: u64) {
    let every = EVERY.load(Ordering::Relaxed);
    let on = every != 0 && step % every == 0;
    ACTIVE.store(on, Ordering::SeqCst);
    if on {
        ACC.lock().unwrap_or_else(|p| p.into_inner()).clear();
    }
}

/// Single cheap gate the backend checks before paying for the oracle.
#[inline]
pub fn active() -> bool {
    EVERY.load(Ordering::Relaxed) != 0 && ACTIVE.load(Ordering::Relaxed)
}

/// Fold one (approx, exact) product pair into the step accumulator.
fn record(name: &'static str, approx: &[f32], exact: &[f32]) {
    let rel = stats::rel_l2(approx, exact);
    let cos = stats::cossim(approx, exact);
    let mut acc = ACC.lock().unwrap_or_else(|p| p.into_inner());
    let f = acc.entry(name).or_insert(Fold {
        rel_l2: f64::NEG_INFINITY,
        cossim: f64::INFINITY,
    });
    // NaN-poisoning folds: a NaN sample must surface, not vanish.
    f.rel_l2 = stats::nan_max(f.rel_l2, rel);
    f.cossim = nan_min(f.cossim, cos);
}

fn nan_min(a: f64, b: f64) -> f64 {
    if a.is_nan() || b.is_nan() {
        f64::NAN
    } else {
        a.min(b)
    }
}

/// Extract the causal (j ≤ i) entries of two (n, n) score-shaped
/// matrices into dense pair vectors, skipping non-finite entries (the
/// masked positions the kernels encode as −∞).
fn causal_pairs(approx: &[f32], exact: &[f32], n: usize) -> (Vec<f32>, Vec<f32>) {
    let mut a = Vec::with_capacity(n * (n + 1) / 2);
    let mut e = Vec::with_capacity(n * (n + 1) / 2);
    for i in 0..n {
        for j in 0..=i {
            let (x, y) = (approx[i * n + j], exact[i * n + j]);
            if x.is_finite() && y.is_finite() {
                a.push(x);
                e.push(y);
            }
        }
    }
    (a, e)
}

/// Compare one INT8 attention trace against the exact FPA oracle and
/// fold all seven matmul products into the step accumulator.
///
/// For causal attention the score-shaped intermediates (S̃, dP, dS) are
/// restricted to j ≤ i: the tiled kernel never computes fully-masked
/// tiles (their slots stay zero), and the oracle marks masked scores
/// with −∞ — neither is a quantization error.
pub fn probe(approx: &AttnTrace, exact: &AttnTrace, causal: bool) {
    let n = approx.s.shape[0];
    if causal {
        let (a, e) = causal_pairs(&approx.s.data, &exact.s.data, n);
        record("qk", &a, &e);
        let (a, e) = causal_pairs(&approx.dp.data, &exact.dp.data, n);
        record("dp", &a, &e);
        let (a, e) = causal_pairs(&approx.ds.data, &exact.ds.data, n);
        record("ds", &a, &e);
    } else {
        record("qk", &approx.s.data, &exact.s.data);
        record("dp", &approx.dp.data, &exact.dp.data);
        record("ds", &approx.ds.data, &exact.ds.data);
    }
    record("pv", &approx.o.data, &exact.o.data);
    record("dv", &approx.dv.data, &exact.dv.data);
    record("dq", &approx.dq.data, &exact.dq.data);
    record("dk", &approx.dk.data, &exact.dk.data);
}

/// Drain the step accumulator: `(matmul name, max rel-L2, min cossim)`
/// in deterministic name order.  Empty when the step was not sampled or
/// no INT8 attention ran.
pub fn take_step() -> Vec<(&'static str, f64, f64)> {
    let mut acc = ACC.lock().unwrap_or_else(|p| p.into_inner());
    let drained = std::mem::take(&mut *acc);
    drained
        .into_iter()
        .map(|(name, f)| (name, f.rel_l2, f.cossim))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn causal_pairs_skip_masked_and_upper_triangle() {
        let n = 3;
        let mut approx = vec![0.0f32; 9];
        let mut exact = vec![0.0f32; 9];
        // Upper triangle poisoned: must never be read.
        for i in 0..n {
            for j in (i + 1)..n {
                approx[i * n + j] = f32::NAN;
                exact[i * n + j] = 7.0;
            }
        }
        // Masked entry (row 1, col 0) encoded as -inf on both sides.
        approx[n] = f32::NEG_INFINITY;
        exact[n] = f32::NEG_INFINITY;
        let (a, e) = causal_pairs(&approx, &exact, n);
        assert_eq!(a.len(), 5); // 6 lower-tri entries minus the masked one
        assert_eq!(a.len(), e.len());
        assert!(a.iter().chain(e.iter()).all(|v| v.is_finite()));
    }

    #[test]
    fn nan_min_poisons() {
        assert_eq!(nan_min(1.0, 2.0), 1.0);
        assert!(nan_min(1.0, f64::NAN).is_nan());
        assert!(nan_min(f64::NAN, 1.0).is_nan());
    }
}
