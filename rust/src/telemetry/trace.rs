//! Hierarchical span tracing (DESIGN.md §14).
//!
//! A pure-std observability layer over the whole training stack:
//! `train_step → fwd/bwd → layer → attention → linalg GEMMs →
//! workspace`.  Scoped [`SpanGuard`]s record wall time per span into a
//! thread-local table; worker threads from the scoped pool merge their
//! tables into a process-global aggregate when they exit, so a report
//! sees every thread that contributed since the last reset.  Named
//! counters ride the same machinery: the workspace arena's `ws_*`
//! tallies and the GEMM dispatcher's per-tier `simd_calls_scalar` /
//! `simd_calls_avx2` / `simd_calls_fma` counts (DESIGN.md §15) are
//! ordinary `counter` lines in the report — no schema change per
//! counter name.
//!
//! Contracts (test-asserted in `rust/tests/telemetry_trace.rs`):
//!
//! * **Determinism** — tracing never touches numeric state: guards only
//!   read the clock and write side tables, so training curves are
//!   bitwise identical with tracing on or off.
//! * **Near-zero overhead when off** — [`span`]/[`counter_add`] bail on
//!   a single branch over a thread-local [`Cell`]; no allocation, no
//!   clock read, no lock.  New threads inherit the process-wide flag at
//!   thread-local init, so [`set_enabled`] must run before workers
//!   spawn (the scoped pool creates workers per call, satisfying this).
//! * **Schema** — reports serialize as `sagebwd-trace-v1` JSONL, one
//!   event object per line: a leading `meta` line with the span/counter
//!   counts, then one `span` line per aggregated span and one `counter`
//!   line per counter.  [`TraceReport::parse_jsonl`] rejects unknown
//!   keys, unknown kinds, and count mismatches; the key lists live in
//!   lockstep with `analysis::lints::TRACE_V1_FIELDS` (lint A5).
//!
//! The monotonic [`now_ns`] clock works whether or not tracing is
//! enabled — it is the single step-timing clock shared by the trainer's
//! `step_ms` series and the bench harness.

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::util::json::{schema, Json};
use crate::util::stats;

/// Schema tag carried by every JSONL event line.
pub const TRACE_SCHEMA: &str = "sagebwd-trace-v1";

/// Per-span duration samples kept for the p50/p99 estimate.  Totals,
/// min/max and call counts keep accumulating past the cap; only the
/// percentile sample set is bounded so multi-thousand-call GEMM spans
/// cannot grow memory without bound.
const SAMPLE_CAP: usize = 4096;

static ENABLED: AtomicBool = AtomicBool::new(false);

thread_local! {
    /// Cached copy of [`ENABLED`], read by every guard: the off path is
    /// one thread-local load and branch.  Initialized from the global
    /// when the thread first touches tracing.
    static TL_ON: Cell<bool> = Cell::new(ENABLED.load(Ordering::Relaxed));

    static TRACER: RefCell<ThreadTracer> = const { RefCell::new(ThreadTracer::new()) };
}

/// Turn tracing on/off process-wide and for the calling thread.  Call
/// before spawning workers; threads born afterwards inherit the flag.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::SeqCst);
    TL_ON.with(|c| c.set(on));
}

/// The single-branch gate every instrumentation site checks first.
#[inline]
pub fn enabled() -> bool {
    TL_ON.with(Cell::get)
}

/// Monotonic nanoseconds since the first call in this process.  Works
/// with tracing disabled — the unified step/bench clock.
pub fn now_ns() -> u64 {
    static START: OnceLock<Instant> = OnceLock::new();
    START.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

struct Frame {
    name: &'static str,
    parent: Option<&'static str>,
    start: u64,
    child_ns: u64,
}

#[derive(Clone)]
struct SpanStat {
    parent: Option<&'static str>,
    calls: u64,
    total_ns: u64,
    self_ns: u64,
    min_ns: u64,
    max_ns: u64,
    durs: Vec<u64>,
}

impl SpanStat {
    fn new() -> SpanStat {
        SpanStat {
            parent: None,
            calls: 0,
            total_ns: 0,
            self_ns: 0,
            min_ns: u64::MAX,
            max_ns: 0,
            durs: Vec::new(),
        }
    }
}

type SpanMap = BTreeMap<&'static str, SpanStat>;
type CounterMap = BTreeMap<&'static str, u64>;

struct ThreadTracer {
    stack: Vec<Frame>,
    spans: SpanMap,
    adds: CounterMap,
    maxes: CounterMap,
}

impl ThreadTracer {
    const fn new() -> ThreadTracer {
        ThreadTracer {
            stack: Vec::new(),
            spans: BTreeMap::new(),
            adds: BTreeMap::new(),
            maxes: BTreeMap::new(),
        }
    }

    fn is_empty(&self) -> bool {
        self.spans.is_empty() && self.adds.is_empty() && self.maxes.is_empty()
    }
}

impl Drop for ThreadTracer {
    /// Scoped-pool workers die at the end of each `execute_many`; their
    /// tables fold into the global aggregate here.
    fn drop(&mut self) {
        if self.is_empty() {
            return;
        }
        let spans = std::mem::take(&mut self.spans);
        let adds = std::mem::take(&mut self.adds);
        let maxes = std::mem::take(&mut self.maxes);
        let mut g = GLOBAL.lock().unwrap_or_else(|p| p.into_inner());
        g.threads += 1;
        merge_spans(&mut g.spans, spans);
        merge_adds(&mut g.adds, adds);
        merge_maxes(&mut g.maxes, maxes);
    }
}

struct Aggregate {
    threads: u64,
    spans: SpanMap,
    adds: CounterMap,
    maxes: CounterMap,
}

static GLOBAL: Mutex<Aggregate> = Mutex::new(Aggregate {
    threads: 0,
    spans: BTreeMap::new(),
    adds: BTreeMap::new(),
    maxes: BTreeMap::new(),
});

fn merge_spans(into: &mut SpanMap, from: SpanMap) {
    for (name, s) in from {
        let dst = into.entry(name).or_insert_with(SpanStat::new);
        if dst.parent.is_none() {
            dst.parent = s.parent;
        }
        dst.calls += s.calls;
        dst.total_ns += s.total_ns;
        dst.self_ns += s.self_ns;
        dst.min_ns = dst.min_ns.min(s.min_ns);
        dst.max_ns = dst.max_ns.max(s.max_ns);
        let room = SAMPLE_CAP.saturating_sub(dst.durs.len());
        dst.durs.extend(s.durs.into_iter().take(room));
    }
}

fn merge_adds(into: &mut CounterMap, from: CounterMap) {
    for (name, v) in from {
        *into.entry(name).or_insert(0) += v;
    }
}

fn merge_maxes(into: &mut CounterMap, from: CounterMap) {
    for (name, v) in from {
        let dst = into.entry(name).or_insert(0);
        *dst = (*dst).max(v);
    }
}

/// RAII span: records `now - start` into the thread-local table on
/// drop, attributing the elapsed time to the parent's child total so
/// self time is exact.  Inert (no clock read) when tracing is off.
pub struct SpanGuard {
    armed: bool,
}

#[must_use = "a span measures the scope it is bound to; binding to _ drops it immediately"]
pub fn span(name: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard { armed: false };
    }
    let start = now_ns();
    TRACER.with(|t| {
        let mut t = t.borrow_mut();
        let parent = t.stack.last().map(|f| f.name);
        t.stack.push(Frame {
            name,
            parent,
            start,
            child_ns: 0,
        });
    });
    SpanGuard { armed: true }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        let end = now_ns();
        TRACER.with(|t| {
            let mut t = t.borrow_mut();
            let Some(f) = t.stack.pop() else { return };
            let total = end.saturating_sub(f.start);
            let self_ns = total.saturating_sub(f.child_ns);
            if let Some(top) = t.stack.last_mut() {
                top.child_ns += total;
            }
            let stat = t.spans.entry(f.name).or_insert_with(SpanStat::new);
            if stat.parent.is_none() {
                stat.parent = f.parent;
            }
            stat.calls += 1;
            stat.total_ns += total;
            stat.self_ns += self_ns;
            stat.min_ns = stat.min_ns.min(total);
            stat.max_ns = stat.max_ns.max(total);
            if stat.durs.len() < SAMPLE_CAP {
                stat.durs.push(total);
            }
        });
    }
}

/// Add `delta` to a summing counter (arena hits/misses, fan-out tallies).
#[inline]
pub fn counter_add(name: &'static str, delta: u64) {
    if !enabled() {
        return;
    }
    TRACER.with(|t| {
        let mut t = t.borrow_mut();
        *t.adds.entry(name).or_insert(0) += delta;
    });
}

/// Fold `value` into a high-water counter (arena high-water bytes).
#[inline]
pub fn counter_max(name: &'static str, value: u64) {
    if !enabled() {
        return;
    }
    TRACER.with(|t| {
        let mut t = t.borrow_mut();
        let dst = t.maxes.entry(name).or_insert(0);
        *dst = (*dst).max(value);
    });
}

/// One aggregated span in a [`TraceReport`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpanRow {
    pub name: String,
    pub parent: Option<String>,
    pub calls: u64,
    pub total_ns: u64,
    pub self_ns: u64,
    pub min_ns: u64,
    pub max_ns: u64,
    pub p50_ns: u64,
    pub p99_ns: u64,
}

/// One counter in a [`TraceReport`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CounterRow {
    pub name: String,
    pub value: u64,
}

/// Snapshot of every span and counter merged across threads.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct TraceReport {
    pub threads: u64,
    pub spans: Vec<SpanRow>,
    pub counters: Vec<CounterRow>,
}

fn build_rows(threads: u64, spans: SpanMap, adds: CounterMap, maxes: CounterMap) -> TraceReport {
    let mut span_rows = Vec::with_capacity(spans.len());
    for (name, s) in spans {
        let (p50, p99) = if s.durs.is_empty() {
            (0, 0)
        } else {
            let durs: Vec<f64> = s.durs.iter().map(|&d| d as f64).collect();
            (
                stats::percentile(&durs, 50.0) as u64,
                stats::percentile(&durs, 99.0) as u64,
            )
        };
        span_rows.push(SpanRow {
            name: name.to_string(),
            parent: s.parent.map(str::to_string),
            calls: s.calls,
            total_ns: s.total_ns,
            self_ns: s.self_ns,
            min_ns: if s.min_ns == u64::MAX { 0 } else { s.min_ns },
            max_ns: s.max_ns,
            p50_ns: p50,
            p99_ns: p99,
        });
    }
    let mut counter_rows = Vec::with_capacity(adds.len() + maxes.len());
    for (name, value) in adds.into_iter().chain(maxes) {
        counter_rows.push(CounterRow {
            name: name.to_string(),
            value,
        });
    }
    counter_rows.sort_by(|a, b| a.name.cmp(&b.name));
    TraceReport {
        threads,
        spans: span_rows,
        counters: counter_rows,
    }
}

fn collect(reset: bool) -> TraceReport {
    let (lspans, ladds, lmaxes) = TRACER.with(|t| {
        let mut t = t.borrow_mut();
        if reset {
            t.stack.clear();
            (
                std::mem::take(&mut t.spans),
                std::mem::take(&mut t.adds),
                std::mem::take(&mut t.maxes),
            )
        } else {
            (t.spans.clone(), t.adds.clone(), t.maxes.clone())
        }
    });
    let had_local = !(lspans.is_empty() && ladds.is_empty() && lmaxes.is_empty());
    let mut g = GLOBAL.lock().unwrap_or_else(|p| p.into_inner());
    let (mut spans, mut adds, mut maxes, mut threads) = if reset {
        let taken = (
            std::mem::take(&mut g.spans),
            std::mem::take(&mut g.adds),
            std::mem::take(&mut g.maxes),
            g.threads,
        );
        g.threads = 0;
        taken
    } else {
        (g.spans.clone(), g.adds.clone(), g.maxes.clone(), g.threads)
    };
    drop(g);
    if had_local {
        threads += 1;
    }
    merge_spans(&mut spans, lspans);
    merge_adds(&mut adds, ladds);
    merge_maxes(&mut maxes, lmaxes);
    build_rows(threads, spans, adds, maxes)
}

/// Drain the calling thread's table plus the global aggregate into a
/// report, leaving both empty for the next run.
pub fn take_report() -> TraceReport {
    collect(true)
}

/// Non-draining view of everything recorded so far (heartbeats).
pub fn snapshot() -> TraceReport {
    collect(false)
}

/// Discard everything recorded so far.
pub fn reset() {
    let _ = collect(true);
}

/// One-line progress summary for log/heartbeat lines: step-span volume
/// plus the current top self-time span.  `None` when tracing is off or
/// nothing was recorded yet.
pub fn heartbeat() -> Option<String> {
    if !enabled() {
        return None;
    }
    let report = snapshot();
    let top = report.spans.iter().max_by_key(|s| s.self_ns)?;
    let mut line = match report.spans.iter().find(|s| s.name == "train_step") {
        Some(ts) if ts.calls > 0 => format!(
            "train_step x{} p50 {:.1}ms",
            ts.calls,
            ts.p50_ns as f64 / 1e6
        ),
        _ => format!("{} spans", report.spans.len()),
    };
    line.push_str(&format!(
        " | top self: {} {:.1}ms",
        top.name,
        top.self_ns as f64 / 1e6
    ));
    Some(line)
}

const META_KEYS: [&str; 5] = ["schema", "kind", "threads", "spans", "counters"];
const SPAN_KEYS: [&str; 11] = [
    "schema", "kind", "name", "parent", "calls", "total_ns", "self_ns", "min_ns", "max_ns",
    "p50_ns", "p99_ns",
];
const COUNTER_KEYS: [&str; 4] = ["schema", "kind", "name", "value"];

fn check_keys(doc: &Json, allowed: &[&str]) -> Result<()> {
    let obj = doc.as_obj().context("trace event must be a JSON object")?;
    for k in obj.keys() {
        if !allowed.contains(&k.as_str()) {
            bail!("unknown trace event key {k:?}");
        }
    }
    Ok(())
}

/// One strictly-validated `sagebwd-trace-v1` event line.
fn parse_event(
    doc: &Json,
    report: &mut TraceReport,
    meta: &mut Option<(usize, usize)>,
) -> Result<()> {
    schema::expect_tag(doc, TRACE_SCHEMA)?;
    match schema::str_field(doc, "kind")? {
        "meta" => {
            check_keys(doc, &META_KEYS)?;
            if meta.is_some() {
                bail!("duplicate meta event");
            }
            if !report.spans.is_empty() || !report.counters.is_empty() {
                bail!("meta event must come first");
            }
            report.threads = schema::u64_field(doc, "threads")?;
            *meta = Some((
                schema::usize_field(doc, "spans")?,
                schema::usize_field(doc, "counters")?,
            ));
        }
        "span" => {
            check_keys(doc, &SPAN_KEYS)?;
            report.spans.push(SpanRow {
                name: schema::str_field(doc, "name")?.to_string(),
                parent: schema::opt_str_field(doc, "parent")?.map(str::to_string),
                calls: schema::u64_field(doc, "calls")?,
                total_ns: schema::u64_field(doc, "total_ns")?,
                self_ns: schema::u64_field(doc, "self_ns")?,
                min_ns: schema::u64_field(doc, "min_ns")?,
                max_ns: schema::u64_field(doc, "max_ns")?,
                p50_ns: schema::u64_field(doc, "p50_ns")?,
                p99_ns: schema::u64_field(doc, "p99_ns")?,
            });
        }
        "counter" => {
            check_keys(doc, &COUNTER_KEYS)?;
            report.counters.push(CounterRow {
                name: schema::str_field(doc, "name")?.to_string(),
                value: schema::u64_field(doc, "value")?,
            });
        }
        other => bail!("unknown trace event kind {other:?}"),
    }
    Ok(())
}

impl TraceReport {
    /// Serialize as `sagebwd-trace-v1` JSONL: meta line, then spans,
    /// then counters.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        let meta = Json::from_pairs(vec![
            ("schema", Json::from(TRACE_SCHEMA)),
            ("kind", Json::from("meta")),
            ("threads", Json::from(self.threads as i64)),
            ("spans", Json::from(self.spans.len())),
            ("counters", Json::from(self.counters.len())),
        ]);
        out.push_str(&meta.to_string());
        out.push('\n');
        for s in &self.spans {
            let parent = match &s.parent {
                Some(p) => Json::from(p.as_str()),
                None => Json::Null,
            };
            let ev = Json::from_pairs(vec![
                ("schema", Json::from(TRACE_SCHEMA)),
                ("kind", Json::from("span")),
                ("name", Json::from(s.name.as_str())),
                ("parent", parent),
                ("calls", Json::from(s.calls as i64)),
                ("total_ns", Json::from(s.total_ns as i64)),
                ("self_ns", Json::from(s.self_ns as i64)),
                ("min_ns", Json::from(s.min_ns as i64)),
                ("max_ns", Json::from(s.max_ns as i64)),
                ("p50_ns", Json::from(s.p50_ns as i64)),
                ("p99_ns", Json::from(s.p99_ns as i64)),
            ]);
            out.push_str(&ev.to_string());
            out.push('\n');
        }
        for c in &self.counters {
            let ev = Json::from_pairs(vec![
                ("schema", Json::from(TRACE_SCHEMA)),
                ("kind", Json::from("counter")),
                ("name", Json::from(c.name.as_str())),
                ("value", Json::from(c.value as i64)),
            ]);
            out.push_str(&ev.to_string());
            out.push('\n');
        }
        out
    }

    /// Strict parse of a `sagebwd-trace-v1` JSONL log.  Rejects unknown
    /// keys, unknown kinds, a missing/duplicated/late meta line, and
    /// meta counts that disagree with the event lines.
    pub fn parse_jsonl(text: &str) -> Result<TraceReport> {
        let mut report = TraceReport::default();
        let mut meta: Option<(usize, usize)> = None;
        for (i, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() {
                continue;
            }
            let doc = Json::parse(line).with_context(|| format!("trace line {}", i + 1))?;
            parse_event(&doc, &mut report, &mut meta)
                .with_context(|| format!("trace line {}", i + 1))?;
        }
        let Some((spans, counters)) = meta else {
            bail!("trace log has no meta event");
        };
        if spans != report.spans.len() || counters != report.counters.len() {
            bail!(
                "meta counts ({spans} spans, {counters} counters) disagree with \
                 event lines ({} spans, {} counters)",
                report.spans.len(),
                report.counters.len()
            );
        }
        Ok(report)
    }

    /// Fixed-width self-time table for `sagebwd trace-report`.
    pub fn render_table(&self) -> String {
        let mut rows: Vec<&SpanRow> = self.spans.iter().collect();
        rows.sort_by(|a, b| b.self_ns.cmp(&a.self_ns).then_with(|| a.name.cmp(&b.name)));
        let mut out = format!("trace: {} spans over {} thread(s)\n", rows.len(), self.threads);
        // Uppercase headers keep these literals out of the A5 key scan.
        out.push_str(&format!(
            "{:<14} {:<12} {:>9} {:>11} {:>11} {:>10} {:>10} {:>10} {:>10}\n",
            "SPAN", "PARENT", "CALLS", "TOTAL_MS", "SELF_MS", "MIN_US", "MAX_US", "P50_US", "P99_US"
        ));
        for r in rows {
            out.push_str(&format!(
                "{:<14} {:<12} {:>9} {:>11.3} {:>11.3} {:>10.1} {:>10.1} {:>10.1} {:>10.1}\n",
                r.name,
                r.parent.as_deref().unwrap_or("-"),
                r.calls,
                r.total_ns as f64 / 1e6,
                r.self_ns as f64 / 1e6,
                r.min_ns as f64 / 1e3,
                r.max_ns as f64 / 1e3,
                r.p50_ns as f64 / 1e3,
                r.p99_ns as f64 / 1e3,
            ));
        }
        if !self.counters.is_empty() {
            out.push_str(&format!("\n{:<28} {:>14}\n", "COUNTER", "VALUE"));
            for c in &self.counters {
                out.push_str(&format!("{:<28} {:>14}\n", c.name, c.value));
            }
        }
        out
    }

    /// Compact summary block for registry run manifests.  The keys are
    /// a subset of the documented `sagebwd-trace-v1` fields.
    pub fn summary_json(&self) -> Json {
        let total: u64 = self
            .spans
            .iter()
            .filter(|s| s.parent.is_none())
            .map(|s| s.total_ns)
            .sum();
        Json::from_pairs(vec![
            ("threads", Json::from(self.threads as i64)),
            ("spans", Json::from(self.spans.len())),
            ("counters", Json::from(self.counters.len())),
            ("total_ns", Json::from(total as i64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> TraceReport {
        TraceReport {
            threads: 2,
            spans: vec![
                SpanRow {
                    name: "train_step".to_string(),
                    parent: None,
                    calls: 5,
                    total_ns: 5_000_000,
                    self_ns: 1_000_000,
                    min_ns: 900_000,
                    max_ns: 1_200_000,
                    p50_ns: 1_000_000,
                    p99_ns: 1_190_000,
                },
                SpanRow {
                    name: "gemm_nn".to_string(),
                    parent: Some("layer".to_string()),
                    calls: 40,
                    total_ns: 4_000_000,
                    self_ns: 4_000_000,
                    min_ns: 80_000,
                    max_ns: 130_000,
                    p50_ns: 100_000,
                    p99_ns: 128_000,
                },
            ],
            counters: vec![CounterRow {
                name: "ws_hit".to_string(),
                value: 123,
            }],
        }
    }

    #[test]
    fn jsonl_roundtrip_is_lossless() {
        let r = report();
        let parsed = TraceReport::parse_jsonl(&r.to_jsonl()).unwrap();
        assert_eq!(parsed, r);
    }

    #[test]
    fn parse_rejects_unknown_key() {
        // Splice an extra key into every span line (Obj keys serialize
        // sorted, so span lines open with "calls").
        let bad = report().to_jsonl().replace("{\"calls\"", "{\"bogus\":1,\"calls\"");
        assert!(TraceReport::parse_jsonl(&bad).is_err());
    }

    #[test]
    fn parse_rejects_unknown_kind_and_missing_meta() {
        let r = report();
        let text = r.to_jsonl().replace("\"kind\":\"counter\"", "\"kind\":\"weird\"");
        assert!(TraceReport::parse_jsonl(&text).is_err());
        let no_meta: String = r
            .to_jsonl()
            .lines()
            .skip(1)
            .map(|l| format!("{l}\n"))
            .collect();
        assert!(TraceReport::parse_jsonl(&no_meta).is_err());
    }

    #[test]
    fn parse_rejects_count_mismatch_and_wrong_schema() {
        let r = report();
        let text = r.to_jsonl().replace("\"spans\":2", "\"spans\":7");
        assert!(TraceReport::parse_jsonl(&text).is_err());
        let text = r.to_jsonl().replace(TRACE_SCHEMA, "sagebwd-trace-v0");
        assert!(TraceReport::parse_jsonl(&text).is_err());
    }

    #[test]
    fn table_and_summary_cover_the_report() {
        let r = report();
        let table = r.render_table();
        assert!(table.contains("train_step") && table.contains("gemm_nn"));
        assert!(table.contains("ws_hit"));
        let s = r.summary_json();
        assert_eq!(s.get("spans").unwrap().as_usize().unwrap(), 2);
        // Root total = train_step only (gemm_nn has a parent).
        assert_eq!(s.get("total_ns").unwrap().as_i64().unwrap(), 5_000_000);
    }
}
