//! Cache-blocked, optionally parallel dense micro-kernels — the compute
//! engine behind every native matmul (DESIGN.md §11).
//!
//! Three f32 GEMM layouts (the ones attention uses: `A·B`, `A·Bᵀ`,
//! `Aᵀ·B`) plus a flat i8×i8→i32 GEMM for the quantized tiles.  All of
//! them reduce to one core kernel, [`gemm_nn`]: `ikj` loop order with an
//! `MR`-row register block and slice-based inner loops (independent
//! per-lane `acc[j] += a·b[j]` updates, so the compiler can autovectorize
//! without reassociating float adds).  The transposed layouts pack the
//! transposed operand once and then run the same kernel.
//!
//! ## Determinism contract
//!
//! For every output element `(i, j)` the products `a[i,t]·b[t,j]` are
//! accumulated in ascending `t` order, starting from `0.0` — exactly the
//! per-element order of the retained naive references ([`naive_matmul`],
//! [`naive_matmul_nt`], [`naive_matmul_tn`]).  Row/column blocking and
//! register blocking never touch that order, and parallelism partitions
//! work by *output rows* (each row is written by exactly one thread, in
//! the serial per-row order).  Therefore:
//!
//! > blocked == naive == parallel, **bitwise**, at any thread count.
//!
//! `rust/tests/linalg_properties.rs` asserts this across odd shapes and
//! `SAGEBWD_THREADS ∈ {1, 4}`; `python/compile/make_golden.py` emits
//! cross-language golden vectors computed in the same order.
//!
//! ## ISA tiers
//!
//! The row kernels are dispatched per [`simd::IsaTier`] (runtime AVX2/
//! FMA detection, `SAGEBWD_ISA` override — DESIGN.md §15).  The tier is
//! resolved **once per public call, on the calling thread, before any
//! workers spawn**, and passed down by value, so a `simd::with_isa` pin
//! governs the whole call even though thread-locals don't propagate
//! into scoped workers.  The contract above holds *within* each tier at
//! any thread count; the default tier (`min(hw, Avx2)`) and the Scalar
//! tier are bitwise identical for f32, and the i8 kernels are exact i32
//! in every tier, so the golden vectors hold at the default too.  Only
//! the opt-in Fma tier may change f32 bytes (single-rounding fmadd).
//!
//! ## Threading
//!
//! [`thread_count`] reads `SAGEBWD_THREADS` (default:
//! `available_parallelism`).  The auto-dispatching entry points only fan
//! out when the MAC volume crosses [`PAR_MIN_VOLUME`] — tiny model-scale
//! matmuls stay serial so thread spawn latency never lands on the
//! training hot path.  The `*_threads` variants honor an explicit count
//! (used by benches and the property tests).
//!
//! ## Observability
//!
//! The layout entry points open `telemetry::trace` spans (`gemm_nn`,
//! `gemm_nt`, `gemm_tn`, `i8_gemm_*`) at the call boundary — never
//! inside the blocked loops — so `--trace` attributes GEMM-family self
//! time with per-call overhead only, and a disabled trace costs one
//! thread-local branch per call.

use std::sync::OnceLock;

use crate::telemetry::trace;
use crate::tensor::simd;

/// Rows processed together by the register block of [`gemm_nn`]: the B
/// row loaded in the inner loop is reused `MR` times (the SIMD tiers in
/// [`simd`] use the same row block, so every tier sees the same row
/// partition).
const MR: usize = 4;

/// Minimum `m·k·n` MAC volume before the auto entry points go parallel
/// (~a 256×64×256 matmul).  Below this, scoped-thread spawn overhead
/// outweighs the win; determinism is unaffected either way.
pub const PAR_MIN_VOLUME: usize = 1 << 22;

/// Minimum summed `n²·d` volume before a **batched coarse-grained** call
/// set (the backend's `execute_many` head fan-out) goes parallel.  Much
/// lower than [`PAR_MIN_VOLUME`]: each batched call is a whole attention
/// forward/backward — quantization, online softmax, and several GEMMs,
/// ≈5–10× the raw `n²·d` MACs — so thread spawn amortizes sooner.
pub const PAR_MIN_BATCH_VOLUME: usize = 1 << 19;

// ---------------------------------------------------------------------------
// Thread-count resolution + work partitioning
// ---------------------------------------------------------------------------

fn default_threads() -> usize {
    static CACHE: OnceLock<usize> = OnceLock::new();
    *CACHE.get_or_init(|| {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    })
}

/// Worker count: `SAGEBWD_THREADS` if set, else `available_parallelism`.
/// `0` means serial (the conventional "off" value — falling back to all
/// cores there would be the opposite of the user's intent); unparseable
/// values fall back to the default.  Read per call so tests and
/// harnesses can re-configure within one process.
pub fn thread_count() -> usize {
    let n = match std::env::var("SAGEBWD_THREADS") {
        Ok(s) => match s.trim().parse::<usize>() {
            Ok(0) => 1,
            Ok(n) => n,
            Err(_) => default_threads(),
        },
        Err(_) => default_threads(),
    };
    // The orchestrator's per-thread budget cap (see with_thread_cap).
    THREAD_CAP.with(|c| c.get()).map_or(n, |cap| n.min(cap))
}

/// Split `n` items into at most `parts` contiguous, near-equal, non-empty
/// ranges (fewer when `n < parts`).  Total-function edge cases: `n = 0`
/// returns no ranges (never a `(0, 0)` stub that would feed a zero-row
/// spawn) and `parts ∈ {0, > n}` clamps to `[1, n]`, so every returned
/// range is non-empty by construction for any tier-dependent row-chunk
/// shape the callers produce.
pub fn partition(n: usize, parts: usize) -> Vec<(usize, usize)> {
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, n);
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut lo = 0;
    for p in 0..parts {
        // parts <= n, so base >= 1 and every range is non-empty.
        let len = base + usize::from(p < rem);
        out.push((lo, lo + len));
        lo += len;
    }
    debug_assert_eq!(lo, n);
    out
}

thread_local! {
    /// When set, [`auto_threads`] stays serial regardless of volume — the
    /// backend's `execute_many` workers run under this so coarse-grained
    /// head fan-out never nest-spawns per-GEMM threads (T² cores-thrashing
    /// oversubscription).  Explicit `*_threads` calls are unaffected.
    static FORCE_SERIAL: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Run `f` with the auto-dispatching entry points pinned serial on this
/// thread.  Results are unchanged (the determinism contract); only the
/// dispatch decision differs.
pub fn with_serial<R>(f: impl FnOnce() -> R) -> R {
    FORCE_SERIAL.with(|c| {
        let prev = c.replace(true);
        let r = f();
        c.set(prev);
        r
    })
}

thread_local! {
    /// Per-thread ceiling on [`thread_count`] — the grid orchestrator's
    /// budget-sharing primitive (DESIGN.md §12): J grid workers each run
    /// their cell under a cap of ⌈T/J⌉ so grid-level × engine-level
    /// threads stay ≈ `SAGEBWD_THREADS` instead of J·T.  Thread-local
    /// (unlike [`pin_threads`]' process-global env override) so
    /// concurrent workers can hold different caps without racing.
    static THREAD_CAP: std::cell::Cell<Option<usize>> =
        const { std::cell::Cell::new(None) };
}

/// Run `f` with the engine's worker count capped at `cap` on this thread
/// (floor 1).  Results are unchanged — the determinism contract makes
/// output independent of the realized thread count; only dispatch width
/// differs.  The cap applies where spawn decisions are made (this
/// thread); workers spawned under it run serial via the existing
/// [`with_serial`] nesting guard in `execute_many`.
pub fn with_thread_cap<R>(cap: usize, f: impl FnOnce() -> R) -> R {
    THREAD_CAP.with(|c| {
        let prev = c.replace(Some(cap.max(1)));
        let r = f();
        c.set(prev);
        r
    })
}

/// RAII override of `SAGEBWD_THREADS`: pins the worker count until the
/// guard drops; the previous value is restored even on panic.
/// Process-global — callers must not have concurrent env readers at pin
/// time (the bench harnesses pin while single-threaded).
pub struct ThreadCountGuard(Option<String>);

pub fn pin_threads(n: usize) -> ThreadCountGuard {
    let saved = std::env::var("SAGEBWD_THREADS").ok();
    std::env::set_var("SAGEBWD_THREADS", n.to_string());
    ThreadCountGuard(saved)
}

impl Drop for ThreadCountGuard {
    fn drop(&mut self) {
        match self.0.take() {
            Some(v) => std::env::set_var("SAGEBWD_THREADS", v),
            None => std::env::remove_var("SAGEBWD_THREADS"),
        }
    }
}

fn auto_threads(m: usize, k: usize, n: usize) -> usize {
    if FORCE_SERIAL.with(|c| c.get())
        || m.saturating_mul(k).saturating_mul(n) < PAR_MIN_VOLUME
    {
        1
    } else {
        thread_count()
    }
}

// ---------------------------------------------------------------------------
// f32 core kernel + packing
// ---------------------------------------------------------------------------

/// Serial blocked `A·B` over output rows `[i0, i1)` of an `(m,k)×(k,n)`
/// product.  `out` covers exactly those rows and must be zero-filled.
/// This is the Scalar-tier kernel, retained verbatim: `simd` delegates
/// to it for the scalar tier and for sub-`MR` row tails, and the SIMD
/// tiers reproduce its exact per-element accumulation order.
pub(crate) fn gemm_nn_rows_scalar(a: &[f32], b: &[f32], k: usize, n: usize, i0: usize, i1: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), (i1 - i0) * n);
    let mut i = i0;
    while i < i1 {
        let mr = MR.min(i1 - i);
        let obase = (i - i0) * n;
        for t in 0..k {
            let brow = &b[t * n..(t + 1) * n];
            for r in 0..mr {
                let av = a[(i + r) * k + t];
                let orow = &mut out[obase + r * n..obase + (r + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
        i += mr;
    }
}

/// Blocked serial `A·B`: `(m,k) × (k,n) → (m,n)`.  `out` is overwritten.
pub fn gemm_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    let _t = trace::span("gemm_nn");
    par_gemm_nn(a, b, m, k, n, out, 1);
}

/// `dst[(c, r)] = src[(r, c)]` — pack a transposed copy of a row-major
/// `(rows, cols)` matrix; `dst` must hold `rows·cols` elements.
fn pack_transpose<T: Copy>(src: &[T], rows: usize, cols: usize, dst: &mut [T]) {
    debug_assert_eq!(src.len(), rows * cols);
    debug_assert_eq!(dst.len(), rows * cols);
    if rows == 0 || cols == 0 {
        // Degenerate panel: nothing to pack (and `chunks_exact(0)` panics).
        return;
    }
    for (r, row) in src.chunks_exact(cols).enumerate() {
        for (c, &v) in row.iter().enumerate() {
            dst[c * rows + r] = v;
        }
    }
}

/// [`pack_transpose`] for the f32 panels.
pub fn pack_transpose_f32(src: &[f32], rows: usize, cols: usize, dst: &mut [f32]) {
    pack_transpose(src, rows, cols, dst);
}

/// [`pack_transpose`] for the i8 panels.
pub fn pack_transpose_i8(src: &[i8], rows: usize, cols: usize, dst: &mut [i8]) {
    pack_transpose(src, rows, cols, dst);
}

fn par_gemm_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32], threads: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    // Resolve the ISA tier once, before any spawn: scoped workers can't
    // see this thread's `with_isa` pin, so it travels by value.
    let tier = simd::active_tier();
    simd::record_dispatch(tier);
    let threads = threads.clamp(1, m.max(1));
    if threads <= 1 {
        simd::gemm_f32_rows(a, b, k, n, 0, m, out, tier);
        return;
    }
    std::thread::scope(|s| {
        let mut rest = out;
        for (i0, i1) in partition(m, threads) {
            let (chunk, tail) = rest.split_at_mut((i1 - i0) * n);
            rest = tail;
            s.spawn(move || simd::gemm_f32_rows(a, b, k, n, i0, i1, chunk, tier));
        }
    });
}

// ---------------------------------------------------------------------------
// f32 public layouts
// ---------------------------------------------------------------------------

thread_local! {
    /// Per-thread pack scratch for the auto entry points, so the
    /// `Tensor::matmul_nt`/`matmul_tn` hot paths (model forward/backward)
    /// stay allocation-free after warmup without threading a workspace
    /// through every Tensor method.
    static AUTO_PACK: std::cell::RefCell<Vec<f32>> = const { std::cell::RefCell::new(Vec::new()) };
}

/// `A·B` with an explicit thread count (`(m,k) × (k,n) → (m,n)`).
pub fn matmul_threads(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32], threads: usize) {
    let _t = trace::span("gemm_nn");
    par_gemm_nn(a, b, m, k, n, out, threads);
}

/// `A·B`, auto-dispatching serial/parallel by MAC volume.
pub fn matmul_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    let _t = trace::span("gemm_nn");
    par_gemm_nn(a, b, m, k, n, out, auto_threads(m, k, n));
}

/// `A·Bᵀ` (`(m,k) × (n,k) → (m,n)`) with explicit threads and caller
/// scratch for the packed `Bᵀ` panel (resized to `k·n`).
pub fn matmul_nt_scratch(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    threads: usize,
    pack: &mut Vec<f32>,
) {
    let _t = trace::span("gemm_nt");
    debug_assert_eq!(b.len(), n * k);
    pack.clear();
    pack.resize(k * n, 0.0);
    pack_transpose_f32(b, n, k, pack);
    par_gemm_nn(a, pack, m, k, n, out, threads);
}

/// `A·Bᵀ` with an explicit thread count.
pub fn matmul_nt_threads(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32], threads: usize) {
    matmul_nt_scratch(a, b, m, k, n, out, threads, &mut Vec::new());
}

/// `A·Bᵀ`, auto-dispatching by MAC volume (thread-local pack scratch).
pub fn matmul_nt_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    AUTO_PACK.with(|p| {
        matmul_nt_scratch(a, b, m, k, n, out, auto_threads(m, k, n), &mut p.borrow_mut())
    });
}

/// `Aᵀ·B` (`(k,m) × (k,n) → (m,n)`) with explicit threads and caller
/// scratch for the packed `Aᵀ` panel (resized to `k·m`).
pub fn matmul_tn_scratch(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    threads: usize,
    pack: &mut Vec<f32>,
) {
    let _t = trace::span("gemm_tn");
    debug_assert_eq!(a.len(), k * m);
    pack.clear();
    pack.resize(k * m, 0.0);
    pack_transpose_f32(a, k, m, pack);
    par_gemm_nn(pack, b, m, k, n, out, threads);
}

/// `Aᵀ·B` with an explicit thread count.
pub fn matmul_tn_threads(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32], threads: usize) {
    matmul_tn_scratch(a, b, m, k, n, out, threads, &mut Vec::new());
}

/// `Aᵀ·B`, auto-dispatching by MAC volume (thread-local pack scratch).
pub fn matmul_tn_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    AUTO_PACK.with(|p| {
        matmul_tn_scratch(a, b, m, k, n, out, auto_threads(m, k, n), &mut p.borrow_mut())
    });
}

// ---------------------------------------------------------------------------
// i8 × i8 → i32 blocked GEMM (flat tiles; integer, so exact in any order)
// ---------------------------------------------------------------------------

/// Serial blocked i8 `A·B` over rows `[i0, i1)`; `out` zero-filled by the
/// caller.  Scalar-tier kernel, retained verbatim (see
/// [`gemm_nn_rows_scalar`]); every tier matches it bit for bit because
/// i32 accumulation is exact.
pub(crate) fn i8_gemm_nn_rows_scalar(a: &[i8], b: &[i8], k: usize, n: usize, i0: usize, i1: usize, out: &mut [i32]) {
    debug_assert_eq!(out.len(), (i1 - i0) * n);
    let mut i = i0;
    while i < i1 {
        let mr = MR.min(i1 - i);
        let obase = (i - i0) * n;
        for t in 0..k {
            let brow = &b[t * n..(t + 1) * n];
            for r in 0..mr {
                let av = a[(i + r) * k + t] as i32;
                let orow = &mut out[obase + r * n..obase + (r + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv as i32;
                }
            }
        }
        i += mr;
    }
}

/// Blocked i8 `A·B`: `(m,k) × (k,n) → (m,n)` in exact i32.
pub fn int8_gemm_nn(a: &[i8], b: &[i8], m: usize, k: usize, n: usize, out: &mut [i32]) {
    int8_gemm_nn_threads(a, b, m, k, n, out, 1);
}

/// Blocked i8 `A·B` with an explicit thread count (output-row partition).
pub fn int8_gemm_nn_threads(a: &[i8], b: &[i8], m: usize, k: usize, n: usize, out: &mut [i32], threads: usize) {
    let _t = trace::span("i8_gemm_nn");
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    out.fill(0);
    // Tier resolved pre-spawn, like par_gemm_nn (exact i32, so the tier
    // affects speed only — never the bytes).
    let tier = simd::active_tier();
    simd::record_dispatch(tier);
    let threads = threads.clamp(1, m.max(1));
    if threads <= 1 {
        simd::gemm_i8_rows(a, b, k, n, 0, m, out, tier);
        return;
    }
    std::thread::scope(|s| {
        let mut rest = out;
        for (i0, i1) in partition(m, threads) {
            let (chunk, tail) = rest.split_at_mut((i1 - i0) * n);
            rest = tail;
            s.spawn(move || simd::gemm_i8_rows(a, b, k, n, i0, i1, chunk, tier));
        }
    });
}

/// Blocked i8 `A·B`, auto-dispatching serial/parallel by MAC volume
/// (honors [`with_serial`], so `execute_many` workers never nest-spawn).
pub fn int8_gemm_nn_auto(a: &[i8], b: &[i8], m: usize, k: usize, n: usize, out: &mut [i32]) {
    int8_gemm_nn_threads(a, b, m, k, n, out, auto_threads(m, k, n));
}

/// Blocked i8 `A·Bᵀ`: `(m,k) × (n,k) → (m,n)`; `pack` is scratch for the
/// transposed `Bᵀ` panel.
pub fn int8_gemm_nt(a: &[i8], b: &[i8], m: usize, k: usize, n: usize, out: &mut [i32], pack: &mut Vec<i8>) {
    int8_gemm_nt_threads(a, b, m, k, n, out, 1, pack);
}

/// Blocked i8 `A·Bᵀ` with an explicit thread count: pack `Bᵀ` once, then
/// partition output rows exactly like [`int8_gemm_nn_threads`] — exact
/// i32, so bitwise thread-invariant by construction.
#[allow(clippy::too_many_arguments)]
pub fn int8_gemm_nt_threads(a: &[i8], b: &[i8], m: usize, k: usize, n: usize, out: &mut [i32], threads: usize, pack: &mut Vec<i8>) {
    let _t = trace::span("i8_gemm_nt");
    debug_assert_eq!(b.len(), n * k);
    pack.clear();
    pack.resize(k * n, 0);
    pack_transpose_i8(b, n, k, pack);
    int8_gemm_nn_threads(a, pack, m, k, n, out, threads);
}

/// Blocked i8 `A·Bᵀ`, auto-dispatching by MAC volume.
pub fn int8_gemm_nt_auto(a: &[i8], b: &[i8], m: usize, k: usize, n: usize, out: &mut [i32], pack: &mut Vec<i8>) {
    int8_gemm_nt_threads(a, b, m, k, n, out, auto_threads(m, k, n), pack);
}

/// Blocked i8 `Aᵀ·B`: `(k,m) × (k,n) → (m,n)`; `pack` is scratch for the
/// transposed `Aᵀ` panel.
pub fn int8_gemm_tn(a: &[i8], b: &[i8], m: usize, k: usize, n: usize, out: &mut [i32], pack: &mut Vec<i8>) {
    int8_gemm_tn_threads(a, b, m, k, n, out, 1, pack);
}

/// Blocked i8 `Aᵀ·B` with an explicit thread count (same output-row
/// partition as [`int8_gemm_nn_threads`] after packing `Aᵀ`).
#[allow(clippy::too_many_arguments)]
pub fn int8_gemm_tn_threads(a: &[i8], b: &[i8], m: usize, k: usize, n: usize, out: &mut [i32], threads: usize, pack: &mut Vec<i8>) {
    let _t = trace::span("i8_gemm_tn");
    debug_assert_eq!(a.len(), k * m);
    pack.clear();
    pack.resize(k * m, 0);
    pack_transpose_i8(a, k, m, pack);
    int8_gemm_nn_threads(pack, b, m, k, n, out, threads);
}

/// Blocked i8 `Aᵀ·B`, auto-dispatching by MAC volume.
pub fn int8_gemm_tn_auto(a: &[i8], b: &[i8], m: usize, k: usize, n: usize, out: &mut [i32], pack: &mut Vec<i8>) {
    int8_gemm_tn_threads(a, b, m, k, n, out, auto_threads(m, k, n), pack);
}

// ---------------------------------------------------------------------------
// Naive references (retained verbatim from the pre-engine substrate; the
// bitwise-equality oracle for everything above)
// ---------------------------------------------------------------------------

/// Naive `A·B` — the original `Tensor::matmul` triple loop.
pub fn naive_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    for i in 0..m {
        let row = &a[i * k..(i + 1) * k];
        let acc = &mut out[i * n..(i + 1) * n];
        for (t, &av) in row.iter().enumerate() {
            let brow = &b[t * n..(t + 1) * n];
            for (o, &bv) in acc.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// Naive `A·Bᵀ` — the original `Tensor::matmul_nt` dot-product loop.
pub fn naive_matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0f32;
            for (&av, &bv) in arow.iter().zip(brow) {
                acc += av * bv;
            }
            out[i * n + j] = acc;
        }
    }
}

/// Naive `Aᵀ·B` — the original `Tensor::matmul_tn` loop.
pub fn naive_matmul_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    for t in 0..k {
        let arow = &a[t * m..(t + 1) * m];
        let brow = &b[t * n..(t + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            let acc = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in acc.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn randv(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed, 0x11A6);
        let mut v = vec![0f32; len];
        rng.fill_gaussian(&mut v, 1.0);
        v
    }

    #[test]
    fn partition_covers_and_balances() {
        assert_eq!(partition(10, 3), vec![(0, 4), (4, 7), (7, 10)]);
        assert_eq!(partition(2, 8), vec![(0, 1), (1, 2)]);
        assert_eq!(partition(0, 4), Vec::<(usize, usize)>::new());
        assert_eq!(partition(5, 1), vec![(0, 5)]);
    }

    #[test]
    fn partition_degenerate_inputs_yield_no_empty_ranges() {
        // Regression (ISSUE 9 satellite): parts > n, parts = 0, n = 0
        // must never produce a zero-length range that would feed a
        // zero-row worker spawn.
        assert_eq!(partition(0, 0), Vec::<(usize, usize)>::new());
        assert_eq!(partition(0, 1000), Vec::<(usize, usize)>::new());
        assert_eq!(partition(3, 0), vec![(0, 3)]);
        assert_eq!(partition(3, 1000), vec![(0, 1), (1, 2), (2, 3)]);
        for (n, parts) in [(1usize, 7usize), (7, 7), (7, 8), (129, 1000)] {
            let ranges = partition(n, parts);
            assert!(ranges.len() <= parts.max(1) && ranges.len() <= n);
            assert!(ranges.iter().all(|&(lo, hi)| lo < hi), "{n}/{parts}: {ranges:?}");
            assert_eq!(ranges.first().map(|r| r.0), Some(0));
            assert_eq!(ranges.last().map(|r| r.1), Some(n));
            assert!(ranges.windows(2).all(|w| w[0].1 == w[1].0));
        }
    }

    #[test]
    fn blocked_nn_bitwise_matches_naive() {
        for &(m, k, n) in &[(1, 1, 1), (5, 3, 7), (17, 13, 9), (64, 32, 48)] {
            let a = randv(m * k, 1 + m as u64);
            let b = randv(k * n, 2 + n as u64);
            let mut want = vec![0f32; m * n];
            let mut got = vec![0f32; m * n];
            naive_matmul(&a, &b, m, k, n, &mut want);
            gemm_nn(&a, &b, m, k, n, &mut got);
            assert_eq!(want, got, "serial ({m},{k},{n})");
            for threads in [2, 4, 7] {
                matmul_threads(&a, &b, m, k, n, &mut got, threads);
                assert_eq!(want, got, "threads={threads} ({m},{k},{n})");
            }
        }
    }

    #[test]
    fn nt_and_tn_bitwise_match_their_naive_layouts() {
        let (m, k, n) = (11, 6, 13);
        let a = randv(m * k, 3);
        let bt = randv(n * k, 4); // (n, k) for nt
        let at = randv(k * m, 5); // (k, m) for tn
        let b = randv(k * n, 6);
        let mut want = vec![0f32; m * n];
        let mut got = vec![0f32; m * n];
        naive_matmul_nt(&a, &bt, m, k, n, &mut want);
        matmul_nt_threads(&a, &bt, m, k, n, &mut got, 3);
        assert_eq!(want, got, "nt");
        naive_matmul_tn(&at, &b, m, k, n, &mut want);
        matmul_tn_threads(&at, &b, m, k, n, &mut got, 3);
        assert_eq!(want, got, "tn");
    }

    #[test]
    fn i8_gemm_matches_quant_reference() {
        let (m, k, n) = (6, 5, 9);
        let a: Vec<i8> = (0..m * k).map(|i| (i as i32 * 37 % 255 - 127) as i8).collect();
        let b: Vec<i8> = (0..k * n).map(|i| (i as i32 * 91 % 255 - 127) as i8).collect();
        let want = crate::kernels::quant::int8_gemm(&a, &b, m, k, n);
        let mut got = vec![0i32; m * n];
        int8_gemm_nn(&a, &b, m, k, n, &mut got);
        assert_eq!(want, got);
        int8_gemm_nn_threads(&a, &b, m, k, n, &mut got, 4);
        assert_eq!(want, got);
        // nt/tn via packing agree with the quant references too.
        let mut pack = Vec::new();
        let mut bt = vec![0i8; k * n];
        pack_transpose_i8(&b, k, n, &mut bt);
        int8_gemm_nt(&a, &bt, m, k, n, &mut got, &mut pack);
        assert_eq!(want, got, "nt");
        let mut at = vec![0i8; m * k];
        pack_transpose_i8(&a, m, k, &mut at);
        int8_gemm_tn(&at, &b, m, k, n, &mut got, &mut pack);
        assert_eq!(want, got, "tn");
        // The parallel and auto variants are bitwise-identical (exact
        // i32), at thread counts below, at, and above m.
        for threads in [2, 4, 16] {
            int8_gemm_nt_threads(&a, &bt, m, k, n, &mut got, threads, &mut pack);
            assert_eq!(want, got, "nt threads={threads}");
            int8_gemm_tn_threads(&at, &b, m, k, n, &mut got, threads, &mut pack);
            assert_eq!(want, got, "tn threads={threads}");
        }
        int8_gemm_nn_auto(&a, &b, m, k, n, &mut got);
        assert_eq!(want, got, "nn auto");
        int8_gemm_nt_auto(&a, &bt, m, k, n, &mut got, &mut pack);
        assert_eq!(want, got, "nt auto");
        int8_gemm_tn_auto(&at, &b, m, k, n, &mut got, &mut pack);
        assert_eq!(want, got, "tn auto");
    }

    #[test]
    fn pack_transpose_roundtrip() {
        let src: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let mut t = vec![0f32; 12];
        pack_transpose_f32(&src, 3, 4, &mut t);
        assert_eq!(t[0], 0.0);
        assert_eq!(t[1], 4.0); // (1,0) → col-major of (3,4)
        let mut back = vec![0f32; 12];
        pack_transpose_f32(&t, 4, 3, &mut back);
        assert_eq!(src, back);
    }

    #[test]
    fn thread_count_is_positive() {
        assert!(thread_count() >= 1);
        assert!(default_threads() >= 1);
    }

    #[test]
    fn thread_cap_bounds_and_restores() {
        let before = thread_count();
        let capped = with_thread_cap(1, thread_count);
        assert_eq!(capped, 1);
        // Nesting: the tighter cap wins inside, the outer one is restored.
        with_thread_cap(2, || {
            assert!(thread_count() <= 2);
            assert_eq!(with_thread_cap(1, thread_count), 1);
            assert!(thread_count() <= 2);
        });
        // Cap of 0 floors at 1 (serial), never 0 workers.
        assert_eq!(with_thread_cap(0, thread_count), 1);
        assert_eq!(thread_count(), before);
        // A cap larger than the configured count is a no-op.
        assert_eq!(with_thread_cap(usize::MAX, thread_count), before);
    }

    #[test]
    fn thread_cap_does_not_change_results() {
        // The determinism contract extends to the cap: same bytes out.
        let (m, k, n) = (9, 7, 11);
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.37).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.11).cos()).collect();
        let mut want = vec![0f32; m * n];
        matmul_into(&a, &b, m, k, n, &mut want);
        let mut got = vec![0f32; m * n];
        with_thread_cap(1, || matmul_into(&a, &b, m, k, n, &mut got));
        assert_eq!(
            want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            got.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }
}
