//! Host-side tensor substrate: a flat `Vec<f32>`/`Vec<i32>` plus a shape.
//!
//! Originally this was *not* a math library — the heavy math ran inside
//! the XLA executables and the coordinator only needed construction,
//! random init, elementwise accumulation (§4.3), scaling, and the error
//! metrics.  The native CPU kernel backend (`kernels/`, DESIGN.md §3)
//! added the small dense-linear-algebra core it needs: 2-D matmuls in the
//! three layouts attention uses (`A·B`, `A·Bᵀ`, `Aᵀ·B`), transpose, and a
//! numerically-stable row softmax with logsumexp.
//!
//! The matmuls now execute on the cache-blocked, row-parallel compute
//! engine in [`linalg`] (bitwise-identical to the retained naive
//! references at any `SAGEBWD_THREADS` — DESIGN.md §11); [`simd`]
//! supplies the runtime-dispatched AVX2/FMA micro-kernels behind it
//! (DESIGN.md §15); [`workspace`] provides the reusable scratch arena
//! the hot loops thread through.

pub mod linalg;
pub mod simd;
pub mod workspace;

pub use workspace::Workspace;

use anyhow::{bail, Result};

use crate::util::rng::Pcg64;
use crate::util::stats;

/// Dense f32 tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let want: usize = shape.iter().product();
        if data.len() != want {
            bail!("shape {shape:?} wants {want} elements, got {}", data.len());
        }
        Ok(Tensor {
            shape: shape.to_vec(),
            data,
        })
    }

    pub fn scalar(x: f32) -> Tensor {
        Tensor {
            shape: vec![],
            data: vec![x],
        }
    }

    /// N(0, sigma²) random tensor from a seeded stream.
    pub fn randn(shape: &[usize], sigma: f32, rng: &mut Pcg64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_gaussian(&mut t.data, sigma);
        t
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn item(&self) -> f32 {
        assert_eq!(self.len(), 1, "item() on non-scalar {:?}", self.shape);
        self.data[0]
    }

    /// self += other (gradient accumulation hot path).
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// self *= c (microbatch averaging).
    pub fn scale(&mut self, c: f32) {
        for a in self.data.iter_mut() {
            *a *= c;
        }
    }

    pub fn fill(&mut self, c: f32) {
        self.data.fill(c);
    }

    pub fn rms(&self) -> f64 {
        stats::rms(&self.data)
    }

    pub fn cossim(&self, other: &Tensor) -> f64 {
        stats::cossim(&self.data, &other.data)
    }

    pub fn rel_l2(&self, other: &Tensor) -> f64 {
        stats::rel_l2(&self.data, &other.data)
    }

    /// Largest |x| — NaN-propagating: a single NaN element makes the
    /// result NaN (and ∞ dominates), so non-finite activations cannot
    /// evade ceiling checks built on this statistic (the fig1
    /// `max_attn_logit` divergence contract, DESIGN.md §10).  A plain
    /// `f32::max` fold would silently discard NaN.
    pub fn max_abs(&self) -> f32 {
        let mut m = 0f32;
        for &x in &self.data {
            let a = x.abs();
            if a.is_nan() {
                return f32::NAN;
            }
            if a > m {
                m = a;
            }
        }
        m
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }

    /// Rows/cols of a 2-D tensor.
    pub fn dims2(&self) -> Result<(usize, usize)> {
        match self.shape.as_slice() {
            [r, c] => Ok((*r, *c)),
            other => bail!("expected a 2-D tensor, got shape {other:?}"),
        }
    }

    /// `self · other` for 2-D tensors: `(m,k) × (k,n) → (m,n)`.
    ///
    /// Executes on the cache-blocked (auto-parallel) engine in [`linalg`]
    /// — bitwise-identical to the original naive triple loop at any
    /// `SAGEBWD_THREADS` (linalg's determinism contract, DESIGN.md §11).
    pub fn matmul(&self, other: &Tensor) -> Result<Tensor> {
        let (m, k) = self.dims2()?;
        let (k2, n) = other.dims2()?;
        if k != k2 {
            bail!("matmul: inner dims {k} vs {k2}");
        }
        let mut out = vec![0f32; m * n];
        linalg::matmul_into(&self.data, &other.data, m, k, n, &mut out);
        Tensor::from_vec(&[m, n], out)
    }

    /// `self · otherᵀ`: `(m,k) × (n,k) → (m,n)` — the Q·Kᵀ layout.
    pub fn matmul_nt(&self, other: &Tensor) -> Result<Tensor> {
        let (m, k) = self.dims2()?;
        let (n, k2) = other.dims2()?;
        if k != k2 {
            bail!("matmul_nt: inner dims {k} vs {k2}");
        }
        let mut out = vec![0f32; m * n];
        linalg::matmul_nt_into(&self.data, &other.data, m, k, n, &mut out);
        Tensor::from_vec(&[m, n], out)
    }

    /// `selfᵀ · other`: `(k,m) × (k,n) → (m,n)` — the Pᵀ·dO layout.
    pub fn matmul_tn(&self, other: &Tensor) -> Result<Tensor> {
        let (k, m) = self.dims2()?;
        let (k2, n) = other.dims2()?;
        if k != k2 {
            bail!("matmul_tn: inner dims {k} vs {k2}");
        }
        let mut out = vec![0f32; m * n];
        linalg::matmul_tn_into(&self.data, &other.data, m, k, n, &mut out);
        Tensor::from_vec(&[m, n], out)
    }

    /// Transpose a 2-D tensor.
    pub fn transpose(&self) -> Result<Tensor> {
        let (m, n) = self.dims2()?;
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::from_vec(&[n, m], out)
    }

    /// Row-wise numerically-stable softmax of a 2-D tensor.
    /// Returns `(P, lse)` with `lse[i] = log Σ_j exp(S[i,j])` — the
    /// FlashAttention "L" residual.  Rows of all `-inf` produce zeros.
    pub fn softmax_rows(&self) -> Result<(Tensor, Vec<f32>)> {
        let (m, n) = self.dims2()?;
        let mut p = vec![0f32; m * n];
        let mut lse = vec![0f32; m];
        for i in 0..m {
            let row = &self.data[i * n..(i + 1) * n];
            let max = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            if max == f32::NEG_INFINITY {
                lse[i] = f32::NEG_INFINITY;
                continue;
            }
            let out = &mut p[i * n..(i + 1) * n];
            let mut z = 0f32;
            for (o, &s) in out.iter_mut().zip(row) {
                let e = (s - max).exp();
                *o = e;
                z += e;
            }
            for o in out.iter_mut() {
                *o /= z;
            }
            lse[i] = max + z.ln();
        }
        Ok((Tensor::from_vec(&[m, n], p)?, lse))
    }

    /// Extract rows `[lo, hi)` of a 2-D tensor.
    pub fn rows(&self, lo: usize, hi: usize) -> Result<Tensor> {
        let (m, n) = self.dims2()?;
        if lo > hi || hi > m {
            bail!("rows {lo}..{hi} out of bounds for {m} rows");
        }
        Tensor::from_vec(&[hi - lo, n], self.data[lo * n..hi * n].to_vec())
    }

    /// Extract the `(rows, cols)` sub-matrix starting at `(row0, col0)` —
    /// the per-(batch, head) slicing the native model uses.
    pub fn block(&self, row0: usize, col0: usize, rows: usize, cols: usize) -> Result<Tensor> {
        let (m, n) = self.dims2()?;
        if row0 + rows > m || col0 + cols > n {
            bail!("block ({row0}+{rows}, {col0}+{cols}) out of bounds for ({m}, {n})");
        }
        let mut out = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            let base = (row0 + r) * n + col0;
            out.extend_from_slice(&self.data[base..base + cols]);
        }
        Tensor::from_vec(&[rows, cols], out)
    }

    /// Overwrite the sub-matrix at `(row0, col0)` with `b`.
    pub fn set_block(&mut self, row0: usize, col0: usize, b: &Tensor) -> Result<()> {
        let (m, n) = self.dims2()?;
        let (rows, cols) = b.dims2()?;
        if row0 + rows > m || col0 + cols > n {
            bail!("set_block ({row0}+{rows}, {col0}+{cols}) out of bounds for ({m}, {n})");
        }
        for r in 0..rows {
            let base = (row0 + r) * n + col0;
            self.data[base..base + cols].copy_from_slice(&b.data[r * cols..(r + 1) * cols]);
        }
        Ok(())
    }

    /// `self[row0.., col0..] += b` for a sub-matrix `b`.
    pub fn add_block(&mut self, row0: usize, col0: usize, b: &Tensor) -> Result<()> {
        let (m, n) = self.dims2()?;
        let (rows, cols) = b.dims2()?;
        if row0 + rows > m || col0 + cols > n {
            bail!("add_block ({row0}+{rows}, {col0}+{cols}) out of bounds for ({m}, {n})");
        }
        for r in 0..rows {
            let base = (row0 + r) * n + col0;
            for (dst, &x) in self.data[base..base + cols].iter_mut().zip(&b.data[r * cols..]) {
                *dst += x;
            }
        }
        Ok(())
    }
}

/// Dense i32 tensor (token ids).
#[derive(Debug, Clone, PartialEq)]
pub struct IntTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl IntTensor {
    pub fn zeros(shape: &[usize]) -> IntTensor {
        IntTensor {
            shape: shape.to_vec(),
            data: vec![0; shape.iter().product()],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> Result<IntTensor> {
        let want: usize = shape.iter().product();
        if data.len() != want {
            bail!("shape {shape:?} wants {want} elements, got {}", data.len());
        }
        Ok(IntTensor {
            shape: shape.to_vec(),
            data,
        })
    }

    pub fn scalar(x: i32) -> IntTensor {
        IntTensor {
            shape: vec![],
            data: vec![x],
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_shape_product() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.len(), 24);
        assert!(t.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn from_vec_validates() {
        assert!(Tensor::from_vec(&[2, 2], vec![0.0; 3]).is_err());
        assert!(Tensor::from_vec(&[2, 2], vec![0.0; 4]).is_ok());
    }

    #[test]
    fn add_assign_and_scale() {
        let mut a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::from_vec(&[3], vec![10.0, 20.0, 30.0]).unwrap();
        a.add_assign(&b);
        a.scale(0.5);
        assert_eq!(a.data, vec![5.5, 11.0, 16.5]);
    }

    #[test]
    fn randn_is_deterministic_per_stream() {
        let mut r1 = Pcg64::new(5, 0);
        let mut r2 = Pcg64::new(5, 0);
        let a = Tensor::randn(&[16], 1.0, &mut r1);
        let b = Tensor::randn(&[16], 1.0, &mut r2);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn metrics_delegate() {
        let a = Tensor::from_vec(&[2], vec![3.0, 4.0]).unwrap();
        assert!((a.rms() - (12.5f64).sqrt()).abs() < 1e-9);
        assert!((a.cossim(&a) - 1.0).abs() < 1e-12);
        assert_eq!(a.rel_l2(&a), 0.0);
        assert_eq!(a.max_abs(), 4.0);
    }

    #[test]
    fn max_abs_propagates_non_finite() {
        // Regression for the fig1 telemetry path (DESIGN.md §10): the
        // divergence ceiling compares against this statistic, and a
        // NaN-discarding fold would let a non-finite activation evade it.
        let mut a = Tensor::from_vec(&[3], vec![1.0, -2.0, 0.5]).unwrap();
        assert_eq!(a.max_abs(), 2.0);
        a.data[1] = f32::NAN;
        assert!(a.max_abs().is_nan());
        a.data[1] = f32::NEG_INFINITY;
        assert_eq!(a.max_abs(), f32::INFINITY);
    }

    #[test]
    fn finite_detection() {
        let mut a = Tensor::zeros(&[2]);
        assert!(a.is_finite());
        a.data[1] = f32::NAN;
        assert!(!a.is_finite());
    }

    #[test]
    fn scalar_item() {
        assert_eq!(Tensor::scalar(2.5).item(), 2.5);
        assert_eq!(IntTensor::scalar(7).data, vec![7]);
    }

    #[test]
    fn matmul_known_values() {
        let a = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let b = Tensor::from_vec(&[3, 2], vec![7., 8., 9., 10., 11., 12.]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.shape, vec![2, 2]);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_layout_variants_agree() {
        let mut rng = Pcg64::new(3, 0);
        let a = Tensor::randn(&[4, 6], 1.0, &mut rng.split(0));
        let b = Tensor::randn(&[5, 6], 1.0, &mut rng.split(1));
        // A·Bᵀ three ways.
        let nt = a.matmul_nt(&b).unwrap();
        let via_t = a.matmul(&b.transpose().unwrap()).unwrap();
        let tn = a
            .transpose()
            .unwrap()
            .matmul_tn(&b.transpose().unwrap())
            .unwrap();
        assert!(nt.rel_l2(&via_t) < 1e-6);
        assert!(nt.rel_l2(&tn) < 1e-6);
    }

    #[test]
    fn matmul_shape_errors() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4, 2]);
        assert!(a.matmul(&b).is_err());
        assert!(a.matmul_nt(&Tensor::zeros(&[4, 4])).is_err());
        assert!(Tensor::zeros(&[3]).matmul(&a).is_err());
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Pcg64::new(5, 0);
        let a = Tensor::randn(&[3, 7], 1.0, &mut rng);
        let back = a.transpose().unwrap().transpose().unwrap();
        assert_eq!(a, back);
    }

    #[test]
    fn softmax_rows_sum_to_one_with_lse() {
        let s = Tensor::from_vec(&[2, 3], vec![0., 1., 2., -5., 0., 5.]).unwrap();
        let (p, lse) = s.softmax_rows().unwrap();
        for row in p.data.chunks(3) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-6, "row sum {sum}");
        }
        // P[i,j] must equal exp(S[i,j] − lse[i]).
        for i in 0..2 {
            for j in 0..3 {
                let expect = (s.data[i * 3 + j] - lse[i]).exp();
                assert!((p.data[i * 3 + j] - expect).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn softmax_handles_masked_rows() {
        let s = Tensor::from_vec(&[1, 2], vec![f32::NEG_INFINITY, f32::NEG_INFINITY]).unwrap();
        let (p, lse) = s.softmax_rows().unwrap();
        assert_eq!(p.data, vec![0.0, 0.0]);
        assert_eq!(lse[0], f32::NEG_INFINITY);
    }

    #[test]
    fn block_roundtrip_and_accumulate() {
        let mut rng = Pcg64::new(21, 0);
        let a = Tensor::randn(&[4, 6], 1.0, &mut rng);
        let b = a.block(1, 2, 2, 3).unwrap();
        assert_eq!(b.shape, vec![2, 3]);
        assert_eq!(b.data[0], a.data[1 * 6 + 2]);
        assert_eq!(b.data[5], a.data[2 * 6 + 4]);
        // set_block writes back exactly; add_block doubles it.
        let mut c = Tensor::zeros(&[4, 6]);
        c.set_block(1, 2, &b).unwrap();
        assert_eq!(c.block(1, 2, 2, 3).unwrap(), b);
        c.add_block(1, 2, &b).unwrap();
        let doubled = c.block(1, 2, 2, 3).unwrap();
        for (x, y) in doubled.data.iter().zip(&b.data) {
            assert_eq!(*x, 2.0 * y);
        }
        // untouched region stays zero
        assert_eq!(c.data[0], 0.0);
        // out-of-bounds rejected
        assert!(a.block(3, 0, 2, 2).is_err());
        assert!(c.set_block(0, 5, &b).is_err());
        assert!(c.add_block(3, 0, &b).is_err());
    }

    #[test]
    fn rows_slice() {
        let a = Tensor::from_vec(&[3, 2], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let mid = a.rows(1, 3).unwrap();
        assert_eq!(mid.shape, vec![2, 2]);
        assert_eq!(mid.data, vec![3., 4., 5., 6.]);
        assert!(a.rows(2, 4).is_err());
    }
}
