//! Host-side tensor substrate: a flat `Vec<f32>`/`Vec<i32>` plus a shape.
//!
//! This is deliberately *not* a math library — the heavy math runs inside
//! the XLA executables.  The coordinator only needs: construction, random
//! init, elementwise accumulation (gradient accumulation across
//! microbatches, §4.3), scaling, and the error metrics.

use anyhow::{bail, Result};

use crate::util::rng::Pcg64;
use crate::util::stats;

/// Dense f32 tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let want: usize = shape.iter().product();
        if data.len() != want {
            bail!("shape {shape:?} wants {want} elements, got {}", data.len());
        }
        Ok(Tensor {
            shape: shape.to_vec(),
            data,
        })
    }

    pub fn scalar(x: f32) -> Tensor {
        Tensor {
            shape: vec![],
            data: vec![x],
        }
    }

    /// N(0, sigma²) random tensor from a seeded stream.
    pub fn randn(shape: &[usize], sigma: f32, rng: &mut Pcg64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_gaussian(&mut t.data, sigma);
        t
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn item(&self) -> f32 {
        assert_eq!(self.len(), 1, "item() on non-scalar {:?}", self.shape);
        self.data[0]
    }

    /// self += other (gradient accumulation hot path).
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// self *= c (microbatch averaging).
    pub fn scale(&mut self, c: f32) {
        for a in self.data.iter_mut() {
            *a *= c;
        }
    }

    pub fn fill(&mut self, c: f32) {
        self.data.fill(c);
    }

    pub fn rms(&self) -> f64 {
        stats::rms(&self.data)
    }

    pub fn cossim(&self, other: &Tensor) -> f64 {
        stats::cossim(&self.data, &other.data)
    }

    pub fn rel_l2(&self, other: &Tensor) -> f64 {
        stats::rel_l2(&self.data, &other.data)
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0f32, |m, &x| m.max(x.abs()))
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }
}

/// Dense i32 tensor (token ids).
#[derive(Debug, Clone, PartialEq)]
pub struct IntTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl IntTensor {
    pub fn zeros(shape: &[usize]) -> IntTensor {
        IntTensor {
            shape: shape.to_vec(),
            data: vec![0; shape.iter().product()],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> Result<IntTensor> {
        let want: usize = shape.iter().product();
        if data.len() != want {
            bail!("shape {shape:?} wants {want} elements, got {}", data.len());
        }
        Ok(IntTensor {
            shape: shape.to_vec(),
            data,
        })
    }

    pub fn scalar(x: i32) -> IntTensor {
        IntTensor {
            shape: vec![],
            data: vec![x],
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_shape_product() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.len(), 24);
        assert!(t.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn from_vec_validates() {
        assert!(Tensor::from_vec(&[2, 2], vec![0.0; 3]).is_err());
        assert!(Tensor::from_vec(&[2, 2], vec![0.0; 4]).is_ok());
    }

    #[test]
    fn add_assign_and_scale() {
        let mut a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::from_vec(&[3], vec![10.0, 20.0, 30.0]).unwrap();
        a.add_assign(&b);
        a.scale(0.5);
        assert_eq!(a.data, vec![5.5, 11.0, 16.5]);
    }

    #[test]
    fn randn_is_deterministic_per_stream() {
        let mut r1 = Pcg64::new(5, 0);
        let mut r2 = Pcg64::new(5, 0);
        let a = Tensor::randn(&[16], 1.0, &mut r1);
        let b = Tensor::randn(&[16], 1.0, &mut r2);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn metrics_delegate() {
        let a = Tensor::from_vec(&[2], vec![3.0, 4.0]).unwrap();
        assert!((a.rms() - (12.5f64).sqrt()).abs() < 1e-9);
        assert!((a.cossim(&a) - 1.0).abs() < 1e-12);
        assert_eq!(a.rel_l2(&a), 0.0);
        assert_eq!(a.max_abs(), 4.0);
    }

    #[test]
    fn finite_detection() {
        let mut a = Tensor::zeros(&[2]);
        assert!(a.is_finite());
        a.data[1] = f32::NAN;
        assert!(!a.is_finite());
    }

    #[test]
    fn scalar_item() {
        assert_eq!(Tensor::scalar(2.5).item(), 2.5);
        assert_eq!(IntTensor::scalar(7).data, vec![7]);
    }
}
