//! ISA-tier dispatch + explicit AVX2/FMA micro-kernels (DESIGN.md §15).
//!
//! The blocked engine in [`super::linalg`] autovectorizes its scalar
//! slice loops; this module adds *explicit* `std::arch` x86_64 paths so
//! the INT8 attention kernels can demonstrate their headline speedup
//! over f32 (ROADMAP item 1).  Everything funnels through two row-range
//! dispatchers — [`gemm_f32_rows`] and [`gemm_i8_rows`] — selected by an
//! [`IsaTier`] resolved *once* per public GEMM call, on the calling
//! thread, before any workers spawn (thread-locals do not propagate into
//! `std::thread::scope` workers, so the tier is passed down by value).
//!
//! ## Tiers and how one is chosen
//!
//! * [`IsaTier::Scalar`] — the verbatim blocked kernels from `linalg`
//!   (the only tier on non-x86_64 targets, via `cfg`).
//! * [`IsaTier::Avx2`] — 8-lane `__m256` f32 kernel (separate mul+add,
//!   same per-lane rounding as scalar) and a widening i8×i8→i32 kernel.
//! * [`IsaTier::Fma`] — the f32 kernel with `_mm256_fmadd_ps`
//!   (single-rounding fused multiply-add); integers gain nothing from
//!   FMA, so the i8 kernel is shared with the Avx2 tier.
//!
//! [`active_tier`] resolves, in order: the thread-local [`with_isa`] pin
//! (how tests force a tier), the `SAGEBWD_ISA=scalar|avx2|fma` env knob
//! (re-read per call, like `SAGEBWD_THREADS`), then the default.  Both
//! overrides clamp to [`hw_tier`] — executing undetected intrinsics
//! would be UB, so a too-high request degrades instead.  The **default
//! is `min(hw, Avx2)`, not FMA**: the Avx2 f32 kernel rounds each
//! multiply and add separately, exactly like the scalar tier, so the
//! engine's `blocked == naive == parallel, bitwise` contract and the
//! numpy golden vectors stay intact out of the box.  FMA is strictly
//! opt-in because fusing changes rounding (see DESIGN.md §15).
//!
//! ## Per-tier determinism contract
//!
//! Within any tier, every output element is accumulated in ascending
//! reduction index from its zero-filled start, by exactly one op kind
//! (mul+add for Scalar/Avx2, fused mul-add for Fma) regardless of which
//! code path — vector body, 8-lane block, or scalar tail — touches it.
//! Blocking and row-parallelism therefore never change the bytes:
//! blocked == parallel bitwise at any `SAGEBWD_THREADS`, per tier.
//! Across tiers: Scalar and Avx2 are bitwise identical for f32; Fma may
//! differ (one rounding instead of two per multiply-add); the INT8
//! kernels are exact i32 arithmetic, hence bitwise identical across
//! *all* tiers.  `rust/tests/linalg_properties.rs` pins each clause.
//!
//! ## Observability
//!
//! Each public GEMM call records its resolved tier on the
//! `simd_calls_{scalar,avx2,fma}` trace counters (self-gated, one
//! thread-local branch when tracing is off), so a `--trace` run shows
//! which tier actually executed; benches stamp rows with an `isa`
//! column from [`active_tier`].

use crate::telemetry::trace;

/// Instruction-set tier, ordered `Scalar < Avx2 < Fma` so overrides can
/// be clamped with `min` against the detected hardware tier.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum IsaTier {
    /// Portable blocked kernels (`linalg`), the only tier off x86_64.
    Scalar,
    /// AVX2 `__m256` kernels; f32 stays bitwise equal to Scalar.
    Avx2,
    /// AVX2 + fused multiply-add for f32 accumulation (opt-in only).
    Fma,
}

impl IsaTier {
    /// Stable lowercase name — the `isa` bench column and knob values.
    pub fn as_str(self) -> &'static str {
        match self {
            IsaTier::Scalar => "scalar",
            IsaTier::Avx2 => "avx2",
            IsaTier::Fma => "fma",
        }
    }

    /// Parse a `SAGEBWD_ISA` value (case/whitespace-insensitive).
    /// Unknown strings are `None` — callers fall back to the default
    /// rather than guessing.
    pub fn parse(s: &str) -> Option<IsaTier> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(IsaTier::Scalar),
            "avx2" => Some(IsaTier::Avx2),
            "fma" => Some(IsaTier::Fma),
            _ => None,
        }
    }
}

/// Highest tier the running CPU supports, detected once per process.
#[cfg(target_arch = "x86_64")]
pub fn hw_tier() -> IsaTier {
    static CACHE: std::sync::OnceLock<IsaTier> = std::sync::OnceLock::new();
    *CACHE.get_or_init(|| {
        if std::arch::is_x86_feature_detected!("avx2") {
            if std::arch::is_x86_feature_detected!("fma") {
                IsaTier::Fma
            } else {
                IsaTier::Avx2
            }
        } else {
            IsaTier::Scalar
        }
    })
}

/// Highest tier the running CPU supports: always Scalar off x86_64.
#[cfg(not(target_arch = "x86_64"))]
pub fn hw_tier() -> IsaTier {
    IsaTier::Scalar
}

thread_local! {
    /// Per-thread tier pin (see [`with_isa`]) — modeled on
    /// `linalg::with_thread_cap`: thread-local so concurrent tests can
    /// pin different tiers without racing on the process env.
    static ISA_PIN: std::cell::Cell<Option<IsaTier>> =
        const { std::cell::Cell::new(None) };
}

/// Run `f` with the ISA tier pinned to `tier` on this thread (clamped to
/// [`hw_tier`] at resolution time).  The previous pin is restored on
/// exit.  Note the pin does **not** propagate into spawned workers —
/// dispatch entry points resolve the tier before fanning out and pass it
/// down by value, so a pinned caller still controls the whole call.
pub fn with_isa<R>(tier: IsaTier, f: impl FnOnce() -> R) -> R {
    ISA_PIN.with(|c| {
        let prev = c.replace(Some(tier));
        let r = f();
        c.set(prev);
        r
    })
}

/// The tier GEMM dispatch will use for a call issued on this thread:
/// [`with_isa`] pin, else `SAGEBWD_ISA` env (re-read per call), else
/// `min(hw, Avx2)` — requests above [`hw_tier`] clamp down, unknown env
/// values fall back to the default.
pub fn active_tier() -> IsaTier {
    let pinned = ISA_PIN.with(|c| c.get());
    let requested = pinned.or_else(|| {
        std::env::var("SAGEBWD_ISA")
            .ok()
            .and_then(|s| IsaTier::parse(&s))
    });
    match requested {
        Some(t) => t.min(hw_tier()),
        // Numerics-preserving default: Avx2 matches Scalar bitwise for
        // f32, so nothing changes out of the box; Fma is opt-in.
        None => hw_tier().min(IsaTier::Avx2),
    }
}

/// Record one GEMM dispatch at `tier` on the per-tier trace counters
/// (`simd_calls_*`).  `counter_add` self-gates on `trace::enabled()`.
pub fn record_dispatch(tier: IsaTier) {
    trace::counter_add(
        match tier {
            IsaTier::Scalar => "simd_calls_scalar",
            IsaTier::Avx2 => "simd_calls_avx2",
            IsaTier::Fma => "simd_calls_fma",
        },
        1,
    );
}

// ---------------------------------------------------------------------------
// Row-range dispatchers (the only entry points linalg calls)
// ---------------------------------------------------------------------------

/// f32 `A·B` over output rows `[i0, i1)` at `tier`: `out` covers exactly
/// those rows and must be zero-filled (same contract as the scalar
/// kernel).  `tier` is re-clamped to [`hw_tier`] here so the `unsafe`
/// kernel calls below are sound even for a hand-constructed tier.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_f32_rows(
    a: &[f32],
    b: &[f32],
    k: usize,
    n: usize,
    i0: usize,
    i1: usize,
    out: &mut [f32],
    tier: IsaTier,
) {
    #[cfg(target_arch = "x86_64")]
    {
        match tier.min(hw_tier()) {
            IsaTier::Scalar => super::linalg::gemm_nn_rows_scalar(a, b, k, n, i0, i1, out),
            // SAFETY: the tier was clamped to hw_tier() on the line
            // above, so reaching this arm proves avx2 was detected on
            // this CPU; the kernel has no alignment requirements.
            IsaTier::Avx2 => unsafe { x86::gemm_f32_rows_avx2(a, b, k, n, i0, i1, out) },
            // SAFETY: clamped tier == Fma proves avx2+fma were detected
            // on this CPU; the kernel has no alignment requirements.
            IsaTier::Fma => unsafe { x86::gemm_f32_rows_fma(a, b, k, n, i0, i1, out) },
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = tier;
        super::linalg::gemm_nn_rows_scalar(a, b, k, n, i0, i1, out);
    }
}

/// i8×i8→i32 `A·B` over output rows `[i0, i1)` at `tier`; `out` covers
/// exactly those rows and must be zero-filled.  Exact i32 accumulation
/// in every tier, so the result is bitwise tier-independent; Fma shares
/// the Avx2 kernel (fused float ops are irrelevant to integers).
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_i8_rows(
    a: &[i8],
    b: &[i8],
    k: usize,
    n: usize,
    i0: usize,
    i1: usize,
    out: &mut [i32],
    tier: IsaTier,
) {
    #[cfg(target_arch = "x86_64")]
    {
        match tier.min(hw_tier()) {
            IsaTier::Scalar => super::linalg::i8_gemm_nn_rows_scalar(a, b, k, n, i0, i1, out),
            // SAFETY: the tier was clamped to hw_tier() on the line
            // above, so avx2 is detected; no alignment requirements.
            IsaTier::Avx2 | IsaTier::Fma => unsafe {
                x86::gemm_i8_rows_avx2(a, b, k, n, i0, i1, out)
            },
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = tier;
        super::linalg::i8_gemm_nn_rows_scalar(a, b, k, n, i0, i1, out);
    }
}

// ---------------------------------------------------------------------------
// x86_64 micro-kernels
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::{
        __m128i, _mm256_add_epi32, _mm256_add_ps, _mm256_cvtepi8_epi32, _mm256_fmadd_ps,
        _mm256_loadu_ps, _mm256_loadu_si256, _mm256_mul_ps, _mm256_mullo_epi32, _mm256_set1_epi32,
        _mm256_set1_ps, _mm256_storeu_ps, _mm256_storeu_si256, _mm_loadl_epi64,
    };

    use crate::tensor::linalg;

    /// Rows per register block — matches the scalar kernels' `MR` so the
    /// same row-range partition feeds every tier.
    const MR: usize = 4;

    /// f32 AVX2 kernel: MR=4 rows × 16 columns (two `__m256` lanes per
    /// row) register tile, `i-block → j-block → t` loop order.  Each
    /// accumulator lane starts from the zero-filled `out` value and adds
    /// `round(a·b)` per step — the *same two roundings in the same
    /// ascending-`t` order* as the scalar kernel, so this tier is
    /// bitwise identical to Scalar element by element.  Column tails
    /// (<8) and row tails (<MR) run the equivalent scalar ops.
    #[target_feature(enable = "avx2")]
    // SAFETY: caller must have verified `avx2` via
    // `is_x86_feature_detected!` (the dispatcher clamps to hw_tier()).
    // All loads/stores are `loadu`/`storeu` — no alignment requirement —
    // and every pointer stays inside the slice bounds proven by the
    // block guards (`i + MR <= i1`, `j + lanes <= n`).
    pub(super) unsafe fn gemm_f32_rows_avx2(
        a: &[f32],
        b: &[f32],
        k: usize,
        n: usize,
        i0: usize,
        i1: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), (i1 - i0) * n);
        debug_assert!(b.len() >= k * n);
        let bp = b.as_ptr();
        let mut i = i0;
        while i + MR <= i1 {
            let obase = (i - i0) * n;
            let mut j = 0usize;
            while j + 16 <= n {
                let op = out.as_mut_ptr().add(obase + j);
                let mut acc = [
                    (_mm256_loadu_ps(op), _mm256_loadu_ps(op.add(8))),
                    (_mm256_loadu_ps(op.add(n)), _mm256_loadu_ps(op.add(n + 8))),
                    (
                        _mm256_loadu_ps(op.add(2 * n)),
                        _mm256_loadu_ps(op.add(2 * n + 8)),
                    ),
                    (
                        _mm256_loadu_ps(op.add(3 * n)),
                        _mm256_loadu_ps(op.add(3 * n + 8)),
                    ),
                ];
                for t in 0..k {
                    let bt = bp.add(t * n + j);
                    let b0 = _mm256_loadu_ps(bt);
                    let b1 = _mm256_loadu_ps(bt.add(8));
                    for (r, lanes) in acc.iter_mut().enumerate() {
                        let av = _mm256_set1_ps(a[(i + r) * k + t]);
                        // mul then add: two roundings, like the scalar
                        // `*o += av * bv` — never fmadd in this tier.
                        lanes.0 = _mm256_add_ps(lanes.0, _mm256_mul_ps(av, b0));
                        lanes.1 = _mm256_add_ps(lanes.1, _mm256_mul_ps(av, b1));
                    }
                }
                for (r, lanes) in acc.iter().enumerate() {
                    _mm256_storeu_ps(op.add(r * n), lanes.0);
                    _mm256_storeu_ps(op.add(r * n + 8), lanes.1);
                }
                j += 16;
            }
            if j + 8 <= n {
                let op = out.as_mut_ptr().add(obase + j);
                let mut acc = [
                    _mm256_loadu_ps(op),
                    _mm256_loadu_ps(op.add(n)),
                    _mm256_loadu_ps(op.add(2 * n)),
                    _mm256_loadu_ps(op.add(3 * n)),
                ];
                for t in 0..k {
                    let b0 = _mm256_loadu_ps(bp.add(t * n + j));
                    for (r, lane) in acc.iter_mut().enumerate() {
                        let av = _mm256_set1_ps(a[(i + r) * k + t]);
                        *lane = _mm256_add_ps(*lane, _mm256_mul_ps(av, b0));
                    }
                }
                for (r, lane) in acc.iter().enumerate() {
                    _mm256_storeu_ps(op.add(r * n), *lane);
                }
                j += 8;
            }
            // Scalar column tail (n % 8 rightmost columns): identical
            // per-element op and order, so still bitwise == Scalar.
            for t in 0..k {
                for r in 0..MR {
                    let av = a[(i + r) * k + t];
                    for jj in j..n {
                        out[obase + r * n + jj] += av * b[t * n + jj];
                    }
                }
            }
            i += MR;
        }
        if i < i1 {
            // Row tail (< MR rows): the scalar kernel computes each
            // element with the same ops in the same order.
            linalg::gemm_nn_rows_scalar(a, b, k, n, i, i1, &mut out[(i - i0) * n..]);
        }
    }

    /// f32 FMA kernel: the AVX2 tile with `_mm256_fmadd_ps` accumulation
    /// (one rounding per multiply-add).  Scalar tails use `f32::mul_add`
    /// — also a single correctly-rounded fused op — so every element is
    /// fma-accumulated in ascending `t` no matter which path touches it:
    /// the tier is deterministic and thread-invariant, but its f32 bytes
    /// legitimately differ from Scalar/Avx2 (hence opt-in only).
    #[target_feature(enable = "avx2,fma")]
    // SAFETY: caller must have verified `avx2` and `fma` via
    // `is_x86_feature_detected!` (the dispatcher clamps to hw_tier());
    // bounds/alignment arguments are identical to gemm_f32_rows_avx2.
    pub(super) unsafe fn gemm_f32_rows_fma(
        a: &[f32],
        b: &[f32],
        k: usize,
        n: usize,
        i0: usize,
        i1: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), (i1 - i0) * n);
        debug_assert!(b.len() >= k * n);
        let bp = b.as_ptr();
        let mut i = i0;
        while i + MR <= i1 {
            let obase = (i - i0) * n;
            let mut j = 0usize;
            while j + 16 <= n {
                let op = out.as_mut_ptr().add(obase + j);
                let mut acc = [
                    (_mm256_loadu_ps(op), _mm256_loadu_ps(op.add(8))),
                    (_mm256_loadu_ps(op.add(n)), _mm256_loadu_ps(op.add(n + 8))),
                    (
                        _mm256_loadu_ps(op.add(2 * n)),
                        _mm256_loadu_ps(op.add(2 * n + 8)),
                    ),
                    (
                        _mm256_loadu_ps(op.add(3 * n)),
                        _mm256_loadu_ps(op.add(3 * n + 8)),
                    ),
                ];
                for t in 0..k {
                    let bt = bp.add(t * n + j);
                    let b0 = _mm256_loadu_ps(bt);
                    let b1 = _mm256_loadu_ps(bt.add(8));
                    for (r, lanes) in acc.iter_mut().enumerate() {
                        let av = _mm256_set1_ps(a[(i + r) * k + t]);
                        lanes.0 = _mm256_fmadd_ps(av, b0, lanes.0);
                        lanes.1 = _mm256_fmadd_ps(av, b1, lanes.1);
                    }
                }
                for (r, lanes) in acc.iter().enumerate() {
                    _mm256_storeu_ps(op.add(r * n), lanes.0);
                    _mm256_storeu_ps(op.add(r * n + 8), lanes.1);
                }
                j += 16;
            }
            if j + 8 <= n {
                let op = out.as_mut_ptr().add(obase + j);
                let mut acc = [
                    _mm256_loadu_ps(op),
                    _mm256_loadu_ps(op.add(n)),
                    _mm256_loadu_ps(op.add(2 * n)),
                    _mm256_loadu_ps(op.add(3 * n)),
                ];
                for t in 0..k {
                    let b0 = _mm256_loadu_ps(bp.add(t * n + j));
                    for (r, lane) in acc.iter_mut().enumerate() {
                        let av = _mm256_set1_ps(a[(i + r) * k + t]);
                        *lane = _mm256_fmadd_ps(av, b0, *lane);
                    }
                }
                for (r, lane) in acc.iter().enumerate() {
                    _mm256_storeu_ps(op.add(r * n), *lane);
                }
                j += 8;
            }
            // Scalar column tail: mul_add keeps the single-rounding op,
            // so tail elements match what a vector lane would compute.
            for t in 0..k {
                for r in 0..MR {
                    let av = a[(i + r) * k + t];
                    for jj in j..n {
                        let o = obase + r * n + jj;
                        out[o] = av.mul_add(b[t * n + jj], out[o]);
                    }
                }
            }
            i += MR;
        }
        // Row tail: fused ops here too — the whole tier must use one op
        // kind per element or thread partitions would change the bytes.
        while i < i1 {
            let obase = (i - i0) * n;
            for t in 0..k {
                let av = a[i * k + t];
                let brow = &b[t * n..(t + 1) * n];
                for (jj, &bv) in brow.iter().enumerate() {
                    out[obase + jj] = av.mul_add(bv, out[obase + jj]);
                }
            }
            i += 1;
        }
    }

    /// i8×i8→i32 AVX2 kernel: MR=4 rows × 16 columns.  Per step, 8
    /// bytes of the B row are sign-extended to i32 lanes
    /// (`_mm256_cvtepi8_epi32`) and multiplied by the broadcast A value
    /// with `_mm256_mullo_epi32` — exact, since |a·b| ≤ 128·127 fits
    /// far inside i32 — then added into i32 accumulators.  No i16
    /// `maddubs` pairing is involved, so there is no saturation edge
    /// case and the result equals the scalar kernel bit for bit at any
    /// blocking or thread count (integer addition commutes).
    #[target_feature(enable = "avx2")]
    // SAFETY: caller must have verified `avx2` via
    // `is_x86_feature_detected!` (the dispatcher clamps to hw_tier()).
    // `_mm_loadl_epi64` reads exactly 8 bytes at `b[t*n + j..]`, in
    // bounds by the `j + lanes <= n` guards; i32 loads/stores are
    // unaligned-tolerant (`loadu`/`storeu`).
    pub(super) unsafe fn gemm_i8_rows_avx2(
        a: &[i8],
        b: &[i8],
        k: usize,
        n: usize,
        i0: usize,
        i1: usize,
        out: &mut [i32],
    ) {
        debug_assert_eq!(out.len(), (i1 - i0) * n);
        debug_assert!(b.len() >= k * n);
        let bp = b.as_ptr();
        let mut i = i0;
        while i + MR <= i1 {
            let obase = (i - i0) * n;
            let mut j = 0usize;
            while j + 16 <= n {
                let op = out.as_mut_ptr().add(obase + j);
                let mut acc = [
                    (
                        _mm256_loadu_si256(op as *const _),
                        _mm256_loadu_si256(op.add(8) as *const _),
                    ),
                    (
                        _mm256_loadu_si256(op.add(n) as *const _),
                        _mm256_loadu_si256(op.add(n + 8) as *const _),
                    ),
                    (
                        _mm256_loadu_si256(op.add(2 * n) as *const _),
                        _mm256_loadu_si256(op.add(2 * n + 8) as *const _),
                    ),
                    (
                        _mm256_loadu_si256(op.add(3 * n) as *const _),
                        _mm256_loadu_si256(op.add(3 * n + 8) as *const _),
                    ),
                ];
                for t in 0..k {
                    let bt = bp.add(t * n + j);
                    let b0 = _mm256_cvtepi8_epi32(_mm_loadl_epi64(bt as *const __m128i));
                    let b1 = _mm256_cvtepi8_epi32(_mm_loadl_epi64(bt.add(8) as *const __m128i));
                    for (r, lanes) in acc.iter_mut().enumerate() {
                        let av = _mm256_set1_epi32(a[(i + r) * k + t] as i32);
                        lanes.0 = _mm256_add_epi32(lanes.0, _mm256_mullo_epi32(av, b0));
                        lanes.1 = _mm256_add_epi32(lanes.1, _mm256_mullo_epi32(av, b1));
                    }
                }
                for (r, lanes) in acc.iter().enumerate() {
                    _mm256_storeu_si256(op.add(r * n) as *mut _, lanes.0);
                    _mm256_storeu_si256(op.add(r * n + 8) as *mut _, lanes.1);
                }
                j += 16;
            }
            if j + 8 <= n {
                let op = out.as_mut_ptr().add(obase + j);
                let mut acc = [
                    _mm256_loadu_si256(op as *const _),
                    _mm256_loadu_si256(op.add(n) as *const _),
                    _mm256_loadu_si256(op.add(2 * n) as *const _),
                    _mm256_loadu_si256(op.add(3 * n) as *const _),
                ];
                for t in 0..k {
                    let b0 = _mm256_cvtepi8_epi32(_mm_loadl_epi64(bp.add(t * n + j) as *const __m128i));
                    for (r, lane) in acc.iter_mut().enumerate() {
                        let av = _mm256_set1_epi32(a[(i + r) * k + t] as i32);
                        *lane = _mm256_add_epi32(*lane, _mm256_mullo_epi32(av, b0));
                    }
                }
                for (r, lane) in acc.iter().enumerate() {
                    _mm256_storeu_si256(op.add(r * n) as *mut _, *lane);
                }
                j += 8;
            }
            for t in 0..k {
                for r in 0..MR {
                    let av = a[(i + r) * k + t] as i32;
                    for jj in j..n {
                        out[obase + r * n + jj] += av * b[t * n + jj] as i32;
                    }
                }
            }
            i += MR;
        }
        if i < i1 {
            linalg::i8_gemm_nn_rows_scalar(a, b, k, n, i, i1, &mut out[(i - i0) * n..]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_names_roundtrip_and_order() {
        for t in [IsaTier::Scalar, IsaTier::Avx2, IsaTier::Fma] {
            assert_eq!(IsaTier::parse(t.as_str()), Some(t));
        }
        assert_eq!(IsaTier::parse(" AVX2\n"), Some(IsaTier::Avx2));
        assert_eq!(IsaTier::parse("avx512"), None);
        assert_eq!(IsaTier::parse(""), None);
        assert!(IsaTier::Scalar < IsaTier::Avx2 && IsaTier::Avx2 < IsaTier::Fma);
        assert_eq!(IsaTier::Fma.min(hw_tier()), hw_tier());
    }

    #[test]
    fn with_isa_pins_clamps_and_restores() {
        let ambient = active_tier();
        assert!(ambient <= hw_tier());
        assert!(ambient <= IsaTier::Avx2 || std::env::var("SAGEBWD_ISA").is_ok());
        with_isa(IsaTier::Scalar, || {
            assert_eq!(active_tier(), IsaTier::Scalar);
            // Nested pins win, outer pin is restored afterwards.
            with_isa(IsaTier::Fma, || {
                assert_eq!(active_tier(), IsaTier::Fma.min(hw_tier()));
            });
            assert_eq!(active_tier(), IsaTier::Scalar);
        });
        assert_eq!(active_tier(), ambient);
    }

    #[test]
    fn dispatchers_match_scalar_on_every_tier() {
        // The dispatcher-level identity: for any tier ≤ hw the f32 Avx2
        // path and the i8 path must be bitwise equal to Scalar (the Fma
        // f32 path is allowed to differ; covered by linalg_properties).
        let (k, n, rows) = (13, 37, 5); // deliberately no multiple of 8/16/MR
        let a: Vec<f32> = (0..rows * k).map(|i| (i as f32 * 0.7).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.3).cos()).collect();
        let ai: Vec<i8> = (0..rows * k).map(|i| (i as i32 * 37 % 255 - 127) as i8).collect();
        let bi: Vec<i8> = (0..k * n).map(|i| (i as i32 * 91 % 255 - 127) as i8).collect();
        let mut want = vec![0f32; rows * n];
        gemm_f32_rows(&a, &b, k, n, 0, rows, &mut want, IsaTier::Scalar);
        let mut wanti = vec![0i32; rows * n];
        gemm_i8_rows(&ai, &bi, k, n, 0, rows, &mut wanti, IsaTier::Scalar);
        if hw_tier() >= IsaTier::Avx2 {
            let mut got = vec![0f32; rows * n];
            gemm_f32_rows(&a, &b, k, n, 0, rows, &mut got, IsaTier::Avx2);
            assert_eq!(
                want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "f32 avx2 != scalar"
            );
        }
        for tier in [IsaTier::Avx2, IsaTier::Fma] {
            let mut goti = vec![0i32; rows * n];
            // Above-hw tiers clamp down inside the dispatcher, so this
            // is exercised (as the best available tier) on any CPU.
            gemm_i8_rows(&ai, &bi, k, n, 0, rows, &mut goti, tier);
            assert_eq!(wanti, goti, "i8 {tier:?} != scalar");
        }
    }

    #[test]
    fn record_dispatch_is_safe_when_tracing_disabled() {
        record_dispatch(active_tier());
    }
}
