//! Reusable scratch arena for the native compute paths (DESIGN.md §11).
//!
//! The tiled attention kernels and the training engine used to allocate a
//! fresh `Vec` for every tile / layer / step (`s_ij`, `p_ij`, quantized
//! tiles, `dP`, MLP scratch, …).  A [`Workspace`] turns those into
//! take/give pairs against per-type buffer pools, so after the first
//! iteration the hot loops run allocation-free.
//!
//! Contract:
//!
//! * [`Workspace::take_f32`] (and the `i8`/`i32` twins) return a buffer of
//!   *exactly* the requested length, zero-filled — callers can treat it
//!   like a fresh `vec![0; len]`.
//! * [`Workspace::give_f32`] returns a buffer to the pool.  Forgetting to
//!   give a buffer back is not a leak (it just drops); giving back is what
//!   enables reuse.
//! * Pools are LIFO, so tight loops that take/give the same sizes settle
//!   into steady-state reuse after one iteration.
//! * A `Workspace` is deliberately `!Sync`-by-use: parallel regions give
//!   each worker thread its own `Workspace` (they are cheap to create —
//!   empty pools), which keeps the threading determinism contract trivial.
//! * **SIMD alignment contract**: the ISA-tier micro-kernels
//!   (`tensor::simd`, DESIGN.md §15) use exclusively unaligned
//!   loads/stores (`loadu`/`storeu`, and `_mm_loadl_epi64` for i8
//!   panels), so pooled buffers need only their natural element
//!   alignment — plain `Vec<T>` storage is sufficient and the pools
//!   never over-align or pad.  Any future kernel wanting aligned moves
//!   must bring its own aligned arena rather than assuming pool layout.

use crate::telemetry::trace;
use crate::tensor::Tensor;

/// Arena telemetry: one pool hit/miss tally plus a high-water mark of
/// the largest single request, all behind the trace enable gate (a
/// thread-local branch when tracing is off).
fn count_take(hit: bool, bytes: usize) {
    if !trace::enabled() {
        return;
    }
    trace::counter_add(if hit { "ws_hit" } else { "ws_miss" }, 1);
    trace::counter_max("ws_high_water_bytes", bytes as u64);
}

/// Pooled scratch buffers for f32 / i8 / i32 intermediates.
#[derive(Debug, Default)]
pub struct Workspace {
    f32s: Vec<Vec<f32>>,
    i8s: Vec<Vec<i8>>,
    i32s: Vec<Vec<i32>>,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Take a zero-filled f32 buffer of exactly `len`.
    pub fn take_f32(&mut self, len: usize) -> Vec<f32> {
        count_take(!self.f32s.is_empty(), len * 4);
        let mut b = self.f32s.pop().unwrap_or_default();
        b.clear();
        b.resize(len, 0.0);
        b
    }

    pub fn give_f32(&mut self, b: Vec<f32>) {
        self.f32s.push(b);
    }

    /// Take a zero-filled i8 buffer of exactly `len`.
    pub fn take_i8(&mut self, len: usize) -> Vec<i8> {
        count_take(!self.i8s.is_empty(), len);
        let mut b = self.i8s.pop().unwrap_or_default();
        b.clear();
        b.resize(len, 0);
        b
    }

    pub fn give_i8(&mut self, b: Vec<i8>) {
        self.i8s.push(b);
    }

    /// Take a zero-filled i32 buffer of exactly `len`.
    pub fn take_i32(&mut self, len: usize) -> Vec<i32> {
        count_take(!self.i32s.is_empty(), len * 4);
        let mut b = self.i32s.pop().unwrap_or_default();
        b.clear();
        b.resize(len, 0);
        b
    }

    pub fn give_i32(&mut self, b: Vec<i32>) {
        self.i32s.push(b);
    }

    /// Take a zero-filled scratch [`Tensor`] (its `data` comes from the
    /// f32 pool; return it with [`Self::give_tensor`]).
    pub fn take_tensor(&mut self, shape: &[usize]) -> Tensor {
        let len = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: self.take_f32(len),
        }
    }

    pub fn give_tensor(&mut self, t: Tensor) {
        self.give_f32(t.data);
    }

    /// Buffers currently pooled (diagnostics only).
    pub fn pooled(&self) -> usize {
        self.f32s.len() + self.i8s.len() + self.i32s.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zeroed_and_reuses_capacity() {
        let mut ws = Workspace::new();
        let mut a = ws.take_f32(8);
        a[3] = 5.0;
        let cap = a.capacity();
        let ptr = a.as_ptr();
        ws.give_f32(a);
        assert_eq!(ws.pooled(), 1);
        let b = ws.take_f32(4);
        // Same allocation, shrunk view, zeroed contents.
        assert_eq!(b.as_ptr(), ptr);
        assert!(b.capacity() >= cap.min(4));
        assert!(b.iter().all(|&x| x == 0.0));
        assert_eq!(b.len(), 4);
        assert_eq!(ws.pooled(), 0);
    }

    #[test]
    fn grow_after_reuse_is_zeroed() {
        let mut ws = Workspace::new();
        let mut a = ws.take_i32(2);
        a[0] = 7;
        a[1] = 9;
        ws.give_i32(a);
        let b = ws.take_i32(6);
        assert_eq!(b, vec![0; 6]);
    }

    #[test]
    fn tensor_roundtrip() {
        let mut ws = Workspace::new();
        let t = ws.take_tensor(&[2, 3]);
        assert_eq!(t.shape, vec![2, 3]);
        assert_eq!(t.data, vec![0.0; 6]);
        ws.give_tensor(t);
        assert_eq!(ws.pooled(), 1);
    }

    #[test]
    fn pools_are_per_type() {
        let mut ws = Workspace::new();
        ws.give_f32(vec![1.0]);
        ws.give_i8(vec![1]);
        ws.give_i32(vec![1]);
        assert_eq!(ws.pooled(), 3);
        let _ = ws.take_i8(1);
        assert_eq!(ws.pooled(), 2);
    }
}
