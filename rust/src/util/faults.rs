//! Seeded fault injection for the training supervisor (DESIGN.md §16).
//!
//! A fault *plan* is a deterministic schedule of failures — worker-thread
//! panics in the attention fan-out, torn artifact writes in the registry,
//! NaN poisoning of a gradient slab — parsed from `SAGEBWD_FAULTS` so the
//! supervisor's recovery paths are exercised by tier-1 tests and the CI
//! smoke job instead of waiting for real hardware faults.  The plan is
//! keyed entirely on logical progress (trainer step number, artifact write
//! ordinal) plus an explicit seed: no wall clock, no OS randomness, so a
//! faulted run is exactly reproducible.
//!
//! Plan grammar (clauses joined by `;` or `,`):
//! ```text
//! seed=N          PRNG seed for slab choice (default 0)
//! panic@S         panic one fan-out worker on the first batch of step S
//! torn@N          truncate the N-th registry artifact write (1-based)
//! nan@S           poison one element of a seeded-random gradient leaf at step S
//! nan@S:substr    ... of the first leaf whose name contains `substr`
//! ```
//! Each clause fires **once** and is then retired, so a supervisor
//! rollback that replays the same step does not re-trip the same fault
//! (which would otherwise livelock recovery).
//!
//! The plan is thread-local: the trainer loop, the registry writes it
//! guards, and the fan-out *decision* all happen on the installing thread
//! (the injected panic itself runs on a worker, but is armed here first).
//! Each test installs its own plan without cross-test interference.

use std::cell::RefCell;

use anyhow::{bail, Context, Result};

use crate::util::rng::Pcg64;

/// Environment variable holding the fault plan.
pub const FAULTS_ENV: &str = "SAGEBWD_FAULTS";

/// Parsed fault schedule (see module docs for the grammar).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Trainer steps at which to panic one fan-out worker.
    pub panics: Vec<u64>,
    /// 1-based registry artifact write ordinals to tear (truncate).
    pub torn: Vec<u64>,
    /// `(step, leaf-name substring)` gradient NaN poisonings.
    pub nans: Vec<(u64, Option<String>)>,
    /// Seed for the slab-choice PRNG.
    pub seed: u64,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.panics.is_empty() && self.torn.is_empty() && self.nans.is_empty()
    }
}

/// Live plan state: the schedule plus consumption bookkeeping.
struct PlanState {
    plan: FaultPlan,
    /// Armed by [`begin_step`], consumed by [`take_worker_panic`].
    panic_armed: bool,
    /// Armed by [`begin_step`], consumed by [`take_nan_slab`].
    nan_armed: Option<Option<String>>,
    /// Count of artifact writes observed so far (1-based ordinals).
    writes: u64,
    rng: Pcg64,
}

thread_local! {
    static STATE: RefCell<Option<PlanState>> = const { RefCell::new(None) };
}

/// Parse a `SAGEBWD_FAULTS` plan string.
pub fn parse_plan(s: &str) -> Result<FaultPlan> {
    let mut plan = FaultPlan::default();
    for clause in s.split([';', ',']).map(str::trim).filter(|c| !c.is_empty()) {
        if let Some(v) = clause.strip_prefix("seed=") {
            plan.seed = v
                .parse::<u64>()
                .with_context(|| format!("fault plan: bad seed in {clause:?}"))?;
        } else if let Some(v) = clause.strip_prefix("panic@") {
            plan.panics.push(
                v.parse::<u64>()
                    .with_context(|| format!("fault plan: bad step in {clause:?}"))?,
            );
        } else if let Some(v) = clause.strip_prefix("torn@") {
            let n = v
                .parse::<u64>()
                .with_context(|| format!("fault plan: bad write ordinal in {clause:?}"))?;
            if n == 0 {
                bail!("fault plan: torn@ ordinals are 1-based, got {clause:?}");
            }
            plan.torn.push(n);
        } else if let Some(v) = clause.strip_prefix("nan@") {
            let (step, leaf) = match v.split_once(':') {
                Some((s, leaf)) => (s, Some(leaf.to_string())),
                None => (v, None),
            };
            plan.nans.push((
                step.parse::<u64>()
                    .with_context(|| format!("fault plan: bad step in {clause:?}"))?,
                leaf,
            ));
        } else {
            bail!(
                "fault plan: unknown clause {clause:?} \
                 (known: seed=N, panic@S, torn@N, nan@S[:leaf])"
            );
        }
    }
    Ok(plan)
}

/// Install a plan on this thread, replacing any previous one.
pub fn install(plan: FaultPlan) {
    let rng = Pcg64::new(plan.seed, 0xFA17);
    STATE.with(|s| {
        *s.borrow_mut() = Some(PlanState {
            plan,
            panic_armed: false,
            nan_armed: None,
            writes: 0,
            rng,
        });
    });
}

/// Install the plan from `SAGEBWD_FAULTS` if set; returns whether one was
/// installed.  Call once per worker thread that drives training.
pub fn install_from_env() -> Result<bool> {
    match std::env::var(FAULTS_ENV) {
        Ok(s) if !s.trim().is_empty() => {
            install(parse_plan(&s).with_context(|| format!("parsing {FAULTS_ENV}={s:?}"))?);
            Ok(true)
        }
        _ => Ok(false),
    }
}

/// Remove any installed plan (tests).
pub fn clear() {
    STATE.with(|s| *s.borrow_mut() = None);
}

/// Whether a plan with any remaining (or armed) faults is installed.
pub fn active() -> bool {
    STATE.with(|s| {
        s.borrow()
            .as_ref()
            .map(|st| !st.plan.is_empty() || st.panic_armed || st.nan_armed.is_some())
            .unwrap_or(false)
    })
}

/// Mark the start of trainer step `step`: arms any panic/NaN clause
/// scheduled for it (retiring the clause so a rollback replay of the same
/// step does not re-fire it).
pub fn begin_step(step: u64) {
    STATE.with(|s| {
        if let Some(st) = s.borrow_mut().as_mut() {
            if let Some(i) = st.plan.panics.iter().position(|&p| p == step) {
                st.plan.panics.remove(i);
                st.panic_armed = true;
            }
            if let Some(i) = st.plan.nans.iter().position(|(n, _)| *n == step) {
                let (_, leaf) = st.plan.nans.remove(i);
                st.nan_armed = Some(leaf);
            }
        }
    });
}

/// Consume an armed worker panic: the caller (the fan-out dispatcher)
/// must make exactly one worker call [`injected_panic`].
pub fn take_worker_panic() -> bool {
    STATE.with(|s| {
        s.borrow_mut()
            .as_mut()
            .map(|st| std::mem::take(&mut st.panic_armed))
            .unwrap_or(false)
    })
}

/// Message carried by an injected worker panic (the fan-out catches the
/// unwind and surfaces this as an error the supervisor can recognize).
pub const INJECTED_PANIC_MSG: &str = "injected worker fault (SAGEBWD_FAULTS)";

/// The injected fault itself — runs on a fan-out worker thread, caught by
/// the dispatcher's `catch_unwind`.
pub fn injected_panic() -> ! {
    // sagebwd-allow(A3): deliberate injected fault, caught by the fan-out dispatcher
    panic!("{}", INJECTED_PANIC_MSG)
}

/// Hook for registry artifact writes: counts every write and, when an
/// armed `torn@N` ordinal is hit, returns the truncated bytes that should
/// land on disk instead (the torn copy keeps at least 1 byte and at most
/// half the payload, so the corruption is always detectable).
pub fn corrupt_write(bytes: &[u8]) -> Option<Vec<u8>> {
    STATE.with(|s| {
        let mut guard = s.borrow_mut();
        let st = guard.as_mut()?;
        st.writes += 1;
        let i = st.plan.torn.iter().position(|&n| n == st.writes)?;
        st.plan.torn.remove(i);
        Some(bytes[..(bytes.len() / 2).max(1).min(bytes.len())].to_vec())
    })
}

/// Consume an armed NaN poisoning: picks the gradient slab to corrupt as
/// `(leaf index, flat index)`.  A named clause (`nan@S:substr`) targets
/// the first leaf whose name contains the substring; otherwise the leaf
/// is drawn from the plan's seeded PRNG.  Leaves with no elements are
/// never chosen.
pub fn take_nan_slab(names: &[String], lens: &[usize]) -> Option<(usize, usize)> {
    STATE.with(|s| {
        let mut guard = s.borrow_mut();
        let st = guard.as_mut()?;
        let filter = st.nan_armed.take()?;
        let candidates: Vec<usize> = (0..names.len()).filter(|&i| lens[i] > 0).collect();
        if candidates.is_empty() {
            return None;
        }
        let leaf = match &filter {
            Some(sub) => candidates
                .iter()
                .copied()
                .find(|&i| names[i].contains(sub.as_str()))?,
            None => candidates[st.rng.below(candidates.len() as u64) as usize],
        };
        let idx = st.rng.below(lens[leaf] as u64) as usize;
        Some((leaf, idx))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn names(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_full_grammar() {
        let p = parse_plan("seed=7; panic@3, torn@2; nan@5:attn; nan@9").unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.panics, vec![3]);
        assert_eq!(p.torn, vec![2]);
        assert_eq!(p.nans, vec![(5, Some("attn".into())), (9, None)]);
        assert!(parse_plan("").unwrap().is_empty());
        assert!(parse_plan("bogus@1").is_err());
        assert!(parse_plan("panic@x").is_err());
        assert!(parse_plan("torn@0").is_err());
    }

    #[test]
    fn panic_arms_once_and_survives_replay() {
        install(parse_plan("panic@2").unwrap());
        begin_step(0);
        assert!(!take_worker_panic());
        begin_step(2);
        assert!(take_worker_panic());
        assert!(!take_worker_panic(), "armed panic is consumed");
        begin_step(2); // rollback replay of the same step
        assert!(!take_worker_panic(), "clause fires once, not per replay");
        assert!(!active());
        clear();
    }

    #[test]
    fn torn_write_hits_exact_ordinal() {
        install(parse_plan("torn@2").unwrap());
        let payload = vec![7u8; 64];
        assert!(corrupt_write(&payload).is_none(), "write 1 untouched");
        let torn = corrupt_write(&payload).expect("write 2 torn");
        assert!(torn.len() < payload.len() && !torn.is_empty());
        assert!(corrupt_write(&payload).is_none(), "write 3 untouched");
        clear();
    }

    #[test]
    fn nan_slab_by_name_and_seeded() {
        install(parse_plan("seed=1; nan@4:k_proj").unwrap());
        begin_step(4);
        let ns = names(&["embed", "blk0.k_proj", "blk0.v_proj"]);
        let (leaf, idx) = take_nan_slab(&ns, &[8, 6, 6]).unwrap();
        assert_eq!(leaf, 1);
        assert!(idx < 6);
        assert!(take_nan_slab(&ns, &[8, 6, 6]).is_none(), "consumed");

        // Unnamed clause: leaf drawn from the seeded PRNG, deterministic.
        install(parse_plan("seed=3; nan@0").unwrap());
        begin_step(0);
        let a = take_nan_slab(&ns, &[8, 6, 6]).unwrap();
        install(parse_plan("seed=3; nan@0").unwrap());
        begin_step(0);
        let b = take_nan_slab(&ns, &[8, 6, 6]).unwrap();
        assert_eq!(a, b);
        clear();
    }

    #[test]
    fn uninstalled_plane_is_inert() {
        clear();
        assert!(!active());
        begin_step(0);
        assert!(!take_worker_panic());
        assert!(corrupt_write(&[1, 2, 3]).is_none());
        assert!(take_nan_slab(&names(&["w"]), &[4]).is_none());
    }
}
