//! Minimal JSON parser/serializer (substrate — no serde in the vendored
//! dependency set).
//!
//! Supports the full JSON grammar the artifact manifests and run configs
//! use: objects, arrays, strings (with escapes), numbers, booleans, null.
//! Numbers are stored as `f64`; integer accessors check exactness.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.  Objects use `BTreeMap` for deterministic
/// serialization order (stable manifests, diff-able checkpoints).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors ----
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // ---- accessors ----
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 || n.abs() > 2f64.powi(53) {
            bail!("expected integer, got {n}");
        }
        Ok(n as i64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        let i = self.as_i64()?;
        usize::try_from(i).map_err(|_| anyhow!("expected non-negative integer, got {i}"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            other => bail!("expected object, got {other:?}"),
        }
    }

    /// Object field lookup with a useful error message.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// Optional object field.
    pub fn get_opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.get(key),
            _ => None,
        }
    }

    pub fn set(&mut self, key: &str, value: Json) {
        if let Json::Obj(o) = self {
            o.insert(key.to_string(), value);
        }
    }

    // ---- serialization ----
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.  Trailing whitespace is allowed; trailing
/// garbage is an error.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value().context("while parsing JSON")?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing garbage at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow!("unexpected end of input"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            bail!("expected {:?} at byte {}, got {:?}", b as char, self.pos - 1, got as char);
        }
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow!("unexpected end of input"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            other => bail!("unexpected byte {:?} at {}", other as char, self.pos),
        }
    }

    fn literal(&mut self, lit: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect_byte(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            let value = self.value()?;
            map.insert(key, value);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(map)),
                other => bail!("expected ',' or '}}', got {:?}", other as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect_byte(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(items)),
                other => bail!("expected ',' or ']', got {:?}", other as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect_byte(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let h = self.bump()?;
                            code = code * 16
                                + (h as char)
                                    .to_digit(16)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?;
                        }
                        // Surrogate pairs: decode if a high surrogate is
                        // followed by \uDC00-\uDFFF.
                        if (0xD800..0xDC00).contains(&code) {
                            self.expect_byte(b'\\')?;
                            self.expect_byte(b'u')?;
                            let mut low = 0u32;
                            for _ in 0..4 {
                                let h = self.bump()?;
                                low = low * 16
                                    + (h as char)
                                        .to_digit(16)
                                        .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            }
                            code = 0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                        }
                        s.push(char::from_u32(code).ok_or_else(|| anyhow!("bad codepoint"))?);
                    }
                    other => bail!("bad escape \\{:?}", other as char),
                },
                b if b < 0x80 => s.push(b as char),
                b => {
                    // Re-assemble multi-byte UTF-8 starting at b.
                    let start = self.pos - 1;
                    let len = utf8_len(b)?;
                    let end = start + len;
                    if end > self.bytes.len() {
                        bail!("truncated UTF-8");
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| anyhow!("invalid UTF-8 in string"))?,
                    );
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        // sagebwd-allow(A3): the number lexer only advanced over ASCII bytes
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let n: f64 = text.parse().with_context(|| format!("bad number {text:?}"))?;
        Ok(Json::Num(n))
    }
}

/// Shared required-key/type checking for the repo's versioned JSON
/// schemas.  One checker, three consumers: `BENCH_*.json`
/// (`bench::check_bench_json`), the artifact manifests
/// (`runtime::manifest`), and the run registry's `sagebwd-run-v1`
/// manifests (`registry::manifest`) — instead of each module hand-rolling
/// its own missing-key/wrong-type errors.
pub mod schema {
    use super::Json;
    use anyhow::{bail, Context, Result};

    /// Check the document's `"schema"` tag.
    pub fn expect_tag(doc: &Json, expected: &str) -> Result<()> {
        let got = str_field(doc, "schema")?;
        if got != expected {
            bail!("schema {got:?} != {expected:?}");
        }
        Ok(())
    }

    /// Required string field.
    pub fn str_field<'a>(obj: &'a Json, key: &str) -> Result<&'a str> {
        obj.get(key)?.as_str().with_context(|| format!("field {key:?}"))
    }

    /// Required number field.
    pub fn f64_field(obj: &Json, key: &str) -> Result<f64> {
        obj.get(key)?.as_f64().with_context(|| format!("field {key:?}"))
    }

    /// Required exact-non-negative-integer field.
    pub fn usize_field(obj: &Json, key: &str) -> Result<usize> {
        obj.get(key)?.as_usize().with_context(|| format!("field {key:?}"))
    }

    /// Required exact-unsigned-integer field.
    pub fn u64_field(obj: &Json, key: &str) -> Result<u64> {
        let i = obj.get(key)?.as_i64().with_context(|| format!("field {key:?}"))?;
        u64::try_from(i).with_context(|| format!("field {key:?}: negative {i}"))
    }

    /// Required array field.
    pub fn arr_field<'a>(obj: &'a Json, key: &str) -> Result<&'a [Json]> {
        obj.get(key)?.as_arr().with_context(|| format!("field {key:?}"))
    }

    /// Required field that is either a number or `null` (absent is an
    /// error — the schema's way of saying "state it explicitly").
    pub fn nullable_f64_field(obj: &Json, key: &str) -> Result<Option<f64>> {
        match obj.get(key)? {
            Json::Null => Ok(None),
            other => Ok(Some(other.as_f64().with_context(|| format!("field {key:?}"))?)),
        }
    }

    /// Optional string field: missing or `null` → `None`.
    pub fn opt_str_field<'a>(obj: &'a Json, key: &str) -> Result<Option<&'a str>> {
        match obj.get_opt(key) {
            None | Some(Json::Null) => Ok(None),
            Some(other) => Ok(Some(other.as_str().with_context(|| format!("field {key:?}"))?)),
        }
    }
}

fn utf8_len(first: u8) -> Result<usize> {
    match first {
        0xC0..=0xDF => Ok(2),
        0xE0..=0xEF => Ok(3),
        0xF0..=0xF7 => Ok(4),
        _ => bail!("invalid UTF-8 lead byte {first:#x}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null],"nested":{"k":"v \"q\" \\"},"unicode":"héllo ∀"}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn surrogate_pair() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn errors() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":1} trailing").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn integer_accessors() {
        assert_eq!(parse("42").unwrap().as_i64().unwrap(), 42);
        assert!(parse("42.5").unwrap().as_i64().is_err());
        assert!(parse("-1").unwrap().as_usize().is_err());
    }

    #[test]
    fn deterministic_object_order() {
        let v = parse(r#"{"z":1,"a":2}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn schema_helpers() {
        let doc = parse(
            r#"{"schema":"demo-v1","name":"x","n":3,"rows":[1],"maybe":null,"neg":-2}"#,
        )
        .unwrap();
        schema::expect_tag(&doc, "demo-v1").unwrap();
        let err = format!("{:#}", schema::expect_tag(&doc, "demo-v2").unwrap_err());
        assert!(err.contains("demo-v1") && err.contains("demo-v2"), "{err}");
        assert_eq!(schema::str_field(&doc, "name").unwrap(), "x");
        assert_eq!(schema::usize_field(&doc, "n").unwrap(), 3);
        assert_eq!(schema::u64_field(&doc, "n").unwrap(), 3);
        assert!(schema::u64_field(&doc, "neg").is_err());
        assert_eq!(schema::arr_field(&doc, "rows").unwrap().len(), 1);
        assert_eq!(schema::nullable_f64_field(&doc, "maybe").unwrap(), None);
        assert_eq!(schema::nullable_f64_field(&doc, "n").unwrap(), Some(3.0));
        assert!(schema::nullable_f64_field(&doc, "absent").is_err());
        assert_eq!(schema::opt_str_field(&doc, "absent").unwrap(), None);
        assert_eq!(schema::opt_str_field(&doc, "maybe").unwrap(), None);
        assert_eq!(schema::opt_str_field(&doc, "name").unwrap(), Some("x"));
        // Errors carry the field name (the shared checker's whole point).
        let err = format!("{:#}", schema::str_field(&doc, "n").unwrap_err());
        assert!(err.contains("\"n\""), "{err}");
    }
}
