//! Substrate utilities: JSON, PRNG, statistics, property testing, timing.

pub mod faults;
pub mod json;
pub mod quickcheck;
pub mod rng;
pub mod stats;

use std::time::Instant;

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Human-readable duration.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2}s")
    } else {
        format!("{:.1}min", s / 60.0)
    }
}

/// Human-readable count (tokens, params).
pub fn fmt_count(n: u64) -> String {
    if n >= 1_000_000_000 {
        format!("{:.2}B", n as f64 / 1e9)
    } else if n >= 1_000_000 {
        format!("{:.2}M", n as f64 / 1e6)
    } else if n >= 1_000 {
        format!("{:.1}K", n as f64 / 1e3)
    } else {
        n.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(5e-6).ends_with("µs"));
        assert!(fmt_secs(5e-2).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
        assert!(fmt_secs(300.0).ends_with("min"));
    }

    #[test]
    fn fmt_count_ranges() {
        assert_eq!(fmt_count(999), "999");
        assert_eq!(fmt_count(2_100_000), "2.10M");
        assert_eq!(fmt_count(78_000_000_000), "78.00B");
    }
}
