//! Mini property-testing framework (substrate — no proptest in the
//! vendored set).
//!
//! A property is a closure from a seeded [`Gen`] to `Result<(), String>`;
//! [`check`] runs it across many seeds and, on failure, reports the seed so
//! the case can be replayed deterministically.  No structural shrinking —
//! generators are seeded, so re-running a failing seed reproduces the case
//! exactly, which is what matters for debugging.

use crate::util::rng::Pcg64;

/// Test-case generator handed to properties.
pub struct Gen {
    pub rng: Pcg64,
    pub size: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.rng.below((hi - lo + 1) as u64) as i64
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.uniform() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.below(items.len() as u64) as usize]
    }

    pub fn vec_f32(&mut self, len: usize, sigma: f32) -> Vec<f32> {
        let mut v = vec![0f32; len];
        self.rng.fill_gaussian(&mut v, sigma);
        v
    }

    pub fn string(&mut self, max_len: usize) -> String {
        let len = self.usize_in(0, max_len);
        (0..len)
            .map(|_| char::from_u32(self.usize_in(32, 126) as u32).unwrap())
            .collect()
    }
}

/// Configuration for a property run.
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Config {
        Config {
            cases: 100,
            // CLAUDE_QC_SEED lets a failing case be replayed exactly.
            seed: std::env::var("QC_SEED")
                .ok()
                .and_then(|s| s.parse().ok())
                .unwrap_or(0xC0FFEE),
        }
    }
}

/// Run `prop` across `cfg.cases` seeds; panic with the failing seed.
pub fn check_with(cfg: Config, name: &str, mut prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    for case in 0..cfg.cases {
        let mut g = Gen {
            rng: Pcg64::new(cfg.seed, case as u64),
            size: 1 + case / 4,
        };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property {name:?} failed on case {case} (QC_SEED={} to replay): {msg}",
                cfg.seed
            );
        }
    }
}

/// Run a property with the default config (100 cases).
pub fn check(name: &str, prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    check_with(Config::default(), name, prop);
}

/// Assert helper producing `Result` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("addition commutes", |g| {
            count += 1;
            let (a, b) = (g.i64_in(-100, 100), g.i64_in(-100, 100));
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
        assert_eq!(count, 100);
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_seed() {
        check("always fails", |_| Err("nope".into()));
    }

    #[test]
    fn generators_are_deterministic_per_case() {
        let mut first: Vec<usize> = Vec::new();
        check_with(Config { cases: 10, seed: 1 }, "collect", |g| {
            first.push(g.usize_in(0, 1000));
            Ok(())
        });
        let mut second: Vec<usize> = Vec::new();
        check_with(Config { cases: 10, seed: 1 }, "collect", |g| {
            second.push(g.usize_in(0, 1000));
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn ranges_respected() {
        check("usize_in bounds", |g| {
            let x = g.usize_in(5, 10);
            if (5..=10).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }
}
